"""L2: Llama-style transformer with an explicit KV cache, built on the L1
Pallas attention kernels.

This is the *compute graph* the Rust coordinator serves. Three entrypoints,
each AOT-lowered to HLO text per pool configuration by ``aot.py``:

* ``decode_step``    — one continuous-batching iteration: every occupied KV
                       slot advances by one token (the paper's Eq. 3 lockstep
                       model).
* ``prefill_chunk``  — one chunked-prefill iteration for a single slot
                       (chunk size C_chunk, the paper's Eq. 4 ceil(L_in/C_chunk)
                       term).
* ``embed_text``     — mean-pooled final hidden state; used by the fidelity
                       study (Table 7) as the semantic-similarity proxy in
                       place of BERTScore (see DESIGN.md §1).

Weights are *runtime arguments*, not baked constants: ``aot.py`` writes them
to ``artifacts/weights.bin`` (flat f32, manifest-ordered) and the Rust
runtime feeds them as leading PJRT inputs. This keeps the HLO text small and
lets one artifact serve any checkpoint with the same shapes.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention, prefill_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Scaled-down Llama-style config (see DESIGN.md §4 live-path scaling)."""

    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    head_dim: int = 16
    ffn_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim


# Parameter manifest: (name, shape) in the exact argument order the HLO
# expects. Rust replays this order when loading weights.bin.
def param_manifest(cfg: ModelConfig):
    entries = [("tok_emb", (cfg.vocab, cfg.d_model))]
    for layer in range(cfg.n_layers):
        p = f"layer{layer}."
        entries += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.qkv_dim)),
            (p + "wk", (cfg.d_model, cfg.qkv_dim)),
            (p + "wv", (cfg.d_model, cfg.qkv_dim)),
            (p + "wo", (cfg.qkv_dim, cfg.d_model)),
            (p + "mlp_norm", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.ffn_dim)),
            (p + "w_up", (cfg.d_model, cfg.ffn_dim)),
            (p + "w_down", (cfg.ffn_dim, cfg.d_model)),
        ]
    entries += [("final_norm", (cfg.d_model,)), ("lm_head", (cfg.d_model, cfg.vocab))]
    return entries


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Seeded synthetic weights (no pretrained checkpoint is available
    offline; see DESIGN.md §1 substitutions)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_manifest(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return params


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def rope(x, positions, theta):
    """Rotary embedding. x: [N, H, D]; positions: [N] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, None, None].astype(jnp.float32) * freqs  # [N, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def _unpack(params, cfg: ModelConfig):
    tok_emb = params[0]
    layers = []
    idx = 1
    for _ in range(cfg.n_layers):
        layers.append(params[idx : idx + 9])
        idx += 9
    final_norm, lm_head = params[idx], params[idx + 1]
    return tok_emb, layers, final_norm, lm_head


# ---------------------------------------------------------------------------
# decode: one lockstep iteration over all S slots
# ---------------------------------------------------------------------------


def decode_step(params, k_cache, v_cache, tokens, pos, cfg: ModelConfig):
    """Advance every slot by one token.

    The cache layout is [S, L, C, H, D] — slot-major — so each slot's block
    is contiguous and identical to ``prefill_chunk``'s [L, C, H, D] layout;
    the Rust coordinator moves slots between prefill and batched decode with
    plain memcpys.

    Args:
      params:  manifest-ordered weight list.
      k_cache: [S, L, C, H, D] key cache.
      v_cache: [S, L, C, H, D] value cache.
      tokens:  [S] int32 the token sampled at the previous step.
      pos:     [S] int32 index this token occupies (its KV write position).

    Returns:
      (logits [S, V], k_cache', v_cache')
    """
    tok_emb, layers, final_norm, lm_head = _unpack(params, cfg)
    S = tokens.shape[0]
    H, D = cfg.n_heads, cfg.head_dim
    x = tok_emb[tokens]  # [S, d]

    def write(cache, val):
        def one(slot_cache, slot_val, slot_pos):
            return jax.lax.dynamic_update_slice(
                slot_cache, slot_val[None], (slot_pos, 0, 0)
            )

        return jax.vmap(one)(cache, val, pos)

    new_k, new_v = [], []
    for li, (attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd) in enumerate(layers):
        h = rms_norm(x, attn_norm)
        q = (h @ wq).reshape(S, H, D)
        k = (h @ wk).reshape(S, H, D)
        v = (h @ wv).reshape(S, H, D)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

        # Scatter the new k/v into each slot's cache at its own position.
        kc = write(k_cache[:, li], k)  # [S, C, H, D]
        vc = write(v_cache[:, li], v)
        new_k.append(kc)
        new_v.append(vc)

        attn = decode_attention(q, kc, vc, pos)  # L1 Pallas kernel
        x = x + attn.reshape(S, H * D) @ wo
        x = x + swiglu(rms_norm(x, mlp_norm), wg, wu, wd)

    logits = rms_norm(x, final_norm) @ lm_head
    return logits, jnp.stack(new_k, axis=1), jnp.stack(new_v, axis=1)


# ---------------------------------------------------------------------------
# prefill: one chunk for a single slot
# ---------------------------------------------------------------------------


def prefill_chunk(params, k_cache, v_cache, tokens, pos_base, cfg: ModelConfig):
    """Process one C_chunk-sized slice of a prompt for one slot.

    Args:
      params:   manifest-ordered weight list.
      k_cache:  [L, C, H, D] this slot's key cache (prefix already filled).
      v_cache:  [L, C, H, D].
      tokens:   [T] int32 chunk tokens (padded; caller tracks valid length).
      pos_base: [] int32 number of tokens already in the cache.

    Returns:
      (logits [T, V], k_cache', v_cache')
    """
    tok_emb, layers, final_norm, lm_head = _unpack(params, cfg)
    T = tokens.shape[0]
    H, D = cfg.n_heads, cfg.head_dim
    positions = pos_base + jnp.arange(T, dtype=jnp.int32)
    x = tok_emb[tokens]  # [T, d]

    new_k, new_v = [], []
    for li, (attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd) in enumerate(layers):
        h = rms_norm(x, attn_norm)
        q = (h @ wq).reshape(T, H, D)
        k = (h @ wk).reshape(T, H, D)
        v = (h @ wv).reshape(T, H, D)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

        kc = jax.lax.dynamic_update_slice(k_cache[li], k, (pos_base, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[li], v, (pos_base, 0, 0))
        new_k.append(kc)
        new_v.append(vc)

        attn = prefill_attention(q, kc, vc, pos_base)  # L1 Pallas kernel
        x = x + attn.reshape(T, H * D) @ wo
        x = x + swiglu(rms_norm(x, mlp_norm), wg, wu, wd)

    logits = rms_norm(x, final_norm) @ lm_head
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# embedding head for the fidelity study
# ---------------------------------------------------------------------------


def embed_text(params, tokens, valid_len, cfg: ModelConfig):
    """Mean-pooled final hidden state over the first ``valid_len`` tokens.

    Runs the full transformer without a persistent cache (pos_base = 0) so
    the HLO is self-contained. Used by Table 7 as the semantic-similarity
    proxy (BERTScore substitute; DESIGN.md §1).
    """
    T = tokens.shape[0]
    H, D = cfg.n_heads, cfg.head_dim

    tok_emb, layers, final_norm, _ = _unpack(params, cfg)
    positions = jnp.arange(T, dtype=jnp.int32)
    x = tok_emb[tokens]
    for li, (attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd) in enumerate(layers):
        h = rms_norm(x, attn_norm)
        q = rope((h @ wq).reshape(T, H, D), positions, cfg.rope_theta)
        k = rope((h @ wk).reshape(T, H, D), positions, cfg.rope_theta)
        v = (h @ wv).reshape(T, H, D)
        attn = prefill_attention(q, k, v, jnp.int32(0))  # causal, full chunk
        x = x + attn.reshape(T, H * D) @ wo
        x = x + swiglu(rms_norm(x, mlp_norm), wg, wu, wd)
    hidden = rms_norm(x, final_norm)  # [T, d]

    mask = (jnp.arange(T) < valid_len).astype(jnp.float32)[:, None]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(hidden * mask, axis=0) / denom
