"""AOT export: lower the L2/L1 graph to HLO *text* artifacts for the Rust
runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the HLO text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (artifacts/):
  decode_short.hlo.txt   one lockstep decode iteration, short pool (S=8, C=256)
  prefill_short.hlo.txt  one prefill chunk for a short-pool slot
  decode_long.hlo.txt    long pool (S=2, C=1024)
  prefill_long.hlo.txt
  embed.hlo.txt          mean-pooled text embedding (fidelity study, Table 7)
  weights.bin            manifest-ordered flat little-endian f32 weights
  manifest.json          shapes + arg order + pool configs for the Rust side

Python runs ONCE at build time; the Rust binary is self-contained after
``make artifacts``.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import ModelConfig, decode_step, embed_text, init_params, param_manifest, prefill_chunk

# Live-path pool configs (scaled-down; DESIGN.md §4). The cliff ratio
# rho_live = n_slots_short / n_slots_long = 4, mirroring the paper's
# short-vs-long slot asymmetry at equal KV budget (8*256 == 2*1024).
POOLS = {
    "short": {"n_slots": 8, "ctx": 256},
    "long": {"n_slots": 2, "ctx": 1024},
}
CHUNK = 64      # live C_chunk
EMBED_LEN = 256  # fixed token window for embed_text


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(out_dir: str, seed: int = 0) -> None:
    cfg = ModelConfig()
    params = init_params(cfg, seed=seed)
    param_specs = [spec(p.shape) for p in params]
    L, H, D = cfg.n_layers, cfg.n_heads, cfg.head_dim

    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}

    for pool, pc in POOLS.items():
        S, C = pc["n_slots"], pc["ctx"]

        dec = functools.partial(decode_step, cfg=cfg)
        lowered = jax.jit(dec).lower(
            param_specs,
            spec((S, L, C, H, D)),
            spec((S, L, C, H, D)),
            spec((S,), jnp.int32),
            spec((S,), jnp.int32),
        )
        name = f"decode_{pool}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        artifacts[name] = {
            "pool": pool,
            "kind": "decode",
            "n_slots": S,
            "ctx": C,
            "args": "params*, k_cache[S,L,C,H,D], v_cache, tokens[S]i32, pos[S]i32",
            "outputs": "logits[S,V], k_cache, v_cache",
        }

        pre = functools.partial(prefill_chunk, cfg=cfg)
        lowered = jax.jit(pre).lower(
            param_specs,
            spec((L, C, H, D)),
            spec((L, C, H, D)),
            spec((CHUNK,), jnp.int32),
            spec((), jnp.int32),
        )
        name = f"prefill_{pool}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        artifacts[name] = {
            "pool": pool,
            "kind": "prefill",
            "chunk": CHUNK,
            "ctx": C,
            "args": "params*, k_cache[L,C,H,D], v_cache, tokens[T]i32, pos_base i32",
            "outputs": "logits[T,V], k_cache, v_cache",
        }

    emb = functools.partial(embed_text, cfg=cfg)
    lowered = jax.jit(emb).lower(
        param_specs, spec((EMBED_LEN,), jnp.int32), spec((), jnp.int32)
    )
    with open(os.path.join(out_dir, "embed.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts["embed"] = {
        "kind": "embed",
        "len": EMBED_LEN,
        "args": "params*, tokens[T]i32, valid_len i32",
        "outputs": "embedding[d]",
    }

    # Flat weights + manifest.
    blob = b"".join(np.asarray(p, dtype="<f4").tobytes() for p in params)
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        f.write(blob)

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "ffn_dim": cfg.ffn_dim,
            "rope_theta": cfg.rope_theta,
            "seed": seed,
        },
        "pools": POOLS,
        "chunk": CHUNK,
        "embed_len": EMBED_LEN,
        "params": [
            {"name": n, "shape": list(s)} for n, s in param_manifest(cfg)
        ],
        "weights_sha256": hashlib.sha256(blob).hexdigest(),
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    total = sum(int(np.prod(s)) for _, s in param_manifest(cfg))
    print(f"wrote {len(artifacts)} HLO artifacts + {total} weights to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build_artifacts(args.out, seed=args.seed)


if __name__ == "__main__":
    main()
