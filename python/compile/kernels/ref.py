"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the L1 kernels are validated against (pytest +
hypothesis). They are deliberately written in the most direct way possible —
no tiling, no online softmax — so that a mismatch always implicates the
kernel, not the oracle.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, pos):
    """Batched single-token decode attention over a KV cache.

    Args:
      q:        [S, H, D]  query for the token just written at index ``pos``.
      k_cache:  [S, C, H, D] key cache (position ``pos`` already updated).
      v_cache:  [S, C, H, D] value cache.
      pos:      [S] int32, index of the newest token per slot. Slot ``s``
                attends to cache positions ``0..pos[s]`` inclusive.

    Returns:
      [S, H, D] attention output.
    """
    S, C, H, D = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, dtype=q.dtype))
    # scores[s, h, c] = q[s, h, :] . k_cache[s, c, h, :]
    scores = jnp.einsum("shd,schd->shc", q, k_cache) * scale
    idx = jnp.arange(C)[None, None, :]
    mask = idx <= pos[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("shc,schd->shd", probs, v_cache)


def prefill_attention_ref(q, k_cache, v_cache, pos_base):
    """Chunked-prefill attention for a single slot.

    Query row ``i`` (global position ``pos_base + i``) attends to cache
    positions ``0..pos_base + i`` inclusive. The cache must already contain
    the chunk's keys/values at ``[pos_base : pos_base + T]``.

    Args:
      q:        [T, H, D] chunk queries (RoPE already applied).
      k_cache:  [C, H, D] key cache.
      v_cache:  [C, H, D] value cache.
      pos_base: scalar int32, number of tokens in the cache before the chunk.

    Returns:
      [T, H, D] attention output for the chunk.
    """
    T, H, D = q.shape
    C = k_cache.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, dtype=q.dtype))
    scores = jnp.einsum("thd,chd->htc", q, k_cache) * scale  # [H, T, C]
    rows = jnp.arange(T)[:, None]
    cols = jnp.arange(C)[None, :]
    mask = cols <= (pos_base + rows)  # [T, C]
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("htc,chd->thd", probs, v_cache)
