"""L1 Pallas kernels: the serving hot-spot (KV-cache attention).

Two kernels, both written TPU-first and executed with ``interpret=True`` on
this CPU-only image (real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot run — see DESIGN.md §Hardware-Adaptation):

* ``decode_attention`` — batched single-token decode over the KV cache,
  FlashAttention-style online softmax over KV *pages*. The page loop is a
  grid dimension, so on a real TPU each page's K/V tiles are staged
  HBM→VMEM by the Pallas pipeline while the previous page is being reduced
  (the role threadblock double-buffering plays in the CUDA formulation).
  Running max / denominator / weighted accumulator live in VMEM scratch.
* ``prefill_attention`` — chunked-prefill attention for one slot: the
  chunk's T queries attend causally to the cache prefix plus the chunk
  itself. T×page score tiles are MXU-shaped matmuls.

Both are validated against ``ref.py`` by pytest + hypothesis sweeps over
shapes, page sizes, and positions.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, page: int, pages: int):
    """One (slot, head, kv-page) grid step of online-softmax decode.

    Block shapes:
      pos_ref: [1]        (SMEM-ish scalar: newest-token index for the slot)
      q_ref:   [1, 1, D]
      k_ref:   [1, P, 1, D]
      v_ref:   [1, P, 1, D]
      o_ref:   [1, 1, D]  (revisited across the page grid dimension)
      scratch: m [1, 1], l [1, 1], acc [1, D]
    """
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :]                      # [D]
    k = k_ref[0, :, 0, :]                   # [P, D]
    v = v_ref[0, :, 0, :]                   # [P, D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))

    # [P] scores for this page; MXU-friendly as a [P, D] x [D] contraction.
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale

    # Causal / length mask: global cache index <= pos (newest token incl.).
    base = p * page
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)[:, 0]
    s = jnp.where(idx <= pos_ref[0], s, NEG_INF)

    # Online softmax update.
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p_exp = jnp.exp(s - m_new)              # [P]
    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p_exp)
    acc_ref[0, :] = acc_ref[0, :] * alpha + jnp.dot(
        p_exp, v, preferred_element_type=jnp.float32
    )
    m_ref[0, 0] = m_new

    @pl.when(p == pages - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_ref[0, :] / l_ref[0, 0]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, page: int = 128):
    """Batched decode attention. See ``ref.decode_attention_ref``.

    Args:
      q:        [S, H, D] new-token queries (RoPE applied).
      k_cache:  [S, C, H, D]; position ``pos[s]`` already holds the new key.
      v_cache:  [S, C, H, D].
      pos:      [S] int32 newest-token index per slot.
      page:     KV page length staged through VMEM per grid step.

    Returns:
      [S, H, D] attention output.
    """
    S, C, H, D = k_cache.shape
    if C % page != 0:
        page = C  # degenerate single-page fallback for odd shapes
    pages = C // page

    kernel = functools.partial(_decode_kernel, page=page, pages=pages)
    return pl.pallas_call(
        kernel,
        grid=(S, H, pages),
        in_specs=[
            pl.BlockSpec((1,), lambda s, h, p: (s,)),
            pl.BlockSpec((1, 1, D), lambda s, h, p: (s, h, 0)),
            pl.BlockSpec((1, page, 1, D), lambda s, h, p: (s, p, h, 0)),
            pl.BlockSpec((1, page, 1, D), lambda s, h, p: (s, p, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda s, h, p: (s, h, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=True,
    )(pos, q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# prefill (chunked) attention
# ---------------------------------------------------------------------------


def _prefill_kernel(base_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                    acc_ref, *, page: int, pages: int, chunk: int):
    """One (head, kv-page) grid step of chunked-prefill flash attention.

    Block shapes:
      base_ref: [1]          (pos_base: tokens already in cache before chunk)
      q_ref:    [T, 1, D]
      k_ref:    [P, 1, D]
      v_ref:    [P, 1, D]
      o_ref:    [T, 1, D]    (revisited across pages)
      scratch:  m [T, 1], l [T, 1], acc [T, D]
    """
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[:, 0, :]                      # [T, D]
    k = k_ref[:, 0, :]                      # [P, D]
    v = v_ref[:, 0, :]                      # [P, D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [T, P]

    # Row i (global position base + i) attends to cache index <= base + i.
    base = base_ref[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 0)
    cols = p * page + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 1)
    s = jnp.where(cols <= base + rows, s, NEG_INF)

    m_prev = m_ref[:, 0]                    # [T]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)         # [T]
    p_exp = jnp.exp(s - m_new[:, None])     # [T, P]
    l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p_exp, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p_exp, v, preferred_element_type=jnp.float32
    )
    m_ref[:, 0] = m_new

    @pl.when(p == pages - 1)
    def _finalize():
        o_ref[:, 0, :] = (acc_ref[...] / l_ref[:, 0][:, None]).astype(
            o_ref.dtype
        )


def prefill_attention(q, k_cache, v_cache, pos_base, *, page: int = 128):
    """Chunked-prefill attention for one slot. See ``ref.prefill_attention_ref``.

    Args:
      q:        [T, H, D] chunk queries (RoPE applied at pos_base..pos_base+T-1).
      k_cache:  [C, H, D]; ``[pos_base : pos_base+T]`` already holds the chunk.
      v_cache:  [C, H, D].
      pos_base: [] or [1] int32.
      page:     KV page length per grid step.

    Returns:
      [T, H, D] attention output for the chunk.
    """
    T, H, D = q.shape
    C = k_cache.shape[0]
    if C % page != 0:
        page = C
    pages = C // page
    base = jnp.reshape(jnp.asarray(pos_base, dtype=jnp.int32), (1,))

    kernel = functools.partial(
        _prefill_kernel, page=page, pages=pages, chunk=T
    )
    return pl.pallas_call(
        kernel,
        grid=(H, pages),
        in_specs=[
            pl.BlockSpec((1,), lambda h, p: (0,)),
            pl.BlockSpec((T, 1, D), lambda h, p: (0, h, 0)),
            pl.BlockSpec((page, 1, D), lambda h, p: (p, h, 0)),
            pl.BlockSpec((page, 1, D), lambda h, p: (p, h, 0)),
        ],
        out_specs=pl.BlockSpec((T, 1, D), lambda h, p: (0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((T, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, D), jnp.float32),
        ],
        interpret=True,
    )(base, q, k_cache, v_cache)
