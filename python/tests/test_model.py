"""L2 model correctness: shapes, prefill/decode consistency, embed masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    decode_step,
    embed_text,
    init_params,
    param_manifest,
    prefill_chunk,
)

CFG = ModelConfig()
PARAMS = init_params(CFG, seed=0)
TOL = dict(rtol=2e-4, atol=2e-4)


def zero_cache(S=None, C=64):
    # Decode caches are slot-major [S, L, C, H, D]; prefill is [L, C, H, D].
    L, H, D = CFG.n_layers, CFG.n_heads, CFG.head_dim
    shape = (S, L, C, H, D) if S is not None else (L, C, H, D)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def toks(seed, n):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=n), jnp.int32)


class TestManifest:
    def test_param_count_matches_manifest(self):
        assert len(PARAMS) == len(param_manifest(CFG))

    def test_manifest_shapes_match(self):
        for p, (name, shape) in zip(PARAMS, param_manifest(CFG)):
            assert p.shape == shape, name

    def test_manifest_order_is_stable(self):
        names = [n for n, _ in param_manifest(CFG)]
        assert names[0] == "tok_emb"
        assert names[-1] == "lm_head"
        assert names[1] == "layer0.attn_norm"


class TestPrefill:
    def test_shapes(self):
        C, T = 64, 16
        kc, vc = zero_cache(C=C)
        logits, kc2, vc2 = prefill_chunk(PARAMS, kc, vc, toks(0, T), jnp.int32(0), CFG)
        assert logits.shape == (T, CFG.vocab)
        assert kc2.shape == kc.shape and vc2.shape == vc.shape

    def test_chunked_equals_oneshot(self):
        """Prefilling in two chunks must produce the same logits and cache
        as one big chunk — the invariant the scheduler relies on."""
        C, T = 128, 32
        tokens = toks(1, T)
        kc, vc = zero_cache(C=C)
        logits_full, kcf, vcf = prefill_chunk(PARAMS, kc, vc, tokens, jnp.int32(0), CFG)

        kc1, vc1 = zero_cache(C=C)
        logits_a, kc1, vc1 = prefill_chunk(PARAMS, kc1, vc1, tokens[:16], jnp.int32(0), CFG)
        logits_b, kc1, vc1 = prefill_chunk(PARAMS, kc1, vc1, tokens[16:], jnp.int32(16), CFG)

        np.testing.assert_allclose(logits_full[:16], logits_a, **TOL)
        np.testing.assert_allclose(logits_full[16:], logits_b, **TOL)
        np.testing.assert_allclose(kcf[:, :T], kc1[:, :T], **TOL)

    def test_cache_prefix_untouched(self):
        """A chunk at pos_base=b must not modify cache entries < b."""
        C = 128
        kc, vc = zero_cache(C=C)
        _, kc, vc = prefill_chunk(PARAMS, kc, vc, toks(2, 16), jnp.int32(0), CFG)
        before_k = kc[:, :16].copy()
        _, kc2, _ = prefill_chunk(PARAMS, kc, vc, toks(3, 16), jnp.int32(16), CFG)
        np.testing.assert_allclose(kc2[:, :16], before_k, rtol=0, atol=0)


class TestDecode:
    def test_shapes(self):
        S, C = 4, 64
        kc, vc = zero_cache(S=S, C=C)
        tokens = toks(4, S)
        pos = jnp.zeros((S,), jnp.int32)
        logits, kc2, vc2 = decode_step(PARAMS, kc, vc, tokens, pos, CFG)
        assert logits.shape == (S, CFG.vocab)
        assert kc2.shape == kc.shape

    def test_decode_consistent_with_prefill(self):
        """decode_step(t_n at pos n) after prefill(t_0..t_{n-1}) must equal
        the last-row logits of prefill(t_0..t_n)."""
        C, n = 128, 20
        tokens = toks(5, n + 1)

        kc, vc = zero_cache(C=C)
        logits_full, _, _ = prefill_chunk(PARAMS, kc, vc, tokens, jnp.int32(0), CFG)
        want = logits_full[n]

        kc, vc = zero_cache(C=C)
        _, kc, vc = prefill_chunk(PARAMS, kc, vc, tokens[:n], jnp.int32(0), CFG)
        # lift the single-slot cache into a batched [1, L, C, H, D] cache
        kcb, vcb = kc[None], vc[None]
        got, _, _ = decode_step(
            PARAMS, kcb, vcb, tokens[n:][:1], jnp.asarray([n], jnp.int32), CFG
        )
        np.testing.assert_allclose(got[0], want, rtol=5e-4, atol=5e-4)

    def test_slots_are_independent(self):
        """Changing slot 1's cache/token must not change slot 0's logits."""
        S, C = 2, 64
        kc, vc = zero_cache(S=S, C=C)
        tokens = toks(6, S)
        pos = jnp.asarray([3, 7], jnp.int32)
        l1, _, _ = decode_step(PARAMS, kc, vc, tokens, pos, CFG)
        kc2 = kc.at[1].set(9.0)
        tokens2 = tokens.at[1].set((tokens[1] + 1) % CFG.vocab)
        l2, _, _ = decode_step(PARAMS, kc2, vc, tokens2, pos, CFG)
        np.testing.assert_allclose(l1[0], l2[0], **TOL)
        assert not np.allclose(l1[1], l2[1], **TOL)

    def test_greedy_generation_runs(self):
        """Short end-to-end generation loop: prefill then 8 greedy steps."""
        C, n = 64, 10
        prompt = toks(7, n)
        kc, vc = zero_cache(C=C)
        logits, kc, vc = prefill_chunk(PARAMS, kc, vc, prompt, jnp.int32(0), CFG)
        tok = jnp.argmax(logits[n - 1]).astype(jnp.int32)
        kcb, vcb = kc[None], vc[None]
        out = []
        for i in range(8):
            logits, kcb, vcb = decode_step(
                PARAMS, kcb, vcb, tok[None], jnp.asarray([n + i], jnp.int32), CFG
            )
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            out.append(int(tok))
        assert len(out) == 8
        assert all(0 <= t < CFG.vocab for t in out)


class TestEmbed:
    def test_shape_and_finite(self):
        emb = embed_text(PARAMS, toks(8, 64), jnp.int32(40), CFG)
        assert emb.shape == (CFG.d_model,)
        assert bool(jnp.all(jnp.isfinite(emb)))

    def test_padding_invariance(self):
        """Tokens beyond valid_len must not affect the embedding (causal
        attention + masked mean-pool)."""
        tokens = toks(9, 64)
        emb1 = embed_text(PARAMS, tokens, jnp.int32(30), CFG)
        poisoned = tokens.at[30:].set(5)
        emb2 = embed_text(PARAMS, poisoned, jnp.int32(30), CFG)
        np.testing.assert_allclose(emb1, emb2, rtol=1e-5, atol=1e-5)

    def test_different_text_different_embedding(self):
        emb1 = embed_text(PARAMS, toks(10, 64), jnp.int32(64), CFG)
        emb2 = embed_text(PARAMS, toks(11, 64), jnp.int32(64), CFG)
        assert not np.allclose(emb1, emb2, rtol=1e-3, atol=1e-3)
