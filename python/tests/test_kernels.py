"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes, page sizes, and cache positions; every example
asserts allclose against ref.py. This is the core correctness signal for
the kernels that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention, prefill_attention
from compile.kernels.ref import decode_attention_ref, prefill_attention_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


class TestDecodeAttention:
    @pytest.mark.parametrize("S", [1, 2, 8])
    @pytest.mark.parametrize("C,page", [(64, 32), (128, 128), (256, 64)])
    def test_matches_ref_grid(self, S, C, page):
        H, D = 4, 16
        q = rand(1, (S, H, D))
        k = rand(2, (S, C, H, D))
        v = rand(3, (S, C, H, D))
        pos = jnp.asarray(np.arange(S) * (C // max(S, 1)) % C, jnp.int32)
        out = decode_attention(q, k, v, pos, page=page)
        ref = decode_attention_ref(q, k, v, pos)
        np.testing.assert_allclose(out, ref, **TOL)

    def test_pos_zero_attends_only_first(self):
        """pos=0 means only cache index 0 is visible: output == v[:, 0]."""
        S, C, H, D = 2, 64, 2, 8
        q = rand(4, (S, H, D))
        k = rand(5, (S, C, H, D))
        v = rand(6, (S, C, H, D))
        pos = jnp.zeros((S,), jnp.int32)
        out = decode_attention(q, k, v, pos, page=32)
        np.testing.assert_allclose(out, v[:, 0], **TOL)

    def test_garbage_beyond_pos_is_masked(self):
        """Poisoning the cache beyond pos must not change the output."""
        S, C, H, D = 2, 128, 2, 8
        q = rand(7, (S, H, D))
        k = rand(8, (S, C, H, D))
        v = rand(9, (S, C, H, D))
        pos = jnp.asarray([10, 63], jnp.int32)
        out1 = decode_attention(q, k, v, pos, page=64)
        k2 = k.at[:, 90:].set(1e9)
        v2 = v.at[:, 90:].set(-1e9)
        out2 = decode_attention(q, k2, v2, pos, page=64)
        np.testing.assert_allclose(out1, out2, **TOL)

    def test_odd_context_falls_back_to_single_page(self):
        S, C, H, D = 1, 96, 2, 8  # 96 % 64 != 0 -> single page
        q = rand(10, (S, H, D))
        k = rand(11, (S, C, H, D))
        v = rand(12, (S, C, H, D))
        pos = jnp.asarray([50], jnp.int32)
        out = decode_attention(q, k, v, pos, page=64)
        ref = decode_attention_ref(q, k, v, pos)
        np.testing.assert_allclose(out, ref, **TOL)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        S=st.integers(1, 6),
        logC=st.integers(5, 9),
        H=st.sampled_from([1, 2, 4]),
        D=st.sampled_from([8, 16, 32]),
        page_div=st.sampled_from([1, 2, 4]),
    )
    def test_hypothesis_sweep(self, seed, S, logC, H, D, page_div):
        C = 1 << logC
        page = C // page_div
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(S, C, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(S, C, H, D)), jnp.float32)
        pos = jnp.asarray(rng.integers(0, C, size=S), jnp.int32)
        out = decode_attention(q, k, v, pos, page=page)
        ref = decode_attention_ref(q, k, v, pos)
        np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------


class TestPrefillAttention:
    @pytest.mark.parametrize("T", [1, 16, 64])
    @pytest.mark.parametrize("C,page", [(128, 64), (256, 256), (512, 128)])
    def test_matches_ref_grid(self, T, C, page):
        H, D = 4, 16
        q = rand(20, (T, H, D))
        k = rand(21, (C, H, D))
        v = rand(22, (C, H, D))
        base = min(C - T, 37)
        out = prefill_attention(q, k, v, base, page=page)
        ref = prefill_attention_ref(q, k, v, base)
        np.testing.assert_allclose(out, ref, **TOL)

    def test_base_zero_first_row_sees_only_itself(self):
        """Row 0 at base 0 attends only to cache[0]: output == v[0]."""
        T, C, H, D = 8, 64, 2, 8
        q = rand(23, (T, H, D))
        k = rand(24, (C, H, D))
        v = rand(25, (C, H, D))
        out = prefill_attention(q, k, v, 0, page=32)
        np.testing.assert_allclose(out[0], v[0], **TOL)

    def test_causality_future_cache_is_masked(self):
        T, C, H, D = 16, 128, 2, 8
        q = rand(26, (T, H, D))
        k = rand(27, (C, H, D))
        v = rand(28, (C, H, D))
        base = 30
        out1 = prefill_attention(q, k, v, base, page=64)
        # poison strictly-future cache (> base + T - 1)
        k2 = k.at[base + T :].set(1e9)
        v2 = v.at[base + T :].set(-1e9)
        out2 = prefill_attention(q, k2, v2, base, page=64)
        np.testing.assert_allclose(out1, out2, **TOL)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        T=st.sampled_from([1, 4, 16, 32]),
        logC=st.integers(6, 9),
        H=st.sampled_from([1, 2, 4]),
        D=st.sampled_from([8, 16]),
        page_div=st.sampled_from([1, 2, 4]),
    )
    def test_hypothesis_sweep(self, seed, T, logC, H, D, page_div):
        C = 1 << logC
        page = C // page_div
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(T, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(C, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(C, H, D)), jnp.float32)
        base = int(rng.integers(0, C - T + 1))
        out = prefill_attention(q, k, v, base, page=page)
        ref = prefill_attention_ref(q, k, v, base)
        np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)
