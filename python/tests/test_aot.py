"""AOT contract tests: the manifest/weights/HLO bundle the Rust runtime
consumes must stay consistent with model.py."""

import json
import os

import numpy as np
import pytest

from compile.model import ModelConfig, param_manifest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_matches_model_config(manifest):
    cfg = ModelConfig()
    m = manifest["model"]
    assert m["vocab"] == cfg.vocab
    assert m["d_model"] == cfg.d_model
    assert m["n_layers"] == cfg.n_layers
    assert m["n_heads"] == cfg.n_heads
    assert m["head_dim"] == cfg.head_dim


def test_param_order_matches(manifest):
    cfg = ModelConfig()
    want = [(n, list(s)) for n, s in param_manifest(cfg)]
    got = [(p["name"], p["shape"]) for p in manifest["params"]]
    assert got == want


def test_weights_bin_size_and_values(manifest):
    total = sum(int(np.prod(p["shape"])) for p in manifest["params"])
    blob = np.fromfile(os.path.join(ART, "weights.bin"), dtype="<f4")
    assert blob.size == total
    assert np.all(np.isfinite(blob))
    # Norm layers are ones.
    off = 0
    for p in manifest["params"]:
        n = int(np.prod(p["shape"]))
        if p["name"].endswith("norm"):
            assert np.all(blob[off : off + n] == 1.0), p["name"]
        off += n


def test_live_pools_express_a_cliff(manifest):
    pools = manifest["pools"]
    s, l = pools["short"], pools["long"]
    # Equal KV budget, slot-count cliff (DESIGN.md §4).
    assert s["n_slots"] * s["ctx"] == l["n_slots"] * l["ctx"]
    assert s["n_slots"] > l["n_slots"]


def test_all_hlo_artifacts_exist(manifest):
    for name in manifest["artifacts"]:
        path = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_weights_sha_matches(manifest):
    import hashlib

    with open(os.path.join(ART, "weights.bin"), "rb") as f:
        blob = f.read()
    assert hashlib.sha256(blob).hexdigest() == manifest["weights_sha256"]
