//! Bench E4 — regenerates paper Table 4 (compressor latency on each
//! workload's borderline band) and breaks the pipeline into stages for the
//! §Perf analysis.

use std::time::Instant;

use fleetopt::compress::corpus;
use fleetopt::compress::doc::Document;
use fleetopt::compress::extractive::compress_doc;
use fleetopt::compress::scoring;
use fleetopt::compress::textrank::textrank;
use fleetopt::compress::tfidf::sentence_scores;
use fleetopt::experiments;
use fleetopt::util::rng::Rng;
use fleetopt::workload::traces;

fn main() {
    let docs = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let t = experiments::table4(docs);
    t.print();
    println!("paper Table 4 (Xeon 8568Y+): Azure p50 1.8 p99 6.5 | LMSYS 1.2/5.2 | Agent 3.4/7.8 ms");

    // Stage breakdown on the heaviest band (Agent, 8K-12K tokens).
    let w = traces::agent_heavy();
    let mut rng = Rng::new(99);
    let text = corpus::generate_borderline(w.b_short, w.gamma, &mut rng);
    let reps = 5;

    let t0 = Instant::now();
    let mut doc = Document::parse(&text);
    for _ in 1..reps {
        doc = Document::parse(&text);
    }
    let parse_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(textrank(&doc));
    }
    let textrank_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(sentence_scores(&doc));
    }
    let tfidf_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(scoring::novelty_scores(&doc));
    }
    let novelty_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(compress_doc(&doc, w.b_short - 512));
    }
    let select_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    println!(
        "\nstage breakdown ({} sentences, {} tokens):",
        doc.n_sentences(),
        doc.total_tokens()
    );
    println!("  parse+tokenize : {parse_ms:8.2} ms");
    println!("  textrank       : {textrank_ms:8.2} ms");
    println!("  tf-idf         : {tfidf_ms:8.2} ms");
    println!("  novelty        : {novelty_ms:8.2} ms");
    println!("  score+select   : {select_ms:8.2} ms (includes all scoring)");
}
