//! Ablation bench — the §6 "Critical: mu_l recalibration" claim: skipping
//! the post-compression long-pool recalibration systematically
//! overestimates the savings of larger gamma (and would under-provision
//! the fleet). Reports correct vs naive long-pool sizes per gamma.

use fleetopt::planner::{plan_fleet, plan_fleet_no_recalibration, PlanInput};
use fleetopt::util::table::Table;
use fleetopt::workload::traces;

fn main() {
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        let mut t = Table::new(
            &format!("mu_l recalibration ablation — {} (B = {})", w.name, w.b_short),
            &["gamma", "n_l correct", "n_l naive", "underprovision", "claimed extra saving"],
        );
        for gamma in [1.2f64, 1.5, 2.0] {
            let correct = plan_fleet(&input, w.b_short, gamma).unwrap();
            let naive = plan_fleet_no_recalibration(&input, w.b_short, gamma).unwrap();
            let under = correct.long.n_gpus as i64 - naive.long.n_gpus as i64;
            t.row(&[
                format!("{gamma:.1}"),
                correct.long.n_gpus.to_string(),
                naive.long.n_gpus.to_string(),
                format!("{under:+} GPUs"),
                format!(
                    "{:.1}%",
                    100.0 * (correct.cost_yr - naive.cost_yr) / correct.cost_yr.max(1.0)
                ),
            ]);
        }
        t.print();
    }
    println!("paper §6: skipping recalibration overestimates savings from larger gamma");
}
