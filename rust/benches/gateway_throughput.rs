//! Bench — C&R gateway hot path (§Perf): single-thread compression
//! throughput and latency at trace-realistic document sizes, old path vs
//! new path, plus the isolated similarity-graph comparison (naive
//! all-pairs vs inverted index). Emits `BENCH_gateway.json` at the repo
//! root so the perf trajectory is tracked across PRs.
//!
//! Paths compared:
//! * **naive**: fresh `Document::parse` + all-pairs TextRank per request —
//!   the pre-§Perf behavior.
//! * **fast**: the gateway's real path — one reused `CompressScratch`
//!   (arena interner, postings-list TextRank, recycled buffers).
//!
//! Selection output is asserted byte-identical across paths before any
//! timing is reported.

use std::time::Instant;

use fleetopt::compress::corpus;
use fleetopt::router::memo::RouteCache;
use fleetopt::router::{effective_workers, Gateway, GatewayConfig, RoutedRequest};
use fleetopt::compress::doc::{overlap, Document};
use fleetopt::compress::extractive::compress_doc_with_mode;
use fleetopt::compress::scratch::CompressScratch;
use fleetopt::compress::textrank::{
    centrality_into, textrank_naive, SimilarityMode, TextrankScratch,
};
use fleetopt::compress::tfidf::sentence_scores_soa;
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::util::json::{obj, Json};
use fleetopt::util::rng::Rng;
use fleetopt::util::simd::{with_dispatch, Dispatch};
use fleetopt::util::stats::Samples;
use fleetopt::workload::traces;

fn main() {
    let n_docs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let w = traces::agent_heavy();
    let mut rng = Rng::new(0xBE7C);
    let docs: Vec<String> = (0..n_docs)
        .map(|_| corpus::generate_borderline_for(&w, &mut rng))
        .collect();
    let budget = w.b_short - 512;
    let parsed: Vec<Document> = docs.iter().map(|d| Document::parse(d)).collect();
    let avg_sentences =
        parsed.iter().map(Document::n_sentences).sum::<usize>() as f64 / n_docs as f64;
    let avg_tokens = docs.iter().map(|d| count_tokens(d) as u64).sum::<u64>() as f64
        / n_docs as f64;
    println!(
        "gateway hot path — {n_docs} borderline docs (avg {avg_sentences:.0} sentences, \
         {avg_tokens:.0} tokens), budget {budget}"
    );

    // --- correctness gate: byte-identical selection across paths ---------
    let mut scratch = CompressScratch::new();
    for (doc, text) in parsed.iter().zip(&docs) {
        let naive = compress_doc_with_mode(doc, budget, SimilarityMode::AllPairs);
        let fast = scratch.compress(text, budget);
        assert_eq!(naive.text, fast.text, "selection must be byte-identical");
        assert_eq!(naive.selected, fast.selected);
    }
    println!("selection output: byte-identical across paths ({n_docs}/{n_docs} docs)");

    // --- isolated similarity-graph stage: all-pairs vs inverted index ----
    let reps = 3usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        for doc in &parsed {
            std::hint::black_box(textrank_naive(doc));
        }
    }
    let allpairs_ms = t0.elapsed().as_secs_f64() * 1e3 / (reps * n_docs) as f64;

    let mut ts = TextrankScratch::default();
    let mut out = Vec::new();
    let t0 = Instant::now();
    for _ in 0..reps {
        for doc in &parsed {
            centrality_into(doc, SimilarityMode::InvertedIndex, &mut ts, &mut out);
            std::hint::black_box(out.last().copied());
        }
    }
    let indexed_ms = t0.elapsed().as_secs_f64() * 1e3 / (reps * n_docs) as f64;
    let stage_speedup = allpairs_ms / indexed_ms.max(1e-9);
    println!(
        "textrank stage     : all-pairs {allpairs_ms:8.3} ms/doc | inverted {indexed_ms:8.3} \
         ms/doc | speedup {stage_speedup:5.2}x"
    );

    // --- end-to-end request path: naive vs scratch -----------------------
    let mut naive_lat = Samples::with_capacity(n_docs);
    let t0 = Instant::now();
    for text in &docs {
        let t1 = Instant::now();
        let doc = Document::parse(text);
        std::hint::black_box(compress_doc_with_mode(&doc, budget, SimilarityMode::AllPairs).ok);
        naive_lat.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    let naive_total_s = t0.elapsed().as_secs_f64();

    let mut fast_lat = Samples::with_capacity(n_docs);
    let t0 = Instant::now();
    for text in &docs {
        let t1 = Instant::now();
        std::hint::black_box(scratch.compress(text, budget).ok);
        fast_lat.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    let fast_total_s = t0.elapsed().as_secs_f64();

    let naive_rps = n_docs as f64 / naive_total_s;
    let fast_rps = n_docs as f64 / fast_total_s;
    let e2e_speedup = fast_rps / naive_rps.max(1e-9);
    println!(
        "end-to-end request : naive {naive_rps:7.1} req/s (p50 {:.2} p99 {:.2} ms)",
        naive_lat.p50(),
        naive_lat.p99()
    );
    println!(
        "                     fast  {fast_rps:7.1} req/s (p50 {:.2} p99 {:.2} ms) | \
         speedup {e2e_speedup:5.2}x",
        fast_lat.p50(),
        fast_lat.p99()
    );
    println!("acceptance: similarity-stage speedup >= 5x on >=100-sentence docs");

    // --- SIMD dispatch: scalar oracles vs vectorized kernels (PR 6) ------
    // Selections must be byte-identical across dispatch modes before any
    // speedup is reported (the tentpole identity policy).
    for doc in &parsed {
        let a = with_dispatch(Dispatch::ForceScalar, || {
            compress_doc_with_mode(doc, budget, SimilarityMode::InvertedIndex)
        });
        let b = with_dispatch(Dispatch::ForceSimd, || {
            compress_doc_with_mode(doc, budget, SimilarityMode::InvertedIndex)
        });
        assert_eq!(a.text, b.text, "dispatch mode must not change selection");
        assert_eq!(a.selected, b.selected);
    }

    // Scoring stage (the CI-gated kernel): TF-IDF sentence salience,
    // per-occurrence `ln` (scalar) vs per-distinct-word weight table.
    let score_reps = 40usize;
    let (mut df, mut tf, mut wt, mut scores) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut time_scoring = |mode: Dispatch| {
        let parsed = &parsed;
        let (df, tf, wt, scores) = (&mut df, &mut tf, &mut wt, &mut scores);
        with_dispatch(mode, move || {
            let t0 = Instant::now();
            let mut checksum = 0.0f64;
            for _ in 0..score_reps {
                for doc in parsed {
                    sentence_scores_soa(doc, df, tf, wt, scores);
                    checksum += scores.last().copied().unwrap_or(0.0);
                }
            }
            std::hint::black_box(checksum);
            t0.elapsed().as_secs_f64() * 1e3 / (score_reps * parsed.len()) as f64
        })
    };
    let scoring_scalar_ms = time_scoring(Dispatch::ForceScalar);
    let scoring_simd_ms = time_scoring(Dispatch::ForceSimd);
    let simd_speedup_scoring = scoring_scalar_ms / scoring_simd_ms.max(1e-9);

    // Sorted-set intersection (gallop/AVX2 vs two-pointer merge).
    let mut time_intersect = |mode: Dispatch| {
        let parsed = &parsed;
        with_dispatch(mode, move || {
            let t0 = Instant::now();
            let mut total = 0usize;
            for _ in 0..reps {
                for doc in parsed {
                    let sets = &doc.word_sets;
                    for i in 0..sets.len() {
                        for j in (i + 1)..sets.len() {
                            total += overlap(&sets[i], &sets[j]);
                        }
                    }
                }
            }
            std::hint::black_box(total);
            t0.elapsed().as_secs_f64() * 1e3 / (reps * parsed.len()) as f64
        })
    };
    let intersect_scalar_ms = time_intersect(Dispatch::ForceScalar);
    let intersect_simd_ms = time_intersect(Dispatch::ForceSimd);
    let simd_speedup_intersect = intersect_scalar_ms / intersect_simd_ms.max(1e-9);

    // TextRank power iteration (CSR SpMV vs edge-scatter).
    let mut time_textrank = |mode: Dispatch| {
        let parsed = &parsed;
        let (ts, out) = (&mut ts, &mut out);
        with_dispatch(mode, move || {
            let t0 = Instant::now();
            for _ in 0..reps {
                for doc in parsed {
                    centrality_into(doc, SimilarityMode::InvertedIndex, ts, out);
                    std::hint::black_box(out.last().copied());
                }
            }
            t0.elapsed().as_secs_f64() * 1e3 / (reps * parsed.len()) as f64
        })
    };
    let textrank_scalar_ms = time_textrank(Dispatch::ForceScalar);
    let textrank_simd_ms = time_textrank(Dispatch::ForceSimd);
    let simd_speedup_textrank = textrank_scalar_ms / textrank_simd_ms.max(1e-9);

    println!(
        "simd vs scalar     : scoring {simd_speedup_scoring:5.2}x | intersect \
         {simd_speedup_intersect:5.2}x | textrank {simd_speedup_textrank:5.2}x \
         (selections byte-identical across modes)"
    );

    // --- sharded admission vs the serial gateway loop (PR 8) -------------
    // Full-pipeline routing (classify + estimate + C&R) over a borderline
    // batch: serial single-scratch loop vs the sharded pipeline at the
    // auto worker count. Outputs are asserted identical (every field but
    // the wall-clock `gateway_s`) before any speedup is reported.
    let gw_cfg = GatewayConfig::two_tier(w.b_short, w.gamma, true);
    let batch_owned: Vec<(String, u32)> = docs
        .iter()
        .cycle()
        .take(2 * n_docs)
        .map(|d| (d.clone(), 512u32))
        .collect();
    let batch: Vec<(&str, u32)> = batch_owned.iter().map(|(t, m)| (t.as_str(), *m)).collect();
    let route_all = |workers: usize, cache: Option<&mut RouteCache>| {
        let mut gw = Gateway::new(gw_cfg.clone());
        let mut out: Vec<Option<RoutedRequest>> = vec![None; batch.len()];
        let t0 = Instant::now();
        gw.route_batch_with_opts(&batch, workers, cache, |i, r| out[i] = Some(r));
        let dt = t0.elapsed().as_secs_f64();
        let out: Vec<RoutedRequest> = out.into_iter().map(Option::unwrap).collect();
        (out, gw, dt)
    };
    let identical = |a: &[RoutedRequest], b: &[RoutedRequest]| {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.tier == y.tier
                    && x.text == y.text
                    && x.prompt_tokens == y.prompt_tokens
                    && x.max_output_tokens == y.max_output_tokens
                    && x.category == y.category
                    && x.estimated_l_total == y.estimated_l_total
                    && x.compressed == y.compressed
            })
    };

    let shard_workers = effective_workers(0, batch.len());
    let (mut serial_best, mut sharded_best) = (f64::MAX, f64::MAX);
    let mut shard_identical = true;
    for rep in 0..3 {
        let (serial_out, serial_gw, serial_dt) = route_all(1, None);
        let (sharded_out, sharded_gw, sharded_dt) = route_all(0, None);
        if rep == 0 {
            shard_identical = identical(&serial_out, &sharded_out)
                && serial_gw.metrics() == sharded_gw.metrics()
                && serial_gw.estimator.c_hat_bits() == sharded_gw.estimator.c_hat_bits();
            assert!(shard_identical, "sharded output diverged from serial");
        }
        serial_best = serial_best.min(serial_dt);
        sharded_best = sharded_best.min(sharded_dt);
    }
    let shard_serial_rps = batch.len() as f64 / serial_best;
    let shard_parallel_rps = batch.len() as f64 / sharded_best;
    let shard_speedup = shard_parallel_rps / shard_serial_rps.max(1e-9);
    println!(
        "sharded admission  : serial {shard_serial_rps:7.1} req/s | {shard_workers} workers \
         {shard_parallel_rps:7.1} req/s | speedup {shard_speedup:5.2}x (outputs identical)"
    );

    // --- fingerprint-keyed route memo (PR 8) -----------------------------
    // Duplicate-heavy trace (production prompts are templated): a small
    // unique pool replayed many times. Hits must be byte-identical to
    // cold routing, and a hostile all-unique trace must stay capacity-
    // bounded with zero hits.
    let n_unique = 8usize.min(n_docs);
    let dup_owned: Vec<(String, u32)> = (0..25 * n_unique)
        .map(|k| (docs[k % n_unique].clone(), 512u32))
        .collect();
    let dup_batch: Vec<(&str, u32)> = dup_owned.iter().map(|(t, m)| (t.as_str(), *m)).collect();
    let route_dup = |cache: Option<&mut RouteCache>| {
        let mut gw = Gateway::new(gw_cfg.clone());
        let mut out: Vec<Option<RoutedRequest>> = vec![None; dup_batch.len()];
        let t0 = Instant::now();
        gw.route_batch_with_opts(&dup_batch, 1, cache, |i, r| out[i] = Some(r));
        let dt = t0.elapsed().as_secs_f64();
        let out: Vec<RoutedRequest> = out.into_iter().map(Option::unwrap).collect();
        (out, gw, dt)
    };
    let (cold_out, cold_gw, cold_dt) = route_dup(None);
    let mut cache = RouteCache::new(512);
    let (warm_out, warm_gw, warm_dt) = route_dup(Some(&mut cache));
    let memo_identical = identical(&cold_out, &warm_out)
        && cold_gw.metrics() == warm_gw.metrics()
        && cold_gw.estimator.c_hat_bits() == warm_gw.estimator.c_hat_bits();
    assert!(memo_identical, "memoized output diverged from cold routing");
    let memo_hit_rate_dup = cache.stats.hit_rate();
    let memo_cold_rps = dup_batch.len() as f64 / cold_dt;
    let memo_warm_rps = dup_batch.len() as f64 / warm_dt;
    let memo_speedup = memo_warm_rps / memo_cold_rps.max(1e-9);
    println!(
        "route memo (dup)   : cold {memo_cold_rps:7.1} req/s | warm {memo_warm_rps:7.1} req/s | \
         hit rate {:.1}% | speedup {memo_speedup:5.2}x (hits byte-identical)",
        memo_hit_rate_dup * 100.0
    );

    let mut unique_cache = RouteCache::new(16);
    {
        let mut gw = Gateway::new(gw_cfg.clone());
        let unique_batch: Vec<(&str, u32)> =
            docs.iter().map(|d| (d.as_str(), 512u32)).collect();
        gw.route_batch_with_opts(&unique_batch, 1, Some(&mut unique_cache), |_, _| {});
    }
    let route_cache_capacity_ok = unique_cache.len() <= unique_cache.capacity();
    assert!(route_cache_capacity_ok, "cache grew past capacity");
    let memo_hit_rate_unique = unique_cache.stats.hit_rate();
    assert_eq!(unique_cache.stats.hits, 0, "all-unique trace must never hit");
    println!(
        "route memo (unique): {} entries / cap {} after {n_docs} unique docs | hit rate {:.1}%",
        unique_cache.len(),
        unique_cache.capacity(),
        memo_hit_rate_unique * 100.0
    );

    let report = obj(vec![
        ("bench", Json::Str("gateway_throughput".into())),
        ("docs", Json::Num(n_docs as f64)),
        ("avg_sentences", Json::Num(avg_sentences)),
        ("avg_tokens", Json::Num(avg_tokens)),
        ("budget_tokens", Json::Num(budget as f64)),
        ("selection_identical", Json::Bool(true)),
        ("allpairs_stage_ms_per_doc", Json::Num(allpairs_ms)),
        ("inverted_stage_ms_per_doc", Json::Num(indexed_ms)),
        ("speedup_vs_allpairs", Json::Num(stage_speedup)),
        ("naive_req_per_s", Json::Num(naive_rps)),
        ("fast_req_per_s", Json::Num(fast_rps)),
        ("e2e_speedup", Json::Num(e2e_speedup)),
        ("naive_p50_ms", Json::Num(naive_lat.p50())),
        ("naive_p99_ms", Json::Num(naive_lat.p99())),
        ("fast_p50_ms", Json::Num(fast_lat.p50())),
        ("fast_p99_ms", Json::Num(fast_lat.p99())),
        ("simd_selection_identical", Json::Bool(true)),
        ("simd_scoring_scalar_ms", Json::Num(scoring_scalar_ms)),
        ("simd_scoring_simd_ms", Json::Num(scoring_simd_ms)),
        ("simd_speedup_scoring", Json::Num(simd_speedup_scoring)),
        ("simd_speedup_intersect", Json::Num(simd_speedup_intersect)),
        ("simd_speedup_textrank", Json::Num(simd_speedup_textrank)),
        ("shard_workers", Json::Num(shard_workers as f64)),
        ("shard_serial_rps", Json::Num(shard_serial_rps)),
        ("shard_parallel_rps", Json::Num(shard_parallel_rps)),
        ("shard_speedup", Json::Num(shard_speedup)),
        ("shard_identical", Json::Bool(shard_identical)),
        ("memo_cold_rps", Json::Num(memo_cold_rps)),
        ("memo_warm_rps", Json::Num(memo_warm_rps)),
        ("memo_speedup", Json::Num(memo_speedup)),
        ("memo_hit_rate_dup", Json::Num(memo_hit_rate_dup)),
        ("memo_hit_rate_unique", Json::Num(memo_hit_rate_unique)),
        ("memo_identical", Json::Bool(memo_identical)),
        ("route_cache_capacity_ok", Json::Bool(route_cache_capacity_ok)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gateway.json");
    std::fs::write(path, report.to_string_pretty() + "\n").expect("writing BENCH_gateway.json");
    println!("wrote {path}");
}
