//! Bench — C&R gateway hot path (§Perf): single-thread compression
//! throughput and latency at trace-realistic document sizes, old path vs
//! new path, plus the isolated similarity-graph comparison (naive
//! all-pairs vs inverted index). Emits `BENCH_gateway.json` at the repo
//! root so the perf trajectory is tracked across PRs.
//!
//! Paths compared:
//! * **naive**: fresh `Document::parse` + all-pairs TextRank per request —
//!   the pre-§Perf behavior.
//! * **fast**: the gateway's real path — one reused `CompressScratch`
//!   (arena interner, postings-list TextRank, recycled buffers).
//!
//! Selection output is asserted byte-identical across paths before any
//! timing is reported.

use std::time::Instant;

use fleetopt::compress::corpus;
use fleetopt::compress::doc::{overlap, Document};
use fleetopt::compress::extractive::compress_doc_with_mode;
use fleetopt::compress::scratch::CompressScratch;
use fleetopt::compress::textrank::{
    centrality_into, textrank_naive, SimilarityMode, TextrankScratch,
};
use fleetopt::compress::tfidf::sentence_scores_soa;
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::util::json::{obj, Json};
use fleetopt::util::rng::Rng;
use fleetopt::util::simd::{with_dispatch, Dispatch};
use fleetopt::util::stats::Samples;
use fleetopt::workload::traces;

fn main() {
    let n_docs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let w = traces::agent_heavy();
    let mut rng = Rng::new(0xBE7C);
    let docs: Vec<String> = (0..n_docs)
        .map(|_| corpus::generate_borderline_for(&w, &mut rng))
        .collect();
    let budget = w.b_short - 512;
    let parsed: Vec<Document> = docs.iter().map(|d| Document::parse(d)).collect();
    let avg_sentences =
        parsed.iter().map(Document::n_sentences).sum::<usize>() as f64 / n_docs as f64;
    let avg_tokens = docs.iter().map(|d| count_tokens(d) as u64).sum::<u64>() as f64
        / n_docs as f64;
    println!(
        "gateway hot path — {n_docs} borderline docs (avg {avg_sentences:.0} sentences, \
         {avg_tokens:.0} tokens), budget {budget}"
    );

    // --- correctness gate: byte-identical selection across paths ---------
    let mut scratch = CompressScratch::new();
    for (doc, text) in parsed.iter().zip(&docs) {
        let naive = compress_doc_with_mode(doc, budget, SimilarityMode::AllPairs);
        let fast = scratch.compress(text, budget);
        assert_eq!(naive.text, fast.text, "selection must be byte-identical");
        assert_eq!(naive.selected, fast.selected);
    }
    println!("selection output: byte-identical across paths ({n_docs}/{n_docs} docs)");

    // --- isolated similarity-graph stage: all-pairs vs inverted index ----
    let reps = 3usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        for doc in &parsed {
            std::hint::black_box(textrank_naive(doc));
        }
    }
    let allpairs_ms = t0.elapsed().as_secs_f64() * 1e3 / (reps * n_docs) as f64;

    let mut ts = TextrankScratch::default();
    let mut out = Vec::new();
    let t0 = Instant::now();
    for _ in 0..reps {
        for doc in &parsed {
            centrality_into(doc, SimilarityMode::InvertedIndex, &mut ts, &mut out);
            std::hint::black_box(out.last().copied());
        }
    }
    let indexed_ms = t0.elapsed().as_secs_f64() * 1e3 / (reps * n_docs) as f64;
    let stage_speedup = allpairs_ms / indexed_ms.max(1e-9);
    println!(
        "textrank stage     : all-pairs {allpairs_ms:8.3} ms/doc | inverted {indexed_ms:8.3} \
         ms/doc | speedup {stage_speedup:5.2}x"
    );

    // --- end-to-end request path: naive vs scratch -----------------------
    let mut naive_lat = Samples::with_capacity(n_docs);
    let t0 = Instant::now();
    for text in &docs {
        let t1 = Instant::now();
        let doc = Document::parse(text);
        std::hint::black_box(compress_doc_with_mode(&doc, budget, SimilarityMode::AllPairs).ok);
        naive_lat.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    let naive_total_s = t0.elapsed().as_secs_f64();

    let mut fast_lat = Samples::with_capacity(n_docs);
    let t0 = Instant::now();
    for text in &docs {
        let t1 = Instant::now();
        std::hint::black_box(scratch.compress(text, budget).ok);
        fast_lat.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    let fast_total_s = t0.elapsed().as_secs_f64();

    let naive_rps = n_docs as f64 / naive_total_s;
    let fast_rps = n_docs as f64 / fast_total_s;
    let e2e_speedup = fast_rps / naive_rps.max(1e-9);
    println!(
        "end-to-end request : naive {naive_rps:7.1} req/s (p50 {:.2} p99 {:.2} ms)",
        naive_lat.p50(),
        naive_lat.p99()
    );
    println!(
        "                     fast  {fast_rps:7.1} req/s (p50 {:.2} p99 {:.2} ms) | \
         speedup {e2e_speedup:5.2}x",
        fast_lat.p50(),
        fast_lat.p99()
    );
    println!("acceptance: similarity-stage speedup >= 5x on >=100-sentence docs");

    // --- SIMD dispatch: scalar oracles vs vectorized kernels (PR 6) ------
    // Selections must be byte-identical across dispatch modes before any
    // speedup is reported (the tentpole identity policy).
    for doc in &parsed {
        let a = with_dispatch(Dispatch::ForceScalar, || {
            compress_doc_with_mode(doc, budget, SimilarityMode::InvertedIndex)
        });
        let b = with_dispatch(Dispatch::ForceSimd, || {
            compress_doc_with_mode(doc, budget, SimilarityMode::InvertedIndex)
        });
        assert_eq!(a.text, b.text, "dispatch mode must not change selection");
        assert_eq!(a.selected, b.selected);
    }

    // Scoring stage (the CI-gated kernel): TF-IDF sentence salience,
    // per-occurrence `ln` (scalar) vs per-distinct-word weight table.
    let score_reps = 40usize;
    let (mut df, mut tf, mut wt, mut scores) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut time_scoring = |mode: Dispatch| {
        let parsed = &parsed;
        let (df, tf, wt, scores) = (&mut df, &mut tf, &mut wt, &mut scores);
        with_dispatch(mode, move || {
            let t0 = Instant::now();
            let mut checksum = 0.0f64;
            for _ in 0..score_reps {
                for doc in parsed {
                    sentence_scores_soa(doc, df, tf, wt, scores);
                    checksum += scores.last().copied().unwrap_or(0.0);
                }
            }
            std::hint::black_box(checksum);
            t0.elapsed().as_secs_f64() * 1e3 / (score_reps * parsed.len()) as f64
        })
    };
    let scoring_scalar_ms = time_scoring(Dispatch::ForceScalar);
    let scoring_simd_ms = time_scoring(Dispatch::ForceSimd);
    let simd_speedup_scoring = scoring_scalar_ms / scoring_simd_ms.max(1e-9);

    // Sorted-set intersection (gallop/AVX2 vs two-pointer merge).
    let mut time_intersect = |mode: Dispatch| {
        let parsed = &parsed;
        with_dispatch(mode, move || {
            let t0 = Instant::now();
            let mut total = 0usize;
            for _ in 0..reps {
                for doc in parsed {
                    let sets = &doc.word_sets;
                    for i in 0..sets.len() {
                        for j in (i + 1)..sets.len() {
                            total += overlap(&sets[i], &sets[j]);
                        }
                    }
                }
            }
            std::hint::black_box(total);
            t0.elapsed().as_secs_f64() * 1e3 / (reps * parsed.len()) as f64
        })
    };
    let intersect_scalar_ms = time_intersect(Dispatch::ForceScalar);
    let intersect_simd_ms = time_intersect(Dispatch::ForceSimd);
    let simd_speedup_intersect = intersect_scalar_ms / intersect_simd_ms.max(1e-9);

    // TextRank power iteration (CSR SpMV vs edge-scatter).
    let mut time_textrank = |mode: Dispatch| {
        let parsed = &parsed;
        let (ts, out) = (&mut ts, &mut out);
        with_dispatch(mode, move || {
            let t0 = Instant::now();
            for _ in 0..reps {
                for doc in parsed {
                    centrality_into(doc, SimilarityMode::InvertedIndex, ts, out);
                    std::hint::black_box(out.last().copied());
                }
            }
            t0.elapsed().as_secs_f64() * 1e3 / (reps * parsed.len()) as f64
        })
    };
    let textrank_scalar_ms = time_textrank(Dispatch::ForceScalar);
    let textrank_simd_ms = time_textrank(Dispatch::ForceSimd);
    let simd_speedup_textrank = textrank_scalar_ms / textrank_simd_ms.max(1e-9);

    println!(
        "simd vs scalar     : scoring {simd_speedup_scoring:5.2}x | intersect \
         {simd_speedup_intersect:5.2}x | textrank {simd_speedup_textrank:5.2}x \
         (selections byte-identical across modes)"
    );

    let report = obj(vec![
        ("bench", Json::Str("gateway_throughput".into())),
        ("docs", Json::Num(n_docs as f64)),
        ("avg_sentences", Json::Num(avg_sentences)),
        ("avg_tokens", Json::Num(avg_tokens)),
        ("budget_tokens", Json::Num(budget as f64)),
        ("selection_identical", Json::Bool(true)),
        ("allpairs_stage_ms_per_doc", Json::Num(allpairs_ms)),
        ("inverted_stage_ms_per_doc", Json::Num(indexed_ms)),
        ("speedup_vs_allpairs", Json::Num(stage_speedup)),
        ("naive_req_per_s", Json::Num(naive_rps)),
        ("fast_req_per_s", Json::Num(fast_rps)),
        ("e2e_speedup", Json::Num(e2e_speedup)),
        ("naive_p50_ms", Json::Num(naive_lat.p50())),
        ("naive_p99_ms", Json::Num(naive_lat.p99())),
        ("fast_p50_ms", Json::Num(fast_lat.p50())),
        ("fast_p99_ms", Json::Num(fast_lat.p99())),
        ("simd_selection_identical", Json::Bool(true)),
        ("simd_scoring_scalar_ms", Json::Num(scoring_scalar_ms)),
        ("simd_scoring_simd_ms", Json::Num(scoring_simd_ms)),
        ("simd_speedup_scoring", Json::Num(simd_speedup_scoring)),
        ("simd_speedup_intersect", Json::Num(simd_speedup_intersect)),
        ("simd_speedup_textrank", Json::Num(simd_speedup_textrank)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gateway.json");
    std::fs::write(path, report.to_string_pretty() + "\n").expect("writing BENCH_gateway.json");
    println!("wrote {path}");
}
