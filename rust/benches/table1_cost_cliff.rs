//! Bench E1 — regenerates paper Table 1 (the cost cliff at B = 8,192).
//! Also sweeps the cliff ratio across boundaries (the 8x-42x range of §2.2).

use fleetopt::config::GpuProfile;
use fleetopt::experiments;
use fleetopt::util::table::Table;

fn main() {
    experiments::table1().print();

    // The rho sweep behind "8x-42x depending on the context window ratio".
    let g = GpuProfile::a100_llama70b();
    let mut t = Table::new(
        "Cliff ratio rho vs boundary (C_max^l = 65,536)",
        &["B_short", "n_max^s", "n_max^l", "rho"],
    );
    for b in [1536u32, 2048, 4096, 8192, 16384] {
        t.row(&[
            b.to_string(),
            g.n_max(b).to_string(),
            g.n_max_long().to_string(),
            format!("{:.1}x", g.cliff_ratio(b)),
        ]);
    }
    t.print();
    println!("paper: 42x at 1,536 | 16x at 4,096 | 8x at 8,192 — see EXPERIMENTS.md E1");
}
