//! Bench E3 — regenerates paper Table 3 (fleet GPU counts, annualized cost
//! and savings for all four methods on all three workloads) and checks the
//! qualitative claims: method ordering, Theorem 2 (co-design <= retrofit),
//! and the gamma* pattern.

use fleetopt::experiments::{table3, table3_rows};
use fleetopt::workload::traces;

fn main() {
    let t0 = std::time::Instant::now();
    table3(1000.0).print();
    println!("generated in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    println!("\nshape checks vs paper:");
    for w in traces::all() {
        let r = table3_rows(&w, 1000.0);
        let ok_order = r.homo.cost_yr >= r.pr.cost_yr
            && r.pr.cost_yr >= r.retrofit.cost_yr
            && r.retrofit.cost_yr >= r.fleetopt.cost_yr;
        println!(
            "  {:12} ordering homo>=PR>=retrofit>=fleetopt: {} | theorem-2 (co<=retro): {} | gamma*={:.1}",
            w.name,
            ok_order,
            r.fleetopt.cost_yr <= r.retrofit.cost_yr,
            r.fleetopt.gamma,
        );
    }
    println!(
        "paper Table 3: Azure 38.7/67.6/82.4% (g*=2.0) | LMSYS 41.7/48.2/57.6% (g*=2.0) | Agent 5.5/6.7/6.7% (g*=1.5)"
    );
}
