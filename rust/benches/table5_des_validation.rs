//! Bench E5/E8 — regenerates paper Table 5: analytical vs DES GPU
//! utilization for the pool-routing fleet, plus the §7.4 P99-TTFT check
//! (many-server regime: prefill-dominated, SLO non-binding).

use fleetopt::experiments;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30_000);
    let t0 = std::time::Instant::now();
    let t = experiments::table5(1000.0, n);
    t.print();
    println!(
        "DES requests per pool ~{n}; generated in {:.1} s",
        t0.elapsed().as_secs_f64()
    );
    println!("paper Table 5: all |error| <= 3%, analytical slightly optimistic (-0.1..-2.7%)");
    println!("paper §7.4: W99 ~ 0 in the many-server regime; TTFT is prefill-dominated");
}
