//! Bench E2 — regenerates paper Table 2 (borderline fractions and
//! archetypes) and the §4.2 borderline-share-of-above-threshold claim.

use fleetopt::experiments;
use fleetopt::workload::traces;

fn main() {
    experiments::table2().print();

    println!("borderline share of above-threshold traffic (paper: 43-76%):");
    for w in traces::all() {
        let share = w.beta() / (1.0 - w.alpha());
        println!("  {:12} beta/(1-alpha) = {:.1}%", w.name, share * 100.0);
    }
    println!("paper Table 2: Azure a=0.898 b=0.078 | LMSYS a=0.909 b=0.046 | Agent a=0.740 b=0.112");
}
