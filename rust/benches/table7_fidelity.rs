//! Bench E7 — regenerates paper Table 7 / Appendix C: compression fidelity
//! on 300 borderline prompts (Agent-heavy band, 8K-12K tokens), with the
//! model-embedding cosine standing in for BERTScore (DESIGN.md §1).

use fleetopt::experiments;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let t0 = std::time::Instant::now();
    let dir = experiments::artifacts_dir();
    if dir.is_none() {
        println!("note: artifacts not built; embedding-cosine row will be omitted");
    }
    let t = experiments::table7(n, dir.as_deref());
    t.print();
    println!("generated in {:.1} s", t0.elapsed().as_secs_f64());
    println!(
        "paper Table 7: p_c 1.00 | BERTScore F1 0.884 | ROUGE-L R 0.856 | TF-IDF cos 0.981 | reduction 15.4%"
    );
}
