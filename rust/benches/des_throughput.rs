//! Bench §Perf — DES engine throughput (the "DES performance" ROADMAP
//! section's evidence). Two CI-gated measurements, written to
//! `BENCH_des.json` at the repo root:
//!
//! 1. **Azure event-loop throughput**: the azure trace through a
//!    homogeneous long-context pool (16 slots/GPU — the queue-op-dominated
//!    shape), simulated twice with identical inputs: once on the
//!    `BinaryHeap` oracle scheduler, once on the calendar queue. Results
//!    are asserted bit-identical before timing counts; the CI floor is a
//!    >= 3x wall-clock speedup (`speedup_vs_heap`). The heap column *is*
//!    the faithful pre-overhaul "before": it runs the exact pre-PR
//!    scheduling algorithm inside the same loop.
//! 2. **Stress archetype**: the default 5M-request / 512-GPU / K=4
//!    diurnal scenario (`fleetopt simulate --stress`); CI gates
//!    `stress.wall_s < 30` in release.

use std::time::Instant;

use fleetopt::config::GpuProfile;
use fleetopt::fleetsim::{
    mean_occupancy_s, run_stress, simulate_pool_with, QueueImpl, SimConfig, SimRequest,
    SimResult, SimScratch, StressConfig,
};
use fleetopt::util::json::{obj, Json};
use fleetopt::workload::arrivals::generate_trace;
use fleetopt::workload::traces;

/// The azure homogeneous-pool trace: `n` requests at `lambda` req/s with
/// lengths drawn from the azure workload (no routing/compression — the
/// homogeneous baseline shape of Table 3), via the shared trace generator.
fn azure_trace(lambda: f64, n: usize, seed: u64) -> Vec<SimRequest> {
    generate_trace(&traces::azure(), lambda, n, seed)
        .iter()
        .map(|r| SimRequest {
            arrival_s: r.arrival_s,
            l_in: r.l_in,
            l_out: r.l_out,
        })
        .collect()
}

fn main() {
    // --- azure event-loop throughput: calendar vs the heap oracle -------
    let g = GpuProfile::a100_llama70b();
    let n_slots = g.n_max_long(); // 16 slots/GPU: queue-op-dominated
    let lambda = 2_000.0;
    let n = 1_500_000;
    let reqs = azure_trace(lambda, n, 0xDE5BE);
    // Size the pool for rho ~0.8 from the trace's own mean occupancy.
    let occ = mean_occupancy_s(&reqs, &g, n_slots);
    let n_gpus = (lambda * occ / (n_slots as f64 * 0.8)).ceil() as u64;
    println!(
        "azure event-loop: {n} requests, {n_gpus} GPUs x {n_slots} slots, \
         E[occupancy] {occ:.1} s"
    );

    let run = |which: QueueImpl| -> (SimResult, f64) {
        let mut cfg = SimConfig::new(g.clone(), n_gpus, n_slots);
        cfg.queue_impl = which;
        let mut scratch = SimScratch::new();
        let t0 = Instant::now();
        let res = simulate_pool_with(&cfg, &reqs, &mut scratch);
        (res, t0.elapsed().as_secs_f64() * 1e3)
    };
    // Untimed warm-up of both backends on a prefix so the first timed run
    // doesn't pay process-cold page-fault/allocator costs (the heap would
    // otherwise run first and cold, biasing the CI-gated ratio).
    for which in [QueueImpl::BinaryHeap, QueueImpl::Calendar] {
        let mut cfg = SimConfig::new(g.clone(), n_gpus, n_slots);
        cfg.queue_impl = which;
        std::hint::black_box(simulate_pool_with(
            &cfg,
            &reqs[..reqs.len().min(150_000)],
            &mut SimScratch::new(),
        ));
    }
    let (res_heap, heap_ms) = run(QueueImpl::BinaryHeap);
    let (res_cal, cal_ms) = run(QueueImpl::Calendar);
    let identical = res_heap.utilization.to_bits() == res_cal.utilization.to_bits()
        && res_heap.completed == res_cal.completed
        && res_heap.events == res_cal.events;
    let (mut th, mut tc) = (res_heap.ttft, res_cal.ttft);
    let (mut wh, mut wc) = (res_heap.wait, res_cal.wait);
    let identical = identical
        && th.p99().to_bits() == tc.p99().to_bits()
        && wh.p99().to_bits() == wc.p99().to_bits();
    assert!(identical, "calendar queue diverged from the heap oracle");
    let speedup = heap_ms / cal_ms.max(1e-9);
    let events_per_s = res_cal.events as f64 / (cal_ms / 1e3).max(1e-9);
    println!(
        "  heap {heap_ms:8.1} ms | calendar {cal_ms:8.1} ms ({speedup:.2}x, \
         {:.2} M events/s, {} events, identical)",
        events_per_s / 1e6,
        res_cal.events,
    );

    // --- stress archetype: 5M requests, 512 GPUs, K=4, diurnal ----------
    let scfg = StressConfig::default();
    let rep = run_stress(&scfg);
    assert_eq!(rep.completed, rep.n_requests, "stress run lost requests");
    assert_eq!(rep.censored, 0);
    println!(
        "stress: {} requests, {} GPUs (per tier {:?}), {} events in {:.2} s \
         (gen {:.2} s + sim {:.2} s) = {:.2} M events/s",
        rep.n_requests,
        scfg.n_gpus_total,
        rep.gpus,
        rep.events,
        rep.wall_s,
        rep.gen_s,
        rep.sim_s,
        rep.events_per_s() / 1e6,
    );

    let report = obj(vec![
        ("bench", Json::Str("des_throughput".into())),
        (
            "azure",
            obj(vec![
                ("n_requests", Json::Num(n as f64)),
                ("n_gpus", Json::Num(n_gpus as f64)),
                ("n_slots", Json::Num(n_slots as f64)),
                ("events", Json::Num(res_cal.events as f64)),
                ("heap_ms", Json::Num(heap_ms)),
                ("calendar_ms", Json::Num(cal_ms)),
                ("speedup_vs_heap", Json::Num(speedup)),
                ("events_per_s_calendar", Json::Num(events_per_s)),
                ("identical", Json::Bool(identical)),
            ]),
        ),
        (
            "stress",
            obj(vec![
                ("n_requests", Json::Num(rep.n_requests as f64)),
                ("gpus_total", Json::Num(scfg.n_gpus_total as f64)),
                ("k", Json::Num(scfg.windows.len() as f64)),
                ("wall_s", Json::Num(rep.wall_s)),
                ("gen_s", Json::Num(rep.gen_s)),
                ("sim_s", Json::Num(rep.sim_s)),
                ("events", Json::Num(rep.events as f64)),
                ("events_per_s", Json::Num(rep.events_per_s())),
                ("completed", Json::Num(rep.completed as f64)),
                ("censored", Json::Num(rep.censored as f64)),
                ("lambda_base", Json::Num(rep.lambda_base)),
                ("horizon_s", Json::Num(rep.horizon_s)),
            ]),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_des.json");
    std::fs::write(path, report.to_string_pretty() + "\n").expect("writing BENCH_des.json");
    println!("wrote {path}");
}
