//! Bench E6 — regenerates paper Table 6: arrival-rate sensitivity for the
//! Agent-heavy workload (savings stability across a 20x lambda range).

use fleetopt::experiments;

fn main() {
    let t0 = std::time::Instant::now();
    let t = experiments::table6(&[100.0, 200.0, 500.0, 1000.0, 2000.0]);
    t.print();
    println!("generated in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    println!("paper Table 6: PR saving stable 5.4-5.5%; FleetOpt 6.2-6.8% across the range");
    println!("shape check: savings should be near-constant in lambda (proportional scaling)");
}
