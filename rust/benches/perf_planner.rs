//! Bench E9 / §Perf — planner wall-clock. The paper claims the full
//! Algorithm-1 sweep completes in under 1 ms; this bench times single
//! cells, the fixed-B gamma sweep, and the full (B, gamma) sweep for each
//! workload (serial vs thread-scope-sharded), plus the Table-5 DES
//! validation replications (sequential vs parallel). Emits
//! `BENCH_planner.json` at the repo root so the perf trajectory is tracked
//! across PRs.

use std::time::Instant;

use fleetopt::config::{GpuProfile, SkuCatalog};
use fleetopt::experiments::table5_validate_replicated;
use fleetopt::fleetsim::sim::{simulate_pool, simulate_pool_replications, SimConfig, SimRequest};
use fleetopt::planner::replan::{ReplanConfig, Replanner};
use fleetopt::planner::sizing::{clear_warm_hints, min_gpus, sizing_probe_stats};
use fleetopt::planner::{
    anytime_search, plan_fleet, sweep_cell_bounds, sweep_full, sweep_full_serial, sweep_gamma,
    sweep_tiered, sweep_tiered_pruned, sweep_tiered_skus_pruned, AnytimeConfig, CalibCache,
    Deadline, PlanInput,
};
use fleetopt::queueing::erlang::erlang_cache_stats;
use fleetopt::queueing::service::{calibrate, MomentTable};
use fleetopt::util::json::{obj, Json};
use fleetopt::util::par::set_thread_cap;
use fleetopt::util::rng::Rng;
use fleetopt::workload::traces;

fn time_ms<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

/// Median per-rep wall time — the CI floor checks use medians so one
/// scheduler hiccup on a shared runner cannot fail a hard gate.
fn median_ms<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

fn main() {
    let mut sweep_rows = Vec::new();
    let mut plan_fleet_ms_max = 0.0f64;
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        let cell = time_ms(10, || {
            std::hint::black_box(plan_fleet(&input, w.b_short, 1.5).unwrap());
        });
        // The < 1 ms CI floor: one full Algorithm-1 cell (2 calibrations +
        // 2 Erlang inversions), median over reps after one warm-up call.
        let plan_fleet_ms = median_ms(21, || {
            std::hint::black_box(plan_fleet(&input, w.b_short, 1.5).unwrap());
        });
        plan_fleet_ms_max = plan_fleet_ms_max.max(plan_fleet_ms);
        let gsweep = time_ms(5, || {
            std::hint::black_box(sweep_gamma(&input, w.b_short).unwrap());
        });
        let full_serial = time_ms(3, || {
            std::hint::black_box(sweep_full_serial(&input).unwrap());
        });
        let full_par = time_ms(3, || {
            std::hint::black_box(sweep_full(&input).unwrap());
        });
        println!(
            "{:12} cell={cell:7.3} ms (median {plan_fleet_ms:7.3}) | \
             gamma-sweep(11)={gsweep:8.3} ms | \
             full-sweep serial={full_serial:8.3} ms parallel={full_par:8.3} ms \
             ({:.2}x)",
            w.name,
            full_serial / full_par.max(1e-9),
        );
        sweep_rows.push(obj(vec![
            ("workload", Json::Str(w.name.into())),
            ("cell_ms", Json::Num(cell)),
            ("plan_fleet_ms", Json::Num(plan_fleet_ms)),
            ("gamma_sweep_ms", Json::Num(gsweep)),
            ("full_sweep_serial_ms", Json::Num(full_serial)),
            ("full_sweep_parallel_ms", Json::Num(full_par)),
            (
                "full_sweep_speedup",
                Json::Num(full_serial / full_par.max(1e-9)),
            ),
        ]));
    }
    println!("paper §6: plan_fleet < 1 ms (hard CI floor, median)");

    // --- Erlang-memo: the sizing inversion, first-fill vs warm (§Perf) ---
    // "First-fill" repetitions run on a fresh scoped thread each (fresh
    // thread-local Erlang memo, every cell computed at least once — note
    // this is NOT a pre-memo baseline: intra-run repeats already hit the
    // memo); the warm pass re-runs the identical lambda grid on this
    // thread with the memo fully populated. Results are bit-identical
    // either way (tested in `planner::sizing`); the drop shows what a
    // warm replanner/sweep saves per revisited cell.
    let wz = traces::azure();
    let gpz = GpuProfile::a100_llama70b();
    let svc = calibrate(&wz.cdf, &wz.output, &gpz, 682, 10_000, 11);
    let lambdas: Vec<f64> = (1..=40).map(|i| 75.0 * i as f64).collect();
    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        std::thread::scope(|s| {
            s.spawn(|| {
                for &lam in &lambdas {
                    std::hint::black_box(min_gpus(lam, &svc, 0.5, 0.85, false).unwrap());
                }
            })
            .join()
            .expect("first-fill sizing worker panicked");
        });
    }
    let sizing_first_fill_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    for &lam in &lambdas {
        std::hint::black_box(min_gpus(lam, &svc, 0.5, 0.85, false).unwrap());
    }
    let t0 = Instant::now();
    for _ in 0..reps {
        for &lam in &lambdas {
            std::hint::black_box(min_gpus(lam, &svc, 0.5, 0.85, false).unwrap());
        }
    }
    let sizing_warm_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    let (erlang_hits, erlang_misses) = erlang_cache_stats();
    println!(
        "sizing inversion x{}: first-fill={sizing_first_fill_ms:7.3} ms | \
         warm={sizing_warm_ms:7.3} ms \
         ({:.1}x; erlang memo {erlang_hits} hits / {erlang_misses} misses)",
        lambdas.len(),
        sizing_first_fill_ms / sizing_warm_ms.max(1e-9),
    );

    // --- K-tier boundary-combination sweeps (Table 8 substrate) ----------
    // `k3_sweep_ms` is the pre-PR full-evaluation sweep (the oracle);
    // `k3_pruned_ms` is the bound-and-prune path that selects the
    // bit-identical plan — the < 10 ms CI floor, measured with the
    // one-time moment table warm (its build is reported separately).
    let mut tier_rows = Vec::new();
    let mut table_build_ms = 0.0f64;
    let mut k3_pruned_ms_max = 0.0f64;
    let mut pruned_frac_min = 1.0f64;
    let mut azure_k3_cold_ms = 0.0f64;
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        let t0 = Instant::now();
        std::hint::black_box(MomentTable::for_workload(&input.workload, input.gpu.chunk));
        table_build_ms += t0.elapsed().as_secs_f64() * 1e3;
        let k3 = time_ms(3, || {
            std::hint::black_box(sweep_tiered(&input, 3).unwrap());
        });
        if w.name == "azure" {
            azure_k3_cold_ms = k3;
        }
        let k4 = time_ms(1, || {
            std::hint::black_box(sweep_tiered(&input, 4).unwrap());
        });
        // Prune decisions race on the incumbent atomic (conservatively),
        // so the fraction wobbles run-to-run — report the min over the
        // reps, a stable lower bound paired with the median wall time.
        let mut frac = 1.0f64;
        let k3_pruned = median_ms(5, || {
            let (best, stats) = sweep_tiered_pruned(&input, 3, &CalibCache::new()).unwrap();
            std::hint::black_box(&best);
            frac = frac.min(stats.pruned_frac());
        });
        k3_pruned_ms_max = k3_pruned_ms_max.max(k3_pruned);
        pruned_frac_min = pruned_frac_min.min(frac);
        println!(
            "{:12} K=3 sweep={k3:8.1} ms | pruned={k3_pruned:7.2} ms \
             ({:.0}% cells pruned) | K=4 sweep={k4:8.1} ms (floor: pruned K=3 < 10 ms)",
            w.name,
            frac * 100.0,
        );
        tier_rows.push(obj(vec![
            ("workload", Json::Str(w.name.into())),
            ("k3_sweep_ms", Json::Num(k3)),
            ("k3_pruned_ms", Json::Num(k3_pruned)),
            ("k3_pruned_frac", Json::Num(frac)),
            ("k4_sweep_ms", Json::Num(k4)),
        ]));
    }
    println!("moment-table builds (one-time, all workloads): {table_build_ms:.1} ms");

    // --- deadline-bounded anytime planner (PR 7, CI-gated) ---------------
    // Single-SKU spaces: the anytime entry point must return the pruned
    // sweep's argmin bit-identically on every trace x K=2/3 (the every-run
    // `anytime_exact_single_sku` gate).
    let mut anytime_exact_single_sku = true;
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        for k in [2usize, 3] {
            let (oracle, _) = sweep_tiered_pruned(&input, k, &CalibCache::new()).unwrap();
            let res = anytime_search(
                &input,
                k,
                None,
                &CalibCache::new(),
                Deadline::none(),
                &AnytimeConfig::default(),
            )
            .unwrap();
            let same = res.exact
                && res.plan.cost_yr.to_bits() == oracle.cost_yr.to_bits()
                && res.plan.boundaries() == oracle.boundaries();
            if !same {
                println!(
                    "ANYTIME MISMATCH {} K={k}: anytime ${:.2} vs oracle ${:.2}",
                    w.name, res.plan.cost_yr, oracle.cost_yr
                );
                anytime_exact_single_sku = false;
            }
        }
    }

    // Mixed-SKU azure K=3 (19,602 cells, forced onto the sampled path by
    // the space size) under a 50 ms budget, judged against the exhaustive
    // SKU sweep oracle. Medians over reps, each on a fresh calibration
    // cache so the deadline bounds cold-cache work.
    let input_any = PlanInput::new(traces::azure(), 1000.0);
    let catalog = SkuCatalog::demo(&input_any.gpu);
    let (oracle_mixed, _) =
        sweep_tiered_skus_pruned(&input_any, 3, &catalog, &CalibCache::new()).unwrap();
    let mut any_ms = Vec::new();
    let mut any_gap = Vec::new();
    let mut any_cps = Vec::new();
    for _ in 0..5 {
        let cache = CalibCache::new();
        let t0 = Instant::now();
        let res = anytime_search(
            &input_any,
            3,
            Some(&catalog),
            &cache,
            Deadline::after_ms(50),
            &AnytimeConfig::default(),
        )
        .unwrap();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        any_gap.push((res.plan.cost_yr - oracle_mixed.cost_yr) / oracle_mixed.cost_yr * 100.0);
        any_cps.push(res.cells_evaluated as f64 / (ms / 1e3).max(1e-9));
        any_ms.push(ms);
    }
    let med = |v: &mut Vec<f64>| -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let anytime_incumbent_ms = med(&mut any_ms);
    let anytime_gap_pct = med(&mut any_gap);
    let anytime_cells_per_s = med(&mut any_cps);
    println!(
        "anytime azure K=3 mixed (50 ms budget): incumbent={anytime_incumbent_ms:7.2} ms | \
         gap={anytime_gap_pct:.2}% vs oracle ${:.0}K | {:.0} cells/s | \
         single-SKU exact={anytime_exact_single_sku}",
        oracle_mixed.cost_yr / 1000.0,
        anytime_cells_per_s,
    );
    println!("floors: single-SKU exactness every run; gap <= 5% on >= 4-core runners");

    // --- SIMD batched cell bounds vs per-cell scalar (PR 6, CI-gated) ----
    // Thread cap pinned to 1 so the ratio reflects kernel work (cut-memo
    // dedupe + lane-parallel stability counts), not spawn scheduling; the
    // gate uses the *minimum* speedup across traces so no workload can
    // hide a regression. Bounds are asserted bit-identical first.
    set_thread_cap(1);
    let mut cells_rows = Vec::new();
    let mut simd_cells_scalar_ms = 0.0f64;
    let mut simd_cells_batched_ms = 0.0f64;
    let mut simd_speedup_cells = f64::INFINITY;
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        let scalar_bounds = sweep_cell_bounds(&input, 3, false);
        let batched_bounds = sweep_cell_bounds(&input, 3, true);
        assert_eq!(scalar_bounds.len(), batched_bounds.len(), "{}", w.name);
        for (i, (s, b)) in scalar_bounds.iter().zip(&batched_bounds).enumerate() {
            assert_eq!(
                s.map(f64::to_bits),
                b.map(f64::to_bits),
                "{} cell {i}: batched bound must be bit-identical",
                w.name
            );
        }
        let cells_scalar_ms = median_ms(9, || {
            std::hint::black_box(sweep_cell_bounds(&input, 3, false).len());
        });
        let cells_batched_ms = median_ms(9, || {
            std::hint::black_box(sweep_cell_bounds(&input, 3, true).len());
        });
        let speedup = cells_scalar_ms / cells_batched_ms.max(1e-9);
        simd_cells_scalar_ms = simd_cells_scalar_ms.max(cells_scalar_ms);
        simd_cells_batched_ms = simd_cells_batched_ms.max(cells_batched_ms);
        simd_speedup_cells = simd_speedup_cells.min(speedup);
        println!(
            "{:12} cell bounds: per-cell={cells_scalar_ms:8.2} ms | \
             batched={cells_batched_ms:8.2} ms ({speedup:.2}x, bit-identical)",
            w.name,
        );
        cells_rows.push(obj(vec![
            ("workload", Json::Str(w.name.into())),
            ("cells_scalar_ms", Json::Num(cells_scalar_ms)),
            ("cells_batched_ms", Json::Num(cells_batched_ms)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    set_thread_cap(0);
    println!("floor: batched cell evaluation >= 2x per-cell on >= 4-core runners");

    // --- lane-parallel Erlang-C (ungated, informational) -----------------
    #[cfg(feature = "simd")]
    let simd_speedup_erlang_lanes = {
        use fleetopt::queueing::erlang::erlang_c;
        use fleetopt::queueing::simd::lanes::erlang_c_batch;
        let points: Vec<(u64, f64)> = (0..4096u64)
            .map(|i| (1 + (i % 512) * 4, 0.5 + 0.4999 * (i as f64 / 4096.0)))
            .collect();
        let erlang_scalar_ms = median_ms(9, || {
            let mut acc = 0.0;
            for &(c, rho) in &points {
                acc += erlang_c(c, rho);
            }
            std::hint::black_box(acc);
        });
        let mut lanes_out = Vec::new();
        let erlang_lanes_ms = median_ms(9, || {
            erlang_c_batch(&points, &mut lanes_out);
            std::hint::black_box(lanes_out.len());
        });
        let speedup = erlang_scalar_ms / erlang_lanes_ms.max(1e-9);
        println!(
            "erlang-C x{}    : scalar {erlang_scalar_ms:7.3} ms | \
             lanes {erlang_lanes_ms:7.3} ms ({speedup:.2}x)",
            points.len(),
        );
        speedup
    };
    #[cfg(not(feature = "simd"))]
    let simd_speedup_erlang_lanes = 1.0;

    // --- warm-vs-cold inversion probes + incremental replanner -----------
    let wz2 = traces::azure();
    let svc2 = calibrate(&wz2.cdf, &wz2.output, &GpuProfile::a100_llama70b(), 682, 10_000, 11);
    let probe_lambdas: Vec<f64> = (1..=30).map(|i| 95.0 * i as f64).collect();
    clear_warm_hints();
    let (pc0, _) = sizing_probe_stats();
    for &lam in &probe_lambdas {
        clear_warm_hints();
        std::hint::black_box(min_gpus(lam, &svc2, 0.5, 0.85, false).unwrap());
    }
    let (pc1, _) = sizing_probe_stats();
    for &lam in &probe_lambdas {
        std::hint::black_box(min_gpus(lam, &svc2, 0.5, 0.85, false).unwrap());
    }
    let (pc2, _) = sizing_probe_stats();
    for &lam in &probe_lambdas {
        std::hint::black_box(min_gpus(lam, &svc2, 0.5, 0.85, false).unwrap());
    }
    let (pc3, _) = sizing_probe_stats();
    let probes_cold = (pc1 - pc0) as f64;
    let probes_warm = (pc3 - pc2) as f64;
    println!(
        "inversion probes x{}: cold={probes_cold:.0} | warm={probes_warm:.0} \
         ({:.2}x fewer)",
        probe_lambdas.len(),
        probes_cold / probes_warm.max(1.0),
    );

    // Incremental replanner: unchanged-fingerprint epochs against a warm
    // cache + neighbourhood seeds, vs the cold full K=3 sweep baseline
    // (>= 10x CI floor).
    let input_rp = PlanInput::new(traces::azure(), 1000.0);
    let (initial, _) = sweep_tiered_pruned(&input_rp, 3, &CalibCache::new()).unwrap();
    let mut rp = Replanner::new(
        ReplanConfig {
            sweep_boundaries: true,
            incremental: true,
            ..ReplanConfig::default()
        },
        initial,
    );
    rp.replan(&input_rp).unwrap(); // warm the cache + fingerprint
    let mut flip = false;
    let replan_warm_ms = median_ms(7, || {
        let mut pi = input_rp.clone();
        pi.lambda = if flip { 1050.0 } else { 955.0 };
        flip = !flip;
        std::hint::black_box(rp.replan(&pi).unwrap());
    });
    let replan_speedup = azure_k3_cold_ms / replan_warm_ms.max(1e-9);
    println!(
        "replanner: warm incremental replan={replan_warm_ms:7.2} ms vs cold K=3 \
         sweep={azure_k3_cold_ms:8.1} ms ({replan_speedup:.1}x; floor >= 10x)"
    );

    // --- DES validation replications: sequential vs parallel -------------
    let w = traces::azure();
    let seeds: Vec<u64> = (0..4).map(|i| 0xDE5 + i).collect();
    let n_per_pool = 3_000;
    let t0 = Instant::now();
    for &s in &seeds {
        std::hint::black_box(table5_validate_replicated(&w, 1000.0, n_per_pool, &[s]).len());
    }
    let des_seq_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    std::hint::black_box(table5_validate_replicated(&w, 1000.0, n_per_pool, &seeds).len());
    let des_par_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "DES validation x{}: sequential {des_seq_ms:8.1} ms | parallel {des_par_ms:8.1} ms \
         ({:.2}x)",
        seeds.len(),
        des_seq_ms / des_par_ms.max(1e-9),
    );

    // --- raw pool-level DES replications (single pool, fixed shape) ------
    let g = GpuProfile::a100_llama70b();
    let cfg = SimConfig::new(g, 4, 16);
    let pool_traces: Vec<Vec<SimRequest>> = (0..4u64)
        .map(|k| {
            let mut rng = Rng::new(0xB00 + k);
            let mut t = 0.0;
            (0..20_000)
                .map(|_| {
                    t += rng.exp(20.0);
                    SimRequest { arrival_s: t, l_in: 1024, l_out: 98 }
                })
                .collect()
        })
        .collect();
    let t0 = Instant::now();
    for tr in &pool_traces {
        std::hint::black_box(simulate_pool(&cfg, tr).completed);
    }
    let pool_seq_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    std::hint::black_box(simulate_pool_replications(&cfg, &pool_traces).len());
    let pool_par_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "pool DES x4       : sequential {pool_seq_ms:8.1} ms | parallel {pool_par_ms:8.1} ms \
         ({:.2}x)",
        pool_seq_ms / pool_par_ms.max(1e-9),
    );

    let report = obj(vec![
        ("bench", Json::Str("perf_planner".into())),
        ("sweeps", Json::Arr(sweep_rows)),
        ("tier_sweeps", Json::Arr(tier_rows)),
        ("plan_fleet_ms_max", Json::Num(plan_fleet_ms_max)),
        ("k3_pruned_ms_max", Json::Num(k3_pruned_ms_max)),
        ("k3_pruned_frac_min", Json::Num(pruned_frac_min)),
        ("moment_table_build_ms", Json::Num(table_build_ms)),
        ("anytime_exact_single_sku", Json::Bool(anytime_exact_single_sku)),
        ("anytime_incumbent_ms", Json::Num(anytime_incumbent_ms)),
        ("anytime_gap_pct", Json::Num(anytime_gap_pct)),
        ("anytime_cells_per_s", Json::Num(anytime_cells_per_s)),
        ("cell_bounds", Json::Arr(cells_rows)),
        ("simd_cells_identical", Json::Bool(true)),
        ("simd_cells_scalar_ms", Json::Num(simd_cells_scalar_ms)),
        ("simd_cells_batched_ms", Json::Num(simd_cells_batched_ms)),
        ("simd_speedup_cells", Json::Num(simd_speedup_cells)),
        ("simd_speedup_erlang_lanes", Json::Num(simd_speedup_erlang_lanes)),
        ("inversion_probes_cold", Json::Num(probes_cold)),
        ("inversion_probes_warm", Json::Num(probes_warm)),
        ("replan_warm_ms", Json::Num(replan_warm_ms)),
        ("replan_cold_sweep_ms", Json::Num(azure_k3_cold_ms)),
        ("replan_warm_speedup", Json::Num(replan_speedup)),
        ("sizing_first_fill_ms", Json::Num(sizing_first_fill_ms)),
        ("sizing_warm_ms", Json::Num(sizing_warm_ms)),
        (
            "sizing_warm_speedup",
            Json::Num(sizing_first_fill_ms / sizing_warm_ms.max(1e-9)),
        ),
        ("erlang_cache_hits", Json::Num(erlang_hits as f64)),
        ("erlang_cache_misses", Json::Num(erlang_misses as f64)),
        ("des_replications", Json::Num(seeds.len() as f64)),
        ("des_requests_per_pool", Json::Num(n_per_pool as f64)),
        ("des_sequential_ms", Json::Num(des_seq_ms)),
        ("des_parallel_ms", Json::Num(des_par_ms)),
        (
            "des_speedup",
            Json::Num(des_seq_ms / des_par_ms.max(1e-9)),
        ),
        ("pool_des_sequential_ms", Json::Num(pool_seq_ms)),
        ("pool_des_parallel_ms", Json::Num(pool_par_ms)),
        (
            "pool_des_speedup",
            Json::Num(pool_seq_ms / pool_par_ms.max(1e-9)),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_planner.json");
    std::fs::write(path, report.to_string_pretty() + "\n").expect("writing BENCH_planner.json");
    println!("wrote {path}");
}
