//! Bench E9 / §Perf — planner wall-clock. The paper claims the full
//! Algorithm-1 sweep completes in under 1 ms; this bench times single
//! cells, the fixed-B gamma sweep, and the full (B, gamma) sweep for each
//! workload (serial vs thread-scope-sharded), plus the Table-5 DES
//! validation replications (sequential vs parallel). Emits
//! `BENCH_planner.json` at the repo root so the perf trajectory is tracked
//! across PRs.

use std::time::Instant;

use fleetopt::config::GpuProfile;
use fleetopt::experiments::table5_validate_replicated;
use fleetopt::fleetsim::sim::{simulate_pool, simulate_pool_replications, SimConfig, SimRequest};
use fleetopt::planner::sizing::min_gpus;
use fleetopt::planner::{
    plan_fleet, sweep_full, sweep_full_serial, sweep_gamma, sweep_tiered, PlanInput,
};
use fleetopt::queueing::erlang::erlang_cache_stats;
use fleetopt::queueing::service::calibrate;
use fleetopt::util::json::{obj, Json};
use fleetopt::util::rng::Rng;
use fleetopt::workload::traces;

fn time_ms<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

fn main() {
    let mut sweep_rows = Vec::new();
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        let cell = time_ms(10, || {
            std::hint::black_box(plan_fleet(&input, w.b_short, 1.5).unwrap());
        });
        let gsweep = time_ms(5, || {
            std::hint::black_box(sweep_gamma(&input, w.b_short).unwrap());
        });
        let full_serial = time_ms(3, || {
            std::hint::black_box(sweep_full_serial(&input).unwrap());
        });
        let full_par = time_ms(3, || {
            std::hint::black_box(sweep_full(&input).unwrap());
        });
        println!(
            "{:12} cell={cell:7.3} ms | gamma-sweep(11)={gsweep:8.3} ms | \
             full-sweep serial={full_serial:8.3} ms parallel={full_par:8.3} ms \
             ({:.2}x)",
            w.name,
            full_serial / full_par.max(1e-9),
        );
        sweep_rows.push(obj(vec![
            ("workload", Json::Str(w.name.into())),
            ("cell_ms", Json::Num(cell)),
            ("gamma_sweep_ms", Json::Num(gsweep)),
            ("full_sweep_serial_ms", Json::Num(full_serial)),
            ("full_sweep_parallel_ms", Json::Num(full_par)),
            (
                "full_sweep_speedup",
                Json::Num(full_serial / full_par.max(1e-9)),
            ),
        ]));
    }
    println!("paper §6: full sweep < 1 ms (target for the §Perf pass)");

    // --- Erlang-memo: the sizing inversion, first-fill vs warm (§Perf) ---
    // "First-fill" repetitions run on a fresh scoped thread each (fresh
    // thread-local Erlang memo, every cell computed at least once — note
    // this is NOT a pre-memo baseline: intra-run repeats already hit the
    // memo); the warm pass re-runs the identical lambda grid on this
    // thread with the memo fully populated. Results are bit-identical
    // either way (tested in `planner::sizing`); the drop shows what a
    // warm replanner/sweep saves per revisited cell.
    let wz = traces::azure();
    let gpz = GpuProfile::a100_llama70b();
    let svc = calibrate(&wz.cdf, &wz.output, &gpz, 682, 10_000, 11);
    let lambdas: Vec<f64> = (1..=40).map(|i| 75.0 * i as f64).collect();
    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        std::thread::scope(|s| {
            s.spawn(|| {
                for &lam in &lambdas {
                    std::hint::black_box(min_gpus(lam, &svc, 0.5, 0.85, false).unwrap());
                }
            })
            .join()
            .expect("first-fill sizing worker panicked");
        });
    }
    let sizing_first_fill_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    for &lam in &lambdas {
        std::hint::black_box(min_gpus(lam, &svc, 0.5, 0.85, false).unwrap());
    }
    let t0 = Instant::now();
    for _ in 0..reps {
        for &lam in &lambdas {
            std::hint::black_box(min_gpus(lam, &svc, 0.5, 0.85, false).unwrap());
        }
    }
    let sizing_warm_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    let (erlang_hits, erlang_misses) = erlang_cache_stats();
    println!(
        "sizing inversion x{}: first-fill={sizing_first_fill_ms:7.3} ms | \
         warm={sizing_warm_ms:7.3} ms \
         ({:.1}x; erlang memo {erlang_hits} hits / {erlang_misses} misses)",
        lambdas.len(),
        sizing_first_fill_ms / sizing_warm_ms.max(1e-9),
    );

    // --- K-tier boundary-combination sweeps (Table 8 substrate) ----------
    let mut tier_rows = Vec::new();
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        let k3 = time_ms(3, || {
            std::hint::black_box(sweep_tiered(&input, 3).unwrap());
        });
        let k4 = time_ms(1, || {
            std::hint::black_box(sweep_tiered(&input, 4).unwrap());
        });
        println!(
            "{:12} K=3 sweep={k3:8.1} ms | K=4 sweep={k4:8.1} ms (acceptance: K=3 < 100 ms)",
            w.name
        );
        tier_rows.push(obj(vec![
            ("workload", Json::Str(w.name.into())),
            ("k3_sweep_ms", Json::Num(k3)),
            ("k4_sweep_ms", Json::Num(k4)),
        ]));
    }

    // --- DES validation replications: sequential vs parallel -------------
    let w = traces::azure();
    let seeds: Vec<u64> = (0..4).map(|i| 0xDE5 + i).collect();
    let n_per_pool = 3_000;
    let t0 = Instant::now();
    for &s in &seeds {
        std::hint::black_box(table5_validate_replicated(&w, 1000.0, n_per_pool, &[s]).len());
    }
    let des_seq_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    std::hint::black_box(table5_validate_replicated(&w, 1000.0, n_per_pool, &seeds).len());
    let des_par_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "DES validation x{}: sequential {des_seq_ms:8.1} ms | parallel {des_par_ms:8.1} ms \
         ({:.2}x)",
        seeds.len(),
        des_seq_ms / des_par_ms.max(1e-9),
    );

    // --- raw pool-level DES replications (single pool, fixed shape) ------
    let g = GpuProfile::a100_llama70b();
    let cfg = SimConfig::new(g, 4, 16);
    let pool_traces: Vec<Vec<SimRequest>> = (0..4u64)
        .map(|k| {
            let mut rng = Rng::new(0xB00 + k);
            let mut t = 0.0;
            (0..20_000)
                .map(|_| {
                    t += rng.exp(20.0);
                    SimRequest { arrival_s: t, l_in: 1024, l_out: 98 }
                })
                .collect()
        })
        .collect();
    let t0 = Instant::now();
    for tr in &pool_traces {
        std::hint::black_box(simulate_pool(&cfg, tr).completed);
    }
    let pool_seq_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    std::hint::black_box(simulate_pool_replications(&cfg, &pool_traces).len());
    let pool_par_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "pool DES x4       : sequential {pool_seq_ms:8.1} ms | parallel {pool_par_ms:8.1} ms \
         ({:.2}x)",
        pool_seq_ms / pool_par_ms.max(1e-9),
    );

    let report = obj(vec![
        ("bench", Json::Str("perf_planner".into())),
        ("sweeps", Json::Arr(sweep_rows)),
        ("tier_sweeps", Json::Arr(tier_rows)),
        ("sizing_first_fill_ms", Json::Num(sizing_first_fill_ms)),
        ("sizing_warm_ms", Json::Num(sizing_warm_ms)),
        (
            "sizing_warm_speedup",
            Json::Num(sizing_first_fill_ms / sizing_warm_ms.max(1e-9)),
        ),
        ("erlang_cache_hits", Json::Num(erlang_hits as f64)),
        ("erlang_cache_misses", Json::Num(erlang_misses as f64)),
        ("des_replications", Json::Num(seeds.len() as f64)),
        ("des_requests_per_pool", Json::Num(n_per_pool as f64)),
        ("des_sequential_ms", Json::Num(des_seq_ms)),
        ("des_parallel_ms", Json::Num(des_par_ms)),
        (
            "des_speedup",
            Json::Num(des_seq_ms / des_par_ms.max(1e-9)),
        ),
        ("pool_des_sequential_ms", Json::Num(pool_seq_ms)),
        ("pool_des_parallel_ms", Json::Num(pool_par_ms)),
        (
            "pool_des_speedup",
            Json::Num(pool_seq_ms / pool_par_ms.max(1e-9)),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_planner.json");
    std::fs::write(path, report.to_string_pretty() + "\n").expect("writing BENCH_planner.json");
    println!("wrote {path}");
}
