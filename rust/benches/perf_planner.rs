//! Bench E9 / §Perf — planner wall-clock. The paper claims the full
//! Algorithm-1 sweep completes in under 1 ms; this bench times single
//! cells, the fixed-B gamma sweep, and the full (B, gamma) sweep for each
//! workload, and reports per-stage costs for the optimization log.

use std::time::Instant;

use fleetopt::planner::{plan_fleet, sweep_full, sweep_gamma, PlanInput};
use fleetopt::workload::traces;

fn time_ms<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

fn main() {
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        let cell = time_ms(10, || {
            std::hint::black_box(plan_fleet(&input, w.b_short, 1.5).unwrap());
        });
        let gsweep = time_ms(5, || {
            std::hint::black_box(sweep_gamma(&input, w.b_short).unwrap());
        });
        let full = time_ms(3, || {
            std::hint::black_box(sweep_full(&input).unwrap());
        });
        println!(
            "{:12} cell={cell:7.3} ms | gamma-sweep(11)={gsweep:8.3} ms | full-sweep={full:8.3} ms",
            w.name
        );
    }
    println!("paper §6: full sweep < 1 ms (target for the §Perf pass)");
}
