//! Integration: the full AOT bridge — load HLO-text artifacts, compile on
//! the PJRT CPU client, run prefill → decode → embed, and check the
//! numerics behave like a language model (finite logits, deterministic,
//! KV-cache consistency between chunked prefill and decode).
//!
//! Skips (with a notice) when `artifacts/` has not been built.

use fleetopt::runtime::{cosine, ModelRuntime, PoolKind};

fn runtime() -> Option<ModelRuntime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ModelRuntime::load(dir).expect("loading artifacts"))
}

#[test]
fn prefill_decode_roundtrip() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let chunk = m.chunk;
    let slot = rt.slot_cache_len(PoolKind::Short);
    let vocab = m.model.vocab;

    // Prefill a 10-token prompt in one chunk.
    let k0 = vec![0f32; slot];
    let v0 = vec![0f32; slot];
    let mut tokens = vec![0i32; chunk];
    for (i, t) in tokens.iter_mut().enumerate().take(10) {
        *t = (i as i32 * 37 + 11) % vocab as i32;
    }
    let out = rt
        .prefill(PoolKind::Short, &k0, &v0, &tokens, 0)
        .expect("prefill");
    assert_eq!(out.logits.len(), chunk * vocab);
    assert!(out.logits.iter().all(|x| x.is_finite()));
    assert_eq!(out.k_cache.len(), slot);

    // The prompt's last-position logits pick the first generated token.
    let last = &out.logits[9 * vocab..10 * vocab];
    let first_tok = last
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;

    // Assemble a batched decode cache: slot 0 = the prefilled slot, the
    // other slots idle (pos 0, token 0 — outputs ignored).
    let shape = m.pool(PoolKind::Short);
    let mut kb = vec![0f32; shape.n_slots * slot];
    let mut vb = vec![0f32; shape.n_slots * slot];
    kb[..slot].copy_from_slice(&out.k_cache);
    vb[..slot].copy_from_slice(&out.v_cache);
    let mut toks = vec![0i32; shape.n_slots];
    let mut pos = vec![0i32; shape.n_slots];
    toks[0] = first_tok;
    pos[0] = 10;
    let dec = rt
        .decode(PoolKind::Short, &kb, &vb, &toks, &pos)
        .expect("decode");
    assert_eq!(dec.logits.len(), shape.n_slots * vocab);
    assert!(dec.logits.iter().all(|x| x.is_finite()));

    // Determinism: same inputs, same outputs.
    let dec2 = rt.decode(PoolKind::Short, &kb, &vb, &toks, &pos).unwrap();
    assert_eq!(dec.logits, dec2.logits);
}

#[test]
fn chunked_prefill_matches_oneshot() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let chunk = m.chunk;
    let slot = rt.slot_cache_len(PoolKind::Short);
    let vocab = m.model.vocab;
    let n = chunk + 8; // forces two chunks

    let prompt: Vec<i32> = (0..n).map(|i| (i as i32 * 53 + 7) % vocab as i32).collect();

    // Two chunks.
    let mut k = vec![0f32; slot];
    let mut v = vec![0f32; slot];
    let out1 = rt
        .prefill(PoolKind::Short, &k, &v, &prompt[..chunk], 0)
        .unwrap();
    k = out1.k_cache;
    v = out1.v_cache;
    let mut tail = vec![0i32; chunk];
    tail[..8].copy_from_slice(&prompt[chunk..]);
    let out2 = rt
        .prefill(PoolKind::Short, &k, &v, &tail, chunk as i32)
        .unwrap();

    // Last valid logits row of the second chunk must equal a decode step's
    // prediction context — check finiteness and that the cache positions
    // beyond n are untouched zeros is NOT expected (garbage tolerated), but
    // the first n rows must be stable across a replay.
    let replay = rt
        .prefill(PoolKind::Short, &k, &v, &tail, chunk as i32)
        .unwrap();
    assert_eq!(out2.logits, replay.logits);
    let row = &out2.logits[7 * vocab..8 * vocab];
    assert!(row.iter().all(|x| x.is_finite()));
}

#[test]
fn long_pool_artifacts_work() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let slot = rt.slot_cache_len(PoolKind::Long);
    let shape = m.pool(PoolKind::Long);
    let k = vec![0f32; shape.n_slots * slot];
    let v = vec![0f32; shape.n_slots * slot];
    let toks = vec![5i32; shape.n_slots];
    let pos = vec![0i32; shape.n_slots];
    let out = rt.decode(PoolKind::Long, &k, &v, &toks, &pos).unwrap();
    assert_eq!(out.logits.len(), shape.n_slots * m.model.vocab);
    assert!(out.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn embedding_similarity_orders_sensibly() {
    let Some(rt) = runtime() else { return };
    let a = "The fleet planner derives the minimum cost configuration from the workload CDF.";
    let a_near = "The fleet planner computes the minimum cost configuration from the workload distribution.";
    let b = "Quarterly marketing results improved across all regional retail segments.";

    let ea = rt.embed_text(a).unwrap();
    let ea2 = rt.embed_text(a).unwrap();
    assert_eq!(ea, ea2, "embedding must be deterministic");

    let en = rt.embed_text(a_near).unwrap();
    let eb = rt.embed_text(b).unwrap();
    let sim_near = cosine(&ea, &en);
    let sim_far = cosine(&ea, &eb);
    assert!(
        sim_near > sim_far,
        "near {sim_near} should beat far {sim_far}"
    );
}
