//! Sharded/memoized gateway admission vs the serial oracle (PR 8).
//!
//! `OracleGateway::route` below is a **verbatim transcription of the
//! pre-refactor serial `Gateway::route`** — one gateway, one scratch, one
//! request at a time. Every test pins the production path (decomposed
//! ladder, sharded batches, route memo) against it: all `RoutedRequest`
//! fields except the wall-clock `gateway_s`, the merged counters, and the
//! EMA estimator bits must be identical for every worker count, cache
//! capacity, and batch decomposition.

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::compress::extractive::compress_with;
use fleetopt::compress::gate::{clamp_gamma, compression_budget, gate, GateDecision};
use fleetopt::compress::scratch::CompressScratch;
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::router::classify::classify;
use fleetopt::router::memo::{CacheKey, Lookup, RouteCache};
use fleetopt::router::{
    effective_workers, Gateway, GatewayConfig, GatewayMetrics, RoutedRequest, TokenEstimator,
};
use fleetopt::util::check::{ensure, forall};
use fleetopt::util::par::set_thread_cap;
use fleetopt::util::rng::Rng;
use fleetopt::util::simd::{with_dispatch, Dispatch};
use fleetopt::workload::request::Category;

// ---------------------------------------------------------------------------
// The serial oracle (pre-refactor Gateway::route, kept verbatim).

struct OracleGateway {
    cfg: GatewayConfig,
    estimator: TokenEstimator,
    scratch: CompressScratch,
    n_routed: Vec<u64>,
    n_compressed: u64,
    n_compress_failed: u64,
}

struct OracleRouted {
    tier: usize,
    text: String,
    prompt_tokens: u32,
    max_output_tokens: u32,
    category: Category,
    estimated_l_total: u32,
    compressed: bool,
}

impl OracleGateway {
    fn new(cfg: GatewayConfig) -> Self {
        let k = cfg.n_tiers();
        OracleGateway {
            cfg,
            estimator: TokenEstimator::default(),
            scratch: CompressScratch::new(),
            n_routed: vec![0; k],
            n_compressed: 0,
            n_compress_failed: 0,
        }
    }

    fn route(&mut self, text: &str, max_output_tokens: u32) -> OracleRouted {
        let category = classify(text);
        let est_prompt = self.estimator.estimate_prompt_tokens(text.len(), category);
        let est_total = est_prompt + max_output_tokens;
        let actual_prompt = count_tokens(text);
        self.estimator.update(text.len(), actual_prompt, category);

        let last_tier = self.cfg.tiers.len();
        let mut routed = None;
        for tier in 0..last_tier {
            let tr = self.cfg.tiers[tier];
            let gamma = if self.cfg.enable_cr { tr.gamma } else { 1.0 };
            let gamma = clamp_gamma(
                tr.boundary,
                self.cfg.tiers.get(tier + 1).map(|t| t.boundary),
                gamma,
            );
            match gate(est_total, tr.boundary, gamma, category) {
                GateDecision::RouteShort => {
                    routed = Some(OracleRouted {
                        tier,
                        text: text.to_string(),
                        prompt_tokens: actual_prompt,
                        max_output_tokens,
                        category,
                        estimated_l_total: est_total,
                        compressed: false,
                    });
                    break;
                }
                GateDecision::CompressAndRoute => {
                    match compression_budget(tr.boundary, max_output_tokens) {
                        Some(budget) => {
                            let c = compress_with(&mut self.scratch, text, budget);
                            if c.ok {
                                self.n_compressed += 1;
                                routed = Some(OracleRouted {
                                    tier,
                                    prompt_tokens: count_tokens(&c.text),
                                    text: c.text,
                                    max_output_tokens,
                                    category,
                                    estimated_l_total: est_total,
                                    compressed: true,
                                });
                                break;
                            }
                            self.n_compress_failed += 1;
                        }
                        None => {
                            self.n_compress_failed += 1;
                        }
                    }
                }
                GateDecision::BandButUnsafe | GateDecision::RouteLong => {}
            }
        }
        let routed = routed.unwrap_or_else(|| OracleRouted {
            tier: last_tier,
            text: text.to_string(),
            prompt_tokens: actual_prompt,
            max_output_tokens,
            category,
            estimated_l_total: est_total,
            compressed: false,
        });
        self.n_routed[routed.tier] += 1;
        routed
    }

    fn metrics(&self) -> GatewayMetrics {
        GatewayMetrics {
            n_routed: self.n_routed.clone(),
            n_compressed: self.n_compressed,
            n_compress_failed: self.n_compress_failed,
        }
    }
}

// ---------------------------------------------------------------------------
// Traces: three mixed workloads (short / borderline prose / borderline
// code / long / in-trace duplicates), sized for debug-build test budgets.

fn doc(tokens: u32, rng: &mut Rng) -> String {
    corpus::generate_document(
        &CorpusConfig {
            target_tokens: tokens,
            ..Default::default()
        },
        rng,
    )
}

/// (config, requests) — requests share texts (duplicates) on purpose.
fn trace(kind: usize) -> (GatewayConfig, Vec<(String, u32)>) {
    let mut rng = Rng::new(100 + kind as u64);
    let cfg = match kind {
        0 => GatewayConfig::two_tier(512, 1.5, true),
        1 => GatewayConfig::tiered(&[256, 768], 1.5, true),
        _ => GatewayConfig::two_tier(640, 1.4, true),
    };
    // A small unique pool with short, borderline-prose, borderline-code,
    // and long docs; the trace resamples it with repeats.
    let b = cfg.b_short();
    let mut pool: Vec<(String, u32)> = Vec::new();
    for i in 0..4 {
        pool.push((doc(120 + 40 * i, &mut rng), 16));
    }
    for i in 0..4 {
        // Land inside the band of some boundary: est ~ [B+eps, 1.4 B].
        pool.push((doc(b + 30 + 60 * i, &mut rng), 32));
    }
    pool.push((corpus::generate_code(b + 100, &mut rng), 32));
    pool.push((doc(3 * b, &mut rng), 64));
    // One band request with an output budget >= boundary (no feasible
    // compression budget -> fail-safe fall-through).
    pool.push((doc(b / 4, &mut rng), b + 50));
    let mut requests = Vec::new();
    for k in 0..28 {
        let pick = (k * 7 + kind) % pool.len();
        requests.push(pool[pick].clone());
    }
    (cfg, requests)
}

fn collect(
    gw: &mut Gateway,
    batch: &[(&str, u32)],
    workers: usize,
    cache: Option<&mut RouteCache>,
) -> Vec<RoutedRequest> {
    let mut out: Vec<Option<RoutedRequest>> = vec![None; batch.len()];
    gw.route_batch_with_opts(batch, workers, cache, |i, r| out[i] = Some(r));
    out.into_iter().map(|r| r.expect("sink saw every index")).collect()
}

fn assert_matches_oracle(kind: usize, got: &[RoutedRequest], oracle: &[OracleRouted]) {
    assert_eq!(got.len(), oracle.len());
    for (i, (g, o)) in got.iter().zip(oracle).enumerate() {
        assert_eq!(g.tier, o.tier, "trace {kind} req {i} tier");
        assert_eq!(g.text, o.text, "trace {kind} req {i} text bytes");
        assert_eq!(g.prompt_tokens, o.prompt_tokens, "trace {kind} req {i}");
        assert_eq!(g.max_output_tokens, o.max_output_tokens, "trace {kind} req {i}");
        assert_eq!(g.category, o.category, "trace {kind} req {i}");
        assert_eq!(g.estimated_l_total, o.estimated_l_total, "trace {kind} req {i}");
        assert_eq!(g.compressed, o.compressed, "trace {kind} req {i}");
    }
}

// ---------------------------------------------------------------------------
// Tentpole identity: every worker count x cache setting == serial oracle.

#[test]
fn sharded_routing_matches_serial_oracle_all_traces() {
    for kind in 0..3 {
        let (cfg, requests) = trace(kind);
        let batch: Vec<(&str, u32)> = requests.iter().map(|(t, m)| (t.as_str(), *m)).collect();
        let mut oracle = OracleGateway::new(cfg.clone());
        let oracle_out: Vec<OracleRouted> =
            batch.iter().map(|&(t, m)| oracle.route(t, m)).collect();

        for workers in [1usize, 2, 8] {
            for cache_cap in [0usize, 1024, 4] {
                let mut gw = Gateway::new(cfg.clone());
                let mut cache = (cache_cap > 0).then(|| RouteCache::new(cache_cap));
                let got = collect(&mut gw, &batch, workers, cache.as_mut());
                assert_matches_oracle(kind, &got, &oracle_out);
                assert_eq!(
                    gw.metrics(),
                    oracle.metrics(),
                    "trace {kind} workers {workers} cache {cache_cap}: merged counters"
                );
                assert_eq!(
                    gw.estimator.c_hat_bits(),
                    oracle.estimator.c_hat_bits(),
                    "trace {kind} workers {workers} cache {cache_cap}: EMA bits"
                );
                if let Some(c) = &cache {
                    assert!(c.len() <= c.capacity(), "capacity bound");
                }
            }
        }
    }
}

/// Cache state (stats, LRU order) and outputs must not depend on how a
/// request stream is chopped into batches or on the worker count.
#[test]
fn batch_decomposition_and_worker_count_leave_cache_state_invariant() {
    let (cfg, requests) = trace(0);
    let batch: Vec<(&str, u32)> = requests.iter().map(|(t, m)| (t.as_str(), *m)).collect();

    let mut reference: Option<(Vec<RoutedRequest>, _, Vec<CacheKey>)> = None;
    for (workers, splits) in [(1usize, 1usize), (2, 1), (8, 1), (2, 3), (8, 4)] {
        let mut gw = Gateway::new(cfg.clone());
        let mut cache = RouteCache::new(64);
        let mut got = Vec::new();
        let per = batch.len().div_ceil(splits);
        for chunk in batch.chunks(per) {
            got.extend(collect(&mut gw, chunk, workers, Some(&mut cache)));
        }
        let state = (got, cache.stats, cache.keys_lru_order());
        if let Some((ref_out, ref_stats, ref_lru)) = &reference {
            for (g, r) in state.0.iter().zip(ref_out) {
                assert_eq!(g.tier, r.tier, "workers {workers} splits {splits}");
                assert_eq!(g.text, r.text, "workers {workers} splits {splits}");
                assert_eq!(g.prompt_tokens, r.prompt_tokens);
                assert_eq!(g.compressed, r.compressed);
                assert_eq!(g.estimated_l_total, r.estimated_l_total);
            }
            assert_eq!(
                state.1, *ref_stats,
                "workers {workers} splits {splits}: cache stats"
            );
            assert_eq!(
                state.2, *ref_lru,
                "workers {workers} splits {splits}: LRU order"
            );
        } else {
            reference = Some(state);
        }
    }
}

#[test]
fn thread_cap_forces_serial_sharding() {
    // Pin the cap explicitly so the asserts hold regardless of any
    // ambient FLEETOPT_THREADS in the environment.
    set_thread_cap(16);
    assert_eq!(effective_workers(64, 1000), 16, "hard ceiling");
    assert_eq!(effective_workers(3, 2), 2, "never more workers than items");
    assert_eq!(effective_workers(1, 100), 1);
    set_thread_cap(1);
    assert_eq!(effective_workers(8, 100), 1, "--threads 1 forces serial");
    assert_eq!(effective_workers(0, 100), 1, "auto honors the cap too");
    set_thread_cap(0);
}

// ---------------------------------------------------------------------------
// Failover-retry satellite: rerouting a failed request must be
// side-effect-free on first-attempt state (ISSUE 9).

/// A crash-failover retry storm must leave the gateway's first-attempt
/// accounting untouched: the EMA estimator bits, the per-tier routed
/// counters, and the route memo (stats *and* LRU order) are pinned before
/// and after hammering `reroute_failed` — a retried request is a routing
/// decision replay, not a new observation.
#[test]
fn failover_retries_leave_estimator_and_memo_untouched() {
    for kind in 0..3 {
        let (cfg, requests) = trace(kind);
        let batch: Vec<(&str, u32)> = requests.iter().map(|(t, m)| (t.as_str(), *m)).collect();
        let mut gw = Gateway::new(cfg.clone());
        let mut cache = RouteCache::new(64);
        // Warm pass: populates the estimator, counters, and memo.
        let _warm = collect(&mut gw, &batch, 2, Some(&mut cache));

        let ema = gw.estimator.c_hat_bits();
        let metrics = gw.metrics();
        let stats = cache.stats;
        let lru = cache.keys_lru_order();
        assert_eq!(gw.n_rerouted, 0);

        // The storm: every request fails over three times, interleaved so
        // any accidental state mutation would compound across requests.
        let mut retried: Vec<Vec<RoutedRequest>> = vec![Vec::new(); batch.len()];
        for _round in 0..3 {
            for (i, &(text, max_out)) in batch.iter().enumerate() {
                retried[i].push(gw.reroute_failed(text, max_out));
            }
        }

        assert_eq!(gw.estimator.c_hat_bits(), ema, "trace {kind}: EMA moved");
        assert_eq!(gw.metrics(), metrics, "trace {kind}: first-attempt counters moved");
        assert_eq!(cache.stats, stats, "trace {kind}: memo stats moved");
        assert_eq!(cache.keys_lru_order(), lru, "trace {kind}: memo LRU moved");
        assert_eq!(gw.n_rerouted, 3 * batch.len() as u64, "trace {kind}");

        // Retries are deterministic replays: all three rounds agree with
        // each other, and the decision matches the first attempt whenever
        // the first attempt ran on the same estimator state (i.e. for
        // every request, the retry uses the *final* EMA — so at minimum
        // the three retry rounds must be bit-identical among themselves).
        for (i, rounds) in retried.iter().enumerate() {
            for r in &rounds[1..] {
                assert_eq!(r.tier, rounds[0].tier, "trace {kind} req {i}");
                assert_eq!(r.text, rounds[0].text, "trace {kind} req {i}: text bytes");
                assert_eq!(r.prompt_tokens, rounds[0].prompt_tokens, "trace {kind} req {i}");
                assert_eq!(r.compressed, rounds[0].compressed, "trace {kind} req {i}");
                assert_eq!(
                    r.estimated_l_total, rounds[0].estimated_l_total,
                    "trace {kind} req {i}"
                );
            }
        }
        // And the storm's replies still carry routable tiers.
        for (i, rounds) in retried.iter().enumerate() {
            assert!(rounds[0].tier < cfg.n_tiers(), "trace {kind} req {i}");
        }
    }
}

// ---------------------------------------------------------------------------
// Memo satellites: eviction order, capacity, invalidation, dispatch modes.

/// LRU behaviour against a straight `Vec`-based reference model, over
/// random op sequences on a small key space.
#[test]
fn memo_eviction_order_matches_reference_lru() {
    forall(
        "route-cache-lru",
        60,
        |rng| {
            let cap = rng.range(1, 5);
            let ops: Vec<(usize, bool)> = (0..40)
                .map(|_| (rng.range(0, 8), rng.bool(0.5)))
                .collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let texts: Vec<String> = (0..8).map(|i| format!("request-text-{i}")).collect();
            let mut cache = RouteCache::new(*cap);
            cache.ensure_config(1);
            // Reference: MRU-first vec of key ids.
            let mut model: Vec<usize> = Vec::new();
            for &(id, probe_only) in ops {
                let key = CacheKey::new(&texts[id], 64, 0);
                let model_hit = model.iter().position(|&k| k == id);
                let got = cache.lookup(key, &texts[id]);
                match (model_hit, &got) {
                    (Some(pos), Lookup::Hit(out)) => {
                        ensure(out.text == texts[id], "hit returned wrong entry")?;
                        model.remove(pos);
                        model.insert(0, id);
                    }
                    (None, Lookup::Miss) => {
                        if !probe_only {
                            if let Some(slot) = cache.reserve(key, &texts[id], 0) {
                                cache.fill(
                                    slot,
                                    fleetopt::router::gateway::RouteOutcome {
                                        tier: 0,
                                        text: texts[id].clone(),
                                        prompt_tokens: 1,
                                        actual_prompt: 1,
                                        category: Category::Conversational,
                                        compressed: false,
                                        n_compress_failed: 0,
                                    },
                                );
                            }
                            if model.len() == *cap {
                                model.pop();
                            }
                            model.insert(0, id);
                        }
                    }
                    _ => {
                        return Err(format!(
                            "model/cache disagree on {id}: model {model_hit:?} cache {got:?}"
                        ))
                    }
                }
                let want: Vec<u64> =
                    model.iter().map(|&k| CacheKey::new(&texts[k], 64, 0).text_hash).collect();
                let got_order: Vec<u64> =
                    cache.keys_lru_order().iter().map(|k| k.text_hash).collect();
                ensure(
                    got_order == want,
                    format!("LRU order {got_order:?} != model {want:?}"),
                )?;
                ensure(cache.len() <= *cap, "capacity bound violated")?;
            }
            Ok(())
        },
    );
}

/// An all-unique adversarial trace must not grow the cache past capacity
/// and must never hit.
#[test]
fn memo_capacity_bound_under_all_unique_trace() {
    let cfg = GatewayConfig::two_tier(512, 1.5, true);
    let mut rng = Rng::new(7);
    let texts: Vec<String> = (0..60).map(|i| doc(80 + (i % 13) * 9, &mut rng)).collect();
    let batch: Vec<(&str, u32)> = texts.iter().map(|t| (t.as_str(), 16)).collect();
    let mut gw = Gateway::new(cfg);
    let mut cache = RouteCache::new(16);
    let _ = collect(&mut gw, &batch, 2, Some(&mut cache));
    assert!(cache.len() <= 16, "len {} > cap", cache.len());
    assert_eq!(cache.stats.hits, 0);
    assert_eq!(cache.stats.misses, 60);
    assert_eq!(cache.stats.evictions, 60 - 16);
}

/// A replan/hot-reload that moves any boundary or gamma must invalidate
/// every memoized decision.
#[test]
fn memo_invalidates_on_boundary_and_gamma_change() {
    let mut rng = Rng::new(8);
    let text = doc(300, &mut rng);
    let mut cache = RouteCache::new(32);

    let mut g1 = Gateway::new(GatewayConfig::two_tier(512, 1.5, true));
    g1.route_cached(&mut cache, &text, 16);
    g1.route_cached(&mut cache, &text, 16);
    assert_eq!(cache.stats.hits, 1, "same config: second route hits");

    // Replan moves the boundary: the entry must not survive.
    let mut g2 = Gateway::new(GatewayConfig::two_tier(520, 1.5, true));
    g2.route_cached(&mut cache, &text, 16);
    assert_eq!(cache.stats.hits, 1, "boundary change: cold again");
    assert_eq!(cache.stats.invalidations, 1);

    // Hot-reload moves gamma: invalidated again.
    let mut g3 = Gateway::new(GatewayConfig::two_tier(520, 1.4, true));
    g3.route_cached(&mut cache, &text, 16);
    assert_eq!(cache.stats.hits, 1, "gamma change: cold again");
    assert_eq!(cache.stats.invalidations, 2);

    // And back to g2's config: fingerprints differ from g3, cold again —
    // then warm within the same config.
    let mut g4 = Gateway::new(GatewayConfig::two_tier(520, 1.5, true));
    g4.route_cached(&mut cache, &text, 16);
    g4.route_cached(&mut cache, &text, 16);
    assert_eq!(cache.stats.invalidations, 3);
    assert_eq!(cache.stats.hits, 2);
}

/// Cache hits must be byte-identical to cold routing in *both* SIMD
/// dispatch modes: the doubled trace's second half is served from cache
/// under ForceSimd and compared to a scalar, uncached oracle.
#[test]
fn memo_hits_bit_identical_across_dispatch_modes() {
    let (cfg, requests) = trace(1);
    let mut doubled = requests.clone();
    doubled.extend(requests.clone());
    let batch: Vec<(&str, u32)> = doubled.iter().map(|(t, m)| (t.as_str(), *m)).collect();

    let scalar_cold = with_dispatch(Dispatch::ForceScalar, || {
        let mut gw = Gateway::new(cfg.clone());
        collect(&mut gw, &batch, 1, None)
    });
    for dispatch in [Dispatch::ForceScalar, Dispatch::ForceSimd] {
        let (cached, stats) = with_dispatch(dispatch, || {
            let mut gw = Gateway::new(cfg.clone());
            let mut cache = RouteCache::new(256);
            let out = collect(&mut gw, &batch, 2, Some(&mut cache));
            (out, cache.stats)
        });
        for (i, (c, s)) in cached.iter().zip(&scalar_cold).enumerate() {
            assert_eq!(c.tier, s.tier, "{dispatch:?} req {i}");
            assert_eq!(c.text, s.text, "{dispatch:?} req {i}: text bytes");
            assert_eq!(c.prompt_tokens, s.prompt_tokens, "{dispatch:?} req {i}");
            assert_eq!(c.compressed, s.compressed, "{dispatch:?} req {i}");
            assert_eq!(c.estimated_l_total, s.estimated_l_total, "{dispatch:?} req {i}");
        }
        assert!(
            stats.hits >= requests.len() as u64 / 2,
            "{dispatch:?}: duplicate-heavy trace should mostly hit, stats {stats:?}"
        );
    }
}
