//! Integration + property tests for the full C&R pipeline: the hard OOM
//! guarantee under randomized documents and budgets, the safety gate's
//! code exclusion, fidelity bounds, and the Eq. 14 routing arithmetic.

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::compress::extractive::compress;
use fleetopt::compress::fidelity;
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::compress::{compression_budget, gate, GateDecision};
use fleetopt::router::{classify, Gateway, GatewayConfig};
use fleetopt::util::check::{ensure, forall};
use fleetopt::util::rng::Rng;
use fleetopt::workload::request::Category;
use fleetopt::workload::traces;

#[test]
fn oom_guarantee_over_randomized_documents() {
    // The Eq. 15 property: whenever compression reports success, the
    // *recounted* tokens of the emitted text fit the budget.
    forall(
        "oom-guarantee",
        15,
        |rng| {
            let target = rng.range(400, 4_000) as u32;
            let redundancy = rng.uniform(0.0, 0.4);
            let budget_frac = rng.uniform(0.3, 1.1);
            (target, redundancy, budget_frac, rng.next_u64())
        },
        |&(target, redundancy, budget_frac, seed)| {
            let mut rng = Rng::new(seed);
            let doc = corpus::generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    redundancy,
                    paragraph_prob: 0.1,
                },
                &mut rng,
            );
            let total = count_tokens(&doc);
            let budget = ((total as f64) * budget_frac) as u32;
            let c = compress(&doc, budget);
            if c.ok {
                ensure(
                    count_tokens(&c.text) <= budget,
                    format!("{} > {budget}", count_tokens(&c.text)),
                )
            } else {
                // Failure is only legitimate when the mandatory skeleton
                // cannot fit.
                ensure(budget < total, "failed despite fitting budget")
            }
        },
    );
}

#[test]
fn compression_is_monotone_in_budget() {
    // A larger budget never yields fewer kept tokens.
    let mut rng = Rng::new(3);
    let doc = corpus::generate_document(
        &CorpusConfig {
            target_tokens: 2_000,
            ..Default::default()
        },
        &mut rng,
    );
    let total = count_tokens(&doc);
    let mut last = 0u32;
    for frac in [0.4, 0.6, 0.8, 1.0] {
        let c = compress(&doc, (total as f64 * frac) as u32);
        assert!(c.ok);
        assert!(
            c.compressed_tokens >= last,
            "kept tokens shrank at frac {frac}"
        );
        last = c.compressed_tokens;
    }
}

#[test]
fn fidelity_bounds_hold_on_borderline_band() {
    // ROUGE-L recall of an extractive summary ~ kept-fraction of words;
    // TF-IDF cosine stays high (the paper's 0.981).
    let w = traces::agent_heavy();
    let mut rng = Rng::new(4);
    for _ in 0..3 {
        let doc = corpus::generate_borderline_for(&w, &mut rng);
        let c = compress(&doc, w.b_short - 512);
        assert!(c.ok);
        let f = fidelity::measure(&doc, &c.text);
        assert!(f.rouge_l_recall > 0.5, "rouge {}", f.rouge_l_recall);
        assert!(f.tfidf_cosine > 0.9, "cosine {}", f.tfidf_cosine);
        assert!(
            (f.rouge_l_recall - (1.0 - f.token_reduction)).abs() < 0.15,
            "extractive identity: recall {} vs 1-reduction {}",
            f.rouge_l_recall,
            1.0 - f.token_reduction
        );
    }
}

#[test]
fn gate_code_never_compressed_end_to_end() {
    // Generated code documents at borderline lengths must flow through the
    // gateway uncompressed regardless of budget pressure.
    let mut g = Gateway::new(GatewayConfig::two_tier(2048, 1.5, true));
    let mut rng = Rng::new(5);
    for _ in 0..5 {
        let code = corpus::generate_code(2_600, &mut rng);
        let routed = g.route(&code, 128);
        assert!(!routed.compressed, "code must never be compressed");
        assert_eq!(routed.text.len(), code.len());
    }
    assert_eq!(g.n_compressed, 0);
}

#[test]
fn gate_decision_partition_is_total() {
    // Every (L_total, category) lands in exactly one decision; boundaries
    // are handled consistently (property over the whole input space).
    forall(
        "gate-partition",
        200,
        |rng| {
            let b = 1024u32;
            let l = rng.range(1, 4096) as u32;
            let cat = *rng.choice(&[
                Category::Conversational,
                Category::Rag,
                Category::Code,
                Category::ToolUse,
            ]);
            (b, l, cat)
        },
        |&(b, l, cat)| {
            let d = gate(l, b, 1.5, cat);
            let expected = if l <= b {
                GateDecision::RouteShort
            } else if l <= (1.5 * b as f64).floor() as u32 {
                if cat.compressible() {
                    GateDecision::CompressAndRoute
                } else {
                    GateDecision::BandButUnsafe
                }
            } else {
                GateDecision::RouteLong
            };
            ensure(d == expected, format!("{d:?} != {expected:?} at l={l}"))
        },
    );
}

#[test]
fn budget_identity_never_overflows() {
    forall(
        "eq15-identity",
        300,
        |rng| {
            let b = rng.range(64, 65_536) as u32;
            let out = rng.range(1, 70_000) as u32;
            (b, out)
        },
        |&(b, out)| match compression_budget(b, out) {
            Some(tc) => ensure(tc + out == b, "Tc + L_out != B"),
            None => ensure(out >= b, "None only when L_out >= B"),
        },
    );
}

#[test]
fn realized_alpha_prime_matches_eq14() {
    // Drive the gateway with a synthetic banded mix and check the realized
    // short fraction equals alpha + beta * p_c within sampling noise.
    let b_short = 1024u32;
    let mut g = Gateway::new(GatewayConfig::two_tier(b_short, 1.5, true));
    let mut rng = Rng::new(6);
    let n = 150usize;
    let (mut alpha_n, mut beta_n) = (0usize, 0usize);
    for i in 0..n {
        // ~60% short, ~25% borderline prose, ~15% long.
        let target = match i % 20 {
            0..=11 => rng.range(100, 700) as u32,
            12..=16 => rng.range(1200, 1450) as u32,
            _ => rng.range(2200, 3000) as u32,
        };
        let doc = corpus::generate_document(
            &CorpusConfig {
                target_tokens: target,
                ..Default::default()
            },
            &mut rng,
        );
        let routed = g.route(&doc, 64);
        let est = routed.estimated_l_total;
        if est <= b_short {
            alpha_n += 1;
        } else if est <= (1.5 * b_short as f64) as u32 && routed.category.compressible() {
            beta_n += 1;
        }
    }
    let expect = (alpha_n + beta_n) as f64 / n as f64; // p_c = 1 for prose
    let got = g.alpha_prime();
    assert!(
        (got - expect).abs() < 0.05,
        "alpha' {got} vs alpha+beta*pc {expect}"
    );
}

#[test]
fn classifier_is_deterministic_and_total() {
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let doc = corpus::generate_document(&Default::default(), &mut rng);
        assert_eq!(classify(&doc), classify(&doc));
    }
    // Pathological inputs must not panic.
    for s in ["", " ", "{", "\u{1F600}\u{1F600}", "a", "\n\n\n"] {
        let _ = classify(s);
    }
}

#[test]
fn compressing_pathological_inputs_is_safe() {
    // Failure injection: no sentences, one giant sentence, unicode soup.
    for text in [
        "",
        "word",
        &"x".repeat(10_000),
        &"лорем ипсум долор сит амет ".repeat(400),
        &"one two three ".repeat(2_000), // no terminators at all
    ] {
        let c = compress(text, 100);
        if c.ok {
            assert!(count_tokens(&c.text) <= 100);
        }
    }
}
