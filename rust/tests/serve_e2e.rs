//! End-to-end integration: the full three-layer stack — gateway routing
//! with C&R, two-pool replicas, PJRT-executed prefill/decode — on a small
//! live workload. Skips when artifacts are absent.

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::coordinator::{serve, ServeConfig, ServeItem};
use fleetopt::router::GatewayConfig;
use fleetopt::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

/// Live-scale boundary: the short pool's window is 256 tokens; leave room
/// for outputs.
const B_SHORT: u32 = 224;

fn workload(n: usize, seed: u64) -> Vec<ServeItem> {
    let mut rng = Rng::new(seed);
    let mut items = Vec::new();
    let mut t = 0.0;
    for i in 0..n {
        t += rng.exp(40.0); // 40 req/s offered
        // Mix: 70% short prose, 20% borderline (compressible), 10% long.
        let target = match i % 10 {
            0..=6 => rng.range(40, 150) as u32,
            7 | 8 => rng.range(240, 320) as u32, // borderline band (gamma 1.5)
            _ => rng.range(400, 700) as u32,
        };
        let text = corpus::generate_document(
            &CorpusConfig {
                target_tokens: target,
                ..Default::default()
            },
            &mut rng,
        );
        items.push(ServeItem {
            text,
            max_output: 12,
            arrival_offset_s: t,
        });
    }
    items
}

#[test]
fn two_pool_fleet_serves_mixed_workload() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServeConfig {
        gateway: GatewayConfig {
            b_short: B_SHORT,
            gamma: 1.5,
            enable_cr: true,
        },
        replicas_short: 1,
        replicas_long: 1,
    };
    let items = workload(40, 1);
    let n = items.len() as u64;
    let mut report = serve(&dir, &cfg, items, 0.05).expect("serve");

    // Everything completes, across both pools.
    assert_eq!(report.short.completed + report.long.completed, n);
    assert!(report.short.completed > 0, "short pool must see traffic");
    assert!(report.long.completed > 0, "long pool must see traffic");
    // C&R fired on borderline prose.
    assert!(report.n_compressed > 0, "expected compressions");
    // Every request produced tokens and a sane latency breakdown.
    assert!(report.short.output_tokens > 0);
    assert!(report.short.ttft.p50() > 0.0);
    assert!(report.throughput_rps > 0.0);
    println!(
        "e2e: {} | {} | compressed={} gw={:.2}ms",
        report.short.summary(),
        report.long.summary(),
        report.n_compressed,
        report.mean_gateway_s * 1e3,
    );
}

#[test]
fn cr_keeps_borderline_out_of_long_pool() {
    let Some(dir) = artifacts() else { return };
    let items = workload(30, 2);
    let n_long_without = {
        let cfg = ServeConfig {
            gateway: GatewayConfig {
                b_short: B_SHORT,
                gamma: 1.5,
                enable_cr: false,
            },
            replicas_short: 1,
            replicas_long: 1,
        };
        serve(&dir, &cfg, items.clone(), 0.02).unwrap().n_routed_long
    };
    let n_long_with = {
        let cfg = ServeConfig {
            gateway: GatewayConfig {
                b_short: B_SHORT,
                gamma: 1.5,
                enable_cr: true,
            },
            replicas_short: 1,
            replicas_long: 1,
        };
        serve(&dir, &cfg, items, 0.02).unwrap().n_routed_long
    };
    assert!(
        n_long_with < n_long_without,
        "C&R must shrink long-pool traffic: {n_long_with} vs {n_long_without}"
    );
}

#[test]
fn generation_is_deterministic_across_runs() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServeConfig {
        gateway: GatewayConfig {
            b_short: B_SHORT,
            gamma: 1.5,
            enable_cr: true,
        },
        replicas_short: 1,
        replicas_long: 1,
    };
    // Single request: output tokens must be identical run-to-run (greedy
    // decoding over a deterministic engine).
    let item = workload(1, 3);
    let r1 = serve(&dir, &cfg, item.clone(), 0.0).unwrap();
    let r2 = serve(&dir, &cfg, item, 0.0).unwrap();
    assert_eq!(
        r1.short.output_tokens + r1.long.output_tokens,
        r2.short.output_tokens + r2.long.output_tokens
    );
}
