//! End-to-end integration: the full three-layer stack — gateway routing
//! with C&R, two-pool replicas, PJRT-executed prefill/decode — on a small
//! live workload. Skips when artifacts are absent.

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::coordinator::{serve, ServeConfig, ServeItem};
use fleetopt::router::GatewayConfig;
use fleetopt::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

/// Live-scale boundary: the short pool's window is 256 tokens; leave room
/// for outputs.
const B_SHORT: u32 = 224;

fn workload(n: usize, seed: u64) -> Vec<ServeItem> {
    let mut rng = Rng::new(seed);
    let mut items = Vec::new();
    let mut t = 0.0;
    for i in 0..n {
        t += rng.exp(40.0); // 40 req/s offered
        // Mix: 70% short prose, 20% borderline (compressible), 10% long.
        let target = match i % 10 {
            0..=6 => rng.range(40, 150) as u32,
            7 | 8 => rng.range(240, 320) as u32, // borderline band (gamma 1.5)
            _ => rng.range(400, 700) as u32,
        };
        let text = corpus::generate_document(
            &CorpusConfig {
                target_tokens: target,
                ..Default::default()
            },
            &mut rng,
        );
        items.push(ServeItem {
            text,
            max_output: 12,
            arrival_offset_s: t,
        });
    }
    items
}

#[test]
fn two_pool_fleet_serves_mixed_workload() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServeConfig::two_tier(GatewayConfig::two_tier(B_SHORT, 1.5, true), 1, 1);
    let items = workload(40, 1);
    let n = items.len() as u64;
    let mut report = serve(&dir, &cfg, items, 0.05).expect("serve");

    // Everything completes, across both pools.
    assert_eq!(report.tiers.len(), 2);
    assert_eq!(report.completed(), n);
    assert!(report.tiers[0].completed > 0, "short pool must see traffic");
    assert!(report.tiers[1].completed > 0, "long pool must see traffic");
    // C&R fired on borderline prose.
    assert!(report.n_compressed > 0, "expected compressions");
    // Every request produced tokens and a sane latency breakdown.
    assert!(report.tiers[0].output_tokens > 0);
    assert!(report.tiers[0].ttft.p50() > 0.0);
    assert!(report.throughput_rps > 0.0);
    let (short_summary, long_summary) = {
        let [s, l] = &mut report.tiers[..] else { unreachable!() };
        (s.summary(), l.summary())
    };
    println!(
        "e2e: {} | {} | compressed={} gw={:.2}ms",
        short_summary,
        long_summary,
        report.n_compressed,
        report.mean_gateway_s * 1e3,
    );
}

#[test]
fn cr_keeps_borderline_out_of_long_pool() {
    let Some(dir) = artifacts() else { return };
    let items = workload(30, 2);
    let n_long_without = {
        let cfg = ServeConfig::two_tier(GatewayConfig::two_tier(B_SHORT, 1.5, false), 1, 1);
        serve(&dir, &cfg, items.clone(), 0.02).unwrap().n_routed_long()
    };
    let n_long_with = {
        let cfg = ServeConfig::two_tier(GatewayConfig::two_tier(B_SHORT, 1.5, true), 1, 1);
        serve(&dir, &cfg, items, 0.02).unwrap().n_routed_long()
    };
    assert!(
        n_long_with < n_long_without,
        "C&R must shrink long-pool traffic: {n_long_with} vs {n_long_without}"
    );
}

#[test]
fn three_tier_fleet_serves_and_conserves() {
    let Some(dir) = artifacts() else { return };
    // A dense 128-token tier below the usual short pool: short prose lands
    // in tier 0, mid-size in tier 1, the tail in tier 2.
    let cfg = ServeConfig {
        gateway: GatewayConfig::tiered(&[128, B_SHORT], 1.5, true),
        replicas: vec![1, 1, 1],
    };
    let items = workload(30, 4);
    let n = items.len() as u64;
    let report = serve(&dir, &cfg, items, 0.02).expect("serve");
    assert_eq!(report.tiers.len(), 3);
    assert_eq!(report.completed(), n);
    assert_eq!(report.n_routed.iter().sum::<u64>(), n);
    assert!(report.n_routed_short() > 0, "dense tier must see traffic");
}

#[test]
fn replica_count_mismatch_is_an_error() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServeConfig {
        gateway: GatewayConfig::two_tier(B_SHORT, 1.5, true),
        replicas: vec![1, 1, 1], // three replica sets for two tiers
    };
    assert!(serve(&dir, &cfg, workload(2, 5), 0.0).is_err());
}

#[test]
fn generation_is_deterministic_across_runs() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServeConfig::two_tier(GatewayConfig::two_tier(B_SHORT, 1.5, true), 1, 1);
    // Single request: output tokens must be identical run-to-run (greedy
    // decoding over a deterministic engine).
    let item = workload(1, 3);
    let r1 = serve(&dir, &cfg, item.clone(), 0.0).unwrap();
    let r2 = serve(&dir, &cfg, item, 0.0).unwrap();
    let out = |r: &fleetopt::coordinator::ServeReport| -> u64 {
        r.tiers.iter().map(|t| t.output_tokens).sum()
    };
    assert_eq!(out(&r1), out(&r2));
}
