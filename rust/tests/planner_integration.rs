//! Integration: the planner reproduces the paper's qualitative results
//! (Table 3 orderings, Theorem 2, gamma* pattern, Table 6 stability) and
//! its invariants hold across workloads and arrival rates.

use fleetopt::config::PlannerConfig;
use fleetopt::planner::{
    candidate_boundaries, plan_fleet, plan_homogeneous, sweep_full, sweep_gamma, PlanInput,
};
use fleetopt::workload::traces;

fn fast_input(w: fleetopt::workload::traces::Workload, lambda: f64) -> PlanInput {
    let mut i = PlanInput::new(w, lambda);
    i.cfg = PlannerConfig {
        mc_samples: 6_000,
        ..PlannerConfig::default()
    };
    i
}

#[test]
fn table3_method_ordering_all_workloads() {
    // Paper Table 3: homogeneous >= PR >= PR+C&R >= FleetOpt, strictly for
    // the first step on every workload.
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let homo = plan_homogeneous(&input).unwrap();
        let pr = plan_fleet(&input, w.b_short, 1.0).unwrap();
        let cr = plan_fleet(&input, w.b_short, 1.5).unwrap();
        let opt = sweep_gamma(&input, w.b_short).unwrap();
        assert!(pr.cost_yr < homo.cost_yr, "{}: PR must beat homogeneous", w.name);
        assert!(cr.cost_yr <= pr.cost_yr, "{}: C&R must not lose to PR", w.name);
        assert!(opt.cost_yr <= cr.cost_yr, "{}: co-design <= retrofit (Thm 2)", w.name);
    }
}

#[test]
fn savings_ordering_across_workloads_matches_paper() {
    // Paper: Azure saves most, Agent-heavy least (Table 3's spread).
    let mut savings = std::collections::HashMap::new();
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let homo = plan_homogeneous(&input).unwrap();
        let opt = sweep_gamma(&input, w.b_short).unwrap();
        savings.insert(w.name, 1.0 - opt.cost_yr / homo.cost_yr);
    }
    assert!(savings["azure"] > savings["agent-heavy"]);
    assert!(savings["lmsys"] > savings["agent-heavy"]);
    // All in a plausible band (paper: 6.7% - 82.4%).
    for (name, s) in &savings {
        assert!((0.05..0.9).contains(s), "{name}: savings {s}");
    }
}

#[test]
fn cr_increment_largest_for_azure() {
    // Paper: C&R adds most where beta * rho is largest (Azure: 16x cliff,
    // beta 7.8%) and least for Agent-heavy (8x, p_c 0.75).
    let mut incr = std::collections::HashMap::new();
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let homo = plan_homogeneous(&input).unwrap().cost_yr;
        let pr = plan_fleet(&input, w.b_short, 1.0).unwrap().cost_yr;
        let cr = plan_fleet(&input, w.b_short, 1.5).unwrap().cost_yr;
        incr.insert(w.name, (pr - cr) / homo);
    }
    assert!(
        incr["azure"] > incr["agent-heavy"],
        "azure {} vs agent {}",
        incr["azure"],
        incr["agent-heavy"]
    );
}

#[test]
fn gamma_star_is_two_for_archetype_one() {
    // Paper §6: Archetype I/II workloads (Azure, LMSYS) push gamma* to 2.0.
    for w in [traces::azure(), traces::lmsys()] {
        let input = fast_input(w.clone(), 1000.0);
        let opt = sweep_gamma(&input, w.b_short).unwrap();
        assert!(opt.gamma >= 1.9, "{}: gamma* = {}", w.name, opt.gamma);
    }
}

#[test]
fn table6_savings_stable_across_lambda() {
    // Paper Table 6: savings vary by < ~2pp across a 20x arrival range.
    let w = traces::agent_heavy();
    let mut savings = Vec::new();
    for lambda in [100.0, 500.0, 2000.0] {
        let input = fast_input(w.clone(), lambda);
        let homo = plan_homogeneous(&input).unwrap();
        let pr = plan_fleet(&input, w.b_short, 1.0).unwrap();
        savings.push(1.0 - pr.cost_yr / homo.cost_yr);
    }
    let spread = savings
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max)
        - savings.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread < 0.03, "savings spread {spread} too wide: {savings:?}");
}

#[test]
fn fleet_scales_linearly_with_lambda() {
    let w = traces::azure();
    let n_at = |lambda: f64| {
        let input = fast_input(w.clone(), lambda);
        plan_homogeneous(&input).unwrap().total_gpus() as f64
    };
    let (n1, n10) = (n_at(200.0), n_at(2000.0));
    assert!((n10 / n1 - 10.0).abs() < 0.5, "ratio {}", n10 / n1);
}

#[test]
fn full_sweep_optimum_beats_every_grid_cell() {
    let input = fast_input(traces::lmsys(), 1000.0);
    let (best, grid) = sweep_full(&input).unwrap();
    for (b, g, cost) in &grid {
        assert!(
            best.cost_yr <= *cost + 1e-6,
            "optimum {} beaten by B={b} gamma={g}: {cost}",
            best.cost_yr
        );
    }
}

#[test]
fn boundaries_are_workload_feasible() {
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let cands = candidate_boundaries(&input);
        assert!(
            (3..=15).contains(&cands.len()),
            "{}: paper says 5-15 candidates, got {}",
            w.name,
            cands.len()
        );
        assert!(cands.contains(&w.b_short), "{}: evaluation B missing", w.name);
    }
}

#[test]
fn pools_never_exceed_rho_max() {
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        for gamma in [1.0, 1.5, 2.0] {
            let p = plan_fleet(&input, w.b_short, gamma).unwrap();
            for (name, pool) in [("short", &p.short), ("long", &p.long)] {
                if pool.n_gpus > 0 {
                    let rho = pool.rho_ana();
                    assert!(
                        rho <= 0.85 + 1e-9,
                        "{} {} pool at gamma {gamma}: rho {rho}",
                        w.name,
                        name
                    );
                }
            }
        }
    }
}

#[test]
fn more_compression_never_grows_the_long_pool() {
    // Monotonicity: raising gamma moves traffic out of the long pool, so
    // lambda_l (and with recalibration, n_l's traffic share) shrinks.
    let w = traces::azure();
    let input = fast_input(w.clone(), 1000.0);
    let mut last_lambda_l = f64::INFINITY;
    for gamma in [1.0, 1.2, 1.5, 1.8, 2.0] {
        let p = plan_fleet(&input, w.b_short, gamma).unwrap();
        assert!(
            p.long.lambda <= last_lambda_l + 1e-9,
            "lambda_l grew at gamma {gamma}"
        );
        last_lambda_l = p.long.lambda;
    }
}

#[test]
fn higher_slo_never_cheaper() {
    let w = traces::azure();
    let mut tight = fast_input(w.clone(), 1000.0);
    tight.slo.p99_ttft_s = 0.2;
    let mut loose = fast_input(w, 1000.0);
    loose.slo.p99_ttft_s = 5.0;
    let pt = plan_fleet(&tight, 4096, 1.0).unwrap();
    let pl = plan_fleet(&loose, 4096, 1.0).unwrap();
    assert!(pt.cost_yr >= pl.cost_yr);
}
