//! §Perf equivalence + wall-clock guarantees:
//!
//! * inverted-index TextRank matches the naive all-pairs oracle to 1e-9
//!   (in fact bit-exactly) on randomized documents;
//! * selection output is byte-identical across similarity backends and
//!   across scratch-reuse vs one-shot compression;
//! * the parallel planner sweeps are bit-identical to the serial sweeps;
//! * a full planner sweep completes within a generous wall-clock bound in
//!   release mode (regression smoke for the "<1 ms planner" claim, §6).

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::compress::doc::Document;
use fleetopt::compress::extractive::{compress, compress_doc_with_mode};
use fleetopt::compress::scratch::CompressScratch;
use fleetopt::compress::textrank::{textrank_naive, textrank_with_mode, SimilarityMode};
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::planner::{sweep_full, sweep_full_serial, sweep_gamma, sweep_gamma_serial, PlanInput};
use fleetopt::util::check::{ensure, forall};
use fleetopt::util::rng::Rng;
use fleetopt::workload::traces;

#[test]
fn textrank_inverted_index_matches_naive_property() {
    forall(
        "textrank-inverted-vs-naive",
        20,
        |rng| {
            let target = rng.range(200, 3_000) as u32;
            let redundancy = rng.uniform(0.0, 0.4);
            let paragraph_prob = rng.uniform(0.0, 0.3);
            (target, redundancy, paragraph_prob, rng.next_u64())
        },
        |&(target, redundancy, paragraph_prob, seed)| {
            let mut rng = Rng::new(seed);
            let text = corpus::generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    redundancy,
                    paragraph_prob,
                },
                &mut rng,
            );
            let doc = Document::parse(&text);
            let fast = textrank_with_mode(&doc, SimilarityMode::InvertedIndex);
            let naive = textrank_naive(&doc);
            ensure(fast.len() == naive.len(), "length mismatch")?;
            for (i, (a, b)) in fast.iter().zip(&naive).enumerate() {
                ensure(
                    (a - b).abs() <= 1e-9,
                    format!("score {i}: inverted {a} vs naive {b}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn selection_byte_identical_across_similarity_backends() {
    let mut rng = Rng::new(0x5E1);
    for k in 0..6 {
        let text = corpus::generate_document(
            &CorpusConfig {
                target_tokens: 400 + 500 * k,
                ..Default::default()
            },
            &mut rng,
        );
        let doc = Document::parse(&text);
        for frac in [0.4, 0.7, 0.95] {
            let budget = (count_tokens(&text) as f64 * frac) as u32;
            let a = compress_doc_with_mode(&doc, budget, SimilarityMode::AllPairs);
            let b = compress_doc_with_mode(&doc, budget, SimilarityMode::InvertedIndex);
            assert_eq!(a.text, b.text, "doc {k} frac {frac}");
            assert_eq!(a.selected, b.selected, "doc {k} frac {frac}");
            assert_eq!(a.compressed_tokens, b.compressed_tokens);
            assert_eq!(a.ok, b.ok);
        }
    }
}

#[test]
fn scratch_compress_matches_one_shot_over_randomized_documents() {
    let mut scratch = CompressScratch::new();
    forall(
        "scratch-vs-one-shot",
        12,
        |rng| {
            let target = rng.range(150, 2_500) as u32;
            let frac = rng.uniform(0.3, 1.1);
            (target, frac, rng.next_u64())
        },
        |&(target, frac, seed)| {
            let mut rng = Rng::new(seed);
            let text = corpus::generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    ..Default::default()
                },
                &mut rng,
            );
            let budget = (count_tokens(&text) as f64 * frac) as u32;
            let fresh = compress(&text, budget);
            let reused = scratch.compress(&text, budget);
            ensure(fresh.text == reused.text, "text differs")?;
            ensure(fresh.selected == reused.selected, "selection differs")?;
            ensure(fresh.ok == reused.ok, "ok differs")?;
            ensure(
                fresh.compressed_tokens == reused.compressed_tokens,
                "token counts differ",
            )
        },
    );
}

#[test]
fn parallel_sweeps_bit_identical_to_serial() {
    for w in traces::all() {
        let mut input = PlanInput::new(w.clone(), 1000.0);
        input.cfg.mc_samples = 8_000; // CI-fast calibration grid
        let (best_p, grid_p) = sweep_full(&input).unwrap();
        let (best_s, grid_s) = sweep_full_serial(&input).unwrap();
        assert_eq!(grid_p, grid_s, "{}: cost grid must match bit-for-bit", w.name);
        assert_eq!(best_p.cost_yr, best_s.cost_yr, "{}", w.name);
        assert_eq!(best_p.b_short, best_s.b_short);
        assert_eq!(best_p.gamma, best_s.gamma);
        assert_eq!(best_p.short.n_gpus, best_s.short.n_gpus);
        assert_eq!(best_p.long.n_gpus, best_s.long.n_gpus);

        let gp = sweep_gamma(&input, w.b_short).unwrap();
        let gs = sweep_gamma_serial(&input, w.b_short).unwrap();
        assert_eq!(gp.cost_yr, gs.cost_yr, "{}", w.name);
        assert_eq!(gp.gamma, gs.gamma, "{}", w.name);
    }
}

#[test]
fn full_planner_sweep_completes_within_wall_clock_bound() {
    // Release-mode smoke: the paper's planner is "<1 ms"; we assert a very
    // generous 30 s so only catastrophic regressions (e.g. losing the
    // calibration cache or quadrature path) trip it. Debug builds run the
    // sweep for coverage but skip the timing assertion.
    let mut input = PlanInput::new(traces::azure(), 1000.0);
    input.cfg.mc_samples = 8_000;
    let t0 = std::time::Instant::now();
    let (best, grid) = sweep_full(&input).unwrap();
    let elapsed = t0.elapsed();
    assert!(best.total_gpus() > 0);
    assert!(grid.len() >= 11);
    if !cfg!(debug_assertions) {
        assert!(
            elapsed.as_secs_f64() < 30.0,
            "full sweep took {:.1} s (>30 s wall-clock bound)",
            elapsed.as_secs_f64()
        );
    }
}
