//! §Perf equivalence + wall-clock guarantees:
//!
//! * inverted-index TextRank matches the naive all-pairs oracle to 1e-9
//!   (in fact bit-exactly) on randomized documents;
//! * selection output is byte-identical across similarity backends and
//!   across scratch-reuse vs one-shot compression;
//! * the parallel planner sweeps are bit-identical to the serial sweeps;
//! * a full planner sweep completes within a generous wall-clock bound in
//!   release mode (regression smoke for the "<1 ms planner" claim, §6).

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::compress::doc::{Document, ParseScratch};
use fleetopt::compress::extractive::{compress, compress_doc_with_mode};
use fleetopt::compress::scratch::CompressScratch;
use fleetopt::compress::textrank::{textrank_naive, textrank_with_mode, SimilarityMode};
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::planner::{sweep_full, sweep_full_serial, sweep_gamma, sweep_gamma_serial, PlanInput};
use fleetopt::util::check::{ensure, forall};
use fleetopt::util::rng::Rng;
use fleetopt::workload::traces;

#[test]
fn textrank_inverted_index_matches_naive_property() {
    forall(
        "textrank-inverted-vs-naive",
        20,
        |rng| {
            let target = rng.range(200, 3_000) as u32;
            let redundancy = rng.uniform(0.0, 0.4);
            let paragraph_prob = rng.uniform(0.0, 0.3);
            (target, redundancy, paragraph_prob, rng.next_u64())
        },
        |&(target, redundancy, paragraph_prob, seed)| {
            let mut rng = Rng::new(seed);
            let text = corpus::generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    redundancy,
                    paragraph_prob,
                },
                &mut rng,
            );
            let doc = Document::parse(&text);
            let fast = textrank_with_mode(&doc, SimilarityMode::InvertedIndex);
            let naive = textrank_naive(&doc);
            ensure(fast.len() == naive.len(), "length mismatch")?;
            for (i, (a, b)) in fast.iter().zip(&naive).enumerate() {
                ensure(
                    (a - b).abs() <= 1e-9,
                    format!("score {i}: inverted {a} vs naive {b}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn selection_byte_identical_across_similarity_backends() {
    let mut rng = Rng::new(0x5E1);
    for k in 0..6 {
        let text = corpus::generate_document(
            &CorpusConfig {
                target_tokens: 400 + 500 * k,
                ..Default::default()
            },
            &mut rng,
        );
        let doc = Document::parse(&text);
        for frac in [0.4, 0.7, 0.95] {
            let budget = (count_tokens(&text) as f64 * frac) as u32;
            let a = compress_doc_with_mode(&doc, budget, SimilarityMode::AllPairs);
            let b = compress_doc_with_mode(&doc, budget, SimilarityMode::InvertedIndex);
            assert_eq!(a.text, b.text, "doc {k} frac {frac}");
            assert_eq!(a.selected, b.selected, "doc {k} frac {frac}");
            assert_eq!(a.compressed_tokens, b.compressed_tokens);
            assert_eq!(a.ok, b.ok);
        }
    }
}

#[test]
fn scratch_compress_matches_one_shot_over_randomized_documents() {
    let mut scratch = CompressScratch::new();
    forall(
        "scratch-vs-one-shot",
        12,
        |rng| {
            let target = rng.range(150, 2_500) as u32;
            let frac = rng.uniform(0.3, 1.1);
            (target, frac, rng.next_u64())
        },
        |&(target, frac, seed)| {
            let mut rng = Rng::new(seed);
            let text = corpus::generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    ..Default::default()
                },
                &mut rng,
            );
            let budget = (count_tokens(&text) as f64 * frac) as u32;
            let fresh = compress(&text, budget);
            let reused = scratch.compress(&text, budget);
            ensure(fresh.text == reused.text, "text differs")?;
            ensure(fresh.selected == reused.selected, "selection differs")?;
            ensure(fresh.ok == reused.ok, "ok differs")?;
            ensure(
                fresh.compressed_tokens == reused.compressed_tokens,
                "token counts differ",
            )
        },
    );
}

#[test]
fn parallel_sweeps_bit_identical_to_serial() {
    for w in traces::all() {
        let mut input = PlanInput::new(w.clone(), 1000.0);
        input.cfg.mc_samples = 8_000; // CI-fast calibration grid
        let (best_p, grid_p) = sweep_full(&input).unwrap();
        let (best_s, grid_s) = sweep_full_serial(&input).unwrap();
        assert_eq!(grid_p, grid_s, "{}: cost grid must match bit-for-bit", w.name);
        assert_eq!(best_p.cost_yr, best_s.cost_yr, "{}", w.name);
        assert_eq!(best_p.b_short, best_s.b_short);
        assert_eq!(best_p.gamma, best_s.gamma);
        assert_eq!(best_p.short.n_gpus, best_s.short.n_gpus);
        assert_eq!(best_p.long.n_gpus, best_s.long.n_gpus);

        let gp = sweep_gamma(&input, w.b_short).unwrap();
        let gs = sweep_gamma_serial(&input, w.b_short).unwrap();
        assert_eq!(gp.cost_yr, gs.cost_yr, "{}", w.name);
        assert_eq!(gp.gamma, gs.gamma, "{}", w.name);
    }
}

// ---------------------------------------------------------------------------
// Edge-input pinning (§Perf, PR 6 satellite): the SIMD kernels use the
// scalar path as their oracle, so the scalar interner/tokenizer/selection
// behavior is pinned here on degenerate and non-ASCII inputs *before* any
// dispatch comparison runs (`tests/simd_dispatch.rs`).
// ---------------------------------------------------------------------------

fn edge_texts() -> Vec<&'static str> {
    vec![
        "",
        " ",
        "\n\n\t ",
        "word",
        "Only one sentence here.",
        "the the the the. the the the. the of and to the.",
        "Zwölf Boxkämpfer jagen Viktor quer über den großen Sylter Deich.",
        "すもももももももものうち。隣の客はよく柿食う客だ。",
        "Οι ταχείες καφετιές αλεπούδες πηδούν. Πάνω από τον τεμπέλη σκύλο.",
        "🚀🚀🚀 emoji only 🚀🚀🚀",
        "Ünïçödé wörds mïxed with plain words. Plain words repeat plain words.",
    ]
}

#[test]
fn reparse_matches_parse_on_edge_inputs() {
    // One long-lived Document + ParseScratch reparsed across wildly
    // different inputs must leave no stale state behind: every public
    // field equals a fresh parse, field by field.
    let mut doc = Document::default();
    let mut scratch = ParseScratch::default();
    for (i, text) in edge_texts().iter().enumerate() {
        let fresh = Document::parse(text);
        doc.reparse(text, &mut scratch);
        assert_eq!(fresh.sentences, doc.sentences, "text {i}: sentences");
        assert_eq!(fresh.word_seqs, doc.word_seqs, "text {i}: word_seqs");
        assert_eq!(fresh.word_sets, doc.word_sets, "text {i}: word_sets");
        assert_eq!(fresh.signatures, doc.signatures, "text {i}: signatures");
        assert_eq!(fresh.content_sets, doc.content_sets, "text {i}: content_sets");
        assert_eq!(fresh.token_counts, doc.token_counts, "text {i}: token_counts");
        assert_eq!(fresh.vocab, doc.vocab, "text {i}: vocab");
    }
}

#[test]
fn compression_is_stable_on_edge_inputs() {
    let mut scratch = CompressScratch::new();
    for (i, text) in edge_texts().iter().enumerate() {
        for budget in [1u32, 8, 10_000] {
            let fresh = compress(text, budget);
            let reused = scratch.compress(text, budget);
            assert_eq!(fresh.text, reused.text, "text {i} budget {budget}");
            assert_eq!(fresh.selected, reused.selected, "text {i} budget {budget}");
            assert_eq!(fresh.ok, reused.ok, "text {i} budget {budget}");
            assert_eq!(fresh.compressed_tokens, reused.compressed_tokens, "text {i}");
        }
    }
}

#[test]
fn similarity_backends_agree_on_edge_inputs() {
    for (i, text) in edge_texts().iter().enumerate() {
        let doc = Document::parse(text);
        let budget = count_tokens(text).max(1);
        let a = compress_doc_with_mode(&doc, budget, SimilarityMode::AllPairs);
        let b = compress_doc_with_mode(&doc, budget, SimilarityMode::InvertedIndex);
        assert_eq!(a.text, b.text, "text {i}");
        assert_eq!(a.selected, b.selected, "text {i}");
        assert_eq!(a.ok, b.ok, "text {i}");
    }
}

#[test]
fn randomized_unicode_documents_compress_identically() {
    let words = ["alpha", "Zwölf", "柿食う", "Ünïçödé", "σκύλο", "🚀", "plain", "words"];
    let mut scratch = CompressScratch::new();
    forall(
        "unicode-scratch-vs-one-shot",
        20,
        |rng| {
            let n_sent = rng.range(0, 7);
            let mut text = String::new();
            for _ in 0..n_sent {
                let n_words = rng.range(1, 9);
                for k in 0..n_words {
                    if k > 0 {
                        text.push(' ');
                    }
                    text.push_str(rng.choice(&words));
                }
                text.push_str(". ");
            }
            (text, rng.range(1, 64) as u32)
        },
        |(text, budget)| {
            let fresh = compress(text, *budget);
            let reused = scratch.compress(text, *budget);
            ensure(fresh.text == reused.text, "scratch text differs")?;
            ensure(fresh.selected == reused.selected, "scratch selection differs")?;
            let doc = Document::parse(text);
            let ap = compress_doc_with_mode(&doc, *budget, SimilarityMode::AllPairs);
            let ii = compress_doc_with_mode(&doc, *budget, SimilarityMode::InvertedIndex);
            ensure(ap.text == ii.text, "backend text differs")?;
            ensure(ap.selected == ii.selected, "backend selection differs")
        },
    );
}

#[test]
fn full_planner_sweep_completes_within_wall_clock_bound() {
    // Release-mode smoke: the paper's planner is "<1 ms"; we assert a very
    // generous 30 s so only catastrophic regressions (e.g. losing the
    // calibration cache or quadrature path) trip it. Debug builds run the
    // sweep for coverage but skip the timing assertion.
    let mut input = PlanInput::new(traces::azure(), 1000.0);
    input.cfg.mc_samples = 8_000;
    let t0 = std::time::Instant::now();
    let (best, grid) = sweep_full(&input).unwrap();
    let elapsed = t0.elapsed();
    assert!(best.total_gpus() > 0);
    assert!(grid.len() >= 11);
    if !cfg!(debug_assertions) {
        assert!(
            elapsed.as_secs_f64() < 30.0,
            "full sweep took {:.1} s (>30 s wall-clock bound)",
            elapsed.as_secs_f64()
        );
    }
}
