//! The heterogeneous-SKU anytime planner's regression gates.
//!
//! * Single-SKU spaces: `anytime_search` with no catalog must return the
//!   `sweep_tiered_pruned` argmin bit-identically (boundaries, gammas,
//!   per-tier GPU counts, cost) on all three traces at K = 2, 3 — the
//!   acceptance pin this PR's dispatch rests on. K = 4 in release.
//! * Small mixed spaces: within `exhaustive_cells` and deadline-free the
//!   search must equal the exhaustive `sweep_tiered_skus_pruned` oracle.
//! * Mixed never loses: with the demo catalog (which contains the base
//!   SKU) the incumbent's cost is at or below the single-SKU optimum.
//! * Determinism: the sampled path is a pure function of the seed and
//!   budgets — two runs agree bit for bit, including the evaluated-cell
//!   count (no wall-clock dependence when no deadline is set).
//! * Deadlines truncate rather than hang: an over-budgeted search under a
//!   tight deadline still returns a valid plan promptly.
//! * Catalog validation names the offending entry and index.

use fleetopt::config::{GpuSku, PlannerConfig, SkuCatalog};
use fleetopt::planner::{
    anytime_search, sweep_tiered_pruned, sweep_tiered_skus_pruned, AnytimeConfig, CalibCache,
    Deadline, PlanInput,
};
use fleetopt::workload::traces;

fn fast_input(w: traces::Workload, lambda: f64, mc: usize) -> PlanInput {
    let mut i = PlanInput::new(w, lambda);
    i.cfg = PlannerConfig {
        mc_samples: mc,
        ..PlannerConfig::default()
    };
    i
}

fn assert_plans_bit_identical(
    a: &fleetopt::planner::TieredPlan,
    b: &fleetopt::planner::TieredPlan,
    label: &str,
) {
    assert_eq!(a.cost_yr.to_bits(), b.cost_yr.to_bits(), "{label}");
    assert_eq!(a.boundaries(), b.boundaries(), "{label}");
    assert_eq!(a.gpu_counts(), b.gpu_counts(), "{label}");
    for (x, y) in a.gammas.iter().zip(&b.gammas) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}");
    }
    for (x, y) in a.spec.tiers.iter().zip(&b.spec.tiers) {
        assert_eq!(x.sku_index(), y.sku_index(), "{label}");
    }
}

/// The acceptance pin: on single-SKU spaces the anytime entry point IS
/// the pruned sweep, bit for bit, across traces and fleet sizes.
#[test]
fn anytime_returns_the_pruned_sweep_argmin_on_single_sku_spaces() {
    let heavy = !cfg!(debug_assertions);
    for w in traces::all() {
        for k in [2usize, 3, 4] {
            if k == 4 && !heavy && w.name != "azure" {
                continue;
            }
            let mc = if k == 4 { 1_000 } else { 2_000 };
            let input = fast_input(w.clone(), 1000.0, mc);
            let (oracle, _) = sweep_tiered_pruned(&input, k, &CalibCache::new()).unwrap();
            let res = anytime_search(
                &input,
                k,
                None,
                &CalibCache::new(),
                Deadline::none(),
                &AnytimeConfig::default(),
            )
            .unwrap();
            let label = format!("{} K={k}", w.name);
            assert!(res.exact, "{label}: single-SKU result must be exact");
            assert_eq!(res.bound_gap_pct.to_bits(), 0.0f64.to_bits(), "{label}");
            assert_plans_bit_identical(&res.plan, &oracle, &label);
        }
    }
}

/// Small mixed spaces (demo catalog at K = 2: 3^2 assignments over the
/// plain grid, well under the default `exhaustive_cells`) delegate to the
/// exhaustive SKU sweep and therefore equal its argmin exactly.
#[test]
fn anytime_is_exact_on_small_mixed_spaces() {
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0, 1_500);
        let catalog = SkuCatalog::demo(&input.gpu);
        let (oracle, _) =
            sweep_tiered_skus_pruned(&input, 2, &catalog, &CalibCache::new()).unwrap();
        let res = anytime_search(
            &input,
            2,
            Some(&catalog),
            &CalibCache::new(),
            Deadline::none(),
            &AnytimeConfig::default(),
        )
        .unwrap();
        let label = format!("{} K=2 mixed", w.name);
        assert!(res.exact, "{label}: small space must take the oracle path");
        assert_plans_bit_identical(&res.plan, &oracle, &label);
        // The demo catalog contains the base SKU, so mixed never loses to
        // the single-SKU optimum (Table 10's headline inequality).
        let (single, _) = sweep_tiered_pruned(&input, 2, &CalibCache::new()).unwrap();
        assert!(
            res.plan.cost_yr <= single.cost_yr + 1e-9,
            "{label}: mixed ${:.2} must not exceed single-SKU ${:.2}",
            res.plan.cost_yr,
            single.cost_yr
        );
    }
}

/// The sampled path (forced by `exhaustive_cells: 0`) is a pure function
/// of (seed, budgets): two deadline-free runs agree bit for bit, down to
/// the number of cells evaluated — no wall-clock leaks into the search.
#[test]
fn sampled_search_is_seed_deterministic() {
    let input = fast_input(traces::azure(), 1000.0, 1_500);
    let catalog = SkuCatalog::demo(&input.gpu);
    let cfg = AnytimeConfig {
        explore_cells: 24,
        compress_rounds: 3,
        exhaustive_cells: 0, // force the sampled path even on K = 2
        ..AnytimeConfig::default()
    };
    let run = || {
        anytime_search(
            &input,
            2,
            Some(&catalog),
            &CalibCache::new(),
            Deadline::none(),
            &cfg,
        )
        .unwrap()
    };
    let a = run();
    let b = run();
    assert!(!a.exact, "exhaustive_cells: 0 must force the sampled path");
    assert_eq!(a.cells_evaluated, b.cells_evaluated);
    assert_eq!(a.bound_gap_pct.to_bits(), b.bound_gap_pct.to_bits());
    assert_plans_bit_identical(&a.plan, &b.plan, "azure K=2 sampled");

    // A different seed may pick a different incumbent, but must still be
    // internally deterministic.
    let cfg2 = AnytimeConfig { seed: 7, ..cfg };
    let c = anytime_search(
        &input,
        2,
        Some(&catalog),
        &CalibCache::new(),
        Deadline::none(),
        &cfg2,
    )
    .unwrap();
    let d = anytime_search(
        &input,
        2,
        Some(&catalog),
        &CalibCache::new(),
        Deadline::none(),
        &cfg2,
    )
    .unwrap();
    assert_eq!(c.cells_evaluated, d.cells_evaluated);
    assert_plans_bit_identical(&c.plan, &d.plan, "azure K=2 sampled seed=7");
}

/// A tight deadline truncates the search instead of hanging: a grossly
/// over-budgeted exploration under a few-ms deadline still returns a
/// valid plan in bounded wall time.
#[test]
fn deadline_truncates_an_over_budgeted_search() {
    let input = fast_input(traces::azure(), 1000.0, 1_500);
    let catalog = SkuCatalog::demo(&input.gpu);
    let cfg = AnytimeConfig {
        explore_cells: usize::MAX / 8,
        compress_rounds: 64,
        exhaustive_cells: 0,
        ..AnytimeConfig::default()
    };
    let t0 = std::time::Instant::now();
    let res = anytime_search(
        &input,
        3,
        Some(&catalog),
        &CalibCache::new(),
        Deadline::after_ms(5),
        &cfg,
    )
    .unwrap();
    // Generous bound: the deadline only gates between evaluations, so one
    // in-flight batch may overrun it — but never by tens of seconds.
    assert!(
        t0.elapsed().as_secs_f64() < 30.0,
        "deadline-bounded search ran {:.1} s",
        t0.elapsed().as_secs_f64()
    );
    assert_eq!(res.plan.k(), 3);
    assert!(res.plan.total_gpus() > 0);
    assert!(res.plan.cost_yr.is_finite());
}

/// Catalog validation points at the offending entry by index and name.
#[test]
fn catalog_validation_names_entry_and_index() {
    let sku = |name: &str| GpuSku {
        name: name.to_string(),
        n_max_calib: 128,
        mu_scale: 1.0,
        cost_hr: 2.0,
        spot_discount: 0.0,
        preemptible: false,
    };

    let empty = SkuCatalog { skus: vec![] };
    let err = empty.validate().unwrap_err().to_string();
    assert!(err.contains("empty"), "{err}");

    let mut bad_cost = SkuCatalog { skus: vec![sku("a100"), sku("h100")] };
    bad_cost.skus[1].cost_hr = 0.0;
    let err = bad_cost.validate().unwrap_err().to_string();
    assert!(err.contains("sku 1") && err.contains("h100"), "{err}");
    assert!(err.contains("cost_hr"), "{err}");

    let mut bad_slots = SkuCatalog { skus: vec![sku("a100")] };
    bad_slots.skus[0].n_max_calib = 0;
    let err = bad_slots.validate().unwrap_err().to_string();
    assert!(err.contains("sku 0") && err.contains("a100"), "{err}");
    assert!(err.contains("n_max_calib"), "{err}");

    let mut bad_mu = SkuCatalog { skus: vec![sku("a100"), sku("l40s")] };
    bad_mu.skus[1].mu_scale = -0.5;
    let err = bad_mu.validate().unwrap_err().to_string();
    assert!(err.contains("sku 1") && err.contains("l40s"), "{err}");
    assert!(err.contains("mu_scale"), "{err}");

    let mut bad_spot = SkuCatalog { skus: vec![sku("a100")] };
    bad_spot.skus[0].spot_discount = 1.0;
    let err = bad_spot.validate().unwrap_err().to_string();
    assert!(err.contains("sku 0") && err.contains("spot_discount"), "{err}");

    let dup = SkuCatalog { skus: vec![sku("a100"), sku("h100"), sku("a100")] };
    let err = dup.validate().unwrap_err().to_string();
    assert!(
        err.contains("sku 2") && err.contains("duplicates") && err.contains("sku 0"),
        "{err}"
    );

    // The demo catalog itself must of course validate.
    SkuCatalog::demo(&fleetopt::config::GpuProfile::a100_llama70b())
        .validate()
        .unwrap();
}
