//! Stability-guarded admission control (ISSUE 10 satellite):
//!
//! * **Disabled == verbatim gateway**: an [`AdmissionController`] with no
//!   config routes every request byte-for-byte through [`Gateway::route`]
//!   — same `RoutedRequest` fields, same metrics, same estimator bits —
//!   the identity policy `tests/gateway_concurrency.rs` pins for the
//!   sharded path.
//! * **Hysteresis never flaps**: any constant occupancy settles after one
//!   observation, through the controller's own `route` loop.
//! * **Shed is last**: a request is shed only after recompress and the
//!   whole defer budget are exhausted, in ladder order.
//! * **Counters conserve**: in an overloaded autoscale run every offered
//!   request lands in exactly one terminal counter
//!   (`admitted + recompressed + shed + ...`), and the engine-level flow
//!   balance `completed + shed + dropped + censored == n` holds.

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::config::PlannerConfig;
use fleetopt::fleetsim::{simulate_autoscale_kv, AutoscaleConfig, ChaosOpts, KvFleetOpts};
use fleetopt::planner::{plan_spec_sweep_gamma, PlanInput};
use fleetopt::router::admit::{AdmissionController, AdmitConfig, AdmitDecision};
use fleetopt::router::{Gateway, GatewayConfig};
use fleetopt::util::rng::Rng;
use fleetopt::workload::arrivals::RateModel;
use fleetopt::workload::traces;

fn doc(tokens: u32, rng: &mut Rng) -> String {
    corpus::generate_document(
        &CorpusConfig {
            target_tokens: tokens,
            ..Default::default()
        },
        rng,
    )
}

/// A mixed trace shaped like gateway_concurrency's: short, borderline
/// prose, borderline code, and long docs, with repeats.
fn mixed_trace(cfg: &GatewayConfig, n: usize, seed: u64) -> Vec<(String, u32)> {
    let mut rng = Rng::new(seed);
    let b = cfg.b_short();
    let mut pool: Vec<(String, u32)> = Vec::new();
    for i in 0..4 {
        pool.push((doc(120 + 40 * i, &mut rng), 16));
    }
    for i in 0..4 {
        pool.push((doc(b + 30 + 60 * i, &mut rng), 32));
    }
    pool.push((corpus::generate_code(b + 100, &mut rng), 32));
    pool.push((doc(3 * b, &mut rng), 64));
    (0..n).map(|k| pool[(k * 7) % pool.len()].clone()).collect()
}

#[test]
fn disabled_controller_is_bit_identical_to_gateway_route() {
    // The oracle is Gateway::route itself, called serially on a twin
    // gateway: a `cfg: None` controller must not perturb routing,
    // counters, or the EMA estimator in any way.
    for kind in 0..2usize {
        let cfg = match kind {
            0 => GatewayConfig::two_tier(512, 1.5, true),
            _ => GatewayConfig::tiered(&[256, 768], 1.5, true),
        };
        let requests = mixed_trace(&cfg, 40, 200 + kind as u64);
        let mut oracle = Gateway::new(cfg.clone());
        let mut gw = Gateway::new(cfg);
        let mut ctl = AdmissionController::new(None);
        // Occupancy reads are irrelevant when disabled — hand it a
        // saturated fleet to prove it never looks.
        let occ = [1.0, 1.0, 1.0];
        for (text, max_out) in &requests {
            let want = oracle.route(text, *max_out);
            let (d, got) = ctl.route(&mut gw, text, *max_out, &occ, 0);
            assert_eq!(d, AdmitDecision::Admit);
            let got = got.expect("disabled controller always routes");
            assert_eq!(got.tier, want.tier, "trace {kind}");
            assert_eq!(got.text, want.text, "trace {kind}: text bytes");
            assert_eq!(got.prompt_tokens, want.prompt_tokens);
            assert_eq!(got.max_output_tokens, want.max_output_tokens);
            assert_eq!(got.category, want.category);
            assert_eq!(got.estimated_l_total, want.estimated_l_total);
            assert_eq!(got.compressed, want.compressed);
        }
        assert_eq!(gw.metrics(), oracle.metrics(), "trace {kind}: counters");
        assert_eq!(
            gw.estimator.c_hat_bits(),
            oracle.estimator.c_hat_bits(),
            "trace {kind}: estimator bits diverged"
        );
        assert_eq!(ctl.counters.admitted, requests.len() as u64);
        assert_eq!(ctl.counters.total(), requests.len() as u64);
    }
}

#[test]
fn constant_load_never_flaps_through_the_controller() {
    // Feed the controller a constant occupancy via its own route loop:
    // whatever it decides on the second request, it must keep deciding
    // for every subsequent one (first request may differ: it latches).
    for occ in [0.0, 0.72, 0.85, 0.99] {
        let cfg = GatewayConfig::two_tier(512, 1.5, true);
        let requests = mixed_trace(&cfg, 30, 7);
        let mut gw = Gateway::new(cfg);
        let mut ctl = AdmissionController::new(Some(AdmitConfig {
            // No recompress/defer noise: decisions are pure
            // engage/disengage probes.
            gamma_tighten: 1.0,
            max_defers: 0,
            ..AdmitConfig::default()
        }));
        let occs = vec![occ; 4];
        let mut decisions = Vec::new();
        for (text, max_out) in &requests {
            let (d, _) = ctl.route(&mut gw, text, *max_out, &occs, 0);
            decisions.push(d);
        }
        // Per tier the state settles after one observation; with a global
        // constant occupancy every decision after the first per-tier
        // probe is identical.
        let settled = decisions.last().copied().unwrap();
        for (i, d) in decisions.iter().enumerate().skip(4) {
            assert_eq!(*d, settled, "occ {occ}: flapped at request {i}");
        }
    }
}

#[test]
fn shed_only_after_recompress_and_defers_exhausted() {
    let cfg = GatewayConfig::two_tier(512, 1.5, true);
    let mut rng = Rng::new(11);
    // A compressible borderline doc (prose in the band).
    let band_doc = doc(512 + 60, &mut rng);
    let mut gw = Gateway::new(cfg);
    let acfg = AdmitConfig {
        max_defers: 2,
        ..AdmitConfig::default()
    };
    let mut ctl = AdmissionController::new(Some(acfg));
    let occ = [1.0, 1.0]; // engaged everywhere
    // First attempt: compress harder (terminal, admits).
    let (d, r) = ctl.route(&mut gw, &band_doc, 32, &occ, 0);
    assert_eq!(d, AdmitDecision::Recompress);
    assert!(r.is_some(), "recompress admits into the tightened band");
    // A non-compressible (code) doc: defer, defer, then shed.
    let long_doc = corpus::generate_code(4 * 512, &mut rng);
    for defers in 0..2u32 {
        let (d, r) = ctl.route(&mut gw, &long_doc, 64, &occ, defers);
        assert_eq!(d, AdmitDecision::Defer, "defer {defers}");
        assert!(r.is_none());
    }
    let (d, r) = ctl.route(&mut gw, &long_doc, 64, &occ, 2);
    assert_eq!(d, AdmitDecision::Shed, "budget exhausted: last resort");
    assert!(r.is_none());
    assert_eq!(ctl.counters.recompressed, 1);
    assert_eq!(ctl.counters.deferred, 2);
    assert_eq!(ctl.counters.shed, 1);
    assert_eq!(ctl.counters.total(), 4);
}

#[test]
fn overloaded_autoscale_conserves_every_decision_counter() {
    // A deliberately undersized fleet (plan for a fraction of the offered
    // rate, no replanning) with a tight KV cap: the controller must
    // engage, and the books must balance exactly.
    let w = traces::agent_heavy();
    let base = 120.0;
    let n = 3_000;
    let mut input = PlanInput::new(w.clone(), base * 0.3);
    input.cfg = PlannerConfig {
        mc_samples: 8_000,
        ..PlannerConfig::default()
    };
    let spec = input.gpu.fleet_spec(&[w.b_short]);
    let plan = plan_spec_sweep_gamma(&input, &spec).expect("plan");
    let horizon = n as f64 / base;
    let cfg = AutoscaleConfig {
        epoch_s: horizon / 10.0,
        window_s: horizon / 5.0,
        provision_delay_s: horizon / 20.0,
        replanning: false,
        ..AutoscaleConfig::default()
    };
    let kv = KvFleetOpts {
        cap_frac: Some(0.3),
        admit: Some(AdmitConfig {
            defer_s: horizon / 50.0,
            ..AdmitConfig::default()
        }),
    };
    let rep = simulate_autoscale_kv(
        &w,
        RateModel::Constant(base),
        n,
        &input,
        plan,
        &cfg,
        17,
        &ChaosOpts::default(),
        &kv,
    );
    // Terminal decisions: every request is admitted (plainly or via
    // recompress) or shed, exactly once.
    let terminal = rep.admit.admitted + rep.admit.recompressed + rep.admit.shed;
    assert_eq!(terminal, n as u64, "terminal decisions must cover the trace");
    // Flow balance at the engine level (no chaos => no dropped retries).
    assert_eq!(rep.dropped_retries, 0);
    assert_eq!(
        rep.completed + rep.admit.shed + rep.censored,
        n as u64,
        "completed {} + shed {} + censored {}",
        rep.completed,
        rep.admit.shed,
        rep.censored
    );
    assert_eq!(rep.kv_violations, 0, "ledger oversubscribed");
    // The overload genuinely engaged the controller.
    assert!(
        rep.admit.deferred + rep.admit.recompressed + rep.admit.shed > 0,
        "undersized fleet never engaged admission: {:?}",
        rep.admit
    );
}

#[test]
fn default_kv_opts_change_nothing() {
    // KvFleetOpts::default() (no cap, no admission) must leave the
    // autoscale engine bit-identical to the chaos entry point — the same
    // identity policy as inert fault plans.
    use fleetopt::fleetsim::simulate_autoscale_chaos;
    use fleetopt::metrics::EpochMetrics;
    let w = traces::lmsys();
    let base = 200.0;
    let n = 3_000;
    let mut input = PlanInput::new(w.clone(), base);
    input.cfg = PlannerConfig {
        mc_samples: 8_000,
        ..PlannerConfig::default()
    };
    let spec = input.gpu.fleet_spec(&[w.b_short]);
    let plan = plan_spec_sweep_gamma(&input, &spec).expect("plan");
    let horizon = n as f64 / base;
    let cfg = AutoscaleConfig {
        epoch_s: horizon / 8.0,
        window_s: horizon / 4.0,
        provision_delay_s: horizon / 16.0,
        ..AutoscaleConfig::default()
    };
    let model = RateModel::Diurnal {
        base,
        amp: 0.5,
        period_s: horizon,
        phase: 0.0,
    };
    let chaos = ChaosOpts::default();
    let a = simulate_autoscale_chaos(&w, model.clone(), n, &input, plan.clone(), &cfg, 5, &chaos);
    let b = simulate_autoscale_kv(
        &w,
        model,
        n,
        &input,
        plan,
        &cfg,
        5,
        &chaos,
        &KvFleetOpts::default(),
    );
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.cost.to_bits(), b.cost.to_bits());
    assert_eq!(a.gpu_hours.to_bits(), b.gpu_hours.to_bits());
    assert_eq!(
        EpochMetrics::series_to_json(&a.epochs),
        EpochMetrics::series_to_json(&b.epochs),
        "per-epoch series diverged with default KV opts"
    );
    assert_eq!(b.admit.total(), 0, "no controller => no decisions counted");
    assert_eq!(b.kv_blocked, 0);
    assert_eq!(b.kv_violations, 0);
}
