//! KV-cache decode modeling: identity discipline and stability (ISSUE 10).
//!
//! * **Off == bit-identical**: `simulate_fleet_tiered_kv(.., None)` IS
//!   `simulate_fleet_tiered_chaos` (delegation pinned), and a
//!   `cap_frac = 1.0` policy — provably non-binding, since K requests of
//!   at most `c_max` tokens can never exceed `n_slots * c_max` while a
//!   slot is free — changes no admission decision.
//! * **Binding caps are safe**: a tight cap queues requests instead of
//!   oversubscribing (zero ledger violations), still completes the trace,
//!   and strictly increases waiting.
//! * **Planner floor**: `PlanInput::kv` only ever raises tier counts
//!   (`kv: None` is the bit-identical baseline), and the sized fleet
//!   respects the closed-form `rho_kv <= rho_max` bound per tier.

use fleetopt::config::PlannerConfig;
use fleetopt::fleetsim::{
    simulate_fleet_tiered_chaos, simulate_fleet_tiered_kv, FaultPlan, TieredSimResult,
};
use fleetopt::planner::{plan_spec_sweep_gamma, plan_tiers, PlanInput, TieredPlan};
use fleetopt::queueing::kv::KvPlanPolicy;
use fleetopt::workload::traces::{self, Workload};

fn fast_input(w: &Workload, lambda: f64) -> PlanInput {
    let mut i = PlanInput::new(w.clone(), lambda);
    i.cfg = PlannerConfig {
        mc_samples: 8_000,
        ..PlannerConfig::default()
    };
    i
}

fn plan_for(input: &PlanInput, boundaries: &[u32]) -> TieredPlan {
    let spec = input.gpu.fleet_spec(boundaries);
    plan_spec_sweep_gamma(input, &spec).expect("plan")
}

fn assert_tiers_identical(a: &TieredSimResult, b: &TieredSimResult, label: &str) {
    assert_eq!(a.tiers.len(), b.tiers.len(), "{label}");
    for (ti, (ra, rb)) in a.tiers.iter().zip(&b.tiers).enumerate() {
        match (ra, rb) {
            (Some(ra), Some(rb)) => {
                assert_eq!(ra.completed, rb.completed, "{label} tier {ti}");
                assert_eq!(ra.events, rb.events, "{label} tier {ti}");
                assert_eq!(
                    ra.utilization.to_bits(),
                    rb.utilization.to_bits(),
                    "{label} tier {ti}: utilization bits"
                );
                let (mut ta, mut tb) = (ra.ttft.clone(), rb.ttft.clone());
                assert_eq!(
                    ta.p99().to_bits(),
                    tb.p99().to_bits(),
                    "{label} tier {ti}: ttft bits"
                );
            }
            (None, None) => {}
            _ => panic!("{label} tier {ti}: provisioning diverged"),
        }
    }
}

#[test]
fn kv_none_is_the_chaos_engine_verbatim() {
    let w = traces::azure();
    let input = fast_input(&w, 300.0);
    let plan = plan_for(&input, &[2048, 16384]);
    let n = 5_000;
    let faults = FaultPlan::default();
    let a = simulate_fleet_tiered_chaos(&w, &plan, &input.gpu, 300.0, n, 9, &faults);
    let b = simulate_fleet_tiered_kv(&w, &plan, &input.gpu, 300.0, n, 9, &faults, None);
    assert_tiers_identical(&a, &b, "kv=None");
}

#[test]
fn non_binding_cap_changes_no_admission_decision() {
    // cap_frac = 1.0: the per-GPU cap equals the tier's full slot token
    // budget, which resident requests (each <= c_max) cannot exceed while
    // a slot is free. Every observable except the KV diagnostics matches
    // the cap-less run bit for bit.
    let w = traces::lmsys();
    let input = fast_input(&w, 250.0);
    let plan = plan_for(&input, &[1536]);
    let n = 5_000;
    let faults = FaultPlan::default();
    let off = simulate_fleet_tiered_kv(&w, &plan, &input.gpu, 250.0, n, 3, &faults, None);
    let policy = KvPlanPolicy { cap_frac: 1.0 };
    for (ti, t) in plan.spec.tiers.iter().enumerate() {
        policy.validate(ti, t.n_max, t.c_max).expect("full budget is valid");
    }
    let on = simulate_fleet_tiered_kv(&w, &plan, &input.gpu, 250.0, n, 3, &faults, Some(policy));
    assert_tiers_identical(&off, &on, "cap_frac=1.0");
    for r in on.tiers.iter().flatten() {
        assert_eq!(r.kv_blocked, 0, "full-budget cap must never bind");
        assert_eq!(r.kv_violations, 0);
        assert!(r.kv_util > 0.0, "ledger must have measured under Some cap");
    }
    for r in off.tiers.iter().flatten() {
        assert_eq!(r.kv_util, 0.0, "no ledger without a cap");
    }
}

#[test]
fn binding_cap_queues_rather_than_oversubscribes() {
    let w = traces::azure();
    let input = fast_input(&w, 300.0);
    let plan = plan_for(&input, &[4096]);
    let n = 6_000;
    let faults = FaultPlan::default();
    let open = simulate_fleet_tiered_kv(&w, &plan, &input.gpu, 300.0, n, 4, &faults, None);
    // Tight but deadlock-free (cap >= c_max holds whenever n_slots >= 5).
    let policy = KvPlanPolicy { cap_frac: 0.2 };
    for (ti, t) in plan.spec.tiers.iter().enumerate() {
        policy.validate(ti, t.n_max, t.c_max).expect("cap above c_max");
    }
    let capped = simulate_fleet_tiered_kv(&w, &plan, &input.gpu, 300.0, n, 4, &faults, Some(policy));
    let completed: u64 = capped.tiers.iter().flatten().map(|r| r.completed).sum();
    assert_eq!(completed + capped.censored_total(), n as u64, "conservation");
    assert_eq!(capped.censored_total(), 0, "no horizon: the run must drain");
    let blocked: u64 = capped.tiers.iter().flatten().map(|r| r.kv_blocked).sum();
    assert!(blocked > 0, "a 20% cap must actually bind somewhere");
    for (ti, r) in capped.tiers.iter().flatten().enumerate() {
        assert_eq!(r.kv_violations, 0, "tier {ti}: ledger oversubscribed");
        assert!(r.kv_util <= 1.0 + 1e-9, "tier {ti}: kv_util {}", r.kv_util);
    }
    // Tighter decode memory means at least as much queueing.
    let wait = |s: &TieredSimResult| -> f64 {
        s.tiers
            .iter()
            .flatten()
            .map(|r| {
                let mut w = r.wait.clone();
                w.p99()
            })
            .fold(0.0, f64::max)
    };
    assert!(wait(&capped) >= wait(&open), "cap cannot reduce waiting");
}

#[test]
fn planner_kv_floor_only_raises_tier_counts() {
    // Fixed gammas via `plan_tiers`, so the tier cuts and per-tier rates
    // are pinned and the only degree of freedom is the KV floor itself:
    // `kv: None` must be bit-identical, a derated budget can only raise
    // per-tier counts, tighter budgets dominate looser ones, and a
    // near-zero budget must actually bind.
    for w in traces::all() {
        let input = fast_input(&w, 800.0);
        let spec = input.gpu.fleet_spec(&[w.b_short]);
        let plan = |kv: Option<KvPlanPolicy>| {
            let mut i = fast_input(&w, 800.0);
            i.kv = kv;
            plan_tiers(&i, &spec, &[1.5], true, None).expect("plan")
        };
        let baseline = plan(None);
        let same = plan(None);
        assert_eq!(baseline.gpu_counts(), same.gpu_counts(), "{}", w.name);
        assert_eq!(
            baseline.cost_yr.to_bits(),
            same.cost_yr.to_bits(),
            "{}: kv None must be deterministic and bit-identical",
            w.name
        );
        let loose = plan(Some(KvPlanPolicy { cap_frac: 0.25 }));
        let tight = plan(Some(KvPlanPolicy { cap_frac: 0.02 }));
        for ti in 0..baseline.tiers.len() {
            let (b, l, t) = (
                baseline.tiers[ti].n_gpus,
                loose.tiers[ti].n_gpus,
                tight.tiers[ti].n_gpus,
            );
            assert!(
                l >= b,
                "{} tier {ti}: KV floor lowered the count {b} -> {l}",
                w.name
            );
            assert!(
                t >= l,
                "{} tier {ti}: tighter budget shrank the fleet {l} -> {t}",
                w.name
            );
        }
        assert!(loose.cost_yr >= baseline.cost_yr, "{}", w.name);
        assert!(tight.cost_yr >= loose.cost_yr, "{}", w.name);
        // 2% of the slot token budget is far below the mean resident
        // footprint on every trace: the floor must dominate Erlang-C.
        let total = |p: &fleetopt::planner::TieredPlan| -> u64 {
            p.gpu_counts().iter().sum()
        };
        assert!(
            total(&tight) > total(&baseline),
            "{}: a 2% KV budget never bound ({} vs {})",
            w.name,
            total(&tight),
            total(&baseline)
        );
    }
}
