//! Runtime-dispatch coverage (§Perf, PR 6): force-scalar vs force-SIMD
//! on randomized inputs must agree bit-for-bit on every shipped value —
//! gateway selections and component scores, planner cell bounds, and the
//! full pruned-sweep plan. Under `--no-default-features` the SIMD paths
//! compile out and both modes pin the scalar path, so the identities
//! (trivially) still hold and this suite doubles as the scalar-build
//! smoke test in CI. The one tolerated divergence — the blocked bench
//! checksum reduction — is covered by an explicit ulp-bound policy test.

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::compress::doc::{overlap, overlap_scalar, Document};
use fleetopt::compress::extractive::compress_doc_with_mode;
use fleetopt::compress::textrank::{textrank_with_mode, SimilarityMode};
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::planner::{sweep_cell_bounds, sweep_tiered_pruned, CalibCache, PlanInput};
use fleetopt::util::check::{ensure, forall};
use fleetopt::util::rng::Rng;
use fleetopt::util::simd::{hsum_blocked, ulp_distance, with_dispatch, Dispatch};
use fleetopt::workload::traces;

#[test]
fn gateway_selection_identical_across_dispatch_modes() {
    forall(
        "selection-across-dispatch",
        10,
        |rng| {
            let target = rng.range(200, 2_000) as u32;
            (target, rng.uniform(0.3, 1.0), rng.next_u64())
        },
        |&(target, frac, seed)| {
            let mut rng = Rng::new(seed);
            let text = corpus::generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    ..Default::default()
                },
                &mut rng,
            );
            let doc = Document::parse(&text);
            let budget = (count_tokens(&text) as f64 * frac) as u32;
            let scalar = with_dispatch(Dispatch::ForceScalar, || {
                compress_doc_with_mode(&doc, budget, SimilarityMode::InvertedIndex)
            });
            let simd = with_dispatch(Dispatch::ForceSimd, || {
                compress_doc_with_mode(&doc, budget, SimilarityMode::InvertedIndex)
            });
            ensure(scalar.text == simd.text, "selected text differs")?;
            ensure(scalar.selected == simd.selected, "selection differs")?;
            ensure(scalar.ok == simd.ok, "feasibility flag differs")?;
            let tr_scalar = with_dispatch(Dispatch::ForceScalar, || {
                textrank_with_mode(&doc, SimilarityMode::InvertedIndex)
            });
            let tr_simd = with_dispatch(Dispatch::ForceSimd, || {
                textrank_with_mode(&doc, SimilarityMode::InvertedIndex)
            });
            for (i, (a, b)) in tr_scalar.iter().zip(&tr_simd).enumerate() {
                ensure(
                    a.to_bits() == b.to_bits(),
                    format!("textrank score {i}: scalar {a} vs simd {b}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn overlap_dispatch_matches_scalar_on_random_sets() {
    fn sorted_set(rng: &mut Rng, max_len: usize, universe: u64) -> Vec<u32> {
        let n = rng.range(0, max_len + 1);
        let mut v: Vec<u32> = (0..n).map(|_| rng.below(universe) as u32).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
    forall(
        "overlap-across-dispatch",
        100,
        |rng| (sorted_set(rng, 150, 500), sorted_set(rng, 150, 500)),
        |(a, b)| {
            let want = overlap_scalar(a, b);
            let scalar = with_dispatch(Dispatch::ForceScalar, || overlap(a, b));
            let simd = with_dispatch(Dispatch::ForceSimd, || overlap(a, b));
            ensure(scalar == want, format!("forced-scalar overlap {scalar} != {want}"))?;
            ensure(simd == want, format!("forced-simd overlap {simd} != {want}"))
        },
    );
}

#[test]
fn batched_cell_bounds_identical_on_all_traces() {
    for w in traces::all() {
        let mut input = PlanInput::new(w.clone(), 1000.0);
        input.cfg.mc_samples = 8_000;
        for k in [2usize, 3] {
            let scalar = sweep_cell_bounds(&input, k, false);
            let batched = sweep_cell_bounds(&input, k, true);
            assert_eq!(scalar.len(), batched.len(), "{} K={k}", w.name);
            for (i, (s, b)) in scalar.iter().zip(&batched).enumerate() {
                match (s, b) {
                    (Some(x), Some(y)) => {
                        assert_eq!(x.to_bits(), y.to_bits(), "{} K={k} cell {i}", w.name);
                    }
                    (None, None) => {}
                    _ => panic!("{} K={k} cell {i}: bound presence differs", w.name),
                }
            }
        }
    }
}

#[test]
fn pruned_sweep_plan_identical_across_dispatch_modes() {
    // The planner acceptance identity: argmin cell, per-tier GPU counts,
    // gammas, and cost must not move by a single bit when the batched
    // bound pass replaces the scalar one.
    let mut input = PlanInput::new(traces::azure(), 1000.0);
    input.cfg.mc_samples = 8_000;
    for k in [2usize, 3] {
        let (ps, _) = with_dispatch(Dispatch::ForceScalar, || {
            sweep_tiered_pruned(&input, k, &CalibCache::new()).unwrap()
        });
        let (pv, _) = with_dispatch(Dispatch::ForceSimd, || {
            sweep_tiered_pruned(&input, k, &CalibCache::new()).unwrap()
        });
        assert_eq!(ps.cost_yr.to_bits(), pv.cost_yr.to_bits(), "K={k}");
        assert_eq!(ps.boundaries(), pv.boundaries(), "K={k}");
        assert_eq!(ps.gpu_counts(), pv.gpu_counts(), "K={k}");
        for (a, b) in ps.gammas.iter().zip(&pv.gammas) {
            assert_eq!(a.to_bits(), b.to_bits(), "K={k}");
        }
    }
}

#[test]
fn hsum_blocked_divergence_stays_within_documented_bound() {
    // The single tolerated non-identity: the blocked (SIMD-shaped) bench
    // checksum reduction. Its reassociation error against the sequential
    // sum is bounded for same-sign inputs; 4n ulps is the documented,
    // deliberately loose ceiling (measured divergence is 0-2 ulps).
    forall(
        "hsum-ulp-policy",
        50,
        |rng| {
            let n = rng.range(1, 513);
            (0..n).map(|_| rng.uniform(0.0, 1.0)).collect::<Vec<f64>>()
        },
        |xs| {
            let seq: f64 = xs.iter().sum();
            let blk = hsum_blocked(xs);
            let d = ulp_distance(seq, blk);
            let bound = 4 * xs.len() as u64;
            ensure(
                d <= bound,
                format!("n={}: {d} ulps exceeds documented bound {bound}", xs.len()),
            )
        },
    );
}

#[cfg(feature = "simd")]
#[test]
fn erlang_batch_matches_scalar_on_randomized_grid() {
    use fleetopt::queueing::erlang::erlang_c;
    use fleetopt::queueing::simd::lanes::erlang_c_batch;
    forall(
        "erlang-batch-vs-scalar",
        30,
        |rng| {
            let n = rng.range(1, 40);
            (0..n)
                .map(|_| (1 + rng.below(10_000), rng.uniform(0.01, 0.999)))
                .collect::<Vec<(u64, f64)>>()
        },
        |points| {
            let mut out = Vec::new();
            erlang_c_batch(points, &mut out);
            ensure(out.len() == points.len(), "length mismatch")?;
            for (i, (&(c, rho), &got)) in points.iter().zip(&out).enumerate() {
                let want = erlang_c(c, rho);
                ensure(
                    got.to_bits() == want.to_bits(),
                    format!("point {i}: c={c} rho={rho} got {got} want {want}"),
                )?;
            }
            Ok(())
        },
    );
}
