//! DES engine overhaul regression gate (§Perf).
//!
//! * **Scheduler equivalence**: the calendar queue's pop sequence is
//!   byte-identical to the `BinaryHeap` oracle under adversarial random
//!   schedules — exact time ties, bursts, far-future jumps that force the
//!   direct-search fallback, and full drains through resize churn.
//! * **Simulator equivalence**: the overhauled `simulate_pool` (dense
//!   slot slabs, idle bitset, recycled scratch) is bit-identical to the
//!   **verbatim pre-overhaul implementation** (carried below as
//!   `reference::simulate_pool_reference`, the same way
//!   `tests/tier_equivalence.rs` carries the pre-tiering planner).
//! * **P² error bounds**: the streaming per-epoch P99 stays within a
//!   tested error bound of the exact sort on all three traces' TTFT
//!   streams, and within tight bounds on smooth synthetic distributions.

use fleetopt::config::GpuProfile;
use fleetopt::fleetsim::{
    simulate_pool, simulate_pool_with, EventQueue, QueueImpl, SimConfig, SimRequest, SimScratch,
};
use fleetopt::util::rng::Rng;
use fleetopt::util::stats::{percentile, P2Quantile};
use fleetopt::workload::arrivals::generate_trace;
use fleetopt::workload::traces;

// ---------------------------------------------------------------------------
// scheduler pop-order equivalence
// ---------------------------------------------------------------------------

/// Drive both backends through an identical random schedule/pop script and
/// assert byte-identical (time, payload) sequences.
fn run_schedule_script(seed: u64, n_ops: usize, burst: usize) {
    let mut cal: EventQueue<u64> = EventQueue::with_impl(QueueImpl::Calendar);
    let mut heap: EventQueue<u64> = EventQueue::with_impl(QueueImpl::BinaryHeap);
    let mut rng = Rng::new(seed);
    let mut payload = 0u64;
    let mut recent: Vec<f64> = Vec::new();
    for _ in 0..n_ops {
        match rng.below(10) {
            // Schedule a burst of future events from a mix of gap shapes.
            0..=5 => {
                for _ in 0..rng.range(1, burst + 1) {
                    let now = cal.now();
                    let t = match rng.below(5) {
                        // Exact tie with a previously scheduled time.
                        0 if !recent.is_empty() => {
                            let t = recent[rng.range(0, recent.len())];
                            if t >= now {
                                t
                            } else {
                                now
                            }
                        }
                        // Tie with the current time.
                        1 => now,
                        // Tight cluster.
                        2 => now + rng.f64() * 1e-6,
                        // Far-future jump (forces direct search later).
                        3 => now + 1e4 + rng.f64() * 1e7,
                        // Typical exponential gap.
                        _ => now + rng.exp(5.0),
                    };
                    recent.push(t);
                    if recent.len() > 64 {
                        recent.remove(0);
                    }
                    cal.schedule(t, payload);
                    heap.schedule(t, payload);
                    payload += 1;
                }
            }
            // Pop a run of events.
            _ => {
                for _ in 0..rng.range(1, burst + 1) {
                    let a = cal.pop();
                    let b = heap.pop();
                    match (a, b) {
                        (None, None) => break,
                        (Some((ta, pa)), Some((tb, pb))) => {
                            assert_eq!(ta.to_bits(), tb.to_bits(), "times diverge (seed {seed})");
                            assert_eq!(pa, pb, "tie order diverges at t={ta} (seed {seed})");
                        }
                        (a, b) => panic!("length diverges (seed {seed}): {a:?} vs {b:?}"),
                    }
                }
            }
        }
        assert_eq!(cal.len(), heap.len());
    }
    // Full drain: every remaining event in identical order.
    loop {
        match (cal.pop(), heap.pop()) {
            (None, None) => break,
            (Some((ta, pa)), Some((tb, pb))) => {
                assert_eq!(ta.to_bits(), tb.to_bits());
                assert_eq!(pa, pb);
            }
            (a, b) => panic!("drain length diverges: {a:?} vs {b:?}"),
        }
    }
}

#[test]
fn calendar_pop_order_matches_heap_oracle_under_random_schedules() {
    for seed in [1u64, 7, 42, 0xCA1E, 0xDE5] {
        run_schedule_script(seed, 3_000, 8);
    }
}

#[test]
fn calendar_pop_order_matches_heap_oracle_under_heavy_ties() {
    // Only 4 distinct timestamps over thousands of events: tie-order is
    // the whole signal.
    let mut cal: EventQueue<u32> = EventQueue::with_impl(QueueImpl::Calendar);
    let mut heap: EventQueue<u32> = EventQueue::with_impl(QueueImpl::BinaryHeap);
    let mut rng = Rng::new(9);
    for i in 0..5_000u32 {
        let t = [0.0, 1.5, 1.5, 3.25][rng.range(0, 4)];
        cal.schedule(t, i);
        heap.schedule(t, i);
    }
    loop {
        match (cal.pop(), heap.pop()) {
            (None, None) => break,
            (a, b) => assert_eq!(a, b),
        }
    }
}

// ---------------------------------------------------------------------------
// the verbatim pre-overhaul simulator, as the bit-identity oracle
// ---------------------------------------------------------------------------

mod reference {
    //! The pre-overhaul `fleetsim::{events, sim}` hot path, verbatim
    //! (BinaryHeap scheduler, `Vec<Option<Active>>` slot scans, O(n_gpus)
    //! wake scan, full-sort percentiles happen outside SimResult).

    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    use fleetopt::fleetsim::{SimConfig, SimRequest};
    use fleetopt::util::stats::Samples;

    #[derive(Clone, Debug)]
    struct Scheduled<E> {
        time: f64,
        seq: u64,
        payload: E,
    }

    impl<E> PartialEq for Scheduled<E> {
        fn eq(&self, other: &Self) -> bool {
            self.time == other.time && self.seq == other.seq
        }
    }
    impl<E> Eq for Scheduled<E> {}

    impl<E> Ord for Scheduled<E> {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .time
                .total_cmp(&self.time)
                .then_with(|| other.seq.cmp(&self.seq))
        }
    }
    impl<E> PartialOrd for Scheduled<E> {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }

    struct EventQueue<E> {
        heap: BinaryHeap<Scheduled<E>>,
        seq: u64,
    }

    impl<E> EventQueue<E> {
        fn new() -> Self {
            EventQueue {
                heap: BinaryHeap::new(),
                seq: 0,
            }
        }

        fn schedule(&mut self, time: f64, payload: E) {
            self.heap.push(Scheduled {
                time,
                seq: self.seq,
                payload,
            });
            self.seq += 1;
        }

        fn pop(&mut self) -> Option<(f64, E)> {
            self.heap.pop().map(|s| (s.time, s.payload))
        }
    }

    #[derive(Clone, Copy, Debug)]
    struct Active {
        req: usize,
        prefill_left: u32,
        iters_left: u32,
        first_token_done: bool,
    }

    struct Gpu {
        slots: Vec<Option<Active>>,
        n_busy: u32,
        iterating: bool,
        busy_integral: f64,
        last_change: f64,
    }

    impl Gpu {
        fn new(n_slots: u32) -> Self {
            Gpu {
                slots: vec![None; n_slots as usize],
                n_busy: 0,
                iterating: false,
                busy_integral: 0.0,
                last_change: 0.0,
            }
        }

        fn accumulate(&mut self, t: f64, window: (f64, f64)) {
            let lo = self.last_change.max(window.0);
            let hi = t.min(window.1);
            if hi > lo {
                self.busy_integral += self.n_busy as f64 * (hi - lo);
            }
            self.last_change = t;
        }

        fn free_slots(&self) -> u32 {
            self.slots.len() as u32 - self.n_busy
        }
    }

    #[derive(Clone, Copy, Debug)]
    enum Ev {
        Arrival(usize),
        Iteration(usize),
    }

    pub struct RefResult {
        pub utilization: f64,
        pub ttft: Samples,
        pub wait: Samples,
        pub completed: u64,
        pub censored: u64,
    }

    pub fn simulate_pool_reference(cfg: &SimConfig, requests: &[SimRequest]) -> RefResult {
        assert!(cfg.n_gpus > 0 && cfg.n_slots > 0);
        let n_req = requests.len();
        let warm = (n_req as f64 * cfg.warmup_frac) as usize;
        let window = if n_req == 0 {
            (0.0, 0.0)
        } else {
            let lo = requests[warm.min(n_req - 1)].arrival_s.max(cfg.warmup_s);
            let hi = requests[n_req - 1].arrival_s;
            (lo.min(hi), hi)
        };

        let chunk = cfg.gpu.chunk;
        let t_iter_full = cfg.gpu.t_iter_s(cfg.n_slots);

        let mut gpus: Vec<Gpu> = (0..cfg.n_gpus).map(|_| Gpu::new(cfg.n_slots)).collect();
        let mut queue: std::collections::VecDeque<usize> = Default::default();
        let mut events: EventQueue<Ev> = EventQueue::new();
        for (i, r) in requests.iter().enumerate() {
            events.schedule(r.arrival_s, Ev::Arrival(i));
        }

        let mut ttft = Samples::with_capacity(n_req);
        let mut wait = Samples::with_capacity(n_req);
        let mut completed = 0u64;

        let admit = |g: &mut Gpu,
                     queue: &mut std::collections::VecDeque<usize>,
                     t: f64,
                     wait: &mut Samples,
                     requests: &[SimRequest],
                     warm: usize| {
            while g.free_slots() > 0 {
                let Some(req) = queue.pop_front() else { break };
                let r = &requests[req];
                let prefill = (r.l_in as u64).div_ceil(chunk as u64) as u32;
                let slot = g.slots.iter().position(Option::is_none).unwrap();
                g.slots[slot] = Some(Active {
                    req,
                    prefill_left: prefill,
                    iters_left: prefill + r.l_out,
                    first_token_done: false,
                });
                g.n_busy += 1;
                if req >= warm {
                    wait.push(t - r.arrival_s);
                }
            }
        };

        while let Some((t, ev)) = events.pop() {
            if let Some(h) = cfg.horizon_s {
                if t > h {
                    break;
                }
            }
            match ev {
                Ev::Arrival(i) => {
                    queue.push_back(i);
                    if let Some(gi) = (0..gpus.len())
                        .filter(|&gi| !gpus[gi].iterating)
                        .max_by_key(|&gi| gpus[gi].free_slots())
                    {
                        let g = &mut gpus[gi];
                        g.accumulate(t, window);
                        admit(g, &mut queue, t, &mut wait, requests, warm);
                        if g.n_busy > 0 {
                            let dt = if cfg.lockstep_full {
                                t_iter_full
                            } else {
                                cfg.gpu.t_iter_s(g.n_busy)
                            };
                            g.iterating = true;
                            events.schedule(t + dt, Ev::Iteration(gi));
                        }
                    }
                }
                Ev::Iteration(gi) => {
                    let g = &mut gpus[gi];
                    g.accumulate(t, window);
                    g.iterating = false;
                    for slot in g.slots.iter_mut() {
                        if let Some(a) = slot {
                            a.iters_left -= 1;
                            if a.prefill_left > 0 {
                                a.prefill_left -= 1;
                            } else if !a.first_token_done {
                                a.first_token_done = true;
                                if a.req >= warm {
                                    ttft.push(t - requests[a.req].arrival_s);
                                }
                            }
                            if a.iters_left == 0 {
                                if !a.first_token_done && a.req >= warm {
                                    ttft.push(t - requests[a.req].arrival_s);
                                }
                                *slot = None;
                                g.n_busy -= 1;
                                completed += 1;
                            }
                        }
                    }
                    admit(g, &mut queue, t, &mut wait, requests, warm);
                    if g.n_busy > 0 {
                        let dt = if cfg.lockstep_full {
                            t_iter_full
                        } else {
                            cfg.gpu.t_iter_s(g.n_busy)
                        };
                        g.iterating = true;
                        events.schedule(t + dt, Ev::Iteration(gi));
                    }
                }
            }
        }

        let slot_time: f64 =
            cfg.n_gpus as f64 * cfg.n_slots as f64 * (window.1 - window.0).max(1e-12);
        let busy: f64 = gpus.iter().map(|g| g.busy_integral).sum();
        RefResult {
            utilization: busy / slot_time,
            ttft,
            wait,
            completed,
            censored: n_req as u64 - completed,
        }
    }
}

fn poisson_requests(lambda: f64, n: usize, seed: u64) -> Vec<SimRequest> {
    generate_trace(&traces::azure(), lambda, n, seed)
        .iter()
        .map(|r| SimRequest {
            arrival_s: r.arrival_s,
            l_in: r.l_in,
            l_out: r.l_out,
        })
        .collect()
}

/// Sorted-copy percentile of a sample set (the exact baseline).
fn exact_p99(xs: &[f64]) -> f64 {
    percentile(xs, 0.99)
}

#[test]
fn overhauled_simulator_is_bit_identical_to_the_reference() {
    let g = GpuProfile::a100_llama70b();
    let mut scratch = SimScratch::new();
    // (n_gpus, n_slots, lambda, n, lockstep, horizon)
    let shapes: [(u64, u32, f64, usize, bool, Option<f64>); 5] = [
        (2, 16, 6.0, 2_500, true, None),
        (7, 64, 40.0, 4_000, true, None),
        (1, 16, 30.0, 1_500, true, None), // overloaded: deep queueing
        (3, 32, 15.0, 2_000, false, None), // occupancy-dependent t_iter
        (4, 16, 12.0, 2_500, true, Some(120.0)), // horizon censoring
    ];
    for (i, &(n_gpus, n_slots, lambda, n, lockstep, horizon)) in shapes.iter().enumerate() {
        let reqs = poisson_requests(lambda, n, 0xBEEF + i as u64);
        let mut cfg = SimConfig::new(g.clone(), n_gpus, n_slots);
        cfg.lockstep_full = lockstep;
        cfg.horizon_s = horizon;
        let want = reference::simulate_pool_reference(&cfg, &reqs);
        for which in [QueueImpl::Calendar, QueueImpl::BinaryHeap] {
            cfg.queue_impl = which;
            let got = simulate_pool_with(&cfg, &reqs, &mut scratch);
            assert_eq!(
                want.utilization.to_bits(),
                got.utilization.to_bits(),
                "shape {i} {which:?}: utilization"
            );
            assert_eq!(want.completed, got.completed, "shape {i} {which:?}");
            assert_eq!(want.censored, got.censored, "shape {i} {which:?}");
            assert_eq!(want.ttft.len(), got.ttft.len(), "shape {i} {which:?}");
            assert_eq!(want.wait.len(), got.wait.len(), "shape {i} {which:?}");
            // Sample multisets are equal => every order statistic is
            // bit-identical (insertion order is not part of the contract).
            if !want.ttft.is_empty() {
                for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                    let a = percentile(want.ttft.values(), q);
                    let b = percentile(got.ttft.values(), q);
                    assert_eq!(a.to_bits(), b.to_bits(), "shape {i} {which:?} ttft q={q}");
                }
            }
            if !want.wait.is_empty() {
                for q in [0.5, 0.99] {
                    let a = percentile(want.wait.values(), q);
                    let b = percentile(got.wait.values(), q);
                    assert_eq!(a.to_bits(), b.to_bits(), "shape {i} {which:?} wait q={q}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// P² streaming percentile error bounds
// ---------------------------------------------------------------------------

#[test]
fn p2_is_tight_on_smooth_synthetic_distributions() {
    let mut rng = Rng::new(3);
    // Uniform [0, 1): P99 = 0.99.
    let mut p2 = P2Quantile::new(0.99);
    let mut xs = Vec::with_capacity(50_000);
    for _ in 0..50_000 {
        let x = rng.f64();
        p2.push(x);
        xs.push(x);
    }
    let exact = exact_p99(&xs);
    assert!(
        (p2.value() - exact).abs() / exact < 0.05,
        "uniform: p2 {} vs exact {exact}",
        p2.value()
    );
    // Exponential: heavier tail, still within 10%.
    let mut p2 = P2Quantile::new(0.99);
    let mut xs = Vec::with_capacity(100_000);
    for _ in 0..100_000 {
        let x = rng.exp(2.0);
        p2.push(x);
        xs.push(x);
    }
    let exact = exact_p99(&xs);
    assert!(
        (p2.value() - exact).abs() / exact < 0.10,
        "exponential: p2 {} vs exact {exact}",
        p2.value()
    );
    // Median on the uniform stream, as a second quantile sanity point.
    let mut p50 = P2Quantile::new(0.5);
    for &x in &xs {
        p50.push(x);
    }
    let exact50 = percentile(&xs, 0.5);
    assert!((p50.value() - exact50).abs() / exact50 < 0.05);
}

#[test]
fn p2_small_counts_are_exact_and_reset_reuses() {
    let mut p2 = P2Quantile::new(0.99);
    assert!(p2.is_empty());
    assert_eq!(p2.value(), 0.0);
    for &x in &[5.0, 1.0, 3.0] {
        p2.push(x);
    }
    // n <= 5: exact interpolated percentile of {1, 3, 5}.
    assert_eq!(p2.value(), percentile(&[5.0, 1.0, 3.0], 0.99));
    p2.reset();
    assert!(p2.is_empty());
    for &x in &[2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0] {
        p2.push(x);
    }
    assert_eq!(p2.value(), 2.0, "degenerate stream must stay exact");
}

#[test]
fn p2_epoch_p99_within_bounds_on_all_traces() {
    // Epoch-sized chunks of real DES TTFT streams (the exact shape the
    // autoscale digests see): the P² estimate must stay within a 25%
    // relative / 100 ms absolute envelope of the exact sort, per chunk.
    let g = GpuProfile::a100_llama70b();
    for (wi, w) in traces::all().iter().enumerate() {
        let reqs: Vec<SimRequest> = generate_trace(w, 400.0, 24_000, 0x99 + wi as u64)
            .iter()
            .map(|r| SimRequest {
                arrival_s: r.arrival_s,
                l_in: r.l_in,
                l_out: r.l_out,
            })
            .collect();
        // Size for moderate load from the trace's own occupancy.
        let n_slots = 64u32;
        let occ = fleetopt::fleetsim::mean_occupancy_s(&reqs, &g, n_slots);
        let n_gpus = (400.0 * occ / (n_slots as f64 * 0.7)).ceil() as u64;
        let cfg = SimConfig::new(g.clone(), n_gpus, n_slots);
        let res = simulate_pool(&cfg, &reqs);
        let stream = res.ttft.values();
        assert!(stream.len() > 10_000, "{}: thin TTFT stream", w.name);
        for (ci, chunk) in stream.chunks(2_000).enumerate() {
            if chunk.len() < 100 {
                continue;
            }
            let mut p2 = P2Quantile::new(0.99);
            for &x in chunk {
                p2.push(x);
            }
            let exact = exact_p99(chunk);
            let err = (p2.value() - exact).abs();
            assert!(
                err <= (0.25 * exact).max(0.1),
                "{} chunk {ci}: p2 {} vs exact {exact} (err {err})",
                w.name,
                p2.value()
            );
        }
    }
}
