//! The online control loop's regression gate.
//!
//! * **Stationary projection is exact**: every nonstationary arrival
//!   process with a constant rate function reproduces the stationary
//!   Poisson stream bit-for-bit (same RNG consumption, same arithmetic),
//!   trace generation included; and the autoscale DES with a constant
//!   rate routes each request to the same tier as `route_trace_tiered`.
//! * **Conservation**: autoscale-down draining never loses or duplicates
//!   a request — every generated request completes exactly once.
//! * **Hysteresis**: the dead-band holds small dips, scale-up is
//!   immediate, the switching cost pins the layout.
//! * **Censoring**: truncated or unprovisioned simulations account for
//!   every request instead of silently dropping it from the percentiles.

use fleetopt::config::PlannerConfig;
use fleetopt::fleetsim::{
    route_trace_tiered, simulate_autoscale, simulate_autoscale_chaos, simulate_fleet_tiered,
    AutoscaleConfig, ChaosOpts,
};
use fleetopt::planner::{plan_spec_sweep_gamma, plan_tiers, PlanInput, ReplanConfig};
use fleetopt::workload::arrivals::{
    generate_trace, generate_trace_arrivals, ArrivalProcess, NonstationaryArrivals,
    PoissonArrivals, RateModel,
};
use fleetopt::workload::online::OnlineEstimator;
use fleetopt::workload::traces;

fn fast_input(lambda: f64) -> PlanInput {
    let mut i = PlanInput::new(traces::azure(), lambda);
    i.cfg = PlannerConfig {
        mc_samples: 8_000,
        ..PlannerConfig::default()
    };
    i
}

/// Every constant-rate instance of every nonstationary process family.
fn constant_models(lambda: f64) -> Vec<(&'static str, RateModel)> {
    vec![
        ("constant", RateModel::Constant(lambda)),
        ("schedule", RateModel::Schedule(vec![(0.0, lambda)])),
        (
            "diurnal-amp0",
            RateModel::Diurnal {
                base: lambda,
                amp: 0.0,
                period_s: 600.0,
                phase: 0.0,
            },
        ),
        (
            "mmpp-equal",
            RateModel::Mmpp {
                rates: [lambda, lambda],
                mean_sojourn_s: [5.0, 5.0],
            },
        ),
    ]
}

#[test]
fn constant_rate_processes_are_bitwise_poisson() {
    let lambda = 250.0;
    for seed in [1u64, 42, 0xF1EE7] {
        let reference: Vec<u64> = PoissonArrivals::new(lambda, seed)
            .take(20_000)
            .map(f64::to_bits)
            .collect();
        for (name, model) in constant_models(lambda) {
            let mut p = NonstationaryArrivals::new(model, seed);
            for (i, &want) in reference.iter().enumerate() {
                let got = p.next_arrival().to_bits();
                assert_eq!(got, want, "{name} seed {seed} diverges at arrival {i}");
            }
        }
    }
}

#[test]
fn constant_rate_trace_generation_is_bitwise_identical() {
    let w = traces::agent_heavy();
    let reference = generate_trace(&w, 120.0, 5_000, 9);
    for (name, model) in constant_models(120.0) {
        let mut p = NonstationaryArrivals::new(model, 9);
        let trace = generate_trace_arrivals(&w, &mut p, 5_000, 9);
        for (a, b) in reference.iter().zip(&trace) {
            assert_eq!(a.l_total, b.l_total, "{name}: lengths diverge");
            assert_eq!(
                a.arrival_s.to_bits(),
                b.arrival_s.to_bits(),
                "{name}: arrivals diverge"
            );
            assert_eq!(a.category, b.category, "{name}: categories diverge");
            assert_eq!(a.l_out, b.l_out, "{name}: outputs diverge");
        }
    }
}

#[test]
fn autoscale_routes_bitwise_like_route_trace_tiered_when_static() {
    // Constant rate, controller off: the autoscale DES must route every
    // request to the same tier as the offline router (same seeds, same
    // boundaries/gammas) — per-tier arrival totals match exactly.
    let lambda = 300.0;
    let n = 8_000;
    let seed = 11;
    let input = fast_input(lambda);
    let spec = input.gpu.fleet_spec(&[4096]);
    let plan = plan_spec_sweep_gamma(&input, &spec).unwrap();
    let boundaries = plan.boundaries();
    let gammas = plan.gammas.clone();

    let cfg = AutoscaleConfig {
        replanning: false,
        ..AutoscaleConfig::default()
    };
    let rep = simulate_autoscale(
        &input.workload,
        RateModel::Constant(lambda),
        n,
        &input,
        plan,
        &cfg,
        seed,
    );
    let routed = route_trace_tiered(&input.workload, lambda, n, &boundaries, &gammas, seed);

    let per_tier: Vec<u64> = (0..routed.tiers.len())
        .map(|ti| {
            rep.epochs
                .iter()
                .map(|e| e.tiers[ti].arrivals)
                .sum::<u64>()
        })
        .collect();
    let expect: Vec<u64> = routed.tiers.iter().map(|t| t.len() as u64).collect();
    assert_eq!(per_tier, expect, "per-tier routing diverged");
    assert_eq!(rep.n_compressed, routed.n_compressed());
    assert_eq!(rep.completed, n as u64);
}

#[test]
fn autoscale_drain_conserves_every_request() {
    // A hard step down (400 -> 120 req/s) forces a deep scale-down with
    // draining; every request must complete exactly once (the simulator
    // asserts against duplicates internally).
    let input = fast_input(400.0);
    let spec = input.gpu.fleet_spec(&[4096]);
    let plan = plan_spec_sweep_gamma(&input, &spec).unwrap();
    let model = RateModel::Schedule(vec![(0.0, 400.0), (25.0, 120.0)]);
    let cfg = AutoscaleConfig {
        epoch_s: 8.0,
        window_s: 16.0,
        provision_delay_s: 4.0,
        ..AutoscaleConfig::default()
    };
    let n = 15_000;
    let rep = simulate_autoscale(&input.workload, model, n, &input, plan.clone(), &cfg, 3);
    assert_eq!(rep.completed, n as u64, "lost requests");
    assert_eq!(rep.censored, 0);
    assert!(rep.epochs.len() >= 4, "expected several epochs");
    // The controller actually scaled down after the step.
    let first = rep.epochs.first().unwrap().total_gpus();
    let last = rep.epochs.last().unwrap().total_gpus();
    assert!(
        last < first,
        "no scale-down: first epoch {first} GPUs, last {last}"
    );
    // Conservation also holds per tier: arrivals == completions overall.
    for ti in 0..plan.k() {
        let arr: u64 = rep.epochs.iter().map(|e| e.tiers[ti].arrivals).sum();
        let done: u64 = rep.epochs.iter().map(|e| e.tiers[ti].completed).sum();
        assert_eq!(arr, done, "tier {ti} unbalanced");
    }
}

#[test]
fn autoscale_beats_static_peak_on_a_step_down() {
    // Static provisioning for the peak pays for the trough; the control
    // loop must realize a strictly smaller bill on a declining schedule.
    let input_peak = fast_input(400.0);
    let spec = input_peak.gpu.fleet_spec(&[4096]);
    let static_plan = plan_spec_sweep_gamma(&input_peak, &spec).unwrap();
    let model = RateModel::Schedule(vec![(0.0, 400.0), (20.0, 100.0)]);
    let n = 12_000;
    let cfg_auto = AutoscaleConfig {
        epoch_s: 6.0,
        window_s: 12.0,
        provision_delay_s: 3.0,
        ..AutoscaleConfig::default()
    };
    let mut cfg_static = cfg_auto.clone();
    cfg_static.replanning = false;

    let rep_static = simulate_autoscale(
        &input_peak.workload,
        model.clone(),
        n,
        &input_peak,
        static_plan.clone(),
        &cfg_static,
        5,
    );
    let rep_auto = simulate_autoscale(
        &input_peak.workload,
        model,
        n,
        &input_peak,
        static_plan,
        &cfg_auto,
        5,
    );
    assert_eq!(rep_auto.completed, n as u64);
    assert!(
        rep_auto.cost < rep_static.cost,
        "autoscale ${:.2} must beat static-peak ${:.2}",
        rep_auto.cost,
        rep_static.cost
    );
}

#[test]
fn clamped_schedule_increments_time_travel_events() {
    // The CLI rejects a negative --provision up front, but the chaos
    // entry point deliberately lets one through so the accounting is
    // testable: a scale-up then schedules Provision events in the past,
    // the event queue clamps them to "now", and `time_travel_events`
    // counts every clamp — the counter `fleetopt autoscale` (and the CI
    // autoscale smoke wrapping it) now fails hard on.
    let input = fast_input(150.0);
    let spec = input.gpu.fleet_spec(&[4096]);
    let plan = plan_spec_sweep_gamma(&input, &spec).unwrap();
    // A hard step up forces the controller to provision new GPUs mid-run.
    let model = RateModel::Schedule(vec![(0.0, 150.0), (15.0, 500.0)]);
    let cfg = AutoscaleConfig {
        epoch_s: 5.0,
        window_s: 10.0,
        provision_delay_s: -3.0,
        ..AutoscaleConfig::default()
    };
    let n = 10_000;
    let rep = simulate_autoscale_chaos(
        &input.workload,
        model.clone(),
        n,
        &input,
        plan.clone(),
        &cfg,
        9,
        &ChaosOpts::default(),
    );
    assert!(
        rep.time_travel_events > 0,
        "negative provisioning delay never produced a clamped event"
    );
    assert_eq!(rep.completed, n as u64, "clamping must not lose requests");

    // The same scenario with a sane delay clamps nothing.
    let cfg_ok = AutoscaleConfig {
        provision_delay_s: 2.5,
        ..cfg
    };
    let rep_ok = simulate_autoscale(&input.workload, model, n, &input, plan, &cfg_ok, 9);
    assert_eq!(rep_ok.time_travel_events, 0, "sane schedule must not clamp");
    assert_eq!(rep_ok.completed, n as u64);
}

#[test]
fn online_estimator_feeds_a_plannable_snapshot() {
    let w = traces::azure();
    let mut est = OnlineEstimator::new(30.0);
    let mut arr = NonstationaryArrivals::new(RateModel::Constant(200.0), 21);
    let trace = generate_trace_arrivals(&w, &mut arr, 6_000, 21);
    let mut now = 0.0;
    for r in &trace {
        est.observe(r.arrival_s, r.l_total);
        now = r.arrival_s;
    }
    let rate = est.rate(now);
    assert!((rate - 200.0).abs() / 200.0 < 0.15, "rate estimate {rate}");
    // The snapshot must plan end-to-end through the real planner.
    let snap = est.snapshot(&w).expect("snapshot");
    let mut input = PlanInput::new(snap, rate);
    input.cfg.mc_samples = 8_000;
    let spec = input.gpu.fleet_spec(&[4096]);
    let plan = plan_spec_sweep_gamma(&input, &spec).expect("snapshot must be plannable");
    assert!(plan.total_gpus() > 0);
}

#[test]
fn replan_hysteresis_composes_with_per_tier_slo() {
    // A per-tier SLO set to the fleet default must leave the whole replan
    // trajectory identical (spelled-out defaults change nothing).
    let input = fast_input(800.0);
    let spec = input.gpu.fleet_spec(&[4096]);
    let mut explicit = spec.clone();
    for t in &mut explicit.tiers {
        t.p99_ttft_s = Some(input.slo.p99_ttft_s);
    }
    let a = plan_tiers(&input, &spec, &[1.5], true, None).unwrap();
    let b = plan_tiers(&input, &explicit, &[1.5], true, None).unwrap();
    assert_eq!(a.gpu_counts(), b.gpu_counts());
    assert_eq!(a.cost_yr.to_bits(), b.cost_yr.to_bits());

    // The b-side replanner carries the explicit-SLO spec in its current
    // plan; re-planning at the same inputs must track the default-spec
    // trajectory exactly.
    let mut rp_a = fleetopt::planner::Replanner::new(ReplanConfig::default(), a);
    let mut rp_b = fleetopt::planner::Replanner::new(ReplanConfig::default(), b);
    for lam in [600.0, 900.0, 1100.0] {
        let oa = rp_a.replan(&fast_input(lam)).unwrap();
        let ob = rp_b.replan(&fast_input(lam)).unwrap();
        assert_eq!(oa.plan.gpu_counts(), ob.plan.gpu_counts(), "lam {lam}");
        assert_eq!(oa.switched_layout, ob.switched_layout);
    }
}

#[test]
fn tiered_sim_censors_unprovisioned_tiers_instead_of_dropping() {
    // A fully drained tiered simulation censors nothing...
    let input = fast_input(300.0);
    let spec = input.gpu.fleet_spec(&[4096]);
    let plan = plan_spec_sweep_gamma(&input, &spec).unwrap();
    let sim = simulate_fleet_tiered(&input.workload, &plan, &input.gpu, 300.0, 4_000, 13);
    assert_eq!(sim.censored, vec![0, 0]);
    assert_eq!(sim.censored_total(), 0);
    // ...and a zero-GPU tier with routed traffic is censored in full, not
    // silently dropped from the percentile population.
    let mut starved = plan.clone();
    starved.tiers[1].n_gpus = 0;
    let sim = simulate_fleet_tiered(&input.workload, &starved, &input.gpu, 300.0, 4_000, 13);
    assert!(sim.tiers[1].is_none());
    assert!(sim.censored[1] > 0);
    assert_eq!(sim.censored[1], sim.routed.tiers[1].len() as u64);
    let total: u64 = sim
        .tiers
        .iter()
        .flatten()
        .map(|r| r.completed)
        .sum::<u64>()
        + sim.censored_total();
    assert_eq!(total, 4_000);
}

#[test]
fn diurnal_autoscale_tracks_load_with_bounded_slo_misses() {
    // The smoke-level acceptance: on a diurnal trace the control loop
    // keeps completing everything, spends less than the static peak
    // fleet, and its per-epoch GPU counts actually move with the wave.
    let base = 300.0;
    let model = RateModel::Diurnal {
        base,
        amp: 0.6,
        period_s: 40.0,
        phase: 0.0,
    };
    let input_peak = fast_input(model.peak_rate());
    let spec = input_peak.gpu.fleet_spec(&[4096]);
    let static_plan = plan_spec_sweep_gamma(&input_peak, &spec).unwrap();
    let input0 = fast_input(model.rate_hint());
    let init = plan_spec_sweep_gamma(&input0, &spec).unwrap();
    let n = 24_000; // ~80 s at the mean rate: two full periods
    let cfg = AutoscaleConfig {
        epoch_s: 5.0,
        window_s: 10.0,
        provision_delay_s: 2.5,
        ..AutoscaleConfig::default()
    };
    let rep = simulate_autoscale(&input0.workload, model.clone(), n, &input0, init, &cfg, 17);
    assert_eq!(rep.completed, n as u64);
    assert!(rep.epochs.len() >= 10);
    // GPU counts must vary with the wave (not a frozen fleet).
    let counts: Vec<u64> = rep.epochs.iter().map(|e| e.total_gpus()).collect();
    let lo = counts.iter().min().unwrap();
    let hi = counts.iter().max().unwrap();
    assert!(hi > lo, "autoscaler never moved: {counts:?}");
    // And the realized bill undercuts always-on peak provisioning.
    let mut cfg_static = cfg;
    cfg_static.replanning = false;
    let rep_static = simulate_autoscale(
        &input_peak.workload,
        model,
        n,
        &input_peak,
        static_plan,
        &cfg_static,
        17,
    );
    assert!(
        rep.cost < rep_static.cost * 1.02,
        "autoscale ${:.2} vs static-peak ${:.2}",
        rep.cost,
        rep_static.cost
    );
}
