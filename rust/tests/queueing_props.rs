//! Property tests on the analytical core: Erlang-C/Kimura invariants,
//! sizing monotonicity, Little's-law consistency between the service model
//! and the DES, and stability boundaries.

use fleetopt::config::GpuProfile;
use fleetopt::fleetsim::sim::{simulate_pool, SimConfig, SimRequest};
use fleetopt::planner::sizing::{continuous_gpus, min_gpus};
use fleetopt::queueing::erlang::{erlang_c, erlang_c_logspace};
use fleetopt::queueing::kimura::{w99, w_mean};
use fleetopt::queueing::service::{calibrate, slot_iterations};
use fleetopt::util::check::{ensure, forall};
use fleetopt::util::rng::Rng;
use fleetopt::workload::cdf::{AnchoredCdf, LengthDist};
use fleetopt::workload::request::OutputModel;

#[test]
fn erlang_probability_bounds() {
    forall(
        "erlang-in-unit-interval",
        300,
        |rng| (rng.range(1, 5_000) as u64, rng.uniform(0.01, 0.999)),
        |&(c, rho)| {
            let v = erlang_c(c, rho);
            ensure(
                (0.0..=1.0).contains(&v) && v.is_finite(),
                format!("C({c},{rho}) = {v}"),
            )
        },
    );
}

#[test]
fn erlang_recurrence_agrees_with_logspace() {
    forall(
        "erlang-two-impls",
        60,
        |rng| (rng.range(1, 2_000) as u64, rng.uniform(0.05, 0.99)),
        |&(c, rho)| {
            let a = erlang_c(c, rho);
            let b = erlang_c_logspace(c, rho);
            ensure(
                (a - b).abs() <= 1e-8 * (1.0 + b),
                format!("C({c},{rho}): {a} vs {b}"),
            )
        },
    );
}

#[test]
fn erlang_monotone_in_rho_property() {
    forall(
        "erlang-monotone-rho",
        100,
        |rng| {
            let c = rng.range(1, 500) as u64;
            let r1 = rng.uniform(0.01, 0.95);
            let r2 = rng.uniform(0.01, 0.95);
            (c, r1.min(r2), r1.max(r2))
        },
        |&(c, lo, hi)| {
            ensure(
                erlang_c(c, lo) <= erlang_c(c, hi) + 1e-12,
                "C must be monotone in rho",
            )
        },
    );
}

#[test]
fn kimura_wait_nonnegative_and_stability() {
    forall(
        "kimura-nonneg",
        200,
        |rng| {
            let c = rng.range(1, 1_000) as u64;
            let mu = rng.uniform(0.01, 10.0);
            let rho = rng.uniform(0.01, 1.3); // includes unstable region
            let cs2 = rng.uniform(0.0, 8.0);
            (c, mu, rho, cs2)
        },
        |&(c, mu, rho, cs2)| {
            let lambda = rho * c as f64 * mu;
            let w = w99(c, mu, lambda, cs2);
            if rho >= 1.0 {
                ensure(w.is_infinite(), "unstable queue must have infinite W99")
            } else {
                ensure(w >= 0.0 && w.is_finite(), format!("W99 = {w}"))
            }
        },
    );
}

#[test]
fn mean_wait_below_p99_wait() {
    forall(
        "mean-below-p99",
        100,
        |rng| {
            let c = rng.range(1, 50) as u64;
            let mu = 1.0;
            let rho = rng.uniform(0.5, 0.99);
            let cs2 = rng.uniform(0.1, 4.0);
            (c, mu, rho * c as f64 * mu, cs2)
        },
        |&(c, mu, lambda, cs2)| {
            let mean = w_mean(c, mu, lambda, cs2);
            let p99 = w99(c, mu, lambda, cs2);
            if p99 == 0.0 {
                // Many-server regime: <1% of arrivals wait at all, so the
                // P99 is exactly 0 while the mean can be tiny-positive.
                return ensure(mean < 0.5 / mu, format!("mean {mean} too big for W99=0"));
            }
            // ln(x/0.01) >= x on (0, 1], so the tail quantile dominates.
            ensure(p99 >= mean * 0.99, format!("p99 {p99} < mean {mean}"))
        },
    );
}

#[test]
fn sizing_monotone_in_lambda() {
    let g = GpuProfile::a100_llama70b();
    let dist = AnchoredCdf::new(vec![(64.0, 0.0), (2048.0, 0.8), (16384.0, 1.0)]);
    let out = OutputModel {
        frac: 0.15,
        sigma: 0.3,
        min_tokens: 16,
        max_tokens: 2048,
    };
    let svc = calibrate(&dist, &out, &g, 16, 8_000, 1);
    let mut last = 0u64;
    for lambda in [10.0, 50.0, 100.0, 500.0, 1000.0] {
        let n = min_gpus(lambda, &svc, 0.5, 0.85, false).unwrap();
        assert!(n >= last, "n must not shrink as lambda grows");
        last = n;
    }
}

#[test]
fn integer_sizing_close_to_continuous() {
    let g = GpuProfile::a100_llama70b();
    let dist = AnchoredCdf::new(vec![(64.0, 0.0), (4096.0, 1.0)]);
    let out = OutputModel {
        frac: 0.1,
        sigma: 0.2,
        min_tokens: 16,
        max_tokens: 1024,
    };
    let svc = calibrate(&dist, &out, &g, 64, 8_000, 2);
    forall(
        "integer-vs-continuous-sizing",
        30,
        |rng| rng.uniform(50.0, 3_000.0),
        |&lambda| {
            let n = min_gpus(lambda, &svc, 0.5, 0.85, false).unwrap() as f64;
            let c = continuous_gpus(lambda, &svc, 0.85);
            ensure(
                n >= c - 1e-9 && n <= c + 2.0,
                format!("integer {n} vs continuous {c}"),
            )
        },
    );
}

#[test]
fn slot_iterations_additive_and_monotone() {
    forall(
        "slot-iterations",
        300,
        |rng| {
            (
                rng.range(1, 100_000) as u32,
                rng.range(1, 4_096) as u32,
                *rng.choice(&[128u32, 256, 512, 1024]),
            )
        },
        |&(l_in, l_out, chunk)| {
            let it = slot_iterations(l_in, l_out, chunk);
            let more_in = slot_iterations(l_in + chunk, l_out, chunk);
            let more_out = slot_iterations(l_in, l_out + 1, chunk);
            ensure(
                more_in == it + 1 && more_out == it + 1 && it >= 2,
                format!("iters {it} / {more_in} / {more_out}"),
            )
        },
    );
}

#[test]
fn des_littles_law_holds() {
    // L = lambda * W: mean busy slots equals arrival rate times mean slot
    // occupancy (measured through utilization * slots).
    let g = GpuProfile::a100_llama70b();
    let t_iter = g.t_iter_s(16);
    let (l_in, l_out) = (1024u32, 148u32); // 150 iterations
    let e_s = 150.0 * t_iter;
    let lambda = 15.0;
    let n_gpus = 8u64;
    let mut rng = Rng::new(11);
    let mut t = 0.0;
    let reqs: Vec<SimRequest> = (0..40_000)
        .map(|_| {
            t += rng.exp(lambda);
            SimRequest { arrival_s: t, l_in, l_out }
        })
        .collect();
    let mut cfg = SimConfig::new(g, n_gpus, 16);
    cfg.warmup_s = 3.0 * e_s;
    let res = simulate_pool(&cfg, &reqs);
    let mean_busy_slots = res.utilization * (n_gpus * 16) as f64;
    let littles = lambda * e_s;
    assert!(
        (mean_busy_slots - littles).abs() / littles < 0.02,
        "L = {mean_busy_slots} vs lambda*W = {littles}"
    );
}

#[test]
fn calibration_scv_reflects_dispersion() {
    // A wider length distribution must produce a larger C_s^2.
    let g = GpuProfile::a100_llama70b();
    let out = OutputModel {
        frac: 0.15,
        sigma: 0.0,
        min_tokens: 1,
        max_tokens: 1 << 20,
    };
    let narrow = AnchoredCdf::new(vec![(1000.0, 0.0), (1100.0, 1.0)]);
    let wide = AnchoredCdf::new(vec![(64.0, 0.0), (65536.0, 1.0)]);
    let s_narrow = calibrate(&narrow, &out, &g, 16, 10_000, 3);
    let s_wide = calibrate(&wide, &out, &g, 16, 10_000, 3);
    assert!(s_wide.scv > s_narrow.scv * 5.0);
}

#[test]
fn truncation_mean_bracketing() {
    // E[X | a < X <= b] lies in (a, b]; used throughout the recalibration.
    forall(
        "truncated-mean-bracket",
        50,
        |rng| {
            let lo = rng.uniform(100.0, 5_000.0);
            let hi = lo * rng.uniform(1.5, 10.0);
            (lo, hi)
        },
        |&(lo, hi)| {
            let cdf = AnchoredCdf::new(vec![(16.0, 0.0), (2048.0, 0.7), (65536.0, 1.0)]);
            if cdf.cdf(hi) - cdf.cdf(lo) < 1e-6 {
                return Ok(());
            }
            let t = fleetopt::workload::cdf::TruncatedDist::new(cdf, lo, hi);
            let m = t.mean();
            ensure(m > lo && m <= hi, format!("mean {m} outside ({lo}, {hi}]"))
        },
    );
}
