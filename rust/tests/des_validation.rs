//! Integration: the analytical model vs inference-fleet-sim (the paper's
//! §7.4 validation, scaled for CI speed), plus DES behavioral invariants
//! and failure injection (overload, bursty arrivals, degenerate shapes).

use fleetopt::config::{GpuProfile, PlannerConfig};
use fleetopt::experiments::table5_validate;
use fleetopt::fleetsim::sim::{simulate_pool, SimConfig, SimRequest};
use fleetopt::planner::{plan_fleet, PlanInput};
use fleetopt::util::rng::Rng;
use fleetopt::workload::traces;

fn poisson(lambda: f64, n: usize, l_in: u32, l_out: u32, seed: u64) -> Vec<SimRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exp(lambda);
            SimRequest { arrival_s: t, l_in, l_out }
        })
        .collect()
}

#[test]
fn analytical_within_3pct_of_des_all_workloads() {
    // The paper's headline validation (Table 5), run at reduced volume:
    // every pool's analytical utilization within 3% of the DES.
    for (i, w) in traces::all().iter().enumerate() {
        let (rows, _) = table5_validate(w, 1000.0, 12_000, 100 + i as u64);
        assert_eq!(rows.len(), 2, "{}: expected two pools", w.name);
        for r in rows {
            assert!(
                r.error.abs() <= 0.03,
                "{} {} pool: ana {:.3} vs des {:.3} (err {:+.1}%)",
                r.workload,
                r.pool,
                r.rho_ana,
                r.rho_des,
                r.error * 100.0
            );
        }
    }
}

#[test]
fn des_matches_mm_c_mean_wait() {
    // Single-slot GPUs + exponential-ish service => M/G/c sanity: measured
    // waits shrink as capacity grows, and utilization tracks lambda*E[S]/c.
    let g = GpuProfile::a100_llama70b();
    let t_iter = g.t_iter_s(16);
    let e_s = 100.0 * t_iter;
    for n_gpus in [2u64, 4] {
        let c = n_gpus as f64 * 16.0;
        let lambda = 0.7 * c / e_s;
        let reqs = poisson(lambda, 30_000, 1024, 98, 7);
        let mut cfg = SimConfig::new(g.clone(), n_gpus, 16);
        cfg.warmup_s = 3.0 * e_s;
        let res = simulate_pool(&cfg, &reqs);
        assert!(
            (res.utilization - 0.7).abs() < 0.02,
            "n={n_gpus}: rho {}",
            res.utilization
        );
    }
}

#[test]
fn overload_degrades_gracefully() {
    // Failure injection: 2x overload must not panic, lose requests, or
    // produce nonsense metrics — it saturates and queues grow.
    let g = GpuProfile::a100_llama70b();
    let reqs = poisson(100.0, 5_000, 2048, 50, 9);
    let res = simulate_pool(&SimConfig::new(g, 1, 16), &reqs);
    assert_eq!(res.completed, 5_000);
    assert!(res.utilization > 0.95);
}

#[test]
fn burst_arrivals_handled() {
    // All requests arrive at t=0 (worst-case burst).
    let g = GpuProfile::a100_llama70b();
    let reqs: Vec<SimRequest> = (0..500)
        .map(|_| SimRequest { arrival_s: 0.0, l_in: 512, l_out: 20 })
        .collect();
    let res = simulate_pool(&SimConfig::new(g, 2, 16), &reqs);
    assert_eq!(res.completed, 500);
}

#[test]
fn degenerate_requests_complete() {
    // Zero-ish inputs and outputs must not wedge the simulator.
    let g = GpuProfile::a100_llama70b();
    let reqs = vec![
        SimRequest { arrival_s: 0.0, l_in: 1, l_out: 1 },
        SimRequest { arrival_s: 0.1, l_in: 0, l_out: 1 },
        SimRequest { arrival_s: 0.2, l_in: 65_536, l_out: 1 },
    ];
    let res = simulate_pool(&SimConfig::new(g, 1, 4), &reqs);
    assert_eq!(res.completed, 3);
}

#[test]
fn des_deterministic_across_runs() {
    let w = traces::azure();
    let mut input = PlanInput::new(w.clone(), 500.0);
    input.cfg = PlannerConfig { mc_samples: 4_000, ..Default::default() };
    let plan = plan_fleet(&input, w.b_short, 1.0).unwrap();
    let g = input.gpu.clone();
    let a = fleetopt::fleetsim::simulate_fleet(&w, &plan, &g, 500.0, 10_000, 77);
    let b = fleetopt::fleetsim::simulate_fleet(&w, &plan, &g, 500.0, 10_000, 77);
    assert_eq!(
        a.short.as_ref().unwrap().utilization,
        b.short.as_ref().unwrap().utilization
    );
    assert_eq!(
        a.long.as_ref().unwrap().completed,
        b.long.as_ref().unwrap().completed
    );
}

#[test]
fn occupancy_mode_is_faster_or_equal() {
    // Ablation: occupancy-dependent t_iter (Eq. 3 with n = busy) can only
    // speed iterations up relative to full-lockstep.
    let g = GpuProfile::a100_llama70b();
    let reqs = poisson(2.0, 500, 1024, 50, 13);
    let full = simulate_pool(&SimConfig::new(g.clone(), 1, 128), &reqs);
    let mut cfg = SimConfig::new(g, 1, 128);
    cfg.lockstep_full = false;
    let occ = simulate_pool(&cfg, &reqs);
    let (mut f, mut o) = (full.ttft, occ.ttft);
    assert!(o.p50() <= f.p50() + 1e-9);
}

#[test]
fn cr_routing_shifts_des_load() {
    // With C&R on (gamma 1.5), the DES long pool receives measurably fewer
    // requests than at gamma 1.0 — Eq. 1-2 at the simulation layer.
    let w = traces::azure();
    let r_plain = fleetopt::fleetsim::route_trace(&w, 1000.0, 30_000, 4096, 1.0, 5);
    let r_cr = fleetopt::fleetsim::route_trace(&w, 1000.0, 30_000, 4096, 1.5, 5);
    assert!(r_cr.long.len() < r_plain.long.len());
    let drop = (r_plain.long.len() - r_cr.long.len()) as f64 / 30_000.0;
    assert!((drop - 0.078).abs() < 0.01, "expected ~beta drop, got {drop}");
}
