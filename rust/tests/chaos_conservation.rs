//! Conservation under failure injection (chaos), the tentpole's safety
//! net (ISSUE 9 satellite):
//!
//! * **Exact accounting**: under seeded fault plans — replica crashes,
//!   spot preemptions, whole-tier outages — every generated request
//!   still completes exactly once, across all three traces and fleet
//!   sizes K ∈ {2, 3, 4}; every in-flight kill is exactly one retry.
//! * **Drain x crash interleavings**: a deep scale-down with faults
//!   firing never strands work or a GPU (the engines' internal
//!   dense-slab/idle-bitset debug asserts run under these tests too).
//! * **Inert plans are invisible**: a `FaultPlan` whose processes never
//!   fire leaves the autoscale DES bit-identical to a run with no chaos
//!   wired in at all — per-epoch metrics compared as serialized JSON.
//! * **Determinism**: the same plan and seed reproduce the same fault
//!   trace and the same per-epoch series, run to run.
//! * **Bounded retries** (ISSUE 10 satellite): with a crash-retry budget
//!   the conservation law extends to
//!   `completed + dropped_retries == n`; `--max-retries` unset (or large
//!   enough to never bind) stays bit-identical to the unbounded engine.

use fleetopt::config::PlannerConfig;
use fleetopt::fleetsim::{
    simulate_autoscale, simulate_autoscale_chaos, simulate_fleet_tiered_chaos, AutoscaleConfig,
    ChaosOpts, FaultPlan, ReplicaFaults, SpotFaults, TierOutage,
};
use fleetopt::metrics::EpochMetrics;
use fleetopt::planner::{plan_spec_sweep_gamma, PlanInput, TieredPlan};
use fleetopt::router::failover::FailoverConfig;
use fleetopt::workload::arrivals::RateModel;
use fleetopt::workload::traces::{self, Workload};

fn fast_input(w: &Workload, lambda: f64) -> PlanInput {
    let mut i = PlanInput::new(w.clone(), lambda);
    i.cfg = PlannerConfig {
        mc_samples: 8_000,
        ..PlannerConfig::default()
    };
    i
}

/// K-1 boundaries for a K-tier fleet (K in 2..=4).
fn boundaries_for(k: usize) -> &'static [u32] {
    match k {
        2 => &[4096],
        3 => &[2048, 16384],
        4 => &[1024, 4096, 16384],
        _ => unreachable!("tests cover K in 2..=4"),
    }
}

fn plan_for(input: &PlanInput, k: usize) -> TieredPlan {
    let spec = input.gpu.fleet_spec(boundaries_for(k));
    plan_spec_sweep_gamma(input, &spec).expect("plan")
}

/// A fault plan that genuinely fires at test scale: per-replica crashes
/// every ~horizon/2, spot preemptions on preemptible SKUs, and one
/// outage window on the named tier.
fn stormy_plan(horizon_s: f64, outage_tier: usize, seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        replica: Some(ReplicaFaults {
            mtbf_s: horizon_s / 2.0,
            mttr_s: horizon_s / 40.0,
        }),
        spot: Some(SpotFaults {
            mtbp_s: horizon_s,
            mttr_s: horizon_s / 30.0,
        }),
        outages: vec![TierOutage {
            tier: outage_tier,
            start_s: horizon_s * 0.4,
            duration_s: horizon_s * 0.1,
        }],
    }
}

#[test]
fn autoscale_conserves_every_request_under_faults() {
    // Seeded fault plans x all three traces x K in {2,3,4}: exact
    // accounting — completed == n, zero censored, and the kill/retry
    // identity holds (every in-flight kill is requeued exactly once).
    let n = 4_000;
    let base = 300.0;
    let horizon = n as f64 / base;
    for (wi, w) in traces::all().iter().enumerate() {
        for k in 2..=4usize {
            let seed = 0xC0_05 + (wi * 8 + k) as u64;
            let input = fast_input(w, base);
            let plan = plan_for(&input, k);
            let model = RateModel::Diurnal {
                base,
                amp: 0.5,
                period_s: horizon,
                phase: 0.0,
            };
            let cfg = AutoscaleConfig {
                epoch_s: horizon / 10.0,
                window_s: horizon / 5.0,
                provision_delay_s: horizon / 20.0,
                ..AutoscaleConfig::default()
            };
            let chaos = ChaosOpts {
                faults: Some(stormy_plan(horizon, k - 1, seed)),
                failover: Some(FailoverConfig::default()),
            };
            let rep =
                simulate_autoscale_chaos(w, model, n, &input, plan, &cfg, seed, &chaos);
            let label = format!("{} K={k}", w.name);
            assert_eq!(rep.completed, n as u64, "{label}: lost requests");
            assert_eq!(rep.censored, 0, "{label}: censored under faults");
            assert!(
                rep.crashes + rep.preemptions > 0,
                "{label}: fault plan never fired"
            );
            assert_eq!(
                rep.retries_total, rep.killed_in_flight,
                "{label}: kill/retry identity broken"
            );
            assert_eq!(rep.time_travel_events, 0, "{label}: clamped events");
            // Per-tier flow balance over the epoch series: every arrival
            // into a tier completes in that tier.
            for ti in 0..k {
                let arr: u64 = rep.epochs.iter().map(|e| e.tiers[ti].arrivals).sum();
                let done: u64 = rep.epochs.iter().map(|e| e.tiers[ti].completed).sum();
                assert_eq!(arr, done, "{label}: tier {ti} unbalanced");
            }
        }
    }
}

#[test]
fn drain_and_crash_interleavings_never_strand_work() {
    // A hard step down forces deep draining exactly while crashes and an
    // outage are killing GPUs — the nastiest interleaving for the
    // dense-slab/idle-bitset bookkeeping. Everything must still drain.
    let w = traces::azure();
    let input = fast_input(&w, 400.0);
    let plan = plan_for(&input, 3);
    let n = 10_000;
    let horizon = 35.0; // ~400 req/s head, 120 req/s tail
    let model = RateModel::Schedule(vec![(0.0, 400.0), (horizon * 0.4, 120.0)]);
    let cfg = AutoscaleConfig {
        epoch_s: 4.0,
        window_s: 8.0,
        provision_delay_s: 2.0,
        ..AutoscaleConfig::default()
    };
    for seed in [3u64, 7, 0xBAD] {
        let chaos = ChaosOpts {
            faults: Some(stormy_plan(horizon, 0, seed)),
            failover: Some(FailoverConfig::default()),
        };
        let rep = simulate_autoscale_chaos(
            &w,
            model.clone(),
            n,
            &input,
            plan.clone(),
            &cfg,
            seed,
            &chaos,
        );
        assert_eq!(rep.completed, n as u64, "seed {seed}: lost requests");
        assert_eq!(rep.censored, 0, "seed {seed}");
        assert!(rep.crashes > 0, "seed {seed}: no crashes fired");
        assert_eq!(rep.retries_total, rep.killed_in_flight, "seed {seed}");
        // The controller did scale down through the chaos.
        let first = rep.epochs.first().unwrap().total_gpus();
        let last = rep.epochs.last().unwrap().total_gpus();
        assert!(last < first, "seed {seed}: no scale-down {first} -> {last}");
    }
}

#[test]
fn inert_fault_plan_is_bit_identical_to_no_chaos() {
    // A plan with no failure processes (and an outage aimed past the
    // fleet) schedules zero events: the chaos engine must reproduce the
    // plain autoscale run bit for bit, failover armed or not.
    let w = traces::lmsys();
    let input = fast_input(&w, 250.0);
    let plan = plan_for(&input, 2);
    let n = 6_000;
    let model = RateModel::Diurnal {
        base: 250.0,
        amp: 0.6,
        period_s: 24.0,
        phase: 0.0,
    };
    let cfg = AutoscaleConfig {
        epoch_s: 3.0,
        window_s: 6.0,
        provision_delay_s: 1.5,
        ..AutoscaleConfig::default()
    };
    let plain = simulate_autoscale(&w, model.clone(), n, &input, plan.clone(), &cfg, 23);
    let inert = ChaosOpts {
        faults: Some(FaultPlan {
            seed: 99,
            replica: None,
            spot: None,
            outages: vec![TierOutage {
                tier: 7, // past the K = 2 fleet: never scheduled
                start_s: 1.0,
                duration_s: 1.0,
            }],
        }),
        failover: Some(FailoverConfig::default()),
    };
    let chaos = simulate_autoscale_chaos(&w, model, n, &input, plan, &cfg, 23, &inert);
    assert_eq!(chaos.crashes, 0);
    assert_eq!(chaos.preemptions, 0);
    assert_eq!(chaos.killed_in_flight, 0);
    assert_eq!(chaos.spilled, 0);
    assert_eq!(plain.completed, chaos.completed);
    assert_eq!(plain.cost.to_bits(), chaos.cost.to_bits(), "cost diverged");
    assert_eq!(
        plain.gpu_hours.to_bits(),
        chaos.gpu_hours.to_bits(),
        "gpu-hours diverged"
    );
    assert_eq!(
        EpochMetrics::series_to_json(&plain.epochs),
        EpochMetrics::series_to_json(&chaos.epochs),
        "per-epoch series diverged"
    );
}

#[test]
fn chaos_runs_are_deterministic_per_seed() {
    let w = traces::agent_heavy();
    let input = fast_input(&w, 200.0);
    let plan = plan_for(&input, 2);
    let n = 5_000;
    let horizon = n as f64 / 200.0;
    let model = RateModel::Constant(200.0);
    let cfg = AutoscaleConfig {
        epoch_s: horizon / 8.0,
        window_s: horizon / 4.0,
        provision_delay_s: horizon / 16.0,
        ..AutoscaleConfig::default()
    };
    let chaos = ChaosOpts {
        faults: Some(stormy_plan(horizon, 1, 0xD5)),
        failover: Some(FailoverConfig::default()),
    };
    let a = simulate_autoscale_chaos(&w, model.clone(), n, &input, plan.clone(), &cfg, 6, &chaos);
    let b = simulate_autoscale_chaos(&w, model, n, &input, plan, &cfg, 6, &chaos);
    assert_eq!(a.crashes, b.crashes);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.killed_in_flight, b.killed_in_flight);
    assert_eq!(a.spilled, b.spilled);
    assert_eq!(a.cost.to_bits(), b.cost.to_bits());
    assert_eq!(
        EpochMetrics::series_to_json(&a.epochs),
        EpochMetrics::series_to_json(&b.epochs)
    );
}

#[test]
fn retry_budget_extends_conservation_to_dropped_requests() {
    // Budget 0: the first kill drops the request instead of requeueing
    // it. The books must still balance exactly — every request completes
    // or is dropped, never both, never neither — and the kill/retry
    // identity survives (the attempt is counted even when the budget
    // refuses the requeue).
    let w = traces::azure();
    let base = 300.0;
    let n = 5_000;
    let horizon = n as f64 / base;
    let input = fast_input(&w, base);
    let plan = plan_for(&input, 2);
    let model = RateModel::Constant(base);
    let base_cfg = AutoscaleConfig {
        epoch_s: horizon / 10.0,
        window_s: horizon / 5.0,
        provision_delay_s: horizon / 20.0,
        ..AutoscaleConfig::default()
    };
    let chaos = ChaosOpts {
        faults: Some(stormy_plan(horizon, 1, 0x5EED)),
        failover: Some(FailoverConfig::default()),
    };
    let run = |max_retries: Option<u32>| {
        let cfg = AutoscaleConfig {
            max_retries,
            ..base_cfg.clone()
        };
        simulate_autoscale_chaos(&w, model.clone(), n, &input, plan.clone(), &cfg, 13, &chaos)
    };
    let strict = run(Some(0));
    assert!(strict.dropped_retries > 0, "budget 0 never dropped a kill");
    assert_eq!(
        strict.completed + strict.dropped_retries,
        n as u64,
        "completed {} + dropped {} must cover the trace",
        strict.completed,
        strict.dropped_retries
    );
    assert_eq!(strict.censored, 0);
    assert_eq!(
        strict.retries_total, strict.killed_in_flight,
        "kill/retry identity must survive the budget"
    );
    // With budget 0 every kill is a drop: the two tallies coincide.
    assert_eq!(strict.dropped_retries, strict.killed_in_flight);

    // None (unbounded) and a budget too large to ever bind are the same
    // engine, bit for bit.
    let unbounded = run(None);
    let huge = run(Some(u32::MAX));
    assert_eq!(unbounded.dropped_retries, 0);
    assert_eq!(huge.dropped_retries, 0);
    assert_eq!(unbounded.completed, n as u64);
    assert_eq!(huge.completed, unbounded.completed);
    assert_eq!(huge.cost.to_bits(), unbounded.cost.to_bits());
    assert_eq!(
        EpochMetrics::series_to_json(&huge.epochs),
        EpochMetrics::series_to_json(&unbounded.epochs),
        "non-binding budget diverged from the unbounded engine"
    );
}

#[test]
fn pool_level_chaos_conserves_and_projects_per_tier() {
    // The offline tiered DES under the same plan shape: completions plus
    // censoring account for every routed request, and fault counters only
    // land on tiers the plan can actually touch.
    let w = traces::azure();
    let input = fast_input(&w, 300.0);
    let plan = plan_for(&input, 3);
    let n = 6_000;
    let horizon = n as f64 / 300.0;
    let faults = stormy_plan(horizon, 1, 0xF00D);
    let sim = simulate_fleet_tiered_chaos(&w, &plan, &input.gpu, 300.0, n, 21, &faults);
    let completed: u64 = sim.tiers.iter().flatten().map(|r| r.completed).sum();
    assert_eq!(completed + sim.censored_total(), n as u64);
    let crashes: u64 = sim.tiers.iter().flatten().map(|r| r.crashes).sum();
    assert!(crashes > 0, "pool-level fault plan never fired");
    // Default-profile tiers are not preemptible: the spot process must
    // not have produced a single preemption anywhere.
    let preempts: u64 = sim.tiers.iter().flatten().map(|r| r.preemptions).sum();
    assert_eq!(preempts, 0, "non-preemptible tiers saw spot preemptions");
    // The fault-free projection (default plan) is the verbatim path.
    let a = simulate_fleet_tiered_chaos(&w, &plan, &input.gpu, 300.0, n, 21, &FaultPlan::default());
    let b = simulate_fleet_tiered_chaos(&w, &plan, &input.gpu, 300.0, n, 21, &FaultPlan::default());
    for (ra, rb) in a.tiers.iter().zip(&b.tiers) {
        match (ra, rb) {
            (Some(ra), Some(rb)) => {
                assert_eq!(ra.completed, rb.completed);
                assert_eq!(ra.utilization.to_bits(), rb.utilization.to_bits());
            }
            (None, None) => {}
            _ => panic!("tier provisioning diverged between identical runs"),
        }
    }
}
