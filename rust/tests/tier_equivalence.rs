//! The K-tier refactor's regression gate: the generalized planner, DES
//! router, and fleet simulator must reproduce the pre-refactor two-pool
//! outputs **bit-identically** at K = 2 on all three evaluation workloads.
//!
//! The reference implementations below are verbatim transcriptions of the
//! pre-refactor `plan_cell` / `route_trace` / `simulate_fleet` logic,
//! written against public APIs only — the same role `SimilarityMode::
//! AllPairs` plays for the compressor (§Perf equivalence oracle). If a
//! future change to the tiered path alters any K = 2 result by even one
//! ULP, these tests fail.
//!
//! Also here: K = 3 structural properties (traffic conservation, no tier
//! overflow), the sweep_tiered(K=2) == sweep_full identity, the Table-8
//! acceptance check (K=3 <= K=2 on at least one trace), and the release-
//! mode K=3 sweep wall-clock bound.

use fleetopt::config::PlannerConfig;
use fleetopt::fleetsim::sim::{simulate_pool, SimConfig, SimRequest};
use fleetopt::fleetsim::{route_trace, simulate_fleet};
use fleetopt::planner::cost::fleet_cost_yr;
use fleetopt::planner::sizing::min_gpus;
use fleetopt::planner::{
    plan_fleet, plan_tiers, sweep_full, sweep_tiered, Plan, PlanInput, PoolPlan,
};
use fleetopt::queueing::service::calibrate_quadrature;
use fleetopt::util::rng::Rng;
use fleetopt::workload::arrivals::PoissonArrivals;
use fleetopt::workload::cdf::{LengthDist, TruncatedDist};
use fleetopt::workload::traces::{self, Workload};

fn fast_input(w: Workload, lambda: f64) -> PlanInput {
    let mut i = PlanInput::new(w, lambda);
    i.cfg = PlannerConfig {
        mc_samples: 8_000,
        ..PlannerConfig::default()
    };
    i
}

/// Verbatim pre-refactor two-pool planner cell (Algorithm 1, one (B,
/// gamma) point with long-pool recalibration), public API only.
fn reference_two_pool(input: &PlanInput, b_short: u32, gamma: f64) -> Plan {
    assert!(gamma >= 1.0);
    let w = &input.workload;
    let g = &input.gpu;
    let b = b_short as f64;
    let alpha = w.cdf.cdf(b);
    let beta = w.cdf.cdf(gamma * b) - alpha;
    let p_c = if gamma > 1.0 { w.p_c } else { 0.0 };
    let alpha_prime = alpha + beta * p_c;
    let lambda_s = alpha_prime * input.lambda;
    let lambda_l = input.lambda - lambda_s;

    let min_t = w.cdf.min_tokens();
    let max_t = w.cdf.max_tokens();
    let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
    let calib = |lo: f64, hi: f64, n_slots: u32| {
        let dist = TruncatedDist::new(w.cdf.clone(), lo, hi);
        calibrate_quadrature(&dist, &w.output, g, n_slots, len_points, 8)
    };

    let short = if lambda_s > 0.0 && alpha > 0.0 {
        let svc = calib(min_t, b.min(max_t), g.n_max(b_short));
        let n = min_gpus(
            lambda_s,
            &svc,
            input.slo.p99_ttft_s,
            input.cfg.rho_max,
            input.strict_slo,
        )
        .unwrap();
        (n, lambda_s, Some(svc))
    } else {
        (0, 0.0, None)
    };
    let long_cut = gamma * b;
    let long = if lambda_l > input.lambda * 1e-9 && w.cdf.cdf(long_cut) < 1.0 - 1e-12 {
        let svc = calib(long_cut.max(min_t), max_t, g.n_max_long());
        let n = min_gpus(
            lambda_l,
            &svc,
            input.slo.p99_ttft_s,
            input.cfg.rho_max,
            input.strict_slo,
        )
        .unwrap();
        (n, lambda_l, Some(svc))
    } else {
        (0, 0.0, None)
    };

    Plan {
        b_short,
        gamma,
        alpha,
        beta,
        alpha_prime,
        cost_yr: fleet_cost_yr(short.0, long.0, g),
        short: PoolPlan {
            n_gpus: short.0,
            lambda: short.1,
            svc: short.2,
        },
        long: PoolPlan {
            n_gpus: long.0,
            lambda: long.1,
            svc: long.2,
        },
    }
}

#[test]
fn k2_planner_bit_identical_to_reference_on_all_workloads() {
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        for (b, gamma) in [(w.b_short, 1.0), (w.b_short, 1.5), (w.b_short, 2.0), (2048, 1.3)] {
            let generalized = plan_fleet(&input, b, gamma).unwrap();
            let reference = reference_two_pool(&input, b, gamma);
            assert_eq!(generalized.short.n_gpus, reference.short.n_gpus, "{} B={b}", w.name);
            assert_eq!(generalized.long.n_gpus, reference.long.n_gpus, "{} B={b}", w.name);
            assert_eq!(
                generalized.short.lambda.to_bits(),
                reference.short.lambda.to_bits(),
                "{} B={b} gamma={gamma}: lambda_s",
                w.name
            );
            assert_eq!(
                generalized.long.lambda.to_bits(),
                reference.long.lambda.to_bits(),
                "{} B={b} gamma={gamma}: lambda_l",
                w.name
            );
            assert_eq!(
                generalized.cost_yr.to_bits(),
                reference.cost_yr.to_bits(),
                "{} B={b} gamma={gamma}: cost",
                w.name
            );
            assert_eq!(generalized.alpha.to_bits(), reference.alpha.to_bits());
            assert_eq!(generalized.beta.to_bits(), reference.beta.to_bits());
            assert_eq!(
                generalized.alpha_prime.to_bits(),
                reference.alpha_prime.to_bits()
            );
            // Calibrated service stats must match to the bit as well.
            for (got, want) in [
                (&generalized.short.svc, &reference.short.svc),
                (&generalized.long.svc, &reference.long.svc),
            ] {
                match (got, want) {
                    (Some(x), Some(y)) => {
                        assert_eq!(x.e_s.to_bits(), y.e_s.to_bits());
                        assert_eq!(x.scv.to_bits(), y.scv.to_bits());
                        assert_eq!(x.p99_prefill_s.to_bits(), y.p99_prefill_s.to_bits());
                        assert_eq!(x.t_iter_s.to_bits(), y.t_iter_s.to_bits());
                        assert_eq!(x.n_slots, y.n_slots);
                    }
                    (None, None) => {}
                    _ => panic!("svc presence mismatch"),
                }
            }
        }
    }
}

/// Verbatim pre-refactor DES router.
fn reference_route(
    w: &Workload,
    lambda: f64,
    n: usize,
    b_short: u32,
    gamma: f64,
    seed: u64,
) -> (Vec<SimRequest>, Vec<SimRequest>, u64) {
    let mut rng = Rng::new(seed ^ 0xF1EE7);
    let arrivals = PoissonArrivals::new(lambda, seed);
    let mut short = Vec::new();
    let mut long = Vec::new();
    let mut n_compressed = 0u64;
    for (i, t) in arrivals.take(n).enumerate() {
        let r = w.sample_request(i as u64, t, &mut rng);
        let band_hi = fleetopt::compress::gate::band_hi(b_short, gamma);
        if r.l_total <= b_short {
            short.push(SimRequest {
                arrival_s: t,
                l_in: r.l_in,
                l_out: r.l_out,
            });
        } else if r.l_total <= band_hi && r.category.compressible() && r.l_out < b_short {
            n_compressed += 1;
            short.push(SimRequest {
                arrival_s: t,
                l_in: b_short - r.l_out,
                l_out: r.l_out,
            });
        } else {
            long.push(SimRequest {
                arrival_s: t,
                l_in: r.l_in,
                l_out: r.l_out,
            });
        }
    }
    (short, long, n_compressed)
}

fn assert_trace_eq(a: &[SimRequest], b: &[SimRequest], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits(), "{label}[{i}]");
        assert_eq!(x.l_in, y.l_in, "{label}[{i}]");
        assert_eq!(x.l_out, y.l_out, "{label}[{i}]");
    }
}

#[test]
fn k2_route_trace_bit_identical_to_reference_on_all_workloads() {
    for (i, w) in traces::all().iter().enumerate() {
        for gamma in [1.0, 1.5] {
            let seed = 100 + i as u64;
            let (ref_short, ref_long, ref_comp) =
                reference_route(w, 1000.0, 20_000, w.b_short, gamma, seed);
            let routed = route_trace(w, 1000.0, 20_000, w.b_short, gamma, seed);
            assert_trace_eq(&routed.short, &ref_short, &format!("{} short", w.name));
            assert_trace_eq(&routed.long, &ref_long, &format!("{} long", w.name));
            assert_eq!(routed.n_compressed, ref_comp, "{}", w.name);
            assert_eq!(routed.n_total, 20_000);
        }
    }
}

#[test]
fn k2_fleet_des_bit_identical_to_reference() {
    // Pre-refactor simulate_fleet: route, then per-pool DES with 3x-E[S]
    // warm-up. The tiered path must reproduce utilization and completion
    // counts exactly (per-pool DES is deterministic given its trace).
    for (i, w) in traces::all().iter().enumerate() {
        let input = fast_input(w.clone(), 800.0);
        let plan = plan_fleet(&input, w.b_short, 1.0).unwrap();
        let g = input.gpu.clone();
        let seed = 200 + i as u64;
        let sim = simulate_fleet(w, &plan, &g, 800.0, 12_000, seed);

        let (ref_short, ref_long, _) = reference_route(w, 800.0, 12_000, w.b_short, 1.0, seed);
        let warm = |svc: &Option<fleetopt::queueing::service::ServiceStats>| {
            svc.as_ref().map(|s| 3.0 * s.e_s).unwrap_or(0.0)
        };
        let ref_s = (plan.short.n_gpus > 0 && !ref_short.is_empty()).then(|| {
            let mut cfg = SimConfig::new(g.clone(), plan.short.n_gpus, g.n_max(plan.b_short));
            cfg.warmup_s = warm(&plan.short.svc);
            simulate_pool(&cfg, &ref_short)
        });
        let ref_l = (plan.long.n_gpus > 0 && !ref_long.is_empty()).then(|| {
            let mut cfg = SimConfig::new(g.clone(), plan.long.n_gpus, g.n_max_long());
            cfg.warmup_s = warm(&plan.long.svc);
            simulate_pool(&cfg, &ref_long)
        });

        for (got, want, label) in [(&sim.short, &ref_s, "short"), (&sim.long, &ref_l, "long")] {
            match (got, want) {
                (Some(a), Some(b)) => {
                    assert_eq!(
                        a.utilization.to_bits(),
                        b.utilization.to_bits(),
                        "{} {label} rho",
                        w.name
                    );
                    assert_eq!(a.completed, b.completed, "{} {label}", w.name);
                    assert_eq!(a.window.0.to_bits(), b.window.0.to_bits());
                    assert_eq!(a.window.1.to_bits(), b.window.1.to_bits());
                }
                (None, None) => {}
                _ => panic!("{} {label}: presence mismatch", w.name),
            }
        }
    }
}

#[test]
fn k2_gateway_bit_identical_to_reference() {
    // Verbatim pre-refactor gateway route(): classify -> estimate -> EMA
    // update -> single-boundary gate -> compress-or-long. The K-tier
    // gateway with one boundary must reproduce every decision, every
    // compressed byte, and the shared estimator state.
    use fleetopt::compress::corpus::{self, CorpusConfig};
    use fleetopt::compress::extractive::compress_with;
    use fleetopt::compress::gate::{compression_budget, gate, GateDecision};
    use fleetopt::compress::scratch::CompressScratch;
    use fleetopt::compress::tokenizer::count_tokens;
    use fleetopt::router::{classify, Gateway, GatewayConfig, TokenEstimator};

    let b_short = 2048u32;
    let gamma = 1.5;
    let mut gw = Gateway::new(GatewayConfig::two_tier(b_short, gamma, true));
    let mut est = TokenEstimator::default();
    let mut scratch = CompressScratch::new();
    let mut rng = Rng::new(0x6A7E);
    for i in 0..40u32 {
        let target = match i % 4 {
            0 => 300,
            1 => 2600, // borderline band (compress path)
            2 => 700,
            _ => 4000, // above the band
        };
        let text = corpus::generate_document(
            &CorpusConfig {
                target_tokens: target,
                ..Default::default()
            },
            &mut rng,
        );
        let max_output = 64u32;

        let category = classify(&text);
        let est_total = est.estimate_prompt_tokens(text.len(), category) + max_output;
        let actual_prompt = count_tokens(&text);
        est.update(text.len(), actual_prompt, category);
        let (ref_tier, ref_text, ref_tokens, ref_compressed) =
            match gate(est_total, b_short, gamma, category) {
                GateDecision::RouteShort => (0usize, text.clone(), actual_prompt, false),
                GateDecision::CompressAndRoute => match compression_budget(b_short, max_output) {
                    Some(budget) => {
                        let c = compress_with(&mut scratch, &text, budget);
                        if c.ok {
                            let tokens = count_tokens(&c.text);
                            (0, c.text, tokens, true)
                        } else {
                            (1, text.clone(), actual_prompt, false)
                        }
                    }
                    None => (1, text.clone(), actual_prompt, false),
                },
                GateDecision::BandButUnsafe | GateDecision::RouteLong => {
                    (1, text.clone(), actual_prompt, false)
                }
            };

        let r = gw.route(&text, max_output);
        assert_eq!(r.tier, ref_tier, "doc {i}");
        assert_eq!(r.estimated_l_total, est_total, "doc {i}");
        assert_eq!(r.text, ref_text, "doc {i}");
        assert_eq!(r.prompt_tokens, ref_tokens, "doc {i}");
        assert_eq!(r.compressed, ref_compressed, "doc {i}");
    }
    assert!(gw.n_compressed > 0, "compress path must be exercised");
    assert!(gw.n_routed_long() > 0, "long path must be exercised");
}

#[test]
fn sweep_tiered_k2_selects_the_sweep_full_optimum() {
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let (best2, grid2) = sweep_full(&input).unwrap();
        let (tiered, gridt) = sweep_tiered(&input, 2).unwrap();
        assert_eq!(gridt.len(), grid2.len(), "{}", w.name);
        assert_eq!(tiered.cost_yr.to_bits(), best2.cost_yr.to_bits(), "{}", w.name);
        assert_eq!(tiered.boundaries(), vec![best2.b_short], "{}", w.name);
        assert_eq!(tiered.gammas[0].to_bits(), best2.gamma.to_bits(), "{}", w.name);
        assert_eq!(
            tiered.gpu_counts(),
            vec![best2.short.n_gpus, best2.long.n_gpus],
            "{}",
            w.name
        );
        // Grid costs agree cell-by-cell.
        for (a, b) in gridt.iter().zip(&grid2) {
            assert_eq!(a.boundaries, vec![b.0]);
            assert_eq!(a.gamma.to_bits(), b.1.to_bits());
            assert_eq!(a.cost_yr.to_bits(), b.2.to_bits());
        }
    }
}

#[test]
fn k3_plan_conserves_traffic_and_orders_tiers() {
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let cands = fleetopt::planner::candidate_boundaries(&input);
        assert!(cands.len() >= 2, "{}", w.name);
        let spec = input.gpu.fleet_spec(&[cands[0], cands[cands.len() - 1]]);
        let tp = plan_tiers(&input, &spec, &[1.5, 1.5], true, None).unwrap();
        let total: f64 = tp.tiers.iter().map(|t| t.lambda).sum();
        assert!((total - 1000.0).abs() < 1e-9, "{}: sum lambda {total}", w.name);
        // Slot counts strictly decrease tier over tier at these windows.
        for pair in tp.spec.tiers.windows(2) {
            assert!(pair[0].n_max > pair[1].n_max);
        }
        // Every tier with traffic got capacity.
        for (i, t) in tp.tiers.iter().enumerate() {
            if t.lambda > 1.0 {
                assert!(t.n_gpus > 0, "{} tier {i} has traffic but no GPUs", w.name);
            }
        }
    }
}

#[test]
fn table8_acceptance_k3_at_most_k2_on_some_trace() {
    // Acceptance: a third tier pays (cost <=) on at least one evaluation
    // trace — the cost-cliff argument applied recursively.
    let mut wins = Vec::new();
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let (best2, _) = sweep_full(&input).unwrap();
        let (best3, _) = sweep_tiered(&input, 3).unwrap();
        if best3.cost_yr <= best2.cost_yr {
            wins.push((w.name, best2.cost_yr, best3.cost_yr));
        }
    }
    assert!(!wins.is_empty(), "K=3 never beat K=2 on any trace");
}

#[test]
fn sku_catalog_of_one_plans_bit_identical_to_plain_specs() {
    // The heterogeneous-SKU generalization's K-tier pin: planning against
    // the catalog-of-one spec (base SKU assigned to every tier) reproduces
    // the plain `fleet_spec` plan bit for bit — sizes, lambdas, gammas and
    // cost. The paper's A100 profile prices both pools equally (phi = 1),
    // which is exactly when the projection is defined.
    use fleetopt::config::SkuCatalog;
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let catalog = SkuCatalog::single(&input.gpu);
        for bounds in [&[w.b_short][..], &[2048, 16384][..]] {
            let plain_spec = input.gpu.fleet_spec(bounds);
            let sku_spec =
                input
                    .gpu
                    .fleet_spec_skus(bounds, &catalog, &vec![0; bounds.len() + 1]);
            let gammas = vec![1.5; bounds.len()];
            let a = plan_tiers(&input, &plain_spec, &gammas, true, None);
            let b = plan_tiers(&input, &sku_spec, &gammas, true, None);
            let label = format!("{} bounds={bounds:?}", w.name);
            match (a, b) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.cost_yr.to_bits(), b.cost_yr.to_bits(), "{label}");
                    assert_eq!(a.gpu_counts(), b.gpu_counts(), "{label}");
                    for (x, y) in a.tiers.iter().zip(&b.tiers) {
                        assert_eq!(x.lambda.to_bits(), y.lambda.to_bits(), "{label}");
                    }
                    for t in &b.spec.tiers {
                        assert_eq!(t.sku_index(), Some(0), "{label}");
                    }
                }
                // Both paths must agree on feasibility too.
                (Err(ea), Err(eb)) => {
                    assert_eq!(format!("{ea:?}"), format!("{eb:?}"), "{label}")
                }
                (a, b) => panic!(
                    "{label}: feasibility diverged (plain ok={}, catalog-of-one ok={})",
                    a.is_ok(),
                    b.is_ok()
                ),
            }
        }
    }
}

#[test]
fn zero_redundancy_is_bit_identical_and_spares_add_exactly_k() {
    // The N+k sizing constraint's identity pin: k = 0 — spelled as the
    // empty default, an explicit [0; K], or a broadcast [0] — must leave
    // every planner output bit-identical, sweeps included (the spares
    // ride the same closed-form lower bound, so pruning decisions cannot
    // move either). And k > 0 adds exactly k GPUs to each provisioned
    // tier at unchanged boundaries/gammas.
    for w in traces::all() {
        let input = fast_input(w.clone(), 1000.0);
        let spec = input.gpu.fleet_spec(&[w.b_short]);
        let base = plan_tiers(&input, &spec, &[1.5], true, None).unwrap();
        for zero in [vec![], vec![0], vec![0, 0]] {
            let mut iz = input.clone();
            iz.redundancy = zero.clone();
            let pz = plan_tiers(&iz, &spec, &[1.5], true, None).unwrap();
            assert_eq!(pz.gpu_counts(), base.gpu_counts(), "{} {zero:?}", w.name);
            assert_eq!(pz.cost_yr.to_bits(), base.cost_yr.to_bits(), "{} {zero:?}", w.name);
            let (sz, _) = sweep_tiered(&iz, 2).unwrap();
            let (sb, _) = sweep_tiered(&input, 2).unwrap();
            assert_eq!(sz.cost_yr.to_bits(), sb.cost_yr.to_bits(), "{} {zero:?}", w.name);
            assert_eq!(sz.boundaries(), sb.boundaries(), "{} {zero:?}", w.name);
            assert_eq!(sz.gpu_counts(), sb.gpu_counts(), "{} {zero:?}", w.name);
        }
        // Broadcast N+1: every provisioned tier gains exactly one spare.
        let mut i1 = input.clone();
        i1.redundancy = vec![1];
        let p1 = plan_tiers(&i1, &spec, &[1.5], true, None).unwrap();
        for (ti, (a, b)) in base.tiers.iter().zip(&p1.tiers).enumerate() {
            let want = if a.n_gpus > 0 { a.n_gpus + 1 } else { 0 };
            assert_eq!(b.n_gpus, want, "{} tier {ti}", w.name);
            assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "{} tier {ti}", w.name);
        }
        // Per-tier spec: spares land only on the named tier.
        let mut ip = input.clone();
        ip.redundancy = vec![2, 0];
        let pp = plan_tiers(&ip, &spec, &[1.5], true, None).unwrap();
        let want0 = if base.tiers[0].n_gpus > 0 { base.tiers[0].n_gpus + 2 } else { 0 };
        assert_eq!(pp.tiers[0].n_gpus, want0, "{}", w.name);
        assert_eq!(pp.tiers[1].n_gpus, base.tiers[1].n_gpus, "{}", w.name);
        // The sweep stays exact with spares priced into its bound: its
        // incumbent must match the fixed-boundary plan at the incumbent's
        // own cell.
        let (s1, _) = sweep_tiered(&i1, 2).unwrap();
        let spec1 = input.gpu.fleet_spec(&s1.boundaries());
        let check = plan_tiers(&i1, &spec1, &s1.gammas, true, None).unwrap();
        assert_eq!(s1.cost_yr.to_bits(), check.cost_yr.to_bits(), "{}", w.name);
        assert_eq!(s1.gpu_counts(), check.gpu_counts(), "{}", w.name);
    }
}

#[test]
fn k3_sweep_meets_release_wall_clock_bound() {
    // Acceptance: the full K=3 boundary-combination sweep finishes inside
    // 100 ms in release mode (debug builds run it for coverage only).
    let input = PlanInput::new(traces::azure(), 1000.0);
    let t0 = std::time::Instant::now();
    let (best, grid) = sweep_tiered(&input, 3).unwrap();
    let elapsed = t0.elapsed();
    assert!(best.total_gpus() > 0);
    assert!(!grid.is_empty());
    if !cfg!(debug_assertions) {
        assert!(
            elapsed.as_secs_f64() < 0.1,
            "K=3 sweep took {:.1} ms (>100 ms release bound)",
            elapsed.as_secs_f64() * 1e3
        );
    }
}
