//! The sub-millisecond-planner PR's regression gates.
//!
//! * `AnchoredCdf::quantile` binary search vs the verbatim linear-scan
//!   reference (bit-identical — it sits on the DES sample path).
//! * Moment-table cell stats vs the quadrature oracle: the N-point
//!   quadrature must sit within the table's *declared* error bound of the
//!   exact integerized moments, on randomized cuts — the invariant the
//!   bound-and-prune sweep's soundness rests on.
//! * Prune-never-changes-argmin: `sweep_tiered_pruned` selects the
//!   bit-identical plan (boundaries, gammas, per-tier GPU counts, cost)
//!   as the full `sweep_tiered` on all three traces at K = 2, 3, 4 and
//!   across arrival rates.
//! * Incremental-vs-full `Replanner` plan equality under rate drift and
//!   across a CDF-drift (fingerprint-invalidating) epoch.
//! * The `forecast` knob is off by default and a disabled run is
//!   bit-reproducible; an enabled run still conserves every request.
//! * Release-mode wall-clock guard for the pruned K = 3 sweep (the hard
//!   < 10 ms floor is enforced by CI on `BENCH_planner.json`).

use fleetopt::config::{CellStatsMode, PlannerConfig};
use fleetopt::fleetsim::{simulate_autoscale, AutoscaleConfig};
use fleetopt::planner::replan::{ReplanConfig, Replanner};
use fleetopt::planner::{
    plan_fleet, plan_spec_sweep_gamma, sweep_tiered, sweep_tiered_pruned, CalibCache, PlanInput,
};
use fleetopt::queueing::service::MomentTable;
use fleetopt::util::rng::Rng;
use fleetopt::workload::arrivals::RateModel;
use fleetopt::workload::cdf::{AnchoredCdf, LengthDist, TruncatedDist};
use fleetopt::workload::traces;

fn fast_input(w: traces::Workload, lambda: f64, mc: usize) -> PlanInput {
    let mut i = PlanInput::new(w, lambda);
    i.cfg = PlannerConfig {
        mc_samples: mc,
        ..PlannerConfig::default()
    };
    i
}

/// The pre-PR linear-scan quantile, verbatim (public API only).
fn quantile_linear_reference(cdf: &AnchoredCdf, q: f64) -> f64 {
    let anchors = cdf.anchors();
    let q = q.clamp(0.0, 1.0);
    if q <= 0.0 {
        return cdf.min_tokens();
    }
    if q >= 1.0 {
        return cdf.max_tokens();
    }
    let mut i = 0;
    while i + 2 < anchors.len() && anchors[i + 1].1 <= q {
        i += 1;
    }
    let (x0, f0) = anchors[i];
    let (x1, f1) = anchors[i + 1];
    if f1 <= f0 {
        return x1;
    }
    let t = (q - f0) / (f1 - f0);
    x0 * (x1 / x0).powf(t)
}

#[test]
fn quantile_binary_search_bit_identical_to_linear_scan() {
    let mut cdfs: Vec<AnchoredCdf> = traces::all().iter().map(|w| w.cdf.clone()).collect();
    // Flat segments, duplicate F plateaus, and a minimal 2-anchor CDF.
    cdfs.push(AnchoredCdf::new(vec![
        (10.0, 0.0),
        (100.0, 0.5),
        (200.0, 0.5),
        (400.0, 0.5),
        (1000.0, 1.0),
    ]));
    cdfs.push(AnchoredCdf::new(vec![(8.0, 0.0), (64.0, 1.0)]));
    // Randomized anchor sets with occasional plateaus.
    let mut rng = Rng::new(0xFA57);
    for _ in 0..32 {
        let n = 3 + (rng.f64() * 10.0) as usize;
        let mut x = 4.0 + rng.f64() * 16.0;
        let mut f = 0.0;
        let mut anchors = vec![(x, f)];
        for j in 0..n {
            x *= 1.2 + rng.f64() * 3.0;
            f = if j + 1 == n {
                1.0
            } else if rng.f64() < 0.25 {
                f // plateau
            } else {
                (f + rng.f64() * (1.0 - f) * 0.6).min(1.0)
            };
            anchors.push((x, f));
        }
        anchors.last_mut().unwrap().1 = 1.0;
        cdfs.push(AnchoredCdf::new(anchors));
    }

    for cdf in &cdfs {
        // Probe a dense grid plus every anchor F value exactly.
        for i in 0..=2000 {
            let q = i as f64 / 2000.0;
            assert_eq!(
                cdf.quantile(q).to_bits(),
                quantile_linear_reference(cdf, q).to_bits(),
                "q = {q}"
            );
        }
        for &(_, f) in cdf.anchors() {
            assert_eq!(
                cdf.quantile(f).to_bits(),
                quantile_linear_reference(cdf, f).to_bits(),
                "anchor F = {f}"
            );
        }
    }
}

#[test]
fn moment_table_bound_holds_on_random_cuts() {
    for w in traces::all() {
        let table = MomentTable::build(&w.cdf, &w.output, 512);
        let mut rng = Rng::new(0xB0B + w.b_short as u64);
        let (min_t, max_t) = (w.cdf.min_tokens(), w.cdf.max_tokens());
        for _ in 0..20 {
            // Random log-spaced cut inside the support.
            let a = min_t * (max_t / min_t).powf(rng.f64() * 0.8);
            let b = a * (max_t / a).powf(0.2 + rng.f64() * 0.8);
            let (lo, hi) = (a, b.min(max_t));
            if w.cdf.cdf(hi) - w.cdf.cdf(lo) <= 1e-6 {
                continue;
            }
            let dist = TruncatedDist::new(w.cdf.clone(), lo, hi);
            let gpu = fleetopt::config::GpuProfile::a100_llama70b();
            for n in [64usize, 512] {
                let m = table.cut_moments(lo, hi, n).expect("cut has mass");
                let quad = fleetopt::queueing::service::calibrate_quadrature(
                    &dist, &w.output, &gpu, 64, n, 8,
                );
                let quad_iter = quad.e_s / quad.t_iter_s;
                assert!(
                    (quad_iter - m.e_iter).abs() <= m.err_iter,
                    "{} cut ({lo:.1}, {hi:.1}] N={n}: quad {quad_iter} vs exact {} (err {})",
                    w.name,
                    m.e_iter,
                    m.err_iter
                );
            }
        }
    }
}

/// The PR's headline acceptance gate: bound-and-prune selects the exact
/// full-sweep plan on every trace at K = 2, 3, 4 (K = 4 on one trace in
/// debug builds — the full K = 4 grid is quadratic-expensive unoptimized).
#[test]
fn pruned_sweep_never_changes_the_argmin() {
    let heavy = !cfg!(debug_assertions);
    for w in traces::all() {
        for (k, lambdas) in [
            (2usize, &[1000.0, 400.0][..]),
            (3, &[1000.0][..]),
            (4, &[1000.0][..]),
        ] {
            if k == 4 && !heavy && w.name != "azure" {
                continue;
            }
            for &lambda in lambdas {
                // Internal identity at reduced quadrature resolution keeps
                // the debug-mode grid affordable; the identity argument is
                // resolution-independent.
                let mc = if k == 4 { 1_000 } else { 2_000 };
                let input = fast_input(w.clone(), lambda, mc);
                let (full, grid) = sweep_tiered(&input, k).unwrap();
                let (fast, stats) = sweep_tiered_pruned(&input, k, &CalibCache::new()).unwrap();
                assert!(!grid.is_empty());
                let label = format!("{} K={k} lambda={lambda}", w.name);
                assert_eq!(fast.cost_yr.to_bits(), full.cost_yr.to_bits(), "{label}");
                assert_eq!(fast.boundaries(), full.boundaries(), "{label}");
                assert_eq!(fast.gpu_counts(), full.gpu_counts(), "{label}");
                for (a, b) in fast.gammas.iter().zip(&full.gammas) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{label}");
                }
                for (a, b) in fast.tiers.iter().zip(&full.tiers) {
                    assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "{label}");
                }
                assert_eq!(
                    stats.cells,
                    stats.pruned + stats.evaluated + stats.infeasible,
                    "{label}"
                );
                assert!(
                    stats.pruned * 2 > stats.cells,
                    "{label}: only {} of {} cells pruned",
                    stats.pruned,
                    stats.cells
                );
            }
        }
    }
}

#[test]
fn moment_table_mode_plans_land_within_tolerance() {
    // The opt-in CellStatsMode::MomentTable is an approximation: it must
    // never be *far* from the quadrature plan (the exact path keeps
    // bit-identity; this guards the approximation's calibration quality).
    for w in traces::all() {
        let exact = fast_input(w.clone(), 1000.0, 8_000);
        let mut approx = fast_input(w.clone(), 1000.0, 8_000);
        approx.cfg.cell_stats = CellStatsMode::MomentTable;
        for gamma in [1.0, 1.5] {
            let a = plan_fleet(&exact, w.b_short, gamma).unwrap();
            let b = plan_fleet(&approx, w.b_short, gamma).unwrap();
            for (x, y, pool) in [
                (a.short.n_gpus, b.short.n_gpus, "short"),
                (a.long.n_gpus, b.long.n_gpus, "long"),
            ] {
                let tol = 2.0 + 0.025 * x as f64;
                assert!(
                    (x as f64 - y as f64).abs() <= tol,
                    "{} {pool} gamma={gamma}: exact {x} vs table {y}",
                    w.name
                );
            }
        }
    }
}

#[test]
fn incremental_replanner_equals_full_across_rate_and_cdf_drift() {
    // Same adopted plan at every epoch, including the epoch whose CDF
    // snapshot (and so workload fingerprint) changes — incremental mode
    // must fall back to the unseeded sweep there and still agree.
    let base = traces::azure();
    let mut drifted = traces::azure();
    // A mildly different empirical snapshot: shift one interior anchor.
    let mut anchors = drifted.cdf.anchors().to_vec();
    anchors[5].1 = (anchors[5].1 + anchors[6].1) / 2.0;
    drifted.cdf = AnchoredCdf::new(anchors);

    let mk = |incremental| {
        let inp = fast_input(base.clone(), 1000.0, 2_000);
        let spec = inp.gpu.fleet_spec(&[base.b_short]);
        let init = plan_spec_sweep_gamma(&inp, &spec).unwrap();
        Replanner::new(
            ReplanConfig {
                sweep_boundaries: true,
                incremental,
                ..ReplanConfig::default()
            },
            init,
        )
    };
    let mut inc = mk(true);
    let mut full = mk(false);
    let epochs: Vec<PlanInput> = vec![
        fast_input(base.clone(), 950.0, 2_000),
        fast_input(base.clone(), 1100.0, 2_000),
        fast_input(drifted.clone(), 1080.0, 2_000), // fingerprint change
        fast_input(drifted.clone(), 990.0, 2_000),
        fast_input(base.clone(), 1000.0, 2_000), // and back
    ];
    for (e, input) in epochs.iter().enumerate() {
        let a = inc.replan(input).unwrap();
        let b = full.replan(input).unwrap();
        assert_eq!(a.plan.cost_yr.to_bits(), b.plan.cost_yr.to_bits(), "epoch {e}");
        assert_eq!(a.plan.boundaries(), b.plan.boundaries(), "epoch {e}");
        assert_eq!(a.plan.gpu_counts(), b.plan.gpu_counts(), "epoch {e}");
        assert_eq!(a.switched_layout, b.switched_layout, "epoch {e}");
    }
}

#[test]
fn forecast_knob_is_off_by_default_and_inert_when_disabled() {
    let w = traces::azure();
    let input = fast_input(w.clone(), 300.0, 4_000);
    let spec = input.gpu.fleet_spec(&[w.b_short]);
    let init = plan_spec_sweep_gamma(&input, &spec).unwrap();
    let base = AutoscaleConfig {
        epoch_s: 5.0,
        window_s: 10.0,
        provision_delay_s: 2.0,
        ..AutoscaleConfig::default()
    };
    assert!(!base.forecast, "forecast must default off");
    let mut disabled = base.clone();
    disabled.forecast = false;
    let model = RateModel::Diurnal {
        base: 300.0,
        amp: 0.5,
        period_s: 60.0,
        phase: 0.0,
    };
    let n = 6_000;
    let a = simulate_autoscale(&w, model.clone(), n, &input, init.clone(), &base, 9);
    let b = simulate_autoscale(&w, model.clone(), n, &input, init.clone(), &disabled, 9);
    // Spelling the default out changes nothing, bit for bit.
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.gpu_hours.to_bits(), b.gpu_hours.to_bits());
    assert_eq!(a.cost.to_bits(), b.cost.to_bits());
    assert_eq!(a.layout_switches, b.layout_switches);
    assert_eq!(a.final_gpus, b.final_gpus);
    // Enabled: the controller may only provision differently — every
    // request still completes and accounting stays conserved.
    let mut on = base.clone();
    on.forecast = true;
    let c = simulate_autoscale(&w, model, n, &input, init, &on, 9);
    assert_eq!(c.completed, n as u64);
    assert_eq!(c.censored, 0);
}

#[test]
fn pruned_k3_sweep_meets_release_wall_clock_guard() {
    // CI's hard floor is < 10 ms via BENCH_planner.json (warm moment
    // table); this in-test guard is looser to absorb tier-1 runner noise
    // and the one-time table build. Debug builds run it for coverage.
    let input = PlanInput::new(traces::azure(), 1000.0);
    // Warm the shared table (one-time, reported separately by the bench).
    let _ = MomentTable::for_workload(&input.workload, input.gpu.chunk);
    let t0 = std::time::Instant::now();
    let (best, stats) = sweep_tiered_pruned(&input, 3, &CalibCache::new()).unwrap();
    let elapsed = t0.elapsed();
    assert!(best.total_gpus() > 0);
    assert!(stats.pruned > 0);
    if !cfg!(debug_assertions) {
        assert!(
            elapsed.as_secs_f64() < 0.025,
            "pruned K=3 sweep took {:.2} ms (>= 25 ms in-test guard)",
            elapsed.as_secs_f64() * 1e3
        );
    }
}
