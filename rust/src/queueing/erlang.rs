//! Erlang-C in a numerically stable recursive form (paper Eq. 5, App. A).
//!
//! `C(c, rho)` is the probability an arriving request finds all `c` servers
//! (KV slots) busy and must queue. The naive factorial form overflows for
//! c beyond ~170; the paper's Appendix-A reciprocal-sum form is evaluated
//! with a downward term recurrence so it is stable to millions of slots
//! and costs only as many iterations as there are non-negligible terms.
//!
//! §Perf: [`erlang_c_cached`] memoizes the recurrence per `(c, rho)`.
//! The sizing inversion (`planner::sizing::min_gpus`) re-evaluates the
//! tail at the same cells across its bisection steps and across sweep
//! cells that share a tier (every K-subset containing boundary `B`
//! re-sizes `B`'s tier at the identical lambda and calibration), and at
//! c ~ 10^4 slots one evaluation walks thousands of recurrence terms.
//! The memo is thread-local — the scoped sweep workers never contend —
//! and returns the identical f64, so every planner output is
//! bit-identical with or without it.
//!
//! Two further layers keep most evaluations from happening at all: the
//! inversion's bracket warm-start (`planner::sizing`) skips the expensive
//! low-utilization `feasible(hi)` probe — at `rho ~ 0.1` the recurrence
//! decays slowly and a single tail walk costs the most — and the sweep's
//! bound-and-prune pass (`planner::tiered::sweep_tiered_pruned`) skips
//! whole cells with a closed-form stability bound that needs no Erlang-C
//! evaluation whatsoever. Neither changes a returned value.

use std::cell::RefCell;

use crate::util::hash::FxHashMap;

/// Natural log of the Gamma function (Lanczos approximation, g=7, n=9).
/// Used by tests as an independent cross-check of the recurrence.
pub fn ln_gamma(x: f64) -> f64 {
    const COEFFS: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEFFS[0];
    let t = x + 7.5;
    for (i, &c) in COEFFS.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Erlang-C probability of waiting, `C(c, rho)`, for `c` servers at offered
/// per-server utilization `rho = lambda / (c * mu)` in [0, 1).
///
/// Returns 1.0 for rho >= 1 (unstable queue: waiting is certain).
pub fn erlang_c(c: u64, rho: f64) -> f64 {
    assert!(c >= 1, "need at least one server");
    if rho >= 1.0 {
        return 1.0;
    }
    if rho <= 0.0 {
        return 0.0;
    }
    // 1/C = 1 + (1 - rho) * S,  S = sum_{k=0}^{c-1} c!/(k!) * (c rho)^(k-c).
    // Downward recurrence from k = c-1: t_{c-1} = 1/rho,
    // t_{k-1} = t_k * k / (c rho). Terms decay geometrically once k < c*rho.
    let a = c as f64 * rho;
    let mut term = 1.0 / rho;
    let mut sum = term;
    let mut k = (c - 1) as f64;
    while k >= 1.0 {
        term *= k / a;
        sum += term;
        if term < sum * 1e-17 {
            break; // remaining terms are below f64 resolution
        }
        k -= 1.0;
    }
    1.0 / (1.0 + (1.0 - rho) * sum)
}

#[derive(Default)]
struct Memo {
    map: FxHashMap<(u64, u64), f64>,
    hits: u64,
    misses: u64,
}

thread_local! {
    static ERLANG_MEMO: RefCell<Memo> = RefCell::new(Memo::default());
}

/// Bound on the memo table: cleared wholesale past this. The planner's
/// whole sweep grid is a few thousand cells, so 64K entries (~2 MB) cover
/// every reuse pattern with room to spare while keeping the worst case
/// small for long-lived threads whose rho is continuous (the live
/// replanning loop re-estimates lambda every epoch, so its keys rarely
/// repeat — the cap is what bounds that path's memory, not its hit rate).
const MEMO_CAP: usize = 1 << 16;

/// Memoized [`erlang_c`] — identical output, one recurrence evaluation
/// per distinct `(c, rho)` per thread (see module docs). The degenerate
/// regimes short-circuit without touching the table.
pub fn erlang_c_cached(c: u64, rho: f64) -> f64 {
    if rho >= 1.0 {
        return 1.0;
    }
    if rho <= 0.0 {
        return 0.0;
    }
    ERLANG_MEMO.with(|m| {
        let mut m = m.borrow_mut();
        let memo = &mut *m;
        let key = (c, rho.to_bits());
        if let Some(&v) = memo.map.get(&key) {
            memo.hits += 1;
            return v;
        }
        memo.misses += 1;
        let v = erlang_c(c, rho);
        // Evict only on the insert path, so a hit never wipes the table.
        if memo.map.len() >= MEMO_CAP {
            memo.map.clear();
        }
        memo.map.insert(key, v);
        v
    })
}

/// This thread's memo statistics `(hits, misses)` — bench diagnostics.
pub fn erlang_cache_stats() -> (u64, u64) {
    ERLANG_MEMO.with(|m| {
        let m = m.borrow();
        (m.hits, m.misses)
    })
}

/// Erlang-C via the direct log-space sum (independent implementation used
/// to cross-validate the recurrence in tests; O(c) ln_gamma calls).
pub fn erlang_c_logspace(c: u64, rho: f64) -> f64 {
    if rho >= 1.0 {
        return 1.0;
    }
    if rho <= 0.0 {
        return 0.0;
    }
    let a = c as f64 * rho;
    let ln_a = a.ln();
    let ln_top = c as f64 * ln_a - ln_gamma(c as f64 + 1.0) - (1.0 - rho).ln();
    // ln of sum_{k=0}^{c-1} a^k/k!, computed with the log-sum-exp trick.
    let mut max_ln = f64::NEG_INFINITY;
    let lns: Vec<f64> = (0..c)
        .map(|k| {
            let l = k as f64 * ln_a - ln_gamma(k as f64 + 1.0);
            max_ln = max_ln.max(l);
            l
        })
        .collect();
    let sum: f64 = lns.iter().map(|l| (l - max_ln).exp()).sum();
    let ln_bottom_partial = max_ln + sum.ln();
    // C = top / (bottom_partial + top)
    let d = ln_top - ln_bottom_partial;
    if d > 0.0 {
        1.0 / (1.0 + (-d).exp())
    } else {
        d.exp() / (d.exp() + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        let mut fact = 1.0f64;
        for n in 1..=20u64 {
            fact *= n as f64;
            let got = ln_gamma(n as f64 + 1.0);
            assert!(
                (got - fact.ln()).abs() < 1e-9,
                "ln_gamma({}) = {got}, want {}",
                n + 1,
                fact.ln()
            );
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Gamma(1/2) = sqrt(pi).
        let want = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - want).abs() < 1e-10);
    }

    #[test]
    fn single_server_reduces_to_rho() {
        // M/M/1: probability of waiting = rho.
        for rho in [0.1, 0.5, 0.9, 0.99] {
            assert!((erlang_c(1, rho) - rho).abs() < 1e-12, "rho={rho}");
        }
    }

    #[test]
    fn known_values_small_c() {
        // c=2, rho=0.5 (a=1): C = (a^2/(2!(1-rho))) / (1 + a + that) = 1/(1+1+1) ...
        // direct: top = 1/(2*0.5)=1, bottom = 1 + 1 + 1 = 3 -> C = 1/3.
        let c = erlang_c(2, 0.5);
        assert!((c - 1.0 / 3.0).abs() < 1e-12, "c={c}");
    }

    #[test]
    fn recurrence_matches_logspace_small_and_large() {
        for &(c, rho) in &[
            (2u64, 0.3),
            (5, 0.7),
            (16, 0.85),
            (100, 0.5),
            (1000, 0.9),
            (10_000, 0.85),
            (32_592, 0.85), // largest slot count in the paper's fleets
        ] {
            let a = erlang_c(c, rho);
            let b = erlang_c_logspace(c, rho);
            assert!(
                (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                "c={c} rho={rho}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn monotone_in_rho() {
        for c in [1u64, 4, 64, 512] {
            let mut last = 0.0;
            for i in 1..20 {
                let rho = i as f64 / 20.0;
                let v = erlang_c(c, rho);
                assert!(v >= last, "C must increase with rho (c={c})");
                last = v;
            }
        }
    }

    #[test]
    fn monotone_decreasing_in_c_at_fixed_rho() {
        // More servers at the same per-server utilization -> less waiting
        // (statistical multiplexing).
        let mut last = 1.0;
        for c in [1u64, 2, 4, 8, 16, 32, 64, 128, 256] {
            let v = erlang_c(c, 0.85);
            assert!(v <= last + 1e-12, "C(c={c}) = {v} > {last}");
            last = v;
        }
    }

    #[test]
    fn many_server_regime_vanishes() {
        // Paper §7.4: with thousands of slots at rho <= 0.85, C ~ 0.
        assert!(erlang_c(10_000, 0.85) < 1e-50);
        assert!(erlang_c(1_000, 0.85) < 1e-6);
        assert!(erlang_c(112, 0.85) < 0.1); // smallest fleet in Table 5
    }

    #[test]
    fn saturated_queue_always_waits() {
        assert_eq!(erlang_c(10, 1.0), 1.0);
        assert_eq!(erlang_c(10, 1.5), 1.0);
    }

    #[test]
    fn stable_at_extreme_scale() {
        let v = erlang_c(1_000_000, 0.999);
        assert!(v.is_finite() && (0.0..=1.0).contains(&v));
    }

    #[test]
    fn cached_is_bit_identical_and_hits() {
        let (h0, _) = erlang_cache_stats();
        for &(c, rho) in &[(16u64, 0.85), (1000, 0.9), (32_592, 0.85)] {
            let direct = erlang_c(c, rho);
            let first = erlang_c_cached(c, rho);
            let second = erlang_c_cached(c, rho);
            assert_eq!(direct.to_bits(), first.to_bits(), "c={c} rho={rho}");
            assert_eq!(first.to_bits(), second.to_bits());
        }
        let (h1, _) = erlang_cache_stats();
        assert!(h1 >= h0 + 3, "repeat lookups must hit the memo");
        // Degenerate regimes bypass the table entirely.
        assert_eq!(erlang_c_cached(10, 1.5), 1.0);
        assert_eq!(erlang_c_cached(10, 0.0), 0.0);
    }
}
