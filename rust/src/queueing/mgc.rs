//! The M/G/c pool model (paper §3.1): a pool of `n` GPUs is an M/G/c queue
//! with `c = n * n_max` KV slots as servers.

use crate::queueing::kimura;
use crate::queueing::service::ServiceStats;

/// One provisioned pool under the analytical model.
#[derive(Clone, Debug)]
pub struct PoolModel {
    /// Arrival rate into this pool (req/s).
    pub lambda: f64,
    /// GPU count.
    pub n_gpus: u64,
    /// Calibrated service statistics.
    pub svc: ServiceStats,
}

impl PoolModel {
    pub fn new(lambda: f64, n_gpus: u64, svc: ServiceStats) -> Self {
        PoolModel {
            lambda,
            n_gpus,
            svc,
        }
    }

    /// Total KV slots c = n * n_max.
    pub fn c_slots(&self) -> u64 {
        self.n_gpus * self.svc.n_slots as u64
    }

    /// Offered per-slot utilization rho = lambda / (c * mu).
    pub fn utilization(&self) -> f64 {
        self.lambda / (self.c_slots() as f64 * self.svc.mu_slot())
    }

    /// Analytical GPU utilization rho_ana = lambda / (n * mu_gpu) (§7.4) —
    /// identical to the per-slot utilization by construction.
    pub fn rho_ana(&self) -> f64 {
        self.lambda / (self.n_gpus as f64 * self.svc.mu_gpu())
    }

    /// P99 queue waiting time (Eq. 6).
    pub fn w99(&self) -> f64 {
        kimura::w99(
            self.c_slots(),
            self.svc.mu_slot(),
            self.lambda,
            self.svc.scv,
        )
    }

    /// Mean queue waiting time.
    pub fn w_mean(&self) -> f64 {
        kimura::w_mean(
            self.c_slots(),
            self.svc.mu_slot(),
            self.lambda,
            self.svc.scv,
        )
    }

    /// P99 TTFT decomposition (Eq. 7): queue wait + physical prefill + one
    /// decode iteration.
    pub fn ttft_p99(&self) -> f64 {
        self.w99() + self.svc.p99_prefill_s + self.svc.t_iter_s
    }

    /// SLO feasibility (Eq. 8): the queue-wait budget left after prefill
    /// and first decode must cover W99, and the queue must be stable.
    pub fn feasible(&self, t_slo: f64, rho_max: f64) -> bool {
        if self.utilization() > rho_max {
            return false;
        }
        let budget = t_slo - self.svc.p99_prefill_s - self.svc.t_iter_s;
        if budget < 0.0 {
            return false;
        }
        self.w99() <= budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuProfile;
    use crate::queueing::service::calibrate;
    use crate::workload::traces;

    fn pool(lambda: f64, n_gpus: u64, n_slots: u32) -> PoolModel {
        let w = traces::azure();
        let g = GpuProfile::a100_llama70b();
        let svc = calibrate(&w.cdf, &w.output, &g, n_slots, 10_000, 7);
        PoolModel::new(lambda, n_gpus, svc)
    }

    #[test]
    fn utilization_definitions_agree() {
        let p = pool(100.0, 10, 128);
        assert!((p.utilization() - p.rho_ana()).abs() < 1e-12);
    }

    #[test]
    fn slots_product() {
        let p = pool(100.0, 7, 16);
        assert_eq!(p.c_slots(), 112);
    }

    #[test]
    fn many_server_regime_w99_zero() {
        // A generously provisioned pool: W99 should vanish (§7.4).
        let p = pool(100.0, 100, 128);
        assert!(p.utilization() < 0.2);
        assert_eq!(p.w99(), 0.0);
        // TTFT is then prefill-dominated.
        assert!((p.ttft_p99() - (p.svc.p99_prefill_s + p.svc.t_iter_s)).abs() < 1e-12);
    }

    #[test]
    fn overloaded_pool_infeasible() {
        let p = pool(1e6, 1, 16);
        assert!(p.utilization() > 1.0);
        assert!(!p.feasible(0.5, 0.85));
        assert!(p.w99().is_infinite());
    }

    #[test]
    fn feasibility_respects_rho_max() {
        // Find a pool whose W99 is 0 but utilization exceeds the cap:
        // must be infeasible purely due to rho_max.
        let mut p = pool(100.0, 1, 128);
        // scale lambda to hit utilization 0.9
        let mu_gpu = p.svc.mu_gpu();
        p.lambda = 0.9 * mu_gpu;
        assert!(p.utilization() > 0.85 && p.utilization() < 1.0);
        assert!(!p.feasible(10.0, 0.85));
        assert!(p.feasible(10.0, 0.95));
    }

    #[test]
    fn adding_gpus_never_hurts() {
        let base = pool(500.0, 3, 128);
        let more = PoolModel::new(500.0, 6, base.svc);
        assert!(more.w99() <= base.w99());
        assert!(more.utilization() < base.utilization());
    }
}
