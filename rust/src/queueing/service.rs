//! Service-time model under continuous batching (paper Eq. 3–4) and the
//! Monte-Carlo calibration of `(E[S], C_s^2)` used by the planner.
//!
//! A request with `L_in` input and `L_out` output tokens occupies a KV slot
//! for `ceil(L_in / C_chunk) + L_out` lockstep iterations of duration
//! `t_iter = W + H * n_max` — all `n_max` slots advance together, so the
//! iteration latency is evaluated at the configured slot count (§3.1).

use std::sync::{Arc, Mutex, OnceLock};

use crate::config::GpuProfile;
use crate::util::hash::FxHashMap;
use crate::util::rng::Rng;
use crate::util::stats::{Samples, Welford};
use crate::workload::cdf::{AnchoredCdf, LengthDist};
use crate::workload::request::OutputModel;
use crate::workload::traces::Workload;

/// Number of slot iterations a request occupies (Eq. 4's parenthesised term).
pub fn slot_iterations(l_in: u32, l_out: u32, chunk: u32) -> u64 {
    (l_in as u64).div_ceil(chunk as u64) + l_out as u64
}

/// Wall-clock slot occupancy E[S] for a single request, seconds (Eq. 4).
pub fn service_time_s(l_in: u32, l_out: u32, g: &GpuProfile, n_slots: u32) -> f64 {
    slot_iterations(l_in, l_out, g.chunk) as f64 * g.t_iter_s(n_slots)
}

/// Physical prefill time for a request, seconds (§3.2):
/// `T_prefill = ceil(L_in / C_chunk) * t_iter`.
pub fn prefill_time_s(l_in: u32, g: &GpuProfile, n_slots: u32) -> f64 {
    (l_in as u64).div_ceil(g.chunk as u64) as f64 * g.t_iter_s(n_slots)
}

/// Calibrated service statistics for one pool. Plain scalar data: `Copy`,
/// so passing it around costs a register copy — no clones on the planner's
/// per-cell hot path (§Perf).
#[derive(Clone, Copy, Debug)]
pub struct ServiceStats {
    /// Mean slot occupancy E[S], seconds.
    pub e_s: f64,
    /// Squared coefficient of variation C_s^2 = Var[S]/E[S]^2.
    pub scv: f64,
    /// P99 physical prefill time, seconds (enters the SLO budget, Eq. 8).
    pub p99_prefill_s: f64,
    /// Iteration latency at the pool's configured slot count.
    pub t_iter_s: f64,
    /// Slots per GPU in this pool.
    pub n_slots: u32,
}

impl ServiceStats {
    /// Per-slot service rate mu = 1/E[S] (requests/sec/slot).
    pub fn mu_slot(&self) -> f64 {
        1.0 / self.e_s
    }

    /// GPU-level throughput mu_gpu = n_max / E[S] (§3.1).
    pub fn mu_gpu(&self) -> f64 {
        self.n_slots as f64 / self.e_s
    }

    /// These stats on silicon `mu_scale` times as fast: a proportional
    /// service-rate multiplier is a uniform time dilation, so every time
    /// quantity divides by it exactly while `scv` (dimensionless) and the
    /// slot count are invariant. `mu_scale = 1` returns `self` unchanged —
    /// the single-SKU path stays bit-identical by construction, and the
    /// calibration cache can keep storing base-rate stats keyed only by
    /// `(cut, n_slots)`.
    pub fn scaled_mu(self, mu_scale: f64) -> ServiceStats {
        if mu_scale == 1.0 {
            return self;
        }
        ServiceStats {
            e_s: self.e_s / mu_scale,
            scv: self.scv,
            p99_prefill_s: self.p99_prefill_s / mu_scale,
            t_iter_s: self.t_iter_s / mu_scale,
            n_slots: self.n_slots,
        }
    }
}

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// |rel err| < 1.15e-9). Used by the quadrature calibration to enumerate
/// lognormal-jitter quantiles deterministically.
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// The lognormal-jitter factors the quadrature calibration enumerates —
/// midpoint quantiles of the output model's jitter distribution. Shared by
/// [`calibrate_quadrature`] and the [`MomentTable`] so both integrate the
/// identical jitter grid.
pub fn jitter_grid(output: &OutputModel, jitter_points: usize) -> Vec<f64> {
    assert!(jitter_points >= 1);
    (0..jitter_points)
        .map(|j| {
            if output.sigma == 0.0 || jitter_points == 1 {
                1.0
            } else {
                let q = (j as f64 + 0.5) / jitter_points as f64;
                (output.sigma * probit(q)).exp()
            }
        })
        .collect()
}

/// The deterministic output split of one integerized request: given a
/// (rounded, >= 2) total budget `l_total` and a jitter factor, the exact
/// `(l_in, l_out)` the calibration uses — the single definition shared by
/// the quadrature loop and the moment tables (bit-for-bit: the quadrature
/// refactor onto this helper changes no float operation).
#[inline]
pub fn split_request(l_total: f64, jit: f64, output: &OutputModel) -> (u32, u32) {
    let out = (output.frac * l_total * jit).round();
    let l_out = (out as u32)
        .clamp(output.min_tokens, output.max_tokens)
        .min((l_total * 0.9) as u32)
        .max(1);
    let l_in = (l_total as u32).saturating_sub(l_out).max(1);
    (l_in, l_out)
}

/// Deterministic quadrature calibration: the planner's exact path
/// (§Perf). Replaces Monte-Carlo sampling with a midpoint rule over the
/// length distribution's quantile function crossed with a small grid of
/// lognormal-jitter quantiles for the output model. ~100x fewer
/// distribution evaluations than the 20k-sample MC at matching accuracy
/// (cross-validated in tests), and exactly reproducible with no seed.
/// The [`MomentTable`] answers the same integral in O(log n) per cut with
/// a provable error bound — this quadrature stays the equivalence oracle.
pub fn calibrate_quadrature<D: LengthDist>(
    dist: &D,
    output: &OutputModel,
    g: &GpuProfile,
    n_slots: u32,
    len_points: usize,
    jitter_points: usize,
) -> ServiceStats {
    assert!(len_points >= 16 && jitter_points >= 1);
    let t_iter = g.t_iter_s(n_slots);
    // Precompute jitter factors at midpoint quantiles.
    let jitters = jitter_grid(output, jitter_points);

    let mut w = Welford::new();
    let mut prefill = Samples::with_capacity(len_points * jitter_points);
    for i in 0..len_points {
        let q = (i as f64 + 0.5) / len_points as f64;
        let l_total = dist.quantile(q).round().max(2.0);
        for &jit in &jitters {
            let (l_in, l_out) = split_request(l_total, jit, output);
            w.push(slot_iterations(l_in, l_out, g.chunk) as f64 * t_iter);
            prefill.push(prefill_time_s(l_in, g, n_slots));
        }
    }
    ServiceStats {
        e_s: w.mean(),
        scv: w.scv(),
        p99_prefill_s: prefill.p99(),
        t_iter_s: t_iter,
        n_slots,
    }
}

/// Monte-Carlo calibration of `(E[S], C_s^2, P99 prefill)` from a pool's
/// request-length distribution (paper §3.1: "estimated by Monte Carlo
/// sampling from the pool's request distribution"). Deterministic under
/// `seed`. The planner's hot path uses [`calibrate_quadrature`]; this MC
/// version is the reference the quadrature is validated against.
pub fn calibrate<D: LengthDist>(
    dist: &D,
    output: &OutputModel,
    g: &GpuProfile,
    n_slots: u32,
    samples: usize,
    seed: u64,
) -> ServiceStats {
    assert!(samples >= 100, "too few samples for a stable C_s^2");
    let mut rng = Rng::new(seed);
    let t_iter = g.t_iter_s(n_slots);
    let mut w = Welford::new();
    let mut prefill = Samples::with_capacity(samples);
    for _ in 0..samples {
        let l_total = dist.sample(&mut rng).round().max(2.0);
        let l_out = output.sample_l_out(l_total, &mut rng);
        let l_in = (l_total as u32).saturating_sub(l_out).max(1);
        w.push(slot_iterations(l_in, l_out, g.chunk) as f64 * t_iter);
        prefill.push(prefill_time_s(l_in, g, n_slots));
    }
    ServiceStats {
        e_s: w.mean(),
        scv: w.scv(),
        p99_prefill_s: prefill.p99(),
        t_iter_s: t_iter,
        n_slots,
    }
}

/// Restricted service-time moments of one truncation cut, as served by a
/// [`MomentTable`] — the exact integerized integral plus a *provable*
/// bound on how far the `len_points`-point midpoint quadrature can sit
/// from it (the bound the planner's bound-and-prune sweep leans on).
#[derive(Clone, Copy, Debug)]
pub struct CutMoments {
    /// Parent-measure mass `F(hi) - F(lo)` of the cut.
    pub mass: f64,
    /// Exact `E[iterations | cut]` over the integerized distribution —
    /// the `len_points -> inf` limit of [`calibrate_quadrature`]'s mean
    /// (service time is `iterations * t_iter`, so `E[S] = e_iter * t_iter`).
    pub e_iter: f64,
    /// Exact `E[iterations^2 | cut]`.
    pub e_iter2: f64,
    /// Bound on `|quadrature_mean - e_iter|` at the given resolution:
    /// the midpoint rule over a (near-)monotone step function is within
    /// `(g_max - g_min) / N` of the integral; inflated 1.5x plus two
    /// absolute iterations for the rare non-monotone rounding wiggles and
    /// the Welford accumulation error.
    pub err_iter: f64,
}

/// Precomputed moment tables over the integerized length distribution:
/// one pass over the [`AnchoredCdf`] builds prefix sums of
/// `mass(v) * E_jitter[iterations(v)]` (and squared) at every integer
/// token value, so the restricted moments of **any** truncation cut
/// `(lo, hi]` are two prefix lookups plus O(1) partial-bucket edge
/// corrections — O(log n) CDF evaluations per query instead of a fresh
/// `len_points x jitter_points` quadrature (§Perf; Token-Budget-Aware
/// Pool Routing's budget-table formulation).
///
/// Exactness contract: the quadrature samples `round(Q(q)).max(2)` on a
/// uniform midpoint grid of the cut's quantile space, so as the grid is
/// refined it converges to exactly the integerized expectation this table
/// computes; [`CutMoments::err_iter`] bounds the gap at finite resolution.
/// The planner's *evaluated* cells keep the quadrature (bit-compatibility
/// with the pre-refactor oracles); the table powers the provably-safe
/// cost lower bounds of `planner::tiered::sweep_tiered_pruned` and the
/// opt-in `CellStatsMode::MomentTable` approximation.
#[derive(Clone, Debug)]
pub struct MomentTable {
    cdf: AnchoredCdf,
    output: OutputModel,
    chunk: u32,
    /// Smallest / largest integer token value with table mass.
    v0: u32,
    v1: u32,
    /// `cum_w1[j]` = sum over values `v0..=v0+j` of `mass(v) * gbar(v)`
    /// where `mass(v)` is the parent measure rounding to `v` (the lowest
    /// bucket absorbs everything below, mirroring the `.max(2.0)` clamp)
    /// and `gbar` the jitter-averaged iteration count.
    cum_w1: Vec<f64>,
    /// Same, with `gbar2` (jitter-averaged squared iterations).
    cum_w2: Vec<f64>,
    jitters: Vec<f64>,
}

impl MomentTable {
    /// One-time table build: O(support x jitter_points). Use
    /// [`MomentTable::for_workload`] to share builds process-wide.
    pub fn build(cdf: &AnchoredCdf, output: &OutputModel, chunk: u32) -> MomentTable {
        let jitters = jitter_grid(output, 8);
        let v0 = (cdf.min_tokens().round().max(2.0)) as u32;
        let v1 = (cdf.max_tokens().round()).max(v0 as f64) as u32;
        let len = (v1 - v0 + 1) as usize;
        let mut cum_w1 = Vec::with_capacity(len);
        let mut cum_w2 = Vec::with_capacity(len);
        let (mut acc1, mut acc2) = (0.0f64, 0.0f64);
        let mut f_prev = 0.0f64; // F below the lowest bucket = 0
        for v in v0..=v1 {
            let f_hi = if v == v1 { 1.0 } else { cdf.cdf(v as f64 + 0.5) };
            let mass = (f_hi - f_prev).max(0.0);
            if mass > 0.0 {
                let (g1, g2) = gbar(v as f64, &jitters, output, chunk);
                acc1 += mass * g1;
                acc2 += mass * g2;
            }
            cum_w1.push(acc1);
            cum_w2.push(acc2);
            f_prev = f_hi;
        }
        MomentTable {
            cdf: cdf.clone(),
            output: *output,
            chunk,
            v0,
            v1,
            cum_w1,
            cum_w2,
            jitters,
        }
    }

    /// Process-wide shared table for a workload (keyed by the workload's
    /// calibration fingerprint and the chunk size; bounded registry).
    pub fn for_workload(w: &Workload, chunk: u32) -> Arc<MomentTable> {
        const TABLE_CACHE_CAP: usize = 16;
        static TABLES: OnceLock<Mutex<FxHashMap<u64, Arc<MomentTable>>>> = OnceLock::new();
        let key = w
            .fingerprint()
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(chunk as u64);
        let tables = TABLES.get_or_init(|| Mutex::new(FxHashMap::default()));
        if let Some(t) = tables.lock().expect("table registry poisoned").get(&key) {
            return t.clone();
        }
        // Build outside the lock (builds are ~ms); a racing duplicate
        // build inserts an identical table and the first insert wins.
        let built = Arc::new(MomentTable::build(&w.cdf, &w.output, chunk));
        let mut m = tables.lock().expect("table registry poisoned");
        if m.len() >= TABLE_CACHE_CAP {
            // Drifting online CDF snapshots mint fresh fingerprints every
            // epoch; clearing wholesale bounds the registry like the
            // Erlang memo does.
            m.clear();
        }
        m.entry(key).or_insert(built).clone()
    }

    fn idx(&self, v: u32) -> usize {
        (v - self.v0) as usize
    }

    /// Jitter-averaged `(iterations, iterations^2)` at one integer length.
    fn gbar_at(&self, v: u32) -> (f64, f64) {
        gbar(v as f64, &self.jitters, &self.output, self.chunk)
    }

    /// Restricted moments over the cut `(lo, hi]` at the quadrature
    /// resolution `len_points` (only [`CutMoments::err_iter`] depends on
    /// it). `None` when the cut carries no parent mass.
    pub fn cut_moments(&self, lo: f64, hi: f64, len_points: usize) -> Option<CutMoments> {
        assert!(hi > lo && len_points >= 16);
        let f_lo = self.cdf.cdf(lo);
        let f_hi = self.cdf.cdf(hi);
        let mass = f_hi - f_lo;
        if mass <= 0.0 {
            return None;
        }
        // Bucket of a value x is round(x) (clamped into [v0, v1]); the
        // edge buckets are partially covered by the cut, every interior
        // bucket fully — and `round(lo) - 0.5 <= lo`, so nothing in the
        // cut rounds below `va` (resp. above `vb`).
        let va = (lo.round().max(self.v0 as f64)) as u32;
        let vb = (hi.round().clamp(self.v0 as f64, self.v1 as f64)) as u32;
        let (ga1, ga2) = self.gbar_at(va);
        let (s1, s2) = if va >= vb {
            (mass * ga1, mass * ga2)
        } else {
            let (gb1, gb2) = self.gbar_at(vb);
            let m_lo = (self.cdf.cdf((va as f64 + 0.5).min(hi)) - f_lo).max(0.0);
            let m_hi = (f_hi - self.cdf.cdf((vb as f64 - 0.5).max(lo))).max(0.0);
            let (i1, i2) = if vb > va + 1 {
                (
                    self.cum_w1[self.idx(vb - 1)] - self.cum_w1[self.idx(va)],
                    self.cum_w2[self.idx(vb - 1)] - self.cum_w2[self.idx(va)],
                )
            } else {
                (0.0, 0.0)
            };
            (m_lo * ga1 + i1 + m_hi * gb1, m_lo * ga2 + i2 + m_hi * gb2)
        };
        let e_iter = s1 / mass;
        let e_iter2 = s2 / mass;
        // Midpoint-rule gap bound for a monotone step function, inflated
        // for the rare +-1 rounding wiggles (`split_request` keeps l_in
        // and l_out non-decreasing in l_total for jitter factors <= 1 and
        // under the 0.9 / max_tokens clamps beyond) and for the
        // quadrature's sequential Welford accumulation.
        let span = if va >= vb {
            0.0
        } else {
            (self.gbar_at(vb).0 - ga1).max(0.0)
        };
        // Midpoint-rule term plus a float-cancellation term: the prefix
        // difference loses absolute precision that `/ mass` amplifies on
        // thin cuts, so thin cuts get a proportionally wider bound.
        let err_iter = (span * 1.5 + 2.0) / len_points as f64
            + (e_iter.abs() + 1.0) * 1e-9 / mass.max(1e-12);
        Some(CutMoments {
            mass,
            e_iter,
            e_iter2,
            err_iter,
        })
    }

    /// P99 prefill chunk count over the cut: the smallest chunk count `m`
    /// whose restricted probability reaches 0.99, assuming `l_in` is
    /// non-decreasing in the total budget per jitter (see
    /// [`split_request`]). Approximate at bucket granularity — used only
    /// by the opt-in table-stats mode, never by the exact sweep path.
    fn p99_prefill_chunks(&self, lo: f64, hi: f64) -> Option<f64> {
        let f_lo = self.cdf.cdf(lo);
        let f_hi = self.cdf.cdf(hi);
        let mass = f_hi - f_lo;
        if mass <= 0.0 {
            return None;
        }
        let va = (lo.round().max(self.v0 as f64)) as u32;
        let vb = (hi.round().clamp(self.v0 as f64, self.v1 as f64)) as u32;
        // P[chunks <= m | cut], averaged over the jitter grid
        // (`ceil(l_in / chunk) <= m` iff `l_in <= m * chunk`).
        let p_le = |m: u64| -> f64 {
            let budget = m * self.chunk as u64;
            let mut acc = 0.0;
            for &jit in &self.jitters {
                // Largest v in [va, vb] with l_in(v, jit) <= budget.
                let (l_in_lo, _) = split_request(va as f64, jit, &self.output);
                if l_in_lo as u64 > budget {
                    continue;
                }
                let (mut l, mut r) = (va, vb);
                while l < r {
                    let mid = l + (r - l).div_ceil(2);
                    let (l_in, _) = split_request(mid as f64, jit, &self.output);
                    if l_in as u64 <= budget {
                        l = mid;
                    } else {
                        r = mid - 1;
                    }
                }
                let cover = (self.cdf.cdf((l as f64 + 0.5).min(hi)) - f_lo).max(0.0);
                acc += (cover / mass).min(1.0);
            }
            acc / self.jitters.len() as f64
        };
        let (mut l, mut r) = (1u64, (self.v1 as u64).div_ceil(self.chunk as u64).max(1));
        if p_le(r) < 0.99 {
            return Some(r as f64);
        }
        while l < r {
            let mid = l + (r - l) / 2;
            if p_le(mid) >= 0.99 {
                r = mid;
            } else {
                l = mid + 1;
            }
        }
        Some(l as f64)
    }

    /// Approximate calibrated stats for a cut — the `CellStatsMode::
    /// MomentTable` path. `E[S]`/SCV are the exact integerized integrals
    /// (within [`CutMoments::err_iter`] of the quadrature); the P99
    /// prefill is quantized to whole chunks. `None` on a massless cut.
    pub fn stats(&self, lo: f64, hi: f64, n_slots: u32, g: &GpuProfile) -> Option<ServiceStats> {
        let m = self.cut_moments(lo, hi, 64)?;
        let t_iter = g.t_iter_s(n_slots);
        let scv = (m.e_iter2 / (m.e_iter * m.e_iter) - 1.0).max(0.0);
        let chunks99 = self.p99_prefill_chunks(lo, hi)?;
        Some(ServiceStats {
            e_s: m.e_iter * t_iter,
            scv,
            p99_prefill_s: chunks99 * t_iter,
            t_iter_s: t_iter,
            n_slots,
        })
    }
}

/// Jitter-averaged `(E[iterations], E[iterations^2])` at one integerized
/// total budget — the same split and iteration count the quadrature path
/// pushes into its Welford accumulator.
fn gbar(l_total: f64, jitters: &[f64], output: &OutputModel, chunk: u32) -> (f64, f64) {
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    for &jit in jitters {
        let (l_in, l_out) = split_request(l_total, jit, output);
        let it = slot_iterations(l_in, l_out, chunk) as f64;
        s1 += it;
        s2 += it * it;
    }
    let n = jitters.len() as f64;
    (s1 / n, s2 / n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces;

    fn g() -> GpuProfile {
        GpuProfile::a100_llama70b()
    }

    #[test]
    fn slot_iterations_matches_eq4() {
        // ceil(1000/512) + 100 = 2 + 100
        assert_eq!(slot_iterations(1000, 100, 512), 102);
        assert_eq!(slot_iterations(512, 1, 512), 2);
        assert_eq!(slot_iterations(513, 1, 512), 3);
        assert_eq!(slot_iterations(1, 1, 512), 2);
    }

    #[test]
    fn service_time_example() {
        // Long pool: t_iter = 18.4 ms; 10 prefill chunks + 900 decode steps.
        let s = service_time_s(5120, 900, &g(), 16);
        assert!((s - 910.0 * 0.0184).abs() < 1e-9);
    }

    #[test]
    fn prefill_time_independent_of_output() {
        let p = prefill_time_s(4096, &g(), 16);
        assert!((p - 8.0 * 0.0184).abs() < 1e-9);
    }

    #[test]
    fn calibrate_deterministic() {
        let w = traces::azure();
        let a = calibrate(&w.cdf, &w.output, &g(), 256, 5_000, 1);
        let b = calibrate(&w.cdf, &w.output, &g(), 256, 5_000, 1);
        assert_eq!(a.e_s, b.e_s);
        assert_eq!(a.scv, b.scv);
    }

    #[test]
    fn calibrate_constant_length_has_zero_ish_scv() {
        // A point-mass length distribution with jitter-free outputs gives a
        // (nearly) deterministic service time.
        let dist = AnchoredCdf::new(vec![(999.999, 0.0), (1000.0, 1.0)]);
        let output = crate::workload::request::OutputModel {
            frac: 0.1,
            sigma: 0.0,
            min_tokens: 100,
            max_tokens: 100,
        };
        let s = calibrate(&dist, &output, &g(), 16, 2_000, 2);
        assert!(s.scv < 1e-6, "scv={}", s.scv);
    }

    #[test]
    fn longer_pool_distribution_has_larger_e_s() {
        let w = traces::agent_heavy();
        let short = crate::workload::cdf::TruncatedDist::new(w.cdf.clone(), 64.0, 8192.0);
        let long =
            crate::workload::cdf::TruncatedDist::new(w.cdf.clone(), 8192.0, 65536.0);
        let ss = calibrate(&short, &w.output, &g(), 128, 10_000, 3);
        let sl = calibrate(&long, &w.output, &g(), 16, 10_000, 3);
        // Long requests occupy slots for longer even at the long pool's
        // smaller t_iter... actually t_iter long < t_iter short (16 vs 128
        // slots), so compare iteration counts via e_s / t_iter.
        assert!(sl.e_s / sl.t_iter_s > ss.e_s / ss.t_iter_s);
    }

    #[test]
    fn mu_gpu_scales_with_slots() {
        let w = traces::azure();
        let a = calibrate(&w.cdf, &w.output, &g(), 16, 5_000, 4);
        // mu_gpu = n_slots / E[S]
        assert!((a.mu_gpu() - 16.0 / a.e_s).abs() < 1e-12);
        assert!((a.mu_slot() - 1.0 / a.e_s).abs() < 1e-12);
    }

    #[test]
    fn probit_known_values() {
        assert!((probit(0.5)).abs() < 1e-9);
        assert!((probit(0.975) - 1.959964).abs() < 1e-5);
        assert!((probit(0.025) + 1.959964).abs() < 1e-5);
        assert!((probit(0.99) - 2.326348).abs() < 1e-5);
    }

    #[test]
    fn quadrature_matches_monte_carlo() {
        // The fast path must agree with the 20k-sample MC reference within
        // ~2% on E[S] and loosely on C_s^2 / p99 prefill.
        for w in [traces::azure(), traces::agent_heavy()] {
            for n_slots in [16u32, 128] {
                let mc = calibrate(&w.cdf, &w.output, &g(), n_slots, 20_000, 9);
                let quad =
                    calibrate_quadrature(&w.cdf, &w.output, &g(), n_slots, 128, 8);
                assert!(
                    (quad.e_s - mc.e_s).abs() / mc.e_s < 0.02,
                    "{} E[S]: quad {} vs mc {}",
                    w.name,
                    quad.e_s,
                    mc.e_s
                );
                assert!(
                    (quad.scv - mc.scv).abs() / mc.scv.max(0.1) < 0.15,
                    "{} scv: quad {} vs mc {}",
                    w.name,
                    quad.scv,
                    mc.scv
                );
                assert!(
                    (quad.p99_prefill_s - mc.p99_prefill_s).abs() / mc.p99_prefill_s
                        < 0.15,
                    "{} p99 prefill: quad {} vs mc {}",
                    w.name,
                    quad.p99_prefill_s,
                    mc.p99_prefill_s
                );
            }
        }
    }

    #[test]
    fn quadrature_is_deterministic_and_seedless() {
        let w = traces::lmsys();
        let a = calibrate_quadrature(&w.cdf, &w.output, &g(), 64, 96, 4);
        let b = calibrate_quadrature(&w.cdf, &w.output, &g(), 64, 96, 4);
        assert_eq!(a.e_s, b.e_s);
        assert_eq!(a.scv, b.scv);
    }

    #[test]
    fn moment_table_tracks_the_quadrature_within_its_error_bound() {
        // The table's E[iter] is the exact integerized integral; the
        // N-point quadrature must sit within CutMoments::err_iter of it —
        // the invariant the planner's prune bound is built on — and the
        // gap must shrink at the ~1/N rate as the grid refines.
        for w in [traces::azure(), traces::lmsys(), traces::agent_heavy()] {
            let table = MomentTable::build(&w.cdf, &w.output, g().chunk);
            let cuts = [
                (w.cdf.min_tokens(), w.b_short as f64),
                (w.b_short as f64 * 1.5, w.cdf.max_tokens()),
                (w.cdf.min_tokens(), w.cdf.max_tokens()),
                (1024.0, 3000.0),
            ];
            for &(lo, hi) in &cuts {
                if w.cdf.cdf(hi) - w.cdf.cdf(lo) <= 1e-9 {
                    continue;
                }
                let dist = crate::workload::cdf::TruncatedDist::new(w.cdf.clone(), lo, hi);
                for n in [128usize, 512, 2048] {
                    let m = table.cut_moments(lo, hi, n).expect("cut has mass");
                    let quad = calibrate_quadrature(&dist, &w.output, &g(), 64, n, 8);
                    let quad_iter = quad.e_s / quad.t_iter_s;
                    assert!(
                        (quad_iter - m.e_iter).abs() <= m.err_iter,
                        "{} cut ({lo}, {hi}] N={n}: quad {quad_iter} vs table {} (err {})",
                        w.name,
                        m.e_iter,
                        m.err_iter
                    );
                }
                // SCV agrees loosely at high resolution (both estimate
                // the same second moment).
                let m = table.cut_moments(lo, hi, 2048).expect("mass");
                let quad = calibrate_quadrature(&dist, &w.output, &g(), 64, 2048, 8);
                let table_scv = (m.e_iter2 / (m.e_iter * m.e_iter) - 1.0).max(0.0);
                assert!(
                    (table_scv - quad.scv).abs() <= 0.05 * (1.0 + quad.scv),
                    "{} cut ({lo}, {hi}]: scv table {table_scv} vs quad {}",
                    w.name,
                    quad.scv
                );
            }
        }
    }

    #[test]
    fn moment_table_stats_mode_is_close_to_quadrature() {
        // The opt-in CellStatsMode::MomentTable stats: E[S] within the
        // declared bound of the default 512-point quadrature, P99 prefill
        // within one chunk of it.
        let w = traces::azure();
        let table = MomentTable::build(&w.cdf, &w.output, g().chunk);
        let cuts = [(16.0f64, 4096.0f64, 256u32), (6144.0, 65536.0, 16), (16.0, 65536.0, 16)];
        for &(lo, hi, n_slots) in &cuts {
            let s = table.stats(lo, hi, n_slots, &g()).expect("mass");
            let dist = crate::workload::cdf::TruncatedDist::new(w.cdf.clone(), lo, hi);
            let quad = calibrate_quadrature(&dist, &w.output, &g(), n_slots, 512, 8);
            let m = table.cut_moments(lo, hi, 512).expect("mass");
            assert!(
                (s.e_s - quad.e_s).abs() <= m.err_iter * s.t_iter_s,
                "cut ({lo}, {hi}]: e_s {} vs quad {}",
                s.e_s,
                quad.e_s
            );
            // P99 prefill: both quantize to whole chunks; near a quantile
            // boundary the sample quantile can land a few thin tail bins
            // away from the distributional one.
            assert!(
                (s.p99_prefill_s - quad.p99_prefill_s).abs()
                    <= 3.0 * s.t_iter_s + 0.05 * quad.p99_prefill_s,
                "cut ({lo}, {hi}]: p99 prefill {} vs quad {}",
                s.p99_prefill_s,
                quad.p99_prefill_s
            );
        }
    }

    #[test]
    fn moment_table_registry_shares_builds() {
        let w = traces::lmsys();
        let a = MomentTable::for_workload(&w, g().chunk);
        let b = MomentTable::for_workload(&w, g().chunk);
        assert!(Arc::ptr_eq(&a, &b), "same workload must share one table");
        let other = MomentTable::for_workload(&traces::azure(), g().chunk);
        assert!(!Arc::ptr_eq(&a, &other));
    }

    #[test]
    fn p99_prefill_exceeds_mean_prefill() {
        let w = traces::agent_heavy();
        let s = calibrate(&w.cdf, &w.output, &g(), 16, 20_000, 5);
        // Sanity: p99 prefill must be positive and > one iteration.
        assert!(s.p99_prefill_s > s.t_iter_s);
    }
}
