//! Service-time model under continuous batching (paper Eq. 3–4) and the
//! Monte-Carlo calibration of `(E[S], C_s^2)` used by the planner.
//!
//! A request with `L_in` input and `L_out` output tokens occupies a KV slot
//! for `ceil(L_in / C_chunk) + L_out` lockstep iterations of duration
//! `t_iter = W + H * n_max` — all `n_max` slots advance together, so the
//! iteration latency is evaluated at the configured slot count (§3.1).

use crate::config::GpuProfile;
use crate::util::rng::Rng;
use crate::util::stats::{Samples, Welford};
use crate::workload::cdf::LengthDist;
use crate::workload::request::OutputModel;

/// Number of slot iterations a request occupies (Eq. 4's parenthesised term).
pub fn slot_iterations(l_in: u32, l_out: u32, chunk: u32) -> u64 {
    (l_in as u64).div_ceil(chunk as u64) + l_out as u64
}

/// Wall-clock slot occupancy E[S] for a single request, seconds (Eq. 4).
pub fn service_time_s(l_in: u32, l_out: u32, g: &GpuProfile, n_slots: u32) -> f64 {
    slot_iterations(l_in, l_out, g.chunk) as f64 * g.t_iter_s(n_slots)
}

/// Physical prefill time for a request, seconds (§3.2):
/// `T_prefill = ceil(L_in / C_chunk) * t_iter`.
pub fn prefill_time_s(l_in: u32, g: &GpuProfile, n_slots: u32) -> f64 {
    (l_in as u64).div_ceil(g.chunk as u64) as f64 * g.t_iter_s(n_slots)
}

/// Calibrated service statistics for one pool. Plain scalar data: `Copy`,
/// so passing it around costs a register copy — no clones on the planner's
/// per-cell hot path (§Perf).
#[derive(Clone, Copy, Debug)]
pub struct ServiceStats {
    /// Mean slot occupancy E[S], seconds.
    pub e_s: f64,
    /// Squared coefficient of variation C_s^2 = Var[S]/E[S]^2.
    pub scv: f64,
    /// P99 physical prefill time, seconds (enters the SLO budget, Eq. 8).
    pub p99_prefill_s: f64,
    /// Iteration latency at the pool's configured slot count.
    pub t_iter_s: f64,
    /// Slots per GPU in this pool.
    pub n_slots: u32,
}

impl ServiceStats {
    /// Per-slot service rate mu = 1/E[S] (requests/sec/slot).
    pub fn mu_slot(&self) -> f64 {
        1.0 / self.e_s
    }

    /// GPU-level throughput mu_gpu = n_max / E[S] (§3.1).
    pub fn mu_gpu(&self) -> f64 {
        self.n_slots as f64 / self.e_s
    }
}

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// |rel err| < 1.15e-9). Used by the quadrature calibration to enumerate
/// lognormal-jitter quantiles deterministically.
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Deterministic quadrature calibration: the planner's fast path
/// (§Perf). Replaces Monte-Carlo sampling with a midpoint rule over the
/// length distribution's quantile function crossed with a small grid of
/// lognormal-jitter quantiles for the output model. ~100x fewer
/// distribution evaluations than the 20k-sample MC at matching accuracy
/// (cross-validated in tests), and exactly reproducible with no seed.
pub fn calibrate_quadrature<D: LengthDist>(
    dist: &D,
    output: &OutputModel,
    g: &GpuProfile,
    n_slots: u32,
    len_points: usize,
    jitter_points: usize,
) -> ServiceStats {
    assert!(len_points >= 16 && jitter_points >= 1);
    let t_iter = g.t_iter_s(n_slots);
    // Precompute jitter factors at midpoint quantiles.
    let jitters: Vec<f64> = (0..jitter_points)
        .map(|j| {
            if output.sigma == 0.0 || jitter_points == 1 {
                1.0
            } else {
                let q = (j as f64 + 0.5) / jitter_points as f64;
                (output.sigma * probit(q)).exp()
            }
        })
        .collect();

    let mut w = Welford::new();
    let mut prefill = Samples::with_capacity(len_points * jitter_points);
    for i in 0..len_points {
        let q = (i as f64 + 0.5) / len_points as f64;
        let l_total = dist.quantile(q).round().max(2.0);
        for &jit in &jitters {
            let out = (output.frac * l_total * jit).round();
            let l_out = (out as u32)
                .clamp(output.min_tokens, output.max_tokens)
                .min((l_total * 0.9) as u32)
                .max(1);
            let l_in = (l_total as u32).saturating_sub(l_out).max(1);
            w.push(slot_iterations(l_in, l_out, g.chunk) as f64 * t_iter);
            prefill.push(prefill_time_s(l_in, g, n_slots));
        }
    }
    ServiceStats {
        e_s: w.mean(),
        scv: w.scv(),
        p99_prefill_s: prefill.p99(),
        t_iter_s: t_iter,
        n_slots,
    }
}

/// Monte-Carlo calibration of `(E[S], C_s^2, P99 prefill)` from a pool's
/// request-length distribution (paper §3.1: "estimated by Monte Carlo
/// sampling from the pool's request distribution"). Deterministic under
/// `seed`. The planner's hot path uses [`calibrate_quadrature`]; this MC
/// version is the reference the quadrature is validated against.
pub fn calibrate<D: LengthDist>(
    dist: &D,
    output: &OutputModel,
    g: &GpuProfile,
    n_slots: u32,
    samples: usize,
    seed: u64,
) -> ServiceStats {
    assert!(samples >= 100, "too few samples for a stable C_s^2");
    let mut rng = Rng::new(seed);
    let t_iter = g.t_iter_s(n_slots);
    let mut w = Welford::new();
    let mut prefill = Samples::with_capacity(samples);
    for _ in 0..samples {
        let l_total = dist.sample(&mut rng).round().max(2.0);
        let l_out = output.sample_l_out(l_total, &mut rng);
        let l_in = (l_total as u32).saturating_sub(l_out).max(1);
        w.push(slot_iterations(l_in, l_out, g.chunk) as f64 * t_iter);
        prefill.push(prefill_time_s(l_in, g, n_slots));
    }
    ServiceStats {
        e_s: w.mean(),
        scv: w.scv(),
        p99_prefill_s: prefill.p99(),
        t_iter_s: t_iter,
        n_slots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::AnchoredCdf;
    use crate::workload::traces;

    fn g() -> GpuProfile {
        GpuProfile::a100_llama70b()
    }

    #[test]
    fn slot_iterations_matches_eq4() {
        // ceil(1000/512) + 100 = 2 + 100
        assert_eq!(slot_iterations(1000, 100, 512), 102);
        assert_eq!(slot_iterations(512, 1, 512), 2);
        assert_eq!(slot_iterations(513, 1, 512), 3);
        assert_eq!(slot_iterations(1, 1, 512), 2);
    }

    #[test]
    fn service_time_example() {
        // Long pool: t_iter = 18.4 ms; 10 prefill chunks + 900 decode steps.
        let s = service_time_s(5120, 900, &g(), 16);
        assert!((s - 910.0 * 0.0184).abs() < 1e-9);
    }

    #[test]
    fn prefill_time_independent_of_output() {
        let p = prefill_time_s(4096, &g(), 16);
        assert!((p - 8.0 * 0.0184).abs() < 1e-9);
    }

    #[test]
    fn calibrate_deterministic() {
        let w = traces::azure();
        let a = calibrate(&w.cdf, &w.output, &g(), 256, 5_000, 1);
        let b = calibrate(&w.cdf, &w.output, &g(), 256, 5_000, 1);
        assert_eq!(a.e_s, b.e_s);
        assert_eq!(a.scv, b.scv);
    }

    #[test]
    fn calibrate_constant_length_has_zero_ish_scv() {
        // A point-mass length distribution with jitter-free outputs gives a
        // (nearly) deterministic service time.
        let dist = AnchoredCdf::new(vec![(999.999, 0.0), (1000.0, 1.0)]);
        let output = crate::workload::request::OutputModel {
            frac: 0.1,
            sigma: 0.0,
            min_tokens: 100,
            max_tokens: 100,
        };
        let s = calibrate(&dist, &output, &g(), 16, 2_000, 2);
        assert!(s.scv < 1e-6, "scv={}", s.scv);
    }

    #[test]
    fn longer_pool_distribution_has_larger_e_s() {
        let w = traces::agent_heavy();
        let short = crate::workload::cdf::TruncatedDist::new(w.cdf.clone(), 64.0, 8192.0);
        let long =
            crate::workload::cdf::TruncatedDist::new(w.cdf.clone(), 8192.0, 65536.0);
        let ss = calibrate(&short, &w.output, &g(), 128, 10_000, 3);
        let sl = calibrate(&long, &w.output, &g(), 16, 10_000, 3);
        // Long requests occupy slots for longer even at the long pool's
        // smaller t_iter... actually t_iter long < t_iter short (16 vs 128
        // slots), so compare iteration counts via e_s / t_iter.
        assert!(sl.e_s / sl.t_iter_s > ss.e_s / ss.t_iter_s);
    }

    #[test]
    fn mu_gpu_scales_with_slots() {
        let w = traces::azure();
        let a = calibrate(&w.cdf, &w.output, &g(), 16, 5_000, 4);
        // mu_gpu = n_slots / E[S]
        assert!((a.mu_gpu() - 16.0 / a.e_s).abs() < 1e-12);
        assert!((a.mu_slot() - 1.0 / a.e_s).abs() < 1e-12);
    }

    #[test]
    fn probit_known_values() {
        assert!((probit(0.5)).abs() < 1e-9);
        assert!((probit(0.975) - 1.959964).abs() < 1e-5);
        assert!((probit(0.025) + 1.959964).abs() < 1e-5);
        assert!((probit(0.99) - 2.326348).abs() < 1e-5);
    }

    #[test]
    fn quadrature_matches_monte_carlo() {
        // The fast path must agree with the 20k-sample MC reference within
        // ~2% on E[S] and loosely on C_s^2 / p99 prefill.
        for w in [traces::azure(), traces::agent_heavy()] {
            for n_slots in [16u32, 128] {
                let mc = calibrate(&w.cdf, &w.output, &g(), n_slots, 20_000, 9);
                let quad =
                    calibrate_quadrature(&w.cdf, &w.output, &g(), n_slots, 128, 8);
                assert!(
                    (quad.e_s - mc.e_s).abs() / mc.e_s < 0.02,
                    "{} E[S]: quad {} vs mc {}",
                    w.name,
                    quad.e_s,
                    mc.e_s
                );
                assert!(
                    (quad.scv - mc.scv).abs() / mc.scv.max(0.1) < 0.15,
                    "{} scv: quad {} vs mc {}",
                    w.name,
                    quad.scv,
                    mc.scv
                );
                assert!(
                    (quad.p99_prefill_s - mc.p99_prefill_s).abs() / mc.p99_prefill_s
                        < 0.15,
                    "{} p99 prefill: quad {} vs mc {}",
                    w.name,
                    quad.p99_prefill_s,
                    mc.p99_prefill_s
                );
            }
        }
    }

    #[test]
    fn quadrature_is_deterministic_and_seedless() {
        let w = traces::lmsys();
        let a = calibrate_quadrature(&w.cdf, &w.output, &g(), 64, 96, 4);
        let b = calibrate_quadrature(&w.cdf, &w.output, &g(), 64, 96, 4);
        assert_eq!(a.e_s, b.e_s);
        assert_eq!(a.scv, b.scv);
    }

    #[test]
    fn p99_prefill_exceeds_mean_prefill() {
        let w = traces::agent_heavy();
        let s = calibrate(&w.cdf, &w.output, &g(), 16, 20_000, 5);
        // Sanity: p99 prefill must be positive and > one iteration.
        assert!(s.p99_prefill_s > s.t_iter_s);
    }
}
