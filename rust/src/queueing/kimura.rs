//! Kimura's two-moment M/G/c approximation for tail waiting time
//! (paper Eq. 6; Kimura 1994).
//!
//! `W99 = ln(C(c, rho) / 0.01) * (1 + Cs^2) / (2 (c mu - lambda))`
//!
//! The exponential-tail form: P[W > t] ~ C * exp(-2(c mu - lambda) t / (1 + Cs^2)),
//! solved for the 99th percentile. When `C <= 0.01` an arriving request has
//! less than a 1% chance of waiting at all, so the P99 wait is 0 — the
//! "many-server regime" the paper's fleets operate in (§7.4).

use crate::queueing::erlang::erlang_c_cached;

/// P-quantile of the queue waiting time for an M/G/c with `c` servers,
/// per-server rate `mu`, arrival rate `lambda`, and service-time SCV `cs2`.
/// `p` is the tail mass (0.01 for P99). Erlang-C goes through the
/// thread-local memo (§Perf: the sizing inversion revisits cells) —
/// bit-identical to the direct recurrence. W99 is monotone non-increasing
/// in `c` above the stability point (tested below and in
/// `planner::sizing`) — the property that makes both the sizing bisection
/// and its warm-started bracket exact.
pub fn w_quantile(c: u64, mu: f64, lambda: f64, cs2: f64, p: f64) -> f64 {
    assert!(mu > 0.0 && lambda >= 0.0 && p > 0.0 && p < 1.0);
    let capacity = c as f64 * mu;
    if lambda >= capacity {
        return f64::INFINITY;
    }
    if lambda == 0.0 {
        return 0.0;
    }
    let rho = lambda / capacity;
    let c_wait = erlang_c_cached(c, rho);
    if c_wait <= p {
        return 0.0;
    }
    (c_wait / p).ln() * (1.0 + cs2) / (2.0 * (capacity - lambda))
}

/// P99 queue waiting time (paper Eq. 6).
pub fn w99(c: u64, mu: f64, lambda: f64, cs2: f64) -> f64 {
    w_quantile(c, mu, lambda, cs2, 0.01)
}

/// Mean waiting time under the same exponential-tail approximation
/// (Kimura's two-moment mean): `Wq = C * (1 + Cs^2) / (2 (c mu - lambda))`.
pub fn w_mean(c: u64, mu: f64, lambda: f64, cs2: f64) -> f64 {
    let capacity = c as f64 * mu;
    if lambda >= capacity {
        return f64::INFINITY;
    }
    erlang_c_cached(c, lambda / capacity) * (1.0 + cs2) / (2.0 * (capacity - lambda))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm1_mean_wait_matches_exact() {
        // M/M/1 (cs2 = 1): Wq = rho / (mu - lambda). Kimura's two-moment
        // mean is exact for M/M/1.
        let (mu, lambda) = (1.0, 0.8);
        let got = w_mean(1, mu, lambda, 1.0);
        let want = 0.8 / (1.0 - 0.8);
        assert!((got - want).abs() < 1e-9, "got={got} want={want}");
    }

    #[test]
    fn mm1_p99_matches_exact() {
        // M/M/1: P[W > t] = rho * exp(-(mu - lambda) t); P99 wait
        // = ln(rho/0.01)/(mu - lambda). Kimura with cs2=1 reproduces it.
        let (mu, lambda) = (1.0, 0.8);
        let got = w99(1, mu, lambda, 1.0);
        let want = (0.8f64 / 0.01).ln() / (1.0 - 0.8);
        assert!((got - want).abs() < 1e-9);
    }

    #[test]
    fn unstable_is_infinite() {
        assert!(w99(4, 1.0, 4.0, 1.0).is_infinite());
        assert!(w99(4, 1.0, 5.0, 1.0).is_infinite());
    }

    #[test]
    fn many_server_regime_is_zero() {
        // Paper §7.4: thousands of slots at rho = 0.85 -> W99 = 0.
        assert_eq!(w99(2096, 1.0, 0.85 * 2096.0, 2.0), 0.0);
    }

    #[test]
    fn higher_variance_waits_longer() {
        // Small c and high rho so C(c, rho) > 0.01.
        let (c, mu, lambda) = (2, 1.0, 1.9);
        let low = w99(c, mu, lambda, 0.5);
        let high = w99(c, mu, lambda, 4.0);
        assert!(high > low);
    }

    #[test]
    fn deterministic_service_halves_mm1_wait() {
        // M/D/1 mean wait = half of M/M/1 (cs2 = 0 vs 1).
        let (mu, lambda) = (1.0, 0.9);
        let md1 = w_mean(1, mu, lambda, 0.0);
        let mm1 = w_mean(1, mu, lambda, 1.0);
        assert!((md1 * 2.0 - mm1).abs() < 1e-12);
    }

    #[test]
    fn w99_monotone_decreasing_in_c_at_fixed_lambda() {
        // Adding servers at fixed lambda can only reduce the P99 wait.
        let (mu, lambda, cs2) = (1.0, 1.8, 1.5);
        let mut last = f64::INFINITY;
        for c in 2..12u64 {
            let w = w99(c, mu, lambda, cs2);
            assert!(w <= last + 1e-12, "c={c}: {w} > {last}");
            last = w;
        }
    }

    #[test]
    fn zero_arrivals_zero_wait() {
        assert_eq!(w99(4, 1.0, 0.0, 1.0), 0.0);
    }
}
