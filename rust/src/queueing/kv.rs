//! KV-occupancy service model and the closed-form KV stability boundary
//! (ROADMAP item 4). The slot model (Eq. 3–4) counts *slots*; this module
//! counts *tokens*: a request that is resident for `T = ceil(L_in/chunk)
//! + L_out` lockstep iterations holds a KV reservation of `L_in + L_out`
//! tokens for all of them (the engines reserve the full decode budget at
//! admission, so a request can never be evicted mid-decode — see
//! `fleetsim`). By Little's law the steady-state expected reserved tokens
//! per pool are `lambda * E[(L_in + L_out) * T] * t_iter`, which against a
//! per-GPU capacity of `cap_tokens` gives the utilization
//!
//! ```text
//! rho_kv = lambda * E[(L_in + L_out) * T] * t_iter / (n_gpus * cap_tokens)
//! ```
//!
//! and the stability boundary `rho_kv < 1` ("A Queueing-Theoretic
//! Framework for Stability Analysis of LLM Inference with KV Cache Memory
//! Constraints", PAPERS.md). The calibration below integrates the exact
//! same `(len_points x jitter_points)` midpoint grids as
//! [`calibrate_quadrature`](crate::queueing::service::calibrate_quadrature),
//! so the analytical boundary and the slot stats describe one and the
//! same integerized request population.

use crate::config::GpuProfile;
use crate::util::stats::Welford;
use crate::workload::cdf::LengthDist;
use crate::workload::request::OutputModel;

use super::service::{jitter_grid, slot_iterations, split_request};

/// Calibrated KV-occupancy statistics for one pool. Plain scalar data
/// (`Copy`), mirroring [`ServiceStats`](super::service::ServiceStats).
#[derive(Clone, Copy, Debug)]
pub struct KvStats {
    /// `E[(L_in + L_out) * T]` in token-iterations: the mean KV
    /// reservation (tokens) times the iterations it is held.
    pub e_kv_iter: f64,
    /// `E[T]` — mean resident iterations (the slot model's `e_s / t_iter`).
    pub e_iter: f64,
    /// `E[L_in + L_out]` — mean reserved tokens per request.
    pub e_tokens: f64,
    /// Iteration latency at the pool's configured slot count, seconds.
    pub t_iter_s: f64,
    /// Slots per GPU in this pool.
    pub n_slots: u32,
}

impl KvStats {
    /// Mean KV token-seconds one request contributes:
    /// `E[(L_in + L_out) * T] * t_iter`.
    pub fn e_kv_s(&self) -> f64 {
        self.e_kv_iter * self.t_iter_s
    }

    /// These stats on silicon `mu_scale` times as fast — the same uniform
    /// time dilation as [`ServiceStats::scaled_mu`]: only `t_iter_s`
    /// divides; token and iteration counts are invariant. `mu_scale = 1`
    /// returns `self` unchanged (single-SKU bit-identity by construction).
    pub fn scaled_mu(self, mu_scale: f64) -> KvStats {
        if mu_scale == 1.0 {
            return self;
        }
        KvStats {
            t_iter_s: self.t_iter_s / mu_scale,
            ..self
        }
    }
}

/// Deterministic quadrature calibration of the KV moments over the same
/// midpoint grids as the slot-stats quadrature: `len_points` quantile
/// midpoints of the length distribution crossed with the output model's
/// lognormal-jitter grid, split by [`split_request`]. Seedless and
/// exactly reproducible.
pub fn calibrate_kv_quadrature<D: LengthDist>(
    dist: &D,
    output: &OutputModel,
    g: &GpuProfile,
    n_slots: u32,
    len_points: usize,
    jitter_points: usize,
) -> KvStats {
    assert!(len_points >= 16 && jitter_points >= 1);
    let jitters = jitter_grid(output, jitter_points);
    let mut kv = Welford::new();
    let mut iters = Welford::new();
    let mut toks = Welford::new();
    for i in 0..len_points {
        let q = (i as f64 + 0.5) / len_points as f64;
        let l_total = dist.quantile(q).round().max(2.0);
        for &jit in &jitters {
            let (l_in, l_out) = split_request(l_total, jit, output);
            let t = slot_iterations(l_in, l_out, g.chunk) as f64;
            let tokens = (l_in + l_out) as f64;
            kv.push(tokens * t);
            iters.push(t);
            toks.push(tokens);
        }
    }
    KvStats {
        e_kv_iter: kv.mean(),
        e_iter: iters.mean(),
        e_tokens: toks.mean(),
        t_iter_s: g.t_iter_s(n_slots),
        n_slots,
    }
}

/// KV utilization `rho_kv` of a pool of `n_gpus` GPUs, each with
/// `cap_tokens` of KV capacity, under arrival rate `lambda` (req/s).
pub fn rho_kv(lambda: f64, n_gpus: u64, cap_tokens: u64, kv: &KvStats) -> f64 {
    if n_gpus == 0 || cap_tokens == 0 {
        return f64::INFINITY;
    }
    lambda * kv.e_kv_s() / (n_gpus as f64 * cap_tokens as f64)
}

/// The KV stability boundary `lambda*`: the arrival rate at which
/// `rho_kv = 1` for the given pool. Queues grow without bound beyond it.
pub fn lambda_star(n_gpus: u64, cap_tokens: u64, kv: &KvStats) -> f64 {
    n_gpus as f64 * cap_tokens as f64 / kv.e_kv_s()
}

/// Minimum GPUs to keep `rho_kv <= rho_max` at arrival rate `lambda` —
/// the closed-form KV sizing floor the planner takes a `max` with
/// (never replacing the slot-model Erlang sizing, only raising it).
pub fn min_gpus_kv(lambda: f64, cap_tokens: u64, rho_max: f64, kv: &KvStats) -> u64 {
    if lambda <= 0.0 {
        return 0;
    }
    assert!(rho_max > 0.0 && cap_tokens > 0);
    (lambda * kv.e_kv_s() / (rho_max * cap_tokens as f64)).ceil() as u64
}

/// Planner-facing KV capacity policy: what fraction of a GPU's
/// calibration token budget (`n_max_calib * c_calib` slots-times-context,
/// i.e. the KV footprint the profile was calibrated at) is actually
/// available to request KV. The derate models weights, activations, and
/// fragmentation; at `cap_frac = 1.0` the token budget equals the slot
/// budget and KV never binds before slots do.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvPlanPolicy {
    pub cap_frac: f64,
}

impl Default for KvPlanPolicy {
    fn default() -> Self {
        KvPlanPolicy { cap_frac: 1.0 }
    }
}

impl KvPlanPolicy {
    /// Per-GPU KV capacity in tokens for a tier shaped `n_slots x c_max`.
    /// (`n_max(c) * c ~= n_max_calib * c_calib`, so every tier of a
    /// profile carries the same token budget before the derate.)
    pub fn cap_tokens(&self, n_slots: u32, c_max: u32) -> u64 {
        (self.cap_frac * n_slots as f64 * c_max as f64).floor() as u64
    }

    /// Validate against a tier shape: the cap must admit the largest
    /// request the router can send (`c_max` tokens), or an empty GPU
    /// could block forever on one request (and the DES ledger could
    /// never be violation-free by construction).
    pub fn validate(&self, tier: usize, n_slots: u32, c_max: u32) -> anyhow::Result<()> {
        if !self.cap_frac.is_finite() || self.cap_frac <= 0.0 || self.cap_frac > 1.0 {
            anyhow::bail!(
                "kv policy: cap_frac must be inside (0, 1], got {}",
                self.cap_frac
            );
        }
        let cap = self.cap_tokens(n_slots, c_max);
        if cap < c_max as u64 {
            anyhow::bail!(
                "kv policy: tier {tier}: cap_frac {} gives {} KV tokens/GPU, below the \
                 tier's c_max {} — a full-context request could never be admitted",
                self.cap_frac,
                cap,
                c_max
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queueing::service::calibrate_quadrature;
    use crate::workload::traces;

    fn g() -> GpuProfile {
        GpuProfile::a100_llama70b()
    }

    #[test]
    fn kv_quadrature_is_deterministic_and_seedless() {
        let w = traces::azure();
        let a = calibrate_kv_quadrature(&w.cdf, &w.output, &g(), 64, 128, 8);
        let b = calibrate_kv_quadrature(&w.cdf, &w.output, &g(), 64, 128, 8);
        assert_eq!(a.e_kv_iter, b.e_kv_iter);
        assert_eq!(a.e_iter, b.e_iter);
        assert_eq!(a.e_tokens, b.e_tokens);
    }

    #[test]
    fn kv_iterations_match_slot_quadrature() {
        // Same grids, same split: E[T] here integrates the identical
        // sample set as the slot quadrature's e_s / t_iter (only the
        // t_iter scaling differs, so agreement is to float accumulation
        // error, not model error).
        for w in [traces::azure(), traces::agent_heavy()] {
            for n_slots in [16u32, 128] {
                let kv = calibrate_kv_quadrature(&w.cdf, &w.output, &g(), n_slots, 128, 8);
                let s = calibrate_quadrature(&w.cdf, &w.output, &g(), n_slots, 128, 8);
                assert_eq!(kv.t_iter_s.to_bits(), s.t_iter_s.to_bits());
                assert!(
                    (kv.e_iter * s.t_iter_s - s.e_s).abs() < 1e-9 * s.e_s.abs(),
                    "{} n_slots {n_slots}",
                    w.name
                );
            }
        }
    }

    #[test]
    fn kv_moment_dominates_product_of_means() {
        // (L_in + L_out) and T are positively associated (both increase
        // with L_total), so E[tokens * T] >= E[tokens] * E[T].
        let w = traces::agent_heavy();
        let kv = calibrate_kv_quadrature(&w.cdf, &w.output, &g(), 16, 256, 8);
        assert!(kv.e_kv_iter >= kv.e_tokens * kv.e_iter * (1.0 - 1e-12));
    }

    #[test]
    fn scaled_mu_identity_and_dilation() {
        let w = traces::azure();
        let kv = calibrate_kv_quadrature(&w.cdf, &w.output, &g(), 64, 64, 4);
        let same = kv.scaled_mu(1.0);
        assert_eq!(same.t_iter_s.to_bits(), kv.t_iter_s.to_bits());
        assert_eq!(same.e_kv_iter.to_bits(), kv.e_kv_iter.to_bits());
        let fast = kv.scaled_mu(2.0);
        assert_eq!(fast.t_iter_s, kv.t_iter_s / 2.0);
        assert_eq!(fast.e_kv_iter, kv.e_kv_iter);
        assert_eq!(fast.e_kv_s(), kv.e_kv_s() / 2.0);
    }

    #[test]
    fn rho_and_boundary_are_consistent() {
        let w = traces::azure();
        let kv = calibrate_kv_quadrature(&w.cdf, &w.output, &g(), 128, 128, 8);
        let cap = 1 << 20;
        let n = 8u64;
        let ls = lambda_star(n, cap, &kv);
        assert!((rho_kv(ls, n, cap, &kv) - 1.0).abs() < 1e-12);
        assert!(rho_kv(0.5 * ls, n, cap, &kv) < 1.0);
        assert!(rho_kv(1.5 * ls, n, cap, &kv) > 1.0);
        // Sizing floor inverts rho: at the returned GPU count rho <= rho_max,
        // one fewer GPU exceeds it.
        let lam = 0.9 * ls;
        let need = min_gpus_kv(lam, cap, 0.85, &kv);
        assert!(rho_kv(lam, need, cap, &kv) <= 0.85 + 1e-12);
        if need > 1 {
            assert!(rho_kv(lam, need - 1, cap, &kv) > 0.85);
        }
        assert_eq!(min_gpus_kv(0.0, cap, 0.85, &kv), 0);
    }

    #[test]
    fn plan_policy_cap_and_validation() {
        let p = KvPlanPolicy { cap_frac: 0.5 };
        assert_eq!(p.cap_tokens(128, 8192), (0.5f64 * 128.0 * 8192.0) as u64);
        assert!(p.validate(0, 128, 8192).is_ok());
        // A cap below c_max is rejected, naming the tier.
        let tight = KvPlanPolicy { cap_frac: 0.01 };
        let err = tight.validate(2, 16, 65536).unwrap_err().to_string();
        assert!(err.contains("tier 2"), "{err}");
        assert!(err.contains("c_max"), "{err}");
        for bad in [0.0, -0.5, 1.5, f64::NAN] {
            let p = KvPlanPolicy { cap_frac: bad };
            assert!(p.validate(0, 128, 8192).is_err(), "cap_frac {bad}");
        }
        assert_eq!(KvPlanPolicy::default().cap_frac, 1.0);
    }
}
