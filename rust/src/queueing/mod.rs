//! The analytical core (paper §3): M/G/c queueing with Erlang-C and the
//! Kimura two-moment tail-wait approximation, plus the continuous-batching
//! service-time model.

pub mod erlang;
pub mod kimura;
pub mod kv;
pub mod mgc;
pub mod service;
#[cfg(feature = "simd")]
pub mod simd;
