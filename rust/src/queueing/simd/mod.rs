//! Lane-parallel kernels for the planner's analytical core (§Perf, PR 6;
//! `simd` cargo feature, default on).
//!
//! * [`lanes`] — masked-lockstep Erlang-C and Kimura quantile evaluation,
//!   8 independent (c, rho) points per call. Every lane replays the
//!   scalar recurrence's exact control flow (per-lane convergence break
//!   and `k >= 1` bound), so each lane is bit-identical to
//!   `erlang::erlang_c` / `kimura::w_quantile`.
//! * [`cells`] — the batched `MomentTable` cut evaluator behind
//!   `sweep_tiered_pruned`'s bound pass: a [`cells::CutMemo`] dedupes the
//!   (pure, table-fixed) `cut_moments` calls that neighboring sweep cells
//!   share, and [`cells::stability_counts_lanes`] runs the per-cell
//!   stability lower-bound arithmetic for a cluster of up to 8 cells in
//!   lane lockstep — per-lane ops exactly the scalar `cell_cost_lb`
//!   sequence, no cross-lane reduction.
//!
//! Identity policy: nothing in this module reassociates a floating-point
//! reduction; batching changes how many times pure functions are
//! evaluated, never their values, so planner argmin / GPU counts / cost
//! are bit-identical to the scalar sweep (property-tested).

pub mod cells;
pub mod lanes;
