//! Batched `MomentTable` cut evaluation for the bound-and-prune sweep
//! (§Perf, PR 6): `sweep_tiered_pruned` scores surviving cell clusters
//! [`CELL_LANES`] at a time instead of one `cut_moments` chain per cell.
//!
//! Two mechanisms, both value-preserving:
//!
//! * [`CutMemo`] — `MomentTable::cut_moments(lo, hi, len_points)` is a
//!   pure function of the cut for a fixed table and resolution, and
//!   neighboring sweep cells share most of their cuts (the tier-0 cut is
//!   gamma-independent, so a whole gamma row reuses it; boundary combos
//!   overlap pairwise). The memo returns the identical `CutMoments` the
//!   per-cell path recomputes, trading ~70-90% of the quadrature walks
//!   for hash lookups.
//! * [`stability_counts_lanes`] — the per-tier stability lower-bound
//!   arithmetic (`e_iter_lb -> a_lb -> ceil`) runs for up to 8 cells in
//!   lane lockstep. Each live lane performs exactly the scalar
//!   `cell_cost_lb` operation sequence on its own operands; lanes never
//!   share an accumulator, so every lane is bit-identical to the scalar
//!   bound (property-tested in `tests/simd_dispatch.rs`).

use crate::queueing::service::{CutMoments, MomentTable};
use crate::util::hash::FxHashMap;

/// Lane width of the batched cell evaluator.
pub const CELL_LANES: usize = 8;

/// Per-sweep memo over `(lo, hi)` cut keys (bit-exact f64 keys; the table
/// and `len_points` are fixed for the memo's lifetime by the sweep).
#[derive(Default)]
pub struct CutMemo {
    map: FxHashMap<(u64, u64), Option<CutMoments>>,
    /// Lookup counters (bench/diagnostic; no behavioral role).
    pub hits: u64,
    pub misses: u64,
}

impl CutMemo {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memoized `table.cut_moments(lo, hi, len_points)` — bit-identical
    /// to the direct call (pure function, exact keys).
    pub fn cut(
        &mut self,
        table: &MomentTable,
        lo: f64,
        hi: f64,
        len_points: usize,
    ) -> Option<CutMoments> {
        let key = (lo.to_bits(), hi.to_bits());
        if let Some(v) = self.map.get(&key) {
            self.hits += 1;
            return *v;
        }
        self.misses += 1;
        let v = table.cut_moments(lo, hi, len_points);
        self.map.insert(key, v);
        v
    }
}

/// One lane block of per-tier stability inputs; `live[l] = false` lanes
/// are passed through as zero counts (the scalar path's "no cut or no
/// traffic -> 0 GPUs" arm).
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneInputs {
    pub lambda: [f64; CELL_LANES],
    pub e_iter: [f64; CELL_LANES],
    pub err_iter: [f64; CELL_LANES],
    pub t_iter: [f64; CELL_LANES],
    pub n_slots: [f64; CELL_LANES],
    pub live: [bool; CELL_LANES],
}

/// Lane-blocked stability lower-bound GPU counts. Per live lane:
///
/// ```text
/// e_iter_lb = max(e_iter - err_iter, 1)
/// a_lb      = lambda * (e_iter_lb * t_iter) / n_slots
/// n_lb      = max(ceil(a_lb / rho_max), 1)
/// ```
///
/// — operation-for-operation the scalar `cell_cost_lb` tier arm, so each
/// lane's count is exactly the scalar one.
pub fn stability_counts_lanes(li: &LaneInputs, rho_max: f64, out: &mut [u64; CELL_LANES]) {
    for l in 0..CELL_LANES {
        out[l] = if li.live[l] {
            let e_iter_lb = (li.e_iter[l] - li.err_iter[l]).max(1.0);
            let e_s_lb = e_iter_lb * li.t_iter[l];
            let a_lb = li.lambda[l] * e_s_lb / li.n_slots[l];
            (a_lb / rho_max).ceil().max(1.0) as u64
        } else {
            0
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_arithmetic_matches_scalar_sequence() {
        let mut li = LaneInputs::default();
        let rho_max = 0.85;
        let cases = [
            (12.0, 900.0, 3.5, 0.04, 64.0),
            (0.5, 30.0, 29.9, 0.01, 8.0), // e_iter_lb clamps to 1.0
            (200.0, 5_000.0, 12.0, 0.08, 2_048.0),
        ];
        for (l, &(lambda, e_iter, err, t_iter, slots)) in cases.iter().enumerate() {
            li.live[l] = true;
            li.lambda[l] = lambda;
            li.e_iter[l] = e_iter;
            li.err_iter[l] = err;
            li.t_iter[l] = t_iter;
            li.n_slots[l] = slots;
        }
        let mut out = [0u64; CELL_LANES];
        stability_counts_lanes(&li, rho_max, &mut out);
        for (l, &(lambda, e_iter, err, t_iter, slots)) in cases.iter().enumerate() {
            let e_iter_lb = (e_iter - err).max(1.0);
            let a_lb = lambda * (e_iter_lb * t_iter) / slots;
            let want = (a_lb / rho_max).ceil().max(1.0) as u64;
            assert_eq!(out[l], want, "lane {l}");
        }
        for l in cases.len()..CELL_LANES {
            assert_eq!(out[l], 0, "dead lane {l}");
        }
    }

    #[test]
    fn memo_returns_identical_moments() {
        use crate::workload::traces;
        let w = traces::azure();
        let table = MomentTable::for_workload(&w, 512);
        let mut memo = CutMemo::new();
        let cuts = [(800.0, 6_000.0), (800.0, 6_000.0), (6_000.0, 32_000.0)];
        for &(lo, hi) in &cuts {
            let direct = table.cut_moments(lo, hi, 128);
            let memoed = memo.cut(&table, lo, hi, 128);
            match (direct, memoed) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.mass.to_bits(), b.mass.to_bits());
                    assert_eq!(a.e_iter.to_bits(), b.e_iter.to_bits());
                    assert_eq!(a.e_iter2.to_bits(), b.e_iter2.to_bits());
                    assert_eq!(a.err_iter.to_bits(), b.err_iter.to_bits());
                }
                (None, None) => {}
                _ => panic!("memo changed presence for ({lo}, {hi})"),
            }
        }
        assert_eq!(memo.misses, 2, "duplicate cut must hit the memo");
        assert_eq!(memo.hits, 1);
    }
}
