//! Masked-lockstep Erlang-C / Kimura evaluation, [`LANES`] independent
//! points per call (§Perf, PR 6).
//!
//! The scalar `erlang::erlang_c` recurrence has data-dependent control
//! flow (a convergence break and the `k >= 1` bound), so naive batching
//! would change per-point arithmetic. Here every lane carries its own
//! live mask and the update is written as selects — a live lane performs
//! exactly the scalar sequence `term *= k/a; sum += term;` with the same
//! break conditions, a retired lane holds its state — which keeps each
//! lane bit-identical to the scalar function (property-tested below)
//! while the straight-line select body is vectorizable across lanes.

use crate::queueing::erlang::erlang_c;

/// Lane width of the batched evaluators.
pub const LANES: usize = 8;

/// `out[l] = erlang_c(c[l], rho[l])` for every lane, in masked lockstep.
/// Requires `c[l] >= 1` (the scalar function's contract).
pub fn erlang_c_lanes(c: &[u64; LANES], rho: &[f64; LANES]) -> [f64; LANES] {
    let mut term = [0.0f64; LANES];
    let mut sum = [0.0f64; LANES];
    let mut k = [0.0f64; LANES];
    let mut a = [1.0f64; LANES];
    let mut live = [false; LANES];
    for l in 0..LANES {
        debug_assert!(c[l] >= 1, "need at least one server");
        if rho[l] > 0.0 && rho[l] < 1.0 {
            a[l] = c[l] as f64 * rho[l];
            term[l] = 1.0 / rho[l];
            sum[l] = term[l];
            k[l] = (c[l] - 1) as f64;
            live[l] = k[l] >= 1.0;
        }
    }
    while live.iter().any(|&x| x) {
        for l in 0..LANES {
            // Select form of the scalar loop body: a retired lane keeps
            // its state bit-for-bit; a live lane runs the exact scalar
            // ops (t and s may be garbage for retired lanes — discarded).
            let t = term[l] * (k[l] / a[l]);
            let s = sum[l] + t;
            let cont = live[l];
            term[l] = if cont { t } else { term[l] };
            sum[l] = if cont { s } else { sum[l] };
            let stop = t < s * 1e-17 || k[l] - 1.0 < 1.0;
            live[l] = cont && !stop;
            k[l] = if cont { k[l] - 1.0 } else { k[l] };
        }
    }
    let mut out = [0.0f64; LANES];
    for l in 0..LANES {
        out[l] = if rho[l] >= 1.0 {
            1.0
        } else if rho[l] <= 0.0 {
            0.0
        } else {
            1.0 / (1.0 + (1.0 - rho[l]) * sum[l])
        };
    }
    out
}

/// Lane-parallel Kimura P-quantile: `out[l] = kimura::w_quantile(c[l],
/// mu, lambda[l], cs2, p)` with the Erlang-C stage batched through
/// [`erlang_c_lanes`]. The scalar path's memo returns the identical f64
/// the direct recurrence produces, so each lane is bit-identical to the
/// scalar function.
pub fn w_quantile_lanes(
    c: &[u64; LANES],
    mu: f64,
    lambda: &[f64; LANES],
    cs2: f64,
    p: f64,
) -> [f64; LANES] {
    assert!(mu > 0.0 && p > 0.0 && p < 1.0);
    let mut rho = [0.0f64; LANES];
    let mut capacity = [0.0f64; LANES];
    for l in 0..LANES {
        assert!(lambda[l] >= 0.0);
        capacity[l] = c[l] as f64 * mu;
        // Unstable lanes get rho >= 1: erlang_c_lanes returns 1.0 there
        // without running the recurrence, and the result is overridden
        // with the scalar path's INFINITY below.
        rho[l] = lambda[l] / capacity[l];
    }
    let c_wait = erlang_c_lanes(c, &rho);
    let mut out = [0.0f64; LANES];
    for l in 0..LANES {
        out[l] = if lambda[l] >= capacity[l] {
            f64::INFINITY
        } else if lambda[l] == 0.0 || c_wait[l] <= p {
            0.0
        } else {
            (c_wait[l] / p).ln() * (1.0 + cs2) / (2.0 * (capacity[l] - lambda[l]))
        };
    }
    out
}

/// P99 batch form (`p = 0.01`), the planner's tail-SLO currency.
pub fn w99_lanes(c: &[u64; LANES], mu: f64, lambda: &[f64; LANES], cs2: f64) -> [f64; LANES] {
    w_quantile_lanes(c, mu, lambda, cs2, 0.01)
}

/// Convenience over arbitrary-length slices: batches full lane blocks,
/// pads the tail block with the last point (padding lanes discarded).
pub fn erlang_c_batch(points: &[(u64, f64)], out: &mut Vec<f64>) {
    out.clear();
    if points.is_empty() {
        return;
    }
    let mut c = [1u64; LANES];
    let mut rho = [0.0f64; LANES];
    for block in points.chunks(LANES) {
        for l in 0..LANES {
            let &(ci, ri) = block.get(l).unwrap_or(&block[block.len() - 1]);
            c[l] = ci;
            rho[l] = ri;
        }
        let res = erlang_c_lanes(&c, &rho);
        out.extend_from_slice(&res[..block.len()]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queueing::kimura::w_quantile;
    use crate::util::check::{ensure, forall};

    #[test]
    fn erlang_lanes_bit_identical_to_scalar() {
        forall(
            "erlang-lanes-vs-scalar",
            50,
            |rng| {
                let mut c = [1u64; LANES];
                let mut rho = [0.0f64; LANES];
                for l in 0..LANES {
                    c[l] = 1 + rng.below(20_000);
                    rho[l] = match rng.below(10) {
                        0 => 0.0,
                        1 => 1.0 + rng.f64(),
                        2 => -rng.f64(),
                        _ => rng.uniform(1e-6, 0.999_999),
                    };
                }
                (c, rho)
            },
            |&(c, rho)| {
                let got = erlang_c_lanes(&c, &rho);
                for l in 0..LANES {
                    let want = erlang_c(c[l], rho[l]);
                    ensure(
                        got[l].to_bits() == want.to_bits(),
                        format!("lane {l}: c={} rho={} got {} want {want}", c[l], rho[l], got[l]),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn kimura_lanes_bit_identical_to_scalar() {
        forall(
            "kimura-lanes-vs-scalar",
            50,
            |rng| {
                let mu = rng.uniform(0.05, 4.0);
                let cs2 = rng.uniform(0.0, 5.0);
                let mut c = [1u64; LANES];
                let mut lambda = [0.0f64; LANES];
                for l in 0..LANES {
                    c[l] = 1 + rng.below(5_000);
                    lambda[l] = match rng.below(8) {
                        0 => 0.0,
                        1 => c[l] as f64 * mu * rng.uniform(1.0, 2.0), // unstable
                        _ => c[l] as f64 * mu * rng.uniform(0.01, 0.999),
                    };
                }
                (c, mu, lambda, cs2)
            },
            |&(c, mu, lambda, cs2)| {
                let got = w99_lanes(&c, mu, &lambda, cs2);
                for l in 0..LANES {
                    let want = w_quantile(c[l], mu, lambda[l], cs2, 0.01);
                    ensure(
                        got[l].to_bits() == want.to_bits(),
                        format!(
                            "lane {l}: c={} lambda={} got {} want {want}",
                            c[l], lambda[l], got[l]
                        ),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batch_handles_ragged_tails() {
        let points: Vec<(u64, f64)> = (1..=11)
            .map(|i| (i * 7, 0.8 + 0.01 * i as f64 / 11.0))
            .collect();
        let mut out = Vec::new();
        erlang_c_batch(&points, &mut out);
        assert_eq!(out.len(), points.len());
        for (i, &(c, rho)) in points.iter().enumerate() {
            assert_eq!(out[i].to_bits(), erlang_c(c, rho).to_bits(), "point {i}");
        }
        erlang_c_batch(&[], &mut out);
        assert!(out.is_empty());
    }
}
