//! Request model: token budgets, categories, output-length split.
//!
//! A request's total token budget is `L_total = L_in + L_out` (paper §2.1:
//! prompt estimate + max_output_tokens). The traces publish the L_total
//! distribution; the split into input/output follows a per-workload output
//! model documented in DESIGN.md §1 (substitutions).

use crate::util::rng::Rng;

/// Content category (paper §5.2: the safety gate compresses only RAG and
/// prose; code is excluded).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    Conversational,
    Rag,
    Code,
    ToolUse,
}

impl Category {
    /// Whether the C&R safety gate allows extractive compression (§5.2).
    pub fn compressible(self) -> bool {
        matches!(self, Category::Conversational | Category::Rag)
    }

    pub fn name(self) -> &'static str {
        match self {
            Category::Conversational => "conversational",
            Category::Rag => "rag",
            Category::Code => "code",
            Category::ToolUse => "tool_use",
        }
    }
}

/// A serving request as seen by the gateway.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Total token budget L_total = L_in + L_out.
    pub l_total: u32,
    /// Prompt tokens.
    pub l_in: u32,
    /// max_output_tokens.
    pub l_out: u32,
    pub category: Category,
    /// Arrival time, seconds since epoch of the run.
    pub arrival_s: f64,
}

impl Request {
    pub fn new(
        id: u64,
        l_total: u32,
        l_out: u32,
        category: Category,
        arrival_s: f64,
    ) -> Self {
        let l_out = l_out.min(l_total.saturating_sub(1)).max(1);
        Request {
            id,
            l_total,
            l_in: l_total - l_out,
            l_out,
            category,
            arrival_s,
        }
    }
}

/// Per-workload output-length model: `L_out = clamp(frac * L_total * jitter)`
/// with lognormal jitter — documented substitution for the traces' per-request
/// output counts (DESIGN.md §1).
#[derive(Clone, Copy, Debug)]
pub struct OutputModel {
    pub frac: f64,
    pub sigma: f64,
    pub min_tokens: u32,
    pub max_tokens: u32,
}

impl OutputModel {
    pub fn sample_l_out(&self, l_total: f64, rng: &mut Rng) -> u32 {
        let jitter = rng.lognormal(0.0, self.sigma);
        let out = (self.frac * l_total * jitter).round();
        (out as u32)
            .clamp(self.min_tokens, self.max_tokens)
            .min((l_total * 0.9) as u32)
            .max(1)
    }

    /// Deterministic expectation of the clamp-free model (for analytics).
    pub fn mean_l_out(&self, l_total: f64) -> f64 {
        // E[lognormal(0, sigma)] = exp(sigma^2 / 2)
        (self.frac * l_total * (self.sigma * self.sigma / 2.0).exp())
            .clamp(self.min_tokens as f64, self.max_tokens as f64)
            .min(l_total * 0.9)
            .max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_gate_matches_paper() {
        assert!(Category::Conversational.compressible());
        assert!(Category::Rag.compressible());
        assert!(!Category::Code.compressible());
        assert!(!Category::ToolUse.compressible());
    }

    #[test]
    fn request_split_adds_up() {
        let r = Request::new(1, 1000, 200, Category::Rag, 0.0);
        assert_eq!(r.l_in + r.l_out, r.l_total);
        assert_eq!(r.l_out, 200);
    }

    #[test]
    fn request_output_clamped_below_total() {
        let r = Request::new(1, 100, 5000, Category::Rag, 0.0);
        assert!(r.l_out < r.l_total);
        assert!(r.l_in >= 1);
    }

    #[test]
    fn output_model_within_bounds() {
        let m = OutputModel {
            frac: 0.15,
            sigma: 0.3,
            min_tokens: 16,
            max_tokens: 2048,
        };
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            let out = m.sample_l_out(4000.0, &mut rng);
            assert!((16..=2048).contains(&out));
        }
    }

    #[test]
    fn output_model_mean_tracks_frac() {
        let m = OutputModel {
            frac: 0.15,
            sigma: 0.3,
            min_tokens: 1,
            max_tokens: 1_000_000,
        };
        let mut rng = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n)
            .map(|_| m.sample_l_out(10_000.0, &mut rng) as f64)
            .sum::<f64>()
            / n as f64;
        let want = m.mean_l_out(10_000.0);
        assert!((mean - want).abs() / want < 0.02, "mean={mean} want={want}");
    }
}
