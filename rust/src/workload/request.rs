//! Request model: token budgets, categories, output-length split.
//!
//! A request's total token budget is `L_total = L_in + L_out` (paper §2.1:
//! prompt estimate + max_output_tokens). The traces publish the L_total
//! distribution; the split into input/output follows a per-workload output
//! model documented in DESIGN.md §1 (substitutions).

use crate::util::rng::Rng;

/// Content category (paper §5.2: the safety gate compresses only RAG and
/// prose; code is excluded).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    Conversational,
    Rag,
    Code,
    ToolUse,
}

impl Category {
    /// Whether the C&R safety gate allows extractive compression (§5.2).
    pub fn compressible(self) -> bool {
        matches!(self, Category::Conversational | Category::Rag)
    }

    pub fn name(self) -> &'static str {
        match self {
            Category::Conversational => "conversational",
            Category::Rag => "rag",
            Category::Code => "code",
            Category::ToolUse => "tool_use",
        }
    }

    /// Stable index for per-category arrays (same order as
    /// [`Category::ALL`] and the workloads' `category_mix`).
    pub fn index(self) -> usize {
        match self {
            Category::Conversational => 0,
            Category::Rag => 1,
            Category::Code => 2,
            Category::ToolUse => 3,
        }
    }
}

impl Category {
    /// Every category, in `category_mix` / [`Category::index`] order.
    pub const ALL: [Category; 4] = [
        Category::Conversational,
        Category::Rag,
        Category::Code,
        Category::ToolUse,
    ];
}

/// A serving request as seen by the gateway.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Total token budget L_total = L_in + L_out.
    pub l_total: u32,
    /// Prompt tokens.
    pub l_in: u32,
    /// max_output_tokens.
    pub l_out: u32,
    pub category: Category,
    /// Arrival time, seconds since epoch of the run.
    pub arrival_s: f64,
}

impl Request {
    pub fn new(
        id: u64,
        l_total: u32,
        l_out: u32,
        category: Category,
        arrival_s: f64,
    ) -> Self {
        let l_out = l_out.min(l_total.saturating_sub(1)).max(1);
        Request {
            id,
            l_total,
            l_in: l_total - l_out,
            l_out,
            category,
            arrival_s,
        }
    }
}

/// Per-workload output-length model: `L_out = clamp(frac * L_total * jitter)`
/// with lognormal jitter — documented substitution for the traces' per-request
/// output counts (DESIGN.md §1).
#[derive(Clone, Copy, Debug)]
pub struct OutputModel {
    pub frac: f64,
    pub sigma: f64,
    pub min_tokens: u32,
    pub max_tokens: u32,
}

impl OutputModel {
    pub fn sample_l_out(&self, l_total: f64, rng: &mut Rng) -> u32 {
        let jitter = rng.lognormal(0.0, self.sigma);
        let out = (self.frac * l_total * jitter).round();
        (out as u32)
            .clamp(self.min_tokens, self.max_tokens)
            .min((l_total * 0.9) as u32)
            .max(1)
    }

    /// Deterministic expectation of the clamp-free model (for analytics).
    pub fn mean_l_out(&self, l_total: f64) -> f64 {
        // E[lognormal(0, sigma)] = exp(sigma^2 / 2)
        (self.frac * l_total * (self.sigma * self.sigma / 2.0).exp())
            .clamp(self.min_tokens as f64, self.max_tokens as f64)
            .min(l_total * 0.9)
            .max(1.0)
    }

    /// Validate the model's fields, naming the offending field in `ctx`
    /// (the caller supplies "output model" or "output model \"code\"
    /// (index 2)" — same error style as `SkuCatalog::validate`).
    pub fn validate(&self, ctx: &str) -> anyhow::Result<()> {
        if !self.frac.is_finite() || self.frac <= 0.0 || self.frac >= 1.0 {
            anyhow::bail!("{ctx}: frac must be inside (0, 1), got {}", self.frac);
        }
        if !self.sigma.is_finite() || self.sigma < 0.0 {
            anyhow::bail!(
                "{ctx}: sigma must be finite and non-negative, got {}",
                self.sigma
            );
        }
        if self.min_tokens < 1 {
            anyhow::bail!(
                "{ctx}: min_tokens must be at least 1, got {}",
                self.min_tokens
            );
        }
        if self.max_tokens < self.min_tokens {
            anyhow::bail!(
                "{ctx}: max_tokens ({}) must be >= min_tokens ({})",
                self.max_tokens,
                self.min_tokens
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_gate_matches_paper() {
        assert!(Category::Conversational.compressible());
        assert!(Category::Rag.compressible());
        assert!(!Category::Code.compressible());
        assert!(!Category::ToolUse.compressible());
    }

    #[test]
    fn request_split_adds_up() {
        let r = Request::new(1, 1000, 200, Category::Rag, 0.0);
        assert_eq!(r.l_in + r.l_out, r.l_total);
        assert_eq!(r.l_out, 200);
    }

    #[test]
    fn request_output_clamped_below_total() {
        let r = Request::new(1, 100, 5000, Category::Rag, 0.0);
        assert!(r.l_out < r.l_total);
        assert!(r.l_in >= 1);
    }

    #[test]
    fn output_model_within_bounds() {
        let m = OutputModel {
            frac: 0.15,
            sigma: 0.3,
            min_tokens: 16,
            max_tokens: 2048,
        };
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            let out = m.sample_l_out(4000.0, &mut rng);
            assert!((16..=2048).contains(&out));
        }
    }

    #[test]
    fn category_index_matches_all_order() {
        for (i, c) in Category::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
        }
    }

    #[test]
    fn output_model_validation_names_field_and_context() {
        let ok = OutputModel {
            frac: 0.15,
            sigma: 0.3,
            min_tokens: 16,
            max_tokens: 2048,
        };
        assert!(ok.validate("output model").is_ok());
        // Each rejection path names the bad field and echoes the context.
        let cases: [(OutputModel, &str); 5] = [
            (OutputModel { frac: 0.0, ..ok }, "frac"),
            (OutputModel { frac: 1.5, ..ok }, "frac"),
            (OutputModel { sigma: -0.1, ..ok }, "sigma"),
            (OutputModel { min_tokens: 0, ..ok }, "min_tokens"),
            (
                OutputModel {
                    min_tokens: 100,
                    max_tokens: 50,
                    ..ok
                },
                "max_tokens",
            ),
        ];
        for (bad, field) in cases {
            let err = bad
                .validate("output model \"code\" (index 2)")
                .unwrap_err()
                .to_string();
            assert!(err.contains(field), "{err}");
            assert!(err.contains("index 2"), "{err}");
        }
    }

    #[test]
    fn output_model_mean_tracks_frac() {
        let m = OutputModel {
            frac: 0.15,
            sigma: 0.3,
            min_tokens: 1,
            max_tokens: 1_000_000,
        };
        let mut rng = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n)
            .map(|_| m.sample_l_out(10_000.0, &mut rng) as f64)
            .sum::<f64>()
            / n as f64;
        let want = m.mean_l_out(10_000.0);
        assert!((mean - want).abs() / want < 0.02, "mean={mean} want={want}");
    }
}
