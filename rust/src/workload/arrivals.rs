//! Poisson arrival process (paper §3.1: M/G/c — Markovian arrivals).

use crate::util::rng::Rng;
use crate::workload::request::Request;
use crate::workload::traces::Workload;

/// Iterator of exponentially-spaced arrival timestamps at rate `lambda`.
pub struct PoissonArrivals {
    lambda: f64,
    t: f64,
    rng: Rng,
}

impl PoissonArrivals {
    pub fn new(lambda: f64, seed: u64) -> Self {
        assert!(lambda > 0.0);
        PoissonArrivals {
            lambda,
            t: 0.0,
            rng: Rng::new(seed),
        }
    }
}

impl Iterator for PoissonArrivals {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        self.t += self.rng.exp(self.lambda);
        Some(self.t)
    }
}

/// Generate a full trace: `n` requests with Poisson arrivals at `lambda`
/// req/s, lengths/categories drawn from the workload.
pub fn generate_trace(w: &Workload, lambda: f64, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed ^ 0xA11);
    let arrivals = PoissonArrivals::new(lambda, seed);
    arrivals
        .take(n)
        .enumerate()
        .map(|(i, t)| w.sample_request(i as u64, t, &mut rng))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces;

    #[test]
    fn interarrival_mean_is_one_over_lambda() {
        let lambda = 250.0;
        let times: Vec<f64> = PoissonArrivals::new(lambda, 1).take(100_000).collect();
        let mean_gap = times.last().unwrap() / times.len() as f64;
        assert!((mean_gap - 1.0 / lambda).abs() / (1.0 / lambda) < 0.02);
    }

    #[test]
    fn arrivals_strictly_increase() {
        let mut last = 0.0;
        for t in PoissonArrivals::new(10.0, 2).take(10_000) {
            assert!(t > last);
            last = t;
        }
    }

    #[test]
    fn interarrival_scv_near_one() {
        // Exponential gaps => SCV = 1 (the "M" in M/G/c).
        let times: Vec<f64> = PoissonArrivals::new(100.0, 3).take(100_000).collect();
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let scv = var / (mean * mean);
        assert!((scv - 1.0).abs() < 0.03, "scv={scv}");
    }

    #[test]
    fn trace_is_deterministic_under_seed() {
        let w = traces::azure();
        let a = generate_trace(&w, 100.0, 1000, 42);
        let b = generate_trace(&w, 100.0, 1000, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.l_total, y.l_total);
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.category, y.category);
        }
        let c = generate_trace(&w, 100.0, 1000, 43);
        assert!(a.iter().zip(&c).any(|(x, y)| x.l_total != y.l_total));
    }

    #[test]
    fn trace_ids_sequential() {
        let w = traces::lmsys();
        let t = generate_trace(&w, 50.0, 100, 1);
        for (i, r) in t.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }
}
