//! Workload CDF archetypes (paper §2.4): which remediation applies depends
//! on where the distribution's mass sits relative to `B_short`.

use crate::workload::cdf::LengthDist;

/// The three qualitative workload shapes of §2.4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Archetype {
    /// I: sharp knee below B_short (F(B) >= ~0.9); most above-threshold
    /// traffic is borderline, so C&R is highly effective (large rho).
    ConcentratedBelow,
    /// II: mass spread over decades; meaningful borderline traffic, C&R
    /// gives meaningful incremental savings.
    Dispersed,
    /// III: mass above B_short; raise the boundary before compressing.
    ConcentratedAbove,
}

impl Archetype {
    pub fn name(self) -> &'static str {
        match self {
            Archetype::ConcentratedBelow => "I (concentrated-below)",
            Archetype::Dispersed => "II (dispersed)",
            Archetype::ConcentratedAbove => "III (concentrated-above)",
        }
    }
}

/// Classify per the §2.4 rules:
/// * alpha >= 0.85 and the borderline band holds >= half of above-threshold
///   traffic -> Archetype I;
/// * alpha <= 0.5 -> Archetype III;
/// * otherwise -> Archetype II.
pub fn classify<D: LengthDist>(cdf: &D, b_short: u32, gamma: f64) -> Archetype {
    let alpha = cdf.cdf(b_short as f64);
    let beta = cdf.cdf(gamma * b_short as f64) - alpha;
    if alpha <= 0.5 {
        return Archetype::ConcentratedAbove;
    }
    let above = 1.0 - alpha;
    if alpha >= 0.85 && above > 0.0 && beta / above >= 0.5 {
        Archetype::ConcentratedBelow
    } else {
        Archetype::Dispersed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::AnchoredCdf;
    use crate::workload::traces;

    #[test]
    fn paper_workload_archetypes() {
        // Table 2: Azure and LMSYS are I/II; Agent-heavy is II.
        let az = traces::azure();
        assert_eq!(
            classify(&az.cdf, az.b_short, az.gamma),
            Archetype::ConcentratedBelow
        );
        let lm = traces::lmsys();
        assert_eq!(
            classify(&lm.cdf, lm.b_short, lm.gamma),
            Archetype::ConcentratedBelow
        );
        let ag = traces::agent_heavy();
        assert_eq!(
            classify(&ag.cdf, ag.b_short, ag.gamma),
            Archetype::Dispersed
        );
    }

    #[test]
    fn code_agent_tasks_are_type_iii() {
        // §2.4: mass at 10-50K tokens, boundary at 8K.
        let cdf = AnchoredCdf::new(vec![
            (1024.0, 0.0),
            (8192.0, 0.2),
            (16384.0, 0.55),
            (51200.0, 1.0),
        ]);
        assert_eq!(classify(&cdf, 8192, 1.5), Archetype::ConcentratedAbove);
    }

    #[test]
    fn dispersed_when_alpha_mid() {
        let cdf = AnchoredCdf::new(vec![(64.0, 0.0), (4096.0, 0.6), (65536.0, 1.0)]);
        assert_eq!(classify(&cdf, 4096, 1.5), Archetype::Dispersed);
    }
}
