//! Workload characterization: prompt-length CDFs, the three evaluation
//! traces, Poisson arrivals, and CDF archetypes (paper §2, §7.1).

pub mod archetype;
pub mod arrivals;
pub mod cdf;
pub mod request;
pub mod traces;
