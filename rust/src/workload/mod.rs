//! Workload characterization: prompt-length CDFs, the three evaluation
//! traces, stationary and nonstationary arrival processes, sliding-window
//! online estimation, and CDF archetypes (paper §2, §7.1).

pub mod archetype;
pub mod arrivals;
pub mod cdf;
pub mod online;
pub mod request;
pub mod traces;
