//! Sliding-window online workload estimation: the control loop's eyes.
//!
//! The planner consumes a prompt-length CDF and an arrival rate; under a
//! nonstationary workload neither is known a priori. [`OnlineEstimator`]
//! keeps the last `window_s` seconds of `(arrival, L_total)` observations
//! and re-derives both on demand: the rate from the window count, the CDF
//! as an [`AnchoredCdf`] through empirical quantile anchors — the same
//! piecewise log-linear type the offline traces use, so one planner serves
//! both the offline tables and the live controller.
//!
//! §Perf (DES engine overhaul): the quantile anchors are **incremental**.
//! A Fenwick tree over integer token lengths ([`LengthIndex`]) is updated
//! O(log U) per arrival/eviction and answers order statistics and ranks
//! directly, replacing the per-epoch copy + full sort of the window
//! (every controller epoch used to re-sort ~rate x window samples). The
//! anchors are the same order statistics the sort produced — bit-identical
//! CDFs, property-tested in `tests/des_engine.rs`.

use std::collections::VecDeque;

use crate::workload::cdf::AnchoredCdf;
use crate::workload::traces::Workload;

/// Quantile levels the empirical CDF is anchored at (interior points; the
/// support endpoints are added explicitly).
const ANCHOR_QS: [f64; 13] = [
    0.05, 0.10, 0.20, 0.30, 0.40, 0.50, 0.60, 0.70, 0.80, 0.90, 0.95, 0.98, 0.99,
];

/// Upper bound on indexable token lengths. Lengths at or above this land
/// in the top bucket *and are counted* ([`LengthIndex::n_clamped`]): while
/// any such observation is inside the window, [`OnlineEstimator::empirical_cdf`]
/// falls back to the exact copy-and-sort path, so the anchors stay
/// bit-identical for arbitrary (e.g. user-config) workloads. The bundled
/// trace CDFs top out at 64–131K tokens, far below; the tree costs 1 MB
/// once per estimator.
const MAX_LEN: usize = 1 << 18;

/// Fenwick (binary-indexed) tree over integer token lengths: O(log U)
/// add/remove, k-th order statistic, and rank queries over the current
/// window — the incremental replacement for sorting the window per epoch.
#[derive(Clone, Debug)]
struct LengthIndex {
    /// 1-based Fenwick array; slot `v + 1` counts observations of value v.
    tree: Vec<u32>,
    n: u64,
    /// Observations currently clamped into the top bucket (value lost).
    n_clamped: u64,
}

impl LengthIndex {
    fn new() -> Self {
        LengthIndex {
            tree: vec![0; MAX_LEN + 1],
            n: 0,
            n_clamped: 0,
        }
    }

    /// Fenwick slot for a token-length observation (values are whole
    /// numbers: `l_total as f64`).
    fn slot(l: f64) -> usize {
        (l.max(0.0) as usize).min(MAX_LEN - 1) + 1
    }

    fn add(&mut self, l: f64, delta: i64) {
        let mut i = Self::slot(l);
        while i <= MAX_LEN {
            self.tree[i] = (self.tree[i] as i64 + delta) as u32;
            i += i & i.wrapping_neg();
        }
        self.n = (self.n as i64 + delta) as u64;
        if l >= (MAX_LEN - 1) as f64 {
            self.n_clamped = (self.n_clamped as i64 + delta) as u64;
        }
    }

    /// Number of observations with value <= x.
    fn rank_le(&self, x: f64) -> u64 {
        let mut i = Self::slot(x);
        let mut s = 0u64;
        while i > 0 {
            s += self.tree[i] as u64;
            i &= i - 1;
        }
        s
    }

    /// The k-th smallest observation (1-based), as the stored f64 value.
    fn kth(&self, k: u64) -> f64 {
        debug_assert!(k >= 1 && k <= self.n, "k = {k} of {}", self.n);
        let mut idx = 0usize;
        let mut rem = k;
        let mut bit = MAX_LEN; // power of two
        while bit > 0 {
            let next = idx + bit;
            if next <= MAX_LEN {
                let c = self.tree[next] as u64;
                if c < rem {
                    rem -= c;
                    idx = next;
                }
            }
            bit >>= 1;
        }
        // idx = largest prefix with cumulative count < k; slot idx+1 holds
        // the k-th value, which is the slot's value idx.
        idx as f64
    }
}

/// Sliding-window estimator of the arrival rate and prompt-length CDF.
/// Observations must be fed in non-decreasing arrival order (they come
/// straight off the arrival stream).
#[derive(Clone, Debug)]
pub struct OnlineEstimator {
    window_s: f64,
    /// (arrival_s, l_total) pairs inside the window, oldest first.
    buf: VecDeque<(f64, f64)>,
    /// Order-statistics index over the window's lengths (kept in lockstep
    /// with `buf`).
    index: LengthIndex,
    n_seen: u64,
}

impl OnlineEstimator {
    pub fn new(window_s: f64) -> Self {
        assert!(window_s > 0.0, "window must be positive");
        OnlineEstimator {
            window_s,
            buf: VecDeque::new(),
            index: LengthIndex::new(),
            n_seen: 0,
        }
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Observations currently inside the window.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total observations ever fed (diagnostics).
    pub fn n_seen(&self) -> u64 {
        self.n_seen
    }

    /// Record one arrival; evicts everything older than the window.
    pub fn observe(&mut self, arrival_s: f64, l_total: u32) {
        self.buf.push_back((arrival_s, l_total as f64));
        self.index.add(l_total as f64, 1);
        self.n_seen += 1;
        self.evict(arrival_s);
    }

    fn evict(&mut self, now: f64) {
        let cutoff = now - self.window_s;
        while let Some(&(t, l)) = self.buf.front() {
            if t < cutoff {
                self.buf.pop_front();
                self.index.add(l, -1);
            } else {
                break;
            }
        }
    }

    /// Windowed arrival-rate estimate at time `now`, req/s. Early in a run
    /// (before one full window has elapsed) the denominator is the elapsed
    /// time, so the estimate is unbiased from the first observation.
    /// Robust to a stale buffer (eviction happens on `observe`, but `rate`
    /// only counts observations inside `[now - window, now]`).
    pub fn rate(&self, now: f64) -> f64 {
        let span = self.window_s.min(now);
        if span <= 0.0 {
            return 0.0;
        }
        let cutoff = now - self.window_s;
        let count = self
            .buf
            .iter()
            .rev()
            .take_while(|&&(t, _)| t >= cutoff)
            .count();
        count as f64 / span
    }

    /// One O(window) pass bucketing the window into `parts` equal
    /// sub-intervals: `(span, sub-interval width, per-interval counts)`,
    /// or `None` before any time has elapsed. Shared by the peak,
    /// forecast, and combined planning-rate estimators so a controller
    /// epoch never scans the buffer twice.
    fn sub_counts(&self, now: f64, parts: usize) -> Option<(f64, f64, Vec<u64>)> {
        assert!(parts >= 1);
        let span = self.window_s.min(now);
        if span <= 0.0 {
            return None;
        }
        let sub = span / parts as f64;
        let cutoff = now - span;
        let mut counts = vec![0u64; parts];
        for &(t, _) in self.buf.iter().rev() {
            if t < cutoff {
                break;
            }
            let idx = (((t - cutoff) / sub) as usize).min(parts - 1);
            counts[idx] += 1;
        }
        Some((span, sub, counts))
    }

    /// The busiest sub-interval's rate from precomputed bucket counts.
    fn peak_of(sub: f64, counts: &[u64]) -> f64 {
        counts.iter().map(|&c| c as f64 / sub).fold(0.0, f64::max)
    }

    /// Least-squares linear extrapolation of the sub-interval rates to
    /// `horizon_s` past the window end, floored at 0 (falls back to the
    /// window mean on a degenerate fit).
    fn forecast_of(span: f64, sub: f64, counts: &[u64], horizon_s: f64) -> f64 {
        let n = counts.len() as f64;
        let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
        for (i, &c) in counts.iter().enumerate() {
            let x = (i as f64 + 0.5) * sub;
            let y = c as f64 / sub;
            sx += x;
            sy += y;
            sxx += x * x;
            sxy += x * y;
        }
        let denom = n * sxx - sx * sx;
        if denom <= 0.0 {
            return sy / n;
        }
        let slope = (n * sxy - sx * sy) / denom;
        let intercept = (sy - slope * sx) / n;
        (intercept + slope * (span + horizon_s)).max(0.0)
    }

    /// Peak-tracking rate estimate: the window is split into `parts`
    /// equal sub-intervals and the busiest one's rate is returned. Under
    /// a ramp the mean-window estimate lags by ~window/2; the peak
    /// estimate lags by ~window/(2*parts) and also captures bursts — the
    /// controller provisions against this so upswings don't burn SLO.
    /// Falls back to [`Self::rate`] semantics when the window is young.
    pub fn peak_rate(&self, now: f64, parts: usize) -> f64 {
        match self.sub_counts(now, parts) {
            Some((_, sub, counts)) => Self::peak_of(sub, &counts),
            None => 0.0,
        }
    }

    /// One-step-ahead linear rate forecast: least-squares fit through the
    /// window's `parts` sub-interval rates (the same sub-rates
    /// [`Self::peak_rate`] maxes over), extrapolated `horizon_s` past
    /// `now` and floored at 0. Under a ramp this anticipates the demand
    /// the fleet will face one controller epoch out — the anticipatory-
    /// scaling knob (`forecast` in the autoscale configs, off by
    /// default); on a flat window the slope fits ~0 and the forecast
    /// collapses to the window mean.
    pub fn forecast_rate(&self, now: f64, horizon_s: f64, parts: usize) -> f64 {
        assert!(parts >= 2, "a trend needs at least 2 sub-intervals");
        assert!(horizon_s >= 0.0);
        match self.sub_counts(now, parts) {
            Some((span, sub, counts)) => Self::forecast_of(span, sub, &counts, horizon_s),
            None => 0.0,
        }
    }

    /// The controllers' planning-rate estimate in a single buffer pass:
    /// the peak sub-rate, maxed with the `horizon_s`-ahead forecast when
    /// anticipatory scaling is on. With `horizon_s == None` this is
    /// exactly [`Self::peak_rate`] (the forecast-off no-op property).
    pub fn planning_rate(&self, now: f64, parts: usize, horizon_s: Option<f64>) -> f64 {
        assert!(parts >= 2);
        let Some((span, sub, counts)) = self.sub_counts(now, parts) else {
            return 0.0;
        };
        let peak = Self::peak_of(sub, &counts);
        match horizon_s {
            Some(h) => peak.max(Self::forecast_of(span, sub, &counts, h)),
            None => peak,
        }
    }

    /// Empirical prompt-length CDF over the window, anchored at the
    /// [`ANCHOR_QS`] quantiles. `None` with fewer than 8 observations —
    /// too little signal to re-plan from. Anchors are exact window order
    /// statistics, served by the incremental [`LengthIndex`] (no per-call
    /// sort); a window containing lengths beyond the index's range falls
    /// back to the exact sort, so the anchors are bit-identical to the
    /// former copy-and-sort in every case.
    pub fn empirical_cdf(&self) -> Option<AnchoredCdf> {
        let n = self.buf.len();
        debug_assert_eq!(n as u64, self.index.n, "index out of lockstep");
        if n < 8 {
            return None;
        }
        if self.index.n_clamped > 0 {
            let mut xs: Vec<f64> = self.buf.iter().map(|&(_, l)| l).collect();
            xs.sort_by(f64::total_cmp);
            return anchors_from(
                |k| xs[k as usize - 1],
                |x| xs.partition_point(|&v| v <= x) as u64,
                n,
            );
        }
        anchors_from(|k| self.index.kth(k), |x| self.index.rank_le(x), n)
    }

    /// A re-plannable [`Workload`]: the template's categories, output
    /// model and compressibility with the window's empirical CDF swapped
    /// in. `None` when the window is too thin (see [`Self::empirical_cdf`]).
    pub fn snapshot(&self, template: &Workload) -> Option<Workload> {
        let cdf = self.empirical_cdf()?;
        let mut w = template.clone();
        w.cdf = cdf;
        Some(w)
    }
}

/// Period-aware (seasonal) rate forecaster: per-phase-bin running means
/// of observed rates over a known period (diurnal, weekly, ...). The
/// sliding-window [`OnlineEstimator`] forgets everything older than its
/// window; this keeps one scalar mean per phase bin instead, so a
/// controller can anticipate a recurring ramp it has seen on previous
/// periods — before the reactive window can. Consumed by the autoscale
/// controllers behind `seasonal_period_s` (off by default): planning
/// takes `max(reactive, seasonal forecast)`, so the knob only ever
/// raises the planning rate (the same no-op contract as `forecast`).
#[derive(Clone, Debug)]
pub struct SeasonalEstimator {
    period_s: f64,
    /// Per-bin running sums/counts of observed rates.
    sums: Vec<f64>,
    counts: Vec<u64>,
}

impl SeasonalEstimator {
    pub fn new(period_s: f64, bins: usize) -> Self {
        assert!(period_s > 0.0 && period_s.is_finite(), "period must be positive");
        assert!(bins >= 2, "need at least 2 phase bins");
        SeasonalEstimator {
            period_s,
            sums: vec![0.0; bins],
            counts: vec![0; bins],
        }
    }

    /// The phase bin time `t` falls into.
    fn bin(&self, t: f64) -> usize {
        let phase = t.rem_euclid(self.period_s) / self.period_s;
        ((phase * self.sums.len() as f64) as usize).min(self.sums.len() - 1)
    }

    /// Fold one rate observation taken at time `t` into its phase bin.
    pub fn observe(&mut self, t: f64, rate: f64) {
        let b = self.bin(t);
        self.sums[b] += rate;
        self.counts[b] += 1;
    }

    /// The mean observed rate at the phase of time `t`, or `None` when
    /// that phase has no history yet (first pass through the period) —
    /// the caller then keeps its reactive estimate.
    pub fn forecast(&self, t: f64) -> Option<f64> {
        let b = self.bin(t);
        (self.counts[b] > 0).then(|| self.sums[b] / self.counts[b] as f64)
    }
}

/// Build the anchored CDF from order-statistic (`kth`, 1-based rank in the
/// window) and rank (`rank_le`, observations <= x) oracles — shared by the
/// Fenwick fast path and the exact-sort fallback so both produce the same
/// anchors by construction.
fn anchors_from(
    kth: impl Fn(u64) -> f64,
    rank_le: impl Fn(f64) -> u64,
    n: usize,
) -> Option<AnchoredCdf> {
    let hi = kth(n as u64);
    // Support lower edge strictly below the smallest sample (AnchoredCdf
    // requires F(first anchor) = 0 and x > 0; L_total >= 2 always).
    let lo = (kth(1) - 1.0).max(1.0);
    if hi <= lo {
        return None;
    }
    let mut anchors: Vec<(f64, f64)> = vec![(lo, 0.0)];
    for &q in &ANCHOR_QS {
        let idx = ((q * (n - 1) as f64).round() as usize).min(n - 1);
        let x = kth(idx as u64 + 1);
        let last = *anchors.last().expect("non-empty");
        if x <= last.0 || x >= hi {
            continue;
        }
        // Exact empirical mass at x, so anchors are self-consistent even
        // when quantile ranks collide on duplicate lengths.
        let f = rank_le(x) as f64 / n as f64;
        if f <= last.1 || f >= 1.0 {
            continue;
        }
        anchors.push((x, f));
    }
    anchors.push((hi, 1.0));
    Some(AnchoredCdf::new(anchors))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::cdf::LengthDist;
    use crate::workload::traces;

    #[test]
    fn rate_tracks_window_count() {
        let mut e = OnlineEstimator::new(10.0);
        // 100 arrivals over 10 s => 10 req/s.
        for i in 0..100 {
            e.observe(i as f64 * 0.1, 500);
        }
        let r = e.rate(9.9);
        assert!((r - 10.1).abs() < 0.5, "rate {r}");
        assert_eq!(e.n_seen(), 100);
    }

    #[test]
    fn peak_rate_tracks_the_busy_subwindow() {
        let mut e = OnlineEstimator::new(8.0);
        // 4 s at 10 req/s then 4 s at 40 req/s.
        let mut t = 0.0;
        while t < 4.0 {
            e.observe(t, 100);
            t += 0.1;
        }
        while t < 8.0 {
            e.observe(t, 100);
            t += 0.025;
        }
        let mean = e.rate(8.0);
        let peak = e.peak_rate(8.0, 4);
        assert!((mean - 25.0).abs() < 3.0, "mean {mean}");
        assert!((peak - 40.0).abs() < 6.0, "peak {peak}");
        assert!(peak > mean);
        // A constant stream: peak ~= mean (no phantom headroom).
        let mut c = OnlineEstimator::new(8.0);
        let mut t = 0.0;
        while t < 8.0 {
            c.observe(t, 100);
            t += 0.05;
        }
        let (m, p) = (c.rate(8.0), c.peak_rate(8.0, 4));
        assert!((p - m).abs() / m < 0.1, "mean {m} vs peak {p}");
    }

    #[test]
    fn forecast_anticipates_a_ramp_and_matches_a_flat_window() {
        // Linearly ramping arrivals: the one-epoch-ahead forecast must
        // exceed both the window-mean and the current instantaneous-ish
        // estimates (that's the point of anticipatory scaling).
        let mut e = OnlineEstimator::new(16.0);
        let mut t = 0.0;
        while t < 16.0 {
            // rate(t) ~ 10 + 5t req/s.
            let r = 10.0 + 5.0 * t;
            t += 1.0 / r;
            e.observe(t, 200);
        }
        let mean = e.rate(16.0);
        let fc = e.forecast_rate(16.0, 4.0, 4);
        assert!(fc > mean, "forecast {fc} must exceed window mean {mean}");
        // ~10 + 5*20 = 110 req/s expected 4 s out; generous tolerance.
        assert!((80.0..150.0).contains(&fc), "forecast {fc}");
        // The combined single-pass estimate: exactly the peak with the
        // horizon off (the forecast-off no-op), >= both with it on.
        assert_eq!(
            e.planning_rate(16.0, 4, None).to_bits(),
            e.peak_rate(16.0, 4).to_bits()
        );
        let combined = e.planning_rate(16.0, 4, Some(4.0));
        assert!(combined >= e.peak_rate(16.0, 4) && combined >= fc);

        // Flat window: the fitted slope is ~0 and the forecast collapses
        // to the mean — no phantom headroom.
        let mut c = OnlineEstimator::new(16.0);
        let mut t = 0.0;
        while t < 16.0 {
            t += 0.05;
            c.observe(t, 200);
        }
        let (m, f) = (c.rate(16.0), c.forecast_rate(16.0, 4.0, 4));
        assert!((f - m).abs() / m < 0.1, "flat: mean {m} vs forecast {f}");
        // Downward ramps floor at zero, never negative.
        let mut d = OnlineEstimator::new(8.0);
        let mut t = 0.0;
        while t < 8.0 {
            let r = (100.0 - 12.0 * t).max(1.0);
            t += 1.0 / r;
            d.observe(t, 200);
        }
        assert!(d.forecast_rate(8.0, 8.0, 4) >= 0.0);
        // An empty estimator forecasts zero.
        assert_eq!(OnlineEstimator::new(8.0).forecast_rate(0.0, 4.0, 4), 0.0);
    }

    #[test]
    fn rate_ignores_stale_buffer_tail() {
        // Without new observations the estimate must decay, not freeze.
        let mut e = OnlineEstimator::new(5.0);
        for i in 0..50 {
            e.observe(i as f64 * 0.1, 100); // 10 req/s until t = 5
        }
        assert!(e.rate(5.0) > 8.0);
        assert_eq!(e.rate(20.0), 0.0, "stale observations must not count");
    }

    #[test]
    fn eviction_keeps_only_window() {
        let mut e = OnlineEstimator::new(5.0);
        for i in 0..100 {
            e.observe(i as f64, 100);
        }
        // At t = 99, the window [94, 99] holds 6 observations.
        assert!(e.len() <= 6, "len {}", e.len());
    }

    #[test]
    fn empirical_cdf_recovers_quantiles() {
        let w = traces::azure();
        let mut rng = Rng::new(5);
        let mut e = OnlineEstimator::new(1e9);
        for i in 0..50_000u32 {
            let l = w.cdf.sample(&mut rng).round().max(2.0) as u32;
            e.observe(i as f64 * 1e-3, l);
        }
        let cdf = e.empirical_cdf().expect("enough samples");
        for q in [0.25, 0.5, 0.75, 0.9] {
            let est = cdf.quantile(q);
            let truth = w.cdf.quantile(q);
            assert!(
                (est - truth).abs() / truth < 0.15,
                "q={q}: est {est} vs true {truth}"
            );
        }
    }

    #[test]
    fn snapshot_swaps_cdf_and_keeps_template() {
        let w = traces::agent_heavy();
        let mut e = OnlineEstimator::new(1e9);
        for i in 0..1000u32 {
            e.observe(i as f64, 100 + (i % 900));
        }
        let snap = e.snapshot(&w).expect("snapshot");
        assert_eq!(snap.p_c, w.p_c);
        assert_eq!(snap.category_mix, w.category_mix);
        assert!(snap.cdf.max_tokens() <= 1000.0);
    }

    #[test]
    fn thin_window_yields_no_cdf() {
        let mut e = OnlineEstimator::new(10.0);
        for i in 0..5u32 {
            e.observe(i as f64, 100);
        }
        assert!(e.empirical_cdf().is_none());
        assert!(e.snapshot(&traces::azure()).is_none());
    }

    /// The pre-overhaul sort-based anchor computation, verbatim — the
    /// equivalence oracle for the incremental Fenwick index.
    fn sorted_reference_cdf(window: &[(f64, f64)]) -> Option<AnchoredCdf> {
        if window.len() < 8 {
            return None;
        }
        let mut xs: Vec<f64> = window.iter().map(|&(_, l)| l).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let hi = xs[n - 1];
        let lo = (xs[0] - 1.0).max(1.0);
        if hi <= lo {
            return None;
        }
        let mut anchors: Vec<(f64, f64)> = vec![(lo, 0.0)];
        for &q in &ANCHOR_QS {
            let idx = ((q * (n - 1) as f64).round() as usize).min(n - 1);
            let x = xs[idx];
            let last = *anchors.last().expect("non-empty");
            if x <= last.0 || x >= hi {
                continue;
            }
            let f = xs.partition_point(|&v| v <= x) as f64 / n as f64;
            if f <= last.1 || f >= 1.0 {
                continue;
            }
            anchors.push((x, f));
        }
        anchors.push((hi, 1.0));
        Some(AnchoredCdf::new(anchors))
    }

    #[test]
    fn incremental_anchors_match_the_sorted_oracle_bitwise() {
        // Sliding window with eviction churn on a fat-tailed stream: the
        // Fenwick order statistics must reproduce the copy-and-sort CDF
        // bit for bit at every probe.
        let w = traces::agent_heavy();
        let mut rng = Rng::new(77);
        let mut est = OnlineEstimator::new(20.0);
        let mut shadow: Vec<(f64, f64)> = Vec::new();
        let mut t = 0.0;
        for i in 0..30_000u32 {
            t += rng.exp(150.0);
            let l = w.cdf.sample(&mut rng).round().max(2.0) as u32;
            est.observe(t, l);
            shadow.push((t, l as f64));
            if i % 2_500 == 0 {
                let cutoff = t - 20.0;
                let window: Vec<(f64, f64)> =
                    shadow.iter().copied().filter(|&(ts, _)| ts >= cutoff).collect();
                let want = sorted_reference_cdf(&window);
                let got = est.empirical_cdf();
                assert_eq!(want.is_some(), got.is_some(), "probe {i}");
                if let (Some(a), Some(b)) = (want, got) {
                    for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
                        assert_eq!(
                            a.quantile(q).to_bits(),
                            b.quantile(q).to_bits(),
                            "probe {i} q {q}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn out_of_range_lengths_fall_back_to_the_exact_sort() {
        // Lengths beyond the Fenwick range (>= 2^18) must not silently
        // clamp: the estimator switches to the sort path and still
        // matches the reference bitwise.
        let mut est = OnlineEstimator::new(1e9);
        let mut shadow: Vec<(f64, f64)> = Vec::new();
        let mut x = 7u64;
        for i in 0..500u32 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // Mix of ordinary and huge lengths (u32 range, above 2^18).
            let l = if i % 7 == 0 {
                300_000 + (x >> 40) as u32
            } else {
                2 + (x >> 48) as u32 % 9_000
            };
            est.observe(i as f64, l);
            shadow.push((i as f64, l as f64));
        }
        let want = sorted_reference_cdf(&shadow).expect("reference cdf");
        let got = est.empirical_cdf().expect("fallback cdf");
        for q in [0.05, 0.5, 0.9, 0.99] {
            assert_eq!(want.quantile(q).to_bits(), got.quantile(q).to_bits(), "q {q}");
        }
        // The support upper edge is the true maximum, not the clamp.
        assert!(got.quantile(1.0) > (1u32 << 18) as f64);
    }

    #[test]
    fn seasonal_anticipates_a_diurnal_ramp() {
        // A sinusoidal "day": rate(t) = 100 + 80 sin(2 pi t / P). After
        // two full periods of epoch observations, the forecast one epoch
        // ahead of the trough's rising edge must see the coming ramp —
        // i.e. exceed the rate observed *at* that time — and the forecast
        // at any phase must track the true rate closely.
        let period = 86_400.0;
        let epoch = period / 48.0; // 30-minute epochs
        let rate_at = |t: f64| 100.0 + 80.0 * (2.0 * std::f64::consts::PI * t / period).sin();
        let mut se = SeasonalEstimator::new(period, 16);
        let mut t = 0.0;
        while t < 2.0 * period {
            se.observe(t, rate_at(t));
            t += epoch;
        }
        // Third day, early rising edge: the same-phase history anticipates.
        let probe = 2.0 * period + period / 16.0;
        let fc = se.forecast(probe + epoch).expect("two days of history");
        assert!(fc > rate_at(probe), "forecast {fc} vs current {}", rate_at(probe));
        for i in 0..16 {
            let tp = 2.0 * period + (i as f64 + 0.5) / 16.0 * period;
            let f = se.forecast(tp).expect("full history");
            let truth = rate_at(tp);
            assert!((f - truth).abs() < 0.2 * truth + 5.0, "phase {i}: {f} vs {truth}");
        }
    }

    #[test]
    fn seasonal_is_flat_on_a_flat_window_and_none_without_history() {
        let mut se = SeasonalEstimator::new(1000.0, 8);
        assert_eq!(se.forecast(0.0), None, "no history yet");
        for i in 0..200 {
            se.observe(i as f64 * 10.0, 42.0);
        }
        // A constant rate forecasts exactly itself at every phase — no
        // phantom headroom for the max(reactive, seasonal) combiner.
        for i in 0..20 {
            let f = se.forecast(i as f64 * 137.0).expect("history");
            assert!((f - 42.0).abs() < 1e-9, "{f}");
        }
        // A phase never observed still reads None.
        let mut sparse = SeasonalEstimator::new(1000.0, 8);
        sparse.observe(0.0, 10.0);
        assert!(sparse.forecast(0.0).is_some());
        assert_eq!(sparse.forecast(500.0), None);
    }

    #[test]
    fn degenerate_equal_lengths_still_build_a_cdf() {
        let mut e = OnlineEstimator::new(10.0);
        for i in 0..50u32 {
            e.observe(i as f64 * 0.01, 512);
        }
        let cdf = e.empirical_cdf().expect("two-anchor cdf");
        assert_eq!(cdf.cdf(512.0), 1.0);
        assert_eq!(cdf.cdf(300.0), 0.0);
    }
}
