//! Sliding-window online workload estimation: the control loop's eyes.
//!
//! The planner consumes a prompt-length CDF and an arrival rate; under a
//! nonstationary workload neither is known a priori. [`OnlineEstimator`]
//! keeps the last `window_s` seconds of `(arrival, L_total)` observations
//! and re-derives both on demand: the rate from the window count, the CDF
//! as an [`AnchoredCdf`] through empirical quantile anchors — the same
//! piecewise log-linear type the offline traces use, so one planner serves
//! both the offline tables and the live controller.

use std::collections::VecDeque;

use crate::workload::cdf::AnchoredCdf;
use crate::workload::traces::Workload;

/// Quantile levels the empirical CDF is anchored at (interior points; the
/// support endpoints are added explicitly).
const ANCHOR_QS: [f64; 13] = [
    0.05, 0.10, 0.20, 0.30, 0.40, 0.50, 0.60, 0.70, 0.80, 0.90, 0.95, 0.98, 0.99,
];

/// Sliding-window estimator of the arrival rate and prompt-length CDF.
/// Observations must be fed in non-decreasing arrival order (they come
/// straight off the arrival stream).
#[derive(Clone, Debug)]
pub struct OnlineEstimator {
    window_s: f64,
    /// (arrival_s, l_total) pairs inside the window, oldest first.
    buf: VecDeque<(f64, f64)>,
    n_seen: u64,
}

impl OnlineEstimator {
    pub fn new(window_s: f64) -> Self {
        assert!(window_s > 0.0, "window must be positive");
        OnlineEstimator {
            window_s,
            buf: VecDeque::new(),
            n_seen: 0,
        }
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Observations currently inside the window.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total observations ever fed (diagnostics).
    pub fn n_seen(&self) -> u64 {
        self.n_seen
    }

    /// Record one arrival; evicts everything older than the window.
    pub fn observe(&mut self, arrival_s: f64, l_total: u32) {
        self.buf.push_back((arrival_s, l_total as f64));
        self.n_seen += 1;
        self.evict(arrival_s);
    }

    fn evict(&mut self, now: f64) {
        let cutoff = now - self.window_s;
        while let Some(&(t, _)) = self.buf.front() {
            if t < cutoff {
                self.buf.pop_front();
            } else {
                break;
            }
        }
    }

    /// Windowed arrival-rate estimate at time `now`, req/s. Early in a run
    /// (before one full window has elapsed) the denominator is the elapsed
    /// time, so the estimate is unbiased from the first observation.
    /// Robust to a stale buffer (eviction happens on `observe`, but `rate`
    /// only counts observations inside `[now - window, now]`).
    pub fn rate(&self, now: f64) -> f64 {
        let span = self.window_s.min(now);
        if span <= 0.0 {
            return 0.0;
        }
        let cutoff = now - self.window_s;
        let count = self
            .buf
            .iter()
            .rev()
            .take_while(|&&(t, _)| t >= cutoff)
            .count();
        count as f64 / span
    }

    /// Peak-tracking rate estimate: the window is split into `parts`
    /// equal sub-intervals and the busiest one's rate is returned. Under
    /// a ramp the mean-window estimate lags by ~window/2; the peak
    /// estimate lags by ~window/(2*parts) and also captures bursts — the
    /// controller provisions against this so upswings don't burn SLO.
    /// Falls back to [`Self::rate`] semantics when the window is young.
    pub fn peak_rate(&self, now: f64, parts: usize) -> f64 {
        assert!(parts >= 1);
        let span = self.window_s.min(now);
        if span <= 0.0 {
            return 0.0;
        }
        let sub = span / parts as f64;
        let cutoff = now - span;
        let mut counts = vec![0u64; parts];
        for &(t, _) in self.buf.iter().rev() {
            if t < cutoff {
                break;
            }
            let idx = (((t - cutoff) / sub) as usize).min(parts - 1);
            counts[idx] += 1;
        }
        counts
            .iter()
            .map(|&c| c as f64 / sub)
            .fold(0.0, f64::max)
    }

    /// Empirical prompt-length CDF over the window, anchored at the
    /// [`ANCHOR_QS`] quantiles. `None` with fewer than 8 observations —
    /// too little signal to re-plan from.
    pub fn empirical_cdf(&self) -> Option<AnchoredCdf> {
        if self.buf.len() < 8 {
            return None;
        }
        let mut xs: Vec<f64> = self.buf.iter().map(|&(_, l)| l).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let hi = xs[n - 1];
        // Support lower edge strictly below the smallest sample (AnchoredCdf
        // requires F(first anchor) = 0 and x > 0; L_total >= 2 always).
        let lo = (xs[0] - 1.0).max(1.0);
        if hi <= lo {
            return None;
        }
        let mut anchors: Vec<(f64, f64)> = vec![(lo, 0.0)];
        for &q in &ANCHOR_QS {
            let idx = ((q * (n - 1) as f64).round() as usize).min(n - 1);
            let x = xs[idx];
            let last = *anchors.last().expect("non-empty");
            if x <= last.0 || x >= hi {
                continue;
            }
            // Exact empirical mass at x, so anchors are self-consistent
            // even when quantile ranks collide on duplicate lengths.
            let f = xs.partition_point(|&v| v <= x) as f64 / n as f64;
            if f <= last.1 || f >= 1.0 {
                continue;
            }
            anchors.push((x, f));
        }
        anchors.push((hi, 1.0));
        Some(AnchoredCdf::new(anchors))
    }

    /// A re-plannable [`Workload`]: the template's categories, output
    /// model and compressibility with the window's empirical CDF swapped
    /// in. `None` when the window is too thin (see [`Self::empirical_cdf`]).
    pub fn snapshot(&self, template: &Workload) -> Option<Workload> {
        let cdf = self.empirical_cdf()?;
        let mut w = template.clone();
        w.cdf = cdf;
        Some(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::cdf::LengthDist;
    use crate::workload::traces;

    #[test]
    fn rate_tracks_window_count() {
        let mut e = OnlineEstimator::new(10.0);
        // 100 arrivals over 10 s => 10 req/s.
        for i in 0..100 {
            e.observe(i as f64 * 0.1, 500);
        }
        let r = e.rate(9.9);
        assert!((r - 10.1).abs() < 0.5, "rate {r}");
        assert_eq!(e.n_seen(), 100);
    }

    #[test]
    fn peak_rate_tracks_the_busy_subwindow() {
        let mut e = OnlineEstimator::new(8.0);
        // 4 s at 10 req/s then 4 s at 40 req/s.
        let mut t = 0.0;
        while t < 4.0 {
            e.observe(t, 100);
            t += 0.1;
        }
        while t < 8.0 {
            e.observe(t, 100);
            t += 0.025;
        }
        let mean = e.rate(8.0);
        let peak = e.peak_rate(8.0, 4);
        assert!((mean - 25.0).abs() < 3.0, "mean {mean}");
        assert!((peak - 40.0).abs() < 6.0, "peak {peak}");
        assert!(peak > mean);
        // A constant stream: peak ~= mean (no phantom headroom).
        let mut c = OnlineEstimator::new(8.0);
        let mut t = 0.0;
        while t < 8.0 {
            c.observe(t, 100);
            t += 0.05;
        }
        let (m, p) = (c.rate(8.0), c.peak_rate(8.0, 4));
        assert!((p - m).abs() / m < 0.1, "mean {m} vs peak {p}");
    }

    #[test]
    fn rate_ignores_stale_buffer_tail() {
        // Without new observations the estimate must decay, not freeze.
        let mut e = OnlineEstimator::new(5.0);
        for i in 0..50 {
            e.observe(i as f64 * 0.1, 100); // 10 req/s until t = 5
        }
        assert!(e.rate(5.0) > 8.0);
        assert_eq!(e.rate(20.0), 0.0, "stale observations must not count");
    }

    #[test]
    fn eviction_keeps_only_window() {
        let mut e = OnlineEstimator::new(5.0);
        for i in 0..100 {
            e.observe(i as f64, 100);
        }
        // At t = 99, the window [94, 99] holds 6 observations.
        assert!(e.len() <= 6, "len {}", e.len());
    }

    #[test]
    fn empirical_cdf_recovers_quantiles() {
        let w = traces::azure();
        let mut rng = Rng::new(5);
        let mut e = OnlineEstimator::new(1e9);
        for i in 0..50_000u32 {
            let l = w.cdf.sample(&mut rng).round().max(2.0) as u32;
            e.observe(i as f64 * 1e-3, l);
        }
        let cdf = e.empirical_cdf().expect("enough samples");
        for q in [0.25, 0.5, 0.75, 0.9] {
            let est = cdf.quantile(q);
            let truth = w.cdf.quantile(q);
            assert!(
                (est - truth).abs() / truth < 0.15,
                "q={q}: est {est} vs true {truth}"
            );
        }
    }

    #[test]
    fn snapshot_swaps_cdf_and_keeps_template() {
        let w = traces::agent_heavy();
        let mut e = OnlineEstimator::new(1e9);
        for i in 0..1000u32 {
            e.observe(i as f64, 100 + (i % 900));
        }
        let snap = e.snapshot(&w).expect("snapshot");
        assert_eq!(snap.p_c, w.p_c);
        assert_eq!(snap.category_mix, w.category_mix);
        assert!(snap.cdf.max_tokens() <= 1000.0);
    }

    #[test]
    fn thin_window_yields_no_cdf() {
        let mut e = OnlineEstimator::new(10.0);
        for i in 0..5u32 {
            e.observe(i as f64, 100);
        }
        assert!(e.empirical_cdf().is_none());
        assert!(e.snapshot(&traces::azure()).is_none());
    }

    #[test]
    fn degenerate_equal_lengths_still_build_a_cdf() {
        let mut e = OnlineEstimator::new(10.0);
        for i in 0..50u32 {
            e.observe(i as f64 * 0.01, 512);
        }
        let cdf = e.empirical_cdf().expect("two-anchor cdf");
        assert_eq!(cdf.cdf(512.0), 1.0);
        assert_eq!(cdf.cdf(300.0), 0.0);
    }
}
