//! The three evaluation workloads (paper §7.1), as calibrated synthetic
//! trace generators.
//!
//! The real Azure/LMSYS traces are unavailable offline; each workload's CDF
//! is anchored to the paper's published statistics and the per-trace tests
//! below assert that every published number (alpha, beta, quantiles, mean)
//! is reproduced. The Agent-heavy trace is synthetic in the paper too,
//! built from the same published component statistics.

use crate::util::rng::Rng;
use crate::workload::cdf::{AnchoredCdf, LengthDist};
use crate::workload::request::{Category, OutputModel, Request};

/// A named workload: CDF + evaluation parameters from paper Table 2.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: &'static str,
    pub cdf: AnchoredCdf,
    /// Evaluation boundary B_short (paper Table 2).
    pub b_short: u32,
    /// Compression bandwidth used in the retrofit baseline (Table 2).
    pub gamma: f64,
    /// Compressibility rate p_c of borderline traffic (§3.1; 1.0 for
    /// prose/RAG-dominated workloads, 0.75 for Agent-heavy where 25% of the
    /// borderline band is code).
    pub p_c: f64,
    /// Fraction of *borderline* traffic that is code-category.
    pub borderline_code_frac: f64,
    /// Unconditional category weights: (conversational, rag, code, tool_use).
    pub category_mix: [f64; 4],
    pub output: OutputModel,
    /// Per-archetype output models, indexed by [`Category::index`]. `None`
    /// (every built-in evaluation trace) keeps the single shared `output`
    /// model and the historical RNG draw order — bit-identical sampling.
    /// `Some` draws the category *before* the output length so each
    /// archetype can decode-skew differently (the "agentic" trace);
    /// `output` then serves as the blended analytic stand-in for
    /// calibrations that integrate one model.
    pub output_by_category: Option<[OutputModel; 4]>,
}

impl Workload {
    /// alpha = F(B_short): fraction already routed short (§2.3).
    pub fn alpha(&self) -> f64 {
        self.cdf.cdf(self.b_short as f64)
    }

    /// beta = F(gamma * B) - F(B): the borderline fraction (§2.3).
    pub fn beta(&self) -> f64 {
        self.beta_at(self.gamma)
    }

    pub fn beta_at(&self, gamma: f64) -> f64 {
        self.cdf.cdf(gamma * self.b_short as f64) - self.alpha()
    }

    /// Effective short fraction with C&R active: alpha' = alpha + beta*p_c
    /// (Eq. 1 / Eq. 14).
    pub fn alpha_prime(&self, gamma: f64) -> f64 {
        self.alpha() + self.beta_at(gamma) * self.p_c
    }

    /// Sample the content category, conditioned on borderline membership:
    /// within the band the code fraction follows `borderline_code_frac`
    /// (paper §7.1: ~25% of Agent-heavy borderline traffic is code).
    pub fn sample_category(&self, l_total: f64, gamma: f64, rng: &mut Rng) -> Category {
        let b = self.b_short as f64;
        let borderline = l_total > b && l_total <= gamma * b;
        if borderline {
            if rng.bool(self.borderline_code_frac) {
                return Category::Code;
            }
            // Non-code borderline traffic is prose/RAG by assumption (§5.2).
            let w = [self.category_mix[0], self.category_mix[1]];
            return match rng.weighted(&w) {
                0 => Category::Conversational,
                _ => Category::Rag,
            };
        }
        match rng.weighted(&self.category_mix) {
            0 => Category::Conversational,
            1 => Category::Rag,
            2 => Category::Code,
            _ => Category::ToolUse,
        }
    }

    /// FNV-1a over the workload features service-time calibration depends
    /// on (CDF anchors and the output model). The planner's calibration
    /// cache and the shared moment-table registry key by truncation cuts
    /// under this fingerprint: a drifted empirical CDF snapshot mints a
    /// fresh fingerprint and so invalidates both.
    pub fn fingerprint(&self) -> u64 {
        use crate::util::hash::{fnv1a_words, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        for &(x, f) in self.cdf.anchors() {
            h = fnv1a_words(h, &[x.to_bits(), f.to_bits()]);
        }
        h = fnv1a_words(
            h,
            &[
                self.output.frac.to_bits(),
                self.output.sigma.to_bits(),
                self.output.min_tokens as u64,
                self.output.max_tokens as u64,
            ],
        );
        // Absorb per-category models only when present, so every workload
        // without them keeps its pre-existing fingerprint (cache keys and
        // moment-table registry entries survive the field's addition).
        if let Some(models) = &self.output_by_category {
            for m in models {
                h = fnv1a_words(
                    h,
                    &[
                        m.frac.to_bits(),
                        m.sigma.to_bits(),
                        m.min_tokens as u64,
                        m.max_tokens as u64,
                    ],
                );
            }
        }
        h
    }

    /// Draw one request (without arrival time; see [`super::arrivals`]).
    pub fn sample_request(&self, id: u64, arrival_s: f64, rng: &mut Rng) -> Request {
        let l_total = self.cdf.sample(rng).round().max(2.0);
        match &self.output_by_category {
            // Identity discipline: without per-category models the draw
            // order (length, output jitter, category) is the historical
            // stream — bit-identical requests under any seed.
            None => {
                let l_out = self.output.sample_l_out(l_total, rng);
                let category = self.sample_category(l_total, self.gamma, rng);
                Request::new(id, l_total as u32, l_out, category, arrival_s)
            }
            // The opt-in path must know the category before drawing the
            // output length, so it reorders to (length, category, output).
            Some(models) => {
                let category = self.sample_category(l_total, self.gamma, rng);
                let l_out = models[category.index()].sample_l_out(l_total, rng);
                Request::new(id, l_total as u32, l_out, category, arrival_s)
            }
        }
    }
}

/// Azure LLM Inference Trace 2023 (Patel et al. 2024): 28,185 requests,
/// mean L_total = 1,588, p90 = 4,242, p99 = 7,445; alpha = 0.898 and
/// beta = 0.078 at B_short = 4,096, gamma = 1.5 (16x cliff; Archetype I/II).
pub fn azure() -> Workload {
    Workload {
        name: "azure",
        cdf: AnchoredCdf::new(vec![
            (16.0, 0.0),
            (64.0, 0.03),
            (128.0, 0.08),
            (256.0, 0.18),
            (512.0, 0.36),
            (1024.0, 0.56),
            (2048.0, 0.76),
            (3072.0, 0.855),
            (4096.0, 0.898),
            (4242.0, 0.90),
            (6144.0, 0.976),
            (7445.0, 0.99),
            (16384.0, 0.998),
            (65536.0, 1.0),
        ]),
        b_short: 4096,
        gamma: 1.5,
        p_c: 1.0,
        borderline_code_frac: 0.0,
        // 8,819 coding / 19,366 conversational in the trace; coding requests
        // are short-pool dominated and never borderline in this workload.
        category_mix: [0.55, 0.14, 0.31, 0.0],
        output: OutputModel {
            frac: 0.15,
            sigma: 0.3,
            min_tokens: 16,
            max_tokens: 2048,
        },
        output_by_category: None,
    }
}

/// LMSYS-Chat-1M multi-turn (Zheng et al. 2024), accumulated context per
/// turn: alpha = 0.909, beta = 0.046 at B_short = 1,536, gamma = 1.5
/// (42x cliff; Archetype I/II).
pub fn lmsys() -> Workload {
    Workload {
        name: "lmsys",
        cdf: AnchoredCdf::new(vec![
            (16.0, 0.0),
            (64.0, 0.10),
            (128.0, 0.25),
            (256.0, 0.45),
            (512.0, 0.65),
            (768.0, 0.75),
            (1024.0, 0.83),
            (1536.0, 0.909),
            (2304.0, 0.955),
            (4096.0, 0.985),
            (8192.0, 0.996),
            (32768.0, 1.0),
        ]),
        b_short: 1536,
        gamma: 1.5,
        p_c: 1.0,
        borderline_code_frac: 0.0,
        category_mix: [0.85, 0.05, 0.10, 0.0],
        output: OutputModel {
            frac: 0.20,
            sigma: 0.3,
            min_tokens: 16,
            max_tokens: 1024,
        },
        output_by_category: None,
    }
}

/// Agent-heavy synthetic trace (paper §7.1): SWE-bench 40% + BFCL 25% +
/// RAG 35%; mean = 6,511, p50 = 4,096, p90 = 16,384, p99 = 32,768;
/// alpha = 0.740, beta = 0.112 at B_short = 8,192 (8x cliff; Archetype II).
/// 25% of borderline traffic is code => p_c = 0.75.
pub fn agent_heavy() -> Workload {
    Workload {
        name: "agent-heavy",
        cdf: AnchoredCdf::new(vec![
            (64.0, 0.0),
            (256.0, 0.04),
            (512.0, 0.09),
            (1024.0, 0.17),
            (2048.0, 0.30),
            (4096.0, 0.50),
            (8192.0, 0.74),
            (12288.0, 0.852),
            (16384.0, 0.90),
            (20480.0, 0.95),
            (32768.0, 0.99),
            (65536.0, 1.0),
        ]),
        b_short: 8192,
        gamma: 1.5,
        p_c: 0.75,
        borderline_code_frac: 0.25,
        category_mix: [0.05, 0.35, 0.40, 0.20],
        output: OutputModel {
            frac: 0.10,
            sigma: 0.4,
            min_tokens: 16,
            max_tokens: 2048,
        },
        output_by_category: None,
    }
}

/// Long-decode "agentic" variant of the Agent-heavy trace (ROADMAP item
/// 4): the same prompt-length CDF and category structure, but decode
/// budgets dominated by multi-step tool loops — per-archetype output
/// models with 2.5–3.5x the base decode fraction, so decode-phase KV
/// growth (not prompt length) is the binding resource. The shared
/// `output` model is the mixture's analytic stand-in for single-model
/// calibrations; the DES samples the per-category models.
pub fn agentic() -> Workload {
    let base = agent_heavy();
    Workload {
        name: "agentic",
        output: OutputModel {
            frac: 0.30,
            sigma: 0.45,
            min_tokens: 64,
            max_tokens: 4096,
        },
        output_by_category: Some([
            // Conversational: shorter summarize/answer turns.
            OutputModel {
                frac: 0.25,
                sigma: 0.4,
                min_tokens: 64,
                max_tokens: 2048,
            },
            // RAG: grounded synthesis over retrieved context.
            OutputModel {
                frac: 0.28,
                sigma: 0.4,
                min_tokens: 64,
                max_tokens: 4096,
            },
            // Code: multi-file edit streams — the decode-heaviest class.
            OutputModel {
                frac: 0.35,
                sigma: 0.5,
                min_tokens: 128,
                max_tokens: 4096,
            },
            // Tool use: long call/observation loops.
            OutputModel {
                frac: 0.32,
                sigma: 0.5,
                min_tokens: 64,
                max_tokens: 4096,
            },
        ]),
        ..base
    }
}

impl Workload {
    /// Load a workload from a JSON config (the launcher's `--config`):
    ///
    /// ```json
    /// {
    ///   "name": "my-trace",
    ///   "cdf": [[16, 0.0], [2048, 0.7], [65536, 1.0]],
    ///   "b_short": 4096, "gamma": 1.5, "p_c": 1.0,
    ///   "borderline_code_frac": 0.0,
    ///   "category_mix": [0.6, 0.2, 0.2, 0.0],
    ///   "output": {"frac": 0.15, "sigma": 0.3, "min_tokens": 16, "max_tokens": 2048}
    /// }
    /// ```
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Workload> {
        use crate::util::json::Json;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("custom")
            .to_string();
        let anchors = j
            .get("cdf")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("config missing `cdf` anchor array"))?
            .iter()
            .map(|p| -> anyhow::Result<(f64, f64)> {
                let pair = p
                    .as_arr()
                    .filter(|a| a.len() == 2)
                    .ok_or_else(|| anyhow::anyhow!("cdf anchors must be [tokens, F] pairs"))?;
                Ok((
                    pair[0].as_f64().ok_or_else(|| anyhow::anyhow!("bad anchor x"))?,
                    pair[1].as_f64().ok_or_else(|| anyhow::anyhow!("bad anchor F"))?,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let f = |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        let mix = j
            .get("category_mix")
            .and_then(Json::as_arr)
            .map(|a| {
                let mut m = [0.0f64; 4];
                for (i, v) in a.iter().take(4).enumerate() {
                    m[i] = v.as_f64().unwrap_or(0.0);
                }
                m
            })
            .unwrap_or([0.7, 0.2, 0.1, 0.0]);
        let out = j.get("output");
        let of = |k: &str, d: f64| out.and_then(|o| o.get(k)).and_then(Json::as_f64).unwrap_or(d);
        let output = OutputModel {
            frac: of("frac", 0.15),
            sigma: of("sigma", 0.3),
            min_tokens: of("min_tokens", 16.0) as u32,
            max_tokens: of("max_tokens", 2048.0) as u32,
        };
        output.validate("output model")?;
        // Optional per-archetype override block, keyed by category name;
        // absent categories inherit the base model, and every model is
        // validated with its category name and index in the error.
        let output_by_category = match j.get("output_by_category") {
            None => None,
            Some(per) => {
                let mut models = [output; 4];
                for (i, c) in Category::ALL.iter().enumerate() {
                    if let Some(o) = per.get(c.name()) {
                        let g =
                            |k: &str, d: f64| o.get(k).and_then(Json::as_f64).unwrap_or(d);
                        models[i] = OutputModel {
                            frac: g("frac", output.frac),
                            sigma: g("sigma", output.sigma),
                            min_tokens: g("min_tokens", output.min_tokens as f64) as u32,
                            max_tokens: g("max_tokens", output.max_tokens as f64) as u32,
                        };
                    }
                    models[i]
                        .validate(&format!("output model \"{}\" (index {i})", c.name()))?;
                }
                Some(models)
            }
        };
        Ok(Workload {
            // Config-loaded workloads live for the process lifetime.
            name: Box::leak(name.into_boxed_str()),
            cdf: AnchoredCdf::new(anchors),
            b_short: f("b_short", 4096.0) as u32,
            gamma: f("gamma", 1.5),
            p_c: f("p_c", 1.0),
            borderline_code_frac: f("borderline_code_frac", 0.0),
            category_mix: mix,
            output,
            output_by_category,
        })
    }

    /// Load from a JSON file path.
    pub fn from_config_file(path: &str) -> anyhow::Result<Workload> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let j = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        Workload::from_json(&j)
    }
}

/// One replayable request from a JSONL text trace: the prompt itself,
/// the output budget, and the arrival offset from trace start. This is
/// the live-gateway analog of [`Workload::sample_request`] — real text
/// instead of sampled token counts — and what `fleetopt serve --trace`
/// feeds through the admission pipeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceItem {
    pub text: String,
    pub max_output: u32,
    pub arrival_s: f64,
}

/// Parse one trace line: `{"text": "...", "max_output": 64,
/// "arrival_s": 1.25}` (`max_output` defaults to 64, `arrival_s` to 0).
/// Blank lines and `#` comments yield `None`.
pub fn parse_trace_line(line: &str) -> anyhow::Result<Option<TraceItem>> {
    use crate::util::json::Json;
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') {
        return Ok(None);
    }
    let j = Json::parse(t).map_err(|e| anyhow::anyhow!("bad trace line: {e}"))?;
    let text = j
        .get("text")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("trace line missing `text`"))?
        .to_string();
    let max_output = j
        .get("max_output")
        .and_then(Json::as_f64)
        .unwrap_or(64.0) as u32;
    let arrival_s = j.get("arrival_s").and_then(Json::as_f64).unwrap_or(0.0);
    if max_output == 0 {
        anyhow::bail!("trace line has max_output = 0");
    }
    if !arrival_s.is_finite() || arrival_s < 0.0 {
        anyhow::bail!("trace line has bad arrival_s {arrival_s}");
    }
    Ok(Some(TraceItem {
        text,
        max_output,
        arrival_s,
    }))
}

/// Whole-buffer parse, the oracle the streaming loader is pinned to
/// (`streamed_trace_loading_matches_whole_file_parse` below).
pub fn parse_text_trace(text: &str) -> anyhow::Result<Vec<TraceItem>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(item) =
            parse_trace_line(line).map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?
        {
            out.push(item);
        }
    }
    Ok(out)
}

/// Stream a JSONL text trace from disk: `BufRead` line iteration with one
/// reused line buffer, so peak memory is one line (plus the parsed
/// items), not the whole file — traces at "millions of users" scale are
/// far bigger than any single prompt. Parses identically to
/// [`parse_text_trace`] line for line.
pub fn load_text_trace(path: &str) -> anyhow::Result<Vec<TraceItem>> {
    use std::io::BufRead;
    let file =
        std::fs::File::open(path).map_err(|e| anyhow::anyhow!("opening {path}: {e}"))?;
    let mut reader = std::io::BufReader::new(file);
    let mut line = String::new();
    let mut out = Vec::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        if n == 0 {
            break;
        }
        lineno += 1;
        if let Some(item) =
            parse_trace_line(&line).map_err(|e| anyhow::anyhow!("{path}:{lineno}: {e}"))?
        {
            out.push(item);
        }
    }
    Ok(out)
}

/// All three evaluation workloads in paper order.
pub fn all() -> Vec<Workload> {
    vec![azure(), lmsys(), agent_heavy()]
}

pub fn by_name(name: &str) -> Option<Workload> {
    match name {
        "azure" => Some(azure()),
        "lmsys" => Some(lmsys()),
        "agent-heavy" | "agent" => Some(agent_heavy()),
        // Not part of `all()`: the evaluation tables iterate the paper's
        // three traces; the agentic variant is the KV-overload scenario.
        "agentic" | "agent-decode" => Some(agentic()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn azure_matches_published_stats() {
        let w = azure();
        assert!((w.alpha() - 0.898).abs() < 1e-9, "alpha={}", w.alpha());
        assert!((w.beta() - 0.078).abs() < 1e-9, "beta={}", w.beta());
        // quantiles
        assert!((w.cdf.cdf(4242.0) - 0.90).abs() < 1e-9);
        assert!((w.cdf.cdf(7445.0) - 0.99).abs() < 1e-9);
        // mean within 1% of 1,588
        let m = w.cdf.mean();
        assert!((m - 1588.0).abs() / 1588.0 < 0.01, "mean={m}");
    }

    #[test]
    fn lmsys_matches_published_stats() {
        let w = lmsys();
        assert!((w.alpha() - 0.909).abs() < 1e-9);
        assert!((w.beta() - 0.046).abs() < 1e-9);
    }

    #[test]
    fn agent_matches_published_stats() {
        let w = agent_heavy();
        assert!((w.alpha() - 0.740).abs() < 1e-9);
        assert!((w.beta() - 0.112).abs() < 1e-9);
        assert!((w.cdf.quantile(0.50) - 4096.0).abs() < 1.0);
        assert!((w.cdf.quantile(0.90) - 16384.0).abs() < 1.0);
        assert!((w.cdf.quantile(0.99) - 32768.0).abs() < 1.0);
        let m = w.cdf.mean();
        assert!((m - 6511.0).abs() / 6511.0 < 0.05, "mean={m}");
    }

    #[test]
    fn alpha_prime_reflects_pc() {
        let w = agent_heavy();
        let ap = w.alpha_prime(1.5);
        assert!((ap - (0.740 + 0.112 * 0.75)).abs() < 1e-9);
    }

    #[test]
    fn borderline_band_fractions_of_above_threshold() {
        // Paper §1/§4.2: the band holds 43-76% of above-threshold traffic.
        for w in all() {
            let frac = w.beta() / (1.0 - w.alpha());
            assert!(
                (0.40..=0.80).contains(&frac),
                "{}: borderline share of above-threshold = {frac}",
                w.name
            );
        }
    }

    #[test]
    fn agent_borderline_code_fraction() {
        let w = agent_heavy();
        let mut rng = Rng::new(7);
        let n = 50_000;
        let mut code = 0;
        for _ in 0..n {
            // sample a borderline length uniformly inside the band
            let l = rng.uniform(8192.0 + 1.0, 1.5 * 8192.0);
            if w.sample_category(l, 1.5, &mut rng) == Category::Code {
                code += 1;
            }
        }
        let frac = code as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.01, "borderline code frac={frac}");
    }

    #[test]
    fn sampled_requests_reproduce_alpha() {
        let w = azure();
        let mut rng = Rng::new(8);
        let n = 100_000;
        let below = (0..n)
            .filter(|i| {
                w.sample_request(*i as u64, 0.0, &mut rng).l_total <= w.b_short
            })
            .count();
        let frac = below as f64 / n as f64;
        assert!((frac - 0.898).abs() < 0.01, "sampled alpha={frac}");
    }

    #[test]
    fn requests_have_consistent_split() {
        let w = agent_heavy();
        let mut rng = Rng::new(9);
        for i in 0..10_000 {
            let r = w.sample_request(i, 0.0, &mut rng);
            assert_eq!(r.l_in + r.l_out, r.l_total);
            assert!(r.l_out >= 1);
        }
    }

    #[test]
    fn from_json_roundtrips_core_fields() {
        let src = r#"{
          "name": "custom-trace",
          "cdf": [[16, 0.0], [2048, 0.7], [65536, 1.0]],
          "b_short": 2048, "gamma": 1.6, "p_c": 0.9,
          "category_mix": [0.5, 0.3, 0.2, 0.0],
          "output": {"frac": 0.2, "sigma": 0.1, "min_tokens": 8, "max_tokens": 512}
        }"#;
        let j = crate::util::json::Json::parse(src).unwrap();
        let w = Workload::from_json(&j).unwrap();
        assert_eq!(w.name, "custom-trace");
        assert_eq!(w.b_short, 2048);
        assert!((w.gamma - 1.6).abs() < 1e-12);
        assert!((w.alpha() - 0.7).abs() < 1e-12);
        assert_eq!(w.output.max_tokens, 512);
        // And it plans end-to-end.
        let mut rng = Rng::new(1);
        let r = w.sample_request(0, 0.0, &mut rng);
        assert!(r.l_total >= 16);
    }

    #[test]
    fn from_json_rejects_bad_cdf() {
        let j = crate::util::json::Json::parse(r#"{"cdf": [[16, 0.5]]}"#).unwrap();
        assert!(std::panic::catch_unwind(|| Workload::from_json(&j)).is_err());
        let j = crate::util::json::Json::parse(r#"{"b_short": 10}"#).unwrap();
        assert!(Workload::from_json(&j).is_err());
    }

    #[test]
    fn streamed_trace_loading_matches_whole_file_parse() {
        let body = concat!(
            "# replayable text trace\n",
            r#"{"text": "short question about rust", "max_output": 32, "arrival_s": 0.0}"#,
            "\n",
            "\n",
            r#"{"text": "a much longer prompt body with \"quotes\" and unicode é", "arrival_s": 1.5}"#,
            "\n",
            r#"{"text": "defaults only"}"#,
            "\n",
        );
        let path = std::env::temp_dir().join("fleetopt_trace_stream_test.jsonl");
        std::fs::write(&path, body).unwrap();
        let streamed = load_text_trace(path.to_str().unwrap()).unwrap();
        let whole = parse_text_trace(body).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(streamed, whole);
        assert_eq!(streamed.len(), 3);
        assert_eq!(streamed[0].max_output, 32);
        assert_eq!(streamed[1].text, "a much longer prompt body with \"quotes\" and unicode é");
        assert!((streamed[1].arrival_s - 1.5).abs() < 1e-12);
        assert_eq!(streamed[2].max_output, 64);
        assert!((streamed[2].arrival_s - 0.0).abs() < 1e-12);
    }

    #[test]
    fn trace_lines_reject_bad_fields() {
        assert!(parse_trace_line(r#"{"max_output": 5}"#).is_err());
        assert!(parse_trace_line(r#"{"text": "x", "max_output": 0}"#).is_err());
        assert!(parse_trace_line(r#"{"text": "x", "arrival_s": -1}"#).is_err());
        assert!(parse_trace_line("not json").is_err());
        assert!(parse_trace_line("").unwrap().is_none());
        assert!(parse_trace_line("# comment").unwrap().is_none());
    }

    #[test]
    fn by_name_roundtrip() {
        for w in all() {
            assert_eq!(by_name(w.name).unwrap().name, w.name);
        }
        assert_eq!(by_name("agentic").unwrap().name, "agentic");
        assert_eq!(by_name("agent-decode").unwrap().name, "agentic");
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn agentic_trace_is_decode_heavy_but_not_in_all() {
        let w = agentic();
        assert!(w.output_by_category.is_some());
        // Same length structure as agent-heavy, heavier decode.
        assert!((w.alpha() - agent_heavy().alpha()).abs() < 1e-12);
        let mut rng = Rng::new(11);
        let n = 20_000;
        let mean_out: f64 = (0..n)
            .map(|i| w.sample_request(i, 0.0, &mut rng).l_out as f64)
            .sum::<f64>()
            / n as f64;
        let mut rng = Rng::new(11);
        let mean_base: f64 = (0..n)
            .map(|i| agent_heavy().sample_request(i, 0.0, &mut rng).l_out as f64)
            .sum::<f64>()
            / n as f64;
        assert!(
            mean_out > 2.0 * mean_base,
            "agentic mean l_out {mean_out} vs agent-heavy {mean_base}"
        );
        // Not an evaluation trace: the paper tables iterate all() as-is.
        assert!(all().iter().all(|t| t.name != "agentic"));
    }

    #[test]
    fn fingerprint_ignores_absent_category_models() {
        // Adding the field changed no existing fingerprint (calibration
        // caches survive), while Some(models) mints a fresh one.
        let base = agent_heavy();
        let mut with = base.clone();
        with.output_by_category = Some([base.output; 4]);
        assert_eq!(base.fingerprint(), agent_heavy().fingerprint());
        assert_ne!(base.fingerprint(), with.fingerprint());
        assert_ne!(agentic().fingerprint(), base.fingerprint());
    }

    #[test]
    fn sampling_without_category_models_is_order_preserving() {
        // The None arm draws (length, output, category) exactly as before
        // the field existed: pin against a hand-rolled replay of that
        // order on a shared RNG stream.
        let w = azure();
        let mut rng = Rng::new(42);
        let mut oracle = Rng::new(42);
        for i in 0..5_000 {
            let r = w.sample_request(i, 0.0, &mut rng);
            let l_total = w.cdf.sample(&mut oracle).round().max(2.0);
            let l_out = w.output.sample_l_out(l_total, &mut oracle);
            let category = w.sample_category(l_total, w.gamma, &mut oracle);
            let want = Request::new(i, l_total as u32, l_out, category, 0.0);
            assert_eq!(r.l_total, want.l_total);
            assert_eq!(r.l_out, want.l_out);
            assert_eq!(r.category, want.category);
        }
    }

    #[test]
    fn from_json_parses_per_category_output_models() {
        let src = r#"{
          "cdf": [[16, 0.0], [2048, 0.7], [65536, 1.0]],
          "output": {"frac": 0.2, "sigma": 0.1, "min_tokens": 8, "max_tokens": 512},
          "output_by_category": {
            "code": {"frac": 0.4, "max_tokens": 4096},
            "tool_use": {"frac": 0.35}
          }
        }"#;
        let j = crate::util::json::Json::parse(src).unwrap();
        let w = Workload::from_json(&j).unwrap();
        let models = w.output_by_category.expect("per-category block parsed");
        // Overridden fields land on the named category...
        assert!((models[Category::Code.index()].frac - 0.4).abs() < 1e-12);
        assert_eq!(models[Category::Code.index()].max_tokens, 4096);
        assert!((models[Category::ToolUse.index()].frac - 0.35).abs() < 1e-12);
        // ...unspecified fields and categories inherit the base model.
        assert!((models[Category::Code.index()].sigma - 0.1).abs() < 1e-12);
        assert_eq!(models[Category::Rag.index()].max_tokens, 512);
    }

    #[test]
    fn from_json_rejects_bad_output_models_naming_field_and_index() {
        let base = r#""cdf": [[16, 0.0], [65536, 1.0]]"#;
        // Bad base model: field named, no index.
        let j = crate::util::json::Json::parse(&format!(
            r#"{{{base}, "output": {{"frac": 1.5}}}}"#
        ))
        .unwrap();
        let err = Workload::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("frac"), "{err}");
        // Bad per-category model: category name and index both named.
        let j = crate::util::json::Json::parse(&format!(
            r#"{{{base}, "output_by_category": {{"code": {{"min_tokens": 0}}}}}}"#
        ))
        .unwrap();
        let err = Workload::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("min_tokens"), "{err}");
        assert!(err.contains("\"code\""), "{err}");
        assert!(err.contains("index 2"), "{err}");
        // max < min across inherited fields is still caught.
        let j = crate::util::json::Json::parse(&format!(
            r#"{{{base}, "output_by_category": {{"rag": {{"min_tokens": 9000}}}}}}"#
        ))
        .unwrap();
        let err = Workload::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("max_tokens"), "{err}");
        assert!(err.contains("index 1"), "{err}");
    }
}
