//! Prompt-length distributions: the workload CDF `F` that drives the entire
//! provisioning pipeline (paper §2.3–2.4).
//!
//! The production traces themselves are not available offline, so each
//! workload is an [`AnchoredCdf`]: a piecewise log-linear CDF through anchor
//! points taken from the paper's published statistics (quantiles, alpha and
//! beta at the evaluation thresholds, means). The planner, the DES and the
//! gateway all consume this one type, exactly as they would an empirical
//! CDF from a real trace (see DESIGN.md §1 substitutions).

use crate::util::rng::Rng;

/// A distribution over total token budgets L_total.
pub trait LengthDist {
    /// F(x) = P[L_total <= x].
    fn cdf(&self, x: f64) -> f64;

    /// Inverse CDF (quantile function).
    fn quantile(&self, q: f64) -> f64;

    /// Density f(x) (used by the marginal-cost analysis, Prop. 1).
    fn density(&self, x: f64) -> f64 {
        let eps = (x * 1e-4).max(1e-6);
        (self.cdf(x + eps) - self.cdf(x - eps)) / (2.0 * eps)
    }

    /// Draw one sample (inverse-transform by default).
    fn sample(&self, rng: &mut Rng) -> f64 {
        self.quantile(rng.f64())
    }

    /// Mean via the closed-form segment integral when available, else MC.
    fn mean(&self) -> f64;
}

/// Piecewise log-linear CDF through `(tokens, F)` anchor points.
///
/// Between anchors the CDF is linear in `ln x`, which matches how
/// prompt-length distributions look on the standard log-x CDF plots the
/// paper's archetypes are defined over.
#[derive(Clone, Debug)]
pub struct AnchoredCdf {
    /// (x, F(x)) pairs; x strictly increasing, F non-decreasing,
    /// F(first) = 0, F(last) = 1.
    anchors: Vec<(f64, f64)>,
}

impl AnchoredCdf {
    pub fn new(anchors: Vec<(f64, f64)>) -> Self {
        assert!(anchors.len() >= 2, "need at least 2 anchors");
        for w in anchors.windows(2) {
            assert!(w[1].0 > w[0].0, "x must be strictly increasing: {w:?}");
            assert!(w[1].1 >= w[0].1, "F must be non-decreasing: {w:?}");
        }
        let first = anchors.first().unwrap();
        let last = anchors.last().unwrap();
        assert!(first.0 > 0.0, "log-linear interpolation needs x > 0");
        assert!(
            first.1 == 0.0 && (last.1 - 1.0).abs() < 1e-12,
            "F must span [0, 1]"
        );
        AnchoredCdf { anchors }
    }

    pub fn anchors(&self) -> &[(f64, f64)] {
        &self.anchors
    }

    pub fn min_tokens(&self) -> f64 {
        self.anchors[0].0
    }

    pub fn max_tokens(&self) -> f64 {
        self.anchors[self.anchors.len() - 1].0
    }

    fn segment_for_x(&self, x: f64) -> usize {
        // Largest i with anchors[i].0 <= x, clamped to a valid segment start.
        match self
            .anchors
            .binary_search_by(|(ax, _)| ax.partial_cmp(&x).unwrap())
        {
            Ok(i) => i.min(self.anchors.len() - 2),
            Err(0) => 0,
            Err(i) => (i - 1).min(self.anchors.len() - 2),
        }
    }
}

impl LengthDist for AnchoredCdf {
    fn cdf(&self, x: f64) -> f64 {
        if x <= self.min_tokens() {
            return 0.0;
        }
        if x >= self.max_tokens() {
            return 1.0;
        }
        let i = self.segment_for_x(x);
        let (x0, f0) = self.anchors[i];
        let (x1, f1) = self.anchors[i + 1];
        f0 + (f1 - f0) * (x / x0).ln() / (x1 / x0).ln()
    }

    fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        if q <= 0.0 {
            return self.min_tokens();
        }
        if q >= 1.0 {
            return self.max_tokens();
        }
        // Segment with f0 <= q < f1 (skipping flat segments): the smallest
        // i with F(anchors[i+1]) > q, clamped to the last segment. Found by
        // binary search over the interior anchors — `cdf` already binary-
        // searches, and `quantile` sits on the DES sample path and the
        // gateway band checks. Bit-identical to the former linear scan
        // (same i, same interpolation; property-tested in
        // `tests/planner_fastpath.rs` against the verbatim scan).
        let interior = &self.anchors[1..self.anchors.len() - 1];
        let i = interior.partition_point(|&(_, f)| f <= q);
        let (x0, f0) = self.anchors[i];
        let (x1, f1) = self.anchors[i + 1];
        if f1 <= f0 {
            return x1;
        }
        let t = (q - f0) / (f1 - f0);
        x0 * (x1 / x0).powf(t)
    }

    fn density(&self, x: f64) -> f64 {
        if x <= self.min_tokens() || x >= self.max_tokens() {
            return 0.0;
        }
        let i = self.segment_for_x(x);
        let (x0, f0) = self.anchors[i];
        let (x1, f1) = self.anchors[i + 1];
        // d/dx [f0 + dF * ln(x/x0)/ln(x1/x0)] = dF / (x ln(x1/x0))
        (f1 - f0) / (x * (x1 / x0).ln())
    }

    fn mean(&self) -> f64 {
        // Closed form per segment: integral of x f(x) dx over [x0, x1]
        // with f = dF/(x ln(x1/x0)) is dF * (x1 - x0) / ln(x1/x0).
        self.anchors
            .windows(2)
            .map(|w| {
                let (x0, f0) = w[0];
                let (x1, f1) = w[1];
                let df = f1 - f0;
                if df <= 0.0 {
                    0.0
                } else {
                    df * (x1 - x0) / (x1 / x0).ln()
                }
            })
            .sum()
    }
}

/// CDF restricted to an interval — the planner recalibrates pool service
/// rates from `F` restricted to `[1, B]` (short) and `(gamma*B, inf)`
/// (post-compression long pool; paper §6 "Critical: mu_l recalibration").
#[derive(Clone, Debug)]
pub struct TruncatedDist<D: LengthDist> {
    inner: D,
    lo: f64,
    hi: f64,
    f_lo: f64,
    f_hi: f64,
}

impl<D: LengthDist> TruncatedDist<D> {
    pub fn new(inner: D, lo: f64, hi: f64) -> Self {
        assert!(hi > lo);
        let f_lo = inner.cdf(lo);
        let f_hi = inner.cdf(hi);
        assert!(
            f_hi > f_lo,
            "truncation interval [{lo}, {hi}] has zero mass (F: {f_lo}..{f_hi})"
        );
        TruncatedDist {
            inner,
            lo,
            hi,
            f_lo,
            f_hi,
        }
    }

    pub fn mass(&self) -> f64 {
        self.f_hi - self.f_lo
    }
}

impl<D: LengthDist> LengthDist for TruncatedDist<D> {
    fn cdf(&self, x: f64) -> f64 {
        if x <= self.lo {
            return 0.0;
        }
        if x >= self.hi {
            return 1.0;
        }
        (self.inner.cdf(x) - self.f_lo) / (self.f_hi - self.f_lo)
    }

    fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        self.inner
            .quantile(self.f_lo + q * (self.f_hi - self.f_lo))
            .clamp(self.lo, self.hi)
    }

    fn mean(&self) -> f64 {
        // No closed form in general: Simpson over the quantile function,
        // E[X] = integral_0^1 Q(q) dq.
        let n = 2000;
        let mut acc = 0.0;
        for i in 0..n {
            let q0 = i as f64 / n as f64;
            let q1 = (i + 1) as f64 / n as f64;
            let qm = 0.5 * (q0 + q1);
            acc += (self.quantile(q0) + 4.0 * self.quantile(qm) + self.quantile(q1)) / 6.0
                / n as f64;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple() -> AnchoredCdf {
        AnchoredCdf::new(vec![(10.0, 0.0), (100.0, 0.5), (1000.0, 1.0)])
    }

    #[test]
    fn cdf_hits_anchors_exactly() {
        let d = simple();
        assert_eq!(d.cdf(10.0), 0.0);
        assert!((d.cdf(100.0) - 0.5).abs() < 1e-12);
        assert_eq!(d.cdf(1000.0), 1.0);
    }

    #[test]
    fn cdf_log_linear_midpoint() {
        let d = simple();
        // Geometric midpoint of [10, 100] is ~31.6 -> F = 0.25.
        assert!((d.cdf(31.6227766) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn quantile_inverts_cdf() {
        let d = simple();
        for q in [0.01, 0.1, 0.25, 0.5, 0.77, 0.95, 0.99] {
            let x = d.quantile(q);
            assert!((d.cdf(x) - q).abs() < 1e-9, "q={q} x={x}");
        }
    }

    #[test]
    fn quantile_handles_flat_segments() {
        let d = AnchoredCdf::new(vec![
            (10.0, 0.0),
            (100.0, 0.5),
            (200.0, 0.5), // flat
            (1000.0, 1.0),
        ]);
        let x = d.quantile(0.5);
        assert!((100.0..=200.0).contains(&x));
        assert!((d.cdf(d.quantile(0.7)) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn density_integrates_to_one() {
        let d = simple();
        let n = 100_000;
        let (lo, hi) = (10.0f64, 1000.0f64);
        let mut acc = 0.0;
        for i in 0..n {
            // integrate in log space: dx = x dlnx
            let lx = lo.ln() + (hi.ln() - lo.ln()) * (i as f64 + 0.5) / n as f64;
            let x = lx.exp();
            acc += d.density(x) * x * (hi.ln() - lo.ln()) / n as f64;
        }
        assert!((acc - 1.0).abs() < 1e-3, "integral={acc}");
    }

    #[test]
    fn mean_closed_form_matches_mc() {
        let d = simple();
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mc: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        let cf = d.mean();
        assert!(
            (mc - cf).abs() / cf < 0.01,
            "closed-form {cf} vs MC {mc}"
        );
    }

    #[test]
    fn samples_respect_support() {
        let d = simple();
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((10.0..=1000.0).contains(&x));
        }
    }

    #[test]
    fn sample_distribution_matches_cdf() {
        let d = simple();
        let mut rng = Rng::new(3);
        let n = 100_000;
        let below_100 = (0..n).filter(|_| d.sample(&mut rng) <= 100.0).count();
        assert!((below_100 as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn truncated_restricts_support() {
        let d = TruncatedDist::new(simple(), 100.0, 1000.0);
        let mut rng = Rng::new(4);
        for _ in 0..5_000 {
            let x = d.sample(&mut rng);
            assert!((100.0..=1000.0).contains(&x));
        }
        assert_eq!(d.cdf(100.0), 0.0);
        assert_eq!(d.cdf(1000.0), 1.0);
        assert!((d.mass() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn truncated_mean_above_cut_exceeds_full_mean() {
        let full = simple();
        let full_mean = full.mean();
        let tail = TruncatedDist::new(simple(), 100.0, 1000.0);
        assert!(tail.mean() > full_mean);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_unsorted_anchors() {
        AnchoredCdf::new(vec![(10.0, 0.0), (5.0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "zero mass")]
    fn truncated_rejects_empty_interval() {
        TruncatedDist::new(simple(), 2000.0, 3000.0);
    }
}
