//! Stub model runtime, compiled when the `pjrt` feature is off.
//!
//! The `xla` crate (PJRT bindings) is not vendored in offline build
//! images, so the default build substitutes this module for
//! `runtime::engine` with an identical API surface: `ModelRuntime::load`
//! fails with a descriptive error, and every execution entry point is
//! unreachable because no `ModelRuntime` value can ever be constructed.
//! The analytical planner, DES, compressor, and gateway are unaffected —
//! only the live prefill/decode/embed path needs the real runtime.

use anyhow::{bail, Result};

use crate::runtime::artifacts::{Manifest, PoolKind};

/// Output of one decode/prefill call (mirrors `engine::StepOutput`).
pub struct StepOutput {
    /// Row-major logits [n, vocab] (n = slots for decode, chunk for prefill).
    pub logits: Vec<f32>,
    /// Updated key cache (same layout as the input).
    pub k_cache: Vec<f32>,
    /// Updated value cache.
    pub v_cache: Vec<f32>,
}

/// The process-wide model runtime (stub: cannot be constructed).
pub struct ModelRuntime {
    pub manifest: Manifest,
    _unconstructible: (),
}

impl ModelRuntime {
    /// Always fails: the PJRT runtime requires the `pjrt` feature.
    pub fn load(_dir: impl AsRef<std::path::Path>) -> Result<ModelRuntime> {
        bail!(
            "fleetopt was built without the `pjrt` feature: the PJRT/XLA \
             runtime is unavailable; rebuild with `--features pjrt` (and the \
             `xla` dependency) to run the live serving path"
        )
    }

    pub fn device_count(&self) -> usize {
        0
    }

    /// Per-slot KV cache length in f32 scalars: L * C * H * D.
    pub fn slot_cache_len(&self, kind: PoolKind) -> usize {
        let m = &self.manifest.model;
        let p = self.manifest.pool(kind);
        m.n_layers * p.ctx * m.n_heads * m.head_dim
    }

    pub fn prefill(
        &self,
        _kind: PoolKind,
        _k_cache: &[f32],
        _v_cache: &[f32],
        _tokens: &[i32],
        _pos_base: i32,
    ) -> Result<StepOutput> {
        bail!("pjrt feature disabled")
    }

    pub fn decode(
        &self,
        _kind: PoolKind,
        _k_cache: &[f32],
        _v_cache: &[f32],
        _tokens: &[i32],
        _pos: &[i32],
    ) -> Result<StepOutput> {
        bail!("pjrt feature disabled")
    }

    pub fn embed_tokens(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!("pjrt feature disabled")
    }

    pub fn embed_text(&self, _text: &str) -> Result<Vec<f32>> {
        bail!("pjrt feature disabled")
    }
}

/// Cosine similarity between two embeddings (Table 7's semantic proxy).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = ModelRuntime::load("/nonexistent").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }
}
