//! AOT artifact manifest: shapes, pool configs, and weight loading.
//!
//! `python/compile/aot.py` writes `manifest.json` + `weights.bin` +
//! `*.hlo.txt` once at build time; this module is the Rust half of that
//! contract. Weights are flat little-endian f32 in manifest order.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model dimensions (mirrors `ModelConfig` in model.py).
#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
}

/// One pool's live-path configuration.
#[derive(Clone, Copy, Debug)]
pub struct PoolShape {
    /// Concurrent KV slots per replica (the live n_max).
    pub n_slots: usize,
    /// Context window per slot, tokens.
    pub ctx: usize,
}

/// One weight tensor's manifest entry.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub short: PoolShape,
    pub long: PoolShape,
    /// Prefill chunk size (live C_chunk).
    pub chunk: usize,
    /// Fixed token window of the embed artifact.
    pub embed_len: usize,
    pub params: Vec<ParamSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;

        let m = j.expect("model");
        let get = |o: &Json, k: &str| -> Result<usize> {
            o.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing `{k}`"))
        };
        let model = ModelDims {
            vocab: get(m, "vocab")?,
            d_model: get(m, "d_model")?,
            n_layers: get(m, "n_layers")?,
            n_heads: get(m, "n_heads")?,
            head_dim: get(m, "head_dim")?,
            ffn_dim: get(m, "ffn_dim")?,
        };
        let pools = j.expect("pools");
        let pool = |name: &str| -> Result<PoolShape> {
            let p = pools
                .get(name)
                .with_context(|| format!("manifest missing pool `{name}`"))?;
            Ok(PoolShape {
                n_slots: get(p, "n_slots")?,
                ctx: get(p, "ctx")?,
            })
        };
        let params = j
            .expect("params")
            .as_arr()
            .context("manifest `params` must be an array")?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .context("param name")?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            model,
            short: pool("short")?,
            long: pool("long")?,
            chunk: get(&j, "chunk")?,
            embed_len: get(&j, "embed_len")?,
            params,
            dir,
        })
    }

    /// Total weight scalars expected in weights.bin.
    pub fn total_weights(&self) -> usize {
        self.params.iter().map(ParamSpec::elements).sum()
    }

    /// Load weights.bin into per-parameter f32 vectors (manifest order).
    pub fn load_weights(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expect = self.total_weights() * 4;
        if bytes.len() != expect {
            bail!(
                "weights.bin is {} bytes, manifest expects {expect}",
                bytes.len()
            );
        }
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0usize;
        for p in &self.params {
            let n = p.elements();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[off + i * 4..off + i * 4 + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n * 4;
            out.push(v);
        }
        Ok(out)
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn pool(&self, kind: PoolKind) -> PoolShape {
        match kind {
            PoolKind::Short => self.short,
            PoolKind::Long => self.long,
        }
    }
}

/// Which live pool an engine replica belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Short,
    Long,
}

impl PoolKind {
    pub fn name(self) -> &'static str {
        match self {
            PoolKind::Short => "short",
            PoolKind::Long => "long",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn manifest_parses_when_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.n_heads * m.model.head_dim, m.model.d_model);
        assert!(m.short.n_slots > m.long.n_slots, "live cliff must exist");
        assert_eq!(m.short.n_slots * m.short.ctx, m.long.n_slots * m.long.ctx);
        assert!(m.chunk > 0 && m.embed_len > 0);
        assert_eq!(m.params.first().unwrap().name, "tok_emb");
        assert_eq!(m.params.last().unwrap().name, "lm_head");
    }

    #[test]
    fn weights_load_and_match_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let w = m.load_weights().unwrap();
        assert_eq!(w.len(), m.params.len());
        for (v, p) in w.iter().zip(&m.params) {
            assert_eq!(v.len(), p.elements(), "{}", p.name);
            assert!(v.iter().all(|x| x.is_finite()), "{}", p.name);
        }
        // Norm weights are initialized to ones.
        let norm_idx = m.params.iter().position(|p| p.name.ends_with("norm")).unwrap();
        assert!(w[norm_idx].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
