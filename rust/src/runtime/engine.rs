//! PJRT execution engine: loads the HLO-text artifacts and exposes typed
//! `prefill` / `decode` / `embed` calls to the coordinator.
//!
//! One `ModelRuntime` per process: a CPU PJRT client, the compiled
//! executables (one per artifact), and the weight literals fed as leading
//! arguments on every call. Python never runs here — the HLO text was
//! produced once by `make artifacts` (see /opt/xla-example/README.md for
//! why text, not serialized protos).

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::artifacts::{Manifest, PoolKind};

/// Output of one decode/prefill call.
pub struct StepOutput {
    /// Row-major logits [n, vocab] (n = slots for decode, chunk for prefill).
    pub logits: Vec<f32>,
    /// Updated key cache (same layout as the input).
    pub k_cache: Vec<f32>,
    /// Updated value cache.
    pub v_cache: Vec<f32>,
}

/// The process-wide model runtime.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: PjRtClient,
    weights: Vec<Literal>,
    prefill_short: PjRtLoadedExecutable,
    prefill_long: PjRtLoadedExecutable,
    decode_short: PjRtLoadedExecutable,
    decode_long: PjRtLoadedExecutable,
    embed: PjRtLoadedExecutable,
}

impl ModelRuntime {
    /// Load artifacts from `dir`, compile all executables on the CPU PJRT
    /// client, and upload weights.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;

        let compile = |name: &str| -> Result<PjRtLoadedExecutable> {
            let path = manifest.hlo_path(name);
            let proto = HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))
        };

        let weights = manifest
            .load_weights()?
            .into_iter()
            .zip(&manifest.params)
            .map(|(v, p)| {
                let lit = Literal::vec1(&v);
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .with_context(|| format!("reshaping {}", p.name))
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(ModelRuntime {
            prefill_short: compile("prefill_short")?,
            prefill_long: compile("prefill_long")?,
            decode_short: compile("decode_short")?,
            decode_long: compile("decode_long")?,
            embed: compile("embed")?,
            manifest,
            client,
            weights,
        })
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Per-slot KV cache length in f32 scalars: L * C * H * D.
    pub fn slot_cache_len(&self, kind: PoolKind) -> usize {
        let m = &self.manifest.model;
        let p = self.manifest.pool(kind);
        m.n_layers * p.ctx * m.n_heads * m.head_dim
    }

    fn run(
        &self,
        exe: &PjRtLoadedExecutable,
        extra: Vec<Literal>,
        n_outputs_logits: usize,
    ) -> Result<StepOutput> {
        // Weights first (manifest order), then the call-specific args.
        let mut args: Vec<&Literal> = self.weights.iter().collect();
        for lit in &extra {
            args.push(lit);
        }
        let result = exe.execute::<&Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let outs = result.to_tuple().context("untupling result")?;
        anyhow::ensure!(outs.len() == 3, "expected 3 outputs, got {}", outs.len());
        let mut it = outs.into_iter();
        let logits_lit = it.next().unwrap();
        let k_lit = it.next().unwrap();
        let v_lit = it.next().unwrap();
        let logits = logits_lit.to_vec::<f32>()?;
        anyhow::ensure!(
            logits.len() == n_outputs_logits,
            "logits size {} != expected {n_outputs_logits}",
            logits.len()
        );
        Ok(StepOutput {
            logits,
            k_cache: k_lit.to_vec::<f32>()?,
            v_cache: v_lit.to_vec::<f32>()?,
        })
    }

    /// One chunked-prefill iteration for a single slot.
    ///
    /// `k_cache`/`v_cache`: [L, C, H, D] flat; `tokens`: exactly `chunk`
    /// ids (pad with 0; only the first `valid` matter to the caller);
    /// `pos_base`: tokens already in the cache.
    pub fn prefill(
        &self,
        kind: PoolKind,
        k_cache: &[f32],
        v_cache: &[f32],
        tokens: &[i32],
        pos_base: i32,
    ) -> Result<StepOutput> {
        let m = &self.manifest;
        anyhow::ensure!(tokens.len() == m.chunk, "prefill chunk size mismatch");
        let slot_len = self.slot_cache_len(kind);
        anyhow::ensure!(k_cache.len() == slot_len && v_cache.len() == slot_len);
        let p = m.pool(kind);
        let dims = [
            m.model.n_layers as i64,
            p.ctx as i64,
            m.model.n_heads as i64,
            m.model.head_dim as i64,
        ];
        let extra = vec![
            Literal::vec1(k_cache).reshape(&dims)?,
            Literal::vec1(v_cache).reshape(&dims)?,
            Literal::vec1(tokens),
            Literal::scalar(pos_base),
        ];
        let exe = match kind {
            PoolKind::Short => &self.prefill_short,
            PoolKind::Long => &self.prefill_long,
        };
        self.run(exe, extra, m.chunk * m.model.vocab)
    }

    /// One lockstep decode iteration over all of a replica's slots.
    ///
    /// `k_cache`/`v_cache`: [S, L, C, H, D] flat; `tokens`/`pos`: length S.
    pub fn decode(
        &self,
        kind: PoolKind,
        k_cache: &[f32],
        v_cache: &[f32],
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<StepOutput> {
        let m = &self.manifest;
        let p = m.pool(kind);
        anyhow::ensure!(tokens.len() == p.n_slots && pos.len() == p.n_slots);
        let slot_len = self.slot_cache_len(kind);
        anyhow::ensure!(k_cache.len() == p.n_slots * slot_len);
        let dims = [
            p.n_slots as i64,
            m.model.n_layers as i64,
            p.ctx as i64,
            m.model.n_heads as i64,
            m.model.head_dim as i64,
        ];
        let extra = vec![
            Literal::vec1(k_cache).reshape(&dims)?,
            Literal::vec1(v_cache).reshape(&dims)?,
            Literal::vec1(tokens),
            Literal::vec1(pos),
        ];
        let exe = match kind {
            PoolKind::Short => &self.decode_short,
            PoolKind::Long => &self.decode_long,
        };
        self.run(exe, extra, p.n_slots * m.model.vocab)
    }

    /// Mean-pooled text embedding (the Table-7 BERTScore substitute).
    /// `tokens` is truncated/padded to the artifact's fixed window.
    pub fn embed_tokens(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let len = self.manifest.embed_len;
        let valid = tokens.len().min(len) as i32;
        let mut padded = vec![0i32; len];
        padded[..valid as usize].copy_from_slice(&tokens[..valid as usize]);
        // embed_text never touches lm_head, so jax prunes it from the HLO
        // signature — feed every weight except that one.
        let mut args: Vec<&Literal> = self
            .weights
            .iter()
            .zip(&self.manifest.params)
            .filter(|(_, p)| p.name != "lm_head")
            .map(|(w, _)| w)
            .collect();
        let tok_lit = Literal::vec1(&padded);
        let len_lit = Literal::scalar(valid);
        args.push(&tok_lit);
        args.push(&len_lit);
        let result = self.embed.execute::<&Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching embedding")?;
        // return_tuple=True -> 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Embed raw text via the shared hash tokenizer. Documents longer than
    /// the artifact's fixed window are *stride-sampled* (evenly spaced
    /// tokens across the whole text) rather than truncated, so the
    /// embedding reflects the full document — essential for the Table-7
    /// fidelity proxy, where compression edits the middle of the prompt.
    pub fn embed_text(&self, text: &str) -> Result<Vec<f32>> {
        let ids =
            crate::compress::tokenizer::hash_tokens(text, self.manifest.model.vocab as u32);
        let len = self.manifest.embed_len;
        if ids.len() <= len {
            return self.embed_tokens(&ids);
        }
        let sampled: Vec<i32> = (0..len)
            .map(|i| ids[i * ids.len() / len])
            .collect();
        self.embed_tokens(&sampled)
    }
}

/// Cosine similarity between two embeddings (Table 7's semantic proxy).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }
}
