//! Runtime: PJRT CPU client loading the AOT HLO-text artifacts (L2 model +
//! L1 Pallas kernels) and executing prefill/decode/embed from the Rust hot
//! path. Python never runs at request time.
//!
//! The real engine needs the `xla` crate and is gated behind the `pjrt`
//! feature; default builds get an API-compatible stub whose `load` fails
//! (offline images do not vendor the PJRT bindings — see `stub.rs`).

pub mod artifacts;

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod engine;

pub use artifacts::{Manifest, PoolKind, PoolShape};
pub use engine::{cosine, ModelRuntime, StepOutput};
