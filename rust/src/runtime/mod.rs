//! Runtime: PJRT CPU client loading the AOT HLO-text artifacts (L2 model +
//! L1 Pallas kernels) and executing prefill/decode/embed from the Rust hot
//! path. Python never runs at request time.

pub mod artifacts;
pub mod engine;

pub use artifacts::{Manifest, PoolKind, PoolShape};
pub use engine::{cosine, ModelRuntime, StepOutput};
