//! The live serving coordinator: engine replicas (KV-slot manager +
//! continuous batcher + chunked-prefill/decode scheduler), the threaded
//! K-tier serving loop fed by the gateway (two-pool at K = 2), and the
//! periodic autoscaling controller that resizes replica sets live.

pub mod controller;
pub mod replica;
pub mod serve;

pub use controller::{replica_targets, ControllerConfig, LiveEpoch};
pub use replica::{FinishedRequest, LiveRequest, Replica};
pub use serve::{
    serve, serve_autoscaled, serve_autoscaled_with, serve_failover_with, serve_with,
    AdmissionOpts, AutoscaledServeReport, FailoverOpts, ServeConfig, ServeItem, ServeReport,
};
