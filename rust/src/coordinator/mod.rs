//! The live serving coordinator: engine replicas (KV-slot manager +
//! continuous batcher + chunked-prefill/decode scheduler) and the threaded
//! K-tier serving loop fed by the gateway (two-pool at K = 2).

pub mod replica;
pub mod serve;

pub use replica::{FinishedRequest, LiveRequest, Replica};
pub use serve::{serve, ServeConfig, ServeItem, ServeReport};
