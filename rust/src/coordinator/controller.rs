//! The live autoscaling controller: configuration and replica-set sizing
//! for [`crate::coordinator::serve::serve_autoscaled`].
//!
//! The live loop mirrors the DES controller
//! ([`crate::fleetsim::autoscale`]): the gateway driver feeds a sliding
//! [`OnlineEstimator`](crate::workload::online::OnlineEstimator) as it
//! routes, and a controller thread wakes every epoch, re-estimates the
//! window CDF and rate, runs the hysteretic
//! [`Replanner`](crate::planner::replan::Replanner), and resizes the
//! per-tier replica sets: scale-up spawns fresh replica threads (each
//! paying its real ModelRuntime cold-start — the live analogue of the
//! DES's provisioning delay), scale-down lets the highest-indexed
//! replicas finish their in-flight requests and exit (connection
//! draining; the shared tier queue keeps undispatched work).

use crate::planner::replan::ReplanConfig;
use crate::planner::{PlanInput, TieredPlan};

/// Configuration for the live autoscaling controller.
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Controller period, in workload (arrival-offset) seconds — scaled
    /// by the serve loop's `time_scale` exactly like arrivals are.
    pub epoch_s: f64,
    /// Sliding estimation window, workload seconds.
    pub window_s: f64,
    /// Hysteresis knobs for the incremental planner.
    pub replan: ReplanConfig,
    /// Planner template (SLO, GPU profile, grid). The workload inside is
    /// only a category/output template; the CDF is re-estimated live.
    pub input: PlanInput,
    /// The plan the fleet booted with (seeds the replanner).
    pub initial: TieredPlan,
    /// Scale factor from planner GPU counts to live replicas (a live demo
    /// replica stands in for many planned GPUs).
    pub gpus_per_replica: f64,
    /// Hard ceiling on replicas per tier (live hosts are finite).
    pub max_replicas: usize,
    /// Multiplier on the peak-window rate estimate before planning — the
    /// same knob as `AutoscaleConfig::target_headroom` in the DES, so the
    /// live loop provisions with the identical upswing slack the
    /// simulator's acceptance numbers were produced with.
    pub target_headroom: f64,
    /// Anticipatory scaling (off by default), mirroring
    /// `AutoscaleConfig::forecast`: plan against `max(peak, one-epoch-
    /// ahead linear forecast)` of the live estimator.
    pub forecast: bool,
}

impl ControllerConfig {
    /// A controller whose replica scale maps the initial plan onto the
    /// given starting replica counts: `gpus_per_replica` is chosen so the
    /// initial plan's *largest* tier maps to its configured replica count.
    pub fn scaled_to(
        input: PlanInput,
        initial: TieredPlan,
        replicas: &[usize],
        epoch_s: f64,
        max_replicas: usize,
    ) -> Self {
        assert_eq!(initial.k(), replicas.len());
        let mut scale = 1.0f64;
        for (pool, &r) in initial.tiers.iter().zip(replicas) {
            if pool.n_gpus > 0 && r > 0 {
                scale = scale.max(pool.n_gpus as f64 / r as f64);
            }
        }
        ControllerConfig {
            epoch_s,
            window_s: epoch_s * 2.0,
            replan: ReplanConfig::default(),
            input,
            initial,
            gpus_per_replica: scale,
            max_replicas,
            target_headroom: 1.10,
            forecast: false,
        }
    }
}

/// One live controller epoch (diagnostics; the live analogue of
/// [`crate::metrics::EpochMetrics`], without DES-grade integrals).
#[derive(Clone, Debug)]
pub struct LiveEpoch {
    /// Workload-time of the decision, seconds.
    pub t_s: f64,
    pub lambda_est: f64,
    /// Replica targets per tier after this epoch's replan.
    pub targets: Vec<usize>,
    pub switched_layout: bool,
}

/// Map planner GPU counts onto live replica targets. Every tier keeps at
/// least one replica (a zero-replica tier would strand queued requests),
/// and no tier exceeds `max_replicas`.
pub fn replica_targets(counts: &[u64], gpus_per_replica: f64, max_replicas: usize) -> Vec<usize> {
    assert!(gpus_per_replica > 0.0);
    assert!(max_replicas >= 1);
    counts
        .iter()
        .map(|&n| {
            let r = (n as f64 / gpus_per_replica).round() as usize;
            r.clamp(1, max_replicas)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::plan_spec_sweep_gamma;
    use crate::workload::traces;

    #[test]
    fn replica_targets_clamp_and_round() {
        assert_eq!(replica_targets(&[20, 5, 0], 10.0, 4), vec![2, 1, 1]);
        assert_eq!(replica_targets(&[100, 1], 10.0, 4), vec![4, 1]);
        assert_eq!(replica_targets(&[14, 16], 10.0, 4), vec![1, 2]);
    }

    #[test]
    fn scaled_to_maps_initial_plan_onto_start_replicas() {
        let mut input = PlanInput::new(traces::azure(), 1000.0);
        input.cfg.mc_samples = 8_000;
        let spec = input.gpu.fleet_spec(&[4096]);
        let plan = plan_spec_sweep_gamma(&input, &spec).unwrap();
        let counts = plan.gpu_counts();
        let ctl = ControllerConfig::scaled_to(input, plan, &[2, 1], 5.0, 8);
        let targets = replica_targets(&counts, ctl.gpus_per_replica, ctl.max_replicas);
        // The initial plan must map back to at most the starting shape
        // (the largest tier anchors the scale; smaller tiers round down
        // to >= 1).
        assert!(targets.iter().all(|&t| (1..=8).contains(&t)));
        assert!(targets[0] <= 2 && targets[1] <= 1);
    }
}
