//! The serving loop: gateway → per-tier FCFS queues → replica threads.
//!
//! Threads + channels stand in for an async runtime (no tokio offline;
//! DESIGN.md §1): each replica runs on its own thread, pulling from its
//! tier's shared queue at iteration boundaries — the same admission
//! discipline as the DES, so live TTFTs decompose exactly like Eq. 7.
//! The fleet is K-tier (`GatewayConfig::n_tiers()` queues); the paper's
//! two-pool deployment is the K = 2 case with one replica set per pool.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::controller::{replica_targets, ControllerConfig, LiveEpoch};
use crate::coordinator::replica::{FinishedRequest, LiveRequest, Replica};
use crate::metrics::PoolMetrics;
use crate::router::failover::{effective_gateway_config, FailoverConfig};
use crate::router::memo::{CacheStats, RouteCache};
use crate::router::{Gateway, GatewayConfig, RoutedRequest};
use crate::runtime::{ModelRuntime, PoolKind};
use crate::workload::online::OnlineEstimator;

/// Live fleet configuration: one replica count per tier (length must be
/// `gateway.n_tiers()`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub gateway: GatewayConfig,
    pub replicas: Vec<usize>,
}

impl ServeConfig {
    /// The paper's two-pool deployment shape.
    pub fn two_tier(gateway: GatewayConfig, replicas_short: usize, replicas_long: usize) -> Self {
        ServeConfig {
            gateway,
            replicas: vec![replicas_short, replicas_long],
        }
    }
}

/// Ingress concurrency/caching knobs (§Perf, PR 8), shared by [`serve`]
/// and [`serve_autoscaled`] through the common admission helper. The
/// default is the legacy serial, uncached ingress.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionOpts {
    /// Gateway shard workers per due-batch: 1 = serial streaming ingress
    /// (each request enqueued the moment it routes), 0 = auto (available
    /// parallelism, capped by `FLEETOPT_THREADS`/`--threads`), N = exactly
    /// N workers. Routing outputs are bit-identical for every setting.
    pub gateway_workers: usize,
    /// Route-memo capacity in entries (0 = memoization off).
    pub route_cache_cap: usize,
}

impl Default for AdmissionOpts {
    fn default() -> Self {
        AdmissionOpts {
            gateway_workers: 1,
            route_cache_cap: 0,
        }
    }
}

/// Operator-declared degraded tiers for a live run (a zone outage, a SKU
/// recall): routing runs on the failover-effective ladder — degraded
/// boundaries dropped, the seam gamma tightened by `cfg.gamma_boost` —
/// and every admitted request is remapped onto the surviving original
/// tier's queue. With no tier degraded the routing is bit-identical to
/// the plain serve path.
#[derive(Clone, Debug)]
pub struct FailoverOpts {
    /// One flag per tier; `true` marks the tier's capacity as unusable.
    pub degraded: Vec<bool>,
    pub cfg: FailoverConfig,
}

/// The shared admission pipeline: gateway (+ optional route memo), the
/// paced-arrival driver loop, and the enqueue/wake dispatch. One
/// implementation serves both drivers — `serve` passes a no-op observer,
/// `serve_autoscaled` feeds its online estimator per routed request.
struct Admission {
    gateway: Gateway,
    cache: Option<RouteCache>,
    workers: usize,
    /// Summed per-request gateway seconds (for `mean_gateway_s`).
    total_s: f64,
    /// Effective-tier → original-tier remap under degraded-capacity
    /// failover (None = identity: the gateway routes on the full ladder).
    tier_map: Option<Vec<usize>>,
}

impl Admission {
    fn new(
        gateway_cfg: &GatewayConfig,
        opts: AdmissionOpts,
        tier_map: Option<Vec<usize>>,
    ) -> Self {
        Admission {
            gateway: Gateway::new(gateway_cfg.clone()),
            cache: (opts.route_cache_cap > 0).then(|| RouteCache::new(opts.route_cache_cap)),
            workers: opts.gateway_workers,
            total_s: 0.0,
            tier_map,
        }
    }

    /// Per-original-tier routed counts: the gateway counts per *effective*
    /// tier, so under failover the counts are folded back through the map.
    fn n_routed(&self, k: usize) -> Vec<u64> {
        match &self.tier_map {
            Some(map) => {
                let mut v = vec![0u64; k];
                for (ei, &n) in self.gateway.n_routed.iter().enumerate() {
                    v[map[ei]] += n;
                }
                v
            }
            None => self.gateway.n_routed.clone(),
        }
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats).unwrap_or_default()
    }

    /// Route + feed with paced arrivals. Arrivals that are already due
    /// when the driver wakes are routed together through the gateway's
    /// batch API (§Perf) — one warm pass over the compression scratches
    /// (sharded across workers when `gateway_workers != 1`) instead of
    /// per-request cold calls, exactly the burst shape where gateway
    /// latency matters most. Each request is enqueued (and its tier
    /// woken) as soon as its result is emitted; `observe` sees every
    /// routed request with its global item index before dispatch.
    fn drive(
        &mut self,
        items: &[ServeItem],
        time_scale: f64,
        start: Instant,
        vocab: u32,
        pools: &[Arc<PoolState>],
        in_flight: &AtomicU64,
        mut observe: impl FnMut(usize, &RoutedRequest),
    ) {
        let mut next = 0usize;
        while next < items.len() {
            let target = items[next].arrival_offset_s * time_scale;
            let elapsed = start.elapsed().as_secs_f64();
            if target > elapsed {
                std::thread::sleep(std::time::Duration::from_secs_f64(target - elapsed));
            }
            // Gather every item that is due by now into one batch.
            let now = start.elapsed().as_secs_f64();
            let mut end = next + 1;
            while end < items.len() && items[end].arrival_offset_s * time_scale <= now {
                end += 1;
            }
            let batch: Vec<(&str, u32)> = items[next..end]
                .iter()
                .map(|it| (it.text.as_str(), it.max_output))
                .collect();
            let base = next;
            let Admission {
                gateway,
                cache,
                workers,
                total_s,
                tier_map,
            } = self;
            gateway.route_batch_with_opts(&batch, *workers, cache.as_mut(), |idx, routed| {
                *total_s += routed.gateway_s;
                observe(base + idx, &routed);
                let req = LiveRequest {
                    id: (base + idx) as u64,
                    tokens: crate::compress::tokenizer::hash_tokens(&routed.text, vocab),
                    max_output: routed.max_output_tokens,
                    arrival: Instant::now(),
                };
                // Under failover the gateway routed on the effective ladder;
                // land the request on the surviving original tier's queue.
                let dest = tier_map.as_ref().map_or(routed.tier, |m| m[routed.tier]);
                in_flight.fetch_add(1, Ordering::AcqRel);
                {
                    let mut q = pools[dest].queue.lock().unwrap();
                    q.push_back(req);
                }
                pools[dest].wake.notify_all();
            });
            next = end;
        }
    }
}

/// One tier's shared state.
struct PoolState {
    queue: Mutex<VecDeque<LiveRequest>>,
    wake: Condvar,
}

impl PoolState {
    fn new() -> Self {
        PoolState {
            queue: Mutex::new(VecDeque::new()),
            wake: Condvar::new(),
        }
    }
}

/// Aggregated serving results, one metrics block per tier.
#[derive(Debug)]
pub struct ServeReport {
    /// Per-tier metrics (index 0 = densest tier, last = full-context).
    pub tiers: Vec<PoolMetrics>,
    /// Wall-clock duration of the run, seconds.
    pub duration_s: f64,
    /// Requests completed per second over the run.
    pub throughput_rps: f64,
    /// Gateway counters.
    pub n_compressed: u64,
    /// Requests routed to each tier.
    pub n_routed: Vec<u64>,
    /// Mean gateway (routing + compression) overhead per request, seconds.
    pub mean_gateway_s: f64,
    /// Route-memo counters for the run (all-zero when caching was off).
    pub route_cache: CacheStats,
    /// Configured gateway shard workers (1 = serial, 0 = auto).
    pub gateway_workers: usize,
    /// Per-stage timings of the last sharded ingress batch (None when
    /// every batch ran serially).
    pub shard_timing: Option<crate::router::ShardTiming>,
}

impl ServeReport {
    pub fn n_routed_short(&self) -> u64 {
        self.n_routed[0]
    }

    pub fn n_routed_long(&self) -> u64 {
        *self.n_routed.last().expect("at least two tiers")
    }

    pub fn completed(&self) -> u64 {
        self.tiers.iter().map(|t| t.completed).sum()
    }
}

/// A workload item for the live fleet: prompt text, output budget, and the
/// arrival offset from run start (seconds).
#[derive(Clone, Debug)]
pub struct ServeItem {
    pub text: String,
    pub max_output: u32,
    pub arrival_offset_s: f64,
}

/// Metric label for tier `i` of `k`: the two-pool names are kept for the
/// K = 2 deployment; larger fleets get positional names.
fn tier_name(i: usize, k: usize) -> String {
    if k == 2 {
        (if i == 0 { "short" } else { "long" }).to_string()
    } else {
        format!("tier{i}")
    }
}

/// Which AOT artifact pool a tier's replicas execute. The artifact set
/// compiles two shapes (dense short / full-context long); every non-last
/// tier uses the dense executable, the last tier the full-context one.
fn tier_artifact(i: usize, k: usize) -> PoolKind {
    if i + 1 == k {
        PoolKind::Long
    } else {
        PoolKind::Short
    }
}

/// Every tier boundary must fit inside the context window of the AOT
/// artifact its replicas execute; an oversized prompt would otherwise
/// overflow a replica's KV slot mid-serve. Shared by [`serve`] and
/// [`serve_autoscaled`].
fn check_boundaries_fit(
    gateway: &GatewayConfig,
    manifest: &crate::runtime::Manifest,
    k: usize,
) -> Result<()> {
    for (i, tr) in gateway.tiers.iter().enumerate() {
        let shape = manifest.pool(tier_artifact(i, k));
        if tr.boundary as usize > shape.ctx {
            bail!(
                "tier {i} boundary {} exceeds its artifact context window {}",
                tr.boundary,
                shape.ctx
            );
        }
    }
    Ok(())
}

/// Drive `items` through a live K-tier fleet. Arrivals are paced in real
/// time by `time_scale` (0.1 = 10x faster than the offsets say); the
/// gateway (classification + C&R compression) runs on the driver thread,
/// exactly as a real deployment's ingress does.
///
/// Each replica thread owns its own `ModelRuntime` (PJRT client +
/// executables): the `xla` crate's handles are not `Send`/`Sync`, and a
/// per-replica client also mirrors the one-engine-per-GPU deployment shape.
pub fn serve(
    artifacts_dir: &std::path::Path,
    cfg: &ServeConfig,
    items: Vec<ServeItem>,
    time_scale: f64,
) -> Result<ServeReport> {
    serve_with(artifacts_dir, cfg, AdmissionOpts::default(), items, time_scale)
}

/// [`serve`] with explicit ingress concurrency/caching ([`AdmissionOpts`]).
pub fn serve_with(
    artifacts_dir: &std::path::Path,
    cfg: &ServeConfig,
    opts: AdmissionOpts,
    items: Vec<ServeItem>,
    time_scale: f64,
) -> Result<ServeReport> {
    serve_impl(artifacts_dir, cfg, opts, None, items, time_scale)
}

/// [`serve_with`] under degraded-capacity failover: tiers flagged in
/// `fo.degraded` are dropped from the routing ladder and their traffic
/// spills onto the survivors (down-spill re-qualified through C&R at the
/// tightened seam gamma, up-spill admitted as-is). Their replica sets
/// still start — a degraded tier's queue simply never receives work.
pub fn serve_failover_with(
    artifacts_dir: &std::path::Path,
    cfg: &ServeConfig,
    opts: AdmissionOpts,
    fo: &FailoverOpts,
    items: Vec<ServeItem>,
    time_scale: f64,
) -> Result<ServeReport> {
    serve_impl(artifacts_dir, cfg, opts, Some(fo), items, time_scale)
}

fn serve_impl(
    artifacts_dir: &std::path::Path,
    cfg: &ServeConfig,
    opts: AdmissionOpts,
    fo: Option<&FailoverOpts>,
    items: Vec<ServeItem>,
    time_scale: f64,
) -> Result<ServeReport> {
    let k = cfg.gateway.n_tiers();
    if cfg.replicas.len() != k {
        bail!(
            "replica counts ({}) must match tier count ({k})",
            cfg.replicas.len()
        );
    }
    let (route_cfg, tier_map) = match fo {
        Some(f) => {
            if f.degraded.len() != k {
                bail!(
                    "degraded flags ({}) must match tier count ({k})",
                    f.degraded.len()
                );
            }
            if f.degraded.iter().any(|&d| d) {
                let (eff, map) = effective_gateway_config(&cfg.gateway, &f.degraded, &f.cfg);
                (eff, Some(map))
            } else {
                (cfg.gateway.clone(), None)
            }
        }
        None => (cfg.gateway.clone(), None),
    };
    let manifest = crate::runtime::Manifest::load(artifacts_dir)?;
    check_boundaries_fit(&cfg.gateway, &manifest, k)?;
    let pools: Vec<Arc<PoolState>> = (0..k).map(|_| Arc::new(PoolState::new())).collect();
    let done_feeding = Arc::new(AtomicBool::new(false));
    let in_flight = Arc::new(AtomicU64::new(0));
    let results: Arc<Mutex<Vec<(usize, FinishedRequest)>>> = Arc::new(Mutex::new(Vec::new()));

    let mut handles = Vec::new();
    for (tier, &count) in cfg.replicas.iter().enumerate() {
        let kind = tier_artifact(tier, k);
        for _ in 0..count {
            let dir = artifacts_dir.to_path_buf();
            let pool = pools[tier].clone();
            let done = done_feeding.clone();
            let in_flight = in_flight.clone();
            let results = results.clone();
            handles.push(std::thread::spawn(move || -> Result<()> {
                let rt = Arc::new(ModelRuntime::load(&dir)?);
                let mut replica = Replica::new(rt, kind);
                loop {
                    // Admit as many queued requests as there are free slots.
                    {
                        let mut q = pool.queue.lock().unwrap();
                        while replica.n_free() > 0 {
                            let Some(req) = q.pop_front() else { break };
                            assert!(replica.admit(req));
                        }
                        if !replica.has_work() {
                            if done.load(Ordering::Acquire) && q.is_empty() {
                                return Ok(());
                            }
                            // Sleep until an arrival wakes this pool.
                            let (guard, _) = pool
                                .wake
                                .wait_timeout(q, std::time::Duration::from_millis(20))
                                .unwrap();
                            drop(guard);
                            continue;
                        }
                    }
                    for fin in replica.step()? {
                        in_flight.fetch_sub(1, Ordering::AcqRel);
                        results.lock().unwrap().push((tier, fin));
                    }
                }
            }));
        }
    }

    // Driver: the shared admission pipeline (no per-request observer).
    let mut admission = Admission::new(&route_cfg, opts, tier_map);
    let vocab = manifest.model.vocab as u32;
    let start = Instant::now();
    let n_items = items.len() as u64;
    admission.drive(&items, time_scale, start, vocab, &pools, &in_flight, |_, _| {});
    done_feeding.store(true, Ordering::Release);
    for p in &pools {
        p.wake.notify_all();
    }
    for h in handles {
        h.join().expect("replica thread panicked")?;
    }
    let duration_s = start.elapsed().as_secs_f64();

    let mut tiers: Vec<PoolMetrics> = (0..k).map(|i| PoolMetrics::new(tier_name(i, k))).collect();
    let all = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
    let completed = all.len() as u64;
    for (tier, fin) in all {
        tiers[tier].record(&fin);
    }
    let lost = in_flight.load(Ordering::Acquire);
    if lost != 0 {
        // A serving-path accounting failure must surface as an error the
        // caller can handle, not a coordinator panic.
        bail!("{lost} request(s) lost in flight ({completed} completed of {n_items})");
    }
    Ok(ServeReport {
        tiers,
        duration_s,
        throughput_rps: completed as f64 / duration_s.max(1e-9),
        n_compressed: admission.gateway.n_compressed,
        n_routed: admission.n_routed(k),
        mean_gateway_s: admission.total_s / n_items.max(1) as f64,
        route_cache: admission.cache_stats(),
        gateway_workers: opts.gateway_workers,
        shard_timing: admission.gateway.last_shard,
    })
}

/// [`serve`] with the autoscaling controller in the loop.
#[derive(Debug)]
pub struct AutoscaledServeReport {
    pub report: ServeReport,
    /// One entry per controller epoch that made a decision.
    pub epochs: Vec<LiveEpoch>,
}

/// Everything a replica thread needs; bundled so live scale-up can spawn
/// replicas from the controller thread with one clone.
struct ReplicaCtx {
    dir: std::path::PathBuf,
    pools: Vec<Arc<PoolState>>,
    done_feeding: Arc<AtomicBool>,
    in_flight: Arc<AtomicU64>,
    results: Arc<Mutex<Vec<(usize, FinishedRequest)>>>,
    /// Per-tier replica targets; a replica whose index is at or above its
    /// tier's target drains (finishes in-flight work, admits nothing new)
    /// and then *parks* as a warm standby — it must not exit, or a later
    /// scale-up back past its index could never be satisfied. Parked
    /// replicas exit with everyone else once feeding is done and the
    /// queue is empty.
    targets: Arc<Vec<AtomicUsize>>,
}

fn spawn_replica(
    ctx: &Arc<ReplicaCtx>,
    tier: usize,
    index: usize,
    kind: PoolKind,
) -> std::thread::JoinHandle<Result<()>> {
    let ctx = ctx.clone();
    std::thread::spawn(move || -> Result<()> {
        let rt = Arc::new(ModelRuntime::load(&ctx.dir)?);
        let mut replica = Replica::new(rt, kind);
        let pool = &ctx.pools[tier];
        loop {
            let active = index < ctx.targets[tier].load(Ordering::Acquire);
            {
                let mut q = pool.queue.lock().unwrap();
                if active {
                    // Admit as many queued requests as there are free slots.
                    while replica.n_free() > 0 {
                        let Some(req) = q.pop_front() else { break };
                        assert!(replica.admit(req));
                    }
                }
                if !replica.has_work() {
                    if ctx.done_feeding.load(Ordering::Acquire) && q.is_empty() {
                        return Ok(());
                    }
                    // Idle — or drained (inactive): park on the condvar.
                    // A re-raised target wakes this replica right back up
                    // (the controller notifies after every retarget).
                    let (guard, _) = pool
                        .wake
                        .wait_timeout(q, std::time::Duration::from_millis(20))
                        .unwrap();
                    drop(guard);
                    continue;
                }
            }
            for fin in replica.step()? {
                ctx.in_flight.fetch_sub(1, Ordering::AcqRel);
                ctx.results.lock().unwrap().push((tier, fin));
            }
        }
    })
}

/// Drive `items` through a live K-tier fleet with a periodic autoscaling
/// controller: the driver feeds a sliding-window estimator as it routes;
/// every `ctl.epoch_s` (workload time) the controller re-estimates the
/// CDF and rate, replans with hysteresis, and resizes the per-tier
/// replica sets — scale-up spawns replica threads (real runtime
/// cold-start), scale-down drains the highest-indexed replicas. With the
/// controller quiescent (targets never change) the serving behaviour is
/// the plain [`serve`] loop.
pub fn serve_autoscaled(
    artifacts_dir: &std::path::Path,
    cfg: &ServeConfig,
    ctl: &ControllerConfig,
    items: Vec<ServeItem>,
    time_scale: f64,
) -> Result<AutoscaledServeReport> {
    serve_autoscaled_with(
        artifacts_dir,
        cfg,
        ctl,
        AdmissionOpts::default(),
        items,
        time_scale,
    )
}

/// [`serve_autoscaled`] with explicit ingress concurrency/caching.
pub fn serve_autoscaled_with(
    artifacts_dir: &std::path::Path,
    cfg: &ServeConfig,
    ctl: &ControllerConfig,
    opts: AdmissionOpts,
    items: Vec<ServeItem>,
    time_scale: f64,
) -> Result<AutoscaledServeReport> {
    let k = cfg.gateway.n_tiers();
    if cfg.replicas.len() != k {
        bail!(
            "replica counts ({}) must match tier count ({k})",
            cfg.replicas.len()
        );
    }
    if ctl.initial.k() != k {
        bail!("controller plan has {} tiers, fleet has {k}", ctl.initial.k());
    }
    if cfg.replicas.iter().any(|&r| r == 0) {
        bail!("every tier needs at least one starting replica");
    }
    let manifest = crate::runtime::Manifest::load(artifacts_dir)?;
    check_boundaries_fit(&cfg.gateway, &manifest, k)?;

    let ctx = Arc::new(ReplicaCtx {
        dir: artifacts_dir.to_path_buf(),
        pools: (0..k).map(|_| Arc::new(PoolState::new())).collect(),
        done_feeding: Arc::new(AtomicBool::new(false)),
        in_flight: Arc::new(AtomicU64::new(0)),
        results: Arc::new(Mutex::new(Vec::new())),
        targets: Arc::new(
            cfg.replicas
                .iter()
                .map(|&r| AtomicUsize::new(r))
                .collect(),
        ),
    });
    let handles: Arc<Mutex<Vec<std::thread::JoinHandle<Result<()>>>>> =
        Arc::new(Mutex::new(Vec::new()));
    let spawned: Vec<usize> = cfg.replicas.clone();
    for (tier, &count) in cfg.replicas.iter().enumerate() {
        let kind = tier_artifact(tier, k);
        for index in 0..count {
            let h = spawn_replica(&ctx, tier, index, kind);
            handles.lock().unwrap().push(h);
        }
    }

    // Controller thread: estimator snapshot -> replan -> retarget.
    let estimator = Arc::new(Mutex::new(OnlineEstimator::new(ctl.window_s)));
    let epochs: Arc<Mutex<Vec<LiveEpoch>>> = Arc::new(Mutex::new(Vec::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let start = Instant::now();
    let controller = {
        let ctx = ctx.clone();
        let estimator = estimator.clone();
        let epochs = epochs.clone();
        let stop = stop.clone();
        let handles = handles.clone();
        let ctl = ctl.clone();
        let mut spawned_ctl = spawned.clone();
        let epoch_wall = ctl.epoch_s * time_scale;
        std::thread::spawn(move || {
            let mut replanner =
                crate::planner::Replanner::new(ctl.replan.clone(), ctl.initial.clone());
            let mut next_wall = epoch_wall;
            loop {
                // Sleep in short slices so shutdown is prompt.
                while start.elapsed().as_secs_f64() < next_wall {
                    if stop.load(Ordering::Acquire) {
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                next_wall += epoch_wall;
                if stop.load(Ordering::Acquire) {
                    return;
                }
                let now_items = start.elapsed().as_secs_f64() / time_scale.max(1e-12);
                // Plan against the peak-window estimate plus headroom,
                // exactly like the DES controller (`fleetsim::autoscale`):
                // the mean estimate lags upswings by ~window/2.
                let (lam, snap) = {
                    // Anticipatory scaling: with the knob on, plan against
                    // the larger of the peak window and the one-epoch-ahead
                    // forecast — a single buffer pass inside the estimator
                    // lock either way (ingest contends on it).
                    let horizon = ctl.forecast.then_some(ctl.epoch_s);
                    let e = estimator.lock().unwrap();
                    (
                        e.planning_rate(now_items, 4, horizon) * ctl.target_headroom,
                        e.snapshot(&ctl.input.workload),
                    )
                };
                if lam <= 0.0 {
                    continue;
                }
                let mut pi = ctl.input.clone();
                pi.lambda = lam;
                if let Some(sw) = snap {
                    pi.workload = sw;
                }
                let Ok(out) = replanner.replan(&pi) else { continue };
                let targets = replica_targets(
                    &out.plan.gpu_counts(),
                    ctl.gpus_per_replica,
                    ctl.max_replicas,
                );
                for (tier, &target) in targets.iter().enumerate() {
                    ctx.targets[tier].store(target, Ordering::Release);
                    while spawned_ctl[tier] < target {
                        let kind = tier_artifact(tier, ctx.targets.len());
                        let h = spawn_replica(&ctx, tier, spawned_ctl[tier], kind);
                        handles.lock().unwrap().push(h);
                        spawned_ctl[tier] += 1;
                    }
                    ctx.pools[tier].wake.notify_all();
                }
                epochs.lock().unwrap().push(LiveEpoch {
                    t_s: now_items,
                    lambda_est: lam,
                    targets,
                    switched_layout: out.switched_layout,
                });
            }
        })
    };

    // Driver: the shared admission pipeline; the observer feeds the
    // controller's estimator the *pre-compression* length estimate — the
    // planner applies its own band-compression accounting, so feeding it
    // post-compression lengths would double-count C&R.
    let mut admission = Admission::new(&cfg.gateway, opts, None);
    let vocab = manifest.model.vocab as u32;
    let n_items = items.len() as u64;
    admission.drive(
        &items,
        time_scale,
        start,
        vocab,
        &ctx.pools,
        &ctx.in_flight,
        |i, routed| {
            estimator
                .lock()
                .unwrap()
                .observe(items[i].arrival_offset_s, routed.estimated_l_total);
        },
    );
    ctx.done_feeding.store(true, Ordering::Release);
    for p in ctx.pools.iter() {
        p.wake.notify_all();
    }
    // Join replicas (new ones may appear while we join — drain the list).
    // Errors are collected, not propagated mid-join: the controller
    // thread must be stopped before this function returns.
    let mut first_err: Option<anyhow::Error> = None;
    loop {
        let batch: Vec<_> = {
            let mut h = handles.lock().unwrap();
            h.drain(..).collect()
        };
        if batch.is_empty() {
            break;
        }
        for h in batch {
            if let Err(e) = h.join().expect("replica thread panicked") {
                first_err.get_or_insert(e);
            }
        }
    }
    stop.store(true, Ordering::Release);
    controller.join().expect("controller thread panicked");
    let duration_s = start.elapsed().as_secs_f64();

    // Replicas the controller may have spawned after the last join sweep:
    // one final drain.
    let leftovers: Vec<_> = {
        let mut h = handles.lock().unwrap();
        h.drain(..).collect()
    };
    for h in leftovers {
        if let Err(e) = h.join().expect("replica thread panicked") {
            first_err.get_or_insert(e);
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }

    let mut tiers: Vec<PoolMetrics> =
        (0..k).map(|i| PoolMetrics::new(tier_name(i, k))).collect();
    let all: Vec<(usize, FinishedRequest)> =
        std::mem::take(&mut *ctx.results.lock().unwrap());
    let completed = all.len() as u64;
    for (tier, fin) in all {
        tiers[tier].record(&fin);
    }
    let lost = ctx.in_flight.load(Ordering::Acquire);
    if lost != 0 {
        bail!("{lost} request(s) lost in flight ({completed} completed of {n_items})");
    }
    Ok(AutoscaledServeReport {
        report: ServeReport {
            tiers,
            duration_s,
            throughput_rps: completed as f64 / duration_s.max(1e-9),
            n_compressed: admission.gateway.n_compressed,
            n_routed: admission.gateway.n_routed.clone(),
            mean_gateway_s: admission.total_s / n_items.max(1) as f64,
            route_cache: admission.cache_stats(),
            gateway_workers: opts.gateway_workers,
            shard_timing: admission.gateway.last_shard,
        },
        epochs: std::mem::take(&mut *epochs.lock().unwrap()),
    })
}
