//! The serving loop: gateway → per-pool FCFS queues → replica threads.
//!
//! Threads + channels stand in for an async runtime (no tokio offline;
//! DESIGN.md §1): each replica runs on its own thread, pulling from its
//! pool's shared queue at iteration boundaries — the same admission
//! discipline as the DES, so live TTFTs decompose exactly like Eq. 7.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::replica::{FinishedRequest, LiveRequest, Replica};
use crate::metrics::PoolMetrics;
use crate::router::{Gateway, GatewayConfig};
use crate::runtime::{ModelRuntime, PoolKind};

/// Live fleet configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub gateway: GatewayConfig,
    pub replicas_short: usize,
    pub replicas_long: usize,
}

/// One pool's shared state.
struct PoolState {
    queue: Mutex<VecDeque<LiveRequest>>,
    wake: Condvar,
}

impl PoolState {
    fn new() -> Self {
        PoolState {
            queue: Mutex::new(VecDeque::new()),
            wake: Condvar::new(),
        }
    }
}

/// Aggregated serving results.
#[derive(Debug)]
pub struct ServeReport {
    pub short: PoolMetrics,
    pub long: PoolMetrics,
    /// Wall-clock duration of the run, seconds.
    pub duration_s: f64,
    /// Requests completed per second over the run.
    pub throughput_rps: f64,
    /// Gateway counters.
    pub n_compressed: u64,
    pub n_routed_short: u64,
    pub n_routed_long: u64,
    /// Mean gateway (routing + compression) overhead per request, seconds.
    pub mean_gateway_s: f64,
}

/// A workload item for the live fleet: prompt text, output budget, and the
/// arrival offset from run start (seconds).
#[derive(Clone, Debug)]
pub struct ServeItem {
    pub text: String,
    pub max_output: u32,
    pub arrival_offset_s: f64,
}

/// Drive `items` through a live two-pool fleet. Arrivals are paced in real
/// time by `time_scale` (0.1 = 10x faster than the offsets say); the
/// gateway (classification + C&R compression) runs on the driver thread,
/// exactly as a real deployment's ingress does.
///
/// Each replica thread owns its own `ModelRuntime` (PJRT client +
/// executables): the `xla` crate's handles are not `Send`/`Sync`, and a
/// per-replica client also mirrors the one-engine-per-GPU deployment shape.
pub fn serve(
    artifacts_dir: &std::path::Path,
    cfg: &ServeConfig,
    items: Vec<ServeItem>,
    time_scale: f64,
) -> Result<ServeReport> {
    let manifest = crate::runtime::Manifest::load(artifacts_dir)?;
    let pools: [Arc<PoolState>; 2] = [Arc::new(PoolState::new()), Arc::new(PoolState::new())];
    let done_feeding = Arc::new(AtomicBool::new(false));
    let in_flight = Arc::new(AtomicU64::new(0));
    let results: Arc<Mutex<Vec<(PoolKind, FinishedRequest)>>> =
        Arc::new(Mutex::new(Vec::new()));

    let mut handles = Vec::new();
    for (kind, count) in [
        (PoolKind::Short, cfg.replicas_short),
        (PoolKind::Long, cfg.replicas_long),
    ] {
        let pool_idx = match kind {
            PoolKind::Short => 0,
            PoolKind::Long => 1,
        };
        for _ in 0..count {
            let dir = artifacts_dir.to_path_buf();
            let pool = pools[pool_idx].clone();
            let done = done_feeding.clone();
            let in_flight = in_flight.clone();
            let results = results.clone();
            handles.push(std::thread::spawn(move || -> Result<()> {
                let rt = Arc::new(ModelRuntime::load(&dir)?);
                let mut replica = Replica::new(rt, kind);
                loop {
                    // Admit as many queued requests as there are free slots.
                    {
                        let mut q = pool.queue.lock().unwrap();
                        while replica.n_free() > 0 {
                            let Some(req) = q.pop_front() else { break };
                            assert!(replica.admit(req));
                        }
                        if !replica.has_work() {
                            if done.load(Ordering::Acquire) && q.is_empty() {
                                return Ok(());
                            }
                            // Sleep until an arrival wakes this pool.
                            let (guard, _) = pool
                                .wake
                                .wait_timeout(q, std::time::Duration::from_millis(20))
                                .unwrap();
                            drop(guard);
                            continue;
                        }
                    }
                    for fin in replica.step()? {
                        in_flight.fetch_sub(1, Ordering::AcqRel);
                        results.lock().unwrap().push((kind, fin));
                    }
                }
            }));
        }
    }

    // Driver: route + feed with paced arrivals. Arrivals that are already
    // due when the driver wakes are routed together through the gateway's
    // batch API (§Perf): one warm pass over the shared compression scratch
    // instead of per-request cold calls — exactly the burst shape where
    // gateway latency matters most.
    let mut gateway = Gateway::new(cfg.gateway.clone());
    let vocab = manifest.model.vocab as u32;
    let start = Instant::now();
    let mut gateway_total_s = 0.0;
    let n_items = items.len() as u64;
    let mut next = 0usize;
    while next < items.len() {
        let target = items[next].arrival_offset_s * time_scale;
        let elapsed = start.elapsed().as_secs_f64();
        if target > elapsed {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - elapsed));
        }
        // Gather every item that is due by now into one batch.
        let now = start.elapsed().as_secs_f64();
        let mut end = next + 1;
        while end < items.len() && items[end].arrival_offset_s * time_scale <= now {
            end += 1;
        }
        let batch: Vec<(&str, u32)> = items[next..end]
            .iter()
            .map(|it| (it.text.as_str(), it.max_output))
            .collect();
        // Streaming sink: each request is enqueued (and its pool woken)
        // the moment it is routed, while later batch members are still in
        // the gateway — no head-of-line blocking behind a slow
        // compression, and per-item arrival stamps keep the latency
        // metrics comparable to per-item routing.
        gateway.route_batch_with(&batch, |k, routed| {
            gateway_total_s += routed.gateway_s;
            let req = LiveRequest {
                id: (next + k) as u64,
                tokens: crate::compress::tokenizer::hash_tokens(&routed.text, vocab),
                max_output: routed.max_output_tokens,
                arrival: Instant::now(),
            };
            let pool_idx = match routed.pool {
                PoolKind::Short => 0,
                PoolKind::Long => 1,
            };
            in_flight.fetch_add(1, Ordering::AcqRel);
            {
                let mut q = pools[pool_idx].queue.lock().unwrap();
                q.push_back(req);
            }
            pools[pool_idx].wake.notify_all();
        });
        next = end;
    }
    done_feeding.store(true, Ordering::Release);
    for p in &pools {
        p.wake.notify_all();
    }
    for h in handles {
        h.join().expect("replica thread panicked")?;
    }
    let duration_s = start.elapsed().as_secs_f64();

    let mut short = PoolMetrics::new("short");
    let mut long = PoolMetrics::new("long");
    let all = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
    let completed = all.len() as u64;
    for (kind, fin) in all {
        match kind {
            PoolKind::Short => short.record(&fin),
            PoolKind::Long => long.record(&fin),
        }
    }
    assert_eq!(in_flight.load(Ordering::Acquire), 0, "requests lost in flight");
    Ok(ServeReport {
        short,
        long,
        duration_s,
        throughput_rps: completed as f64 / duration_s.max(1e-9),
        n_compressed: gateway.n_compressed,
        n_routed_short: gateway.n_routed_short,
        n_routed_long: gateway.n_routed_long,
        mean_gateway_s: gateway_total_s / n_items.max(1) as f64,
    })
}
