//! An engine replica: one "GPU" worth of KV slots advancing under
//! continuous batching, driven by the PJRT runtime.
//!
//! A replica owns a slot-major KV cache (`[S, L, C, H, D]` flat f32 — the
//! layout the decode artifact expects, with each slot's block identical to
//! the prefill artifact's `[L, C, H, D]`). One `step()` is one engine
//! iteration: at most one chunked-prefill call for one slot (Sarathi-style
//! mixed batching) plus one batched decode call advancing every decoding
//! slot in lockstep (paper Eq. 3's model, §3.1).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{ModelRuntime, PoolKind};

/// A request admitted to the live path (already routed + tokenized).
#[derive(Clone, Debug)]
pub struct LiveRequest {
    pub id: u64,
    /// Prompt token ids (hash-tokenized at the gateway).
    pub tokens: Vec<i32>,
    pub max_output: u32,
    /// Arrival timestamp (TTFT/e2e reference point).
    pub arrival: Instant,
}

/// A completed request with its latency breakdown.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    pub id: u64,
    pub output: Vec<i32>,
    /// Arrival -> first token, seconds.
    pub ttft_s: f64,
    /// Arrival -> completion, seconds.
    pub e2e_s: f64,
    /// Arrival -> slot admission, seconds.
    pub queue_s: f64,
}

#[derive(Clone, Debug)]
enum Phase {
    /// `consumed` prompt tokens already prefilled.
    Prefill { consumed: usize },
    /// Generated `produced` tokens; `last` awaits its KV write.
    Decode { produced: u32, last: i32 },
}

#[derive(Clone, Debug)]
struct Active {
    req: LiveRequest,
    admitted: Instant,
    phase: Phase,
    output: Vec<i32>,
    ttft_s: Option<f64>,
}

/// One engine replica.
pub struct Replica {
    rt: Arc<ModelRuntime>,
    pub kind: PoolKind,
    slots: Vec<Option<Active>>,
    k: Vec<f32>,
    v: Vec<f32>,
    slot_len: usize,
    next_prefill_slot: usize,
    /// Iterations executed (diagnostics / perf accounting).
    pub iterations: u64,
    pub prefill_calls: u64,
    pub decode_calls: u64,
}

impl Replica {
    pub fn new(rt: Arc<ModelRuntime>, kind: PoolKind) -> Replica {
        let shape = rt.manifest.pool(kind);
        let slot_len = rt.slot_cache_len(kind);
        Replica {
            kind,
            slots: vec![None; shape.n_slots],
            k: vec![0.0; shape.n_slots * slot_len],
            v: vec![0.0; shape.n_slots * slot_len],
            slot_len,
            next_prefill_slot: 0,
            iterations: 0,
            prefill_calls: 0,
            decode_calls: 0,
            rt,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn n_busy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn n_free(&self) -> usize {
        self.n_slots() - self.n_busy()
    }

    pub fn is_idle(&self) -> bool {
        self.n_busy() == 0
    }

    /// Context window per slot.
    pub fn ctx(&self) -> usize {
        self.rt.manifest.pool(self.kind).ctx
    }

    /// Admit a request into a free slot. Returns false when full. Prompts
    /// are clamped so prompt + output always fits the slot's window (the
    /// gateway guarantees this for short-pool traffic by Eq. 15; the clamp
    /// is belt-and-braces for the long pool).
    pub fn admit(&mut self, mut req: LiveRequest) -> bool {
        let Some(idx) = self.slots.iter().position(Option::is_none) else {
            return false;
        };
        let ctx = self.ctx();
        let max_prompt = ctx.saturating_sub(req.max_output as usize + 1).max(1);
        if req.tokens.len() > max_prompt {
            req.tokens.truncate(max_prompt);
        }
        if req.tokens.is_empty() {
            req.tokens.push(0);
        }
        // Zero this slot's cache (stale values are masked by pos anyway,
        // but zeroing keeps replays bit-identical).
        let o = idx * self.slot_len;
        self.k[o..o + self.slot_len].fill(0.0);
        self.v[o..o + self.slot_len].fill(0.0);
        self.slots[idx] = Some(Active {
            admitted: Instant::now(),
            phase: Phase::Prefill { consumed: 0 },
            output: Vec::with_capacity(req.max_output as usize),
            ttft_s: None,
            req,
        });
        true
    }

    fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        best as i32
    }

    /// One engine iteration. Returns requests that completed this step.
    pub fn step(&mut self) -> Result<Vec<FinishedRequest>> {
        self.iterations += 1;
        let chunk = self.rt.manifest.chunk;
        let vocab = self.rt.manifest.model.vocab;
        let mut finished = Vec::new();

        // --- one prefill chunk for one slot (round-robin) ---------------
        let n = self.slots.len();
        let prefill_slot = (0..n)
            .map(|i| (self.next_prefill_slot + i) % n)
            .find(|&i| {
                matches!(
                    self.slots[i],
                    Some(Active {
                        phase: Phase::Prefill { .. },
                        ..
                    })
                )
            });
        if let Some(i) = prefill_slot {
            self.next_prefill_slot = (i + 1) % n;
            let a = self.slots[i].as_mut().unwrap();
            let Phase::Prefill { consumed } = a.phase else { unreachable!() };
            let remaining = &a.req.tokens[consumed..];
            let valid = remaining.len().min(chunk);
            let mut toks = vec![0i32; chunk];
            toks[..valid].copy_from_slice(&remaining[..valid]);
            let o = i * self.slot_len;
            let out = self.rt.prefill(
                self.kind,
                &self.k[o..o + self.slot_len],
                &self.v[o..o + self.slot_len],
                &toks,
                consumed as i32,
            )?;
            self.k[o..o + self.slot_len].copy_from_slice(&out.k_cache);
            self.v[o..o + self.slot_len].copy_from_slice(&out.v_cache);
            self.prefill_calls += 1;
            let a = self.slots[i].as_mut().unwrap();
            let done = consumed + valid;
            if done == a.req.tokens.len() {
                // Prompt fully prefilled: the last valid row's logits give
                // the first generated token.
                let row = &out.logits[(valid - 1) * vocab..valid * vocab];
                let first = Self::argmax(row);
                a.ttft_s = Some(a.req.arrival.elapsed().as_secs_f64());
                a.output.push(first);
                if a.req.max_output <= 1 {
                    finished.push(Self::finish(self.slots[i].take().unwrap()));
                } else {
                    a.phase = Phase::Decode { produced: 1, last: first };
                }
            } else {
                a.phase = Phase::Prefill { consumed: done };
            }
        }

        // --- batched lockstep decode -------------------------------------
        let any_decoding = self.slots.iter().any(|s| {
            matches!(
                s,
                Some(Active {
                    phase: Phase::Decode { .. },
                    ..
                })
            )
        });
        if any_decoding {
            let s_count = self.slots.len();
            let mut toks = vec![0i32; s_count];
            let mut pos = vec![0i32; s_count];
            for (i, slot) in self.slots.iter().enumerate() {
                if let Some(Active {
                    phase: Phase::Decode { produced, last },
                    req,
                    ..
                }) = slot
                {
                    toks[i] = *last;
                    pos[i] = (req.tokens.len() as u32 + produced - 1) as i32;
                }
            }
            let out = self.rt.decode(self.kind, &self.k, &self.v, &toks, &pos)?;
            self.k = out.k_cache;
            self.v = out.v_cache;
            self.decode_calls += 1;
            let ctx = self.ctx();
            for i in 0..s_count {
                let is_decoding = matches!(
                    self.slots[i],
                    Some(Active {
                        phase: Phase::Decode { .. },
                        ..
                    })
                );
                if !is_decoding {
                    continue;
                }
                let a = self.slots[i].as_mut().unwrap();
                let Phase::Decode { produced, .. } = a.phase else { unreachable!() };
                let row = &out.logits[i * vocab..(i + 1) * vocab];
                let next = Self::argmax(row);
                a.output.push(next);
                let produced = produced + 1;
                let next_write = a.req.tokens.len() + produced as usize - 1;
                if produced >= a.req.max_output || next_write >= ctx {
                    finished.push(Self::finish(self.slots[i].take().unwrap()));
                } else {
                    a.phase = Phase::Decode { produced, last: next };
                }
            }
        }

        Ok(finished)
    }

    fn finish(a: Active) -> FinishedRequest {
        FinishedRequest {
            id: a.req.id,
            e2e_s: a.req.arrival.elapsed().as_secs_f64(),
            ttft_s: a.ttft_s.unwrap_or_else(|| a.req.arrival.elapsed().as_secs_f64()),
            queue_s: (a.admitted - a.req.arrival).as_secs_f64(),
            output: a.output,
        }
    }

    /// Whether there is any in-flight work.
    pub fn has_work(&self) -> bool {
        self.slots.iter().any(Option::is_some)
    }
}

#[cfg(test)]
mod tests {
    // Replica logic is exercised end-to-end in rust/tests/serve_e2e.rs
    // (needs built artifacts); pure-logic pieces are tested here.
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(Replica::argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(Replica::argmax(&[3.0]), 0);
        assert_eq!(Replica::argmax(&[2.0, 1.0, 2.0]), 0); // first max wins
    }
}
