//! Scoped-thread fan-out substrate (§Perf, PR 6).
//!
//! One generic family of parallel maps shared by the planner sweeps, the
//! experiment grids, and the DES replication drivers (previously two
//! near-identical private helpers plus six ad-hoc `thread::scope` sites),
//! plus the process-wide worker cap — `FLEETOPT_THREADS` in the
//! environment or `fleetopt --threads N` on the CLI — that every fan-out
//! honors so bench runs are reproducible on shared CI runners.
//!
//! All maps return results in input order and are bit-identical to a
//! serial evaluation whenever `f` is deterministic: the cap and the
//! worker count change scheduling, never values (property-tested in
//! `tests/perf_equivalence.rs` via the parallel-vs-serial sweeps).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// 0 = unset (fall back to the environment, then uncapped).
static CAP: AtomicUsize = AtomicUsize::new(0);

fn env_cap() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("FLEETOPT_THREADS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(0)
    })
}

/// Cap every scoped-thread fan-out at `n` workers. `0` clears the
/// programmatic cap, falling back to `FLEETOPT_THREADS` (or uncapped).
pub fn set_thread_cap(n: usize) {
    CAP.store(n, Ordering::Relaxed);
}

/// The effective worker cap: the last [`set_thread_cap`], else
/// `FLEETOPT_THREADS`, else `usize::MAX` (uncapped).
pub fn thread_cap() -> usize {
    let cap = CAP.load(Ordering::Relaxed);
    let cap = if cap > 0 { cap } else { env_cap() };
    if cap > 0 {
        cap
    } else {
        usize::MAX
    }
}

/// Worker count for `items` work items where each worker should amortize
/// its spawn over at least `per_worker` items: available parallelism,
/// clamped by the item count, a hard ceiling of 16, and [`thread_cap`].
pub fn workers_for(items: usize, per_worker: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(items.div_ceil(per_worker.max(1)))
        .min(16)
        .min(thread_cap())
        .max(1)
}

/// Fallible parallel map over contiguous chunks (the planner-sweep
/// shape): results in input order, first error wins. `parallel = false`
/// or an effective worker count of 1 evaluates serially on the caller's
/// thread — same values either way.
pub fn par_map<T: Sync, R: Send, E: Send>(
    items: &[T],
    parallel: bool,
    f: impl Fn(&T) -> Result<R, E> + Sync,
) -> Result<Vec<R>, E> {
    let workers = if parallel {
        workers_for(items.len(), 4)
    } else {
        1
    };
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let chunk_len = items.len().div_ceil(workers);
    let f_ref = &f;
    let shards: Result<Vec<Vec<R>>, E> = std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .chunks(chunk_len)
            .map(|shard| {
                scope.spawn(move || shard.iter().map(f_ref).collect::<Result<Vec<R>, E>>())
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    });
    Ok(shards?.into_iter().flatten().collect())
}

/// Infallible strided parallel map at ~4 items per worker. Work items
/// whose cost varies by orders of magnitude across the input (e.g. pruned
/// vs evaluated sweep cells) load-balance better striped than chunked:
/// worker `w` takes items `w, w+workers, w+2*workers, ...`, and results
/// are reassembled in input order.
pub fn par_map_strided<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    par_map_strided_with(items, 4, f)
}

/// Strided map at one item per worker — for heavyweight items (whole DES
/// replications, Table-9 variants) where the old code spawned one thread
/// per item. With ≤ 16 items and no cap this spawns exactly as many
/// workers as items, preserving that behavior while honoring the cap.
pub fn par_map_each<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    par_map_strided_with(items, 1, f)
}

/// Strided parallel map with per-worker mutable state (the gateway
/// scratch-pool shape): worker `w` evaluates items `w, w+W, w+2W, ...`
/// with exclusive access to `states[w]`, and results are reassembled in
/// input order. `states.len()` IS the worker count — callers size it
/// with [`workers_for`] or an explicit request already clamped by
/// [`thread_cap`]; one state (or ≤ 1 item) runs serially on the caller's
/// thread. Values are independent of the worker count whenever `f`'s
/// output does not depend on its state argument's history (scratch
/// buffers, not accumulators) — the property the gateway pins in
/// `tests/gateway_concurrency.rs`.
pub fn par_map_with<T: Sync, R: Send, S: Send>(
    items: &[T],
    states: &mut [S],
    f: impl Fn(&mut S, &T) -> R + Sync,
) -> Vec<R> {
    let workers = states.len().min(items.len());
    if workers <= 1 {
        let Some(s0) = states.first_mut() else {
            assert!(items.is_empty(), "par_map_with needs at least one state");
            return Vec::new();
        };
        return items.iter().map(|t| f(s0, t)).collect();
    }
    let f_ref = &f;
    let shards: Vec<Vec<R>> = std::thread::scope(|scope| {
        let handles: Vec<_> = states[..workers]
            .iter_mut()
            .enumerate()
            .map(|(w, state)| {
                scope.spawn(move || {
                    items
                        .iter()
                        .skip(w)
                        .step_by(workers)
                        .map(|t| f_ref(state, t))
                        .collect::<Vec<R>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map_with worker panicked"))
            .collect()
    });
    let mut iters: Vec<_> = shards.into_iter().map(|s| s.into_iter()).collect();
    (0..items.len())
        .map(|i| iters[i % workers].next().expect("stride shard underflow"))
        .collect()
}

fn par_map_strided_with<T: Sync, R: Send>(
    items: &[T],
    per_worker: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let workers = workers_for(items.len(), per_worker);
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let f_ref = &f;
    let shards: Vec<Vec<R>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    items
                        .iter()
                        .skip(w)
                        .step_by(workers)
                        .map(f_ref)
                        .collect::<Vec<R>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map_strided worker panicked"))
            .collect()
    });
    let mut iters: Vec<_> = shards.into_iter().map(|s| s.into_iter()).collect();
    (0..items.len())
        .map(|i| iters[i % workers].next().expect("stride shard underflow"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order_and_values() {
        let items: Vec<u64> = (0..103).collect();
        let got = par_map(&items, true, |&x| Ok::<_, ()>(x * x)).unwrap();
        let want: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_propagates_errors() {
        let items: Vec<u64> = (0..50).collect();
        let got = par_map(&items, true, |&x| if x == 31 { Err(x) } else { Ok(x) });
        assert_eq!(got, Err(31));
    }

    #[test]
    fn strided_and_each_match_serial() {
        for n in [0usize, 1, 2, 7, 16, 33, 64] {
            let items: Vec<usize> = (0..n).collect();
            let want: Vec<usize> = items.iter().map(|&x| x.wrapping_mul(7) ^ 5).collect();
            assert_eq!(par_map_strided(&items, |&x| x.wrapping_mul(7) ^ 5), want);
            assert_eq!(par_map_each(&items, |&x| x.wrapping_mul(7) ^ 5), want);
        }
    }

    #[test]
    fn thread_cap_forces_serial_with_identical_results() {
        let items: Vec<u64> = (0..64).collect();
        let uncapped = par_map_strided(&items, |&x| x as f64 * 0.1);
        set_thread_cap(1);
        assert_eq!(workers_for(64, 1), 1);
        let capped = par_map_strided(&items, |&x| x as f64 * 0.1);
        set_thread_cap(0);
        assert_eq!(uncapped, capped);
    }

    #[test]
    fn par_map_with_matches_serial_and_touches_state() {
        for n in [0usize, 1, 2, 7, 33, 64] {
            for w in [1usize, 2, 5, 8] {
                let items: Vec<usize> = (0..n).collect();
                let mut states = vec![0u64; w];
                let got = par_map_with(&items, &mut states, |s, &x| {
                    *s += 1; // per-worker tally; must not affect values
                    x.wrapping_mul(7) ^ 5
                });
                let want: Vec<usize> = items.iter().map(|&x| x.wrapping_mul(7) ^ 5).collect();
                assert_eq!(got, want, "n={n} w={w}");
                assert_eq!(states.iter().sum::<u64>(), n as u64);
            }
        }
    }

    #[test]
    fn workers_for_respects_item_granularity() {
        assert_eq!(workers_for(0, 4), 1);
        assert_eq!(workers_for(1, 4), 1);
        assert!(workers_for(4, 4) <= 1 + 4 / 4);
        assert!(workers_for(1_000, 1) <= 16);
    }
}
