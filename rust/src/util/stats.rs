//! Streaming and batch statistics: Welford moments, percentiles, histograms.
//!
//! Used by the DES (utilization, wait times), the metrics layer (TTFT
//! recorders), the compressor latency study (Table 4), and the Monte-Carlo
//! service-time calibration (C_s², Eq. 4).

/// Streaming mean/variance via Welford's algorithm, plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Squared coefficient of variation Var[X] / E[X]^2 — the C_s² the
    /// Kimura approximation needs (Eq. 6).
    pub fn scv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.variance() / (self.mean * self.mean)
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n;
        self.m2 += other.m2 + delta * delta * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch percentile over a slice (nearest-rank with linear interpolation).
/// `q` in [0, 1]. Sorts a copy; for hot paths use [`Reservoir`].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = q * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Sample accumulator with exact percentiles (stores all samples).
/// The studies here run up to millions of samples, so percentile reads use
/// `select_nth_unstable` — O(n) exact order statistics, bit-identical to a
/// full sort (§Perf: the DES's end-of-run P50/P99 no longer pay
/// O(n log n) sorts). Sample order is unspecified after a percentile read.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    data: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Samples { data: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Samples {
            data: Vec::with_capacity(n),
        }
    }

    pub fn push(&mut self, x: f64) {
        // The old sort-based reads panicked loudly on NaN (partial_cmp
        // unwrap); the selection path orders NaN last instead, so keep
        // the loud failure at the write site in debug builds.
        debug_assert!(!x.is_nan(), "NaN sample pushed into Samples");
        self.data.push(x);
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f64>() / self.data.len() as f64
        }
    }

    /// Exact q-quantile with linear interpolation — the same order
    /// statistics (hence bit-identical values) as sorting and indexing,
    /// via in-place selection. Reorders the underlying samples.
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!(!self.data.is_empty());
        assert!((0.0..=1.0).contains(&q));
        let n = self.data.len();
        if n == 1 {
            return self.data[0];
        }
        let rank = q * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let (_, &mut x_lo, rest) = self.data.select_nth_unstable_by(lo, f64::total_cmp);
        if lo == hi {
            return x_lo;
        }
        // hi == lo + 1: the next order statistic is the suffix minimum.
        let x_hi = rest.iter().copied().fold(f64::INFINITY, f64::min);
        let frac = rank - lo as f64;
        x_lo * (1.0 - frac) + x_hi * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(0.99)
    }

    pub fn max(&mut self) -> f64 {
        assert!(!self.data.is_empty());
        self.data.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// The raw samples, in unspecified order (percentile reads permute).
    pub fn values(&self) -> &[f64] {
        &self.data
    }
}

/// Streaming single-quantile estimator — the P² algorithm (Jain &
/// Chlamtac 1985). Five markers track {min, q/2, q, (1+q)/2, max} with
/// parabolic height adjustment: O(1) memory and O(1) per observation,
/// where an exact quantile stores every sample. Used for the per-epoch
/// P99s in the autoscale DES (`metrics::EpochDigest`); the error against
/// exact sorting is bounds-tested on all three traces in
/// `tests/des_engine.rs`. Final-table percentiles stay exact (`Samples`).
#[derive(Clone, Debug)]
pub struct P2Quantile {
    q: f64,
    /// Marker heights (quantile estimates); the first `n` entries hold the
    /// raw observations until five have arrived.
    heights: [f64; 5],
    /// Marker positions, 1-based ranks.
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Per-observation desired-position increments.
    inc: [f64; 5],
    n: u64,
}

impl P2Quantile {
    pub fn new(q: f64) -> Self {
        assert!(q > 0.0 && q < 1.0, "quantile must be interior, got {q}");
        P2Quantile {
            q,
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            inc: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            n: 0,
        }
    }

    /// Reset for reuse (epoch boundaries) — allocation-free.
    pub fn reset(&mut self) {
        let q = self.q;
        self.pos = [1.0, 2.0, 3.0, 4.0, 5.0];
        self.desired = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0];
        self.n = 0;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn push(&mut self, x: f64) {
        if self.n < 5 {
            self.heights[self.n as usize] = x;
            self.n += 1;
            if self.n == 5 {
                self.heights.sort_by(f64::total_cmp);
            }
            return;
        }
        self.n += 1;
        let h = &mut self.heights;
        let k = if x < h[0] {
            h[0] = x;
            0
        } else if x < h[1] {
            0
        } else if x < h[2] {
            1
        } else if x < h[3] {
            2
        } else if x <= h[4] {
            3
        } else {
            h[4] = x;
            3
        };
        for p in self.pos.iter_mut().skip(k + 1) {
            *p += 1.0;
        }
        for (d, i) in self.desired.iter_mut().zip(&self.inc) {
            *d += i;
        }
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            let room_up = self.pos[i + 1] - self.pos[i] > 1.0;
            let room_down = self.pos[i - 1] - self.pos[i] < -1.0;
            if (d >= 1.0 && room_up) || (d <= -1.0 && room_down) {
                let d = d.signum();
                let hp = self.parabolic(i, d);
                self.heights[i] = if self.heights[i - 1] < hp && hp < self.heights[i + 1] {
                    hp
                } else {
                    self.linear(i, d)
                };
                self.pos[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (hm, hi, hp) = (self.heights[i - 1], self.heights[i], self.heights[i + 1]);
        let (nm, ni, np) = (self.pos[i - 1], self.pos[i], self.pos[i + 1]);
        hi + d / (np - nm)
            * ((ni - nm + d) * (hp - hi) / (np - ni) + (np - ni - d) * (hi - hm) / (ni - nm))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.heights[i] + d * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current quantile estimate (exact while n <= 5 — at n == 5 the
    /// markers are still the raw sorted observations; 0.0 when empty).
    pub fn value(&self) -> f64 {
        let m = self.n as usize;
        match m {
            0 => 0.0,
            1..=5 => {
                let mut v = [0.0; 5];
                v[..m].copy_from_slice(&self.heights[..m]);
                let v = &mut v[..m];
                v.sort_by(f64::total_cmp);
                percentile_sorted(v, self.q)
            }
            _ => self.heights[2],
        }
    }
}

/// Fixed-width bucket histogram over [lo, hi); out-of-range clamps to the
/// edge buckets. Used for CDF reconstruction in the workload layer.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; buckets],
            total: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        let b = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64)
            .floor()
            .clamp(0.0, (self.counts.len() - 1) as f64) as usize;
        self.counts[b] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Empirical CDF at x: fraction of samples in buckets entirely <= x.
    pub fn cdf(&self, x: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let width = (self.hi - self.lo) / self.counts.len() as f64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let upper = self.lo + (i + 1) as f64 * width;
            if upper <= x {
                acc += c;
            } else {
                break;
            }
        }
        acc as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 100.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-9);
        assert!((w.variance() - var).abs() < 1e-9);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 100.0);
    }

    #[test]
    fn welford_scv_of_constant_is_zero() {
        let mut w = Welford::new();
        for _ in 0..10 {
            w.push(3.0);
        }
        assert!(w.scv().abs() < 1e-12);
    }

    #[test]
    fn welford_scv_of_exponential_near_one() {
        // SCV of an exponential distribution is exactly 1.
        let mut rng = crate::util::rng::Rng::new(2);
        let mut w = Welford::new();
        for _ in 0..200_000 {
            w.push(rng.exp(3.0));
        }
        assert!((w.scv() - 1.0).abs() < 0.02, "scv={}", w.scv());
    }

    #[test]
    fn welford_merge_equals_combined() {
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut all = Welford::new();
        for i in 0..50 {
            let x = (i as f64).sin() * 10.0;
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentile_simple() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert!((percentile(&xs, 0.25) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-9);
        assert!((percentile(&xs, 0.99) - 9.9).abs() < 1e-9);
    }

    #[test]
    fn samples_percentiles() {
        let mut s = Samples::new();
        for i in (0..=100).rev() {
            s.push(i as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.percentile(0.99), 99.0);
        assert_eq!(s.max(), 100.0);
        assert_eq!(s.len(), 101);
    }

    #[test]
    fn samples_resort_after_push() {
        let mut s = Samples::new();
        s.push(5.0);
        assert_eq!(s.p50(), 5.0);
        s.push(1.0);
        s.push(9.0);
        assert_eq!(s.p50(), 5.0);
        s.push(100.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn histogram_cdf() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..100 {
            h.push(i as f64 + 0.5);
        }
        assert!((h.cdf(50.0) - 0.5).abs() < 0.02);
        assert_eq!(h.cdf(100.0), 1.0);
        assert_eq!(h.cdf(0.0), 0.0);
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(50.0);
        assert_eq!(h.total(), 2);
    }
}
