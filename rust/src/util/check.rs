//! Mini property-testing harness (no proptest crate offline — DESIGN.md §1).
//!
//! `forall(name, iters, strategy, property)` draws seeded random cases and
//! on failure re-reports the failing seed so the case can be replayed by
//! constructing `Rng::new(seed)` in a debugger. A light shrinking pass
//! retries the property with "smaller" cases when the strategy supports it.

use crate::util::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` against `iters` random cases drawn by `gen`.
///
/// Panics (failing the enclosing #[test]) with the seed and message of the
/// first failing case.
pub fn forall<T, G, P>(name: &str, iters: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    // A fixed base seed keeps CI deterministic; vary cases via the index.
    const BASE_SEED: u64 = 0x5EED_F1EE7;
    for i in 0..iters {
        let seed = BASE_SEED.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property `{name}` failed at iter {i} (seed {seed:#x}):\n  case: {case:?}\n  {msg}"
            );
        }
    }
}

/// Like [`forall`] but also passes a fresh RNG to the property (for
/// properties that are themselves randomized, e.g. comparing two seeded
/// simulations).
pub fn forall_with_rng<T, G, P>(name: &str, iters: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T, &mut Rng) -> PropResult,
{
    const BASE_SEED: u64 = 0xCAFE_BABE;
    for i in 0..iters {
        let seed = BASE_SEED.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        let mut prop_rng = rng.fork(0xF00D);
        if let Err(msg) = prop(&case, &mut prop_rng) {
            panic!(
                "property `{name}` failed at iter {i} (seed {seed:#x}):\n  case: {case:?}\n  {msg}"
            );
        }
    }
}

/// Common generators.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vec of f64 in [lo, hi), length in [min_len, max_len].
    pub fn vec_f64(
        rng: &mut Rng,
        min_len: usize,
        max_len: usize,
        lo: f64,
        hi: f64,
    ) -> Vec<f64> {
        let n = rng.range(min_len, max_len + 1);
        (0..n).map(|_| rng.uniform(lo, hi)).collect()
    }

    /// Positive token count, log-uniform across decades (matches the long
    /// tails of prompt-length distributions).
    pub fn token_count(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        (rng.uniform(lo.ln(), hi.ln())).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", 100, |r| (r.f64(), r.f64()), |&(a, b)| {
            ensure((a + b - (b + a)).abs() < 1e-12, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_seed() {
        forall("always-fails", 10, |r| r.f64(), |_| Err("nope".into()));
    }

    #[test]
    fn gen_vec_respects_bounds() {
        forall(
            "vec-bounds",
            50,
            |r| gen::vec_f64(r, 1, 20, -5.0, 5.0),
            |v| {
                ensure(
                    (1..=20).contains(&v.len()) && v.iter().all(|x| (-5.0..5.0).contains(x)),
                    "bounds violated",
                )
            },
        );
    }

    #[test]
    fn token_count_log_uniform_spans_decades() {
        let mut rng = Rng::new(1);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let t = gen::token_count(&mut rng, 10.0, 100_000.0);
            assert!((10.0..100_000.0).contains(&t));
            if t < 100.0 {
                lo_seen = true;
            }
            if t > 10_000.0 {
                hi_seen = true;
            }
        }
        assert!(lo_seen && hi_seen, "log-uniform should span decades");
    }
}
