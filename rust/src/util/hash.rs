//! Cheap non-cryptographic hashing (§Perf).
//!
//! `std::collections::HashMap` defaults to SipHash-1-3, which is
//! DoS-resistant but costs ~1–2 ns/byte with a per-map random seed. The
//! planner's calibration memo and the compressor's interner hash only
//! trusted, fixed-width keys ((f64 bits, f64 bits, u32) tuples and short
//! lowercase words), so a multiply-rotate hash in the FxHash family is both
//! sufficient and several times faster. Determinism is also load-bearing:
//! a fixed-seed hasher keeps iteration-independent data structures
//! reproducible run-to-run.

use std::collections::HashMap;
use std::hash::{BuildHasher, Hasher};

/// Golden-ratio multiplier used by the Firefox/rustc "Fx" hash.
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher for short trusted keys (integers, small tuples).
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, x: u8) {
        self.add(x as u64);
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.add(x as u64);
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.add(x);
    }

    #[inline]
    fn write_usize(&mut self, x: usize) {
        self.add(x as u64);
    }
}

/// Fixed-seed builder: no per-map randomness (deterministic, zero set-up).
#[derive(Clone, Copy, Debug, Default)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

/// A `HashMap` using [`FxHasher`] — drop-in for integer-keyed memo tables.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// FNV-1a over raw bytes — used by the interner's open-addressed table.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a continuation over 64-bit words: fold `words` into a running
/// hash `h` (seed with [`FNV_OFFSET`] to start a fresh fingerprint).
/// One definition for every hand-rolled fingerprint — the workload
/// calibration fingerprint and the planner's per-thread cell-cache key
/// chain through this, so what they cover can never silently diverge in
/// mixing.
#[inline]
pub fn fnv1a_words(mut h: u64, words: &[u64]) -> u64 {
    for &w in words {
        h ^= w;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The FNV-1a offset basis (the seed for [`fnv1a_words`] chains).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// A per-process random 64-bit seed (std `RandomState` entropy, computed
/// once). Structures that hash **untrusted** input — the gateway interner
/// hashes attacker-controlled prompt words — mix this in so masked-bucket
/// collisions cannot be precomputed offline (hash-flood resistance).
/// Within a process the seed is fixed, so runs stay deterministic; and the
/// interner assigns word ids by first-appearance order, not by hash, so
/// results are identical across processes regardless of the seed.
pub fn process_seed() -> u64 {
    use std::sync::OnceLock;
    static PROCESS_SEED: OnceLock<u64> = OnceLock::new();
    *PROCESS_SEED.get_or_init(|| {
        let mut h = std::collections::hash_map::RandomState::new().build_hasher();
        h.write_u64(0x5EED_0F_F1CE);
        h.finish()
    })
}

/// Seeded avalanche finalizer for mask-indexed tables: multiplies the
/// seed-xored hash and folds the high bits down so every masked bit
/// depends on the (secret) seed.
#[inline]
pub fn mix64(h: u64, seed: u64) -> u64 {
    let x = (h ^ seed).wrapping_mul(SEED);
    x ^ (x >> 32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_with_tuple_keys() {
        let mut m: FxHashMap<(u64, u64, u32), f64> = FxHashMap::default();
        m.insert((1, 2, 3), 0.5);
        m.insert((1.5f64.to_bits(), 2.5f64.to_bits(), 16), 1.5);
        assert_eq!(m.get(&(1, 2, 3)), Some(&0.5));
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn hasher_is_deterministic() {
        let b = FxBuildHasher;
        let mut h1 = b.build_hasher();
        let mut h2 = b.build_hasher();
        h1.write_u64(0xDEAD_BEEF);
        h2.write_u64(0xDEAD_BEEF);
        assert_eq!(h1.finish(), h2.finish());
    }

    #[test]
    fn distinct_keys_hash_differently() {
        let b = FxBuildHasher;
        let mut seen = std::collections::HashSet::new();
        for i in 0u64..1000 {
            let mut h = b.build_hasher();
            h.write_u64(i);
            seen.insert(h.finish());
        }
        assert_eq!(seen.len(), 1000);
    }

    #[test]
    fn fnv_distinguishes_words() {
        assert_ne!(fnv1a(b"alpha"), fnv1a(b"beta"));
        assert_ne!(fnv1a(b""), fnv1a(b"a"));
        assert_eq!(fnv1a(b"pool"), fnv1a(b"pool"));
    }

    #[test]
    fn process_seed_stable_within_process() {
        assert_eq!(process_seed(), process_seed());
    }

    #[test]
    fn mix64_depends_on_seed_and_input() {
        assert_ne!(mix64(1, 2), mix64(1, 3));
        assert_ne!(mix64(1, 2), mix64(2, 2));
        assert_eq!(mix64(7, 9), mix64(7, 9));
    }
}
