//! Runtime SIMD dispatch (§Perf, PR 6).
//!
//! One process-wide switch decides whether the vectorized kernels in
//! `compress::simd` / `queueing::simd` run or their scalar oracles do.
//! The switch exists even when the `simd` cargo feature is off (so call
//! sites and tests compile in both configurations); with the feature off
//! [`simd_active`] is constantly `false` and every dispatch point takes
//! the scalar path.
//!
//! Identity policy (the PR 5 "fast paths never change evaluated values"
//! discipline, extended): every kernel behind this switch produces
//! **bit-identical** shipped values — gateway selections, planner
//! argmin/GPU-counts/cost — under any dispatch mode. Horizontal SIMD-style
//! reductions (which reassociate and therefore cannot be bit-identical)
//! are never used for shipped values; the only blocked reduction in the
//! tree is [`hsum_blocked`], confined to bench checksums and covered by a
//! tested divergence bound. Because results are mode-independent, the
//! global switch needs no synchronization with worker threads — a racing
//! reader merely picks one of two bit-equal paths.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// Which implementation family dispatch points select.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// Feature-gated default: SIMD when compiled in, scalar otherwise.
    Auto,
    /// Always the scalar oracle (bench baselines, equivalence tests).
    ForceScalar,
    /// Always the vectorized path where one exists.
    ForceSimd,
}

const MODE_UNSET: u8 = u8::MAX;
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

fn encode(d: Dispatch) -> u8 {
    match d {
        Dispatch::Auto => 0,
        Dispatch::ForceScalar => 1,
        Dispatch::ForceSimd => 2,
    }
}

/// `FLEETOPT_SIMD=0|off|scalar` forces scalar, `1|on|simd` forces SIMD,
/// anything else (or unset) is [`Dispatch::Auto`]. Read once per process.
fn env_default() -> Dispatch {
    static ENV: OnceLock<Dispatch> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("FLEETOPT_SIMD").as_deref() {
        Ok("0") | Ok("off") | Ok("scalar") => Dispatch::ForceScalar,
        Ok("1") | Ok("on") | Ok("simd") => Dispatch::ForceSimd,
        _ => Dispatch::Auto,
    })
}

/// Current dispatch mode: the last [`set_dispatch`], else the
/// `FLEETOPT_SIMD` environment default, else [`Dispatch::Auto`].
pub fn dispatch() -> Dispatch {
    match MODE.load(Ordering::Relaxed) {
        0 => Dispatch::Auto,
        1 => Dispatch::ForceScalar,
        2 => Dispatch::ForceSimd,
        _ => env_default(),
    }
}

/// Set the process-wide dispatch mode (benches and the CLI; tests should
/// prefer the scoped [`with_dispatch`]).
pub fn set_dispatch(d: Dispatch) {
    MODE.store(encode(d), Ordering::Relaxed);
}

/// Whether dispatch points should take their vectorized path. Always
/// `false` without the `simd` cargo feature.
pub fn simd_active() -> bool {
    #[cfg(feature = "simd")]
    {
        dispatch() != Dispatch::ForceScalar
    }
    #[cfg(not(feature = "simd"))]
    {
        false
    }
}

/// Run `f` under dispatch mode `d`, restoring the previous mode after.
///
/// A process-wide mutex serializes concurrent `with_dispatch` calls so
/// dispatch-toggling tests cannot interleave their set/restore pairs;
/// code *outside* the mutex observing the temporary mode is benign by the
/// identity policy (both paths are bit-identical).
pub fn with_dispatch<R>(d: Dispatch, f: impl FnOnce() -> R) -> R {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let prev = dispatch();
    set_dispatch(d);
    let out = f();
    set_dispatch(prev);
    out
}

/// Blocked 4-accumulator sum — the shape a horizontal SIMD reduction
/// produces. NOT bit-identical to the sequential `iter().sum()` (the
/// accumulators reassociate the adds); for same-sign inputs the divergence
/// is bounded by the standard recursive-summation bound of roughly
/// `2(n-1)` ulps and measures ~1 ulp in practice (see the policy test in
/// `tests/simd_dispatch.rs`). Per the identity policy this function is
/// never used for shipped values — its consumers are bench checksums.
pub fn hsum_blocked(xs: &[f64]) -> f64 {
    let mut acc = [0.0f64; 4];
    let mut chunks = xs.chunks_exact(4);
    for c in &mut chunks {
        acc[0] += c[0];
        acc[1] += c[1];
        acc[2] += c[2];
        acc[3] += c[3];
    }
    let mut tail = 0.0;
    for &x in chunks.remainder() {
        tail += x;
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Distance in units-in-the-last-place between two finite f64s (the
/// currency of the reassociation-bound policy test). Total-orders the
/// bit patterns so the distance is well-defined across signs.
pub fn ulp_distance(a: f64, b: f64) -> u64 {
    fn ordered(x: f64) -> u64 {
        let bits = x.to_bits();
        if bits >> 63 == 1 {
            !bits
        } else {
            bits | 0x8000_0000_0000_0000
        }
    }
    ordered(a).abs_diff(ordered(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_dispatch_restores_previous_mode() {
        let before = dispatch();
        let seen = with_dispatch(Dispatch::ForceScalar, dispatch);
        assert_eq!(seen, Dispatch::ForceScalar);
        assert_eq!(dispatch(), before);
        let seen = with_dispatch(Dispatch::ForceSimd, dispatch);
        assert_eq!(seen, Dispatch::ForceSimd);
        assert_eq!(dispatch(), before);
    }

    #[test]
    fn simd_active_tracks_feature_and_mode() {
        with_dispatch(Dispatch::ForceScalar, || {
            assert!(!simd_active());
        });
        with_dispatch(Dispatch::ForceSimd, || {
            assert_eq!(simd_active(), cfg!(feature = "simd"));
        });
    }

    #[test]
    fn hsum_blocked_matches_sequential_closely() {
        let xs: Vec<f64> = (0..37).map(|i| 0.5 + (i as f64) * 0.013).collect();
        let seq: f64 = xs.iter().sum();
        let blk = hsum_blocked(&xs);
        // Provably safe reassociation bound (see doc comment); measured
        // divergence on this input is 0-1 ulp.
        assert!(ulp_distance(seq, blk) <= 4 * xs.len() as u64);
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(1.0, f64::from_bits(1.0f64.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(-0.0, 0.0), 1);
        assert!(ulp_distance(1.0, 2.0) > 1_000_000);
    }
}
