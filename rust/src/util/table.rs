//! ASCII table rendering for the bench harness — every paper table is
//! regenerated as one of these, so the rows are directly comparable with
//! the published numbers.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder: header row + data rows, auto-sized columns.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    /// Override per-column alignment (defaults: first column left, rest right).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-able values.
    pub fn rowv(&mut self, cells: Vec<String>) -> &mut Self {
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                match aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(&cells[i]);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(&cells[i]);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };

        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &self.aligns));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers shared by benches.
pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn fmt_int(x: f64) -> String {
    let n = x.round() as i64;
    // thousands separators
    let s = n.abs().to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    if n < 0 {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("| a         |"), "{s}");
        assert!(s.contains("| long-name |"), "{s}");
        assert!(s.contains("| 12345 |"), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn panics_on_width_mismatch() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fmt_int_thousands() {
        assert_eq!(fmt_int(1234567.0), "1,234,567");
        assert_eq!(fmt_int(42.0), "42");
        assert_eq!(fmt_int(-1000.0), "-1,000");
        assert_eq!(fmt_int(999.0), "999");
    }

    #[test]
    fn fmt_pct_rounds() {
        assert_eq!(fmt_pct(0.824), "82.4%");
        assert_eq!(fmt_pct(0.055), "5.5%");
    }

    #[test]
    fn unicode_width_by_chars() {
        let mut t = Table::new("T", &["w", "v"]);
        t.row(&["ρ≤0.85".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("ρ≤0.85"));
    }
}
