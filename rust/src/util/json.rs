//! Minimal JSON parser/emitter (no serde available offline — DESIGN.md §1).
//!
//! Parses the AOT `manifest.json`, the config files in `configs/`, and
//! serializes planner/DES reports. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our artifacts).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.get(key)` that panics with a useful message — for manifests we
    /// generated ourselves where absence is a build bug.
    pub fn expect(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- emitter -----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for object literals in report code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model": {"d": 64, "layers": [1, 2]}, "name": "x", "ok": true}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string_pretty();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_emit_without_fraction() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string_pretty(), "42");
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("a", Json::Num(1.0)), ("b", Json::Str("x".into()))]);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "pools": {"short": {"n_slots": 8, "ctx": 256}, "long": {"n_slots": 2, "ctx": 1024}},
          "params": [{"name": "tok_emb", "shape": [256, 64]}]
        }"#;
        let v = Json::parse(src).unwrap();
        let short = v.expect("pools").expect("short");
        assert_eq!(short.expect("n_slots").as_usize(), Some(8));
        let p0 = &v.expect("params").as_arr().unwrap()[0];
        assert_eq!(p0.expect("shape").as_arr().unwrap()[1].as_usize(), Some(64));
    }
}
