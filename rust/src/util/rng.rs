//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! No external `rand` crate is available offline (DESIGN.md §1), so the
//! whole stack — trace generators, Monte-Carlo C_s² estimation, the DES,
//! and the property-test harness — shares this implementation. Determinism
//! under a fixed seed is load-bearing: the DES validation (Table 5) and the
//! planner's Monte-Carlo calibration must be reproducible run-to-run.

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; more than
/// adequate for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent child stream (for per-pool / per-GPU RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (caches the spare value).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mu, sigma).
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.gauss()
    }

    /// Lognormal with underlying Normal(mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: all weights zero");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let lambda = 4.0;
        let mean: f64 = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn lognormal_median() {
        // Median of lognormal(mu, sigma) is exp(mu).
        let mut r = Rng::new(13);
        let mut xs: Vec<f64> = (0..100_001).map(|_| r.lognormal(2.0, 0.7)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[50_000];
        assert!((median - 2.0f64.exp()).abs() / 2.0f64.exp() < 0.03);
    }

    #[test]
    fn weighted_proportions() {
        let mut r = Rng::new(17);
        let w = [1.0, 3.0];
        let n = 100_000;
        let ones = (0..n).filter(|_| r.weighted(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
