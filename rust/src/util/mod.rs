//! Zero-dependency substrates shared across the stack (DESIGN.md §1):
//! deterministic RNG, JSON, statistics, table rendering, fast
//! non-cryptographic hashing, the property-testing mini-harness, the
//! scoped-thread fan-out helpers, and the SIMD runtime-dispatch shim.

pub mod check;
pub mod hash;
pub mod json;
pub mod par;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod table;
