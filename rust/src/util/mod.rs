//! Zero-dependency substrates shared across the stack (DESIGN.md §1):
//! deterministic RNG, JSON, statistics, table rendering, fast
//! non-cryptographic hashing, and the property-testing mini-harness.

pub mod check;
pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
