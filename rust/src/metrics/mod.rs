//! Serving metrics: per-pool latency recorders (TTFT, e2e, queue wait) and
//! completion counters — the quantities the paper's SLO (Eq. 7–8) is
//! stated over — plus the per-epoch control-loop records ([`epoch`]).

pub mod epoch;

pub use epoch::{EpochDigest, EpochMetrics, EpochTierMetrics};

use crate::coordinator::replica::FinishedRequest;
use crate::util::stats::Samples;

/// Latency/throughput metrics for one pool (one fleet tier).
#[derive(Debug)]
pub struct PoolMetrics {
    pub name: String,
    pub ttft: Samples,
    pub e2e: Samples,
    pub queue: Samples,
    pub completed: u64,
    pub output_tokens: u64,
}

impl PoolMetrics {
    pub fn new(name: impl Into<String>) -> Self {
        PoolMetrics {
            name: name.into(),
            ttft: Samples::new(),
            e2e: Samples::new(),
            queue: Samples::new(),
            completed: 0,
            output_tokens: 0,
        }
    }

    pub fn record(&mut self, fin: &FinishedRequest) {
        self.ttft.push(fin.ttft_s);
        self.e2e.push(fin.e2e_s);
        self.queue.push(fin.queue_s);
        self.completed += 1;
        self.output_tokens += fin.output.len() as u64;
    }

    /// One summary line for reports.
    pub fn summary(&mut self) -> String {
        if self.completed == 0 {
            return format!("{}: no traffic", self.name);
        }
        format!(
            "{}: n={} ttft p50={:.1}ms p99={:.1}ms | e2e p50={:.1}ms | queue p99={:.1}ms | out_toks={}",
            self.name,
            self.completed,
            self.ttft.p50() * 1e3,
            self.ttft.p99() * 1e3,
            self.e2e.p50() * 1e3,
            self.queue.p99() * 1e3,
            self.output_tokens,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fin(ttft: f64) -> FinishedRequest {
        FinishedRequest {
            id: 0,
            output: vec![1, 2, 3],
            ttft_s: ttft,
            e2e_s: ttft + 0.1,
            queue_s: 0.01,
        }
    }

    #[test]
    fn records_accumulate() {
        let mut m = PoolMetrics::new("short");
        for i in 0..10 {
            m.record(&fin(0.01 * i as f64));
        }
        assert_eq!(m.completed, 10);
        assert_eq!(m.output_tokens, 30);
        assert!(m.ttft.p99() <= 0.09 + 1e-12);
        assert!(m.summary().contains("n=10"));
    }

    #[test]
    fn empty_summary_safe() {
        let mut m = PoolMetrics::new("long");
        assert_eq!(m.summary(), "long: no traffic");
    }
}
