//! Per-epoch control-loop metrics: what the autoscaler DES and the live
//! controller record every controller period, and what the CI smoke run
//! uploads as a JSON artifact.

/// One tier's measurements inside one controller epoch.
#[derive(Clone, Debug)]
pub struct EpochTierMetrics {
    /// GPUs (or replicas, on the live path) provisioned at epoch end —
    /// includes draining capacity, which still costs money.
    pub n_gpus: u64,
    /// Controller target after this epoch's replan (takes effect next
    /// epoch; scale-ups arrive after the provisioning delay).
    pub target_gpus: u64,
    /// Busy-slot-time over provisioned slot-time within the epoch.
    pub utilization: f64,
    /// P99 TTFT over requests whose first token landed in this epoch
    /// (0.0 when none did). Includes physical prefill time.
    pub ttft_p99_s: f64,
    /// P99 queue wait over requests admitted in this epoch (0.0 when
    /// none). This is the quantity the SLO check uses — sizing budgets
    /// queue wait, not prefill (see `planner::sizing`'s module note).
    pub wait_p99_s: f64,
    pub completed: u64,
    pub arrivals: u64,
    /// Requests admitted or queued on this tier, still unfinished at
    /// epoch end (in-flight carry-over, not lost).
    pub in_flight: u64,
}

/// One controller epoch of an autoscaled run.
#[derive(Clone, Debug)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub t_start_s: f64,
    pub t_end_s: f64,
    /// Sliding-window rate estimate at the epoch boundary, req/s.
    pub lambda_est: f64,
    /// Realized arrivals in the epoch divided by its duration, req/s.
    pub lambda_realized: f64,
    /// Provisioned GPU-time integrated over the epoch, hours.
    pub gpu_hours: f64,
    /// Epoch cost at the per-tier $/GPU-hr rates, dollars.
    pub cost: f64,
    /// Every tier with admissions met its queue-wait SLO budget this
    /// epoch (the sizing-consistent check; see [`EpochTierMetrics::wait_p99_s`]).
    pub slo_ok: bool,
    /// The replan at this epoch's boundary switched the tier layout.
    pub switched_layout: bool,
    pub tiers: Vec<EpochTierMetrics>,
}

fn num(x: f64) -> String {
    // JSON has no NaN/inf; clamp pathological values to 0 (they only
    // arise from zero-duration or zero-capacity denominators).
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "0.0".to_string()
    }
}

impl EpochMetrics {
    /// Total GPUs provisioned at epoch end, across tiers.
    pub fn total_gpus(&self) -> u64 {
        self.tiers.iter().map(|t| t.n_gpus).sum()
    }

    /// Serialize one epoch as a JSON object.
    pub fn to_json(&self) -> String {
        let tiers: Vec<String> = self
            .tiers
            .iter()
            .map(|t| {
                format!(
                    concat!(
                        "{{\"n_gpus\":{},\"target_gpus\":{},\"utilization\":{},",
                        "\"ttft_p99_s\":{},\"wait_p99_s\":{},\"completed\":{},",
                        "\"arrivals\":{},\"in_flight\":{}}}"
                    ),
                    t.n_gpus,
                    t.target_gpus,
                    num(t.utilization),
                    num(t.ttft_p99_s),
                    num(t.wait_p99_s),
                    t.completed,
                    t.arrivals,
                    t.in_flight,
                )
            })
            .collect();
        format!(
            concat!(
                "{{\"epoch\":{},\"t_start_s\":{},\"t_end_s\":{},\"lambda_est\":{},",
                "\"lambda_realized\":{},\"gpu_hours\":{},\"cost\":{},\"slo_ok\":{},",
                "\"switched_layout\":{},\"tiers\":[{}]}}"
            ),
            self.epoch,
            num(self.t_start_s),
            num(self.t_end_s),
            num(self.lambda_est),
            num(self.lambda_realized),
            num(self.gpu_hours),
            num(self.cost),
            self.slo_ok,
            self.switched_layout,
            tiers.join(","),
        )
    }

    /// Serialize a whole run as a JSON array (the CI artifact format).
    pub fn series_to_json(epochs: &[EpochMetrics]) -> String {
        let rows: Vec<String> = epochs.iter().map(|e| e.to_json()).collect();
        format!("[{}]", rows.join(","))
    }

    /// One human-readable summary line per epoch (CLI output).
    pub fn summary_line(&self) -> String {
        let gpus: Vec<String> = self.tiers.iter().map(|t| t.n_gpus.to_string()).collect();
        let utils: Vec<String> = self
            .tiers
            .iter()
            .map(|t| format!("{:.2}", t.utilization))
            .collect();
        let p99s: Vec<String> = self
            .tiers
            .iter()
            .map(|t| format!("{:.0}", t.ttft_p99_s * 1e3))
            .collect();
        format!(
            "epoch {:3} [{:7.1}s..{:7.1}s] lam est={:7.1} real={:7.1} gpus=[{}] util=[{}] ttft99ms=[{}] {}{}",
            self.epoch,
            self.t_start_s,
            self.t_end_s,
            self.lambda_est,
            self.lambda_realized,
            gpus.join(","),
            utils.join(","),
            p99s.join(","),
            if self.slo_ok { "slo-ok" } else { "SLO-VIOLATED" },
            if self.switched_layout { " switched" } else { "" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn sample() -> EpochMetrics {
        EpochMetrics {
            epoch: 3,
            t_start_s: 30.0,
            t_end_s: 40.0,
            lambda_est: 412.5,
            lambda_realized: 398.0,
            gpu_hours: 0.15,
            cost: 0.33,
            slo_ok: true,
            switched_layout: false,
            tiers: vec![
                EpochTierMetrics {
                    n_gpus: 12,
                    target_gpus: 11,
                    utilization: 0.81,
                    ttft_p99_s: 0.31,
                    wait_p99_s: 0.02,
                    completed: 3800,
                    arrivals: 3900,
                    in_flight: 40,
                },
                EpochTierMetrics {
                    n_gpus: 3,
                    target_gpus: 3,
                    utilization: 0.76,
                    ttft_p99_s: 0.42,
                    wait_p99_s: 0.05,
                    completed: 150,
                    arrivals: 160,
                    in_flight: 10,
                },
            ],
        }
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let e = sample();
        let j = Json::parse(&e.to_json()).expect("valid JSON");
        assert_eq!(j.get("epoch").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("slo_ok").and_then(Json::as_bool), Some(true));
        let tiers = j.get("tiers").and_then(Json::as_arr).unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].get("n_gpus").and_then(Json::as_f64), Some(12.0));

        let series = EpochMetrics::series_to_json(&[e.clone(), e]);
        let arr = Json::parse(&series).expect("valid series JSON");
        assert_eq!(arr.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn non_finite_values_serialize_as_zero() {
        let mut e = sample();
        e.tiers[0].utilization = f64::NAN;
        e.lambda_est = f64::INFINITY;
        assert!(Json::parse(&e.to_json()).is_ok());
    }

    #[test]
    fn summary_line_flags_violations() {
        let mut e = sample();
        assert!(e.summary_line().contains("slo-ok"));
        e.slo_ok = false;
        e.switched_layout = true;
        let s = e.summary_line();
        assert!(s.contains("SLO-VIOLATED") && s.contains("switched"));
        assert_eq!(e.total_gpus(), 15);
    }
}
