//! Per-epoch control-loop metrics: what the autoscaler DES and the live
//! controller record every controller period, and what the CI smoke run
//! uploads as a JSON artifact.

use crate::util::stats::{percentile_sorted, P2Quantile};

/// Observations kept verbatim so ordinary epochs read an *exact* P99.
/// The P² markers need hundreds of samples to adapt toward the 0.99 rank,
/// and epoch SLO flags (and the CI smoke's violation budget, tuned
/// against the exact metric) must not move on estimator error at normal
/// traffic — a controller epoch at a few hundred req/s holds a few
/// thousand samples. Only epochs beyond this head (the million-scale
/// regimes the DES overhaul targets) use the streaming estimate. 16 KB
/// per digest, allocated once and reused across epochs.
const EXACT_HEAD: usize = 2048;

/// Streaming per-epoch latency digest: an exact head buffer plus a P²
/// P99 estimator — what the autoscale DES keeps per tier instead of an
/// unbounded `Samples` buffer (§Perf: bounded memory per tier,
/// allocation-free across epoch resets). Epochs with <= [`EXACT_HEAD`]
/// observations report the exact sorted percentile (bit-identical to the
/// former `Samples` path); larger epochs report the P² estimate, whose
/// error against the exact sort is bounds-tested in
/// `tests/des_engine.rs`. Final-table percentiles elsewhere stay exact.
#[derive(Clone, Debug)]
pub struct EpochDigest {
    p99: P2Quantile,
    head: Vec<f64>,
}

impl Default for EpochDigest {
    fn default() -> Self {
        Self::new()
    }
}

impl EpochDigest {
    pub fn new() -> Self {
        EpochDigest {
            p99: P2Quantile::new(0.99),
            head: Vec::with_capacity(EXACT_HEAD),
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.head.len() < EXACT_HEAD {
            self.head.push(x);
        }
        self.p99.push(x);
    }

    pub fn count(&self) -> u64 {
        self.p99.count()
    }

    pub fn is_empty(&self) -> bool {
        self.p99.is_empty()
    }

    /// P99 over this epoch's observations: exact while the epoch holds at
    /// most [`EXACT_HEAD`] samples, the streaming P² estimate beyond
    /// (0.0 when empty). Sorts the head in place — no allocation.
    pub fn p99(&mut self) -> f64 {
        let n = self.p99.count() as usize;
        if n == 0 {
            return 0.0;
        }
        if n <= EXACT_HEAD {
            self.head.sort_by(f64::total_cmp);
            return percentile_sorted(&self.head, 0.99);
        }
        self.p99.value()
    }

    /// Clear for the next epoch, reusing markers and head capacity.
    pub fn reset(&mut self) {
        self.p99.reset();
        self.head.clear();
    }
}

/// One tier's measurements inside one controller epoch.
#[derive(Clone, Debug)]
pub struct EpochTierMetrics {
    /// GPUs (or replicas, on the live path) provisioned at epoch end —
    /// includes draining capacity, which still costs money.
    pub n_gpus: u64,
    /// Controller target after this epoch's replan (takes effect next
    /// epoch; scale-ups arrive after the provisioning delay).
    pub target_gpus: u64,
    /// Busy-slot-time over provisioned slot-time within the epoch.
    pub utilization: f64,
    /// P99 TTFT over requests whose first token landed in this epoch
    /// (0.0 when none did). Includes physical prefill time.
    pub ttft_p99_s: f64,
    /// P99 queue wait over requests admitted in this epoch (0.0 when
    /// none). This is the quantity the SLO check uses — sizing budgets
    /// queue wait, not prefill (see `planner::sizing`'s module note).
    pub wait_p99_s: f64,
    pub completed: u64,
    pub arrivals: u64,
    /// Requests admitted or queued on this tier, still unfinished at
    /// epoch end (in-flight carry-over, not lost).
    pub in_flight: u64,
}

/// One controller epoch of an autoscaled run.
#[derive(Clone, Debug)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub t_start_s: f64,
    pub t_end_s: f64,
    /// Sliding-window rate estimate at the epoch boundary, req/s.
    pub lambda_est: f64,
    /// Realized arrivals in the epoch divided by its duration, req/s.
    pub lambda_realized: f64,
    /// Provisioned GPU-time integrated over the epoch, hours.
    pub gpu_hours: f64,
    /// Epoch cost at the per-tier $/GPU-hr rates, dollars.
    pub cost: f64,
    /// Every tier with admissions met its queue-wait SLO budget this
    /// epoch (the sizing-consistent check; see [`EpochTierMetrics::wait_p99_s`]).
    pub slo_ok: bool,
    /// The replan at this epoch's boundary switched the tier layout.
    pub switched_layout: bool,
    pub tiers: Vec<EpochTierMetrics>,
}

fn num(x: f64) -> String {
    // JSON has no NaN/inf; clamp pathological values to 0 (they only
    // arise from zero-duration or zero-capacity denominators).
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "0.0".to_string()
    }
}

impl EpochMetrics {
    /// Total GPUs provisioned at epoch end, across tiers.
    pub fn total_gpus(&self) -> u64 {
        self.tiers.iter().map(|t| t.n_gpus).sum()
    }

    /// Serialize one epoch as a JSON object.
    pub fn to_json(&self) -> String {
        let tiers: Vec<String> = self
            .tiers
            .iter()
            .map(|t| {
                format!(
                    concat!(
                        "{{\"n_gpus\":{},\"target_gpus\":{},\"utilization\":{},",
                        "\"ttft_p99_s\":{},\"wait_p99_s\":{},\"completed\":{},",
                        "\"arrivals\":{},\"in_flight\":{}}}"
                    ),
                    t.n_gpus,
                    t.target_gpus,
                    num(t.utilization),
                    num(t.ttft_p99_s),
                    num(t.wait_p99_s),
                    t.completed,
                    t.arrivals,
                    t.in_flight,
                )
            })
            .collect();
        format!(
            concat!(
                "{{\"epoch\":{},\"t_start_s\":{},\"t_end_s\":{},\"lambda_est\":{},",
                "\"lambda_realized\":{},\"gpu_hours\":{},\"cost\":{},\"slo_ok\":{},",
                "\"switched_layout\":{},\"tiers\":[{}]}}"
            ),
            self.epoch,
            num(self.t_start_s),
            num(self.t_end_s),
            num(self.lambda_est),
            num(self.lambda_realized),
            num(self.gpu_hours),
            num(self.cost),
            self.slo_ok,
            self.switched_layout,
            tiers.join(","),
        )
    }

    /// Serialize a whole run as a JSON array (the CI artifact format).
    pub fn series_to_json(epochs: &[EpochMetrics]) -> String {
        let rows: Vec<String> = epochs.iter().map(|e| e.to_json()).collect();
        format!("[{}]", rows.join(","))
    }

    /// One human-readable summary line per epoch (CLI output).
    pub fn summary_line(&self) -> String {
        let gpus: Vec<String> = self.tiers.iter().map(|t| t.n_gpus.to_string()).collect();
        let utils: Vec<String> = self
            .tiers
            .iter()
            .map(|t| format!("{:.2}", t.utilization))
            .collect();
        let p99s: Vec<String> = self
            .tiers
            .iter()
            .map(|t| format!("{:.0}", t.ttft_p99_s * 1e3))
            .collect();
        format!(
            "epoch {:3} [{:7.1}s..{:7.1}s] lam est={:7.1} real={:7.1} gpus=[{}] util=[{}] ttft99ms=[{}] {}{}",
            self.epoch,
            self.t_start_s,
            self.t_end_s,
            self.lambda_est,
            self.lambda_realized,
            gpus.join(","),
            utils.join(","),
            p99s.join(","),
            if self.slo_ok { "slo-ok" } else { "SLO-VIOLATED" },
            if self.switched_layout { " switched" } else { "" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::util::stats::Samples;

    #[test]
    fn digest_head_epochs_are_exact() {
        // Up to the head size the digest must match `Samples` bitwise —
        // epoch SLO flags at ordinary traffic cannot ride on P² error.
        let mut d = EpochDigest::new();
        let mut s = Samples::new();
        assert_eq!(d.p99(), 0.0);
        let mut x = 0.37;
        for i in 0..EXACT_HEAD {
            x = (x * 997.0 + 0.123).fract() * 3.0;
            d.push(x);
            s.push(x);
            if i % 61 == 0 || i + 1 == EXACT_HEAD {
                assert_eq!(
                    d.p99().to_bits(),
                    s.clone().p99().to_bits(),
                    "diverged at n = {}",
                    i + 1
                );
            }
        }
        assert_eq!(d.count(), EXACT_HEAD as u64);
        // Past the head the digest switches to the P² estimate: still a
        // sane value inside the observed range.
        for _ in 0..20_000 {
            x = (x * 997.0 + 0.123).fract() * 3.0;
            d.push(x);
        }
        let est = d.p99();
        assert!(est > 0.0 && est <= 3.0, "p2 estimate {est}");
        d.reset();
        assert!(d.is_empty());
        assert_eq!(d.p99(), 0.0);
    }

    fn sample() -> EpochMetrics {
        EpochMetrics {
            epoch: 3,
            t_start_s: 30.0,
            t_end_s: 40.0,
            lambda_est: 412.5,
            lambda_realized: 398.0,
            gpu_hours: 0.15,
            cost: 0.33,
            slo_ok: true,
            switched_layout: false,
            tiers: vec![
                EpochTierMetrics {
                    n_gpus: 12,
                    target_gpus: 11,
                    utilization: 0.81,
                    ttft_p99_s: 0.31,
                    wait_p99_s: 0.02,
                    completed: 3800,
                    arrivals: 3900,
                    in_flight: 40,
                },
                EpochTierMetrics {
                    n_gpus: 3,
                    target_gpus: 3,
                    utilization: 0.76,
                    ttft_p99_s: 0.42,
                    wait_p99_s: 0.05,
                    completed: 150,
                    arrivals: 160,
                    in_flight: 10,
                },
            ],
        }
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let e = sample();
        let j = Json::parse(&e.to_json()).expect("valid JSON");
        assert_eq!(j.get("epoch").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("slo_ok").and_then(Json::as_bool), Some(true));
        let tiers = j.get("tiers").and_then(Json::as_arr).unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].get("n_gpus").and_then(Json::as_f64), Some(12.0));

        let series = EpochMetrics::series_to_json(&[e.clone(), e]);
        let arr = Json::parse(&series).expect("valid series JSON");
        assert_eq!(arr.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn non_finite_values_serialize_as_zero() {
        let mut e = sample();
        e.tiers[0].utilization = f64::NAN;
        e.lambda_est = f64::INFINITY;
        assert!(Json::parse(&e.to_json()).is_ok());
    }

    #[test]
    fn summary_line_flags_violations() {
        let mut e = sample();
        assert!(e.summary_line().contains("slo-ok"));
        e.slo_ok = false;
        e.switched_layout = true;
        let s = e.summary_line();
        assert!(s.contains("SLO-VIOLATED") && s.contains("switched"));
        assert_eq!(e.total_gpus(), 15);
    }
}
