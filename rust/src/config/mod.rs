//! Configuration layer: GPU profiles, SLO targets, planner settings.
//!
//! The same `GpuProfile` feeds the analytical model (§3), the planner (§6),
//! the DES (§7.4) and — scaled down — the live serving coordinator, so a
//! fleet prescribed by the planner is directly instantiable.

use crate::util::json::Json;

/// Hardware calibration for one GPU type (paper §7.1 "Simulation
/// parameters", calibrated to Llama-3-70B on an A100-80GB 8-GPU TP node).
#[derive(Clone, Debug, PartialEq)]
pub struct GpuProfile {
    /// Baseline per-iteration compute, W (ms). Paper: 8 ms.
    pub w_ms: f64,
    /// Per-slot memory-bandwidth cost, H (ms/slot). Paper: 0.65 ms.
    pub h_ms_per_slot: f64,
    /// Prefill chunk size C_chunk (tokens). Paper: 512.
    pub chunk: u32,
    /// KV-cache growth per token (KB). Paper: 320 KB (Llama-3-70B fp16).
    pub kv_kb_per_token: f64,
    /// Slot-count calibration: n_max(C) = n_max_calib * c_calib / C.
    /// Paper: 128 slots at 8,192 tokens (=> 256 at 4K, 682 at 1.5K, 16 at 64K).
    pub n_max_calib: u32,
    pub c_calib: u32,
    /// Long-pool context window C_max^(l) (tokens). Paper: 65,536.
    pub c_max_long: u32,
    /// GPU cost, $/GPU-hr. Paper: $2.21 for both pools (phi = 1).
    pub cost_short_hr: f64,
    pub cost_long_hr: f64,
}

/// One GPU SKU of a heterogeneous catalog (H100/A100/L40S-class): its own
/// slots-per-window calibration, a service-rate multiplier against the
/// base [`GpuProfile`] timing model, and on-demand/spot pricing.
#[derive(Clone, Debug, PartialEq)]
pub struct GpuSku {
    /// Display name ("a100", "h100-spot", ...). Unique within a catalog.
    pub name: String,
    /// Slots-per-window calibration at `GpuProfile::c_calib` tokens —
    /// this SKU's KV budget expressed in the shared calibration frame, so
    /// `n_max(C) = n_max_calib * c_calib / C` per SKU.
    pub n_max_calib: u32,
    /// Service-rate multiplier mu' = mu_scale * mu vs the base profile:
    /// every iteration runs `1/mu_scale` as long (> 1 = faster silicon).
    pub mu_scale: f64,
    /// On-demand price, $/GPU-hr.
    pub cost_hr: f64,
    /// Spot discount in [0, 1); applied only when `preemptible`.
    pub spot_discount: f64,
    /// Spot/preemptible capacity: priced at the discount, and flagged so
    /// reliability-aware layers can treat the tier as evictable.
    pub preemptible: bool,
}

impl GpuSku {
    /// The price the planner optimizes against: the spot discount applies
    /// iff the SKU is preemptible.
    pub fn effective_cost_hr(&self) -> f64 {
        if self.preemptible {
            self.cost_hr * (1.0 - self.spot_discount)
        } else {
            self.cost_hr
        }
    }
}

/// An ordered set of GPU SKUs a planner cell may assign per tier. The
/// single-SKU world is the catalog-of-one projection ([`SkuCatalog::single`]):
/// planning against it reproduces the plain [`GpuProfile`] plan exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct SkuCatalog {
    pub skus: Vec<GpuSku>,
}

impl SkuCatalog {
    pub fn len(&self) -> usize {
        self.skus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.skus.is_empty()
    }

    /// The catalog-of-one projection of a base profile: one SKU whose
    /// resolved tier values (slots, price, unit rate) are exactly the
    /// profile's own — the bit-identity anchor for the SKU generalization.
    /// A SKU carries one price, so the projection is exact when the
    /// profile prices both pools equally (`phi = 1`, as the paper's A100
    /// calibration does); a `cost_long_hr != cost_short_hr` profile has no
    /// single-SKU equivalent.
    pub fn single(gpu: &GpuProfile) -> SkuCatalog {
        SkuCatalog {
            skus: vec![GpuSku {
                name: "base".to_string(),
                n_max_calib: gpu.n_max_calib,
                mu_scale: 1.0,
                cost_hr: gpu.cost_short_hr,
                spot_discount: 0.0,
                preemptible: false,
            }],
        }
    }

    /// A three-SKU demo catalog around the paper's A100 calibration:
    /// the A100 itself, an H100-class SKU (more KV, faster, pricier) and
    /// a preemptible L40S-class SKU (less KV, slower, discounted). Shared
    /// by Table 10, the planner bench, the example config and the CLI
    /// docs so they all speak about the same fleet.
    pub fn demo(gpu: &GpuProfile) -> SkuCatalog {
        let mut c = SkuCatalog::single(gpu);
        c.skus[0].name = "a100".to_string();
        c.skus.push(GpuSku {
            name: "h100".to_string(),
            n_max_calib: 192,
            mu_scale: 1.7,
            cost_hr: 3.93,
            spot_discount: 0.0,
            preemptible: false,
        });
        c.skus.push(GpuSku {
            name: "l40s-spot".to_string(),
            n_max_calib: 48,
            mu_scale: 0.6,
            cost_hr: 1.9,
            spot_discount: 0.45,
            preemptible: true,
        });
        c
    }

    /// Reject malformed catalogs with messages that name the offending
    /// entry and index: non-positive prices or slot calibrations,
    /// non-positive/non-finite rate multipliers, out-of-range spot
    /// discounts, and duplicate names.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.skus.is_empty() {
            anyhow::bail!("SKU catalog is empty: at least one SKU is required");
        }
        for (i, s) in self.skus.iter().enumerate() {
            if s.name.is_empty() {
                anyhow::bail!("sku {i}: empty name");
            }
            if !s.cost_hr.is_finite() || s.cost_hr <= 0.0 {
                anyhow::bail!(
                    "sku {i} (\"{}\"): cost_hr must be positive, got {}",
                    s.name,
                    s.cost_hr
                );
            }
            if s.n_max_calib == 0 {
                anyhow::bail!(
                    "sku {i} (\"{}\"): n_max_calib must be a positive slot count",
                    s.name
                );
            }
            if !s.mu_scale.is_finite() || s.mu_scale <= 0.0 {
                anyhow::bail!(
                    "sku {i} (\"{}\"): mu_scale must be positive, got {}",
                    s.name,
                    s.mu_scale
                );
            }
            if !s.spot_discount.is_finite() || !(0.0..1.0).contains(&s.spot_discount) {
                anyhow::bail!(
                    "sku {i} (\"{}\"): spot_discount must be in [0, 1), got {}",
                    s.name,
                    s.spot_discount
                );
            }
            if let Some(j) = self.skus[..i].iter().position(|p| p.name == s.name) {
                anyhow::bail!(
                    "sku {i} (\"{}\") duplicates the name of sku {j}",
                    s.name
                );
            }
        }
        Ok(())
    }

    /// Parse from JSON: either `{"skus": [...]}` or a bare array. Each
    /// entry needs `name`, `n_max_calib` and `cost_hr`; `mu_scale`
    /// defaults to 1.0, `spot_discount` to 0.0, `preemptible` to false.
    pub fn from_json(j: &Json) -> anyhow::Result<SkuCatalog> {
        let arr = j
            .get("skus")
            .and_then(Json::as_arr)
            .or_else(|| j.as_arr())
            .ok_or_else(|| anyhow::anyhow!("SKU catalog must be `{{\"skus\": [...]}}` or a JSON array"))?;
        let mut skus = Vec::with_capacity(arr.len());
        for (i, s) in arr.iter().enumerate() {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("sku {i}: missing `name`"))?
                .to_string();
            let calib = s
                .get("n_max_calib")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("sku {i} (\"{name}\"): missing `n_max_calib`"))?;
            if !calib.is_finite() || calib < 1.0 || calib.fract() != 0.0 || calib > u32::MAX as f64
            {
                anyhow::bail!(
                    "sku {i} (\"{name}\"): n_max_calib must be a positive whole slot count, got {calib}"
                );
            }
            let cost_hr = s
                .get("cost_hr")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("sku {i} (\"{name}\"): missing `cost_hr`"))?;
            skus.push(GpuSku {
                name,
                n_max_calib: calib as u32,
                mu_scale: s.get("mu_scale").and_then(Json::as_f64).unwrap_or(1.0),
                cost_hr,
                spot_discount: s.get("spot_discount").and_then(Json::as_f64).unwrap_or(0.0),
                preemptible: s.get("preemptible").and_then(Json::as_bool).unwrap_or(false),
            });
        }
        let c = SkuCatalog { skus };
        c.validate()?;
        Ok(c)
    }

    /// Load and validate a catalog from a JSON file.
    pub fn from_file(path: &str) -> anyhow::Result<SkuCatalog> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading SKU catalog {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    }
}

/// A tier's resolved SKU choice. `TierSpec` is `Copy`, so the choice is
/// an index into the originating [`SkuCatalog`] plus the one SKU property
/// the sizing math needs beyond the already-resolved `n_max`/`cost_hr`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SkuChoice {
    /// Index into the originating catalog (display / round-trips).
    pub index: u16,
    /// The SKU's service-rate multiplier, resolved here so the planner
    /// never needs catalog access on the sizing path.
    pub mu_scale: f64,
    /// Spot-preemptible SKU: chaos runs draw preemption events against
    /// tiers running on it (resolved here so the DES never needs catalog
    /// access either).
    pub preemptible: bool,
}

/// One tier of a K-tier fleet: a context window, the KV-slot count that
/// window yields on this hardware, and the tier's GPU price.
///
/// The paper's two-pool fleet is the K = 2 special case: tier 0 is the
/// short pool (window `B_short`) and the last tier is the long pool
/// (window `C_max^(l)`). Boundaries are implicit: tier `i < K-1` serves
/// requests with `L_total <= c_max_i` that no lower tier claimed, and the
/// last tier serves everything else.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierSpec {
    /// Context window per slot, tokens. Doubles as the routing boundary
    /// for every tier but the last.
    pub c_max: u32,
    /// Concurrent KV slots per GPU at this window (`GpuProfile::n_max`).
    pub n_max: u32,
    /// GPU cost for this tier, $/GPU-hr.
    pub cost_hr: f64,
    /// Per-tier P99 TTFT SLO override, seconds. `None` inherits the
    /// fleet-level [`Slo`] — exactly the pre-refactor global-SLO
    /// behaviour, so configs without per-tier targets plan identically.
    pub p99_ttft_s: Option<f64>,
    /// Which catalog SKU this tier runs on. `None` is the base
    /// [`GpuProfile`] hardware — the single-SKU world, planned
    /// bit-identically to the pre-catalog code.
    pub sku: Option<SkuChoice>,
}

impl TierSpec {
    /// This tier's effective P99 TTFT target given the fleet default.
    pub fn slo_or(&self, fleet_default_s: f64) -> f64 {
        self.p99_ttft_s.unwrap_or(fleet_default_s)
    }

    /// This tier's service-rate multiplier vs the base profile (1.0 when
    /// no SKU is assigned).
    pub fn mu_scale(&self) -> f64 {
        self.sku.map(|s| s.mu_scale).unwrap_or(1.0)
    }

    /// The tier's catalog SKU index, if a SKU is assigned.
    pub fn sku_index(&self) -> Option<usize> {
        self.sku.map(|s| s.index as usize)
    }
}

/// An ordered K-tier fleet specification (windows strictly ascending; the
/// last tier is the full-context "long" tier). This is the shape every
/// layer — planner, DES, gateway, live coordinator — is generalized over;
/// `GpuProfile::fleet_spec(&[b_short])` reproduces the paper's two-pool
/// stack exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    pub tiers: Vec<TierSpec>,
}

impl FleetSpec {
    /// Number of tiers K.
    pub fn k(&self) -> usize {
        self.tiers.len()
    }

    /// The K-1 routing boundaries (every tier window except the last's).
    pub fn boundaries(&self) -> Vec<u32> {
        self.tiers[..self.tiers.len() - 1]
            .iter()
            .map(|t| t.c_max)
            .collect()
    }

    /// Validate ordering and slot monotonicity. Windows must be strictly
    /// ascending and every non-last tier must hold strictly more slots
    /// than the last (otherwise the tier buys nothing — the cost cliff
    /// that motivates routing would be absent).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.tiers.len() < 2 {
            anyhow::bail!("a fleet needs at least 2 tiers, got {}", self.tiers.len());
        }
        let last = self.tiers[self.tiers.len() - 1];
        for pair in self.tiers.windows(2) {
            if pair[1].c_max <= pair[0].c_max {
                anyhow::bail!(
                    "tier windows must be strictly ascending: {} then {}",
                    pair[0].c_max,
                    pair[1].c_max
                );
            }
        }
        for t in &self.tiers {
            if t.cost_hr <= 0.0 {
                anyhow::bail!("tier at {} tokens has non-positive cost", t.c_max);
            }
            if let Some(s) = t.p99_ttft_s {
                if !s.is_finite() || s <= 0.0 {
                    anyhow::bail!(
                        "tier at {} tokens has non-positive P99 TTFT SLO {s}",
                        t.c_max
                    );
                }
            }
            if let Some(s) = t.sku {
                if !s.mu_scale.is_finite() || s.mu_scale <= 0.0 {
                    anyhow::bail!(
                        "tier at {} tokens has non-positive SKU mu_scale {}",
                        t.c_max,
                        s.mu_scale
                    );
                }
            }
        }
        for t in &self.tiers[..self.tiers.len() - 1] {
            if t.n_max <= last.n_max {
                anyhow::bail!(
                    "tier at {} tokens has {} slots/GPU, not above the long tier's {}",
                    t.c_max,
                    t.n_max,
                    last.n_max
                );
            }
        }
        Ok(())
    }

    /// Parse from a JSON `tiers` value: either a plain array of windows
    /// (`[4096, 16384, 65536]`, priced/slotted from `gpu`) or an array of
    /// objects (`[{"c_max": 4096, "cost_hr": 1.8}, ...]`, missing keys
    /// derived from `gpu`).
    pub fn from_json(j: &Json, gpu: &GpuProfile) -> anyhow::Result<FleetSpec> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("`tiers` must be a JSON array"))?;
        if arr.len() < 2 {
            anyhow::bail!("`tiers` needs at least 2 entries");
        }
        // No silent `as u32` truncation: windows and slot counts must be
        // positive whole numbers or the config is rejected with a clear
        // message (a zero window would divide-by-zero inside `n_max`).
        let whole = |v: f64, what: &str| -> anyhow::Result<u32> {
            if !v.is_finite() || v < 1.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
                anyhow::bail!("{what} must be a positive whole number, got {v}");
            }
            Ok(v as u32)
        };
        let mut tiers = Vec::with_capacity(arr.len());
        for (i, t) in arr.iter().enumerate() {
            let last = i + 1 == arr.len();
            let default_cost = if last { gpu.cost_long_hr } else { gpu.cost_short_hr };
            let tier = if let Some(w) = t.as_f64() {
                let c_max = whole(w, &format!("tier {i} window"))?;
                TierSpec {
                    c_max,
                    n_max: gpu.n_max(c_max),
                    cost_hr: default_cost,
                    p99_ttft_s: None,
                    sku: None,
                }
            } else {
                let c_max = t
                    .get("c_max")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("tier {i} missing `c_max`"))?;
                let c_max = whole(c_max, &format!("tier {i} `c_max`"))?;
                TierSpec {
                    c_max,
                    n_max: match t.get("n_max").and_then(Json::as_f64) {
                        Some(n) => whole(n, &format!("tier {i} `n_max`"))?,
                        None => gpu.n_max(c_max),
                    },
                    cost_hr: t.get("cost_hr").and_then(Json::as_f64).unwrap_or(default_cost),
                    p99_ttft_s: t.get("p99_ttft_s").and_then(Json::as_f64),
                    sku: None,
                }
            };
            tiers.push(tier);
        }
        let spec = FleetSpec { tiers };
        spec.validate()?;
        Ok(spec)
    }
}

impl GpuProfile {
    /// The paper's A100-80GB / Llama-3-70B calibration.
    pub fn a100_llama70b() -> Self {
        GpuProfile {
            w_ms: 8.0,
            h_ms_per_slot: 0.65,
            chunk: 512,
            kv_kb_per_token: 320.0,
            n_max_calib: 128,
            c_calib: 8192,
            c_max_long: 65_536,
            cost_short_hr: 2.21,
            cost_long_hr: 2.21,
        }
    }

    /// Concurrent KV slots per GPU for a context window of `c_max` tokens
    /// (§2.2): the KV budget is fixed, so slots scale inversely with the
    /// per-slot context size.
    pub fn n_max(&self, c_max: u32) -> u32 {
        ((self.n_max_calib as u64 * self.c_calib as u64) / c_max as u64).max(1) as u32
    }

    /// Slots per GPU in the long pool.
    pub fn n_max_long(&self) -> u32 {
        self.n_max(self.c_max_long)
    }

    /// Build a K-tier [`FleetSpec`] from K-1 ascending boundaries: one
    /// tier per boundary (window = boundary, slots from the KV budget,
    /// priced at `cost_short_hr`) plus the full-context long tier at
    /// `cost_long_hr`. `fleet_spec(&[b_short])` is the paper's two-pool
    /// fleet verbatim.
    pub fn fleet_spec(&self, boundaries: &[u32]) -> FleetSpec {
        let mut tiers: Vec<TierSpec> = boundaries
            .iter()
            .map(|&b| TierSpec {
                c_max: b,
                n_max: self.n_max(b),
                cost_hr: self.cost_short_hr,
                p99_ttft_s: None,
                sku: None,
            })
            .collect();
        tiers.push(TierSpec {
            c_max: self.c_max_long,
            n_max: self.n_max_long(),
            cost_hr: self.cost_long_hr,
            p99_ttft_s: None,
            sku: None,
        });
        FleetSpec { tiers }
    }

    /// Slots per GPU at window `c_max` for a SKU calibrated to
    /// `n_max_calib` slots at the shared `c_calib` window — the per-SKU
    /// generalization of [`GpuProfile::n_max`] (identical for the base
    /// calibration, by the same integer arithmetic).
    pub fn n_max_with(&self, c_max: u32, n_max_calib: u32) -> u32 {
        ((n_max_calib as u64 * self.c_calib as u64) / c_max as u64).max(1) as u32
    }

    /// The profile with every iteration `1/mu_scale` as long — the DES's
    /// view of a SKU's service-rate multiplier. `mu_scale = 1` returns the
    /// profile unchanged (bit-identical single-SKU timing).
    pub fn scaled_mu(&self, mu_scale: f64) -> GpuProfile {
        if mu_scale == 1.0 {
            return self.clone();
        }
        GpuProfile {
            w_ms: self.w_ms / mu_scale,
            h_ms_per_slot: self.h_ms_per_slot / mu_scale,
            ..self.clone()
        }
    }

    /// Build a K-tier [`FleetSpec`] with a per-tier SKU assignment:
    /// `assignment[i]` indexes `catalog.skus`, one entry per tier
    /// (boundaries plus the long tier). Slots come from each SKU's own
    /// `n_max_calib`, prices from its effective (spot-discounted) rate,
    /// and the choice is recorded on the tier. Assigning the
    /// [`SkuCatalog::single`] base SKU everywhere resolves to exactly the
    /// values of [`GpuProfile::fleet_spec`] (tested).
    pub fn fleet_spec_skus(
        &self,
        boundaries: &[u32],
        catalog: &SkuCatalog,
        assignment: &[usize],
    ) -> FleetSpec {
        assert_eq!(
            assignment.len(),
            boundaries.len() + 1,
            "one SKU per tier (K-1 boundaries + the long tier)"
        );
        let tier = |c_max: u32, sku_idx: usize| -> TierSpec {
            let sku = &catalog.skus[sku_idx];
            TierSpec {
                c_max,
                n_max: self.n_max_with(c_max, sku.n_max_calib),
                cost_hr: sku.effective_cost_hr(),
                p99_ttft_s: None,
                sku: Some(SkuChoice {
                    index: sku_idx as u16,
                    mu_scale: sku.mu_scale,
                    preemptible: sku.preemptible,
                }),
            }
        };
        let mut tiers: Vec<TierSpec> = boundaries
            .iter()
            .zip(assignment)
            .map(|(&b, &s)| tier(b, s))
            .collect();
        tiers.push(tier(self.c_max_long, assignment[boundaries.len()]));
        FleetSpec { tiers }
    }

    /// The cost-cliff ratio rho = n_max^(s) / n_max^(l) at a short-pool
    /// boundary of `b_short` tokens (§2.2): 8x at 8K, 16x at 4K, 42x at 1.5K.
    pub fn cliff_ratio(&self, b_short: u32) -> f64 {
        self.n_max(b_short) as f64 / self.n_max_long() as f64
    }

    /// GPU iteration latency under continuous batching (Eq. 3), seconds.
    /// All `n_slots` slots advance in lockstep per iteration.
    pub fn t_iter_s(&self, n_slots: u32) -> f64 {
        (self.w_ms + self.h_ms_per_slot * n_slots as f64) / 1000.0
    }

    /// KV memory per slot (GB) for a context window of `c_max` tokens.
    pub fn kv_gb_per_slot(&self, c_max: u32) -> f64 {
        c_max as f64 * self.kv_kb_per_token / 1024.0 / 1024.0
    }

    /// GPU cost ratio phi = c_l / c_s (§3.3).
    pub fn phi(&self) -> f64 {
        self.cost_long_hr / self.cost_short_hr
    }

    /// Parse a profile from a JSON config object; missing keys fall back to
    /// the A100/Llama-3-70B defaults.
    pub fn from_json(j: &Json) -> Self {
        let d = GpuProfile::a100_llama70b();
        let f = |k: &str, def: f64| j.get(k).and_then(Json::as_f64).unwrap_or(def);
        GpuProfile {
            w_ms: f("w_ms", d.w_ms),
            h_ms_per_slot: f("h_ms_per_slot", d.h_ms_per_slot),
            chunk: f("chunk", d.chunk as f64) as u32,
            kv_kb_per_token: f("kv_kb_per_token", d.kv_kb_per_token),
            n_max_calib: f("n_max_calib", d.n_max_calib as f64) as u32,
            c_calib: f("c_calib", d.c_calib as f64) as u32,
            c_max_long: f("c_max_long", d.c_max_long as f64) as u32,
            cost_short_hr: f("cost_short_hr", d.cost_short_hr),
            cost_long_hr: f("cost_long_hr", d.cost_long_hr),
        }
    }
}

/// Service-level objective (§3.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slo {
    /// P99 TTFT target, seconds. Paper: 0.5 s.
    pub p99_ttft_s: f64,
}

impl Default for Slo {
    fn default() -> Self {
        Slo { p99_ttft_s: 0.5 }
    }
}

/// How the planner calibrates per-cell service stats (§Perf).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CellStatsMode {
    /// Midpoint quadrature over the restricted quantile function — the
    /// default and the bit-compatibility anchor: every pre-refactor plan
    /// is reproduced exactly (`tests/tier_equivalence.rs`).
    #[default]
    Quadrature,
    /// O(log n) moment-table lookups
    /// ([`crate::queueing::service::MomentTable`]): the exact integerized
    /// moments the quadrature converges to. Within the table's proven
    /// error bound of the quadrature (tolerance-tested), but not
    /// bit-identical — opt-in for latency-critical callers; the exact
    /// sweep gets its speed from bound-and-prune instead.
    MomentTable,
}

/// Planner settings (§4.1, §6).
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Utilization cap rho_max for analytical stability. Paper: 0.85.
    pub rho_max: f64,
    /// Gamma sweep grid. Paper: {1.0, 1.1, ..., 2.0}.
    pub gammas: Vec<f64>,
    /// Monte-Carlo samples for (E[S], C_s^2) calibration.
    pub mc_samples: usize,
    /// Seed for the calibration sampler (determinism).
    pub seed: u64,
    /// Per-cell calibration path (quadrature default; see [`CellStatsMode`]).
    pub cell_stats: CellStatsMode,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            rho_max: 0.85,
            gammas: (0..=10).map(|i| 1.0 + i as f64 * 0.1).collect(),
            mc_samples: 20_000,
            seed: 0xF1EE7,
            cell_stats: CellStatsMode::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_slot_counts() {
        let g = GpuProfile::a100_llama70b();
        // Paper §7.1: 256 slots at 4K, 682 at 1.5K, 128 at 8K, 16 at 64K.
        assert_eq!(g.n_max(4096), 256);
        assert_eq!(g.n_max(1536), 682);
        assert_eq!(g.n_max(8192), 128);
        assert_eq!(g.n_max_long(), 16);
    }

    #[test]
    fn paper_cliff_ratios() {
        let g = GpuProfile::a100_llama70b();
        // Paper §2.2: 8x at 8,192; 16x at 4,096; ~42x at 1,536.
        assert_eq!(g.cliff_ratio(8192), 8.0);
        assert_eq!(g.cliff_ratio(4096), 16.0);
        assert!((g.cliff_ratio(1536) - 42.625).abs() < 0.01);
    }

    #[test]
    fn t_iter_matches_paper() {
        let g = GpuProfile::a100_llama70b();
        // W + H*16 = 8 + 10.4 = 18.4 ms for the long pool.
        assert!((g.t_iter_s(16) - 0.0184).abs() < 1e-9);
    }

    #[test]
    fn kv_gb_per_slot_long_pool() {
        let g = GpuProfile::a100_llama70b();
        // Paper Table 1: ~20.0 GB per 64K slot at 320 KB/token.
        let gb = g.kv_gb_per_slot(65_536);
        assert!((gb - 20.0).abs() < 0.01, "gb={gb}");
    }

    #[test]
    fn gamma_grid_matches_paper() {
        let c = PlannerConfig::default();
        assert_eq!(c.gammas.len(), 11);
        assert!((c.gammas[0] - 1.0).abs() < 1e-12);
        assert!((c.gammas[10] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn from_json_defaults_and_overrides() {
        let j = Json::parse(r#"{"w_ms": 10.0}"#).unwrap();
        let g = GpuProfile::from_json(&j);
        assert_eq!(g.w_ms, 10.0);
        assert_eq!(g.chunk, 512);
    }

    #[test]
    fn two_tier_spec_matches_paper_pools() {
        let g = GpuProfile::a100_llama70b();
        let spec = g.fleet_spec(&[4096]);
        assert_eq!(spec.k(), 2);
        assert_eq!(spec.boundaries(), vec![4096]);
        assert_eq!(spec.tiers[0].n_max, 256);
        assert_eq!(spec.tiers[1].c_max, 65_536);
        assert_eq!(spec.tiers[1].n_max, 16);
        assert_eq!(spec.tiers[0].cost_hr, g.cost_short_hr);
        assert_eq!(spec.tiers[1].cost_hr, g.cost_long_hr);
        spec.validate().unwrap();
    }

    #[test]
    fn k_tier_spec_slots_descend() {
        let g = GpuProfile::a100_llama70b();
        let spec = g.fleet_spec(&[4096, 16_384]);
        assert_eq!(spec.k(), 3);
        assert_eq!(spec.tiers[1].n_max, 64);
        spec.validate().unwrap();
        // Windows must stay ascending.
        let bad = g.fleet_spec(&[16_384, 4096]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn fleet_spec_from_json_windows_and_objects() {
        let g = GpuProfile::a100_llama70b();
        let j = Json::parse("[4096, 16384, 65536]").unwrap();
        let spec = FleetSpec::from_json(&j, &g).unwrap();
        assert_eq!(spec.k(), 3);
        assert_eq!(spec.tiers[0].n_max, 256);
        let j = Json::parse(r#"[{"c_max": 4096, "cost_hr": 1.5}, {"c_max": 65536}]"#).unwrap();
        let spec = FleetSpec::from_json(&j, &g).unwrap();
        assert_eq!(spec.tiers[0].cost_hr, 1.5);
        assert_eq!(spec.tiers[1].cost_hr, g.cost_long_hr);
        assert!(FleetSpec::from_json(&Json::parse("[4096]").unwrap(), &g).is_err());
    }

    #[test]
    fn fleet_spec_per_tier_slo_parses_and_defaults() {
        let g = GpuProfile::a100_llama70b();
        let j = Json::parse(
            r#"[{"c_max": 4096, "p99_ttft_s": 0.2}, {"c_max": 65536}]"#,
        )
        .unwrap();
        let spec = FleetSpec::from_json(&j, &g).unwrap();
        assert_eq!(spec.tiers[0].p99_ttft_s, Some(0.2));
        assert_eq!(spec.tiers[1].p99_ttft_s, None);
        assert_eq!(spec.tiers[0].slo_or(0.5), 0.2);
        assert_eq!(spec.tiers[1].slo_or(0.5), 0.5);
        // Plain window arrays inherit the fleet default everywhere.
        let spec = g.fleet_spec(&[4096]);
        assert!(spec.tiers.iter().all(|t| t.p99_ttft_s.is_none()));
        // Non-positive per-tier SLOs are rejected.
        let j = Json::parse(
            r#"[{"c_max": 4096, "p99_ttft_s": -0.1}, {"c_max": 65536}]"#,
        )
        .unwrap();
        assert!(FleetSpec::from_json(&j, &g).is_err());
    }

    #[test]
    fn sku_catalog_of_one_projects_bit_identically() {
        // The SKU generalization's bit-identity anchor: the base SKU
        // assigned everywhere resolves to exactly the plain fleet spec's
        // slots, prices and unit rate.
        let g = GpuProfile::a100_llama70b();
        let catalog = SkuCatalog::single(&g);
        catalog.validate().unwrap();
        for bounds in [&[4096u32][..], &[2048, 8192][..]] {
            let plain = g.fleet_spec(bounds);
            let skued = g.fleet_spec_skus(bounds, &catalog, &vec![0; bounds.len() + 1]);
            assert_eq!(plain.k(), skued.k());
            for (a, b) in plain.tiers.iter().zip(&skued.tiers) {
                assert_eq!(a.c_max, b.c_max);
                assert_eq!(a.n_max, b.n_max);
                assert_eq!(a.cost_hr.to_bits(), b.cost_hr.to_bits());
                assert_eq!(b.mu_scale().to_bits(), 1.0f64.to_bits());
                assert_eq!(b.sku_index(), Some(0));
            }
        }
    }

    #[test]
    fn sku_catalog_validation_names_entry_and_index() {
        let g = GpuProfile::a100_llama70b();
        let mut dup = SkuCatalog::demo(&g);
        dup.skus[2].name = "h100".to_string();
        let err = dup.validate().unwrap_err().to_string();
        assert!(err.contains("sku 2") && err.contains("h100") && err.contains("sku 1"), "{err}");

        let mut free = SkuCatalog::demo(&g);
        free.skus[1].cost_hr = 0.0;
        let err = free.validate().unwrap_err().to_string();
        assert!(err.contains("sku 1") && err.contains("cost_hr"), "{err}");

        let mut slotless = SkuCatalog::demo(&g);
        slotless.skus[0].n_max_calib = 0;
        let err = slotless.validate().unwrap_err().to_string();
        assert!(err.contains("sku 0") && err.contains("n_max_calib"), "{err}");

        let mut frozen = SkuCatalog::demo(&g);
        frozen.skus[1].mu_scale = -0.5;
        assert!(frozen.validate().unwrap_err().to_string().contains("mu_scale"));

        let mut deep = SkuCatalog::demo(&g);
        deep.skus[2].spot_discount = 1.0;
        assert!(deep.validate().unwrap_err().to_string().contains("spot_discount"));

        SkuCatalog::demo(&g).validate().unwrap();
    }

    #[test]
    fn sku_catalog_json_parses_defaults_and_spot() {
        let j = Json::parse(
            r#"{"skus": [
                {"name": "a100", "n_max_calib": 128, "cost_hr": 2.21},
                {"name": "h100", "n_max_calib": 192, "mu_scale": 1.7, "cost_hr": 3.93},
                {"name": "l40s-spot", "n_max_calib": 48, "mu_scale": 0.6, "cost_hr": 1.9,
                 "spot_discount": 0.45, "preemptible": true}
            ]}"#,
        )
        .unwrap();
        let c = SkuCatalog::from_json(&j).unwrap();
        assert_eq!(c.len(), 3);
        assert_eq!(c.skus[0].mu_scale, 1.0);
        assert!(!c.skus[0].preemptible);
        assert_eq!(c.skus[2].effective_cost_hr(), 1.9 * 0.55);
        // On-demand SKUs ignore any stray discount.
        assert_eq!(c.skus[1].effective_cost_hr(), 3.93);
        // A bare array parses too.
        let j = Json::parse(r#"[{"name": "x", "n_max_calib": 64, "cost_hr": 1.0}]"#).unwrap();
        assert_eq!(SkuCatalog::from_json(&j).unwrap().len(), 1);
        // Fractional slot calibrations are rejected with the entry named.
        let j = Json::parse(r#"[{"name": "x", "n_max_calib": 64.5, "cost_hr": 1.0}]"#).unwrap();
        let err = SkuCatalog::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("sku 0") && err.contains("\"x\""), "{err}");
    }

    #[test]
    fn scaled_mu_profile_is_identity_at_one() {
        let g = GpuProfile::a100_llama70b();
        let same = g.scaled_mu(1.0);
        assert_eq!(same, g);
        let fast = g.scaled_mu(2.0);
        assert_eq!(fast.w_ms, 4.0);
        assert!((fast.t_iter_s(16) - g.t_iter_s(16) / 2.0).abs() < 1e-15);
        // Slots are a KV property, not a speed property.
        assert_eq!(fast.n_max(4096), g.n_max(4096));
    }

    #[test]
    fn fleet_spec_from_json_rejects_bad_windows() {
        let g = GpuProfile::a100_llama70b();
        for bad in ["[0, 65536]", "[-4096, 65536]", "[4096.7, 65536]"] {
            let j = Json::parse(bad).unwrap();
            assert!(FleetSpec::from_json(&j, &g).is_err(), "{bad} must be rejected");
        }
        let j = Json::parse(r#"[{"c_max": 4096, "cost_hr": -1.0}, {"c_max": 65536}]"#).unwrap();
        assert!(FleetSpec::from_json(&j, &g).is_err(), "negative cost must be rejected");
    }
}
