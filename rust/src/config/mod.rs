//! Configuration layer: GPU profiles, SLO targets, planner settings.
//!
//! The same `GpuProfile` feeds the analytical model (§3), the planner (§6),
//! the DES (§7.4) and — scaled down — the live serving coordinator, so a
//! fleet prescribed by the planner is directly instantiable.

use crate::util::json::Json;

/// Hardware calibration for one GPU type (paper §7.1 "Simulation
/// parameters", calibrated to Llama-3-70B on an A100-80GB 8-GPU TP node).
#[derive(Clone, Debug, PartialEq)]
pub struct GpuProfile {
    /// Baseline per-iteration compute, W (ms). Paper: 8 ms.
    pub w_ms: f64,
    /// Per-slot memory-bandwidth cost, H (ms/slot). Paper: 0.65 ms.
    pub h_ms_per_slot: f64,
    /// Prefill chunk size C_chunk (tokens). Paper: 512.
    pub chunk: u32,
    /// KV-cache growth per token (KB). Paper: 320 KB (Llama-3-70B fp16).
    pub kv_kb_per_token: f64,
    /// Slot-count calibration: n_max(C) = n_max_calib * c_calib / C.
    /// Paper: 128 slots at 8,192 tokens (=> 256 at 4K, 682 at 1.5K, 16 at 64K).
    pub n_max_calib: u32,
    pub c_calib: u32,
    /// Long-pool context window C_max^(l) (tokens). Paper: 65,536.
    pub c_max_long: u32,
    /// GPU cost, $/GPU-hr. Paper: $2.21 for both pools (phi = 1).
    pub cost_short_hr: f64,
    pub cost_long_hr: f64,
}

/// One tier of a K-tier fleet: a context window, the KV-slot count that
/// window yields on this hardware, and the tier's GPU price.
///
/// The paper's two-pool fleet is the K = 2 special case: tier 0 is the
/// short pool (window `B_short`) and the last tier is the long pool
/// (window `C_max^(l)`). Boundaries are implicit: tier `i < K-1` serves
/// requests with `L_total <= c_max_i` that no lower tier claimed, and the
/// last tier serves everything else.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierSpec {
    /// Context window per slot, tokens. Doubles as the routing boundary
    /// for every tier but the last.
    pub c_max: u32,
    /// Concurrent KV slots per GPU at this window (`GpuProfile::n_max`).
    pub n_max: u32,
    /// GPU cost for this tier, $/GPU-hr.
    pub cost_hr: f64,
    /// Per-tier P99 TTFT SLO override, seconds. `None` inherits the
    /// fleet-level [`Slo`] — exactly the pre-refactor global-SLO
    /// behaviour, so configs without per-tier targets plan identically.
    pub p99_ttft_s: Option<f64>,
}

impl TierSpec {
    /// This tier's effective P99 TTFT target given the fleet default.
    pub fn slo_or(&self, fleet_default_s: f64) -> f64 {
        self.p99_ttft_s.unwrap_or(fleet_default_s)
    }
}

/// An ordered K-tier fleet specification (windows strictly ascending; the
/// last tier is the full-context "long" tier). This is the shape every
/// layer — planner, DES, gateway, live coordinator — is generalized over;
/// `GpuProfile::fleet_spec(&[b_short])` reproduces the paper's two-pool
/// stack exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    pub tiers: Vec<TierSpec>,
}

impl FleetSpec {
    /// Number of tiers K.
    pub fn k(&self) -> usize {
        self.tiers.len()
    }

    /// The K-1 routing boundaries (every tier window except the last's).
    pub fn boundaries(&self) -> Vec<u32> {
        self.tiers[..self.tiers.len() - 1]
            .iter()
            .map(|t| t.c_max)
            .collect()
    }

    /// Validate ordering and slot monotonicity. Windows must be strictly
    /// ascending and every non-last tier must hold strictly more slots
    /// than the last (otherwise the tier buys nothing — the cost cliff
    /// that motivates routing would be absent).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.tiers.len() < 2 {
            anyhow::bail!("a fleet needs at least 2 tiers, got {}", self.tiers.len());
        }
        let last = self.tiers[self.tiers.len() - 1];
        for pair in self.tiers.windows(2) {
            if pair[1].c_max <= pair[0].c_max {
                anyhow::bail!(
                    "tier windows must be strictly ascending: {} then {}",
                    pair[0].c_max,
                    pair[1].c_max
                );
            }
        }
        for t in &self.tiers {
            if t.cost_hr <= 0.0 {
                anyhow::bail!("tier at {} tokens has non-positive cost", t.c_max);
            }
            if let Some(s) = t.p99_ttft_s {
                if !s.is_finite() || s <= 0.0 {
                    anyhow::bail!(
                        "tier at {} tokens has non-positive P99 TTFT SLO {s}",
                        t.c_max
                    );
                }
            }
        }
        for t in &self.tiers[..self.tiers.len() - 1] {
            if t.n_max <= last.n_max {
                anyhow::bail!(
                    "tier at {} tokens has {} slots/GPU, not above the long tier's {}",
                    t.c_max,
                    t.n_max,
                    last.n_max
                );
            }
        }
        Ok(())
    }

    /// Parse from a JSON `tiers` value: either a plain array of windows
    /// (`[4096, 16384, 65536]`, priced/slotted from `gpu`) or an array of
    /// objects (`[{"c_max": 4096, "cost_hr": 1.8}, ...]`, missing keys
    /// derived from `gpu`).
    pub fn from_json(j: &Json, gpu: &GpuProfile) -> anyhow::Result<FleetSpec> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("`tiers` must be a JSON array"))?;
        if arr.len() < 2 {
            anyhow::bail!("`tiers` needs at least 2 entries");
        }
        // No silent `as u32` truncation: windows and slot counts must be
        // positive whole numbers or the config is rejected with a clear
        // message (a zero window would divide-by-zero inside `n_max`).
        let whole = |v: f64, what: &str| -> anyhow::Result<u32> {
            if !v.is_finite() || v < 1.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
                anyhow::bail!("{what} must be a positive whole number, got {v}");
            }
            Ok(v as u32)
        };
        let mut tiers = Vec::with_capacity(arr.len());
        for (i, t) in arr.iter().enumerate() {
            let last = i + 1 == arr.len();
            let default_cost = if last { gpu.cost_long_hr } else { gpu.cost_short_hr };
            let tier = if let Some(w) = t.as_f64() {
                let c_max = whole(w, &format!("tier {i} window"))?;
                TierSpec {
                    c_max,
                    n_max: gpu.n_max(c_max),
                    cost_hr: default_cost,
                    p99_ttft_s: None,
                }
            } else {
                let c_max = t
                    .get("c_max")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("tier {i} missing `c_max`"))?;
                let c_max = whole(c_max, &format!("tier {i} `c_max`"))?;
                TierSpec {
                    c_max,
                    n_max: match t.get("n_max").and_then(Json::as_f64) {
                        Some(n) => whole(n, &format!("tier {i} `n_max`"))?,
                        None => gpu.n_max(c_max),
                    },
                    cost_hr: t.get("cost_hr").and_then(Json::as_f64).unwrap_or(default_cost),
                    p99_ttft_s: t.get("p99_ttft_s").and_then(Json::as_f64),
                }
            };
            tiers.push(tier);
        }
        let spec = FleetSpec { tiers };
        spec.validate()?;
        Ok(spec)
    }
}

impl GpuProfile {
    /// The paper's A100-80GB / Llama-3-70B calibration.
    pub fn a100_llama70b() -> Self {
        GpuProfile {
            w_ms: 8.0,
            h_ms_per_slot: 0.65,
            chunk: 512,
            kv_kb_per_token: 320.0,
            n_max_calib: 128,
            c_calib: 8192,
            c_max_long: 65_536,
            cost_short_hr: 2.21,
            cost_long_hr: 2.21,
        }
    }

    /// Concurrent KV slots per GPU for a context window of `c_max` tokens
    /// (§2.2): the KV budget is fixed, so slots scale inversely with the
    /// per-slot context size.
    pub fn n_max(&self, c_max: u32) -> u32 {
        ((self.n_max_calib as u64 * self.c_calib as u64) / c_max as u64).max(1) as u32
    }

    /// Slots per GPU in the long pool.
    pub fn n_max_long(&self) -> u32 {
        self.n_max(self.c_max_long)
    }

    /// Build a K-tier [`FleetSpec`] from K-1 ascending boundaries: one
    /// tier per boundary (window = boundary, slots from the KV budget,
    /// priced at `cost_short_hr`) plus the full-context long tier at
    /// `cost_long_hr`. `fleet_spec(&[b_short])` is the paper's two-pool
    /// fleet verbatim.
    pub fn fleet_spec(&self, boundaries: &[u32]) -> FleetSpec {
        let mut tiers: Vec<TierSpec> = boundaries
            .iter()
            .map(|&b| TierSpec {
                c_max: b,
                n_max: self.n_max(b),
                cost_hr: self.cost_short_hr,
                p99_ttft_s: None,
            })
            .collect();
        tiers.push(TierSpec {
            c_max: self.c_max_long,
            n_max: self.n_max_long(),
            cost_hr: self.cost_long_hr,
            p99_ttft_s: None,
        });
        FleetSpec { tiers }
    }

    /// The cost-cliff ratio rho = n_max^(s) / n_max^(l) at a short-pool
    /// boundary of `b_short` tokens (§2.2): 8x at 8K, 16x at 4K, 42x at 1.5K.
    pub fn cliff_ratio(&self, b_short: u32) -> f64 {
        self.n_max(b_short) as f64 / self.n_max_long() as f64
    }

    /// GPU iteration latency under continuous batching (Eq. 3), seconds.
    /// All `n_slots` slots advance in lockstep per iteration.
    pub fn t_iter_s(&self, n_slots: u32) -> f64 {
        (self.w_ms + self.h_ms_per_slot * n_slots as f64) / 1000.0
    }

    /// KV memory per slot (GB) for a context window of `c_max` tokens.
    pub fn kv_gb_per_slot(&self, c_max: u32) -> f64 {
        c_max as f64 * self.kv_kb_per_token / 1024.0 / 1024.0
    }

    /// GPU cost ratio phi = c_l / c_s (§3.3).
    pub fn phi(&self) -> f64 {
        self.cost_long_hr / self.cost_short_hr
    }

    /// Parse a profile from a JSON config object; missing keys fall back to
    /// the A100/Llama-3-70B defaults.
    pub fn from_json(j: &Json) -> Self {
        let d = GpuProfile::a100_llama70b();
        let f = |k: &str, def: f64| j.get(k).and_then(Json::as_f64).unwrap_or(def);
        GpuProfile {
            w_ms: f("w_ms", d.w_ms),
            h_ms_per_slot: f("h_ms_per_slot", d.h_ms_per_slot),
            chunk: f("chunk", d.chunk as f64) as u32,
            kv_kb_per_token: f("kv_kb_per_token", d.kv_kb_per_token),
            n_max_calib: f("n_max_calib", d.n_max_calib as f64) as u32,
            c_calib: f("c_calib", d.c_calib as f64) as u32,
            c_max_long: f("c_max_long", d.c_max_long as f64) as u32,
            cost_short_hr: f("cost_short_hr", d.cost_short_hr),
            cost_long_hr: f("cost_long_hr", d.cost_long_hr),
        }
    }
}

/// Service-level objective (§3.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slo {
    /// P99 TTFT target, seconds. Paper: 0.5 s.
    pub p99_ttft_s: f64,
}

impl Default for Slo {
    fn default() -> Self {
        Slo { p99_ttft_s: 0.5 }
    }
}

/// How the planner calibrates per-cell service stats (§Perf).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CellStatsMode {
    /// Midpoint quadrature over the restricted quantile function — the
    /// default and the bit-compatibility anchor: every pre-refactor plan
    /// is reproduced exactly (`tests/tier_equivalence.rs`).
    #[default]
    Quadrature,
    /// O(log n) moment-table lookups
    /// ([`crate::queueing::service::MomentTable`]): the exact integerized
    /// moments the quadrature converges to. Within the table's proven
    /// error bound of the quadrature (tolerance-tested), but not
    /// bit-identical — opt-in for latency-critical callers; the exact
    /// sweep gets its speed from bound-and-prune instead.
    MomentTable,
}

/// Planner settings (§4.1, §6).
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Utilization cap rho_max for analytical stability. Paper: 0.85.
    pub rho_max: f64,
    /// Gamma sweep grid. Paper: {1.0, 1.1, ..., 2.0}.
    pub gammas: Vec<f64>,
    /// Monte-Carlo samples for (E[S], C_s^2) calibration.
    pub mc_samples: usize,
    /// Seed for the calibration sampler (determinism).
    pub seed: u64,
    /// Per-cell calibration path (quadrature default; see [`CellStatsMode`]).
    pub cell_stats: CellStatsMode,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            rho_max: 0.85,
            gammas: (0..=10).map(|i| 1.0 + i as f64 * 0.1).collect(),
            mc_samples: 20_000,
            seed: 0xF1EE7,
            cell_stats: CellStatsMode::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_slot_counts() {
        let g = GpuProfile::a100_llama70b();
        // Paper §7.1: 256 slots at 4K, 682 at 1.5K, 128 at 8K, 16 at 64K.
        assert_eq!(g.n_max(4096), 256);
        assert_eq!(g.n_max(1536), 682);
        assert_eq!(g.n_max(8192), 128);
        assert_eq!(g.n_max_long(), 16);
    }

    #[test]
    fn paper_cliff_ratios() {
        let g = GpuProfile::a100_llama70b();
        // Paper §2.2: 8x at 8,192; 16x at 4,096; ~42x at 1,536.
        assert_eq!(g.cliff_ratio(8192), 8.0);
        assert_eq!(g.cliff_ratio(4096), 16.0);
        assert!((g.cliff_ratio(1536) - 42.625).abs() < 0.01);
    }

    #[test]
    fn t_iter_matches_paper() {
        let g = GpuProfile::a100_llama70b();
        // W + H*16 = 8 + 10.4 = 18.4 ms for the long pool.
        assert!((g.t_iter_s(16) - 0.0184).abs() < 1e-9);
    }

    #[test]
    fn kv_gb_per_slot_long_pool() {
        let g = GpuProfile::a100_llama70b();
        // Paper Table 1: ~20.0 GB per 64K slot at 320 KB/token.
        let gb = g.kv_gb_per_slot(65_536);
        assert!((gb - 20.0).abs() < 0.01, "gb={gb}");
    }

    #[test]
    fn gamma_grid_matches_paper() {
        let c = PlannerConfig::default();
        assert_eq!(c.gammas.len(), 11);
        assert!((c.gammas[0] - 1.0).abs() < 1e-12);
        assert!((c.gammas[10] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn from_json_defaults_and_overrides() {
        let j = Json::parse(r#"{"w_ms": 10.0}"#).unwrap();
        let g = GpuProfile::from_json(&j);
        assert_eq!(g.w_ms, 10.0);
        assert_eq!(g.chunk, 512);
    }

    #[test]
    fn two_tier_spec_matches_paper_pools() {
        let g = GpuProfile::a100_llama70b();
        let spec = g.fleet_spec(&[4096]);
        assert_eq!(spec.k(), 2);
        assert_eq!(spec.boundaries(), vec![4096]);
        assert_eq!(spec.tiers[0].n_max, 256);
        assert_eq!(spec.tiers[1].c_max, 65_536);
        assert_eq!(spec.tiers[1].n_max, 16);
        assert_eq!(spec.tiers[0].cost_hr, g.cost_short_hr);
        assert_eq!(spec.tiers[1].cost_hr, g.cost_long_hr);
        spec.validate().unwrap();
    }

    #[test]
    fn k_tier_spec_slots_descend() {
        let g = GpuProfile::a100_llama70b();
        let spec = g.fleet_spec(&[4096, 16_384]);
        assert_eq!(spec.k(), 3);
        assert_eq!(spec.tiers[1].n_max, 64);
        spec.validate().unwrap();
        // Windows must stay ascending.
        let bad = g.fleet_spec(&[16_384, 4096]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn fleet_spec_from_json_windows_and_objects() {
        let g = GpuProfile::a100_llama70b();
        let j = Json::parse("[4096, 16384, 65536]").unwrap();
        let spec = FleetSpec::from_json(&j, &g).unwrap();
        assert_eq!(spec.k(), 3);
        assert_eq!(spec.tiers[0].n_max, 256);
        let j = Json::parse(r#"[{"c_max": 4096, "cost_hr": 1.5}, {"c_max": 65536}]"#).unwrap();
        let spec = FleetSpec::from_json(&j, &g).unwrap();
        assert_eq!(spec.tiers[0].cost_hr, 1.5);
        assert_eq!(spec.tiers[1].cost_hr, g.cost_long_hr);
        assert!(FleetSpec::from_json(&Json::parse("[4096]").unwrap(), &g).is_err());
    }

    #[test]
    fn fleet_spec_per_tier_slo_parses_and_defaults() {
        let g = GpuProfile::a100_llama70b();
        let j = Json::parse(
            r#"[{"c_max": 4096, "p99_ttft_s": 0.2}, {"c_max": 65536}]"#,
        )
        .unwrap();
        let spec = FleetSpec::from_json(&j, &g).unwrap();
        assert_eq!(spec.tiers[0].p99_ttft_s, Some(0.2));
        assert_eq!(spec.tiers[1].p99_ttft_s, None);
        assert_eq!(spec.tiers[0].slo_or(0.5), 0.2);
        assert_eq!(spec.tiers[1].slo_or(0.5), 0.5);
        // Plain window arrays inherit the fleet default everywhere.
        let spec = g.fleet_spec(&[4096]);
        assert!(spec.tiers.iter().all(|t| t.p99_ttft_s.is_none()));
        // Non-positive per-tier SLOs are rejected.
        let j = Json::parse(
            r#"[{"c_max": 4096, "p99_ttft_s": -0.1}, {"c_max": 65536}]"#,
        )
        .unwrap();
        assert!(FleetSpec::from_json(&j, &g).is_err());
    }

    #[test]
    fn fleet_spec_from_json_rejects_bad_windows() {
        let g = GpuProfile::a100_llama70b();
        for bad in ["[0, 65536]", "[-4096, 65536]", "[4096.7, 65536]"] {
            let j = Json::parse(bad).unwrap();
            assert!(FleetSpec::from_json(&j, &g).is_err(), "{bad} must be rejected");
        }
        let j = Json::parse(r#"[{"c_max": 4096, "cost_hr": -1.0}, {"c_max": 65536}]"#).unwrap();
        assert!(FleetSpec::from_json(&j, &g).is_err(), "negative cost must be rejected");
    }
}
