//! KV-cache memory accounting and the cost cliff (paper §2.2, Table 1).
//!
//! The cliff is the structural discontinuity pool routing creates at
//! `B_short`: a request one token above the boundary is assigned a long-pool
//! slot provisioned for the full `C_max^(l)` window, consuming
//! `rho = n_max^(s)/n_max^(l)` times the throughput capacity of a short-pool
//! request while using only a sliver of its KV allocation.

use crate::config::GpuProfile;

/// Which pool a request occupies (given a boundary `B_short`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pool {
    Short,
    Long,
}

/// One row of the cost-cliff accounting (Table 1).
#[derive(Clone, Debug)]
pub struct CliffRow {
    pub l_total: u32,
    pub pool: Pool,
    pub slots_per_gpu: u32,
    /// Fraction of the allocated slot's KV budget actually used.
    pub kv_utilized: f64,
    /// KV bytes actually used, GB.
    pub kv_used_gb: f64,
    /// Throughput capacity consumed relative to a short-pool request
    /// (1.0 below the boundary, rho above it).
    pub cost_ratio: f64,
}

/// Compute the Table-1 row for a request of `l_total` tokens at boundary
/// `b_short`.
pub fn cliff_row(g: &GpuProfile, b_short: u32, l_total: u32) -> CliffRow {
    let pool = if l_total <= b_short {
        Pool::Short
    } else {
        Pool::Long
    };
    let (slots, window) = match pool {
        Pool::Short => (g.n_max(b_short), b_short),
        Pool::Long => (g.n_max_long(), g.c_max_long),
    };
    let kv_utilized = l_total as f64 / window as f64;
    CliffRow {
        l_total,
        pool,
        slots_per_gpu: slots,
        kv_utilized,
        kv_used_gb: g.kv_gb_per_slot(window) * kv_utilized,
        cost_ratio: match pool {
            Pool::Short => 1.0,
            Pool::Long => g.cliff_ratio(b_short),
        },
    }
}

/// The GPU savings formula for pool routing (§2.2, from Chen et al. 2026b):
/// `alpha * (1 - 1/rho)` where `alpha` is the short-pool traffic fraction.
pub fn pool_routing_savings(alpha: f64, rho: f64) -> f64 {
    alpha * (1.0 - 1.0 / rho)
}

/// Incremental savings of C&R beyond pool routing (Eq. 14):
/// `beta * p_c * (1 - 1/rho)`.
pub fn cr_incremental_savings(beta: f64, p_c: f64, rho: f64) -> f64 {
    beta * p_c * (1.0 - 1.0 / rho)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuProfile;

    fn g() -> GpuProfile {
        GpuProfile::a100_llama70b()
    }

    #[test]
    fn table1_row_at_boundary() {
        // Paper Table 1, L_total = 8,192: short pool, 128 slots, 100% of a
        // 2.5 GB slot, cost ratio 1.0.
        let r = cliff_row(&g(), 8192, 8192);
        assert_eq!(r.pool, Pool::Short);
        assert_eq!(r.slots_per_gpu, 128);
        assert!((r.kv_utilized - 1.0).abs() < 1e-12);
        assert!((r.kv_used_gb - 2.5).abs() < 0.01);
        assert_eq!(r.cost_ratio, 1.0);
    }

    #[test]
    fn table1_row_one_token_over() {
        // L_total = 8,193: long pool, 16 slots, 12.5% of 20 GB, 8x cost.
        let r = cliff_row(&g(), 8192, 8193);
        assert_eq!(r.pool, Pool::Long);
        assert_eq!(r.slots_per_gpu, 16);
        assert!((r.kv_utilized - 0.125).abs() < 1e-3, "{}", r.kv_utilized);
        assert_eq!(r.cost_ratio, 8.0);
    }

    #[test]
    fn table1_row_midband() {
        // L_total = 12,000: 18.3% of 20 GB, still 8x.
        let r = cliff_row(&g(), 8192, 12_000);
        assert!((r.kv_utilized - 0.1831).abs() < 1e-3);
        assert_eq!(r.cost_ratio, 8.0);
    }

    #[test]
    fn table1_row_full_window() {
        let r = cliff_row(&g(), 8192, 65_536);
        assert!((r.kv_utilized - 1.0).abs() < 1e-12);
        assert!((r.kv_used_gb - 20.0).abs() < 0.01);
    }

    #[test]
    fn savings_formula_matches_prior_work_range() {
        // Chen et al. 2026b report 16-38% for pool routing; alpha=0.9 and
        // rho=16 gives ~84% of alpha.
        let s = pool_routing_savings(0.898, 16.0);
        assert!((s - 0.8419).abs() < 1e-3);
        // rho -> 1 collapses savings to zero.
        assert!(pool_routing_savings(0.9, 1.0).abs() < 1e-12);
    }

    #[test]
    fn cr_savings_scales_with_beta_pc_rho() {
        let s = cr_incremental_savings(0.078, 1.0, 16.0);
        assert!((s - 0.0731).abs() < 1e-3);
        assert!(cr_incremental_savings(0.078, 0.0, 16.0).abs() < 1e-12);
        assert!(
            cr_incremental_savings(0.112, 0.75, 8.0) < cr_incremental_savings(0.112, 1.0, 8.0)
        );
    }
}
