//! Engine-level models of the serving hardware: KV-cache memory accounting
//! and the cost cliff (paper §2.2, Table 1).

pub mod kv;
