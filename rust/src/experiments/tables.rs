//! Table generators for the paper's evaluation (§7, Tables 1–7) plus the
//! K-tier extension study (Table 8), the online-autoscaling study
//! (Table 9): static worst-case plan vs per-epoch oracle vs the online
//! control loop on diurnal/burst variants of all three traces, and the
//! heterogeneous-fleet study (Table 10): single-SKU vs mixed-SKU fleet
//! cost under the anytime planner, DES-validated like Table 5.

use std::time::Instant;

use crate::compress::corpus;
use crate::compress::extractive::compress;
use crate::compress::fidelity;
use crate::compress::tokenizer::count_tokens;
use crate::config::{FleetSpec, GpuProfile, SkuCatalog};
use crate::fleetsim::autoscale::{
    simulate_autoscale, simulate_autoscale_chaos, AutoscaleConfig, AutoscaleReport, ChaosOpts,
};
use crate::fleetsim::faults::{FaultPlan, ReplicaFaults, TierOutage};
use crate::fleetsim::fleet::{simulate_fleet_tiered, FleetSimResult};
use crate::router::failover::FailoverConfig;
use crate::fleetsim::sim::{simulate_pool, SimConfig, SimRequest};
use crate::queueing::kv::{calibrate_kv_quadrature, lambda_star, rho_kv};
use crate::model::kv::cliff_row;
use crate::planner::{
    anytime_search, plan_fleet, plan_homogeneous, plan_spec_sweep_gamma,
    plan_spec_sweep_gamma_cached, sweep_gamma, sweep_tiered, sweep_tiered_pruned, AnytimeConfig,
    CalibCache, Deadline, Plan, PlanInput,
};
use crate::util::par::{par_map_each, thread_cap};
use crate::util::rng::Rng;
use crate::util::stats::Samples;
use crate::util::table::{fmt_int, fmt_pct, Table};
use crate::workload::archetype;
use crate::workload::arrivals::RateModel;
use crate::workload::traces::{self, Workload};

// ---------------------------------------------------------------------------
// Table 1: the cost cliff
// ---------------------------------------------------------------------------

/// Paper Table 1: throughput capacity consumed around B_short = 8,192 for
/// Llama-3-70B / A100-80GB.
pub fn table1() -> Table {
    let g = GpuProfile::a100_llama70b();
    let b = 8192;
    let mut t = Table::new(
        "Table 1 — the cost cliff at B_short = 8,192 (Llama-3-70B, A100-80GB)",
        &["L_total", "Pool", "Slots/GPU", "KV utilised", "Cost ratio"],
    );
    for l in [8192u32, 8193, 12_000, 65_536] {
        let r = cliff_row(&g, b, l);
        t.row(&[
            fmt_int(l as f64),
            format!("{:?}", r.pool),
            r.slots_per_gpu.to_string(),
            format!("{:.1}% ({:.1} GB/slot)", r.kv_utilized * 100.0, r.kv_used_gb),
            format!("{:.1}x", r.cost_ratio),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 2: borderline fractions
// ---------------------------------------------------------------------------

/// Paper Table 2: alpha, beta, cliff ratio, archetype per workload.
pub fn table2() -> Table {
    let g = GpuProfile::a100_llama70b();
    let mut t = Table::new(
        "Table 2 — borderline fraction beta at representative thresholds",
        &["Workload", "B_short", "alpha", "gamma", "beta", "Cliff rho", "Archetype"],
    );
    for w in traces::all() {
        let arch = archetype::classify(&w.cdf, w.b_short, w.gamma);
        t.row(&[
            w.name.to_string(),
            fmt_int(w.b_short as f64),
            format!("{:.3}", w.alpha()),
            format!("{:.1}", w.gamma),
            format!("{:.3}", w.beta()),
            format!("{:.0}x", g.cliff_ratio(w.b_short)),
            arch.name().to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 3: fleet GPU savings
// ---------------------------------------------------------------------------

/// One Table-3 row set for a workload.
pub struct Table3Rows {
    pub workload: &'static str,
    pub homo: Plan,
    pub pr: Plan,
    pub retrofit: Plan,
    pub fleetopt: Plan,
}

/// Compute the Table-3 plans for one workload at `lambda` req/s.
pub fn table3_rows(w: &Workload, lambda: f64) -> Table3Rows {
    let input = PlanInput::new(w.clone(), lambda);
    Table3Rows {
        workload: w.name,
        homo: plan_homogeneous(&input).expect("homogeneous plan"),
        pr: plan_fleet(&input, w.b_short, 1.0).expect("PR plan"),
        retrofit: plan_fleet(&input, w.b_short, 1.5).expect("retrofit plan"),
        fleetopt: sweep_gamma(&input, w.b_short).expect("fleetopt plan"),
    }
}

/// Paper Table 3: fleet GPU counts and annualized cost at 1,000 req/s.
pub fn table3(lambda: f64) -> Table {
    let mut t = Table::new(
        &format!("Table 3 — fleet GPU counts and annualized cost at lambda = {lambda} req/s"),
        &["Workload", "Method", "n_s", "n_l", "Total", "Ann. cost (K$)", "Savings"],
    );
    for w in traces::all() {
        let rows = table3_rows(&w, lambda);
        let base = rows.homo.cost_yr;
        let mut push = |method: String, p: &Plan| {
            t.row(&[
                w.name.to_string(),
                method,
                p.short.n_gpus.to_string(),
                p.long.n_gpus.to_string(),
                fmt_int(p.total_gpus() as f64),
                fmt_int(p.cost_yr / 1000.0),
                if p.cost_yr == base {
                    "-".into()
                } else {
                    fmt_pct(1.0 - p.cost_yr / base)
                },
            ]);
        };
        push("Homogeneous".into(), &rows.homo);
        push("Pool routing (PR)".into(), &rows.pr);
        push("PR + C&R (g=1.5)".into(), &rows.retrofit);
        push(
            format!("FleetOpt (g*={:.1})", rows.fleetopt.gamma),
            &rows.fleetopt,
        );
    }
    t
}

// ---------------------------------------------------------------------------
// Table 4: compressor latency
// ---------------------------------------------------------------------------

/// Latency profile of the extractive compressor on one workload's
/// borderline band.
pub struct CompressLatency {
    pub workload: &'static str,
    pub beta: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// beta-weighted mean overhead across all requests, ms.
    pub overhead_ms: f64,
    pub docs: usize,
}

/// Measure compressor latency on `n_docs` borderline documents.
pub fn table4_measure(w: &Workload, n_docs: usize, seed: u64) -> CompressLatency {
    let mut rng = Rng::new(seed);
    let mut lat = Samples::with_capacity(n_docs);
    for _ in 0..n_docs {
        let doc = corpus::generate_borderline_for(w, &mut rng);
        let l_out = w.output.sample_l_out(count_tokens(&doc) as f64, &mut rng);
        let budget = w.b_short.saturating_sub(l_out).max(64);
        let t0 = Instant::now();
        let c = compress(&doc, budget);
        lat.push(t0.elapsed().as_secs_f64() * 1e3);
        std::hint::black_box(c.ok);
    }
    let mean: f64 = lat.values().iter().sum::<f64>() / lat.len() as f64;
    CompressLatency {
        workload: w.name,
        beta: w.beta(),
        p50_ms: lat.p50(),
        p95_ms: lat.p95(),
        p99_ms: lat.p99(),
        overhead_ms: w.beta() * mean,
        docs: n_docs,
    }
}

/// Paper Table 4: end-to-end compressor latency per workload.
pub fn table4(n_docs: usize) -> Table {
    let mut t = Table::new(
        "Table 4 — end-to-end compressor latency (ms, this CPU)",
        &["Workload", "B_short", "beta", "p50", "p95", "p99", "Overhead/req"],
    );
    for (i, w) in traces::all().iter().enumerate() {
        let m = table4_measure(w, n_docs, 0x7AB4 + i as u64);
        t.row(&[
            w.name.to_string(),
            fmt_int(w.b_short as f64),
            format!("{:.3}", m.beta),
            format!("{:.1} ms", m.p50_ms),
            format!("{:.1} ms", m.p95_ms),
            format!("{:.1} ms", m.p99_ms),
            format!("{:.2} ms", m.overhead_ms),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 5: analytical vs DES utilization
// ---------------------------------------------------------------------------

/// One pool's analytical-vs-DES comparison.
pub struct DesValidation {
    pub workload: &'static str,
    pub pool: &'static str,
    pub n_gpus: u64,
    pub rho_ana: f64,
    pub rho_des: f64,
    /// (ana - des)/des, the paper's "Error" column.
    pub error: f64,
    pub ttft_p99_ana: f64,
    pub ttft_p99_des: f64,
}

/// Run the Table-5 validation for one workload's PR (gamma = 1) fleet with
/// ~`n_per_pool` DES requests per pool.
pub fn table5_validate(
    w: &Workload,
    lambda: f64,
    n_per_pool: usize,
    seed: u64,
) -> (Vec<DesValidation>, FleetSimResult) {
    let input = PlanInput::new(w.clone(), lambda);
    let plan = plan_fleet(&input, w.b_short, 1.0).expect("PR plan");
    // Scale total samples so (a) the smaller pool still sees ~n_per_pool
    // and (b) the horizon covers the 3x-E[S] warm-up plus >= 7 further mean
    // occupancies of the slowest pool (steady-state measurement).
    let minority = (1.0 - plan.alpha).min(plan.alpha).max(0.02);
    let e_s_max = plan
        .short
        .svc
        .iter()
        .chain(plan.long.svc.iter())
        .map(|s| s.e_s)
        .fold(0.0f64, f64::max);
    let n_for_horizon = (lambda * 10.0 * e_s_max).ceil() as usize;
    let n_total = ((n_per_pool as f64 / minority).ceil() as usize)
        .max(n_for_horizon)
        .min(n_per_pool * 40);
    let g = input.gpu.clone();
    let sim = crate::fleetsim::fleet::simulate_fleet(w, &plan, &g, lambda, n_total, seed);
    let mut out = Vec::new();
    if let Some(s) = &sim.short {
        let mut ttft = s.ttft.clone();
        out.push(DesValidation {
            workload: w.name,
            pool: "short",
            n_gpus: plan.short.n_gpus,
            rho_ana: plan.short.rho_ana(),
            rho_des: s.utilization,
            error: (plan.short.rho_ana() - s.utilization) / s.utilization,
            ttft_p99_ana: plan.short.ttft_p99(),
            ttft_p99_des: ttft.p99(),
        });
    }
    if let Some(l) = &sim.long {
        let mut ttft = l.ttft.clone();
        out.push(DesValidation {
            workload: w.name,
            pool: "long",
            n_gpus: plan.long.n_gpus,
            rho_ana: plan.long.rho_ana(),
            rho_des: l.utilization,
            error: (plan.long.rho_ana() - l.utilization) / l.utilization,
            ttft_p99_ana: plan.long.ttft_p99(),
            ttft_p99_des: ttft.p99(),
        });
    }
    (out, sim)
}

/// Table-5 validation across independent DES replications (distinct
/// seeds), one scoped worker per replication (§Perf: replication wall time
/// is the per-seed maximum instead of the sum). Each entry is bit-identical
/// to a sequential `table5_validate` call with the same seed.
pub fn table5_validate_replicated(
    w: &Workload,
    lambda: f64,
    n_per_pool: usize,
    seeds: &[u64],
) -> Vec<(Vec<DesValidation>, FleetSimResult)> {
    if seeds.len() <= 1 {
        return seeds
            .iter()
            .map(|&s| table5_validate(w, lambda, n_per_pool, s))
            .collect();
    }
    par_map_each(seeds, |&seed| table5_validate(w, lambda, n_per_pool, seed))
}

/// Paper Table 5: analytical vs DES GPU utilization (PR fleet, gamma = 1).
pub fn table5(lambda: f64, n_per_pool: usize) -> Table {
    let mut t = Table::new(
        &format!("Table 5 — analytical vs DES utilization at lambda = {lambda} req/s (PR fleet)"),
        &["Workload", "Pool", "n GPUs", "rho_ana", "rho_des", "Error", "TTFT99 ana", "TTFT99 des"],
    );
    for (i, w) in traces::all().iter().enumerate() {
        let (rows, _) = table5_validate(w, lambda, n_per_pool, 0x7AB5 + i as u64);
        for r in rows {
            t.row(&[
                r.workload.to_string(),
                r.pool.to_string(),
                r.n_gpus.to_string(),
                format!("{:.3}", r.rho_ana),
                format!("{:.3}", r.rho_des),
                format!("{:+.1}%", r.error * 100.0),
                format!("{:.0} ms", r.ttft_p99_ana * 1e3),
                format!("{:.0} ms", r.ttft_p99_des * 1e3),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Table 6: arrival-rate sensitivity
// ---------------------------------------------------------------------------

/// Paper Table 6: fleet size and savings vs arrival rate (Agent-heavy).
pub fn table6(lambdas: &[f64]) -> Table {
    let w = traces::agent_heavy();
    let mut t = Table::new(
        "Table 6 — fleet size and savings vs arrival rate (Agent-heavy, B = 8,192)",
        &["lambda (req/s)", "Homo", "PR", "FleetOpt (g*)", "PR saving", "FleetOpt saving"],
    );
    for &lambda in lambdas {
        let input = PlanInput::new(w.clone(), lambda);
        let homo = plan_homogeneous(&input).unwrap();
        let pr = plan_fleet(&input, w.b_short, 1.0).unwrap();
        let opt = sweep_gamma(&input, w.b_short).unwrap();
        t.row(&[
            fmt_int(lambda),
            fmt_int(homo.total_gpus() as f64),
            fmt_int(pr.total_gpus() as f64),
            format!("{} (g*={:.1})", fmt_int(opt.total_gpus() as f64), opt.gamma),
            fmt_pct(1.0 - pr.cost_yr / homo.cost_yr),
            fmt_pct(1.0 - opt.cost_yr / homo.cost_yr),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 7: compression fidelity
// ---------------------------------------------------------------------------

/// Fidelity study results (paper Appendix C / Table 7).
pub struct FidelityStudy {
    pub n_prompts: usize,
    pub p_c: f64,
    pub rouge: Samples,
    pub tfidf_cos: Samples,
    pub reduction: Samples,
    /// Embedding cosine (BERTScore substitute) when the runtime is present.
    pub embed_cos: Option<Samples>,
}

/// Run the fidelity study on `n` borderline prompts at the Agent-heavy
/// configuration (B = 8,192, gamma = 1.5, band 8K–12K).
pub fn table7_study(n: usize, seed: u64, artifacts_dir: Option<&std::path::Path>) -> FidelityStudy {
    let w = traces::agent_heavy();
    let rt = artifacts_dir.and_then(|d| crate::runtime::ModelRuntime::load(d).ok());
    let mut rng = Rng::new(seed);
    let mut rouge = Samples::with_capacity(n);
    let mut tfidf_cos = Samples::with_capacity(n);
    let mut reduction = Samples::with_capacity(n);
    let mut embed_cos = rt.as_ref().map(|_| Samples::with_capacity(n));
    let mut ok = 0usize;
    for _ in 0..n {
        let doc = corpus::generate_borderline_for(&w, &mut rng);
        let l_out = w.output.sample_l_out(count_tokens(&doc) as f64, &mut rng);
        let budget = w.b_short.saturating_sub(l_out).max(64);
        let c = compress(&doc, budget);
        if !c.ok {
            continue;
        }
        ok += 1;
        let f = fidelity::measure(&doc, &c.text);
        rouge.push(f.rouge_l_recall);
        tfidf_cos.push(f.tfidf_cosine);
        reduction.push(f.token_reduction);
        if let (Some(rt), Some(ec)) = (&rt, embed_cos.as_mut()) {
            let ea = rt.embed_text(&doc).unwrap();
            let eb = rt.embed_text(&c.text).unwrap();
            ec.push(crate::runtime::cosine(&ea, &eb));
        }
    }
    FidelityStudy {
        n_prompts: n,
        p_c: ok as f64 / n as f64,
        rouge,
        tfidf_cos,
        reduction,
        embed_cos,
    }
}

/// Paper Table 7: fidelity metrics (mean / p10 / p50 / p90).
pub fn table7(n: usize, artifacts_dir: Option<&std::path::Path>) -> Table {
    let mut s = table7_study(n, 0x7AB7, artifacts_dir);
    let mut t = Table::new(
        &format!("Table 7 — compression fidelity on {n} borderline prompts (B=8,192, g=1.5)"),
        &["Metric", "Mean", "p10", "p50", "p90"],
    );
    t.row(&[
        "p_c (compressibility)".into(),
        format!("{:.2}", s.p_c),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    let stat_row = |name: &str, s: &mut Samples| {
        let mean = s.values().iter().sum::<f64>() / s.len().max(1) as f64;
        [
            name.to_string(),
            format!("{mean:.3}"),
            format!("{:.3}", s.percentile(0.10)),
            format!("{:.3}", s.percentile(0.50)),
            format!("{:.3}", s.percentile(0.90)),
        ]
    };
    let r = stat_row("ROUGE-L recall", &mut s.rouge);
    t.row(&r);
    let r = stat_row("TF-IDF cosine", &mut s.tfidf_cos);
    t.row(&r);
    if let Some(ec) = s.embed_cos.as_mut() {
        let r = stat_row("Embedding cosine (BERTScore proxy)", ec);
        t.row(&r);
    }
    let r = stat_row("Token reduction", &mut s.reduction);
    t.row(&r);
    t
}

// ---------------------------------------------------------------------------
// Table 8: K-tier fleets
// ---------------------------------------------------------------------------

/// One Table-8 row: the cost-optimal K-tier fleet for a workload.
pub struct Table8Row {
    pub workload: &'static str,
    /// Fleet size K (1 = homogeneous, 2 = the paper's two pools).
    pub k: usize,
    /// The K−1 optimal boundaries (empty for homogeneous).
    pub boundaries: Vec<u32>,
    /// The swept shared compression bandwidth gamma* (per-boundary values
    /// may be clamped below it; this is the unclamped grid value).
    pub gamma: f64,
    /// GPUs per tier, in tier order.
    pub gpus: Vec<u64>,
    pub cost_yr: f64,
    /// Wall time of the K-tier sweep, ms (0 for homogeneous).
    pub sweep_ms: f64,
}

impl Table8Row {
    pub fn total_gpus(&self) -> u64 {
        self.gpus.iter().sum()
    }
}

/// Compute the Table-8 rows for one workload: homogeneous, then the full
/// boundary-combination sweep for each K in `2..=max_k`.
pub fn table8_rows(w: &Workload, lambda: f64, max_k: usize) -> Vec<Table8Row> {
    let input = PlanInput::new(w.clone(), lambda);
    let homo = plan_homogeneous(&input).expect("homogeneous plan");
    let mut rows = vec![Table8Row {
        workload: w.name,
        k: 1,
        boundaries: Vec::new(),
        gamma: 1.0,
        gpus: vec![homo.long.n_gpus],
        cost_yr: homo.cost_yr,
        sweep_ms: 0.0,
    }];
    for k in 2..=max_k {
        let t0 = Instant::now();
        let (best, _) = sweep_tiered(&input, k).expect("K-tier sweep");
        let sweep_ms = t0.elapsed().as_secs_f64() * 1e3;
        rows.push(Table8Row {
            workload: w.name,
            k,
            boundaries: best.boundaries(),
            gamma: best.gammas.last().copied().unwrap_or(1.0),
            gpus: best.gpu_counts(),
            cost_yr: best.cost_yr,
            sweep_ms,
        });
    }
    rows
}

/// Table 8 — K-tier fleets: does a third (fourth) context tier pay beyond
/// the paper's two pools? Reported per workload with the optimal
/// boundaries, per-tier GPU counts, and savings vs the homogeneous fleet.
pub fn table8(lambda: f64, max_k: usize) -> Table {
    let mut t = Table::new(
        &format!("Table 8 — K-tier fleets at lambda = {lambda} req/s (boundary-combination sweep)"),
        &[
            "Workload",
            "K",
            "Boundaries",
            "gamma*",
            "GPUs/tier",
            "Total",
            "Ann. cost (K$)",
            "Savings",
            "Sweep",
        ],
    );
    for w in traces::all() {
        let rows = table8_rows(&w, lambda, max_k);
        let base = rows[0].cost_yr;
        for r in rows {
            let join = |v: Vec<String>| if v.is_empty() { "-".to_string() } else { v.join("+") };
            t.row(&[
                r.workload.to_string(),
                r.k.to_string(),
                join(r.boundaries.iter().map(|b| fmt_int(*b as f64)).collect()),
                format!("{:.1}", r.gamma),
                join(r.gpus.iter().map(|n| n.to_string()).collect()),
                fmt_int(r.total_gpus() as f64),
                fmt_int(r.cost_yr / 1000.0),
                if r.k == 1 {
                    "-".into()
                } else {
                    fmt_pct(1.0 - r.cost_yr / base)
                },
                if r.k == 1 {
                    "-".into()
                } else {
                    format!("{:.1} ms", r.sweep_ms)
                },
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Table 9: static plan vs per-epoch oracle vs online autoscaler
// ---------------------------------------------------------------------------

/// One Table-9 row: a provisioning method's bill and SLO record on one
/// nonstationary variant of a workload.
pub struct Table9Row {
    pub workload: &'static str,
    /// Arrival variant: "diurnal" or "burst".
    pub variant: &'static str,
    /// "static-peak" (plan once for the worst case), "oracle" (per-epoch
    /// hindsight-optimal), or "autoscale" (the online control loop).
    pub method: &'static str,
    pub gpu_hours: f64,
    /// GPU-time priced at the per-tier rates, dollars for the horizon.
    pub cost: f64,
    /// Fraction of epochs meeting every tier's P99 TTFT SLO (1.0 for the
    /// oracle, which meets it analytically by construction).
    pub slo_ok_frac: f64,
    pub epochs: usize,
}

/// The two nonstationary variants each trace is evaluated under, scaled
/// to the run horizon: the diurnal wave completes one full cycle over the
/// run, the burst process dwells long enough for the controller to react.
/// The 400 req/s base is large enough to exercise multi-GPU scaling per
/// tier, small enough that a 3-trace x 2-variant x 2-simulation sweep
/// stays inside the CI budget.
pub fn table9_scenarios(horizon_s: f64) -> Vec<(&'static str, RateModel)> {
    vec![
        (
            "diurnal",
            RateModel::Diurnal {
                base: 400.0,
                amp: 0.6,
                period_s: horizon_s,
                phase: 0.0,
            },
        ),
        (
            "burst",
            RateModel::Mmpp {
                rates: [280.0, 800.0],
                mean_sojourn_s: [horizon_s / 5.0, horizon_s / 10.0],
            },
        ),
    ]
}

fn table9_row(
    w: &Workload,
    variant: &'static str,
    method: &'static str,
    rep: &AutoscaleReport,
) -> Table9Row {
    Table9Row {
        workload: w.name,
        variant,
        method,
        gpu_hours: rep.gpu_hours,
        cost: rep.cost,
        slo_ok_frac: rep.slo_ok_frac,
        epochs: rep.epochs.len(),
    }
}

/// Compute the Table-9 rows for one workload: for each arrival variant,
/// (1) the static worst-case plan (sized at the peak rate, controller
/// off), (2) the per-epoch oracle (hindsight-optimal plan per epoch at
/// the realized rate — GPU-hours integrated analytically), and (3) the
/// online autoscaler (cold-started at the t = 0 rate). All three run on
/// the same request stream per variant (same seed).
///
/// §Perf: the (variant x policy) grid fans out over the shared
/// [`par_map_each`] substrate (one capped worker per arrival variant),
/// and within a variant the static-peak and autoscale simulations (which
/// share nothing but the seed) run concurrently; the oracle follows the
/// autoscaler because it bills over its epoch grid. Every simulation is
/// deterministic per seed, so the rows are bit-identical to a serial run
/// and come out in the fixed (variant, method) order.
pub fn table9_rows(w: &Workload, n: usize, seed: u64) -> Vec<Table9Row> {
    let spec = GpuProfile::a100_llama70b().fleet_spec(&[w.b_short]);
    // Horizon-proportional controller cadence: ~25 control actions per
    // run keep the tracking lag (~2.5 epochs with the peak estimator)
    // small against the one-cycle wave, so the headroom knob covers the
    // upswing shortfall.
    let horizon_est = n as f64 / 400.0;
    let epoch_s = (horizon_est / 25.0).max(1.0);
    let scenarios = table9_scenarios(horizon_est);
    let per_variant: Vec<Vec<Table9Row>> = par_map_each(&scenarios, |sc| {
        table9_variant(w, n, seed, epoch_s, sc.0, sc.1.clone(), &spec)
    });
    per_variant.into_iter().flatten().collect()
}

/// One arrival variant's three Table-9 rows (static-peak, oracle,
/// autoscale) — see [`table9_rows`] for the sharding contract.
fn table9_variant(
    w: &Workload,
    n: usize,
    seed: u64,
    epoch_s: f64,
    variant: &'static str,
    model: RateModel,
    spec: &FleetSpec,
) -> Vec<Table9Row> {
    let mk_input = |lam: f64| {
        let mut i = PlanInput::new(w.clone(), lam);
        i.cfg.mc_samples = 8_000;
        i
    };
    let cfg = AutoscaleConfig {
        epoch_s,
        window_s: epoch_s * 2.0,
        provision_delay_s: epoch_s * 0.5,
        ..AutoscaleConfig::default()
    };

    // (1) static worst-case: provision the peak once, never touch it.
    let run_static = || {
        let input_peak = mk_input(model.peak_rate());
        let static_plan = plan_spec_sweep_gamma(&input_peak, spec).expect("static plan");
        let mut cfg_static = cfg.clone();
        cfg_static.replanning = false;
        simulate_autoscale(w, model.clone(), n, &input_peak, static_plan, &cfg_static, seed)
    };
    // (3) online autoscaler, cold-started at the t = 0 rate.
    let run_auto = || {
        let input0 = mk_input(model.rate_hint());
        let init = plan_spec_sweep_gamma(&input0, spec).expect("initial plan");
        simulate_autoscale(w, model.clone(), n, &input0, init, &cfg, seed)
    };
    // The pair overlaps on a scoped worker unless the process-wide cap
    // (`--threads` / `FLEETOPT_THREADS`) forbids spawning; either way the
    // two runs share nothing but the seed, so the reports are identical.
    let (rep_static, rep_auto) = if thread_cap() <= 1 {
        (run_static(), run_auto())
    } else {
        std::thread::scope(|scope| {
            let h_static = scope.spawn(run_static);
            let auto = run_auto();
            (h_static.join().expect("static sim panicked"), auto)
        })
    };

    // (2) per-epoch oracle over the autoscaler's own epoch grid: the
    // hindsight-optimal plan at each epoch's realized rate, billed
    // analytically for the epoch duration. This is an *optimistic
    // lower bound*: it bills nothing for zero-arrival (drain) epochs
    // and pays no provisioning delay, switching cost, or floors.
    let cache = CalibCache::new();
    let mut gpu_hours = 0.0;
    let mut cost = 0.0;
    let mut epochs = 0usize;
    for e in &rep_auto.epochs {
        if e.lambda_realized <= 0.0 {
            continue;
        }
        let pi = mk_input(e.lambda_realized);
        let Ok(p) = plan_spec_sweep_gamma_cached(&pi, spec, &cache) else {
            continue;
        };
        let dur_h = (e.t_end_s - e.t_start_s) / 3600.0;
        gpu_hours += p.total_gpus() as f64 * dur_h;
        cost += p
            .tiers
            .iter()
            .zip(&p.spec.tiers)
            .map(|(pool, ts)| pool.n_gpus as f64 * ts.cost_hr)
            .sum::<f64>()
            * dur_h;
        epochs += 1;
    }
    vec![
        table9_row(w, variant, "static-peak", &rep_static),
        Table9Row {
            workload: w.name,
            variant,
            method: "oracle",
            gpu_hours,
            cost,
            slo_ok_frac: 1.0,
            epochs,
        },
        table9_row(w, variant, "autoscale", &rep_auto),
    ]
}

/// Table 9 — does the online control loop track the per-epoch oracle?
/// Acceptance (ROADMAP "Online control loop"): autoscale GPU-hours within
/// 10% of the oracle on the diurnal traces while meeting the SLO in
/// >= 95% of epochs, and beating static-peak cost on >= 2 traces.
///
/// §Perf: the three traces shard over scoped workers (each already
/// sharding its variants — see [`table9_rows`]); rows keep the serial
/// trace order and are bit-identical per seed.
pub fn table9(n: usize) -> Table {
    let mut t = Table::new(
        &format!(
            "Table 9 — static plan vs per-epoch oracle vs online autoscaler ({n} requests/variant)"
        ),
        &[
            "Workload",
            "Arrivals",
            "Method",
            "GPU-hours",
            "Cost ($)",
            "SLO-ok epochs",
            "Epochs",
        ],
    );
    let ws = traces::all();
    let items: Vec<(usize, &Workload)> = ws.iter().enumerate().collect();
    let per_trace: Vec<Vec<Table9Row>> =
        par_map_each(&items, |&(i, w)| table9_rows(w, n, 0x7AB9 + i as u64));
    for rows in per_trace {
        for r in rows {
            t.row(&[
                r.workload.to_string(),
                r.variant.to_string(),
                r.method.to_string(),
                format!("{:.2}", r.gpu_hours),
                format!("{:.2}", r.cost),
                fmt_pct(r.slo_ok_frac),
                r.epochs.to_string(),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Table 10: single-SKU vs mixed-SKU fleets (anytime planner)
// ---------------------------------------------------------------------------

/// One Table-10 row: the single-SKU optimum against the mixed-SKU plan
/// the anytime search found over the demo catalog, DES-validated.
pub struct Table10Row {
    pub workload: &'static str,
    pub k: usize,
    /// The plain bound-and-prune argmin over the base profile.
    pub single_cost_yr: f64,
    pub single_gpus: u64,
    /// The anytime incumbent over [`SkuCatalog::demo`].
    pub mixed_cost_yr: f64,
    pub mixed_gpus: u64,
    /// SKU name per tier of the mixed plan, tier order.
    pub skus: Vec<String>,
    pub boundaries: Vec<u32>,
    /// `1 − mixed/single` — non-negative whenever the catalog contains
    /// the base SKU (phase 0 seeds its uniform assignment at the plain
    /// argmin, so the incumbent can only improve from there).
    pub saving: f64,
    /// DES cross-check on the mixed plan (Table-5 style): the worst
    /// per-tier `|rho_ana − rho_des| / rho_des` among simulated tiers.
    pub rho_err_max: f64,
    pub cells_evaluated: usize,
    /// True when the search delegated to the exact exhaustive oracle.
    pub exact: bool,
}

/// Compute one Table-10 row: plain optimum, anytime mixed-SKU plan, and
/// a `n_sim`-request tiered DES of the mixed plan (each tier's DES runs
/// the SKU's time-dilated profile, so the validation exercises the mu
/// scaling end to end).
pub fn table10_rows(
    w: &Workload,
    lambda: f64,
    k: usize,
    n_sim: usize,
    seed: u64,
) -> Table10Row {
    let input = PlanInput::new(w.clone(), lambda);
    let catalog = SkuCatalog::demo(&input.gpu);
    let cache = CalibCache::new();
    let (single, _) = sweep_tiered_pruned(&input, k, &cache).expect("single-SKU plan");
    let r = anytime_search(
        &input,
        k,
        Some(&catalog),
        &cache,
        Deadline::none(),
        &AnytimeConfig::default(),
    )
    .expect("mixed-SKU plan");
    let g = input.gpu.clone();
    let sim = simulate_fleet_tiered(w, &r.plan, &g, lambda, n_sim, seed);
    let mut rho_err_max = 0.0f64;
    for (pool, res) in r.plan.tiers.iter().zip(&sim.tiers) {
        if let Some(sres) = res {
            if pool.n_gpus > 0 && sres.utilization > 0.0 {
                let e = ((pool.rho_ana() - sres.utilization) / sres.utilization).abs();
                rho_err_max = rho_err_max.max(e);
            }
        }
    }
    let skus = r
        .plan
        .spec
        .tiers
        .iter()
        .map(|t| match t.sku_index() {
            Some(i) => catalog.skus[i].name.clone(),
            None => "base".to_string(),
        })
        .collect();
    Table10Row {
        workload: w.name,
        k,
        single_cost_yr: single.cost_yr,
        single_gpus: single.total_gpus(),
        mixed_cost_yr: r.plan.cost_yr,
        mixed_gpus: r.plan.total_gpus(),
        skus,
        boundaries: r.plan.boundaries(),
        saving: 1.0 - r.plan.cost_yr / single.cost_yr,
        rho_err_max,
        cells_evaluated: r.cells_evaluated,
        exact: r.exact,
    }
}

/// Table 10 — heterogeneous fleets: what does a mixed-SKU assignment
/// (demo catalog: a100 base / h100 / discounted spot l40s) save over the
/// best single-SKU fleet, per trace at K = 3? The anytime search runs
/// unbounded here (reporting, not latency, is the point); the DES
/// cross-checks each mixed plan's per-tier utilization.
pub fn table10(lambda: f64, n_sim: usize) -> Table {
    let mut t = Table::new(
        &format!("Table 10 — single-SKU vs mixed-SKU fleet cost at lambda = {lambda} req/s (K = 3, demo catalog)"),
        &[
            "Workload",
            "Single (K$)",
            "Mixed (K$)",
            "Saving",
            "SKUs/tier",
            "Boundaries",
            "rho err (DES)",
            "Cells",
            "Exact",
        ],
    );
    for (i, w) in traces::all().iter().enumerate() {
        let r = table10_rows(w, lambda, 3, n_sim, 0x7AB10 + i as u64);
        let join = |v: Vec<String>| if v.is_empty() { "-".to_string() } else { v.join("+") };
        t.row(&[
            r.workload.to_string(),
            fmt_int(r.single_cost_yr / 1000.0),
            fmt_int(r.mixed_cost_yr / 1000.0),
            fmt_pct(r.saving),
            r.skus.join("+"),
            join(r.boundaries.iter().map(|b| fmt_int(*b as f64)).collect()),
            format!("{:.1}%", r.rho_err_max * 100.0),
            r.cells_evaluated.to_string(),
            if r.exact { "yes".into() } else { "no".into() },
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 11: redundancy policies under failure injection (chaos)
// ---------------------------------------------------------------------------

/// One Table-11 row: one (trace, fault intensity, policy) cell of the
/// chaos study — SLO outcome, fault counters, and the GPU-cost premium
/// the policy pays over the no-redundancy baseline on the *same* fault
/// trace (identical plan seed, identical per-GPU failure streams).
pub struct Table11Row {
    pub workload: &'static str,
    pub intensity: &'static str,
    pub policy: &'static str,
    pub slo_ok_frac: f64,
    pub crashes: u64,
    pub preemptions: u64,
    pub killed: u64,
    pub spilled: u64,
    pub gpu_hours: f64,
    pub cost: f64,
    /// `cost / cost(no-redundancy) − 1` within the same intensity cell.
    pub added_cost: f64,
}

/// The standard Table-11 fault plan at one of two intensities, scaled to
/// the run horizon: `moderate` is replica churn alone (each replica
/// expects ~1 crash per run), `heavy` triples the crash rate and takes
/// the whole short tier out across the diurnal peak — the scenario the
/// ROADMAP reliability item names.
pub fn table11_faults(horizon_s: f64, heavy: bool, seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        replica: Some(ReplicaFaults {
            mtbf_s: if heavy { horizon_s / 3.0 } else { horizon_s },
            mttr_s: horizon_s / 50.0,
        }),
        spot: None,
        outages: if heavy {
            vec![TierOutage {
                tier: 0,
                start_s: horizon_s * 0.45,
                duration_s: horizon_s * 0.10,
            }]
        } else {
            Vec::new()
        },
    }
}

/// One fault intensity's three Table-11 rows: the same diurnal stream and
/// the same fault plan under (1) no redundancy, (2) N+1 sizing, and
/// (3) N+1 plus cross-tier failover. Policies share nothing but the seed,
/// so they fan out over scoped workers like the Table-9 methods do.
fn table11_intensity(
    w: &Workload,
    n: usize,
    seed: u64,
    epoch_s: f64,
    model: &RateModel,
    spec: &FleetSpec,
    intensity: &'static str,
    heavy: bool,
) -> Vec<Table11Row> {
    let horizon_est = n as f64 / 400.0;
    let faults = table11_faults(horizon_est, heavy, seed);
    let cfg = AutoscaleConfig {
        epoch_s,
        window_s: epoch_s * 2.0,
        provision_delay_s: epoch_s * 0.5,
        ..AutoscaleConfig::default()
    };
    let run = |redundancy: &[u64], failover: bool| {
        let mut input0 = PlanInput::new(w.clone(), model.rate_hint());
        input0.cfg.mc_samples = 8_000;
        input0.redundancy = redundancy.to_vec();
        let init = plan_spec_sweep_gamma(&input0, spec).expect("initial plan");
        let chaos = ChaosOpts {
            faults: Some(faults.clone()),
            failover: failover.then(FailoverConfig::default),
        };
        simulate_autoscale_chaos(w, model.clone(), n, &input0, init, &cfg, seed, &chaos)
    };
    let policies: [(&'static str, &[u64], bool); 3] = [
        ("none", &[], false),
        ("n+1", &[1], false),
        ("n+1+fo", &[1], true),
    ];
    let reps: Vec<AutoscaleReport> =
        par_map_each(&policies, |&(_, red, fo)| run(red, fo));
    let base_cost = reps[0].cost;
    policies
        .iter()
        .zip(&reps)
        .map(|(&(policy, _, _), r)| Table11Row {
            workload: w.name,
            intensity,
            policy,
            slo_ok_frac: r.slo_ok_frac,
            crashes: r.crashes,
            preemptions: r.preemptions,
            killed: r.killed_in_flight,
            spilled: r.spilled,
            gpu_hours: r.gpu_hours,
            cost: r.cost,
            added_cost: r.cost / base_cost.max(1e-12) - 1.0,
        })
        .collect()
}

/// Compute the Table-11 rows for one workload: the Table-9 diurnal
/// variant (one full cycle over the run) under the standard fault plan at
/// both intensities. Deterministic per seed — the two intensity cells
/// shard over the capped worker pool and keep their serial order.
pub fn table11_rows(w: &Workload, n: usize, seed: u64) -> Vec<Table11Row> {
    let spec = GpuProfile::a100_llama70b().fleet_spec(&[w.b_short]);
    let horizon_est = n as f64 / 400.0;
    let epoch_s = (horizon_est / 25.0).max(1.0);
    let model = RateModel::Diurnal {
        base: 400.0,
        amp: 0.6,
        period_s: horizon_est,
        phase: 0.0,
    };
    let cells = [("moderate", false), ("heavy", true)];
    let per_cell: Vec<Vec<Table11Row>> = par_map_each(&cells, |&(label, heavy)| {
        table11_intensity(w, n, seed, epoch_s, &model, &spec, label, heavy)
    });
    per_cell.into_iter().flatten().collect()
}

/// Table 11 — what does surviving failures cost? No-redundancy vs N+1
/// sizing vs N+1 with cross-tier failover, on identical fault traces.
/// Acceptance (ROADMAP "Reliability"): with N+1 + failover the fleet
/// holds the SLO budget through crashes and a whole-tier outage at a
/// bounded GPU-cost premium over the no-redundancy baseline (the CI
/// chaos smoke gates the same scenario end to end).
pub fn table11(n: usize) -> Table {
    let mut t = Table::new(
        &format!(
            "Table 11 — redundancy policies under failure injection ({n} requests/cell, diurnal arrivals)"
        ),
        &[
            "Workload",
            "Faults",
            "Policy",
            "SLO-ok epochs",
            "Crashes",
            "Killed",
            "Spilled",
            "GPU-hours",
            "Cost ($)",
            "Added cost",
        ],
    );
    let ws = traces::all();
    let items: Vec<(usize, &Workload)> = ws.iter().enumerate().collect();
    let per_trace: Vec<Vec<Table11Row>> =
        par_map_each(&items, |&(i, w)| table11_rows(w, n, 0x7AB11 + i as u64));
    for rows in per_trace {
        for r in rows {
            t.row(&[
                r.workload.to_string(),
                r.intensity.to_string(),
                r.policy.to_string(),
                fmt_pct(r.slo_ok_frac),
                r.crashes.to_string(),
                r.killed.to_string(),
                r.spilled.to_string(),
                format!("{:.2}", r.gpu_hours),
                format!("{:.2}", r.cost),
                fmt_pct(r.added_cost),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Table 12: the KV stability boundary, analytics vs DES
// ---------------------------------------------------------------------------

/// One offered-load point of the Table-12 stability sweep.
#[derive(Clone, Debug)]
pub struct Table12Row {
    pub workload: &'static str,
    /// Offered load relative to the analytical boundary, `lambda / lambda*`.
    pub ratio: f64,
    /// Arrival rate, req/s.
    pub lambda: f64,
    /// Analytical `rho_kv` at this lambda (equals `ratio` by construction).
    pub rho_pred: f64,
    /// DES-measured mean KV occupancy over the measurement window.
    pub kv_util: f64,
    /// Fraction of the trace still queued or in flight when the horizon
    /// cut the run — a stable pool strands only its in-flight population
    /// (vanishing as `n` grows); an unstable one strands an O(1) fraction
    /// `~ 1 - 1/ratio`.
    pub censored_frac: f64,
    pub kv_blocked: u64,
    pub kv_violations: u64,
    /// `rho_kv < 1` — the closed-form prediction.
    pub stable_pred: bool,
    /// What the DES observed (bounded backlog, unsaturated ledger).
    pub stable_des: bool,
}

/// Sweep one workload across `ratios * lambda*` on a fixed KV-bound pool
/// and compare the analytical `rho_kv` against the DES ledger (ROADMAP
/// item 4 validation).
///
/// Pool construction mirrors a planner tier: both the DES trace and the
/// analytical calibration integrate the trace distribution truncated at
/// the tier cut `c_max`, and the per-GPU cap is sized so KV — not slots —
/// is the binding resource (at `rho_kv = 1` slot utilization sits near
/// 0.5) while still admitting the largest routable request, so FCFS
/// head-of-line can never deadlock. Each run is cut at its last arrival:
/// the measurement window stays stationary, and an unstable backlog is
/// reported as censored mass instead of being simulated to drain.
/// Deterministic per seed; rows fan out over the capped worker pool.
pub fn table12_rows(w: &Workload, n: usize, ratios: &[f64], seed: u64) -> Vec<Table12Row> {
    let g = GpuProfile::a100_llama70b();
    let (n_gpus, n_slots) = (4u64, 64u32);
    let c_max = 16_384u32;
    let dist = crate::workload::cdf::TruncatedDist::new(w.cdf.clone(), 2.0, c_max as f64);
    let kv = calibrate_kv_quadrature(&dist, &w.output, &g, n_slots, 512, 8);
    // T-weighted mean tokens per resident request: what a busy slot holds
    // on average. Half a slot's share of the cap makes rho_slot ~ 0.5 at
    // the KV boundary.
    let weighted_mean = kv.e_kv_iter / kv.e_iter;
    let cap = ((0.5 * n_slots as f64 * weighted_mean).floor() as u64).max(c_max as u64);
    let ls = lambda_star(n_gpus, cap, &kv);
    let cells: Vec<(usize, f64)> = ratios.iter().copied().enumerate().collect();
    par_map_each(&cells, |&(i, ratio)| {
        let lambda = ratio * ls;
        let mut rng = Rng::new(seed + i as u64);
        let mut t = 0.0;
        let reqs: Vec<SimRequest> = (0..n)
            .map(|_| {
                t += rng.exp(lambda);
                // Same draw order as `Workload::sample_request`: length,
                // then output jitter.
                let l_total = dist.sample(&mut rng).round().max(2.0);
                let l_out = w.output.sample_l_out(l_total, &mut rng);
                let l_in = (l_total as u32).saturating_sub(l_out).max(1);
                SimRequest {
                    arrival_s: t,
                    l_in,
                    l_out,
                }
            })
            .collect();
        let mut cfg = SimConfig::new(GpuProfile::a100_llama70b(), n_gpus, n_slots);
        cfg.kv_cap_tokens = Some(cap);
        cfg.horizon_s = Some(t);
        let res = simulate_pool(&cfg, &reqs);
        let censored_frac = res.censored as f64 / n as f64;
        Table12Row {
            workload: w.name,
            ratio,
            lambda,
            rho_pred: rho_kv(lambda, n_gpus, cap, &kv),
            kv_util: res.kv_util,
            censored_frac,
            kv_blocked: res.kv_blocked,
            kv_violations: res.kv_violations,
            stable_pred: ratio < 1.0,
            stable_des: censored_frac < 0.10 && res.kv_util < 0.98,
        }
    })
}

/// Paper-style Table 12: does the closed-form KV stability boundary
/// `rho_kv = lambda * E[(L_in+L_out)*T] * t_iter / (n * cap)` predict the
/// DES? Stable side: measured occupancy within 5% of `rho_kv`. Unstable
/// side (one boundary step past `lambda*`): the ledger saturates and the
/// backlog grows without bound (censored mass).
pub fn table12(n: usize) -> Table {
    let ratios = [0.60, 0.75, 0.90, 1.10, 1.30];
    let mut t = Table::new(
        &format!("Table 12 — KV stability boundary: analytical rho_kv vs DES ({n} requests/cell, 4 GPUs, KV-bound cap)"),
        &[
            "Workload",
            "lambda/lambda*",
            "lambda req/s",
            "rho_kv pred",
            "KV util DES",
            "err",
            "censored",
            "stable pred/DES",
        ],
    );
    for w in traces::all() {
        for r in table12_rows(&w, n, &ratios, 0x7AB12) {
            let err = if r.stable_pred {
                format!("{:+.1}%", (r.kv_util - r.rho_pred) / r.rho_pred * 100.0)
            } else {
                "-".into()
            };
            t.row(&[
                r.workload.to_string(),
                format!("{:.2}", r.ratio),
                format!("{:.2}", r.lambda),
                format!("{:.3}", r.rho_pred),
                format!("{:.3}", r.kv_util),
                err,
                fmt_pct(r.censored_frac),
                format!(
                    "{} / {}",
                    if r.stable_pred { "yes" } else { "no" },
                    if r.stable_des { "yes" } else { "no" }
                ),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// helpers used by benches
// ---------------------------------------------------------------------------

/// Simulate one synthetic pool quickly (bench helper).
pub fn quick_pool_sim(n_gpus: u64, n_slots: u32, lambda: f64, n: usize, seed: u64) -> f64 {
    let g = GpuProfile::a100_llama70b();
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let reqs: Vec<crate::fleetsim::sim::SimRequest> = (0..n)
        .map(|_| {
            t += rng.exp(lambda);
            crate::fleetsim::sim::SimRequest {
                arrival_s: t,
                l_in: 1024,
                l_out: 100,
            }
        })
        .collect();
    simulate_pool(&SimConfig::new(g, n_gpus, n_slots), &reqs).utilization
}

/// The default artifacts directory (exists only after `make artifacts`).
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[allow(unused_imports)]
use crate::workload::cdf::LengthDist;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_paper_rows() {
        let t = table1();
        assert_eq!(t.n_rows(), 4);
        let s = t.render();
        assert!(s.contains("8.0x"), "{s}");
        assert!(s.contains("128"));
    }

    #[test]
    fn table2_has_three_workloads() {
        let t = table2();
        assert_eq!(t.n_rows(), 3);
        let s = t.render();
        assert!(s.contains("0.898") && s.contains("0.078"));
        assert!(s.contains("16x") && s.contains("43x") || s.contains("42x"), "{s}");
    }

    #[test]
    fn table4_latency_sane() {
        let w = traces::lmsys();
        let m = table4_measure(&w, 5, 1);
        assert!(m.p50_ms > 0.0 && m.p99_ms < 5_000.0);
        assert!(m.overhead_ms < m.p99_ms);
    }

    #[test]
    fn table8_k2_beats_homogeneous_and_renders() {
        let w = traces::azure();
        let rows = table8_rows(&w, 1000.0, 2);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].k, 1);
        assert_eq!(rows[0].boundaries.len(), 0);
        assert_eq!(rows[1].boundaries.len(), 1);
        assert_eq!(rows[1].gpus.len(), 2);
        assert!(
            rows[1].cost_yr < rows[0].cost_yr,
            "two-pool {} must beat homogeneous {}",
            rows[1].cost_yr,
            rows[0].cost_yr
        );
        let t = table8(1000.0, 2);
        assert_eq!(t.n_rows(), 6);
        assert!(t.render().contains("azure"));
    }

    #[test]
    fn table9_rows_cover_methods_and_stay_consistent() {
        let w = traces::azure();
        let rows = table9_rows(&w, 4_000, 7);
        assert_eq!(rows.len(), 6, "2 variants x 3 methods");
        let methods: Vec<&str> = rows.iter().map(|r| r.method).collect();
        assert_eq!(
            methods,
            vec![
                "static-peak",
                "oracle",
                "autoscale",
                "static-peak",
                "oracle",
                "autoscale"
            ]
        );
        for r in &rows {
            assert!(r.gpu_hours > 0.0, "{}/{}", r.variant, r.method);
            assert!(r.cost > 0.0);
            assert!(r.epochs > 0);
            assert!((0.0..=1.0).contains(&r.slo_ok_frac));
        }
        // Hindsight-optimal per-epoch plans cannot materially exceed the
        // worst-case static fleet's bill.
        for chunk in rows.chunks(3) {
            assert!(
                chunk[1].gpu_hours <= chunk[0].gpu_hours * 1.05,
                "{}: oracle {} vs static {}",
                chunk[1].variant,
                chunk[1].gpu_hours,
                chunk[0].gpu_hours
            );
        }
    }

    #[test]
    fn table10_mixed_never_loses_to_single_sku() {
        // K = 2 keeps the demo space inside the exhaustive oracle, so
        // this also pins `exact` and the per-tier SKU naming.
        let w = traces::azure();
        let r = table10_rows(&w, 1000.0, 2, 4_000, 7);
        assert_eq!(r.k, 2);
        assert_eq!(r.skus.len(), 2);
        assert!(
            r.mixed_cost_yr <= r.single_cost_yr + 1e-9,
            "mixed {} vs single {}",
            r.mixed_cost_yr,
            r.single_cost_yr
        );
        assert!(r.saving >= -1e-12);
        assert!(r.exact, "K=2 demo space fits the exhaustive oracle");
        assert!(r.cells_evaluated > 0);
        // DES agreement within the Table-5 ballpark (generous: short run).
        assert!(r.rho_err_max < 0.25, "rho err {}", r.rho_err_max);
        // The rendered K = 3 table across all traces is exercised by the
        // CI `tables --only 10 --fast` run, not here (debug-mode cost).
    }

    #[test]
    fn table11_policies_pay_for_redundancy_and_spill_under_outage() {
        let w = traces::azure();
        let rows = table11_rows(&w, 4_000, 7);
        assert_eq!(rows.len(), 6, "2 intensities x 3 policies");
        let policies: Vec<&str> = rows.iter().map(|r| r.policy).collect();
        assert_eq!(policies, vec!["none", "n+1", "n+1+fo", "none", "n+1", "n+1+fo"]);
        for r in &rows {
            assert!(r.gpu_hours > 0.0, "{}/{}", r.intensity, r.policy);
            assert!((0.0..=1.0).contains(&r.slo_ok_frac));
            assert!(r.crashes > 0, "the fault plan must actually fire");
        }
        for chunk in rows.chunks(3) {
            // The baseline defines the premium; spares never come free.
            assert!(chunk[0].added_cost.abs() < 1e-12);
            assert!(chunk[1].cost >= chunk[0].cost, "{}", chunk[1].intensity);
            assert!(chunk[1].added_cost >= 0.0);
        }
        // The heavy cell's whole-tier outage must push traffic across the
        // boundary when failover is armed — and only then.
        let heavy_fo = &rows[5];
        assert_eq!((heavy_fo.intensity, heavy_fo.policy), ("heavy", "n+1+fo"));
        assert!(heavy_fo.spilled > 0, "outage with failover must spill");
        assert_eq!(rows[4].spilled, 0, "no failover => no spill counting");
    }

    #[test]
    fn table12_boundary_separates_stable_from_unstable() {
        // Away-from-boundary grid at test scale: the analytical verdict
        // and the DES verdict must agree on every point, and stable-side
        // occupancy must track rho_kv (the full 5%-at-scale gate is the
        // CI `tables --only 12` run; debug mode gets a finite-n margin).
        let w = traces::azure();
        let rows = table12_rows(&w, 6_000, &[0.60, 0.80, 1.30], 0x7AB12);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.kv_violations, 0, "reservation ledger oversubscribed");
            assert_eq!(
                r.stable_pred, r.stable_des,
                "ratio {}: pred {} DES {} (censored {:.3}, kv_util {:.3})",
                r.ratio, r.stable_pred, r.stable_des, r.censored_frac, r.kv_util
            );
        }
        for r in rows.iter().filter(|r| r.stable_pred) {
            let err = (r.kv_util - r.rho_pred).abs();
            assert!(
                err <= 0.05 * r.rho_pred + 0.02,
                "ratio {}: rho_kv {} vs DES {}",
                r.ratio,
                r.rho_pred,
                r.kv_util
            );
        }
        // The unstable point saturates the ledger and strands an O(1)
        // fraction of the trace; it must also have hit the KV brake.
        let un = &rows[2];
        assert!(un.kv_util > rows[1].kv_util, "overload must raise occupancy");
        assert!(un.censored_frac > 0.10, "censored {}", un.censored_frac);
        assert!(un.kv_blocked > 0, "KV cap never bound under overload");
    }

    #[test]
    fn table7_small_study_fidelity_bounds() {
        let s = table7_study(5, 2, None);
        assert!(s.p_c > 0.5, "p_c = {}", s.p_c);
        let mut rouge = s.rouge;
        assert!(rouge.p50() > 0.5 && rouge.p50() <= 1.0);
        let mut cos = s.tfidf_cos;
        assert!(cos.p50() > 0.8, "tfidf cosine {}", cos.p50());
    }
}
