//! Experiment drivers: one function per evaluation table (1–12), shared by
//! the CLI (`fleetopt tables`) and the bench binaries (`cargo bench`). Each
//! regenerates the corresponding table's rows from this implementation so
//! measured values can be laid side-by-side with the published ones
//! (EXPERIMENTS.md).

pub mod tables;

pub use tables::*;
