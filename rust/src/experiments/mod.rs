//! Experiment drivers: one function per paper table (1–7), shared by the
//! CLI (`fleetopt tables`) and the bench binaries (`cargo bench`). Each
//! regenerates the corresponding table's rows from this implementation so
//! measured values can be laid side-by-side with the published ones
//! (EXPERIMENTS.md).

pub mod tables;

pub use tables::*;
