//! `fleetopt` — the FleetOpt launcher.
//!
//! Subcommands:
//!   plan      — plan a fleet for one workload (Algorithm 1 at a fixed B,
//!               or K-tier at fixed `--tiers` windows)
//!   sweep     — full Algorithm-1 sweep over candidate boundaries
//!               (`--tiers K` or a window list sweeps K-tier fleets;
//!               `--sku-catalog` adds per-tier GPU SKU assignment and
//!               `--budget-ms` bounds the search with the anytime planner)
//!   tables    — regenerate the paper's evaluation tables (1–12)
//!   simulate  — DES validation of the analytical model (Table 5; K-tier
//!               with `--tiers`)
//!   compress  — compress a borderline sample and report fidelity
//!   serve     — live serving demo on the AOT artifacts (K-tier with
//!               `--tiers`)
//!
//! Hand-rolled argument parsing (no clap offline; DESIGN.md §1). Numeric
//! flags are validated: counts must be positive integers, rates positive,
//! and gamma inside the paper's [1.0, 2.0] grid.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::config::SkuCatalog;
use fleetopt::compress::extractive::compress;
use fleetopt::compress::fidelity;
use fleetopt::coordinator::{serve_with, AdmissionOpts, ServeConfig, ServeItem};
use fleetopt::experiments;
use fleetopt::fleetsim::{
    run_stress, simulate_autoscale_kv, simulate_fleet_tiered_kv, AutoscaleConfig, ChaosOpts,
    FaultPlan, KvFleetOpts, QueueImpl, StressConfig,
};
use fleetopt::metrics::EpochMetrics;
use fleetopt::planner::{
    anytime_search, candidate_boundaries, plan_fleet, plan_homogeneous, plan_spec_sweep_gamma,
    sweep_full, sweep_gamma, sweep_tiered, AnytimeConfig, AnytimeResult, CalibCache, Deadline,
    Plan, PlanInput, TieredPlan,
};
use fleetopt::queueing::kv::KvPlanPolicy;
use fleetopt::router::admit::AdmitConfig;
use fleetopt::router::failover::FailoverConfig;
use fleetopt::router::GatewayConfig;
use fleetopt::util::rng::Rng;
use fleetopt::util::table::fmt_int;
use fleetopt::workload::arrivals::parse_arrival_spec;
use fleetopt::workload::traces;

fn usage() -> ! {
    eprintln!(
        "fleetopt — analytical fleet provisioning with Compress-and-Route

USAGE:
  fleetopt plan      --workload <azure|lmsys|agent> [--config F.json] [--lambda N] [--gamma G] [--b-short B] [--tiers W1,W2,..|K]
                     [--sku-catalog F.json] [--budget-ms N]
  fleetopt sweep     --workload <name> [--config F.json] [--lambda N] [--tiers W1,W2,..|K]
                     [--sku-catalog F.json] [--budget-ms N]
  fleetopt tables    [--only 1..12] [--fast]
  fleetopt simulate  --workload <name> [--lambda N] [--requests N] [--tiers W1,W2,..|K]
                     [--chaos plan.json] [--kv FRAC]
  fleetopt simulate  --stress [--requests N] [--gpus N] [--queue calendar|heap] [--seed N]
                     (fixed synthetic 5M-request/512-GPU/K=4 diurnal azure scenario)
  fleetopt autoscale --workload <name> [--config F.json] [--lambda N] [--requests N]
                     [--arrivals poisson|diurnal:amp=A,period=P|burst:high=H,low=L|schedule:F.json]
                     [--epoch S] [--window S] [--provision S] [--no-replan] [--forecast]
                     [--tiers W1,W2,..] [--out metrics.json] [--max-violation-frac F]
                     [--chaos plan.json] [--redundancy k|k1,k2,..] [--failover]
                     [--spill-watermark F] [--recover-watermark F] [--gamma-boost G]
                     [--kv FRAC] [--admit] [--admit-high F] [--admit-low F]
                     [--defer-s S] [--max-defers N] [--gamma-tighten G]
                     [--max-shed-frac F] [--max-retries N] [--forecast-seasonal P]
  fleetopt compress  [--tokens N] [--budget N] [--seed N]
  fleetopt serve     [--requests N] [--rate R] [--no-cr] [--artifacts DIR] [--tiers W1,W2,..]
                     [--trace F.jsonl] [--gateway-workers N] [--route-cache-cap N]

  --tiers takes either K-1 boundaries plus the long window
  (e.g. 4096,16384,65536) or a bare fleet size K (2..=6) to sweep
  boundary combinations.

  --sku-catalog F.json loads a heterogeneous GPU SKU catalog (see
  examples/configs/sku_catalog.json) and searches per-tier SKU
  assignments alongside boundaries; it needs the `--tiers K` form.
  --budget-ms N bounds that search with the anytime planner, which
  returns the best incumbent found within the deadline.

  --threads N caps every internal thread fan-out (sweeps, DES
  replications, table grids) at N workers; FLEETOPT_THREADS=N in the
  environment does the same. FLEETOPT_SIMD=0 forces the scalar kernels.

  --chaos plan.json injects deterministic failures (per-replica
  crash-restart, scheduled tier outages, spot preemption on preemptible
  SKUs; see examples/configs/chaos_plan.json). --redundancy sizes each
  tier with k hot spares (N+k); --failover spills routing across tier
  boundaries when a tier's live capacity drops below --spill-watermark
  (recovering at --recover-watermark, down-spill re-qualified through
  C&R at gamma x --gamma-boost).

  --kv FRAC turns on per-GPU KV-token bookkeeping in the DES, capping
  each tier at FRAC of its slot token budget (n_slots x c_max); off,
  the engines are bit-identical to the slot-only model. --admit (plus
  knobs) arms the stability-guarded admission controller in front of
  the C&R ladder: above --admit-high projected occupancy it escalates
  recompress -> defer -> shed, releasing below --admit-low.
  --max-shed-frac F fails the run if more than F of the offered load
  is shed; KV-ledger violations always fail it. --max-retries N drops
  a request after N crash retries (counted in dropped_retries);
  --forecast-seasonal P blends a period-P per-phase forecast into the
  autoscaler's planning rate.

  serve --trace F.jsonl replays a JSONL text trace (one
  {{\"text\", \"max_output\", \"arrival_s\"}} object per line, streamed
  from disk) instead of the synthetic workload. --gateway-workers N
  shards batch admission across N workers (0 = auto, 1 = serial;
  bit-identical output either way); --route-cache-cap N bounds the C&R
  route memo (0 = off).
"
    );
    std::process::exit(2);
}

/// Tiny flag parser: `--key value` pairs plus positionals.
fn parse_args(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
    }
}

/// A strictly positive numeric flag (rates, lambdas).
fn flag_pos_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    let v = flag_f64(flags, key, default)?;
    if !v.is_finite() || v <= 0.0 {
        bail!("--{key} must be a positive number, got {v}");
    }
    Ok(v)
}

/// A strictly positive whole-number flag (request counts, boundaries) —
/// no silent `as usize` truncation of fractional or negative input.
fn flag_count(flags: &HashMap<String, String>, key: &str, default: u64) -> Result<u64> {
    let v = flag_pos_f64(flags, key, default as f64)?;
    if v.fract() != 0.0 {
        bail!("--{key} must be a whole number, got {v}");
    }
    Ok(v as u64)
}

/// A non-negative whole-number flag, where 0 selects a feature-specific
/// default (auto worker count, cache off).
fn flag_count0(flags: &HashMap<String, String>, key: &str, default: u64) -> Result<u64> {
    let v = flag_f64(flags, key, default as f64)?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
        bail!("--{key} must be a non-negative whole number, got {v}");
    }
    Ok(v as u64)
}

/// A positive whole-number flag that must fit token-count width (u32).
fn flag_u32(flags: &HashMap<String, String>, key: &str, default: u32) -> Result<u32> {
    let v = flag_count(flags, key, default as u64)?;
    if v > u32::MAX as u64 {
        bail!("--{key} must fit in 32 bits, got {v}");
    }
    Ok(v as u32)
}

/// A compression bandwidth flag, restricted to the paper's grid range.
fn flag_gamma(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    let v = flag_f64(flags, key, default)?;
    if !(1.0..=2.0).contains(&v) {
        bail!("--{key} must be within [1.0, 2.0], got {v}");
    }
    Ok(v)
}

/// `--tiers` argument: explicit windows or a fleet size to sweep.
enum TiersArg {
    /// K-1 boundaries plus the long window, strictly ascending.
    Windows(Vec<u32>),
    /// Sweep boundary combinations for a K-tier fleet.
    K(usize),
}

fn tiers_arg(flags: &HashMap<String, String>) -> Result<Option<TiersArg>> {
    let Some(s) = flags.get("tiers") else {
        return Ok(None);
    };
    if s.contains(',') {
        let mut windows = Vec::new();
        for part in s.split(',') {
            let v: f64 = part
                .trim()
                .parse()
                .with_context(|| format!("--tiers entry `{part}`"))?;
            if !v.is_finite() || v < 1.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
                bail!("--tiers windows must be positive whole token counts, got `{part}`");
            }
            windows.push(v as u32);
        }
        if windows.len() < 2 {
            bail!("--tiers needs at least 2 windows (K-1 boundaries + the long window)");
        }
        if !windows.windows(2).all(|p| p[1] > p[0]) {
            bail!("--tiers windows must be strictly ascending, got {windows:?}");
        }
        Ok(Some(TiersArg::Windows(windows)))
    } else {
        let k: usize = s
            .parse()
            .with_context(|| format!("--tiers `{s}` (expected a window list or a fleet size)"))?;
        if !(2..=6).contains(&k) {
            bail!("--tiers fleet size must be in 2..=6, got {k}");
        }
        Ok(Some(TiersArg::K(k)))
    }
}

/// `--sku-catalog F.json`: an optional heterogeneous GPU catalog for the
/// mixed-SKU planner paths.
fn sku_catalog_arg(flags: &HashMap<String, String>) -> Result<Option<SkuCatalog>> {
    match flags.get("sku-catalog") {
        None => Ok(None),
        Some(path) => Ok(Some(SkuCatalog::from_file(path)?)),
    }
}

/// `--budget-ms N`: an optional wall-clock deadline for the anytime planner.
fn deadline_arg(flags: &HashMap<String, String>) -> Result<Deadline> {
    match flags.get("budget-ms") {
        None => Ok(Deadline::none()),
        Some(_) => Ok(Deadline::after_ms(flag_count(flags, "budget-ms", 50)?)),
    }
}

fn workload_arg(flags: &HashMap<String, String>) -> Result<fleetopt::workload::traces::Workload> {
    if let Some(path) = flags.get("config") {
        return fleetopt::workload::traces::Workload::from_config_file(path);
    }
    let name = flags
        .get("workload")
        .map(String::as_str)
        .unwrap_or("azure");
    traces::by_name(name).with_context(|| format!("unknown workload `{name}`"))
}

fn print_plan(label: &str, p: &Plan, baseline: Option<f64>) {
    let savings = baseline
        .map(|b| format!(" savings={:.1}%", (1.0 - p.cost_yr / b) * 100.0))
        .unwrap_or_default();
    println!(
        "{label:28} B={:6} gamma={:.1} n_s={:5} n_l={:5} total={:5} cost/yr=${}K{}",
        p.b_short,
        p.gamma,
        p.short.n_gpus,
        p.long.n_gpus,
        p.total_gpus(),
        fmt_int(p.cost_yr / 1000.0),
        savings,
    );
}

fn print_tiered(label: &str, p: &TieredPlan, baseline: Option<f64>, catalog: Option<&SkuCatalog>) {
    let savings = baseline
        .map(|b| format!(" savings={:.1}%", (1.0 - p.cost_yr / b) * 100.0))
        .unwrap_or_default();
    let bounds: Vec<String> = p.boundaries().iter().map(|b| b.to_string()).collect();
    let gammas: Vec<String> = p.gammas.iter().map(|g| format!("{g:.2}")).collect();
    let gpus: Vec<String> = p.gpu_counts().iter().map(|n| n.to_string()).collect();
    println!(
        "{label:28} K={} B=[{}] gamma=[{}] gpus=[{}] total={:5} cost/yr=${}K{}",
        p.k(),
        bounds.join(","),
        gammas.join(","),
        gpus.join(","),
        p.total_gpus(),
        fmt_int(p.cost_yr / 1000.0),
        savings,
    );
    for (i, (pool, tier)) in p.tiers.iter().zip(&p.spec.tiers).enumerate() {
        // Mixed-SKU plans carry a per-tier SKU choice; name it from the
        // catalog when one is loaded, else fall back to the index.
        let sku = match tier.sku_index() {
            None => String::new(),
            Some(si) => match catalog.and_then(|c| c.skus.get(si)) {
                Some(s) => format!(" sku={}", s.name),
                None => format!(" sku=#{si}"),
            },
        };
        println!(
            "  tier {i}: window={:6} slots/gpu={:4} n={:5} lambda={:7.1} rho={:.3} ttft99={:.0}ms{sku}",
            tier.c_max,
            tier.n_max,
            pool.n_gpus,
            pool.lambda,
            pool.rho_ana(),
            pool.ttft_p99() * 1e3,
        );
    }
}

/// Run the deadline-bounded anytime planner (`--sku-catalog`/`--budget-ms`)
/// and report its search statistics before returning the incumbent.
fn run_anytime(
    input: &PlanInput,
    k: usize,
    catalog: Option<&SkuCatalog>,
    flags: &HashMap<String, String>,
) -> Result<AnytimeResult> {
    let deadline = deadline_arg(flags)?;
    let cache = CalibCache::new();
    let t0 = std::time::Instant::now();
    let res = anytime_search(input, k, catalog, &cache, deadline, &AnytimeConfig::default())?;
    let dt = t0.elapsed();
    println!(
        "anytime: {} cells evaluated in {:.1} ms, bound gap {:.2}%, exact={}",
        res.cells_evaluated,
        dt.as_secs_f64() * 1e3,
        res.bound_gap_pct,
        res.exact,
    );
    Ok(res)
}

/// Plan a K-tier fleet at fixed windows (the `--tiers W1,..` form): the
/// last window becomes the long-tier context, the rest the boundaries;
/// the shared gamma grid is swept by `planner::plan_spec_sweep_gamma`.
fn plan_fixed_windows(input: &PlanInput, windows: &[u32]) -> Result<TieredPlan> {
    let k = windows.len();
    let mut input = input.clone();
    input.gpu.c_max_long = windows[k - 1];
    let spec = input.gpu.fleet_spec(&windows[..k - 1]);
    spec.validate()?;
    Ok(plan_spec_sweep_gamma(&input, &spec)?)
}

fn cmd_plan(flags: &HashMap<String, String>) -> Result<()> {
    let w = workload_arg(flags)?;
    let lambda = flag_pos_f64(flags, "lambda", 1000.0)?;
    let input = PlanInput::new(w.clone(), lambda);
    let homo = plan_homogeneous(&input)?;

    if let Some(tiers) = tiers_arg(flags)? {
        print_plan("homogeneous", &homo, None);
        let catalog = sku_catalog_arg(flags)?;
        let best = match tiers {
            TiersArg::Windows(windows) => {
                if catalog.is_some() || flags.contains_key("budget-ms") {
                    bail!(
                        "--sku-catalog/--budget-ms search SKU assignments and boundaries, \
                         so they need the `--tiers K` fleet-size form, not fixed windows"
                    );
                }
                plan_fixed_windows(&input, &windows)?
            }
            TiersArg::K(k) => {
                if catalog.is_some() || flags.contains_key("budget-ms") {
                    let res = run_anytime(&input, k, catalog.as_ref(), flags)?;
                    res.plan
                } else {
                    sweep_tiered(&input, k)?.0
                }
            }
        };
        print_tiered("fleetopt K-tier", &best, Some(homo.cost_yr), catalog.as_ref());
        return Ok(());
    }

    let b_short = flag_u32(flags, "b-short", w.b_short)?;
    print_plan("homogeneous", &homo, None);
    let pr = plan_fleet(&input, b_short, 1.0)?;
    print_plan("pool-routing", &pr, Some(homo.cost_yr));
    if flags.contains_key("gamma") {
        let gamma = flag_gamma(flags, "gamma", 1.5)?;
        let p = plan_fleet(&input, b_short, gamma)?;
        print_plan(&format!("pr+c&r (gamma={gamma})"), &p, Some(homo.cost_yr));
    }
    let opt = sweep_gamma(&input, b_short)?;
    print_plan("fleetopt (gamma*)", &opt, Some(homo.cost_yr));
    println!(
        "\npools at gamma*: short rho={:.3} ttft99={:.0}ms | long rho={:.3} ttft99={:.0}ms",
        opt.short.rho_ana(),
        opt.short.ttft_p99() * 1e3,
        opt.long.rho_ana(),
        opt.long.ttft_p99() * 1e3,
    );
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let w = workload_arg(flags)?;
    let lambda = flag_pos_f64(flags, "lambda", 1000.0)?;
    let input = PlanInput::new(w.clone(), lambda);

    if let Some(tiers) = tiers_arg(flags)? {
        let k = match &tiers {
            TiersArg::Windows(ws) => ws.len(),
            TiersArg::K(k) => *k,
        };
        let catalog = sku_catalog_arg(flags)?;
        if catalog.is_some() || flags.contains_key("budget-ms") {
            if matches!(tiers, TiersArg::Windows(_)) {
                bail!(
                    "--sku-catalog/--budget-ms search SKU assignments and boundaries, \
                     so they need the `--tiers K` fleet-size form, not fixed windows"
                );
            }
            let res = run_anytime(&input, k, catalog.as_ref(), flags)?;
            print_tiered("incumbent", &res.plan, None, catalog.as_ref());
            return Ok(());
        }
        let t0 = std::time::Instant::now();
        let (best, grid) = sweep_tiered(&input, k)?;
        let dt = t0.elapsed();
        println!(
            "swept {} K={k} cells in {:.1} ms",
            grid.len(),
            dt.as_secs_f64() * 1e3
        );
        print_tiered("optimum", &best, None, None);
        if let TiersArg::Windows(windows) = tiers {
            let fixed = plan_fixed_windows(&input, &windows)?;
            print_tiered("fixed --tiers windows", &fixed, Some(best.cost_yr), None);
        }
        return Ok(());
    }

    let cands = candidate_boundaries(&input);
    println!("candidate boundaries: {cands:?}");
    let t0 = std::time::Instant::now();
    let (best, grid) = sweep_full(&input)?;
    let dt = t0.elapsed();
    println!(
        "swept {} cells in {:.1} ms",
        grid.len(),
        dt.as_secs_f64() * 1e3
    );
    print_plan("optimum", &best, None);
    println!("\ncost grid (K$/yr), gamma -> 1.0 .. 2.0:");
    for &b in &cands {
        let row: Vec<String> = grid
            .iter()
            .filter(|(bb, _, _)| *bb == b)
            .map(|(_, _, c)| fmt_int(c / 1000.0))
            .collect();
        println!("  B={b:6}: {}", row.join(" "));
    }
    Ok(())
}

fn cmd_tables(flags: &HashMap<String, String>) -> Result<()> {
    let fast = flags.contains_key("fast");
    let only: Option<u32> = flags.get("only").map(|s| s.parse()).transpose()?;
    if let Some(n) = only {
        if !(1..=12).contains(&n) {
            bail!("--only must name a table in 1..=12, got {n}");
        }
    }
    let want = |n: u32| only.is_none() || only == Some(n);
    let (docs, des_n, fid_n, auto_n) =
        if fast { (10, 3_000, 30, 8_000) } else { (60, 30_000, 300, 40_000) };

    if want(1) {
        experiments::table1().print();
    }
    if want(2) {
        experiments::table2().print();
    }
    if want(3) {
        experiments::table3(1000.0).print();
    }
    if want(4) {
        experiments::table4(docs).print();
    }
    if want(5) {
        experiments::table5(1000.0, des_n).print();
    }
    if want(6) {
        experiments::table6(&[100.0, 200.0, 500.0, 1000.0, 2000.0]).print();
    }
    if want(7) {
        experiments::table7(fid_n, experiments::artifacts_dir().as_deref()).print();
    }
    if want(8) {
        experiments::table8(1000.0, if fast { 3 } else { 4 }).print();
    }
    if want(9) {
        experiments::table9(auto_n).print();
    }
    if want(10) {
        experiments::table10(1000.0, des_n).print();
    }
    if want(11) {
        experiments::table11(auto_n).print();
    }
    if want(12) {
        experiments::table12(des_n).print();
    }
    Ok(())
}

/// `--kv FRAC` plus the admission knobs shared by simulate and autoscale.
/// Returns default (all-off) opts when neither is given — the engines'
/// bit-identical path.
fn kv_arg(flags: &HashMap<String, String>) -> Result<KvFleetOpts> {
    let cap_frac = match flags.get("kv") {
        None => None,
        Some(v) => {
            let f: f64 = v.parse().with_context(|| format!("--kv {v}"))?;
            if !f.is_finite() || f <= 0.0 || f > 1.0 {
                bail!("--kv must be a fraction inside (0, 1], got {f}");
            }
            Some(f)
        }
    };
    let wants_admit = flags.contains_key("admit")
        || flags.contains_key("admit-high")
        || flags.contains_key("admit-low")
        || flags.contains_key("defer-s")
        || flags.contains_key("max-defers")
        || flags.contains_key("gamma-tighten");
    let admit = if wants_admit {
        if cap_frac.is_none() {
            bail!("--admit watches KV occupancy; add --kv FRAC to enable the ledger");
        }
        let d = AdmitConfig::default();
        let cfg = AdmitConfig {
            high_watermark: flag_f64(flags, "admit-high", d.high_watermark)?,
            low_watermark: flag_f64(flags, "admit-low", d.low_watermark)?,
            defer_s: flag_f64(flags, "defer-s", d.defer_s)?,
            max_defers: flag_count(flags, "max-defers", d.max_defers as u64)? as u32,
            gamma_tighten: flag_f64(flags, "gamma-tighten", d.gamma_tighten)?,
        };
        cfg.validate()?;
        Some(cfg)
    } else {
        None
    };
    Ok(KvFleetOpts { cap_frac, admit })
}

/// `--redundancy k|k1,k2,..`: per-tier N+k hot-spare counts (a single
/// value broadcasts to every tier).
fn redundancy_arg(flags: &HashMap<String, String>) -> Result<Vec<u64>> {
    let Some(s) = flags.get("redundancy") else {
        return Ok(Vec::new());
    };
    let mut ks = Vec::new();
    for part in s.split(',') {
        let v: u64 = part
            .trim()
            .parse()
            .with_context(|| format!("--redundancy entry `{part}`"))?;
        ks.push(v);
    }
    Ok(ks)
}

/// Chaos/failover flags shared semantics: `--chaos plan.json` loads a
/// deterministic fault plan; `--failover` (plus optional watermark knobs)
/// arms cross-tier spill routing.
fn chaos_arg(flags: &HashMap<String, String>) -> Result<ChaosOpts> {
    let faults = match flags.get("chaos") {
        None => None,
        Some(path) => Some(FaultPlan::from_file(path)?),
    };
    let wants_failover = flags.contains_key("failover")
        || flags.contains_key("spill-watermark")
        || flags.contains_key("recover-watermark")
        || flags.contains_key("gamma-boost");
    let failover = if wants_failover {
        let d = FailoverConfig::default();
        let cfg = FailoverConfig {
            spill_watermark: flag_f64(flags, "spill-watermark", d.spill_watermark)?,
            recover_watermark: flag_f64(flags, "recover-watermark", d.recover_watermark)?,
            gamma_boost: flag_f64(flags, "gamma-boost", d.gamma_boost)?,
        };
        if !(0.0..=1.0).contains(&cfg.spill_watermark)
            || !(0.0..=1.0).contains(&cfg.recover_watermark)
            || cfg.recover_watermark < cfg.spill_watermark
        {
            bail!(
                "watermarks must satisfy 0 <= spill <= recover <= 1, got spill={} recover={}",
                cfg.spill_watermark,
                cfg.recover_watermark
            );
        }
        if !(1.0..=2.0).contains(&cfg.gamma_boost) {
            bail!("--gamma-boost must be within [1.0, 2.0], got {}", cfg.gamma_boost);
        }
        Some(cfg)
    } else {
        None
    };
    Ok(ChaosOpts { faults, failover })
}

fn cmd_autoscale(flags: &HashMap<String, String>) -> Result<()> {
    let w = workload_arg(flags)?;
    let base = flag_pos_f64(flags, "lambda", 400.0)?;
    let n = flag_count(flags, "requests", 40_000)? as usize;
    let spec_str = flags
        .get("arrivals")
        .map(String::as_str)
        .unwrap_or("diurnal:amp=0.6,period=120");
    let model = parse_arrival_spec(spec_str, base)?;

    let input0 = PlanInput::new(w.clone(), model.rate_hint());
    let fleet_spec = match tiers_arg(flags)? {
        None => input0.gpu.fleet_spec(&[w.b_short]),
        Some(TiersArg::Windows(windows)) => {
            let mut gpu = input0.gpu.clone();
            gpu.c_max_long = windows[windows.len() - 1];
            gpu.fleet_spec(&windows[..windows.len() - 1])
        }
        Some(TiersArg::K(_)) => {
            bail!("autoscale --tiers needs explicit windows (e.g. 4096,65536)")
        }
    };
    fleet_spec.validate()?;
    let mut input0 = input0;
    input0.gpu.c_max_long = fleet_spec.tiers[fleet_spec.k() - 1].c_max;
    input0.redundancy = redundancy_arg(flags)?;
    let chaos = chaos_arg(flags)?;
    let kv = kv_arg(flags)?;
    if let Some(f) = kv.cap_frac {
        let policy = KvPlanPolicy { cap_frac: f };
        for (i, t) in fleet_spec.tiers.iter().enumerate() {
            policy.validate(i, t.n_max, t.c_max)?;
        }
    }

    let max_retries = flags
        .get("max-retries")
        .map(|v| v.parse::<u32>().with_context(|| format!("--max-retries {v}")))
        .transpose()?;
    let seasonal_period_s = match flags.get("forecast-seasonal") {
        None => None,
        Some(v) => {
            let p: f64 = v.parse().with_context(|| format!("--forecast-seasonal {v}"))?;
            if !p.is_finite() || p <= 0.0 {
                bail!("--forecast-seasonal must be a positive period in seconds, got {p}");
            }
            Some(p)
        }
    };
    let epoch_s = flag_pos_f64(flags, "epoch", 10.0)?;
    let cfg = AutoscaleConfig {
        epoch_s,
        window_s: flag_pos_f64(flags, "window", epoch_s * 2.0)?,
        provision_delay_s: flag_f64(flags, "provision", epoch_s * 0.5)?,
        replanning: !flags.contains_key("no-replan"),
        forecast: flags.contains_key("forecast"),
        max_retries,
        seasonal_period_s,
        ..AutoscaleConfig::default()
    };
    if cfg.provision_delay_s < 0.0 {
        bail!("--provision must be non-negative");
    }

    let initial = plan_spec_sweep_gamma(&input0, &fleet_spec)?;
    println!(
        "initial plan (lambda0 = {:.1} req/s): gpus = {:?}, arrivals = {spec_str}",
        input0.lambda,
        initial.gpu_counts()
    );
    let report = simulate_autoscale_kv(&w, model, n, &input0, initial, &cfg, 42, &chaos, &kv);

    for e in &report.epochs {
        println!("{}", e.summary_line());
    }
    if chaos.faults.is_some() {
        println!(
            "chaos: {} crash(es), {} preemption(s), {} in-flight kill(s), \
             {} retry(ies) (max {} per request), {} dropped, {} spilled route(s)",
            report.crashes,
            report.preemptions,
            report.killed_in_flight,
            report.retries_total,
            report.max_retry,
            report.dropped_retries,
            report.spilled,
        );
    }
    if kv.cap_frac.is_some() {
        println!(
            "kv admission: {} admitted, {} deferred, {} recompressed, {} shed, \
             {} kv-blocked, {} kv violation(s)",
            report.admit.admitted,
            report.admit.deferred,
            report.admit.recompressed,
            report.admit.shed,
            report.kv_blocked,
            report.kv_violations,
        );
    }
    let violated = 1.0 - report.slo_ok_frac;
    println!(
        "totals: {} of {} completed ({} censored), {} compressed, {:.2} GPU-hours, \
         ${:.2}, slo-ok {:.0}% of {} epochs, {} layout switch(es), final gpus {:?}",
        report.completed,
        report.n_total,
        report.censored,
        report.n_compressed,
        report.gpu_hours,
        report.cost,
        report.slo_ok_frac * 100.0,
        report.epochs.len(),
        report.layout_switches,
        report.final_gpus,
    );

    if let Some(path) = flags.get("out") {
        std::fs::write(path, EpochMetrics::series_to_json(&report.epochs))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote per-epoch metrics to {path}");
    }
    if report.censored != 0 {
        bail!("{} request(s) never completed", report.censored);
    }
    // A clamped (past-scheduled) event means the controller computed an
    // impossible schedule; that must fail the run — and the CI smoke job
    // that wraps it — not silently round time forward.
    if report.time_travel_events != 0 {
        bail!(
            "{} event(s) were scheduled in the past and clamped",
            report.time_travel_events
        );
    }
    let budget = flag_f64(flags, "max-violation-frac", 1.0)?;
    if !(0.0..=1.0).contains(&budget) {
        bail!("--max-violation-frac must be in [0, 1], got {budget}");
    }
    if violated > budget + 1e-12 {
        bail!(
            "SLO violated in {:.0}% of epochs (budget {:.0}%)",
            violated * 100.0,
            budget * 100.0
        );
    }
    // KV-ledger violations are a correctness failure (the reservation
    // admission must never oversubscribe), not a tunable budget.
    if report.kv_violations != 0 {
        bail!(
            "{} KV-capacity violation(s) in the DES ledger",
            report.kv_violations
        );
    }
    let shed_budget = flag_f64(flags, "max-shed-frac", 1.0)?;
    if !(0.0..=1.0).contains(&shed_budget) {
        bail!("--max-shed-frac must be in [0, 1], got {shed_budget}");
    }
    let shed_frac = report.admit.shed as f64 / report.n_total.max(1) as f64;
    if shed_frac > shed_budget + 1e-12 {
        bail!(
            "shed {:.2}% of offered load (budget {:.2}%)",
            shed_frac * 100.0,
            shed_budget * 100.0
        );
    }
    Ok(())
}

/// `fleetopt simulate --stress`: the 5M-request / 512-GPU / K=4 diurnal
/// stress archetype (ROADMAP "DES performance"). Must complete in seconds
/// in release — CI gates the same scenario through the des_throughput
/// bench.
fn cmd_stress(flags: &HashMap<String, String>) -> Result<()> {
    // The stress archetype is a fixed synthetic azure scenario; refuse
    // flags it would silently ignore rather than mislead.
    for key in ["workload", "config", "lambda", "tiers", "gamma", "b-short"] {
        if flags.contains_key(key) {
            bail!(
                "--stress runs the fixed synthetic azure scenario; --{key} is not \
                 supported (tunables: --requests, --gpus, --queue, --seed)"
            );
        }
    }
    let defaults = StressConfig::default();
    // Seeds are raw u64 (0 is valid; values above 2^53 must not round-trip
    // through f64), so bypass the numeric-flag helpers.
    let seed = match flags.get("seed") {
        None => defaults.seed,
        Some(v) => v.parse::<u64>().with_context(|| format!("--seed {v}"))?,
    };
    let cfg = StressConfig {
        n_requests: flag_count(flags, "requests", defaults.n_requests as u64)? as usize,
        n_gpus_total: flag_count(flags, "gpus", defaults.n_gpus_total)?,
        seed,
        queue_impl: match flags.get("queue").map(String::as_str) {
            None | Some("calendar") => QueueImpl::Calendar,
            Some("heap") => QueueImpl::BinaryHeap,
            Some(other) => bail!("--queue must be `calendar` or `heap`, got `{other}`"),
        },
        ..defaults
    };
    println!(
        "stress: {} requests, {} GPUs, K={} windows {:?}, diurnal amp {} ({} cycles), {:?}",
        cfg.n_requests,
        cfg.n_gpus_total,
        cfg.windows.len(),
        cfg.windows,
        cfg.diurnal_amp,
        cfg.periods,
        cfg.queue_impl,
    );
    let rep = run_stress(&cfg);
    println!(
        "sized: lambda_base={:.1} req/s over {:.0} s horizon, gpus/tier {:?}",
        rep.lambda_base, rep.horizon_s, rep.gpus
    );
    for ti in 0..rep.gpus.len() {
        println!(
            "tier {ti}: n={:4} rho={:.3} ttft99={:.0}ms wait99={:.0}ms",
            rep.gpus[ti],
            rep.utilization[ti],
            rep.ttft_p99_s[ti] * 1e3,
            rep.wait_p99_s[ti] * 1e3,
        );
    }
    println!(
        "completed {}/{} ({} censored, {} compressed), {} events in {:.2} s \
         (gen {:.2} s + sim {:.2} s) = {:.2} M events/s",
        rep.completed,
        rep.n_requests,
        rep.censored,
        rep.n_compressed,
        rep.events,
        rep.wall_s,
        rep.gen_s,
        rep.sim_s,
        rep.events_per_s() / 1e6,
    );
    if rep.completed != rep.n_requests {
        bail!("{} request(s) never completed", rep.n_requests - rep.completed);
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("stress") {
        return cmd_stress(flags);
    }
    for key in ["admit", "admit-high", "admit-low", "defer-s", "max-defers", "gamma-tighten"] {
        if flags.contains_key(key) {
            bail!("--{key} is an autoscale flag (the offline tiered DES has no admission loop)");
        }
    }
    let w = workload_arg(flags)?;
    let lambda = flag_pos_f64(flags, "lambda", 1000.0)?;
    let n = flag_count(flags, "requests", 30_000)? as usize;
    let faults = match flags.get("chaos") {
        None => FaultPlan::default(),
        Some(path) => FaultPlan::from_file(path)?,
    };

    if let Some(tiers) = tiers_arg(flags)? {
        let input = PlanInput::new(w.clone(), lambda);
        let plan = match tiers {
            TiersArg::Windows(windows) => plan_fixed_windows(&input, &windows)?,
            TiersArg::K(k) => sweep_tiered(&input, k)?.0,
        };
        let kv_policy = kv_arg(flags)?.cap_frac.map(|f| KvPlanPolicy { cap_frac: f });
        if let Some(policy) = &kv_policy {
            for (i, t) in plan.spec.tiers.iter().enumerate() {
                policy.validate(i, t.n_max, t.c_max)?;
            }
        }
        print_tiered("K-tier plan", &plan, None, None);
        let sim = simulate_fleet_tiered_kv(&w, &plan, &input.gpu, lambda, n, 42, &faults, kv_policy);
        for (i, (pool, res)) in plan.tiers.iter().zip(&sim.tiers).enumerate() {
            match res {
                Some(r) => {
                    let mut ttft = r.ttft.clone();
                    let chaos = if r.crashes + r.preemptions > 0 {
                        format!(
                            " crashes={} preempt={} killed={}",
                            r.crashes, r.preemptions, r.killed_in_flight
                        )
                    } else {
                        String::new()
                    };
                    println!(
                        "tier {i}: n={:5} rho_ana={:.3} rho_des={:.3} err={:+.1}% ttft99 des={:.0}ms{chaos}",
                        pool.n_gpus,
                        pool.rho_ana(),
                        r.utilization,
                        (pool.rho_ana() - r.utilization) / r.utilization * 100.0,
                        ttft.p99() * 1e3,
                    );
                }
                None => println!("tier {i}: no traffic"),
            }
        }
        println!(
            "compressed at boundaries: {:?} of {} requests",
            sim.routed.n_compressed_at, sim.routed.n_total
        );
        if kv_policy.is_some() {
            let utils: Vec<String> = sim
                .tiers
                .iter()
                .flatten()
                .map(|r| format!("{:.3}", r.kv_util))
                .collect();
            let blocked: u64 = sim.tiers.iter().flatten().map(|r| r.kv_blocked).sum();
            let viol: u64 = sim.tiers.iter().flatten().map(|r| r.kv_violations).sum();
            println!(
                "kv: per-tier util [{}], {} blocked admission(s), {} violation(s)",
                utils.join(", "),
                blocked,
                viol
            );
            if viol != 0 {
                bail!("{viol} KV-capacity violation(s) in the DES ledger");
            }
        }
        return Ok(());
    }
    if flags.contains_key("chaos") {
        bail!("simulate --chaos needs a K-tier fleet (add --tiers)");
    }
    if flags.contains_key("kv") {
        bail!("simulate --kv needs a K-tier fleet (add --tiers)");
    }

    let (rows, _) = experiments::table5_validate(&w, lambda, n, 42);
    for r in rows {
        println!(
            "{:12} {:5} n={:5} rho_ana={:.3} rho_des={:.3} err={:+.1}% ttft99 ana={:.0}ms des={:.0}ms",
            r.workload,
            r.pool,
            r.n_gpus,
            r.rho_ana,
            r.rho_des,
            r.error * 100.0,
            r.ttft_p99_ana * 1e3,
            r.ttft_p99_des * 1e3
        );
    }
    Ok(())
}

fn cmd_compress(flags: &HashMap<String, String>) -> Result<()> {
    let tokens = flag_u32(flags, "tokens", 9000)?;
    let seed = flag_f64(flags, "seed", 7.0)? as u64;
    let mut rng = Rng::new(seed);
    let doc = corpus::generate_document(
        &CorpusConfig {
            target_tokens: tokens,
            ..Default::default()
        },
        &mut rng,
    );
    let budget = flag_u32(flags, "budget", (tokens as f64 * 0.8) as u32)?;
    let t0 = std::time::Instant::now();
    let c = compress(&doc, budget);
    let dt = t0.elapsed().as_secs_f64() * 1e3;
    let f = fidelity::measure(&doc, &c.text);
    println!(
        "compressed {} -> {} tokens (budget {budget}, ok={}) in {dt:.1} ms",
        c.original_tokens, c.compressed_tokens, c.ok
    );
    println!(
        "fidelity: rouge-l-recall={:.3} tfidf-cos={:.3} reduction={:.1}%",
        f.rouge_l_recall,
        f.tfidf_cosine,
        f.token_reduction * 100.0
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .or_else(experiments::artifacts_dir)
        .context("artifacts not found; run `make artifacts`")?;
    let n = flag_count(flags, "requests", 40)? as usize;
    let rate = flag_pos_f64(flags, "rate", 40.0)?;
    let enable_cr = !flags.contains_key("no-cr");

    // Live-scale boundaries: the default mirrors the artifact set's dense
    // 256-token short pool; `--tiers` accepts an explicit window list.
    let gateway = match tiers_arg(flags)? {
        None => GatewayConfig::two_tier(224, 1.5, enable_cr),
        Some(TiersArg::Windows(windows)) => {
            GatewayConfig::tiered(&windows[..windows.len() - 1], 1.5, enable_cr)
        }
        Some(TiersArg::K(_)) => {
            bail!("serve --tiers needs explicit windows (e.g. 128,224,512)")
        }
    };
    let k = gateway.n_tiers();

    // Ingress concurrency/caching (§Perf, PR 8): default shards batch
    // admission automatically and memoizes 1024 routing decisions; both
    // settings are bit-identical to `--gateway-workers 1` without a cache.
    let opts = AdmissionOpts {
        gateway_workers: flag_count0(flags, "gateway-workers", 0)? as usize,
        route_cache_cap: flag_count0(flags, "route-cache-cap", 1024)? as usize,
    };

    let items: Vec<ServeItem> = match flags.get("trace") {
        // Replay a JSONL text trace, streamed from disk line by line.
        Some(path) => traces::load_text_trace(path)?
            .into_iter()
            .map(|t| ServeItem {
                text: t.text,
                max_output: t.max_output,
                arrival_offset_s: t.arrival_s,
            })
            .collect(),
        None => {
            let mut rng = Rng::new(11);
            let mut t = 0.0;
            (0..n)
                .map(|i| {
                    t += rng.exp(rate);
                    let target = match i % 10 {
                        0..=6 => rng.range(40, 150) as u32,
                        7 | 8 => rng.range(240, 320) as u32,
                        _ => rng.range(400, 700) as u32,
                    };
                    ServeItem {
                        text: corpus::generate_document(
                            &CorpusConfig {
                                target_tokens: target,
                                ..Default::default()
                            },
                            &mut rng,
                        ),
                        max_output: 16,
                        arrival_offset_s: t,
                    }
                })
                .collect()
        }
    };
    if items.is_empty() {
        bail!("no requests to serve (empty trace?)");
    }
    let cfg = ServeConfig {
        gateway,
        replicas: vec![1; k],
    };
    let mut report = serve_with(&dir, &cfg, opts, items, 0.05)?;
    for tier in &mut report.tiers {
        println!("{}", tier.summary());
    }
    println!(
        "compressed={} routed={:?} throughput={:.1} req/s gateway={:.2} ms/req",
        report.n_compressed,
        report.n_routed,
        report.throughput_rps,
        report.mean_gateway_s * 1e3
    );
    let cs = report.route_cache;
    println!(
        "admission: workers={} route-cache cap={} hits={} misses={} rate={:.1}% evictions={}",
        if opts.gateway_workers == 0 {
            "auto".to_string()
        } else {
            opts.gateway_workers.to_string()
        },
        opts.route_cache_cap,
        cs.hits,
        cs.misses,
        cs.hit_rate() * 100.0,
        cs.evictions,
    );
    if let Some(t) = report.shard_timing {
        println!(
            "last sharded batch: workers={} features={:.2}ms fold={:.2}ms ladder={:.2}ms emit={:.2}ms",
            t.workers,
            t.features_s * 1e3,
            t.fold_s * 1e3,
            t.ladder_s * 1e3,
            t.emit_s * 1e3
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let (_pos, flags) = parse_args(&args[1..]);
    if flags.contains_key("threads") {
        let n = flag_count(&flags, "threads", 1)?;
        fleetopt::util::par::set_thread_cap(n as usize);
    }
    match args[0].as_str() {
        "plan" => cmd_plan(&flags),
        "sweep" => cmd_sweep(&flags),
        "tables" => cmd_tables(&flags),
        "simulate" => cmd_simulate(&flags),
        "autoscale" => cmd_autoscale(&flags),
        "compress" => cmd_compress(&flags),
        "serve" => cmd_serve(&flags),
        "help" | "--help" | "-h" => usage(),
        other => bail!("unknown subcommand `{other}` (try `fleetopt help`)"),
    }
}
