//! `fleetopt` — the FleetOpt launcher.
//!
//! Subcommands:
//!   plan      — plan a fleet for one workload (Algorithm 1 at a fixed B)
//!   sweep     — full Algorithm-1 sweep over candidate boundaries
//!   tables    — regenerate the paper's evaluation tables (1–7)
//!   simulate  — DES validation of the analytical model (Table 5)
//!   compress  — compress a borderline sample and report fidelity
//!   serve     — live two-pool serving demo on the AOT artifacts
//!
//! Hand-rolled argument parsing (no clap offline; DESIGN.md §1).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::compress::extractive::compress;
use fleetopt::compress::fidelity;
use fleetopt::coordinator::{serve, ServeConfig, ServeItem};
use fleetopt::experiments;
use fleetopt::planner::{
    candidate_boundaries, plan_fleet, plan_homogeneous, sweep_full, sweep_gamma, Plan,
    PlanInput,
};
use fleetopt::router::GatewayConfig;
use fleetopt::util::rng::Rng;
use fleetopt::util::table::fmt_int;
use fleetopt::workload::traces;

fn usage() -> ! {
    eprintln!(
        "fleetopt — analytical fleet provisioning with Compress-and-Route

USAGE:
  fleetopt plan     --workload <azure|lmsys|agent> [--config F.json] [--lambda N] [--gamma G] [--b-short B]
  fleetopt sweep    --workload <name> [--config F.json] [--lambda N]
  fleetopt tables   [--only 1..7] [--fast]
  fleetopt simulate --workload <name> [--lambda N] [--requests N]
  fleetopt compress [--tokens N] [--budget N] [--seed N]
  fleetopt serve    [--requests N] [--rate R] [--no-cr] [--artifacts DIR]
"
    );
    std::process::exit(2);
}

/// Tiny flag parser: `--key value` pairs plus positionals.
fn parse_args(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
    }
}

fn workload_arg(flags: &HashMap<String, String>) -> Result<fleetopt::workload::traces::Workload> {
    if let Some(path) = flags.get("config") {
        return fleetopt::workload::traces::Workload::from_config_file(path);
    }
    let name = flags
        .get("workload")
        .map(String::as_str)
        .unwrap_or("azure");
    traces::by_name(name).with_context(|| format!("unknown workload `{name}`"))
}

fn print_plan(label: &str, p: &Plan, baseline: Option<f64>) {
    let savings = baseline
        .map(|b| format!(" savings={:.1}%", (1.0 - p.cost_yr / b) * 100.0))
        .unwrap_or_default();
    println!(
        "{label:28} B={:6} gamma={:.1} n_s={:5} n_l={:5} total={:5} cost/yr=${}K{}",
        p.b_short,
        p.gamma,
        p.short.n_gpus,
        p.long.n_gpus,
        p.total_gpus(),
        fmt_int(p.cost_yr / 1000.0),
        savings,
    );
}

fn cmd_plan(flags: &HashMap<String, String>) -> Result<()> {
    let w = workload_arg(flags)?;
    let lambda = flag_f64(flags, "lambda", 1000.0)?;
    let b_short = flag_f64(flags, "b-short", w.b_short as f64)? as u32;
    let input = PlanInput::new(w.clone(), lambda);

    let homo = plan_homogeneous(&input)?;
    print_plan("homogeneous", &homo, None);
    let pr = plan_fleet(&input, b_short, 1.0)?;
    print_plan("pool-routing", &pr, Some(homo.cost_yr));
    if let Some(g) = flags.get("gamma") {
        let gamma: f64 = g.parse()?;
        let p = plan_fleet(&input, b_short, gamma)?;
        print_plan(&format!("pr+c&r (gamma={gamma})"), &p, Some(homo.cost_yr));
    }
    let opt = sweep_gamma(&input, b_short)?;
    print_plan("fleetopt (gamma*)", &opt, Some(homo.cost_yr));
    println!(
        "\npools at gamma*: short rho={:.3} ttft99={:.0}ms | long rho={:.3} ttft99={:.0}ms",
        opt.short.rho_ana(),
        opt.short.ttft_p99() * 1e3,
        opt.long.rho_ana(),
        opt.long.ttft_p99() * 1e3,
    );
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let w = workload_arg(flags)?;
    let lambda = flag_f64(flags, "lambda", 1000.0)?;
    let input = PlanInput::new(w.clone(), lambda);
    let cands = candidate_boundaries(&input);
    println!("candidate boundaries: {cands:?}");
    let t0 = std::time::Instant::now();
    let (best, grid) = sweep_full(&input)?;
    let dt = t0.elapsed();
    println!(
        "swept {} cells in {:.1} ms",
        grid.len(),
        dt.as_secs_f64() * 1e3
    );
    print_plan("optimum", &best, None);
    println!("\ncost grid (K$/yr), gamma -> 1.0 .. 2.0:");
    for &b in &cands {
        let row: Vec<String> = grid
            .iter()
            .filter(|(bb, _, _)| *bb == b)
            .map(|(_, _, c)| fmt_int(c / 1000.0))
            .collect();
        println!("  B={b:6}: {}", row.join(" "));
    }
    Ok(())
}

fn cmd_tables(flags: &HashMap<String, String>) -> Result<()> {
    let fast = flags.contains_key("fast");
    let only: Option<u32> = flags.get("only").map(|s| s.parse()).transpose()?;
    let want = |n: u32| only.is_none() || only == Some(n);
    let (docs, des_n, fid_n) = if fast { (10, 3_000, 30) } else { (60, 30_000, 300) };

    if want(1) {
        experiments::table1().print();
    }
    if want(2) {
        experiments::table2().print();
    }
    if want(3) {
        experiments::table3(1000.0).print();
    }
    if want(4) {
        experiments::table4(docs).print();
    }
    if want(5) {
        experiments::table5(1000.0, des_n).print();
    }
    if want(6) {
        experiments::table6(&[100.0, 200.0, 500.0, 1000.0, 2000.0]).print();
    }
    if want(7) {
        experiments::table7(fid_n, experiments::artifacts_dir().as_deref()).print();
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let w = workload_arg(flags)?;
    let lambda = flag_f64(flags, "lambda", 1000.0)?;
    let n = flag_f64(flags, "requests", 30_000.0)? as usize;
    let (rows, _) = experiments::table5_validate(&w, lambda, n, 42);
    for r in rows {
        println!(
            "{:12} {:5} n={:5} rho_ana={:.3} rho_des={:.3} err={:+.1}% ttft99 ana={:.0}ms des={:.0}ms",
            r.workload,
            r.pool,
            r.n_gpus,
            r.rho_ana,
            r.rho_des,
            r.error * 100.0,
            r.ttft_p99_ana * 1e3,
            r.ttft_p99_des * 1e3
        );
    }
    Ok(())
}

fn cmd_compress(flags: &HashMap<String, String>) -> Result<()> {
    let tokens = flag_f64(flags, "tokens", 9000.0)? as u32;
    let seed = flag_f64(flags, "seed", 7.0)? as u64;
    let mut rng = Rng::new(seed);
    let doc = corpus::generate_document(
        &CorpusConfig {
            target_tokens: tokens,
            ..Default::default()
        },
        &mut rng,
    );
    let budget = flag_f64(flags, "budget", tokens as f64 * 0.8)? as u32;
    let t0 = std::time::Instant::now();
    let c = compress(&doc, budget);
    let dt = t0.elapsed().as_secs_f64() * 1e3;
    let f = fidelity::measure(&doc, &c.text);
    println!(
        "compressed {} -> {} tokens (budget {budget}, ok={}) in {dt:.1} ms",
        c.original_tokens, c.compressed_tokens, c.ok
    );
    println!(
        "fidelity: rouge-l-recall={:.3} tfidf-cos={:.3} reduction={:.1}%",
        f.rouge_l_recall,
        f.tfidf_cosine,
        f.token_reduction * 100.0
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .or_else(experiments::artifacts_dir)
        .context("artifacts not found; run `make artifacts`")?;
    let n = flag_f64(flags, "requests", 40.0)? as usize;
    let rate = flag_f64(flags, "rate", 40.0)?;
    let enable_cr = !flags.contains_key("no-cr");

    let mut rng = Rng::new(11);
    let mut t = 0.0;
    let items: Vec<ServeItem> = (0..n)
        .map(|i| {
            t += rng.exp(rate);
            let target = match i % 10 {
                0..=6 => rng.range(40, 150) as u32,
                7 | 8 => rng.range(240, 320) as u32,
                _ => rng.range(400, 700) as u32,
            };
            ServeItem {
                text: corpus::generate_document(
                    &CorpusConfig {
                        target_tokens: target,
                        ..Default::default()
                    },
                    &mut rng,
                ),
                max_output: 16,
                arrival_offset_s: t,
            }
        })
        .collect();
    let cfg = ServeConfig {
        gateway: GatewayConfig {
            b_short: 224,
            gamma: 1.5,
            enable_cr,
        },
        replicas_short: 1,
        replicas_long: 1,
    };
    let mut report = serve(&dir, &cfg, items, 0.05)?;
    println!("{}", report.short.summary());
    println!("{}", report.long.summary());
    println!(
        "compressed={} short={} long={} throughput={:.1} req/s gateway={:.2} ms/req",
        report.n_compressed,
        report.n_routed_short,
        report.n_routed_long,
        report.throughput_rps,
        report.mean_gateway_s * 1e3
    );
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let (_pos, flags) = parse_args(&args[1..]);
    match args[0].as_str() {
        "plan" => cmd_plan(&flags),
        "sweep" => cmd_sweep(&flags),
        "tables" => cmd_tables(&flags),
        "simulate" => cmd_simulate(&flags),
        "compress" => cmd_compress(&flags),
        "serve" => cmd_serve(&flags),
        "help" | "--help" | "-h" => usage(),
        other => bail!("unknown subcommand `{other}` (try `fleetopt help`)"),
    }
}
