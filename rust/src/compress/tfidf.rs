//! TF-IDF sentence salience — 35% of the composite score (paper §5.2,
//! cf. Li et al. 2023a) — plus the document-level TF-IDF vectors used by
//! the fidelity study's cosine similarity (Table 7).
//!
//! IDF is computed *within* the document over sentences (df = number of
//! sentences containing the word): no external corpus is needed at the
//! gateway, and rare-within-prompt terms are exactly the ones extraction
//! must keep.

use crate::compress::doc::Document;

/// Per-sentence mean TF-IDF salience.
pub fn sentence_scores(doc: &Document) -> Vec<f64> {
    let mut df = Vec::new();
    let mut tf = Vec::new();
    let mut out = Vec::new();
    sentence_scores_into(doc, &mut df, &mut tf, &mut out);
    out
}

/// Buffer-reusing variant of [`sentence_scores`] (§Perf): `df`/`tf` are
/// caller-owned counting scratch, results land in `out`. Output is
/// identical to [`sentence_scores`].
pub fn sentence_scores_into(
    doc: &Document,
    df: &mut Vec<u32>,
    tf: &mut Vec<u32>,
    out: &mut Vec<f64>,
) {
    out.clear();
    let n = doc.n_sentences();
    if n == 0 {
        return;
    }
    let total_words = count_df_tf(doc, df, tf);
    let idf = |w: u32| ((n as f64 + 1.0) / (df[w as usize] as f64 + 0.5)).ln();

    out.extend(doc.word_seqs.iter().map(|seq| {
        if seq.is_empty() {
            return 0.0;
        }
        let sum: f64 = seq
            .iter()
            .map(|&w| {
                let tfw = tf[w as usize] as f64 / total_words.max(1) as f64;
                tfw * idf(w)
            })
            .sum();
        sum / seq.len() as f64
    }));
}

/// Document frequency + whole-document term frequency per word id into
/// caller scratch; returns the total word count.
fn count_df_tf(doc: &Document, df: &mut Vec<u32>, tf: &mut Vec<u32>) -> u64 {
    df.clear();
    df.resize(doc.vocab, 0);
    for set in &doc.word_sets {
        for &w in set {
            df[w as usize] += 1;
        }
    }
    tf.clear();
    tf.resize(doc.vocab, 0);
    let mut total_words = 0u64;
    for seq in &doc.word_seqs {
        for &w in seq {
            tf[w as usize] += 1;
        }
        total_words += seq.len() as u64;
    }
    total_words
}

/// SoA fast path of [`sentence_scores_into`] (§Perf PR 6, `simd`
/// feature): the per-word weight `(tf_w / total) * idf_w` is computed
/// once per distinct word id into the caller's `wt` table and gathered
/// per occurrence.
///
/// Identity: the table entry is the exact f64 product the scalar path
/// recomputes at every occurrence of word `w` (same two factors, same
/// ops), and the per-sentence gather adds those values in the same
/// sequence order with the same sequential `sum()`, so the output is
/// bit-identical (property-tested). The win is one `ln` per *distinct*
/// word instead of one per *occurrence* — corpus documents repeat a small
/// vocabulary heavily, so the transcendental count drops by the
/// occurrences-per-word ratio. Falls back to the scalar path when SIMD
/// dispatch is off (`wt` is then left cleared).
pub fn sentence_scores_soa(
    doc: &Document,
    df: &mut Vec<u32>,
    tf: &mut Vec<u32>,
    wt: &mut Vec<f64>,
    out: &mut Vec<f64>,
) {
    wt.clear();
    #[cfg(feature = "simd")]
    if crate::util::simd::simd_active() {
        out.clear();
        let n = doc.n_sentences();
        if n == 0 {
            return;
        }
        let total_words = count_df_tf(doc, df, tf);
        wt.resize(doc.vocab, 0.0);
        for ((wt_w, &tf_w), &df_w) in wt.iter_mut().zip(tf.iter()).zip(df.iter()) {
            if tf_w > 0 {
                let tfw = tf_w as f64 / total_words.max(1) as f64;
                *wt_w = tfw * (((n as f64 + 1.0) / (df_w as f64 + 0.5)).ln());
            }
        }
        out.extend(doc.word_seqs.iter().map(|seq| {
            if seq.is_empty() {
                return 0.0;
            }
            let sum: f64 = seq.iter().map(|&w| wt[w as usize]).sum();
            sum / seq.len() as f64
        }));
        return;
    }
    sentence_scores_into(doc, df, tf, out);
}

/// Sparse TF-IDF vector for a full text against its own sentence-level IDF.
/// Returned sorted by word id; used for cosine similarity.
pub fn doc_vector(doc: &Document) -> Vec<(u32, f64)> {
    let n = doc.n_sentences().max(1);
    let mut df = vec![0u32; doc.vocab];
    for set in &doc.word_sets {
        for &w in set {
            df[w as usize] += 1;
        }
    }
    let mut tf = vec![0u32; doc.vocab];
    for seq in &doc.word_seqs {
        for &w in seq {
            tf[w as usize] += 1;
        }
    }
    (0..doc.vocab as u32)
        .filter(|&w| tf[w as usize] > 0)
        .map(|w| {
            let idf = ((n as f64 + 1.0) / (df[w as usize] as f64 + 0.5)).ln();
            (w, tf[w as usize] as f64 * idf)
        })
        .collect()
}

/// Cosine similarity between two **word-count** histograms built over a
/// shared vocabulary — the Table-7 "TF-IDF cosine" metric between original
/// and compressed prompt. Word strings (not per-doc interned ids) keep the
/// two texts in one space.
pub fn tfidf_cosine(original: &str, compressed: &str) -> f64 {
    use std::collections::HashMap;

    let wa = crate::compress::tokenizer::words(original);
    let wb = crate::compress::tokenizer::words(compressed);
    if wa.is_empty() || wb.is_empty() {
        return 0.0;
    }
    let mut ca: HashMap<&str, f64> = HashMap::new();
    for w in &wa {
        *ca.entry(w.as_str()).or_insert(0.0) += 1.0;
    }
    let mut cb: HashMap<&str, f64> = HashMap::new();
    for w in &wb {
        *cb.entry(w.as_str()).or_insert(0.0) += 1.0;
    }
    // IDF over the two-document "corpus" is constant for shared terms; a
    // plain count cosine is the standard implementation of this metric.
    let dot: f64 = ca
        .iter()
        .filter_map(|(w, a)| cb.get(w).map(|b| a * b))
        .sum();
    let na: f64 = ca.values().map(|a| a * a).sum::<f64>().sqrt();
    let nb: f64 = cb.values().map(|b| b * b).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rare_term_sentences_score_higher() {
        // "hyperparameter" appears once; "routing" appears everywhere.
        let d = Document::parse(
            "Routing moves traffic. Routing saves cost. \
             Routing hyperparameter tuning dominates the outcome. \
             Routing is simple.",
        );
        let s = sentence_scores(&d);
        let max_idx = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_idx, 2, "scores {s:?}");
    }

    #[test]
    fn empty_doc() {
        let d = Document::parse("");
        assert!(sentence_scores(&d).is_empty());
        assert!(doc_vector(&d).is_empty());
    }

    #[test]
    fn weight_table_path_is_bit_identical() {
        use crate::util::simd::{with_dispatch, Dispatch};
        for text in [
            "",
            "Only one sentence here.",
            "Routing moves traffic. Routing saves cost. \
             Routing hyperparameter tuning dominates the outcome. \
             Routing is simple. Repetition repetition repetition everywhere.",
        ] {
            let d = Document::parse(text);
            let want = sentence_scores(&d);
            let (mut df, mut tf, mut wt, mut out) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            for mode in [Dispatch::ForceScalar, Dispatch::ForceSimd] {
                with_dispatch(mode, || {
                    sentence_scores_soa(&d, &mut df, &mut tf, &mut wt, &mut out)
                });
                assert_eq!(want.len(), out.len(), "{mode:?} text={text:?}");
                for (i, (a, b)) in want.iter().zip(&out).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} sentence {i}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn doc_vector_sorted_and_positive() {
        let d = Document::parse("Alpha beta. Beta gamma. Gamma delta epsilon.");
        let v = doc_vector(&d);
        assert!(!v.is_empty());
        for w in v.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        assert!(v.iter().all(|(_, x)| *x > 0.0));
    }

    #[test]
    fn cosine_identity_is_one() {
        let t = "The long pool absorbs borderline traffic at high cost.";
        assert!((tfidf_cosine(t, t) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_disjoint_is_zero() {
        assert_eq!(tfidf_cosine("alpha beta gamma", "delta epsilon zeta"), 0.0);
    }

    #[test]
    fn cosine_of_subset_is_high() {
        let orig = "The planner derives the optimal fleet. The planner sweeps gamma. \
                    Extra filler sentence about unrelated matters.";
        let comp = "The planner derives the optimal fleet. The planner sweeps gamma.";
        let c = tfidf_cosine(orig, comp);
        assert!(c > 0.8, "cosine={c}");
    }

    #[test]
    fn cosine_symmetric() {
        let a = "alpha beta beta gamma";
        let b = "beta gamma gamma delta";
        assert!((tfidf_cosine(a, b) - tfidf_cosine(b, a)).abs() < 1e-12);
    }
}
