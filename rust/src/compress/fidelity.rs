//! Compression fidelity metrics (paper Appendix C / Table 7): ROUGE-L
//! recall and TF-IDF cosine, plus the embedding-cosine proxy computed by
//! the live runtime (BERTScore substitute — DESIGN.md §1).
//!
//! ROUGE-L uses a bit-parallel LCS (Allison–Dix) over words: O(n·m/64),
//! comfortably fast for 12K-token prompts.

use std::collections::HashMap;

use crate::compress::tokenizer::words;

/// Length of the longest common subsequence of two word sequences,
/// bit-parallel over 64-word blocks of `a`.
pub fn lcs_len(a: &[u32], b: &[u32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let n = a.len();
    let blocks = n.div_ceil(64);
    // Per-symbol occurrence bitmasks over `a`.
    let mut masks: HashMap<u32, Vec<u64>> = HashMap::new();
    for (i, &s) in a.iter().enumerate() {
        masks
            .entry(s)
            .or_insert_with(|| vec![0u64; blocks])[i / 64] |= 1u64 << (i % 64);
    }
    let zeros = vec![0u64; blocks];
    let mut row = vec![!0u64; blocks];
    // Trim the last block's unused high bits.
    if n % 64 != 0 {
        row[blocks - 1] = (1u64 << (n % 64)) - 1;
    }
    let tail_mask = row[blocks - 1];

    // Hyyrö's update: u = V & M; V = (V + u) | (V - u), with add-carry and
    // sub-borrow propagated across 64-bit blocks.
    for &s in b {
        let m = masks.get(&s).unwrap_or(&zeros);
        let mut carry = 0u64;
        let mut borrow = 0u64;
        for blk in 0..blocks {
            let v = row[blk];
            let u = v & m[blk];
            let (sum1, o1) = v.overflowing_add(u);
            let (sum2, o2) = sum1.overflowing_add(carry);
            carry = (o1 as u64) | (o2 as u64);
            let (dif1, b1) = v.overflowing_sub(u);
            let (dif2, b2) = dif1.overflowing_sub(borrow);
            borrow = (b1 as u64) | (b2 as u64);
            row[blk] = sum2 | dif2;
        }
        row[blocks - 1] &= tail_mask;
    }
    // LCS length = number of zero bits among the first n positions.
    let ones: usize = row.iter().map(|b| b.count_ones() as usize).sum();
    n - ones
}

/// ROUGE-L recall of `compressed` against `original`:
/// `LCS(original, compressed) / len(original)` over words.
pub fn rouge_l_recall(original: &str, compressed: &str) -> f64 {
    let (wa, ids_a, ids_b) = intern_pair(original, compressed);
    if wa == 0 {
        return if compressed.trim().is_empty() { 1.0 } else { 0.0 };
    }
    lcs_len(&ids_a, &ids_b) as f64 / wa as f64
}

fn intern_pair(a: &str, b: &str) -> (usize, Vec<u32>, Vec<u32>) {
    let mut intern: HashMap<String, u32> = HashMap::new();
    let id = |w: String, intern: &mut HashMap<String, u32>| {
        let next = intern.len() as u32;
        *intern.entry(w).or_insert(next)
    };
    let ids_a: Vec<u32> = words(a).into_iter().map(|w| id(w, &mut intern)).collect();
    let ids_b: Vec<u32> = words(b).into_iter().map(|w| id(w, &mut intern)).collect();
    (ids_a.len(), ids_a, ids_b)
}

/// Fidelity bundle for one (original, compressed) pair.
#[derive(Clone, Debug)]
pub struct Fidelity {
    pub rouge_l_recall: f64,
    pub tfidf_cosine: f64,
    pub token_reduction: f64,
}

pub fn measure(original: &str, compressed: &str) -> Fidelity {
    use crate::compress::tokenizer::count_tokens;
    let orig_t = count_tokens(original) as f64;
    let comp_t = count_tokens(compressed) as f64;
    Fidelity {
        rouge_l_recall: rouge_l_recall(original, compressed),
        tfidf_cosine: crate::compress::tfidf::tfidf_cosine(original, compressed),
        token_reduction: if orig_t > 0.0 { 1.0 - comp_t / orig_t } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive O(nm) LCS for cross-checking the bit-parallel version.
    fn lcs_naive(a: &[u32], b: &[u32]) -> usize {
        let mut dp = vec![0usize; b.len() + 1];
        for &x in a {
            let mut prev = 0;
            for (j, &y) in b.iter().enumerate() {
                let cur = dp[j + 1];
                dp[j + 1] = if x == y { prev + 1 } else { dp[j + 1].max(dp[j]) };
                prev = cur;
            }
        }
        dp[b.len()]
    }

    #[test]
    fn lcs_simple_cases() {
        assert_eq!(lcs_len(&[1, 2, 3], &[1, 2, 3]), 3);
        assert_eq!(lcs_len(&[1, 2, 3], &[3, 2, 1]), 1);
        assert_eq!(lcs_len(&[1, 2, 3], &[]), 0);
        assert_eq!(lcs_len(&[1, 2, 3, 4], &[2, 4]), 2);
    }

    #[test]
    fn lcs_matches_naive_random() {
        crate::util::check::forall(
            "lcs-bitparallel-vs-naive",
            40,
            |rng| {
                let n = rng.range(1, 200);
                let m = rng.range(1, 200);
                let k = rng.range(2, 12) as u32;
                let a: Vec<u32> = (0..n).map(|_| rng.below(k as u64) as u32).collect();
                let b: Vec<u32> = (0..m).map(|_| rng.below(k as u64) as u32).collect();
                (a, b)
            },
            |(a, b)| {
                crate::util::check::ensure(
                    lcs_len(a, b) == lcs_naive(a, b),
                    format!("bitparallel {} != naive {}", lcs_len(a, b), lcs_naive(a, b)),
                )
            },
        );
    }

    #[test]
    fn lcs_crosses_block_boundaries() {
        // > 64 symbols forces multi-block carries.
        let a: Vec<u32> = (0..200).map(|i| i % 7).collect();
        let b: Vec<u32> = (0..150).map(|i| i % 5).collect();
        assert_eq!(lcs_len(&a, &b), lcs_naive(&a, &b));
    }

    #[test]
    fn rouge_recall_of_subset_is_reduction_complement() {
        // An extractive summary is a subsequence of the original, so
        // LCS = summary length and recall = kept fraction of words.
        let orig = "alpha beta gamma delta epsilon zeta eta theta";
        let comp = "alpha gamma epsilon theta";
        assert!((rouge_l_recall(orig, comp) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rouge_identity() {
        let t = "the same text verbatim";
        assert!((rouge_l_recall(t, t) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rouge_disjoint_is_zero() {
        assert_eq!(rouge_l_recall("alpha beta", "gamma delta"), 0.0);
    }

    #[test]
    fn measure_bundles_consistently() {
        let orig = "First fact stands. Second fact holds. Third fact remains. Fourth fact stays.";
        let comp = "First fact stands. Third fact remains.";
        let f = measure(orig, comp);
        assert!(f.rouge_l_recall > 0.4 && f.rouge_l_recall < 0.7);
        assert!(f.tfidf_cosine > 0.5);
        assert!(f.token_reduction > 0.3 && f.token_reduction < 0.7);
    }
}
