//! The extractive compression pipeline (paper §5.2): split → score →
//! greedy-select under the hard token budget `T_c = B_short - L_out`
//! (Eq. 15), always retaining the first 3 and last 2 sentences
//! (primacy/recency invariant).
//!
//! The budget is enforced against the same tokenizer the gateway uses, so
//! no compressed request can overflow the short pool's KV cache — the
//! "hard OOM guarantee" is by construction, and is property-tested.

use crate::compress::doc::Document;
use crate::compress::scoring::{
    minmax_normalize_inplace, position_scores_into, score_with_mode,
};
use crate::compress::scratch::CompressScratch;
use crate::compress::textrank::{centrality_into, SimilarityMode};

/// Number of leading sentences always retained.
pub const KEEP_FIRST: usize = 3;
/// Number of trailing sentences always retained.
pub const KEEP_LAST: usize = 2;

/// Outcome of one compression attempt.
#[derive(Clone, Debug)]
pub struct Compression {
    /// The compressed prompt (selected sentences in original order).
    pub text: String,
    /// Token count of the original prompt.
    pub original_tokens: u32,
    /// Token count of the compressed prompt (<= budget when `ok`).
    pub compressed_tokens: u32,
    /// Indices of the selected sentences.
    pub selected: Vec<usize>,
    /// Whether the result fits the budget (the p_c success indicator).
    pub ok: bool,
}

impl Compression {
    /// Fraction of tokens removed (Table 7's "mean token reduction").
    pub fn token_reduction(&self) -> f64 {
        if self.original_tokens == 0 {
            0.0
        } else {
            1.0 - self.compressed_tokens as f64 / self.original_tokens as f64
        }
    }
}

/// Compress `text` to at most `budget_tokens` tokens (T_c of Eq. 15).
///
/// Fails (`ok = false`) when even the mandatory primacy/recency sentences
/// exceed the budget — such requests count against p_c and stay in the
/// long pool.
pub fn compress(text: &str, budget_tokens: u32) -> Compression {
    let doc = Document::parse(text);
    compress_doc(&doc, budget_tokens)
}

/// Compression over a pre-parsed document (lets callers reuse the parse).
pub fn compress_doc(doc: &Document, budget_tokens: u32) -> Compression {
    compress_doc_with_mode(doc, budget_tokens, SimilarityMode::default())
}

/// [`compress_doc`] with an explicit TextRank similarity backend — the
/// §Perf equivalence flag (`AllPairs` reproduces the pre-inverted-index
/// behavior; selection is byte-identical across modes, property-tested).
pub fn compress_doc_with_mode(
    doc: &Document,
    budget_tokens: u32,
    mode: SimilarityMode,
) -> Compression {
    run_selection(
        doc,
        budget_tokens,
        &mut Vec::new(),
        &mut Vec::new(),
        &mut Vec::new(),
        |doc, out| {
            let scores = score_with_mode(doc, mode);
            out.clear();
            out.extend_from_slice(&scores.composite);
        },
        &mut Vec::new(),
    )
}

/// Scratch-backed compression (§Perf): identical output to [`compress`],
/// but every transient buffer — parse, interner, TextRank postings and
/// adjacency, component scores, selection order — lives in the caller's
/// [`CompressScratch`] and is reused across requests. The steady-state
/// gateway path allocates only the returned `Compression` itself.
pub fn compress_with(s: &mut CompressScratch, text: &str, budget_tokens: u32) -> Compression {
    // Split the scratch into disjoint field borrows: the score closure
    // owns the component buffers, `run_selection` owns the selection ones.
    let CompressScratch {
        parse,
        doc,
        textrank,
        tr,
        pos,
        tfv,
        nov,
        composite,
        df,
        tf,
        wt,
        order,
        selected,
        mandatory,
    } = s;
    doc.reparse(text, parse);
    run_selection(
        doc,
        budget_tokens,
        selected,
        order,
        mandatory,
        // Component scores into scratch buffers, min-max normalized in
        // place — arithmetic identical to `scoring::score`.
        |doc, out| {
            centrality_into(doc, SimilarityMode::InvertedIndex, textrank, tr);
            minmax_normalize_inplace(tr);
            position_scores_into(doc.n_sentences(), pos);
            minmax_normalize_inplace(pos);
            crate::compress::tfidf::sentence_scores_soa(doc, df, tf, wt, tfv);
            minmax_normalize_inplace(tfv);
            crate::compress::scoring::novelty_scores_into(doc, nov);
            minmax_normalize_inplace(nov);
            out.clear();
            out.extend(tr.iter().zip(&*pos).zip(&*tfv).zip(&*nov).map(
                |(((tr, pos), tf), nov)| {
                    crate::compress::scoring::W_TEXTRANK * tr
                        + crate::compress::scoring::W_POSITION * pos
                        + crate::compress::scoring::W_TFIDF * tf
                        + crate::compress::scoring::W_NOVELTY * nov
                },
            ));
        },
        composite,
    )
}

/// The single selection implementation both entry points funnel through
/// (so the oracle path and the scratch path cannot drift apart): empty /
/// identity / skeleton-overflow handling, then greedy fill in composite
/// order. `compute_composite` is only invoked when selection is actually
/// needed; `selected`/`order`/`mandatory`/`composite_buf` are caller-owned
/// buffers (fresh Vecs for the one-shot path, scratch fields for the
/// reusing path).
fn run_selection(
    doc: &Document,
    budget_tokens: u32,
    selected: &mut Vec<bool>,
    order: &mut Vec<usize>,
    mandatory: &mut Vec<usize>,
    compute_composite: impl FnOnce(&Document, &mut Vec<f64>),
    composite_buf: &mut Vec<f64>,
) -> Compression {
    let n = doc.n_sentences();
    let original_tokens = doc.total_tokens();
    if n == 0 {
        return Compression {
            text: String::new(),
            original_tokens,
            compressed_tokens: 0,
            selected: Vec::new(),
            ok: budget_tokens > 0,
        };
    }
    // Already within budget: identity compression.
    if original_tokens <= budget_tokens {
        return Compression {
            text: doc.sentences.join(" "),
            original_tokens,
            compressed_tokens: original_tokens,
            selected: (0..n).collect(),
            ok: true,
        };
    }

    selected.clear();
    selected.resize(n, false);
    let mut used: u32 = 0;

    // Step 3 invariant: always retain the first 3 and last 2 sentences.
    mandatory.clear();
    mandatory.extend(0..n.min(KEEP_FIRST));
    for i in n.saturating_sub(KEEP_LAST)..n {
        if !mandatory.contains(&i) {
            mandatory.push(i);
        }
    }
    for &i in mandatory.iter() {
        selected[i] = true;
        used += doc.token_counts[i];
    }
    if used > budget_tokens {
        // Even the skeleton does not fit: compression fails.
        return Compression {
            text: String::new(),
            original_tokens,
            compressed_tokens: used,
            selected: Vec::new(),
            ok: false,
        };
    }

    // Steps 2+3: greedy selection in composite-score order.
    compute_composite(doc, composite_buf);
    order.clear();
    for (i, &sel) in selected.iter().enumerate() {
        if !sel {
            order.push(i);
        }
    }
    // The comparator is total (ties broken by position), so the unstable
    // sort is deterministic and equal to the stable sort here.
    order.sort_unstable_by(|&a, &b| {
        composite_buf[b]
            .partial_cmp(&composite_buf[a])
            .unwrap()
            .then(a.cmp(&b)) // tie-break by position
    });

    // Step 4: stop when the budget is reached (skip-and-continue lets short
    // high-value sentences fill remaining space).
    for &i in order.iter() {
        let cost = doc.token_counts[i];
        if used + cost <= budget_tokens {
            selected[i] = true;
            used += cost;
        }
    }

    let idx: Vec<usize> = (0..n).filter(|&i| selected[i]).collect();
    let mut text = String::new();
    for (k, &i) in idx.iter().enumerate() {
        if k > 0 {
            text.push(' ');
        }
        text.push_str(&doc.sentences[i]);
    }
    Compression {
        compressed_tokens: used,
        original_tokens,
        selected: idx,
        ok: used <= budget_tokens,
        text,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::tokenizer::count_tokens;

    fn long_doc(n: usize) -> String {
        (0..n)
            .map(|i| {
                format!(
                    "Sentence number {i} elaborates on topic {} with supporting detail \
                     about provisioning and compression mechanics.",
                    i % 9
                )
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    #[test]
    fn respects_budget_exactly() {
        let text = long_doc(60);
        let total = count_tokens(&text);
        let budget = total / 2;
        let c = compress(&text, budget);
        assert!(c.ok);
        assert!(c.compressed_tokens <= budget, "{} > {budget}", c.compressed_tokens);
        // Recount from the emitted text: the hard OOM guarantee is about
        // actual tokens, not bookkeeping.
        assert!(count_tokens(&c.text) <= budget);
    }

    #[test]
    fn keeps_first_three_and_last_two() {
        let text = long_doc(40);
        let c = compress(&text, count_tokens(&text) / 2);
        assert!(c.ok);
        for i in 0..3 {
            assert!(c.selected.contains(&i), "first-3 invariant: {:?}", c.selected);
        }
        for i in 38..40 {
            assert!(c.selected.contains(&i), "last-2 invariant: {:?}", c.selected);
        }
    }

    #[test]
    fn preserves_sentence_order() {
        let text = long_doc(30);
        let c = compress(&text, count_tokens(&text) * 2 / 3);
        let mut sorted = c.selected.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, c.selected);
    }

    #[test]
    fn identity_when_within_budget() {
        let text = "Short prompt. Nothing to trim here.";
        let c = compress(text, 1_000);
        assert!(c.ok);
        assert_eq!(c.compressed_tokens, c.original_tokens);
        assert_eq!(c.token_reduction(), 0.0);
    }

    #[test]
    fn fails_when_skeleton_exceeds_budget() {
        let text = long_doc(10);
        let c = compress(&text, 5); // absurd budget
        assert!(!c.ok);
        assert!(c.selected.is_empty());
    }

    #[test]
    fn empty_text() {
        let c = compress("", 100);
        assert!(c.ok);
        assert_eq!(c.compressed_tokens, 0);
    }

    #[test]
    fn budget_pressure_drops_exactly_the_overflow() {
        // 8 sentences, budget = total minus ~one sentence: exactly one of
        // the three droppable middle sentences must be cut, never the
        // mandatory first-3/last-2.
        let text = long_doc(8);
        let total = count_tokens(&text);
        let c = compress(&text, total - 10);
        assert!(c.ok);
        assert_eq!(c.selected.len(), 7, "{:?}", c.selected);
        for i in [0usize, 1, 2, 6, 7] {
            assert!(c.selected.contains(&i), "mandatory {i} missing: {:?}", c.selected);
        }
    }

    #[test]
    fn selection_is_deterministic() {
        let text = long_doc(25);
        let budget = count_tokens(&text) / 2;
        let a = compress(&text, budget);
        let b = compress(&text, budget);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.text, b.text);
    }

    #[test]
    fn token_reduction_matches_counts() {
        let text = long_doc(50);
        let c = compress(&text, count_tokens(&text) / 3);
        assert!(c.ok);
        let want = 1.0 - c.compressed_tokens as f64 / c.original_tokens as f64;
        assert!((c.token_reduction() - want).abs() < 1e-12);
        assert!(c.token_reduction() > 0.5);
    }

    #[test]
    fn oom_guarantee_property() {
        // Property test: for random budgets, ok => recounted tokens fit.
        crate::util::check::forall(
            "compress-oom-guarantee",
            25,
            |rng| {
                let n = rng.range(6, 50);
                let frac = rng.uniform(0.1, 1.2);
                (n, frac)
            },
            |&(n, frac)| {
                let text = long_doc(n);
                let total = count_tokens(&text);
                let budget = ((total as f64) * frac) as u32;
                let c = compress(&text, budget);
                if c.ok {
                    crate::util::check::ensure(
                        count_tokens(&c.text) <= budget,
                        format!("OOM guarantee violated: {} > {budget}", count_tokens(&c.text)),
                    )
                } else {
                    Ok(())
                }
            },
        );
    }
}
