//! Compress-and-Route: the gateway-layer extractive compression pipeline
//! (paper §5) that converts the hard pool boundary into a software knob.
//!
//! Pipeline (§5.2): [`sentence`] split → composite [`scoring`]
//! (TextRank 0.20 / Position 0.40 / TF-IDF 0.35 / Novelty 0.05) →
//! [`extractive`] greedy selection under the hard budget
//! `T_c = B_short − L_out` (Eq. 15) with the first-3/last-2 invariant.
//! [`gate`] applies the content-type safety gate (code excluded);
//! [`fidelity`] implements the Table-7 metrics; [`corpus`] generates the
//! study documents (DESIGN.md §1 substitution for LMSYS prompts).

pub mod corpus;
pub mod doc;
pub mod extractive;
pub mod fidelity;
pub mod gate;
pub mod scoring;
pub mod scratch;
pub mod sentence;
#[cfg(feature = "simd")]
pub mod simd;
pub mod textrank;
pub mod tfidf;
pub mod tokenizer;

pub use extractive::{compress, compress_with, Compression};
pub use gate::{band_hi, compression_budget, gate, GateDecision};
pub use scratch::CompressScratch;
pub use textrank::SimilarityMode;
