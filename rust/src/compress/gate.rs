//! The content-type safety gate and C&R band check (paper §5.1–5.2):
//! compression applies only to borderline requests (`B < L_total <= gamma B`)
//! whose category is structurally safe to extract (RAG / prose; code is
//! excluded). The category signal reuses the router's per-request estimate
//! at zero additional cost.

use crate::workload::request::Category;

/// Gate decision for a request at the gateway.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateDecision {
    /// Below or at the boundary: route short, no compression needed.
    RouteShort,
    /// In the borderline band and category-safe: compress then route short.
    CompressAndRoute,
    /// In the band but category-unsafe (code/tool-use): route long.
    BandButUnsafe,
    /// Above the band: genuinely long, route long.
    RouteLong,
}

/// Upper edge of the borderline band, `floor(gamma * B)` — shared by the
/// gate, the DES router, and the planner so band membership is decided
/// identically at every layer.
#[inline]
pub fn band_hi(b_short: u32, gamma: f64) -> u32 {
    (gamma * b_short as f64).floor() as u32
}

/// Clamp a boundary's compression bandwidth so its band cannot cross the
/// next boundary up (`None` for the last boundary: unclamped, the K = 2
/// case verbatim). One shared definition keeps the planner
/// (`planner::tiered`), the DES router (`fleetsim::route_trace_tiered`)
/// and the live gateway deciding band membership identically.
#[inline]
pub fn clamp_gamma(boundary: u32, next_boundary: Option<u32>, gamma: f64) -> f64 {
    match next_boundary {
        Some(nb) => gamma.min(nb as f64 / boundary as f64),
        None => gamma,
    }
}

/// Apply the gate (Eq. 14's p_c is the realized fraction of
/// `CompressAndRoute` among band members).
#[inline]
pub fn gate(l_total: u32, b_short: u32, gamma: f64, category: Category) -> GateDecision {
    if l_total <= b_short {
        return GateDecision::RouteShort;
    }
    if l_total <= band_hi(b_short, gamma) {
        if category.compressible() {
            GateDecision::CompressAndRoute
        } else {
            GateDecision::BandButUnsafe
        }
    } else {
        GateDecision::RouteLong
    }
}

/// The compressed token budget T_c = B_short - L_out (Eq. 15): chosen so
/// `T_c + L_out = B_short` and KV overflow is impossible by construction.
/// Returns None when the output budget alone exceeds the boundary (such
/// requests cannot be made short no matter the compression).
#[inline]
pub fn compression_budget(b_short: u32, l_out: u32) -> Option<u32> {
    if l_out >= b_short {
        None
    } else {
        Some(b_short - l_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const B: u32 = 8192;

    #[test]
    fn below_boundary_routes_short() {
        assert_eq!(gate(B, B, 1.5, Category::Rag), GateDecision::RouteShort);
        assert_eq!(gate(1, B, 1.5, Category::Code), GateDecision::RouteShort);
    }

    #[test]
    fn band_prose_compresses() {
        assert_eq!(
            gate(B + 1, B, 1.5, Category::Rag),
            GateDecision::CompressAndRoute
        );
        assert_eq!(
            gate(12_288, B, 1.5, Category::Conversational),
            GateDecision::CompressAndRoute
        );
    }

    #[test]
    fn band_code_is_excluded() {
        // Paper §5.2: code is excluded from compression.
        assert_eq!(
            gate(B + 100, B, 1.5, Category::Code),
            GateDecision::BandButUnsafe
        );
        assert_eq!(
            gate(B + 100, B, 1.5, Category::ToolUse),
            GateDecision::BandButUnsafe
        );
    }

    #[test]
    fn above_band_routes_long() {
        assert_eq!(
            gate(12_289, B, 1.5, Category::Rag),
            GateDecision::RouteLong
        );
        assert_eq!(gate(65_536, B, 1.5, Category::Rag), GateDecision::RouteLong);
    }

    #[test]
    fn gamma_one_has_empty_band() {
        assert_eq!(gate(B + 1, B, 1.0, Category::Rag), GateDecision::RouteLong);
    }

    #[test]
    fn clamp_gamma_stops_band_at_next_boundary() {
        // 2.0 * 1024 would cross 1536: clamp to 1536/1024 = 1.5.
        assert!((clamp_gamma(1024, Some(1536), 2.0) - 1.5).abs() < 1e-12);
        // Band already inside the next boundary: unchanged.
        assert_eq!(clamp_gamma(1024, Some(4096), 1.5), 1.5);
        // Last boundary: unclamped (the K = 2 path, bit-for-bit).
        assert_eq!(clamp_gamma(1024, None, 2.0).to_bits(), 2.0f64.to_bits());
    }

    #[test]
    fn budget_identity_eq15() {
        // T_c + L_out = B_short, always.
        for l_out in [1u32, 100, 4000, 8191] {
            let t_c = compression_budget(B, l_out).unwrap();
            assert_eq!(t_c + l_out, B);
        }
    }

    #[test]
    fn budget_impossible_when_output_fills_boundary() {
        assert_eq!(compression_budget(B, B), None);
        assert_eq!(compression_budget(B, B + 10), None);
    }
}
