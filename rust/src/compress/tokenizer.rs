//! Approximate LLM tokenizer: deterministic word/subword token counting.
//!
//! No pretrained BPE vocabulary is available offline, so token counts use
//! the standard ~4-chars-per-token heuristic at subword granularity: every
//! whitespace-delimited word contributes `ceil(len/4)` tokens and
//! punctuation runs contribute one token each. The *same* counter is used
//! by the compressor's budget enforcement, the gateway's EMA calibration,
//! and the live path's hash-tokenizer, so the hard OOM guarantee (Eq. 15)
//! is enforced against a single consistent measure.

/// Number of tokens for a text under the subword heuristic.
pub fn count_tokens(text: &str) -> u32 {
    let mut tokens = 0u32;
    for word in text.split_whitespace() {
        tokens += word_tokens(word);
    }
    tokens
}

fn word_tokens(word: &str) -> u32 {
    // Split the word into alphanumeric runs and punctuation runs; each
    // punctuation run is one token, alnum runs cost ceil(chars/4).
    let mut tokens = 0u32;
    let mut alnum_run = 0u32;
    for c in word.chars() {
        if c.is_alphanumeric() {
            alnum_run += 1;
        } else {
            if alnum_run > 0 {
                tokens += alnum_run.div_ceil(4);
                alnum_run = 0;
            }
            tokens += 1; // punctuation char
        }
    }
    if alnum_run > 0 {
        tokens += alnum_run.div_ceil(4);
    }
    tokens.max(1)
}

/// Visit each lowercased alphanumeric word of `text` without allocating a
/// `String` per word: `buf` is a caller-owned scratch that is reused for
/// every word (the gateway's `CompressScratch` threads one through the
/// whole pipeline, §Perf). Word boundaries and lowercasing are identical
/// to [`words`].
pub fn for_each_word(text: &str, buf: &mut String, mut f: impl FnMut(&str)) {
    buf.clear();
    for c in text.chars() {
        if c.is_alphanumeric() {
            for lc in c.to_lowercase() {
                buf.push(lc);
            }
        } else if !buf.is_empty() {
            f(buf.as_str());
            buf.clear();
        }
    }
    if !buf.is_empty() {
        f(buf.as_str());
        buf.clear();
    }
}

/// Lowercased alphanumeric words (the unit for TextRank / TF-IDF / ROUGE).
pub fn words(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut buf = String::new();
    for_each_word(text, &mut buf, |w| out.push(w.to_string()));
    out
}

/// Map text to live-path token ids (hash into the scaled-down model's
/// vocabulary). Used by the embedding fidelity proxy and the e2e example.
pub fn hash_tokens(text: &str, vocab: u32) -> Vec<i32> {
    let mut out = Vec::new();
    let mut buf = String::new();
    for_each_word(text, &mut buf, |w| {
        let mut h = 1469598103934665603u64; // FNV-1a
        for b in w.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(1099511628211);
        }
        out.push((h % vocab as u64) as i32);
    });
    out
}

/// Bytes-per-token of a text (the quantity the router's EMA tracks, §2.1).
pub fn bytes_per_token(text: &str) -> f64 {
    let t = count_tokens(text);
    if t == 0 {
        4.0
    } else {
        text.len() as f64 / t as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        assert_eq!(count_tokens(""), 0);
        assert_eq!(count_tokens("   \n\t "), 0);
    }

    #[test]
    fn short_words_one_token() {
        assert_eq!(count_tokens("the cat sat"), 3);
    }

    #[test]
    fn long_words_split_into_subwords() {
        // 14 chars -> ceil(14/4) = 4 tokens.
        assert_eq!(count_tokens("internationali"), 4);
    }

    #[test]
    fn punctuation_costs_tokens() {
        assert_eq!(count_tokens("end."), 2); // "end" + "."
        assert!(count_tokens("a,b,c") >= 5);
    }

    #[test]
    fn count_is_additive_over_whitespace_join() {
        let a = "retrieval augmented generation pipeline";
        let b = "compresses borderline requests.";
        assert_eq!(
            count_tokens(&format!("{a} {b}")),
            count_tokens(a) + count_tokens(b)
        );
    }

    #[test]
    fn words_lowercase_alnum() {
        assert_eq!(words("The KV-cache, 320KB!"), vec!["the", "kv", "cache", "320kb"]);
    }

    #[test]
    fn for_each_word_matches_words() {
        for text in [
            "",
            "one",
            "The KV-cache, 320KB!",
            "Ünïcode Ärger; straße 12.5x",
            "trailing word",
        ] {
            let mut seen = Vec::new();
            let mut buf = String::new();
            for_each_word(text, &mut buf, |w| seen.push(w.to_string()));
            assert_eq!(seen, words(text), "text={text:?}");
        }
    }

    #[test]
    fn hash_tokens_in_vocab_and_deterministic() {
        let t1 = hash_tokens("hello world hello", 256);
        let t2 = hash_tokens("hello world hello", 256);
        assert_eq!(t1, t2);
        assert_eq!(t1[0], t1[2]); // same word, same id
        assert!(t1.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn bytes_per_token_is_near_four_for_prose() {
        let b = bytes_per_token(
            "The quick brown fox jumps over the lazy dog near the riverbank today.",
        );
        assert!((2.0..=7.0).contains(&b), "b={b}");
    }

    #[test]
    fn realistic_prose_rate() {
        // ~1 token per ~4 chars on running prose.
        let text = "Fleet provisioning for large language model inference is \
                    typically driven by worst-case context lengths, which the \
                    vast majority of production requests never approach.";
        let t = count_tokens(text) as f64;
        let chars = text.len() as f64;
        assert!((chars / t) > 2.5 && (chars / t) < 6.5);
    }
}
