//! Seeded synthetic corpus generator for the compression studies.
//!
//! Stands in for the LMSYS-Chat-1M prompts of the paper's fidelity/latency
//! studies (Tables 4 and 7), which are unavailable offline (DESIGN.md §1):
//! produces multi-sentence prose/RAG-style documents with topic structure,
//! named entities, and controllable redundancy, at a target token length —
//! the same length band and structure the extractive pipeline sees in
//! production.

use crate::compress::tokenizer::count_tokens;
use crate::util::rng::Rng;

const SUBJECTS: [&str; 18] = [
    "The retrieval pipeline",
    "The deployment guide",
    "The incident report",
    "The design document",
    "The benchmark suite",
    "The migration plan",
    "The customer ticket",
    "The audit trail",
    "The capacity model",
    "The orchestration layer",
    "The compliance review",
    "The research summary",
    "The onboarding memo",
    "The architecture review",
    "The postmortem analysis",
    "The quarterly report",
    "The integration test",
    "The release checklist",
];

const VERBS: [&str; 12] = [
    "describes",
    "documents",
    "examines",
    "summarizes",
    "outlines",
    "enumerates",
    "contrasts",
    "evaluates",
    "motivates",
    "clarifies",
    "quantifies",
    "traces",
];

const OBJECTS: [&str; 16] = [
    "the caching strategy for embedding lookups",
    "the failover behavior of the regional clusters",
    "the latency budget across service tiers",
    "the provisioning workflow for new tenants",
    "the schema migration applied last quarter",
    "the rate-limiting policy at the gateway",
    "the replication topology of the metadata store",
    "the cost attribution model for shared GPUs",
    "the alert thresholds for queue saturation",
    "the rollout sequence for the scheduler upgrade",
    "the retention policy for conversation logs",
    "the quota negotiation between product teams",
    "the sharding function over customer accounts",
    "the backpressure protocol under burst load",
    "the token accounting rules for batch requests",
    "the capacity reservation process for peak season",
];

const MODIFIERS: [&str; 12] = [
    "in considerable operational detail",
    "with quantitative supporting evidence",
    "across three production regions",
    "for the upcoming planning cycle",
    "under sustained peak traffic",
    "according to the platform guidelines",
    "as agreed in the architecture forum",
    "despite known measurement caveats",
    "following the vendor recommendations",
    "with explicit rollback procedures",
    "per the reliability objectives",
    "including historical context",
];

const ENTITIES: [&str; 10] = [
    "Service Mercury",
    "Cluster Borealis",
    "Tenant Acme",
    "Region West-2",
    "Pipeline Delta",
    "Queue Zeta",
    "Model Garnet",
    "Gateway Primary",
    "Shard Seventeen",
    "Cache Layer Two",
];

/// Configuration for document generation.
#[derive(Clone, Copy, Debug)]
pub struct CorpusConfig {
    /// Target token length (documents land within ~one sentence of this).
    pub target_tokens: u32,
    /// Probability a sentence duplicates an earlier one (RAG redundancy).
    pub redundancy: f64,
    /// Probability of a paragraph break after a sentence.
    pub paragraph_prob: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            target_tokens: 2048,
            redundancy: 0.12,
            paragraph_prob: 0.15,
        }
    }
}

fn make_sentence(rng: &mut Rng) -> String {
    let mut s = format!(
        "{} {} {} {}",
        rng.choice(&SUBJECTS),
        rng.choice(&VERBS),
        rng.choice(&OBJECTS),
        rng.choice(&MODIFIERS),
    );
    if rng.bool(0.4) {
        s.push_str(&format!(", referencing {}", rng.choice(&ENTITIES)));
    }
    if rng.bool(0.25) {
        s.push_str(&format!(
            " and reporting a {}.{}% deviation",
            rng.below(40),
            rng.below(10)
        ));
    }
    s.push('.');
    s
}

/// Generate one prose/RAG-style document of ~`cfg.target_tokens` tokens.
pub fn generate_document(cfg: &CorpusConfig, rng: &mut Rng) -> String {
    let mut out = String::new();
    let mut sentences: Vec<String> = Vec::new();
    let mut tokens = 0u32;
    while tokens < cfg.target_tokens {
        let s = if !sentences.is_empty() && rng.bool(cfg.redundancy) {
            rng.choice(&sentences).clone()
        } else {
            make_sentence(rng)
        };
        tokens += count_tokens(&s);
        if !out.is_empty() {
            out.push_str(if rng.bool(cfg.paragraph_prob) { "\n\n" } else { " " });
        }
        out.push_str(&s);
        sentences.push(s);
    }
    out
}

/// Generate a code-like document (for the safety-gate tests: code is never
/// compressed, §5.2).
pub fn generate_code(target_tokens: u32, rng: &mut Rng) -> String {
    let mut out = String::new();
    let mut tokens = 0u32;
    let mut fn_id = 0;
    while tokens < target_tokens {
        let block = format!(
            "fn handler_{fn_id}(req: &Request) -> Result<Response, Error> {{\n    \
             let shard = route(req.key, {});\n    \
             if shard.load() > THRESHOLD_{} {{ return Err(Error::Backpressure); }}\n    \
             Ok(dispatch(shard, req)?)\n}}\n\n",
            rng.below(64),
            rng.below(9),
        );
        tokens += count_tokens(&block);
        out.push_str(&block);
        fn_id += 1;
    }
    out
}

/// A borderline-band document: token length uniform in `(b_short, gamma*b]`
/// measured by the shared tokenizer (used by gate/latency smoke paths).
pub fn generate_borderline(b_short: u32, gamma: f64, rng: &mut Rng) -> String {
    let target = rng.uniform(b_short as f64 * 1.02, b_short as f64 * gamma) as u32;
    generate_document(
        &CorpusConfig {
            target_tokens: target,
            ..CorpusConfig::default()
        },
        rng,
    )
}

/// A borderline document whose length follows a workload's CDF restricted
/// to the band — production borderline traffic clusters just above
/// `B_short` because F is concave there, which the fidelity numbers
/// (Table 7's token reduction) are sensitive to.
pub fn generate_borderline_for(
    w: &crate::workload::traces::Workload,
    rng: &mut Rng,
) -> String {
    use crate::workload::cdf::{LengthDist, TruncatedDist};
    let band = TruncatedDist::new(
        w.cdf.clone(),
        w.b_short as f64 * 1.02,
        w.b_short as f64 * w.gamma,
    );
    let target = band.sample(rng) as u32;
    generate_document(
        &CorpusConfig {
            target_tokens: target,
            ..CorpusConfig::default()
        },
        rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn document_hits_target_length() {
        let mut rng = Rng::new(1);
        for target in [256u32, 1024, 8192] {
            let doc = generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    ..CorpusConfig::default()
                },
                &mut rng,
            );
            let t = count_tokens(&doc);
            assert!(
                t >= target && t <= target + 64,
                "target {target} got {t}"
            );
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let cfg = CorpusConfig::default();
        let a = generate_document(&cfg, &mut Rng::new(7));
        let b = generate_document(&cfg, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn has_sentence_structure() {
        let mut rng = Rng::new(2);
        let doc = generate_document(&CorpusConfig::default(), &mut rng);
        let sents = crate::compress::sentence::split_sentences(&doc);
        assert!(sents.len() > 10, "got {} sentences", sents.len());
    }

    #[test]
    fn redundancy_produces_duplicates() {
        let mut rng = Rng::new(3);
        let doc = generate_document(
            &CorpusConfig {
                target_tokens: 4096,
                redundancy: 0.3,
                paragraph_prob: 0.0,
            },
            &mut rng,
        );
        let sents = crate::compress::sentence::split_sentences(&doc);
        let mut seen = std::collections::HashSet::new();
        let dups = sents.iter().filter(|s| !seen.insert(s.as_str())).count();
        assert!(dups > 0, "expected duplicated sentences");
    }

    #[test]
    fn borderline_lands_in_band() {
        let mut rng = Rng::new(4);
        for _ in 0..10 {
            let doc = generate_borderline(2048, 1.5, &mut rng);
            let t = count_tokens(&doc);
            assert!(
                t > 2048 && t <= (2048.0 * 1.5) as u32 + 64,
                "tokens {t} outside band"
            );
        }
    }

    #[test]
    fn code_generator_emits_code() {
        let mut rng = Rng::new(5);
        let code = generate_code(512, &mut rng);
        assert!(code.contains("fn handler_0"));
        assert!(code.contains('{') && code.contains('}'));
        assert!(count_tokens(&code) >= 512);
    }
}
