//! Unicode-aware sentence splitting (paper §5.2 step 1).
//!
//! Heuristic splitter: a sentence ends at `.`, `!`, `?`, `…` (or CJK
//! equivalents) followed by whitespace and an upper-case/digit/quote
//! opener, or at blank lines. A small abbreviation list suppresses false
//! boundaries ("e.g.", "Dr.", "vs.").

/// Terminator characters that may end a sentence.
const TERMINATORS: [char; 6] = ['.', '!', '?', '…', '。', '！'];

/// Abbreviations that do not end a sentence even when followed by a space
/// and a capital (lower-cased, without the trailing dot).
const ABBREVIATIONS: [&str; 14] = [
    "e.g", "i.e", "etc", "vs", "dr", "mr", "mrs", "ms", "prof", "fig", "eq", "cf", "al",
    "approx",
];

/// Allocation-free comparison of a char-slice word against a lowercase
/// abbreviation/word: lowercases `chars` on the fly (full case folding,
/// matching `str::to_lowercase`).
fn word_eq_lower(chars: &[char], target: &str) -> bool {
    let mut it = target.chars();
    for &c in chars {
        for lc in c.to_lowercase() {
            if it.next() != Some(lc) {
                return false;
            }
        }
    }
    it.next().is_none()
}

/// The last whitespace-delimited word of `chars` (a trimmed-of-trailing-dot
/// prefix), as a subslice. Empty slice when there is none.
fn last_word(chars: &[char]) -> &[char] {
    let mut end = chars.len();
    while end > 0 && chars[end - 1].is_whitespace() {
        end -= 1;
    }
    let mut start = end;
    while start > 0 && !chars[start - 1].is_whitespace() {
        start -= 1;
    }
    &chars[start..end]
}

fn ends_with_abbreviation(word: &[char]) -> bool {
    // The last whitespace-delimited word (sans trailing dots) must equal an
    // abbreviation exactly — suffix matching would eat words like
    // "mechanisms" (ends in "ms").
    let mut end = word.len();
    while end > 0 && word[end - 1] == '.' {
        end -= 1;
    }
    let word = &word[..end];
    if word.is_empty() {
        return false;
    }
    ABBREVIATIONS.iter().any(|a| word_eq_lower(word, a))
}

/// Move a trimmed copy of `chars[start..end]` into `out`, recycling a
/// `String` buffer from `spare` (§Perf: the split stage is allocation-free
/// in steady state when the caller keeps `out`/`spare` across documents).
fn push_sentence(
    chars: &[char],
    start: usize,
    end: usize,
    out: &mut Vec<String>,
    spare: &mut Vec<String>,
) {
    let mut a = start;
    let mut b = end;
    while a < b && chars[a].is_whitespace() {
        a += 1;
    }
    while b > a && chars[b - 1].is_whitespace() {
        b -= 1;
    }
    if a == b {
        return;
    }
    let mut s = spare.pop().unwrap_or_default();
    s.clear();
    s.extend(chars[a..b].iter());
    out.push(s);
}

/// Split text into sentences (returned as owned trimmed strings, in order).
pub fn split_sentences(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut chars = Vec::new();
    let mut spare = Vec::new();
    split_sentences_reuse(text, &mut chars, &mut out, &mut spare);
    out
}

/// Buffer-reusing variant of [`split_sentences`]: `chars` is scratch, the
/// previous contents of `out` are recycled through `spare` so steady-state
/// calls perform no heap allocation. Output is identical to
/// [`split_sentences`].
pub fn split_sentences_reuse(
    text: &str,
    chars: &mut Vec<char>,
    out: &mut Vec<String>,
    spare: &mut Vec<String>,
) {
    spare.append(out);
    chars.clear();
    chars.extend(text.chars());
    let chars = chars.as_slice();
    let mut start = 0usize;

    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        let mut boundary = false;

        if TERMINATORS.contains(&c) {
            // Consume a run of terminators/closing quotes.
            let mut j = i + 1;
            while j < chars.len()
                && (TERMINATORS.contains(&chars[j]) || "\"')]”’".contains(chars[j]))
            {
                j += 1;
            }
            // Boundary if at end of text, or whitespace followed by an
            // opener (uppercase, digit, opening quote/bracket).
            if j >= chars.len() {
                boundary = true;
            } else if chars[j].is_whitespace() {
                let mut k = j;
                while k < chars.len() && chars[k].is_whitespace() {
                    k += 1;
                }
                if k >= chars.len() {
                    boundary = true;
                } else {
                    let next = chars[k];
                    if next.is_uppercase()
                        || next.is_numeric()
                        || "\"'“‘([".contains(next)
                    {
                        boundary = true;
                    }
                }
            }
            if boundary && c == '.' {
                // Trim the trailing dot run off the prefix, then inspect its
                // last word (all on the char slice — no allocation).
                let mut e = i + 1;
                while e > start && chars[e - 1] == '.' {
                    e -= 1;
                }
                let last = last_word(&chars[start..e]);
                if ends_with_abbreviation(last) {
                    boundary = false;
                }
                // Also suppress splits after single initials ("J. Smith").
                // Single *alphabetic* char = an initial; single digits
                // ("topic 4.") do end sentences.
                if last.len() == 1 && last[0].is_alphabetic() {
                    boundary = false;
                }
            }
            if boundary {
                i = j;
                push_sentence(chars, start, i, out, spare);
                start = i;
                continue;
            }
        } else if c == '\n' {
            // Blank line = paragraph boundary = sentence boundary.
            let mut j = i + 1;
            let mut newlines = 1;
            while j < chars.len() && chars[j].is_whitespace() {
                if chars[j] == '\n' {
                    newlines += 1;
                }
                j += 1;
            }
            if newlines >= 2 {
                push_sentence(chars, start, i, out, spare);
                start = j;
                i = j;
                continue;
            }
        }
        i += 1;
    }
    push_sentence(chars, start, chars.len(), out, spare);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_simple_sentences() {
        let s = split_sentences("The fleet is large. It costs money. We optimize it.");
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], "The fleet is large.");
        assert_eq!(s[2], "We optimize it.");
    }

    #[test]
    fn handles_exclamation_and_question() {
        let s = split_sentences("Is it optimal? No! Compress it.");
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn does_not_split_abbreviations() {
        let s = split_sentences("Routing, e.g. pool routing, saves cost. Dr. Chen agrees.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].contains("e.g. pool routing"));
        assert!(s[1].starts_with("Dr. Chen"));
    }

    #[test]
    fn does_not_split_initials() {
        let s = split_sentences("The result follows J. Smith et al. closely here.");
        assert_eq!(s.len(), 1, "{s:?}");
    }

    #[test]
    fn does_not_split_decimal_numbers() {
        let s = split_sentences("Utilization is 0.85 under the cap. Done.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].contains("0.85"));
    }

    #[test]
    fn paragraph_breaks_split() {
        let s = split_sentences("First paragraph without terminator\n\nSecond paragraph.");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn trailing_text_without_terminator_kept() {
        let s = split_sentences("Complete sentence. Trailing fragment without end");
        assert_eq!(s.len(), 2);
        assert_eq!(s[1], "Trailing fragment without end");
    }

    #[test]
    fn unicode_terminators() {
        let s = split_sentences("第一句话。第二句话。 Final sentence…");
        assert!(s.len() >= 2, "{s:?}");
    }

    #[test]
    fn empty_input() {
        assert!(split_sentences("").is_empty());
        assert!(split_sentences("   \n\n  ").is_empty());
    }

    #[test]
    fn quotes_after_terminator_stay_with_sentence() {
        let s = split_sentences("He said \"stop.\" Then he left.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].ends_with("\"stop.\""), "{s:?}");
    }

    #[test]
    fn order_is_preserved_and_content_covered() {
        let text = "Alpha beta gamma. Delta epsilon zeta! Eta theta iota?";
        let s = split_sentences(text);
        let joined = s.join(" ");
        for w in ["Alpha", "Delta", "Eta", "iota"] {
            assert!(joined.contains(w));
        }
    }
}
