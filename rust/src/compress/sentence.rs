//! Unicode-aware sentence splitting (paper §5.2 step 1).
//!
//! Heuristic splitter: a sentence ends at `.`, `!`, `?`, `…` (or CJK
//! equivalents) followed by whitespace and an upper-case/digit/quote
//! opener, or at blank lines. A small abbreviation list suppresses false
//! boundaries ("e.g.", "Dr.", "vs.").

/// Terminator characters that may end a sentence.
const TERMINATORS: [char; 6] = ['.', '!', '?', '…', '。', '！'];

/// Abbreviations that do not end a sentence even when followed by a space
/// and a capital (lower-cased, without the trailing dot).
const ABBREVIATIONS: [&str; 14] = [
    "e.g", "i.e", "etc", "vs", "dr", "mr", "mrs", "ms", "prof", "fig", "eq", "cf", "al",
    "approx",
];

fn ends_with_abbreviation(text: &str) -> bool {
    // The last whitespace-delimited word (sans trailing dots) must equal an
    // abbreviation exactly — suffix matching would eat words like
    // "mechanisms" (ends in "ms").
    let Some(last) = text.split_whitespace().last() else {
        return false;
    };
    let word = last.trim_end_matches('.').to_lowercase();
    ABBREVIATIONS.iter().any(|a| word == *a)
}

/// Split text into sentences (returned as owned trimmed strings, in order).
pub fn split_sentences(text: &str) -> Vec<String> {
    let chars: Vec<char> = text.chars().collect();
    let mut sentences = Vec::new();
    let mut start = 0usize;

    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        let mut boundary = false;

        if TERMINATORS.contains(&c) {
            // Consume a run of terminators/closing quotes.
            let mut j = i + 1;
            while j < chars.len() && (TERMINATORS.contains(&chars[j]) || "\"')]”’".contains(chars[j]))
            {
                j += 1;
            }
            // Boundary if at end of text, or whitespace followed by an
            // opener (uppercase, digit, opening quote/bracket).
            if j >= chars.len() {
                boundary = true;
            } else if chars[j].is_whitespace() {
                let mut k = j;
                while k < chars.len() && chars[k].is_whitespace() {
                    k += 1;
                }
                if k >= chars.len() {
                    boundary = true;
                } else {
                    let next = chars[k];
                    if next.is_uppercase()
                        || next.is_numeric()
                        || "\"'“‘([".contains(next)
                    {
                        boundary = true;
                    }
                }
            }
            if boundary && c == '.' {
                let prefix: String = chars[start..=i.min(chars.len() - 1)].iter().collect();
                let before_dot = prefix.trim_end_matches('.');
                if ends_with_abbreviation(before_dot) {
                    boundary = false;
                }
                // Also suppress splits after single initials ("J. Smith").
                if let Some(last) = before_dot.split_whitespace().last() {
                    // Single *alphabetic* char = an initial ("J. Smith");
                    // single digits ("topic 4.") do end sentences.
                    if last.chars().count() == 1
                        && last.chars().next().unwrap().is_alphabetic()
                    {
                        boundary = false;
                    }
                }
            }
            if boundary {
                i = j;
                let s: String = chars[start..i].iter().collect();
                let s = s.trim();
                if !s.is_empty() {
                    sentences.push(s.to_string());
                }
                start = i;
                continue;
            }
        } else if c == '\n' {
            // Blank line = paragraph boundary = sentence boundary.
            let mut j = i + 1;
            let mut newlines = 1;
            while j < chars.len() && chars[j].is_whitespace() {
                if chars[j] == '\n' {
                    newlines += 1;
                }
                j += 1;
            }
            if newlines >= 2 {
                let s: String = chars[start..i].iter().collect();
                let s = s.trim();
                if !s.is_empty() {
                    sentences.push(s.to_string());
                }
                start = j;
                i = j;
                continue;
            }
        }
        i += 1;
    }
    let tail: String = chars[start..].iter().collect();
    let tail = tail.trim();
    if !tail.is_empty() {
        sentences.push(tail.to_string());
    }
    sentences
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_simple_sentences() {
        let s = split_sentences("The fleet is large. It costs money. We optimize it.");
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], "The fleet is large.");
        assert_eq!(s[2], "We optimize it.");
    }

    #[test]
    fn handles_exclamation_and_question() {
        let s = split_sentences("Is it optimal? No! Compress it.");
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn does_not_split_abbreviations() {
        let s = split_sentences("Routing, e.g. pool routing, saves cost. Dr. Chen agrees.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].contains("e.g. pool routing"));
        assert!(s[1].starts_with("Dr. Chen"));
    }

    #[test]
    fn does_not_split_initials() {
        let s = split_sentences("The result follows J. Smith et al. closely here.");
        assert_eq!(s.len(), 1, "{s:?}");
    }

    #[test]
    fn does_not_split_decimal_numbers() {
        let s = split_sentences("Utilization is 0.85 under the cap. Done.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].contains("0.85"));
    }

    #[test]
    fn paragraph_breaks_split() {
        let s = split_sentences("First paragraph without terminator\n\nSecond paragraph.");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn trailing_text_without_terminator_kept() {
        let s = split_sentences("Complete sentence. Trailing fragment without end");
        assert_eq!(s.len(), 2);
        assert_eq!(s[1], "Trailing fragment without end");
    }

    #[test]
    fn unicode_terminators() {
        let s = split_sentences("第一句话。第二句话。 Final sentence…");
        assert!(s.len() >= 2, "{s:?}");
    }

    #[test]
    fn empty_input() {
        assert!(split_sentences("").is_empty());
        assert!(split_sentences("   \n\n  ").is_empty());
    }

    #[test]
    fn quotes_after_terminator_stay_with_sentence() {
        let s = split_sentences("He said \"stop.\" Then he left.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].ends_with("\"stop.\""), "{s:?}");
    }

    #[test]
    fn order_is_preserved_and_content_covered() {
        let text = "Alpha beta gamma. Delta epsilon zeta! Eta theta iota?";
        let s = split_sentences(text);
        let joined = s.join(" ");
        for w in ["Alpha", "Delta", "Eta", "iota"] {
            assert!(joined.contains(w));
        }
    }
}
