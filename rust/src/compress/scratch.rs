//! The per-worker compression arena (§Perf).
//!
//! A [`CompressScratch`] owns every transient buffer the C&R pipeline
//! needs — the parse scratch (interner arena, char/word buffers, recycled
//! sentence storage), the reusable [`Document`], the TextRank postings /
//! adjacency buffers, and the scoring/selection vectors. All buffers keep
//! their capacity across requests, so a steady-state gateway call
//! allocates nothing on the heap beyond the returned compressed prompt
//! itself. One scratch per gateway (or per worker thread); it is `Send`,
//! not shared.
//!
//! The one-shot [`crate::compress::extractive::compress`] constructs a
//! fresh scratch per call and produces byte-identical output
//! (property-tested), so existing callers are unaffected.

use crate::compress::doc::{Document, ParseScratch};
use crate::compress::extractive::{compress_with, Compression};
use crate::compress::textrank::TextrankScratch;

/// Reusable buffers for the full compress pipeline. See module docs.
#[derive(Clone, Debug, Default)]
pub struct CompressScratch {
    pub(crate) parse: ParseScratch,
    pub(crate) doc: Document,
    pub(crate) textrank: TextrankScratch,
    /// Component scores (raw, then min-max normalized in place).
    pub(crate) tr: Vec<f64>,
    pub(crate) pos: Vec<f64>,
    pub(crate) tfv: Vec<f64>,
    pub(crate) nov: Vec<f64>,
    pub(crate) composite: Vec<f64>,
    /// TF-IDF counting scratch.
    pub(crate) df: Vec<u32>,
    pub(crate) tf: Vec<u32>,
    /// SoA per-word TF-IDF weight table (§Perf PR 6, `simd` dispatch).
    pub(crate) wt: Vec<f64>,
    /// Selection state.
    pub(crate) order: Vec<usize>,
    pub(crate) selected: Vec<bool>,
    pub(crate) mandatory: Vec<usize>,
}

impl CompressScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Compress `text` to at most `budget_tokens` tokens, reusing this
    /// scratch's buffers. Byte-identical to
    /// [`crate::compress::extractive::compress`].
    pub fn compress(&mut self, text: &str, budget_tokens: u32) -> Compression {
        compress_with(self, text, budget_tokens)
    }

    /// The most recently parsed document (valid after a `compress` call).
    pub fn last_doc(&self) -> &Document {
        &self.doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::corpus::{self, CorpusConfig};
    use crate::compress::extractive::compress;
    use crate::compress::tokenizer::count_tokens;
    use crate::util::rng::Rng;

    #[test]
    fn scratch_reuse_matches_one_shot_compress() {
        let mut scratch = CompressScratch::new();
        let mut rng = Rng::new(42);
        for k in 0..6 {
            // Vary size up and down to exercise buffer shrink/grow reuse.
            let target = [900u32, 300, 1500, 150, 1200, 600][k];
            let doc = corpus::generate_document(
                &CorpusConfig {
                    target_tokens: target,
                    ..Default::default()
                },
                &mut rng,
            );
            let budget = count_tokens(&doc) * 2 / 3;
            let fresh = compress(&doc, budget);
            let reused = scratch.compress(&doc, budget);
            assert_eq!(fresh.text, reused.text, "doc {k}");
            assert_eq!(fresh.selected, reused.selected, "doc {k}");
            assert_eq!(fresh.compressed_tokens, reused.compressed_tokens);
            assert_eq!(fresh.original_tokens, reused.original_tokens);
            assert_eq!(fresh.ok, reused.ok);
        }
    }

    #[test]
    fn scratch_handles_degenerate_inputs() {
        let mut scratch = CompressScratch::new();
        for text in ["", "word", "Two words. Here.", &"x ".repeat(5_000)] {
            let a = scratch.compress(text, 50);
            let b = compress(text, 50);
            assert_eq!(a.text, b.text);
            assert_eq!(a.ok, b.ok);
        }
    }
}
