//! Composite sentence scoring (paper §5.2 step 2): TextRank (w = 0.20),
//! Position (w = 0.40), TF-IDF (w = 0.35), Novelty (w = 0.05). Each
//! component is min-max normalized to [0, 1] before weighting so the
//! published weights are meaningful across prompts.

use crate::compress::doc::{jaccard, Document};
use crate::compress::textrank::{textrank_with_mode, SimilarityMode};
use crate::compress::tfidf::sentence_scores;

pub const W_TEXTRANK: f64 = 0.20;
pub const W_POSITION: f64 = 0.40;
pub const W_TFIDF: f64 = 0.35;
pub const W_NOVELTY: f64 = 0.05;

/// Per-sentence component and composite scores.
#[derive(Clone, Debug)]
pub struct SentenceScores {
    pub textrank: Vec<f64>,
    pub position: Vec<f64>,
    pub tfidf: Vec<f64>,
    pub novelty: Vec<f64>,
    pub composite: Vec<f64>,
}

/// Position prior: strong primacy decay with a recency bump — document
/// openings state the task, endings carry the actual question (the
/// first-3/last-2 retention invariant is enforced separately at selection).
pub fn position_scores(n: usize) -> Vec<f64> {
    let mut out = Vec::new();
    position_scores_into(n, &mut out);
    out
}

/// Buffer-reusing variant of [`position_scores`] (§Perf).
pub fn position_scores_into(n: usize, out: &mut Vec<f64>) {
    out.clear();
    out.extend((0..n).map(|i| {
        let primacy = (-(i as f64) / (n as f64 / 4.0).max(1.0)).exp();
        let from_end = n - 1 - i;
        let recency = if from_end < 2 { 0.6 - 0.1 * from_end as f64 } else { 0.0 };
        primacy.max(recency)
    }));
}

/// Novelty: 1 minus the max Jaccard similarity against any *earlier*
/// sentence — a redundancy penalty for repeated content (RAG payloads
/// routinely duplicate retrieved passages).
pub fn novelty_scores(doc: &Document) -> Vec<f64> {
    let mut out = Vec::new();
    novelty_scores_into(doc, &mut out);
    out
}

/// Buffer-reusing variant of [`novelty_scores`] (§Perf).
pub fn novelty_scores_into(doc: &Document, out: &mut Vec<f64>) {
    let n = doc.n_sentences();
    out.clear();
    out.reserve(n);
    for i in 0..n {
        let a = &doc.word_sets[i];
        let sig_a = doc.signatures[i];
        let mut max_sim: f64 = 0.0;
        for j in 0..i {
            let b = &doc.word_sets[j];
            // Size-ratio upper bound on Jaccard: |A∩B|/|A∪B| <= min/max.
            // Skipping pairs that cannot beat the running max cuts the
            // O(S^2) pass substantially on mixed-length documents (§Perf).
            let (lo, hi) = if a.len() < b.len() {
                (a.len(), b.len())
            } else {
                (b.len(), a.len())
            };
            if hi == 0 || (lo as f64 / hi as f64) <= max_sim {
                continue;
            }
            // Bloom-signature upper bound on the intersection: cheap
            // popcounts reject most non-duplicate pairs before the exact
            // merge (§Perf).
            let sig_b = doc.signatures[j];
            let inter_ub = ((sig_a[0] & sig_b[0]).count_ones()
                + (sig_a[1] & sig_b[1]).count_ones()) as f64;
            let union_lb = hi as f64;
            if inter_ub / union_lb <= max_sim {
                continue;
            }
            max_sim = max_sim.max(jaccard(a, b));
            if max_sim >= 1.0 {
                break;
            }
        }
        out.push(1.0 - max_sim);
    }
}

fn minmax_normalize(xs: &[f64]) -> Vec<f64> {
    let mut out = xs.to_vec();
    minmax_normalize_inplace(&mut out);
    out
}

/// In-place min-max normalization (§Perf): same values as
/// [`minmax_normalize`], no allocation.
pub(crate) fn minmax_normalize_inplace(xs: &mut [f64]) {
    if xs.is_empty() {
        return;
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if hi - lo < 1e-12 {
        xs.fill(0.5);
        return;
    }
    for x in xs.iter_mut() {
        *x = (*x - lo) / (hi - lo);
    }
}

/// Score all sentences of a document.
pub fn score(doc: &Document) -> SentenceScores {
    score_with_mode(doc, SimilarityMode::default())
}

/// [`score`] with an explicit TextRank similarity backend (the §Perf
/// equivalence flag: `AllPairs` is the pre-inverted-index oracle).
pub fn score_with_mode(doc: &Document, mode: SimilarityMode) -> SentenceScores {
    let tr = minmax_normalize(&textrank_with_mode(doc, mode));
    let pos = minmax_normalize(&position_scores(doc.n_sentences()));
    let tf = minmax_normalize(&sentence_scores(doc));
    let nov = minmax_normalize(&novelty_scores(doc));
    let composite = (0..doc.n_sentences())
        .map(|i| W_TEXTRANK * tr[i] + W_POSITION * pos[i] + W_TFIDF * tf[i] + W_NOVELTY * nov[i])
        .collect();
    SentenceScores {
        textrank: tr,
        position: pos,
        tfidf: tf,
        novelty: nov,
        composite,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        assert!((W_TEXTRANK + W_POSITION + W_TFIDF + W_NOVELTY - 1.0).abs() < 1e-12);
    }

    #[test]
    fn position_first_is_max() {
        let p = position_scores(20);
        assert_eq!(p.len(), 20);
        let max = p.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(p[0], max);
        // Recency bump: last sentence beats the middle.
        assert!(p[19] > p[10]);
    }

    #[test]
    fn novelty_penalizes_duplicates() {
        let d = Document::parse(
            "The retrieved passage describes fleet provisioning mechanisms. \
             Unrelated content about compression pipelines sits here. \
             The retrieved passage describes fleet provisioning mechanisms.",
        );
        let nv = novelty_scores(&d);
        assert_eq!(nv[0], 1.0); // first sentence is always novel
        assert!(nv[2] < 0.1, "duplicate should score near zero: {nv:?}");
        assert!(nv[1] > nv[2]);
    }

    #[test]
    fn composite_in_unit_interval() {
        let text = (0..30)
            .map(|i| format!("Sentence {i} covers topic {} in detail.", i % 7))
            .collect::<Vec<_>>()
            .join(" ");
        let d = Document::parse(&text);
        let s = score(&d);
        assert_eq!(s.composite.len(), 30);
        for v in &s.composite {
            assert!((0.0..=1.0).contains(v), "{v}");
        }
    }

    #[test]
    fn constant_components_normalize_to_half() {
        assert_eq!(minmax_normalize(&[3.0, 3.0, 3.0]), vec![0.5, 0.5, 0.5]);
    }

    #[test]
    fn deterministic() {
        let d = Document::parse("One sentence here. Another sentence there. Final words now.");
        let a = score(&d);
        let b = score(&d);
        assert_eq!(a.composite, b.composite);
    }
}
