//! Tokenized document representation shared by the scoring stages: interned
//! word ids, per-sentence sorted word sets, and token counts. Building this
//! once keeps TextRank / TF-IDF / novelty passes allocation-light (the
//! compressor's 2–7 ms latency target, Table 4).
//!
//! §Perf: [`Document::reparse`] rebuilds a document **in place** against a
//! caller-owned [`ParseScratch`] (arena-backed [`Interner`], char/word
//! scratch, recycled per-sentence buffers), so steady-state gateway calls
//! parse documents without heap allocation. [`Document::parse`] is the
//! one-shot convenience wrapper with identical output.

use crate::compress::sentence::split_sentences_reuse;
use crate::compress::tokenizer::{count_tokens, for_each_word};
use crate::util::hash::{fnv1a, mix64, process_seed};

/// Arena-backed string interner: word bytes live in one growing `String`,
/// ids index a span table, and lookup goes through a fixed-seed
/// open-addressed hash table. `clear()` retains every allocation, so a
/// reused interner performs no heap allocation in steady state — unlike
/// `HashMap<String, u32>`, which allocates one `String` per distinct word
/// per document (the former top allocator of the parse stage).
///
/// Ids are assigned densely in first-appearance order, matching the
/// behavior of the `HashMap` entry-insert it replaces. The probe index
/// mixes a per-process random seed ([`process_seed`]) into the word hash:
/// prompt text is attacker-controlled, and an unseeded fixed hash would
/// let masked-bucket collisions be precomputed offline (hash-flood DoS,
/// the property the replaced SipHash `HashMap` provided). Ids — and thus
/// all downstream scores — do not depend on the seed.
#[derive(Clone, Debug)]
pub struct Interner {
    arena: String,
    /// Word id -> byte span in `arena`.
    spans: Vec<(u32, u32)>,
    /// Open-addressed table of word ids; `u32::MAX` = empty slot.
    table: Vec<u32>,
    seed: u64,
}

const EMPTY_SLOT: u32 = u32::MAX;

impl Default for Interner {
    fn default() -> Self {
        Interner {
            arena: String::new(),
            spans: Vec::new(),
            table: Vec::new(),
            seed: process_seed(),
        }
    }
}

impl Interner {
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Reset for a new document, keeping all capacity.
    pub fn clear(&mut self) {
        self.arena.clear();
        self.spans.clear();
        self.table.fill(EMPTY_SLOT);
    }

    /// Id of `word`, interning it on first sight.
    pub fn intern(&mut self, word: &str) -> u32 {
        if self.table.is_empty() {
            self.table.resize(64, EMPTY_SLOT);
        }
        let mask = self.table.len() - 1;
        let mut i = mix64(fnv1a(word.as_bytes()), self.seed) as usize & mask;
        loop {
            let id = self.table[i];
            if id == EMPTY_SLOT {
                break;
            }
            let (s, e) = self.spans[id as usize];
            if &self.arena[s as usize..e as usize] == word {
                return id;
            }
            i = (i + 1) & mask;
        }
        let id = self.spans.len() as u32;
        let s = self.arena.len() as u32;
        self.arena.push_str(word);
        self.spans.push((s, self.arena.len() as u32));
        self.table[i] = id;
        // Keep load factor under 3/4.
        if (self.spans.len() + 1) * 4 >= self.table.len() * 3 {
            self.grow();
        }
        id
    }

    fn grow(&mut self) {
        let cap = (self.table.len() * 2).max(64);
        self.table.clear();
        self.table.resize(cap, EMPTY_SLOT);
        let mask = cap - 1;
        for (id, &(s, e)) in self.spans.iter().enumerate() {
            let w = &self.arena[s as usize..e as usize];
            let mut i = mix64(fnv1a(w.as_bytes()), self.seed) as usize & mask;
            while self.table[i] != EMPTY_SLOT {
                i = (i + 1) & mask;
            }
            self.table[i] = id as u32;
        }
    }
}

/// Reusable buffers for [`Document::reparse`]. One per gateway/worker;
/// every field retains its capacity across documents.
#[derive(Clone, Debug, Default)]
pub struct ParseScratch {
    pub(crate) interner: Interner,
    chars: Vec<char>,
    word_buf: String,
    sent_spare: Vec<String>,
    seq_spare: Vec<Vec<u32>>,
    df: Vec<u32>,
}

/// Resize an outer per-sentence buffer table to `n` cleared inner buffers,
/// recycling surplus inner allocations through `spare`.
fn recycle_rows(rows: &mut Vec<Vec<u32>>, n: usize, spare: &mut Vec<Vec<u32>>) {
    while rows.len() > n {
        let mut row = rows.pop().expect("len > n > 0");
        row.clear();
        spare.push(row);
    }
    while rows.len() < n {
        rows.push(spare.pop().unwrap_or_default());
    }
    for row in rows.iter_mut() {
        row.clear();
    }
}

/// A prompt split into sentences with interned word ids.
#[derive(Clone, Debug, Default)]
pub struct Document {
    /// Original sentences, in order.
    pub sentences: Vec<String>,
    /// Word-id sequence per sentence.
    pub word_seqs: Vec<Vec<u32>>,
    /// Sorted, deduplicated word ids per sentence (for O(a+b) overlap).
    pub word_sets: Vec<Vec<u32>>,
    /// 128-bit bloom signature of each word set: cheap popcount-based
    /// upper bound on set overlap, used as a prefilter by the novelty
    /// pass (§Perf).
    pub signatures: Vec<[u64; 2]>,
    /// Content-word sets: `word_sets` minus words appearing in more than
    /// ~20% of sentences. TextRank builds its similarity graph over these
    /// — function words both blur centrality and densify the O(S^2) edge
    /// construction that dominated the compressor profile (§Perf).
    pub content_sets: Vec<Vec<u32>>,
    /// LLM-token count per sentence (budget currency, Eq. 15).
    pub token_counts: Vec<u32>,
    /// Interned vocabulary size.
    pub vocab: usize,
}

impl Document {
    pub fn parse(text: &str) -> Self {
        let mut doc = Document::default();
        let mut scratch = ParseScratch::default();
        doc.reparse(text, &mut scratch);
        doc
    }

    /// Rebuild this document from `text` in place, reusing every buffer in
    /// `self` and `scratch` (§Perf: the steady-state gateway path performs
    /// no heap allocation here). Output is identical to [`Document::parse`].
    pub fn reparse(&mut self, text: &str, scratch: &mut ParseScratch) {
        split_sentences_reuse(
            text,
            &mut scratch.chars,
            &mut self.sentences,
            &mut scratch.sent_spare,
        );
        let n = self.sentences.len();
        scratch.interner.clear();
        recycle_rows(&mut self.word_seqs, n, &mut scratch.seq_spare);
        recycle_rows(&mut self.word_sets, n, &mut scratch.seq_spare);
        recycle_rows(&mut self.content_sets, n, &mut scratch.seq_spare);
        self.signatures.clear();
        self.token_counts.clear();
        for (i, s) in self.sentences.iter().enumerate() {
            let seq = &mut self.word_seqs[i];
            let interner = &mut scratch.interner;
            for_each_word(s, &mut scratch.word_buf, |w| seq.push(interner.intern(w)));
            let set = &mut self.word_sets[i];
            set.extend_from_slice(seq);
            set.sort_unstable();
            set.dedup();
            let mut sig = [0u64; 2];
            for &w in set.iter() {
                let h = (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 57; // 7 bits
                sig[(h >> 6) as usize] |= 1u64 << (h & 63);
            }
            self.signatures.push(sig);
            self.token_counts.push(count_tokens(s));
        }
        // Second pass: document frequency -> content-word sets.
        self.vocab = scratch.interner.len();
        let df = &mut scratch.df;
        df.clear();
        df.resize(self.vocab, 0);
        for set in &self.word_sets {
            for &w in set {
                df[w as usize] += 1;
            }
        }
        let df_cap = ((n as f64 * 0.2).ceil() as u32).max(3);
        for (i, set) in self.word_sets.iter().enumerate() {
            self.content_sets[i]
                .extend(set.iter().copied().filter(|&w| df[w as usize] <= df_cap));
        }
    }

    pub fn n_sentences(&self) -> usize {
        self.sentences.len()
    }

    pub fn total_tokens(&self) -> u32 {
        self.token_counts.iter().sum()
    }
}

/// Size of the intersection of two sorted, deduplicated id slices.
///
/// Dispatches to the galloping/AVX2 kernel (`compress::simd::intersect`)
/// when SIMD is active; counts are integers, so the result is exactly
/// [`overlap_scalar`]'s under every dispatch mode.
pub fn overlap(a: &[u32], b: &[u32]) -> usize {
    #[cfg(feature = "simd")]
    if crate::util::simd::simd_active() {
        return crate::compress::simd::intersect::intersect_count(a, b);
    }
    overlap_scalar(a, b)
}

/// The two-pointer merge oracle (and the scalar-dispatch path).
pub fn overlap_scalar(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// Jaccard similarity of two sorted id sets.
pub fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let inter = overlap(a, b);
    let union = a.len() + b.len() - inter;
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_counts_align() {
        let d = Document::parse("Alpha beta gamma. Beta gamma delta. Epsilon!");
        assert_eq!(d.n_sentences(), 3);
        assert_eq!(d.word_seqs.len(), 3);
        assert_eq!(d.token_counts.len(), 3);
        assert!(d.vocab >= 5);
    }

    #[test]
    fn interning_shares_ids_across_sentences() {
        let d = Document::parse("Alpha beta. Beta alpha.");
        let mut a = d.word_sets[0].clone();
        let mut b = d.word_sets[1].clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn overlap_dispatch_matches_scalar_oracle() {
        use crate::util::simd::{with_dispatch, Dispatch};
        let a: Vec<u32> = (0..97).map(|i| i * 5).collect();
        let b: Vec<u32> = (0..140).map(|i| i * 3 + 1).collect();
        let want = overlap_scalar(&a, &b);
        for mode in [Dispatch::ForceScalar, Dispatch::ForceSimd] {
            assert_eq!(with_dispatch(mode, || overlap(&a, &b)), want, "{mode:?}");
        }
    }

    #[test]
    fn overlap_and_jaccard() {
        assert_eq!(overlap(&[1, 2, 3], &[2, 3, 4]), 2);
        assert_eq!(overlap(&[], &[1]), 0);
        assert!((jaccard(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
        assert!((jaccard(&[1], &[1]) - 1.0).abs() < 1e-12);
        assert_eq!(jaccard(&[1], &[2]), 0.0);
    }

    #[test]
    fn total_tokens_matches_whole_text_roughly() {
        let text = "The borderline band holds most above-threshold traffic. \
                    Extractive compression trims it below the boundary. \
                    The long pool shrinks accordingly.";
        let d = Document::parse(text);
        let whole = crate::compress::tokenizer::count_tokens(text);
        let sum = d.total_tokens();
        // Sentence-wise counting equals whole-text counting (whitespace split).
        assert_eq!(sum, whole);
    }
}
