//! Tokenized document representation shared by the scoring stages: interned
//! word ids, per-sentence sorted word sets, and token counts. Building this
//! once keeps TextRank / TF-IDF / novelty passes allocation-light (the
//! compressor's 2–7 ms latency target, Table 4).

use std::collections::HashMap;

use crate::compress::sentence::split_sentences;
use crate::compress::tokenizer::{count_tokens, words};

/// A prompt split into sentences with interned word ids.
#[derive(Clone, Debug)]
pub struct Document {
    /// Original sentences, in order.
    pub sentences: Vec<String>,
    /// Word-id sequence per sentence.
    pub word_seqs: Vec<Vec<u32>>,
    /// Sorted, deduplicated word ids per sentence (for O(a+b) overlap).
    pub word_sets: Vec<Vec<u32>>,
    /// 128-bit bloom signature of each word set: cheap popcount-based
    /// upper bound on set overlap, used as a prefilter by the novelty
    /// pass (§Perf).
    pub signatures: Vec<[u64; 2]>,
    /// Content-word sets: `word_sets` minus words appearing in more than
    /// ~20% of sentences. TextRank builds its similarity graph over these
    /// — function words both blur centrality and densify the O(S^2) edge
    /// construction that dominated the compressor profile (§Perf).
    pub content_sets: Vec<Vec<u32>>,
    /// LLM-token count per sentence (budget currency, Eq. 15).
    pub token_counts: Vec<u32>,
    /// Interned vocabulary size.
    pub vocab: usize,
}

impl Document {
    pub fn parse(text: &str) -> Self {
        let sentences = split_sentences(text);
        let mut intern: HashMap<String, u32> = HashMap::new();
        let mut word_seqs = Vec::with_capacity(sentences.len());
        let mut word_sets = Vec::with_capacity(sentences.len());
        let mut signatures = Vec::with_capacity(sentences.len());
        let mut token_counts = Vec::with_capacity(sentences.len());
        for s in &sentences {
            let seq: Vec<u32> = words(s)
                .into_iter()
                .map(|w| {
                    let next = intern.len() as u32;
                    *intern.entry(w).or_insert(next)
                })
                .collect();
            let mut set = seq.clone();
            set.sort_unstable();
            set.dedup();
            let mut sig = [0u64; 2];
            for &w in &set {
                let h = (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 57; // 7 bits
                sig[(h >> 6) as usize] |= 1u64 << (h & 63);
            }
            word_seqs.push(seq);
            word_sets.push(set);
            signatures.push(sig);
            token_counts.push(count_tokens(s));
        }
        // Second pass: document frequency -> content-word sets.
        let vocab = intern.len();
        let mut df = vec![0u32; vocab];
        for set in &word_sets {
            for &w in set {
                df[w as usize] += 1;
            }
        }
        let df_cap = ((sentences.len() as f64 * 0.2).ceil() as u32).max(3);
        let content_sets = word_sets
            .iter()
            .map(|set| {
                set.iter()
                    .copied()
                    .filter(|&w| df[w as usize] <= df_cap)
                    .collect()
            })
            .collect();
        Document {
            sentences,
            word_seqs,
            word_sets,
            signatures,
            content_sets,
            token_counts,
            vocab,
        }
    }

    pub fn n_sentences(&self) -> usize {
        self.sentences.len()
    }

    pub fn total_tokens(&self) -> u32 {
        self.token_counts.iter().sum()
    }
}

/// Size of the intersection of two sorted, deduplicated id slices.
pub fn overlap(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// Jaccard similarity of two sorted id sets.
pub fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let inter = overlap(a, b);
    let union = a.len() + b.len() - inter;
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_counts_align() {
        let d = Document::parse("Alpha beta gamma. Beta gamma delta. Epsilon!");
        assert_eq!(d.n_sentences(), 3);
        assert_eq!(d.word_seqs.len(), 3);
        assert_eq!(d.token_counts.len(), 3);
        assert!(d.vocab >= 5);
    }

    #[test]
    fn interning_shares_ids_across_sentences() {
        let d = Document::parse("Alpha beta. Beta alpha.");
        let mut a = d.word_sets[0].clone();
        let mut b = d.word_sets[1].clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn overlap_and_jaccard() {
        assert_eq!(overlap(&[1, 2, 3], &[2, 3, 4]), 2);
        assert_eq!(overlap(&[], &[1]), 0);
        assert!((jaccard(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
        assert!((jaccard(&[1], &[1]) - 1.0).abs() < 1e-12);
        assert_eq!(jaccard(&[1], &[2]), 0.0);
    }

    #[test]
    fn total_tokens_matches_whole_text_roughly() {
        let text = "The borderline band holds most above-threshold traffic. \
                    Extractive compression trims it below the boundary. \
                    The long pool shrinks accordingly.";
        let d = Document::parse(text);
        let whole = crate::compress::tokenizer::count_tokens(text);
        let sum = d.total_tokens();
        // Sentence-wise counting equals whole-text counting (whitespace split).
        assert_eq!(sum, whole);
    }
}
