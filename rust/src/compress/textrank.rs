//! TextRank sentence centrality (Mihalcea & Tarau 2004) — 20% of the
//! composite score (paper §5.2 step 2).
//!
//! Graph nodes are sentences; edge weights are the classic normalized word
//! overlap `|w_i ∩ w_j| / (ln|w_i| + ln|w_j|)`. Scores come from damped
//! power iteration (d = 0.85) over the weighted graph.
//!
//! §Perf: graph construction is driven by an **inverted index** (per-word
//! postings lists over the content-word sets) instead of all-pairs sorted
//! set intersection: only sentence pairs that actually share a content
//! word are ever touched, and the shared-word count *is* the overlap, so
//! the O(S²) merge pass disappears. The previous all-pairs builder is kept
//! behind [`SimilarityMode::AllPairs`] as the equivalence oracle — both
//! paths emit edges in the identical (i, then ascending j) order with the
//! identical arithmetic, so scores are bit-equal (property-tested).

use crate::compress::doc::{overlap, Document};

const DAMPING: f64 = 0.85;
// 20 damped iterations at tol 1e-3/node rank-stabilize hundreds-of-sentence
// documents; the §Perf pass cut this from 100 @ 1e-6 with no selection
// changes on the corpus (scores feed a min-max normalize + 0.20 weight).
const MAX_ITERS: usize = 20;
const TOL: f64 = 1e-3;

/// How the sentence-similarity graph is built.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimilarityMode {
    /// Postings-list (inverted index) construction — the fast default.
    #[default]
    InvertedIndex,
    /// The naive all-pairs sorted-set intersection (equivalence oracle).
    AllPairs,
}

/// Reusable buffers for [`centrality_into`]; every field keeps its
/// capacity across documents (§Perf: zero steady-state allocation).
#[derive(Clone, Debug, Default)]
pub struct TextrankScratch {
    /// Word id -> sentence ids containing it (ascending; len >= 2 only).
    postings: Vec<Vec<u32>>,
    /// Word ids whose postings list is non-empty (for O(used) clearing).
    used_words: Vec<u32>,
    /// Shared-word count per candidate sentence j.
    counts: Vec<u32>,
    /// Candidate j's touched for the current i.
    touched: Vec<u32>,
    /// Adjacency: edges[i] = (j, weight), later degree-normalized.
    edges: Vec<Vec<(u32, f64)>>,
    degree: Vec<f64>,
    score: Vec<f64>,
    next: Vec<f64>,
    /// CSR edge arena for the SIMD-dispatch power iteration (§Perf PR 6).
    #[cfg(feature = "simd")]
    csr: CsrArena,
}

/// SoA transpose of the normalized adjacency: row `i` holds the inbound
/// contributions to sentence `i` in ascending-source order (see
/// [`power_iterate_csr`]). All buffers keep capacity across documents.
#[cfg(feature = "simd")]
#[derive(Clone, Debug, Default)]
struct CsrArena {
    row_off: Vec<u32>,
    col: Vec<u32>,
    w: Vec<f64>,
    /// Per-row write cursors used during the counting-sort transpose.
    fill: Vec<u32>,
}

/// Sentence centrality scores, one per sentence (non-negative, sum ~ n).
pub fn textrank(doc: &Document) -> Vec<f64> {
    textrank_with_mode(doc, SimilarityMode::InvertedIndex)
}

/// The all-pairs reference path (kept for equivalence testing, §Perf).
pub fn textrank_naive(doc: &Document) -> Vec<f64> {
    textrank_with_mode(doc, SimilarityMode::AllPairs)
}

/// One-shot wrapper over [`centrality_into`] with a fresh scratch.
pub fn textrank_with_mode(doc: &Document, mode: SimilarityMode) -> Vec<f64> {
    let mut scratch = TextrankScratch::default();
    let mut out = Vec::new();
    centrality_into(doc, mode, &mut scratch, &mut out);
    out
}

/// Compute centrality scores into `out` using caller-owned buffers.
pub fn centrality_into(
    doc: &Document,
    mode: SimilarityMode,
    ts: &mut TextrankScratch,
    out: &mut Vec<f64>,
) {
    let n = doc.n_sentences();
    out.clear();
    if n == 0 {
        return;
    }
    if n == 1 {
        out.push(1.0);
        return;
    }

    // Sparse adjacency with outbound weights pre-normalized by degree: the
    // power-iteration inner loop is then a single fused multiply-add per
    // edge (§Perf: dense matvec was the compressor's top hotspot).
    while ts.edges.len() < n {
        ts.edges.push(Vec::new());
    }
    for es in ts.edges[..n].iter_mut() {
        es.clear();
    }
    ts.degree.clear();
    ts.degree.resize(n, 0.0);

    match mode {
        SimilarityMode::AllPairs => build_edges_all_pairs(doc, &mut ts.edges, &mut ts.degree),
        SimilarityMode::InvertedIndex => build_edges_inverted(doc, ts),
    }

    // Normalize outbound weights once.
    for (i, es) in ts.edges[..n].iter_mut().enumerate() {
        if ts.degree[i] > 0.0 {
            for e in es.iter_mut() {
                e.1 /= ts.degree[i];
            }
        }
    }

    #[cfg(feature = "simd")]
    if crate::util::simd::simd_active() {
        power_iterate_csr(ts, n, out);
        return;
    }

    ts.score.clear();
    ts.score.resize(n, 1.0);
    ts.next.clear();
    ts.next.resize(n, 0.0);
    for _ in 0..MAX_ITERS {
        ts.next.fill(1.0 - DAMPING);
        for (j, es) in ts.edges[..n].iter().enumerate() {
            let s = DAMPING * ts.score[j];
            for &(i, w_norm) in es {
                ts.next[i as usize] += w_norm * s;
            }
        }
        let delta: f64 = ts
            .score
            .iter()
            .zip(ts.next.iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        std::mem::swap(&mut ts.score, &mut ts.next);
        if delta < TOL * n as f64 {
            break;
        }
    }
    out.extend_from_slice(&ts.score[..n]);
}

/// SIMD-dispatch power iteration over the CSR edge arena (§Perf PR 6).
///
/// Transposes the normalized adjacency with a counting sort — entry
/// `(t, w)` in `edges[j]` lands in CSR row `t` carrying source `j` and
/// weight `w = sim / degree[j]`, rows filled in ascending `j` because the
/// outer loop walks sources in order — then runs the damped iterations as
/// gathers ([`crate::compress::simd::spmv::spmv_step`]). Row `t`'s adds
/// are the scatter loop's adds into `next[t]` in the same order with the
/// same operands, and the delta reduction below is the scalar loop's own
/// sequential sum, so scores are bit-identical (property-tested).
#[cfg(feature = "simd")]
fn power_iterate_csr(ts: &mut TextrankScratch, n: usize, out: &mut Vec<f64>) {
    let csr = &mut ts.csr;
    csr.row_off.clear();
    csr.row_off.resize(n + 1, 0);
    for es in ts.edges[..n].iter() {
        for &(t, _) in es {
            csr.row_off[t as usize + 1] += 1;
        }
    }
    for i in 0..n {
        csr.row_off[i + 1] += csr.row_off[i];
    }
    let nnz = csr.row_off[n] as usize;
    csr.col.clear();
    csr.col.resize(nnz, 0);
    csr.w.clear();
    csr.w.resize(nnz, 0.0);
    csr.fill.clear();
    csr.fill.extend_from_slice(&csr.row_off[..n]);
    for (j, es) in ts.edges[..n].iter().enumerate() {
        for &(t, wv) in es {
            let slot = csr.fill[t as usize] as usize;
            csr.col[slot] = j as u32;
            csr.w[slot] = wv;
            csr.fill[t as usize] += 1;
        }
    }

    ts.score.clear();
    ts.score.resize(n, 1.0);
    ts.next.clear();
    ts.next.resize(n, 0.0);
    for _ in 0..MAX_ITERS {
        crate::compress::simd::spmv::spmv_step(
            &ts.csr.row_off,
            &ts.csr.col,
            &ts.csr.w,
            &ts.score[..n],
            DAMPING,
            1.0 - DAMPING,
            &mut ts.next[..n],
        );
        let delta: f64 = ts
            .score
            .iter()
            .zip(ts.next.iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        std::mem::swap(&mut ts.score, &mut ts.next);
        if delta < TOL * n as f64 {
            break;
        }
    }
    out.extend_from_slice(&ts.score[..n]);
}

/// The classic O(S²) builder: every pair of content-word sets is merged.
fn build_edges_all_pairs(doc: &Document, edges: &mut [Vec<(u32, f64)>], degree: &mut [f64]) {
    let n = doc.n_sentences();
    for i in 0..n {
        for j in (i + 1)..n {
            let (a, b) = (&doc.content_sets[i], &doc.content_sets[j]);
            if a.len() < 2 || b.len() < 2 {
                continue; // ln(1) = 0 denominators
            }
            let ov = overlap(a, b);
            if ov == 0 {
                continue;
            }
            push_edge(edges, degree, i, j, ov, a.len(), b.len());
        }
    }
}

/// Postings-list builder: for each sentence i, walk the postings of its
/// content words and count shared words per later sentence j — the count
/// is exactly `|w_i ∩ w_j|` because content sets are deduplicated. Work is
/// proportional to Σ_w df(w)² over content words (df-capped by
/// construction) instead of S²·|set| merge steps.
fn build_edges_inverted(doc: &Document, ts: &mut TextrankScratch) {
    let n = doc.n_sentences();
    for &w in &ts.used_words {
        ts.postings[w as usize].clear();
    }
    ts.used_words.clear();
    if ts.postings.len() < doc.vocab {
        ts.postings.resize_with(doc.vocab, Vec::new);
    }
    for (i, set) in doc.content_sets.iter().enumerate() {
        if set.len() < 2 {
            continue; // ln(1) = 0 denominators — excluded from the graph
        }
        for &w in set {
            let p = &mut ts.postings[w as usize];
            if p.is_empty() {
                ts.used_words.push(w);
            }
            p.push(i as u32);
        }
    }
    ts.counts.clear();
    ts.counts.resize(n, 0);
    for (i, a) in doc.content_sets.iter().enumerate() {
        if a.len() < 2 {
            continue;
        }
        ts.touched.clear();
        for &w in a {
            let p = &ts.postings[w as usize];
            // Postings are ascending (built in sentence order): only the
            // suffix strictly after i matters.
            let start = p.partition_point(|&j| j as usize <= i);
            for &j in &p[start..] {
                if ts.counts[j as usize] == 0 {
                    ts.touched.push(j);
                }
                ts.counts[j as usize] += 1;
            }
        }
        // Ascending j reproduces the all-pairs emission order, so float
        // accumulation into `degree` is bit-identical.
        ts.touched.sort_unstable();
        for &jt in &ts.touched {
            let j = jt as usize;
            let ov = ts.counts[j] as usize;
            ts.counts[j] = 0;
            let b_len = doc.content_sets[j].len();
            push_edge(&mut ts.edges, &mut ts.degree, i, j, ov, a.len(), b_len);
        }
    }
}

#[inline]
fn push_edge(
    edges: &mut [Vec<(u32, f64)>],
    degree: &mut [f64],
    i: usize,
    j: usize,
    ov: usize,
    a_len: usize,
    b_len: usize,
) {
    let sim = ov as f64 / ((a_len as f64).ln() + (b_len as f64).ln());
    edges[i].push((j as u32, sim));
    edges[j].push((i as u32, sim));
    degree[i] += sim;
    degree[j] += sim;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn central_sentence_scores_highest() {
        // The middle sentence shares words with both others; the outliers
        // share nothing with each other.
        let d = Document::parse(
            "Fleet provisioning drives the cost model here. \
             The cost model and the routing boundary interact strongly. \
             Routing boundary decisions change pool sizes notably.",
        );
        let s = textrank(&d);
        assert_eq!(s.len(), 3);
        assert!(s[1] > s[0] && s[1] > s[2], "scores {s:?}");
    }

    #[test]
    fn isolated_sentences_get_base_score() {
        let d = Document::parse("Alpha beta gamma delta. Epsilon zeta eta theta.");
        let s = textrank(&d);
        // No overlap at all: everything sits at the (1 - d) base.
        for v in &s {
            assert!((v - 0.15).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn single_sentence() {
        let d = Document::parse("Only one sentence here.");
        assert_eq!(textrank(&d), vec![1.0]);
    }

    #[test]
    fn empty_document() {
        let d = Document::parse("");
        assert!(textrank(&d).is_empty());
    }

    #[test]
    fn scores_positive_and_finite() {
        let text = (0..40)
            .map(|i| format!("Sentence number {i} talks about topic {}.", i % 5))
            .collect::<Vec<_>>()
            .join(" ");
        let d = Document::parse(&text);
        let s = textrank(&d);
        assert_eq!(s.len(), 40);
        assert!(s.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn deterministic() {
        let text = "Pools split traffic. Traffic shapes pools. Compression shifts boundaries.";
        let d = Document::parse(text);
        assert_eq!(textrank(&d), textrank(&d));
    }

    #[test]
    fn inverted_index_is_bit_identical_to_all_pairs() {
        for text in [
            "Pools split traffic. Traffic shapes pools. Compression shifts boundaries.",
            "Alpha beta gamma delta. Epsilon zeta eta theta.",
            "One. Two words here. A much longer sentence about pools and traffic \
             and boundaries. Traffic and pools again. Boundaries of pools.",
        ] {
            let d = Document::parse(text);
            assert_eq!(textrank(&d), textrank_naive(&d), "text={text:?}");
        }
    }

    #[test]
    fn csr_dispatch_is_bit_identical_to_scatter() {
        use crate::util::simd::{with_dispatch, Dispatch};
        let text = (0..60)
            .map(|i| format!("Sentence {i} covers topic {} and topic {}.", i % 7, i % 3))
            .collect::<Vec<_>>()
            .join(" ");
        let d = Document::parse(&text);
        let scalar = with_dispatch(Dispatch::ForceScalar, || textrank(&d));
        let simd = with_dispatch(Dispatch::ForceSimd, || textrank(&d));
        assert_eq!(scalar.len(), simd.len());
        for (i, (a, b)) in scalar.iter().zip(&simd).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "sentence {i}: {a} vs {b}");
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let mut ts = TextrankScratch::default();
        let mut out = Vec::new();
        for k in 0..3 {
            let text = (0..(20 + 10 * k))
                .map(|i| format!("Sentence {i} covers topic {} deeply.", i % 4))
                .collect::<Vec<_>>()
                .join(" ");
            let d = Document::parse(&text);
            centrality_into(&d, SimilarityMode::InvertedIndex, &mut ts, &mut out);
            assert_eq!(out, textrank_naive(&d), "doc {k}");
        }
    }
}
