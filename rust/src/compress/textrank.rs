//! TextRank sentence centrality (Mihalcea & Tarau 2004) — 20% of the
//! composite score (paper §5.2 step 2).
//!
//! Graph nodes are sentences; edge weights are the classic normalized word
//! overlap `|w_i ∩ w_j| / (ln|w_i| + ln|w_j|)`. Scores come from damped
//! power iteration (d = 0.85) over the weighted graph.

use crate::compress::doc::{overlap, Document};

const DAMPING: f64 = 0.85;
// 20 damped iterations at tol 1e-3/node rank-stabilize hundreds-of-sentence
// documents; the §Perf pass cut this from 100 @ 1e-6 with no selection
// changes on the corpus (scores feed a min-max normalize + 0.20 weight).
const MAX_ITERS: usize = 20;
const TOL: f64 = 1e-3;

/// Sentence centrality scores, one per sentence (non-negative, sum ~ n).
pub fn textrank(doc: &Document) -> Vec<f64> {
    let n = doc.n_sentences();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![1.0];
    }

    // Sparse CSR adjacency with outbound weights pre-normalized by degree:
    // the power-iteration inner loop is then a single fused multiply-add
    // per edge (§Perf: dense matvec was the compressor's top hotspot).
    let mut edges: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    let mut degree = vec![0.0f64; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let (a, b) = (&doc.content_sets[i], &doc.content_sets[j]);
            if a.len() < 2 || b.len() < 2 {
                continue; // ln(1) = 0 denominators
            }
            let ov = overlap(a, b);
            if ov == 0 {
                continue;
            }
            let sim = ov as f64 / ((a.len() as f64).ln() + (b.len() as f64).ln());
            edges[i].push((j as u32, sim));
            edges[j].push((i as u32, sim));
            degree[i] += sim;
            degree[j] += sim;
        }
    }
    // Normalize outbound weights once.
    for (i, es) in edges.iter_mut().enumerate() {
        if degree[i] > 0.0 {
            for e in es.iter_mut() {
                e.1 /= degree[i];
            }
        }
    }

    let mut score = vec![1.0f64; n];
    let mut next = vec![0.0f64; n];
    for _ in 0..MAX_ITERS {
        next.fill(1.0 - DAMPING);
        for (j, es) in edges.iter().enumerate() {
            let s = DAMPING * score[j];
            for &(i, w_norm) in es {
                next[i as usize] += w_norm * s;
            }
        }
        let delta: f64 = score
            .iter()
            .zip(next.iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        std::mem::swap(&mut score, &mut next);
        if delta < TOL * n as f64 {
            break;
        }
    }
    score
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn central_sentence_scores_highest() {
        // The middle sentence shares words with both others; the outliers
        // share nothing with each other.
        let d = Document::parse(
            "Fleet provisioning drives the cost model here. \
             The cost model and the routing boundary interact strongly. \
             Routing boundary decisions change pool sizes notably.",
        );
        let s = textrank(&d);
        assert_eq!(s.len(), 3);
        assert!(s[1] > s[0] && s[1] > s[2], "scores {s:?}");
    }

    #[test]
    fn isolated_sentences_get_base_score() {
        let d = Document::parse("Alpha beta gamma delta. Epsilon zeta eta theta.");
        let s = textrank(&d);
        // No overlap at all: everything sits at the (1 - d) base.
        for v in &s {
            assert!((v - 0.15).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn single_sentence() {
        let d = Document::parse("Only one sentence here.");
        assert_eq!(textrank(&d), vec![1.0]);
    }

    #[test]
    fn empty_document() {
        let d = Document::parse("");
        assert!(textrank(&d).is_empty());
    }

    #[test]
    fn scores_positive_and_finite() {
        let text = (0..40)
            .map(|i| format!("Sentence number {i} talks about topic {}.", i % 5))
            .collect::<Vec<_>>()
            .join(" ");
        let d = Document::parse(&text);
        let s = textrank(&d);
        assert_eq!(s.len(), 40);
        assert!(s.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn deterministic() {
        let text = "Pools split traffic. Traffic shapes pools. Compression shifts boundaries.";
        let d = Document::parse(text);
        assert_eq!(textrank(&d), textrank(&d));
    }
}
