//! TextRank power iteration as a gather over a CSR edge arena (§Perf,
//! PR 6): the per-node `Vec<Vec<(u32, f64)>>` adjacency scatter becomes a
//! single pass over three flat SoA arrays (row offsets, column ids, edge
//! weights) — contiguous loads, no per-node pointer chase, and the layout
//! the compiler can unroll.
//!
//! Identity: the CSR is the counting-sort transpose of the normalized
//! adjacency, so row `i` holds exactly the contributions the scalar
//! scatter accumulates into `next[i]`, in the same ascending-source
//! order, each computed with the same two multiplies. Per-row
//! accumulation stays strictly sequential — splitting one row's sum
//! across lanes would reassociate, which the identity policy forbids —
//! and rows never share an accumulator, so the whole step is
//! bit-identical to the scatter loop (property-tested).

/// One damped power-iteration step in gather form:
///
/// `next[i] = base + Σ_k w[k] * (damping * score[col[k]])`
///
/// for `k` in row `i` of the CSR (`row_off[i]..row_off[i + 1]`).
pub fn spmv_step(
    row_off: &[u32],
    col: &[u32],
    w: &[f64],
    score: &[f64],
    damping: f64,
    base: f64,
    next: &mut [f64],
) {
    for (i, next_i) in next.iter_mut().enumerate() {
        let (s, e) = (row_off[i] as usize, row_off[i + 1] as usize);
        let mut acc = base;
        for (&wk, &c) in w[s..e].iter().zip(&col[s..e]) {
            acc += wk * (damping * score[c as usize]);
        }
        *next_i = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_matches_scatter_bitwise() {
        // 4-node graph in both layouts; weights chosen non-representable
        // so any op reordering would flip low bits.
        let edges: Vec<Vec<(u32, f64)>> = vec![
            vec![(1, 0.1), (2, 0.3)],
            vec![(0, 0.1), (3, 0.7)],
            vec![(0, 0.3)],
            vec![(1, 0.7)],
        ];
        let n = edges.len();
        let score = [1.0, 0.9, 1.2, 0.8];
        let damping = 0.85;
        let base = 0.15;

        let mut scatter = vec![base; n];
        for (j, es) in edges.iter().enumerate() {
            let s = damping * score[j];
            for &(i, wn) in es {
                scatter[i as usize] += wn * s;
            }
        }

        // Counting-sort transpose (as power_iterate_csr builds it).
        let mut row_off = vec![0u32; n + 1];
        for es in &edges {
            for &(t, _) in es {
                row_off[t as usize + 1] += 1;
            }
        }
        for i in 0..n {
            row_off[i + 1] += row_off[i];
        }
        let nnz = row_off[n] as usize;
        let mut fill: Vec<u32> = row_off[..n].to_vec();
        let mut col = vec![0u32; nnz];
        let mut w = vec![0.0f64; nnz];
        for (j, es) in edges.iter().enumerate() {
            for &(t, wn) in es {
                let slot = fill[t as usize] as usize;
                col[slot] = j as u32;
                w[slot] = wn;
                fill[t as usize] += 1;
            }
        }

        let mut gather = vec![0.0f64; n];
        spmv_step(&row_off, &col, &w, &score, damping, base, &mut gather);
        for i in 0..n {
            assert_eq!(
                scatter[i].to_bits(),
                gather[i].to_bits(),
                "node {i}: scatter {} vs gather {}",
                scatter[i],
                gather[i]
            );
        }
    }

    #[test]
    fn empty_rows_get_base() {
        let row_off = [0u32, 0, 0];
        let mut next = [0.0f64; 2];
        spmv_step(&row_off, &[], &[], &[1.0, 1.0], 0.85, 0.15, &mut next);
        assert_eq!(next, [0.15, 0.15]);
    }
}
