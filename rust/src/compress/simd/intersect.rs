//! Sorted-u32 set intersection: galloping search over the longer list,
//! with an AVX2 8-lane broadcast-compare advancing the gallop on x86_64
//! (runtime `is_x86_feature_detected!`), and a blocked scalar gallop on
//! other architectures. Counts are integers, so every path returns
//! exactly what the two-pointer merge oracle
//! (`compress::doc::overlap_scalar`) returns.
//!
//! Galloping wins over the merge when the two lists have very different
//! lengths (a rare content word probing a long sentence's set) and loses
//! nothing when they are similar: each probe advances through the longer
//! list in 8-element blocks until the block containing the first element
//! `>= x` is found, then finishes with at most 8 scalar steps.

/// Intersection size of two sorted, deduplicated id slices, dispatched to
/// the best available kernel for this CPU.
pub fn intersect_count(a: &[u32], b: &[u32]) -> usize {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability just checked at runtime.
            return unsafe { intersect_count_avx2(a, b) };
        }
    }
    intersect_count_gallop(a, b)
}

/// Portable blocked gallop (also the non-x86_64 dispatch target): skip
/// 8-element blocks of the longer list whose last element is still below
/// the probe, then settle scalar.
pub fn intersect_count_gallop(a: &[u32], b: &[u32]) -> usize {
    let (small, big) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    let mut count = 0usize;
    let mut j = 0usize;
    for &x in small {
        while j + 8 <= big.len() && big[j + 7] < x {
            j += 8;
        }
        while j < big.len() && big[j] < x {
            j += 1;
        }
        if j < big.len() && big[j] == x {
            count += 1;
            j += 1;
        }
    }
    count
}

/// AVX2 gallop: one unaligned 8-lane load per block, unsigned `>= x` via
/// `max_epu32 == self`, movemask to locate the first qualifying lane.
/// Probe order and the final scalar settle are identical to
/// [`intersect_count_gallop`], so the count is exactly the oracle's.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn intersect_count_avx2(a: &[u32], b: &[u32]) -> usize {
    use std::arch::x86_64::{
        _mm256_castsi256_ps, _mm256_cmpeq_epi32, _mm256_loadu_si256, _mm256_max_epu32,
        _mm256_movemask_ps, _mm256_set1_epi32,
    };
    let (small, big) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    let mut count = 0usize;
    let mut j = 0usize;
    for &x in small {
        let bx = _mm256_set1_epi32(x as i32);
        while j + 8 <= big.len() {
            // SAFETY: `j + 8 <= big.len()` bounds the 8-lane load.
            let block = unsafe { _mm256_loadu_si256(big.as_ptr().add(j).cast()) };
            // Lane l sets ge iff big[j+l] >= x (unsigned): max(v, x) == v.
            let ge = _mm256_cmpeq_epi32(_mm256_max_epu32(block, bx), block);
            let mask = _mm256_movemask_ps(_mm256_castsi256_ps(ge));
            if mask != 0 {
                j += mask.trailing_zeros() as usize;
                break;
            }
            j += 8;
        }
        while j < big.len() && big[j] < x {
            j += 1;
        }
        if j < big.len() && big[j] == x {
            count += 1;
            j += 1;
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::doc::overlap_scalar;
    use crate::util::check::{ensure, forall};

    fn sorted_set(rng: &mut crate::util::rng::Rng, max_len: usize, universe: u32) -> Vec<u32> {
        let n = rng.range(0, max_len + 1);
        let mut v: Vec<u32> = (0..n).map(|_| rng.below(universe as u64) as u32).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    #[test]
    fn matches_merge_oracle_on_randomized_sets() {
        forall(
            "intersect-vs-merge",
            200,
            |rng| {
                let a = sorted_set(rng, 120, 300);
                let b = sorted_set(rng, 120, 300);
                (a, b)
            },
            |(a, b)| {
                let want = overlap_scalar(a, b);
                ensure(
                    intersect_count(a, b) == want,
                    format!("dispatched count != oracle {want}"),
                )?;
                ensure(
                    intersect_count_gallop(a, b) == want,
                    format!("gallop count != oracle {want}"),
                )
            },
        );
    }

    #[test]
    fn asymmetric_and_edge_cases() {
        let empty: Vec<u32> = vec![];
        let long: Vec<u32> = (0..1000).map(|i| i * 3).collect();
        assert_eq!(intersect_count(&empty, &long), 0);
        assert_eq!(intersect_count(&long, &empty), 0);
        assert_eq!(intersect_count(&long, &long), long.len());
        // Sparse probes deep into a long list (the gallop's home turf).
        let probes: Vec<u32> = vec![3, 2_001, 2_998, 2_999];
        assert_eq!(intersect_count(&probes, &long), overlap_scalar(&probes, &long));
        // Disjoint interleaved.
        let evens: Vec<u32> = (0..200).map(|i| i * 2).collect();
        let odds: Vec<u32> = (0..200).map(|i| i * 2 + 1).collect();
        assert_eq!(intersect_count(&evens, &odds), 0);
        // Values above i32::MAX exercise the unsigned compare.
        let hi_a: Vec<u32> = vec![1, u32::MAX - 9, u32::MAX - 1, u32::MAX];
        let hi_b: Vec<u32> = (0..64).map(|i| u32::MAX - 63 + i).collect();
        assert_eq!(intersect_count(&hi_a, &hi_b), overlap_scalar(&hi_a, &hi_b));
    }
}
