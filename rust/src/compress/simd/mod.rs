//! SoA/SIMD kernels for the gateway scoring pipeline (§Perf, PR 6;
//! `simd` cargo feature, default on).
//!
//! Three of the compressor's four hottest inner loops live here or are
//! restructured around the layouts defined here:
//!
//! * [`intersect`] — sorted-u32 postings/word-set intersection: galloping
//!   search with an AVX2 8-lane broadcast-compare on x86_64 (runtime
//!   feature detection), a blocked scalar gallop elsewhere. Consumed by
//!   `compress::doc::overlap`, i.e. the novelty pass and the AllPairs
//!   TextRank oracle.
//! * [`spmv`] — the TextRank power-iteration step as a gather over a CSR
//!   edge arena (SoA row-offset/column/weight arrays) instead of per-node
//!   `Vec<(u32, f64)>` adjacency walks.
//! * The TF-IDF SoA weight table lives in `compress::tfidf`
//!   (`sentence_scores_soa`): one `tf/total * idf` per distinct word id,
//!   gathered per occurrence.
//!
//! Identity policy: every kernel's shipped output is bit-identical to its
//! scalar oracle — intersection counts are integers; the CSR gather adds
//! the same f64 terms in the same order as the scalar scatter; the weight
//! table stores the exact product the scalar path recomputes at every
//! occurrence. Dispatch is `crate::util::simd::simd_active()` checked at
//! each call site, so force-scalar always exercises the live fallback.

pub mod intersect;
pub mod spmv;
