//! # FleetOpt
//!
//! Reproduction of *"FleetOpt: Analytical Fleet Provisioning for LLM
//! Inference with Compress-and-Route as Implementation Mechanism"*
//! (CS.DC 2026). See DESIGN.md for the system inventory and EXPERIMENTS.md
//! for the paper-vs-measured record.
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * [`queueing`] — the analytical core: M/G/c model, log-space Erlang-C,
//!   Kimura P99 wait approximation, service-time model (paper §3).
//! * [`planner`] — the FleetOpt offline planner, Algorithm 1 (paper §4, §6).
//! * [`workload`] — prompt-length CDFs, the three evaluation traces, and
//!   Poisson arrival processes (paper §2.4, §7.1).
//! * [`compress`] — the Compress-and-Route extractive pipeline (paper §5).
//! * [`router`] — the gateway: token-budget estimation, category
//!   classification, pool routing + C&R (paper §2.1, §5.1).
//! * [`fleetsim`] — `inference-fleet-sim`, the discrete-event simulator
//!   used to validate the analytical model (paper §7.4).
//! * [`runtime`] — PJRT executor loading the AOT HLO-text artifacts built
//!   by `python/compile/aot.py` (L2 JAX model + L1 Pallas kernels).
//! * [`coordinator`] — the live serving path: KV-slot manager, continuous
//!   batcher, chunked-prefill/decode scheduler, two-pool fleet.
//! * [`util`] — zero-dependency substrates (RNG, JSON, stats, tables,
//!   property-check harness).

pub mod compress;
pub mod config;
pub mod experiments;
pub mod coordinator;
pub mod fleetsim;
pub mod metrics;
pub mod model;
pub mod planner;
pub mod queueing;
pub mod router;
pub mod runtime;
pub mod util;
pub mod workload;
