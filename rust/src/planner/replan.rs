//! Online re-planning with hysteresis: the planner half of the
//! autoscaling control loop.
//!
//! Each epoch the controller re-estimates the window CDF and arrival rate
//! and calls [`Replanner::replan`]. The replanner evaluates two options
//! against the drifted input through one long-lived [`CalibCache`] (warm
//! start — calibrations survive across epochs):
//!
//! 1. **hold** — keep the current tier layout (boundaries + gammas) and
//!    only re-run the Erlang-C inversion, i.e. resize the replica sets;
//! 2. **candidate** — re-sweep the gamma grid at the current spec (and,
//!    with [`ReplanConfig::sweep_boundaries`], the full boundary grid).
//!
//! Hysteresis has two knobs. A *switching cost*: the candidate layout is
//! adopted only when it beats the hold plan by more than
//! `switch_threshold` (relative) — re-tiering a live fleet drains and
//! re-provisions capacity, so a marginal win must not thrash the layout
//! every epoch. A *scale-down dead-band*: within an unchanged layout, a
//! tier sheds GPUs only when the target drops below
//! `current * (1 - scale_down_deadband)`; scale-**up** is always immediate
//! (capacity shortfalls burn SLO, surpluses only burn dollars).

use crate::planner::cost::fleet_cost_yr_tiered;
use crate::planner::sizing::SizingError;
use crate::planner::sweep::{CalibCache, PlanInput};
use crate::planner::tiered::{
    layout_neighborhood, plan_spec_sweep_gamma_cached, plan_tiers, sweep_tiered_pruned,
    sweep_tiered_pruned_seeded, TieredPlan,
};
use crate::workload::traces::Workload;

/// [`Workload::fingerprint`]: [`CalibCache`] keys memoized service stats
/// by truncation cuts only, so a cache may only be reused while the
/// underlying distribution is unchanged — a drifted empirical snapshot
/// must invalidate it (and does the same to the shared moment tables).
fn workload_fingerprint(w: &Workload) -> u64 {
    w.fingerprint()
}

/// Hysteresis configuration for online re-planning.
#[derive(Clone, Debug)]
pub struct ReplanConfig {
    /// Relative cost improvement a structurally different plan must
    /// deliver before the layout switches (0.05 = 5%).
    pub switch_threshold: f64,
    /// Scale-down dead-band: hold a tier's GPU count unless the target is
    /// below `current * (1 - scale_down_deadband)`.
    pub scale_down_deadband: f64,
    /// Also sweep the full boundary grid each epoch (more optimal, more
    /// expensive, and layout switches re-provision the whole fleet).
    pub sweep_boundaries: bool,
    /// Incremental boundary sweeps (only meaningful with
    /// `sweep_boundaries`): on an epoch whose workload fingerprint is
    /// unchanged (pure rate drift — the warm-cache case), evaluate the
    /// previous layout's grid neighbourhood first and let the
    /// bound-and-prune pass dispose of the rest of the grid against that
    /// incumbent. The adopted plan is **identical** to a full sweep's
    /// (seeding never changes the pruned sweep's result — tested); only
    /// the work shrinks, >= 10x vs a cold sweep in the bench. A drifted
    /// fingerprint always falls back to the unseeded (full) sweep.
    pub incremental: bool,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            switch_threshold: 0.05,
            scale_down_deadband: 0.10,
            sweep_boundaries: false,
            incremental: true,
        }
    }
}

/// One epoch's re-planning decision.
#[derive(Clone, Debug)]
pub struct ReplanOutcome {
    /// The adopted plan (GPU counts are post-dead-band).
    pub plan: TieredPlan,
    /// The tier layout (boundaries or gammas) changed.
    pub switched_layout: bool,
    /// Cheapest candidate cost this epoch (pre-hysteresis), $/yr.
    pub candidate_cost_yr: f64,
    /// Cost of resizing in place at the old layout, $/yr.
    pub held_cost_yr: f64,
}

/// Stateful incremental planner: owns the current plan and the shared
/// calibration cache that warm-starts every epoch's sweep.
pub struct Replanner {
    pub cfg: ReplanConfig,
    cache: CalibCache,
    /// Fingerprint of the workload the cache's calibrations belong to
    /// (`0` = empty cache). A changed CDF snapshot resets the cache:
    /// warm-starting across epochs is only sound while the distribution
    /// is unchanged, because [`CalibCache`] keys by truncation cuts only.
    cache_fp: u64,
    current: TieredPlan,
}

impl Replanner {
    /// Seed with the fleet's initially provisioned plan.
    pub fn new(cfg: ReplanConfig, initial: TieredPlan) -> Self {
        Replanner {
            cfg,
            cache: CalibCache::new(),
            cache_fp: 0,
            current: initial,
        }
    }

    /// The plan the fleet is currently provisioned to.
    pub fn current(&self) -> &TieredPlan {
        &self.current
    }

    /// The shared warm-start cache (diagnostics).
    pub fn cache(&self) -> &CalibCache {
        &self.cache
    }

    /// Re-plan against a drifted input (new rate and/or CDF snapshot).
    /// `input.lambda` must be positive; the input's workload is typically
    /// an [`crate::workload::online::OnlineEstimator`] snapshot.
    pub fn replan(&mut self, input: &PlanInput) -> Result<ReplanOutcome, SizingError> {
        let fp = workload_fingerprint(&input.workload);
        let warm = fp == self.cache_fp;
        if !warm {
            self.cache = CalibCache::new();
            self.cache_fp = fp;
        }
        let cur = self.current.clone();
        let k = cur.k();

        // Option 1: resize in place at the current layout.
        let hold = plan_tiers(input, &cur.spec, &cur.gammas, true, Some(&self.cache));

        // Option 2: cheapest candidate layout under the drifted input.
        let mut candidate = plan_spec_sweep_gamma_cached(input, &cur.spec, &self.cache);
        if self.cfg.sweep_boundaries {
            // Bound-and-prune sweep (argmin bit-identical to the full
            // sweep). Unchanged fingerprint + incremental: re-sweep only
            // the previous layout's neighbourhood exactly and prune the
            // rest of the grid against it (same plan, ~10x less work).
            let swept = if self.cfg.incremental && warm {
                let seeds = layout_neighborhood(input, &cur);
                sweep_tiered_pruned_seeded(input, k, &self.cache, &seeds)
            } else {
                sweep_tiered_pruned(input, k, &self.cache)
            };
            if let Ok((swept, _)) = swept {
                let better = match &candidate {
                    Ok(c) => swept.cost_yr < c.cost_yr - 1e-9,
                    Err(_) => true,
                };
                if better {
                    candidate = Ok(swept);
                }
            }
        }

        let (mut adopted, switched, cand_cost, held_cost) = match (hold, candidate) {
            (Ok(h), Ok(c)) => {
                let structurally_different =
                    c.boundaries() != cur.boundaries() || c.gammas != cur.gammas;
                let cand_cost = c.cost_yr;
                let held_cost = h.cost_yr;
                if structurally_different
                    && c.cost_yr < h.cost_yr * (1.0 - self.cfg.switch_threshold)
                {
                    (c, true, cand_cost, held_cost)
                } else {
                    (h, false, cand_cost, held_cost)
                }
            }
            // The old layout became infeasible under the new input: a
            // forced switch, no hysteresis.
            (Err(_), Ok(c)) => {
                let cost = c.cost_yr;
                (c, true, cost, f64::INFINITY)
            }
            (Ok(h), Err(_)) => {
                let cost = h.cost_yr;
                (h, false, f64::INFINITY, cost)
            }
            (Err(e), Err(_)) => return Err(e),
        };

        // Scale-down dead-band, only meaningful when the layout is stable
        // (a switched layout re-provisions from the plan's own counts).
        if !switched && adopted.k() == cur.k() {
            let mut held_any = false;
            for (pool, cur_pool) in adopted.tiers.iter_mut().zip(&cur.tiers) {
                let target = pool.n_gpus;
                let have = cur_pool.n_gpus;
                if target < have
                    && (target as f64) >= have as f64 * (1.0 - self.cfg.scale_down_deadband)
                {
                    pool.n_gpus = have;
                    held_any = true;
                }
            }
            if held_any {
                let counts: Vec<u64> = adopted.tiers.iter().map(|t| t.n_gpus).collect();
                let rates: Vec<f64> =
                    adopted.spec.tiers.iter().map(|t| t.cost_hr).collect();
                adopted.cost_yr = fleet_cost_yr_tiered(&counts, &rates);
            }
        }

        self.current = adopted.clone();
        Ok(ReplanOutcome {
            plan: adopted,
            switched_layout: switched,
            candidate_cost_yr: cand_cost,
            held_cost_yr: held_cost,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::tiered::plan_spec_sweep_gamma;
    use crate::workload::traces;

    fn input(lambda: f64) -> PlanInput {
        let mut i = PlanInput::new(traces::azure(), lambda);
        i.cfg.mc_samples = 8_000;
        i
    }

    fn seeded(lambda: f64, cfg: ReplanConfig) -> Replanner {
        let inp = input(lambda);
        let spec = inp.gpu.fleet_spec(&[4096]);
        let init = plan_spec_sweep_gamma(&inp, &spec).unwrap();
        Replanner::new(cfg, init)
    }

    #[test]
    fn small_rate_dip_is_held_by_deadband() {
        let mut rp = seeded(1000.0, ReplanConfig::default());
        let before = rp.current().gpu_counts();
        // 4% fewer arrivals: targets shrink by < the 10% dead-band.
        let out = rp.replan(&input(960.0)).unwrap();
        assert!(!out.switched_layout);
        assert_eq!(out.plan.gpu_counts(), before, "dead-band must hold");
    }

    #[test]
    fn large_rate_drop_scales_down() {
        let mut rp = seeded(1000.0, ReplanConfig::default());
        let before = rp.current().total_gpus();
        let out = rp.replan(&input(400.0)).unwrap();
        assert!(out.plan.total_gpus() < before);
    }

    #[test]
    fn rate_spike_scales_up_immediately() {
        let mut rp = seeded(1000.0, ReplanConfig::default());
        let before = rp.current().total_gpus();
        let out = rp.replan(&input(1500.0)).unwrap();
        assert!(out.plan.total_gpus() > before);
    }

    #[test]
    fn infinite_switch_threshold_never_switches_layout() {
        let mut rp = seeded(1000.0, ReplanConfig {
            switch_threshold: 1.0,
            sweep_boundaries: true,
            ..ReplanConfig::default()
        });
        let bounds = rp.current().boundaries();
        for lam in [300.0, 1200.0, 700.0] {
            let out = rp.replan(&input(lam)).unwrap();
            assert!(!out.switched_layout);
            assert_eq!(out.plan.boundaries(), bounds);
        }
    }

    #[test]
    fn candidate_never_costs_more_than_hold_at_k2() {
        // At K = 2 no gamma clamping applies, so the gamma-grid candidate
        // dominates the fixed-gamma hold plan.
        let mut rp = seeded(1000.0, ReplanConfig::default());
        let out = rp.replan(&input(650.0)).unwrap();
        assert!(out.candidate_cost_yr <= out.held_cost_yr + 1e-6);
    }

    #[test]
    fn incremental_replans_match_full_sweeps() {
        // Incremental (neighbourhood-seeded) boundary sweeps must adopt
        // the identical plan as full sweeps at every epoch — the seeds
        // only move work, never the argmin.
        let mk = |incremental| {
            seeded(
                1000.0,
                ReplanConfig {
                    sweep_boundaries: true,
                    incremental,
                    ..ReplanConfig::default()
                },
            )
        };
        let mut inc = mk(true);
        let mut full = mk(false);
        for lam in [1000.0, 1050.0, 940.0, 700.0, 1300.0] {
            let a = inc.replan(&input(lam)).unwrap();
            let b = full.replan(&input(lam)).unwrap();
            assert_eq!(a.plan.cost_yr.to_bits(), b.plan.cost_yr.to_bits(), "{lam}");
            assert_eq!(a.plan.boundaries(), b.plan.boundaries(), "{lam}");
            assert_eq!(a.plan.gpu_counts(), b.plan.gpu_counts(), "{lam}");
            assert_eq!(a.switched_layout, b.switched_layout, "{lam}");
        }
    }

    #[test]
    fn warm_cache_grows_across_epochs() {
        let mut rp = seeded(1000.0, ReplanConfig::default());
        rp.replan(&input(900.0)).unwrap();
        let after_one = rp.cache().len();
        assert!(after_one > 0);
        rp.replan(&input(900.0)).unwrap();
        // Same input again: every calibration is already memoized.
        assert_eq!(rp.cache().len(), after_one);
    }
}
