//! Per-pool sizing: Erlang-C inversion with the rho_max utilization cap
//! (paper Eq. 11, §4.1, App. A).
//!
//! The minimum GPU count is found by binary search over
//! `[ceil(a / rho_max), 10 ceil(a)]` with `a = lambda / mu_gpu`, using the
//! feasibility predicate of Eq. 8. W99 is monotone non-increasing in the
//! GPU count above the stability point (verified by test), so binary search
//! is valid.
//!
//! §Perf: each feasibility probe evaluates Erlang-C at `(c, rho)` with
//! c up to tens of thousands of slots — thousands of recurrence terms per
//! probe — and the sweep layer re-runs identical inversions whenever two
//! boundary combinations share a tier (same lambda, same calibration).
//! Those evaluations now go through the thread-local memo in
//! `queueing::erlang::erlang_c_cached` (via `kimura::w99`): bit-identical
//! results, each distinct cell paid once per thread. The first-fill/warm
//! cell wall times are tracked in `BENCH_planner.json`
//! (`sizing_first_fill_ms` / `sizing_warm_ms`).
//!
//! On top of the memo, the bisection **warm-starts its bracket** from the
//! last inversion with the same slot shape on this thread (the sweep's
//! neighbouring cell): feasibility is monotone non-decreasing in the GPU
//! count — W99 is monotone non-increasing above the stability point (the
//! `w99_monotone_in_n_above_stability` test) and utilization strictly
//! decreasing — so a probe at the hint either tightens the upper or the
//! lower end of the bracket and the bisection still lands on exactly the
//! minimal feasible count. Results are bit-identical with hints on, off,
//! stale, or wrong (property-tested); only the probe count changes
//! (`inversion_probes_{cold,warm}` in `BENCH_planner.json`).
//!
//! ## SLO-budget note (paper inconsistency)
//!
//! Taken literally, Eq. 8's budget `T_slo - T_prefill^(99) - t_iter` is
//! *negative* for the paper's own LMSYS configuration (682 slots/GPU gives
//! t_iter = 451 ms against a 500 ms SLO), yet §7.4 reports all SLOs met
//! because sizing is rho_max-dominated. We therefore support two modes:
//! * `strict = false` (default, paper-consistent): when the Eq. 8 budget is
//!   negative, fall back to requiring `W99 <= T_slo` (pure queue-wait SLO);
//!   sizing is then rho_max-dominated exactly as in §7.4.
//! * `strict = true`: Eq. 8 verbatim; returns `Infeasible` when prefill
//!   alone exceeds the SLO.

use crate::queueing::mgc::PoolModel;
use crate::queueing::service::ServiceStats;

/// Sizing failure modes.
#[derive(Clone, Debug, PartialEq)]
pub enum SizingError {
    /// P99 prefill + one iteration exceed the SLO at any fleet size
    /// (only under `strict`).
    InfeasibleSlo { budget_s: f64 },
    /// No fleet size within the search interval satisfied the constraint.
    SearchExhausted { hi: u64 },
    /// The K-tier boundary sweep found no feasible cell (candidate grid
    /// smaller than K−1, or every cell infeasible).
    NoFeasibleTiering { k: usize },
}

impl std::fmt::Display for SizingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SizingError::InfeasibleSlo { budget_s } => write!(
                f,
                "SLO infeasible: prefill + t_iter leave a {budget_s:.3}s queue budget"
            ),
            SizingError::SearchExhausted { hi } => {
                write!(f, "no feasible GPU count found up to n = {hi}")
            }
            SizingError::NoFeasibleTiering { k } => {
                write!(f, "no feasible K = {k} tiering over the candidate boundaries")
            }
        }
    }
}

impl std::error::Error for SizingError {}

thread_local! {
    /// Last inversion result per slot shape on this thread — the bracket
    /// warm-start for the next cell sized at the same `n_slots` (see the
    /// module §Perf note). Purely an accelerator: results are identical
    /// whatever this holds.
    static WARM_HINTS: std::cell::RefCell<crate::util::hash::FxHashMap<u32, u64>> =
        std::cell::RefCell::new(crate::util::hash::FxHashMap::default());
    /// (feasibility probes, inversions) on this thread — bench telemetry.
    static PROBE_STATS: std::cell::Cell<(u64, u64)> = const { std::cell::Cell::new((0, 0)) };
}

/// This thread's cumulative `(feasibility probes, inversions)` counters.
pub fn sizing_probe_stats() -> (u64, u64) {
    PROBE_STATS.with(|c| c.get())
}

/// Drop this thread's warm-start hints (benches/tests measure cold runs).
pub fn clear_warm_hints() {
    WARM_HINTS.with(|h| h.borrow_mut().clear());
}

/// Minimum GPU count for a pool (Eq. 11). Zero-traffic pools need no GPUs.
pub fn min_gpus(
    lambda: f64,
    svc: &ServiceStats,
    t_slo: f64,
    rho_max: f64,
    strict: bool,
) -> Result<u64, SizingError> {
    assert!(rho_max > 0.0 && rho_max < 1.0);
    if lambda <= 0.0 {
        return Ok(0);
    }
    // Effective queue-wait budget per Eq. 8 (see module note).
    let eq8_budget = t_slo - svc.p99_prefill_s - svc.t_iter_s;
    let budget = if eq8_budget >= 0.0 {
        eq8_budget
    } else if strict {
        return Err(SizingError::InfeasibleSlo {
            budget_s: eq8_budget,
        });
    } else {
        t_slo
    };

    let a = lambda / svc.mu_gpu(); // offered load in GPUs
    let lo = (a / rho_max).ceil().max(1.0) as u64;
    let hi = (10.0 * a.ceil()).max(lo as f64 + 1.0) as u64;

    let feasible = |n: u64| -> bool {
        PROBE_STATS.with(|c| {
            let (p, i) = c.get();
            c.set((p + 1, i));
        });
        let p = PoolModel::new(lambda, n, *svc);
        p.utilization() <= rho_max && p.w99() <= budget
    };
    PROBE_STATS.with(|c| {
        let (p, i) = c.get();
        c.set((p, i + 1));
    });

    let hint = WARM_HINTS.with(|h| h.borrow().get(&svc.n_slots).copied());
    let result = min_feasible(lo, hi, hint, &feasible);
    if let Ok(n) = result {
        WARM_HINTS.with(|h| {
            h.borrow_mut().insert(svc.n_slots, n);
        });
    }
    result
}

/// Bisect for the minimal feasible count in `[lo, hi]`, optionally
/// tightening the initial bracket at a warm-start `hint` (see the module
/// §Perf note). Requires `feasible` monotone non-decreasing in `n`; the
/// returned minimum — and the `SearchExhausted` contract at `hi` — are
/// then independent of the hint.
fn min_feasible(
    lo: u64,
    hi: u64,
    hint: Option<u64>,
    feasible: &impl Fn(u64) -> bool,
) -> Result<u64, SizingError> {
    if feasible(lo) {
        return Ok(lo);
    }
    let (mut l, mut r) = (lo, 0u64);
    if let Some(h) = hint.filter(|&h| h > lo && h < hi) {
        if feasible(h) {
            r = h;
        } else {
            l = h;
        }
    }
    if r == 0 {
        if !feasible(hi) {
            return Err(SizingError::SearchExhausted { hi });
        }
        r = hi;
    }
    // Invariant: !feasible(l), feasible(r).
    while r - l > 1 {
        let m = l + (r - l) / 2;
        if feasible(m) {
            r = m;
        } else {
            l = m;
        }
    }
    Ok(r)
}

/// The continuous relaxation of Eq. 11 in the rho_max-dominated regime
/// (§7.4): `n ~= lambda / (rho_max * mu_gpu)`. Used by the marginal-cost
/// analysis (Prop. 1) where the derivative `dn/dlambda = 1/(rho_max mu_gpu)`
/// is needed.
pub fn continuous_gpus(lambda: f64, svc: &ServiceStats, rho_max: f64) -> f64 {
    lambda / (rho_max * svc.mu_gpu())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuProfile;
    use crate::queueing::service::calibrate;
    use crate::workload::traces;

    fn svc(n_slots: u32) -> ServiceStats {
        let w = traces::azure();
        let g = GpuProfile::a100_llama70b();
        calibrate(&w.cdf, &w.output, &g, n_slots, 10_000, 11)
    }

    #[test]
    fn zero_traffic_needs_zero_gpus() {
        assert_eq!(min_gpus(0.0, &svc(16), 0.5, 0.85, false).unwrap(), 0);
    }

    #[test]
    fn result_is_feasible_and_minimal() {
        let s = svc(16);
        let n = min_gpus(500.0, &s, 0.5, 0.85, false).unwrap();
        let at = |k: u64| PoolModel::new(500.0, k, s);
        assert!(at(n).utilization() <= 0.85);
        // Minimality: one fewer GPU must violate the cap or the wait budget.
        if n > 1 {
            let prev = at(n - 1);
            assert!(prev.utilization() > 0.85 || prev.w99() > 0.5);
        }
    }

    #[test]
    fn rho_max_dominates_in_many_server_regime() {
        // Large fleet: Eq. 11 reduces to ceil(lambda / (rho_max mu_gpu))
        // (paper §7.4).
        let s = svc(16);
        let lambda = 1000.0;
        let n = min_gpus(lambda, &s, 0.5, 0.85, false).unwrap();
        let n_cap = (lambda / (0.85 * s.mu_gpu())).ceil() as u64;
        assert!(
            n == n_cap || n == n_cap + 1,
            "n={n} vs rho-cap bound {n_cap}"
        );
    }

    #[test]
    fn sizing_scales_linearly_with_lambda() {
        // Table 6's premise: proportional savings require near-linear
        // scaling of n with lambda.
        let s = svc(16);
        let n1 = min_gpus(100.0, &s, 0.5, 0.85, false).unwrap();
        let n20 = min_gpus(2000.0, &s, 0.5, 0.85, false).unwrap();
        let ratio = n20 as f64 / n1 as f64;
        assert!((ratio - 20.0).abs() < 1.5, "ratio={ratio}");
    }

    #[test]
    fn strict_mode_rejects_impossible_prefill() {
        // 682-slot short pool: t_iter = 451 ms; any multi-chunk prefill
        // blows a 500 ms SLO (the paper's LMSYS configuration).
        let w = traces::lmsys();
        let g = GpuProfile::a100_llama70b();
        let s = calibrate(&w.cdf, &w.output, &g, 682, 10_000, 12);
        let strict = min_gpus(500.0, &s, 0.5, 0.85, true);
        assert!(matches!(strict, Err(SizingError::InfeasibleSlo { .. })));
        // Paper-consistent mode sizes by rho_max instead.
        let relaxed = min_gpus(500.0, &s, 0.5, 0.85, false).unwrap();
        assert!(relaxed > 0);
    }

    #[test]
    fn inversion_is_stable_under_a_warm_erlang_memo() {
        // The memoized Erlang-C path must leave the inversion bit-stable:
        // repeating the same search (memo now warm) and interleaving
        // foreign cells cannot change the result.
        let s = svc(16);
        let cold: Vec<u64> = (1..=6)
            .map(|i| min_gpus(150.0 * i as f64, &s, 0.5, 0.85, false).unwrap())
            .collect();
        let _ = min_gpus(777.0, &s, 0.5, 0.85, false).unwrap();
        let warm: Vec<u64> = (1..=6)
            .map(|i| min_gpus(150.0 * i as f64, &s, 0.5, 0.85, false).unwrap())
            .collect();
        assert_eq!(cold, warm);
    }

    #[test]
    fn warm_hints_never_change_the_inversion() {
        // The bracket warm-start is an accelerator only: cold (hints
        // cleared before every call), warm (hints left from the previous
        // call), and stale (hints poisoned by interleaved foreign sizes)
        // inversions must return the identical GPU count.
        let s = svc(16);
        let lambdas: Vec<f64> = (1..=12).map(|i| 130.0 * i as f64).collect();
        let cold: Vec<u64> = lambdas
            .iter()
            .map(|&lam| {
                clear_warm_hints();
                min_gpus(lam, &s, 0.5, 0.85, false).unwrap()
            })
            .collect();
        clear_warm_hints();
        let warm: Vec<u64> = lambdas
            .iter()
            .map(|&lam| min_gpus(lam, &s, 0.5, 0.85, false).unwrap())
            .collect();
        assert_eq!(cold, warm);
        // Stale hints: size something far away at the same slot shape
        // between every probe.
        let stale: Vec<u64> = lambdas
            .iter()
            .map(|&lam| {
                let _ = min_gpus(7.0, &s, 0.5, 0.85, false).unwrap();
                min_gpus(lam, &s, 0.5, 0.85, false).unwrap()
            })
            .collect();
        assert_eq!(cold, stale);
    }

    #[test]
    fn warm_hints_cut_probe_counts() {
        let s = svc(16);
        let lambdas: Vec<f64> = (1..=10).map(|i| 140.0 * i as f64).collect();
        clear_warm_hints();
        let (p0, _) = sizing_probe_stats();
        for &lam in &lambdas {
            clear_warm_hints();
            min_gpus(lam, &s, 0.5, 0.85, false).unwrap();
        }
        let (p1, _) = sizing_probe_stats();
        // Re-run the identical grid twice so every cell has a one-off
        // neighbour hint at the same slot shape.
        for &lam in &lambdas {
            min_gpus(lam, &s, 0.5, 0.85, false).unwrap();
        }
        let (p2, _) = sizing_probe_stats();
        for &lam in &lambdas {
            min_gpus(lam, &s, 0.5, 0.85, false).unwrap();
        }
        let (p3, _) = sizing_probe_stats();
        let cold = p1 - p0;
        let warm = p3 - p2;
        assert!(
            warm <= cold,
            "warm probes {warm} must not exceed cold probes {cold}"
        );
    }

    #[test]
    fn tighter_slo_needs_no_fewer_gpus() {
        let s = svc(16);
        let loose = min_gpus(800.0, &s, 5.0, 0.85, false).unwrap();
        let tight = min_gpus(800.0, &s, 0.5, 0.85, false).unwrap();
        assert!(tight >= loose);
    }

    #[test]
    fn w99_monotone_in_n_above_stability() {
        // The binary-search validity assumption (module doc).
        let s = svc(16);
        let lambda = 300.0;
        let start = (lambda / s.mu_gpu()).ceil() as u64 + 1;
        let mut last = f64::INFINITY;
        for n in start..start + 40 {
            let w = PoolModel::new(lambda, n, s).w99();
            assert!(w <= last + 1e-12, "W99 must not increase with n");
            last = w;
        }
    }

    #[test]
    fn continuous_matches_integer_in_cap_regime() {
        let s = svc(16);
        let lambda = 1500.0;
        let n = min_gpus(lambda, &s, 0.5, 0.85, false).unwrap() as f64;
        let c = continuous_gpus(lambda, &s, 0.85);
        assert!((n - c).abs() <= 1.5, "integer {n} vs continuous {c}");
    }
}
