//! Fleet cost model (paper §3.3, Eq. 9–10).

use crate::config::GpuProfile;

/// Hours in the paper's annualization (Table 3: $/GPU-hr x 8,760 hr/yr).
pub const HOURS_PER_YEAR: f64 = 8760.0;

/// Annualized K-tier fleet cost `sum_i c_i n_i` (Eq. 9 generalized),
/// dollars/yr. `counts` and `rates_hr` are per-tier, in tier order; the
/// two-pool [`fleet_cost_yr`] is the K = 2 projection of this sum.
pub fn fleet_cost_yr_tiered(counts: &[u64], rates_hr: &[f64]) -> f64 {
    assert_eq!(counts.len(), rates_hr.len());
    let mut acc = 0.0;
    for (&n, &c) in counts.iter().zip(rates_hr) {
        acc += n as f64 * c;
    }
    acc * HOURS_PER_YEAR
}

/// Annualized fleet cost C(n_s, n_l) = c_s n_s + c_l n_l (Eq. 9), dollars/yr.
pub fn fleet_cost_yr(n_s: u64, n_l: u64, g: &GpuProfile) -> f64 {
    fleet_cost_yr_tiered(&[n_s, n_l], &[g.cost_short_hr, g.cost_long_hr])
}

/// Relative savings of `cost` versus `baseline` (Table 3's "Savings" column).
pub fn savings(baseline: f64, cost: f64) -> f64 {
    assert!(baseline > 0.0);
    1.0 - cost / baseline
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_homogeneous_azure_cost() {
        // Table 3: 284 GPUs x $2.21/hr x 8,760 hr = $5,498K/yr.
        let g = GpuProfile::a100_llama70b();
        let c = fleet_cost_yr(0, 284, &g);
        assert!((c / 1000.0 - 5498.0).abs() < 1.0, "cost={c}");
    }

    #[test]
    fn savings_formula() {
        assert!((savings(100.0, 60.0) - 0.4).abs() < 1e-12);
        assert!(savings(100.0, 100.0).abs() < 1e-12);
        assert!(savings(100.0, 120.0) < 0.0); // negative savings possible
    }

    #[test]
    fn tiered_cost_reduces_to_two_pool() {
        let g = GpuProfile::a100_llama70b();
        let two = fleet_cost_yr(12, 7, &g);
        let tiered = fleet_cost_yr_tiered(&[12, 7], &[g.cost_short_hr, g.cost_long_hr]);
        assert_eq!(two.to_bits(), tiered.to_bits());
        let three = fleet_cost_yr_tiered(&[10, 5, 2], &[1.0, 1.5, 2.21]);
        assert!((three - (10.0 + 7.5 + 4.42) * HOURS_PER_YEAR).abs() < 1e-9);
    }

    #[test]
    fn mixed_pool_costs_use_per_pool_rates() {
        let mut g = GpuProfile::a100_llama70b();
        g.cost_long_hr = 4.42; // phi = 2
        let c = fleet_cost_yr(10, 5, &g);
        assert!((c - (10.0 * 2.21 + 5.0 * 4.42) * 8760.0).abs() < 1e-9);
    }
}
